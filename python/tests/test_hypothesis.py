"""Hypothesis sweeps over the Pallas kernels' shape/value space."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import distance as K
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _points(seed, n, d, scale):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.normal(size=(n, d)) * scale, dtype=jnp.float32)


@given(
    seed=st.integers(0, 2**31 - 1),
    tiles=st.integers(1, 3),
    d=st.sampled_from(K.DIMS),
    nc=st.integers(1, K.TC),
    metric=st.sampled_from(K.METRICS),
    scale=st.floats(1e-2, 1e2),
)
@settings(**SETTINGS)
def test_gmm_assign_fuzz(seed, tiles, d, nc, metric, scale):
    pts = _points(seed, tiles * K.TP, d, scale)
    ctr = _points(seed + 1, K.TC, d, scale)
    dmin, amin = K.gmm_assign(pts, ctr, jnp.array([[nc]], jnp.int32),
                              metric=metric)
    rd, ra = ref.gmm_assign(pts, ctr, nc, metric)
    assert_allclose(np.asarray(dmin), np.asarray(rd), rtol=1e-4, atol=1e-4)
    # argmin may legitimately differ on exact ties; check distances agree
    d_full = np.asarray(ref.dist_matrix(pts, ctr, metric))
    picked = d_full[np.arange(len(pts)), np.asarray(amin)]
    assert_allclose(picked, np.asarray(rd), rtol=1e-4, atol=1e-4)
    assert (np.asarray(amin) < nc).all()


@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.sampled_from(K.DIMS),
    metric=st.sampled_from(K.METRICS),
    steps=st.integers(1, 12),
)
@settings(**SETTINGS)
def test_gmm_incremental_consistency_fuzz(seed, d, metric, steps):
    """Incremental gmm_update chain == one-shot gmm_assign (distances)."""
    pts = _points(seed, K.TP, d, 1.0)
    ctr = _points(seed + 2, K.TC, d, 1.0)
    dmin, amin = K.gmm_assign(pts, ctr, jnp.array([[1]], jnp.int32),
                              metric=metric)
    for j in range(1, steps + 1):
        dmin, amin = K.gmm_update(pts, ctr[j:j + 1], dmin, amin,
                                  jnp.array([[j]], jnp.int32), metric=metric)
    fd, _ = K.gmm_assign(pts, ctr, jnp.array([[steps + 1]], jnp.int32),
                         metric=metric)
    assert_allclose(np.asarray(dmin), np.asarray(fd), rtol=1e-4, atol=1e-5)


@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.sampled_from(K.DIMS),
    metric=st.sampled_from(K.METRICS),
)
@settings(**SETTINGS)
def test_triangle_inequality_fuzz(seed, d, metric):
    """Both metrics must satisfy the triangle inequality (the paper's proofs
    depend on it) — checked on kernel outputs."""
    pts = _points(seed, K.TP, d, 1.0)
    ctr = _points(seed + 3, K.TC, d, 1.0)
    dm = np.asarray(K.pairwise(pts, ctr, metric=metric))
    # triangle through the first 16x16x16 triple block via the oracle
    a, b, c = pts[:16], ctr[:16], pts[16:32]
    dab = np.asarray(ref.dist_matrix(a, b, metric))
    dbc = np.asarray(ref.dist_matrix(b, c, metric)) if metric else None
    dac = np.asarray(ref.dist_matrix(a, c, metric))
    for i in range(16):
        for j in range(16):
            for k in range(16):
                assert dac[i, k] <= dab[i, j] + dbc[j, k] + 1e-4
    assert (dm >= -1e-6).all()
