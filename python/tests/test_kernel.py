"""Pallas kernels vs. the pure-jnp oracle — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import distance as K
from compile.kernels import ref

METRICS = list(K.METRICS)
DIMS = list(K.DIMS)


def rng(seed=0):
    return np.random.default_rng(seed)


def rand_points(r, n, d, scale=1.0):
    return jnp.asarray(r.normal(size=(n, d)) * scale, dtype=jnp.float32)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("d", DIMS)
def test_gmm_assign_matches_ref(metric, d):
    r = rng(42)
    pts = rand_points(r, 2 * K.TP, d)
    ctr = rand_points(r, K.TC, d)
    nc = jnp.array([[37]], dtype=jnp.int32)
    dmin, amin = K.gmm_assign(pts, ctr, nc, metric=metric)
    rdmin, ramin = ref.gmm_assign(pts, ctr, 37, metric)
    assert_allclose(np.asarray(dmin), np.asarray(rdmin), rtol=1e-5, atol=1e-5)
    # argmin may differ on near-ties (expanded vs exact distance form):
    # require the picked center to achieve the reference min-distance
    d_full = np.asarray(ref.dist_matrix(pts, ctr, metric))
    picked = d_full[np.arange(len(pts)), np.asarray(amin)]
    assert_allclose(picked, np.asarray(rdmin), rtol=1e-4, atol=1e-4)
    assert (np.asarray(amin) < 37).all()


@pytest.mark.parametrize("metric", METRICS)
def test_gmm_assign_masks_padded_centers(metric):
    """Sentinel-masked centers must never win argmin, even if they are at
    distance zero from a point."""
    r = rng(1)
    d = DIMS[0]
    pts = rand_points(r, K.TP, d)
    ctr = rand_points(r, K.TC, d)
    # center 5 (beyond mask nc=3) is an exact copy of point 0
    ctr = ctr.at[5].set(pts[0])
    nc = jnp.array([[3]], dtype=jnp.int32)
    _, amin = K.gmm_assign(pts, ctr, nc, metric=metric)
    assert int(amin[0]) < 3


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("d", DIMS)
def test_gmm_update_matches_ref(metric, d):
    r = rng(7)
    pts = rand_points(r, K.TP, d)
    ctr0 = rand_points(r, K.TC, d)
    nc = jnp.array([[10]], dtype=jnp.int32)
    dmin, amin = K.gmm_assign(pts, ctr0, nc, metric=metric)
    newc = rand_points(r, 1, d)
    idx = jnp.array([[10]], dtype=jnp.int32)
    ndmin, namin = K.gmm_update(pts, newc, dmin, amin, idx, metric=metric)
    rdmin, ramin = ref.gmm_update(np.asarray(pts), np.asarray(newc)[0],
                                  np.asarray(dmin), np.asarray(amin), 10,
                                  metric)
    assert_allclose(np.asarray(ndmin), np.asarray(rdmin), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(namin), np.asarray(ramin))


@pytest.mark.parametrize("metric", METRICS)
def test_gmm_update_equals_full_assign(metric):
    """Folding centers one at a time must equal one shot against all of them."""
    r = rng(3)
    d = DIMS[0]
    pts = rand_points(r, K.TP, d)
    ctr = rand_points(r, K.TC, d)
    nc1 = jnp.array([[1]], dtype=jnp.int32)
    dmin, amin = K.gmm_assign(pts, ctr, nc1, metric=metric)
    for j in range(1, 8):
        idx = jnp.array([[j]], dtype=jnp.int32)
        dmin, amin = K.gmm_update(pts, ctr[j:j + 1], dmin, amin, idx,
                                  metric=metric)
    fdmin, famin = K.gmm_assign(pts, ctr, jnp.array([[8]], jnp.int32),
                                metric=metric)
    assert_allclose(np.asarray(dmin), np.asarray(fdmin), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(amin), np.asarray(famin))


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("d", DIMS)
def test_pairwise_matches_ref(metric, d):
    r = rng(11)
    a = rand_points(r, K.TP, d)
    b = rand_points(r, K.TC, d)
    out = K.pairwise(a, b, metric=metric)
    expect = ref.dist_matrix(a, b, metric)
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", METRICS)
def test_zero_padding_in_feature_dim_is_neutral(metric):
    """Zero-padding the feature dim (Rust protocol) must not change distances."""
    r = rng(5)
    raw = rand_points(r, K.TP, 25)
    pad32 = jnp.pad(raw, ((0, 0), (0, 7)))
    braw = rand_points(r, K.TC, 25)
    bpad = jnp.pad(braw, ((0, 0), (0, 7)))
    d_raw = ref.dist_matrix(raw, braw, metric)
    d_pad = np.asarray(K.pairwise(pad32, bpad, metric=metric))
    assert_allclose(d_pad, np.asarray(d_raw), rtol=1e-5, atol=1e-5)


def test_pairwise_self_distance_near_zero_euclidean():
    """Self-distance under the MXU-friendly expanded form |x|^2+|c|^2-2xc.

    The expanded form trades exactness at d~0 for an MXU-shaped matmul:
    cancellation leaves O(sqrt(eps_f32)*|x|) residue, so the tolerance here
    is the formula's actual precision, not 0.  (GMM only consumes min-dists,
    where this residue is harmless; the Rust scalar path uses the exact
    difference form when distances near zero matter.)"""
    r = rng(9)
    a = rand_points(r, K.TP, DIMS[0])
    b = jnp.zeros((K.TC, DIMS[0]), jnp.float32).at[: K.TP].set(a[: K.TC])
    out = np.asarray(K.pairwise(a, b, metric="euclidean"))
    diag = np.diag(out)[: min(K.TP, K.TC)]
    scale = np.sqrt((np.asarray(a[: K.TC]) ** 2).sum(axis=1))
    assert (diag <= 2e-3 * np.maximum(scale, 1.0) + 1e-4).all()


@pytest.mark.parametrize("metric", METRICS)
def test_distances_nonnegative_and_symmetric(metric):
    r = rng(13)
    a = rand_points(r, K.TP, DIMS[0])
    b = rand_points(r, K.TC, DIMS[0])
    dab = np.asarray(K.pairwise(a, b, metric=metric))
    assert (dab >= 0).all()
    # symmetry via the oracle on the transposed call
    dba = np.asarray(ref.dist_matrix(b, a, metric))
    assert_allclose(dab, dba.T, rtol=1e-5, atol=1e-5)


def test_cosine_zero_vector_guard():
    """The EPS guard must keep cosine distances finite on zero vectors."""
    a = jnp.zeros((K.TP, DIMS[0]), jnp.float32)
    b = jnp.ones((K.TC, DIMS[0]), jnp.float32)
    out = np.asarray(K.pairwise(a, b, metric="cosine"))
    assert np.isfinite(out).all()


def test_cosine_range():
    r = rng(17)
    a = rand_points(r, K.TP, DIMS[0])
    b = rand_points(r, K.TC, DIMS[0])
    out = np.asarray(K.pairwise(a, b, metric="cosine"))
    assert (out >= 0).all() and (out <= 1.0 + 1e-6).all()


@pytest.mark.parametrize("metric", METRICS)
def test_multi_tile_grid(metric):
    """Kernels must behave identically across grid tiles (4-tile call)."""
    r = rng(19)
    d = DIMS[0]
    pts = rand_points(r, 4 * K.TP, d)
    ctr = rand_points(r, K.TC, d)
    nc = jnp.array([[K.TC]], dtype=jnp.int32)
    dmin, amin = K.gmm_assign(pts, ctr, nc, metric=metric)
    rd, ra = ref.gmm_assign(pts, ctr, K.TC, metric)
    assert_allclose(np.asarray(dmin), np.asarray(rd), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(amin), np.asarray(ra))
