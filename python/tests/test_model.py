"""L2 entry points: shapes, dtypes, and lowering sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import distance as K


def test_all_entries_enumerated():
    entries = model.aot_entries()
    # 3 kernels x 2 metrics x |DIMS| dims
    assert len(entries) == 3 * len(K.METRICS) * len(K.DIMS)
    for name in entries:
        kernel, metric, dtag = name.rsplit("_", 2)
        assert kernel in ("gmm_assign", "gmm_update", "pairwise")
        assert metric in K.METRICS
        assert int(dtag[1:]) in K.DIMS


@pytest.mark.parametrize("name", sorted(model.aot_entries()))
def test_entry_executes_with_example_specs(name):
    fn, specs = model.aot_entries()[name]
    args = []
    r = np.random.default_rng(0)
    for s in specs:
        if s.dtype == jnp.int32:
            args.append(jnp.ones(s.shape, jnp.int32))
        else:
            args.append(jnp.asarray(r.normal(size=s.shape), jnp.float32))
    out = fn(*args)
    assert isinstance(out, tuple)
    for o in out:
        assert np.isfinite(np.asarray(o)).any()


def test_manifest_mentions_every_entry():
    lines = model.manifest_lines()
    names = {l.split("=", 1)[1] for l in lines if l.startswith("entry=")}
    assert names == set(model.aot_entries())
    assert f"np={K.NP}" in lines
    assert f"tc={K.TC}" in lines


@pytest.mark.parametrize("name", ["gmm_update_euclidean_d32",
                                  "pairwise_cosine_d64"])
def test_entry_lowers_to_stablehlo(name):
    fn, specs = model.aot_entries()[name]
    lowered = jax.jit(fn).lower(*specs)
    text = str(lowered.compiler_ir("stablehlo"))
    assert "stablehlo" in text or "func.func" in text
