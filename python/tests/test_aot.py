"""AOT pipeline: HLO-text artifacts are produced and parseable."""

import os
import subprocess
import sys

import pytest

from compile import aot, model

ENTRY = "gmm_update_euclidean_d32"


@pytest.fixture(scope="module")
def hlo_text():
    fn, specs = model.aot_entries()[ENTRY]
    return aot.lower_entry(ENTRY, fn, specs)


def test_hlo_text_has_entry_computation(hlo_text):
    assert "ENTRY" in hlo_text
    assert "HloModule" in hlo_text


def test_hlo_text_shapes_match_manifest(hlo_text):
    # the entry signature must mention the fixed tile geometry
    from compile.kernels import distance as K
    assert f"f32[{K.NP},32]" in hlo_text.replace(" ", "")


def test_hlo_is_text_not_proto(hlo_text):
    # serialized protos are binary; text must be ascii-decodable
    hlo_text.encode("ascii")


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    cmd = [sys.executable, "-m", "compile.aot", "--out", str(out),
           "--only", ENTRY]
    env = dict(os.environ)
    subprocess.run(cmd, check=True, cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env)
    files = sorted(p.name for p in out.iterdir())
    assert f"{ENTRY}.hlo.txt" in files
    assert "manifest.txt" in files
    text = (out / f"{ENTRY}.hlo.txt").read_text()
    assert "ENTRY" in text


def test_aot_rejects_unknown_entry(tmp_path):
    cmd = [sys.executable, "-m", "compile.aot", "--out", str(tmp_path),
           "--only", "nope_not_real"]
    proc = subprocess.run(cmd, capture_output=True, cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode != 0
