"""AOT lowering: JAX entries -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile().serialize()`` / serialized HloModuleProto)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the published ``xla``
crate) rejects with ``proto.id() <= INT_MAX``.  The HLO *text* parser
reassigns ids on load, so text round-trips cleanly.  See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name, fn, specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts",
                        help="output directory for *.hlo.txt artifacts")
    parser.add_argument("--only", default=None,
                        help="comma-separated entry names (default: all)")
    args = parser.parse_args()

    os.makedirs(args.out, exist_ok=True)
    entries = model.aot_entries()
    if args.only:
        wanted = set(args.only.split(","))
        entries = {k: v for k, v in entries.items() if k in wanted}
        missing = wanted - set(entries)
        if missing:
            raise SystemExit(f"unknown entries: {sorted(missing)}")

    for name, (fn, specs) in sorted(entries.items()):
        text = lower_entry(name, fn, specs)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(model.manifest_lines()) + "\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
