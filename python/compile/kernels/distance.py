"""L1 — Pallas distance kernels for the GMM / coreset hot path.

The paper's hot spot is the O(n*tau) distance evaluation inside the GMM
(Gonzalez) clustering and the streaming assignment loop: every point of the
input must repeatedly be compared against the current set of centers.  This
module implements that hot spot as tiled Pallas kernels:

  * ``gmm_assign``  — for a block of points, distance to every center, plus
    min/argmin reduction (used for initial assignment and for the streaming
    restructure step).
  * ``gmm_update``  — incremental GMM iteration: distance of every point to
    ONE new center, folded into the running (min-dist, argmin) state.  This
    is the O(n)-per-iteration inner loop of Algorithm 1 (SeqCoreset).
  * ``pairwise``    — a full distance tile between two point blocks (used to
    precompute coreset distance matrices for the local-search / exhaustive
    final step).

TPU adaptation (see DESIGN.md §5): points are streamed HBM->VMEM in
``TP x D`` tiles via the BlockSpec grid, the center tile (``TC x D``) stays
VMEM-resident, and the inner product runs as an MXU-shaped ``x @ c.T``
matmul with ``preferred_element_type=float32``.  Kernels MUST be lowered
with ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that the Rust runtime
(xla crate) executes directly.

Padding protocol (the Rust caller relies on this):
  * the feature dimension is zero-padded up to ``D`` — this changes neither
    Euclidean nor cosine distances;
  * centers beyond ``n_centers`` (a (1,1) int32 operand) are masked with
    ``HUGE`` so they never win the min/argmin;
  * point rows beyond the true count produce garbage rows that the caller
    simply ignores.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# ---- Tile geometry (keep in sync with rust/src/runtime/shapes.rs) ----
#
# TP is a target-dependent tuning knob (EXPERIMENTS.md §Perf): the Pallas
# grid loop costs ~0.18 ms of dispatch per tile under interpret-mode XLA
# CPU (measured: TP=256 -> 5.8 ms/call, 2048 -> 1.0 ms, 8192 -> 0.2 ms),
# so CPU-validation builds use one full-size tile (grid = 1).  The tile
# still fits a real TPU's VMEM (8192x64x4B = 2 MiB points + 64 KiB centers
# out of ~16 MiB); a double-buffered TPU build would drop back to TP=256
# (64 KiB/tile) purely by changing this constant and re-running
# `make artifacts` — the BlockSpec schedule is unchanged.
TP = 8192      # points per tile (grid dimension walks these)
TC = 256        # centers per call (VMEM-resident tile)
NP = 8192       # points per AOT executable call (grid = NP // TP)
DIMS = (32, 64)  # supported padded feature dims (one artifact set each)

HUGE = 1.0e30   # sentinel distance for masked centers
EPS = 1.0e-12   # norm guard for the cosine metric

METRICS = ("euclidean", "cosine")


def dist_tile(x, c, metric):
    """Distance block between ``x`` (P x D) and ``c`` (C x D) -> (P x C).

    ``euclidean`` is the L2 distance computed via the expanded form
    ``|x|^2 + |c|^2 - 2 x.c`` so the inner product maps onto the MXU.
    ``cosine`` is the *metric* cosine distance of the paper (angular
    distance): ``arccos(cos_sim) / pi`` in [0, 1].
    """
    xc = jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    if metric == "euclidean":
        xx = jnp.sum(x * x, axis=1, keepdims=True)
        cc = jnp.sum(c * c, axis=1, keepdims=True).T
        d2 = jnp.maximum(xx + cc - 2.0 * xc, 0.0)
        return jnp.sqrt(d2)
    elif metric == "cosine":
        xn = jnp.sqrt(jnp.sum(x * x, axis=1, keepdims=True))
        cn = jnp.sqrt(jnp.sum(c * c, axis=1, keepdims=True)).T
        sim = xc / jnp.maximum(xn * cn, EPS)
        sim = jnp.clip(sim, -1.0, 1.0)
        return jnp.arccos(sim) * (1.0 / jnp.pi)
    raise ValueError(f"unknown metric {metric!r}")


# --------------------------------------------------------------------------
# gmm_assign: points vs. the full (masked) center tile, min + argmin.
# --------------------------------------------------------------------------

def _gmm_assign_kernel(metric, x_ref, c_ref, nc_ref, dmin_ref, amin_ref):
    x = x_ref[...]
    c = c_ref[...]
    nc = nc_ref[0, 0]
    d = dist_tile(x, c, metric)
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(col < nc, d, HUGE)
    dmin_ref[...] = jnp.min(d, axis=1)
    amin_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)


def gmm_assign(points, centers, n_centers, *, metric="euclidean"):
    """(NP x D, TC x D, (1,1) i32) -> (NP f32 min-dist, NP i32 argmin)."""
    np_, d = points.shape
    assert np_ % TP == 0, (np_, TP)
    assert centers.shape == (TC, d), centers.shape
    grid = (np_ // TP,)
    return pl.pallas_call(
        functools.partial(_gmm_assign_kernel, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TP, d), lambda i: (i, 0)),
            pl.BlockSpec((TC, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TP,), lambda i: (i,)),
            pl.BlockSpec((TP,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=True,
    )(points, centers, n_centers)


# --------------------------------------------------------------------------
# gmm_update: incremental fold of ONE new center into (min-dist, argmin).
# --------------------------------------------------------------------------

def _gmm_update_kernel(metric, x_ref, c_ref, dmin_ref, amin_ref, idx_ref,
                       odmin_ref, oamin_ref):
    x = x_ref[...]
    c = c_ref[...]                      # (1, D): the newly selected center
    d = dist_tile(x, c, metric)[:, 0]   # (TP,)
    cur_d = dmin_ref[...]
    cur_a = amin_ref[...]
    better = d < cur_d
    odmin_ref[...] = jnp.where(better, d, cur_d)
    oamin_ref[...] = jnp.where(better, idx_ref[0, 0], cur_a)


def gmm_update(points, center, dmin, amin, new_index, *, metric="euclidean"):
    """Fold one new center into the running GMM assignment state.

    points (NP x D), center (1 x D), dmin (NP,), amin (NP,) i32,
    new_index (1,1) i32 -> updated (dmin, amin).
    """
    np_, d = points.shape
    assert np_ % TP == 0
    assert center.shape == (1, d)
    grid = (np_ // TP,)
    return pl.pallas_call(
        functools.partial(_gmm_update_kernel, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TP, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((TP,), lambda i: (i,)),
            pl.BlockSpec((TP,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TP,), lambda i: (i,)),
            pl.BlockSpec((TP,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=True,
    )(points, center, dmin, amin, new_index)


# --------------------------------------------------------------------------
# pairwise: one full distance tile between two blocks.
# --------------------------------------------------------------------------

def _pairwise_kernel(metric, a_ref, b_ref, out_ref):
    out_ref[...] = dist_tile(a_ref[...], b_ref[...], metric)


def pairwise(a, b, *, metric="euclidean"):
    """(NA x D, TC x D) -> NA x TC distance matrix (grid over rows of a)."""
    na, d = a.shape
    assert na % TP == 0
    assert b.shape == (TC, d)
    grid = (na // TP,)
    return pl.pallas_call(
        functools.partial(_pairwise_kernel, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TP, d), lambda i: (i, 0)),
            pl.BlockSpec((TC, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TP, TC), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((na, TC), jnp.float32),
        interpret=True,
    )(a, b)
