"""Pure-jnp oracle for the Pallas distance kernels.

Every kernel in ``distance.py`` must match these reference implementations
to within float tolerance; ``python/tests/test_kernel.py`` pins that with
``assert_allclose`` and hypothesis sweeps.  The Rust scalar path
(rust/src/core/metric.rs) mirrors the same formulas, giving a three-way
correctness triangle: pallas == jnp-ref == rust-scalar.
"""

from __future__ import annotations

import jax.numpy as jnp

HUGE = 1.0e30
EPS = 1.0e-12


def dist_matrix(a, b, metric="euclidean"):
    """Dense distance matrix between rows of ``a`` and rows of ``b``."""
    if metric == "euclidean":
        diff = a[:, None, :] - b[None, :, :]
        return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    elif metric == "cosine":
        an = jnp.sqrt(jnp.sum(a * a, axis=1, keepdims=True))
        bn = jnp.sqrt(jnp.sum(b * b, axis=1, keepdims=True))
        sim = (a @ b.T) / jnp.maximum(an * bn.T, EPS)
        sim = jnp.clip(sim, -1.0, 1.0)
        return jnp.arccos(sim) / jnp.pi
    raise ValueError(f"unknown metric {metric!r}")


def gmm_assign(points, centers, n_centers, metric="euclidean"):
    """Reference min-dist + argmin of points against masked centers."""
    d = dist_matrix(points, centers, metric)
    col = jnp.arange(centers.shape[0])[None, :]
    d = jnp.where(col < n_centers, d, HUGE)
    return jnp.min(d, axis=1), jnp.argmin(d, axis=1).astype(jnp.int32)


def gmm_update(points, center, dmin, amin, new_index, metric="euclidean"):
    """Reference incremental fold of one new center."""
    d = dist_matrix(points, center.reshape(1, -1), metric)[:, 0]
    better = d < dmin
    return (jnp.where(better, d, dmin),
            jnp.where(better, jnp.int32(new_index), amin))
