"""L2 — the JAX compute graph that gets AOT-lowered for the Rust runtime.

The DMMC paper has no neural model; its "model" is the distance geometry
that the coreset constructions consume.  This module defines the AOT entry
points — fixed-shape jitted functions that call the L1 Pallas kernels — and
their example-argument specs.  ``aot.py`` lowers each entry to HLO text; the
Rust runtime (rust/src/runtime/) loads one executable per entry.

Entry naming convention (mirrored by rust/src/runtime/shapes.rs):

    <kernel>_<metric>_d<D>            e.g. gmm_update_cosine_d32

with the tile geometry of ``kernels/distance.py`` (NP points per call, TC
centers per call, feature dim padded to D in {32, 64}).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import distance as K

F32 = jnp.float32
I32 = jnp.int32


def entry_gmm_assign(metric, d):
    def fn(points, centers, n_centers):
        dmin, amin = K.gmm_assign(points, centers, n_centers, metric=metric)
        return (dmin, amin)

    specs = (
        jax.ShapeDtypeStruct((K.NP, d), F32),
        jax.ShapeDtypeStruct((K.TC, d), F32),
        jax.ShapeDtypeStruct((1, 1), I32),
    )
    return fn, specs


def entry_gmm_update(metric, d):
    def fn(points, center, dmin, amin, new_index):
        ndmin, namin = K.gmm_update(points, center, dmin, amin, new_index,
                                    metric=metric)
        return (ndmin, namin)

    specs = (
        jax.ShapeDtypeStruct((K.NP, d), F32),
        jax.ShapeDtypeStruct((1, d), F32),
        jax.ShapeDtypeStruct((K.NP,), F32),
        jax.ShapeDtypeStruct((K.NP,), I32),
        jax.ShapeDtypeStruct((1, 1), I32),
    )
    return fn, specs


def entry_pairwise(metric, d):
    def fn(a, b):
        return (K.pairwise(a, b, metric=metric),)

    specs = (
        jax.ShapeDtypeStruct((K.NP, d), F32),
        jax.ShapeDtypeStruct((K.TC, d), F32),
    )
    return fn, specs


_BUILDERS = {
    "gmm_assign": entry_gmm_assign,
    "gmm_update": entry_gmm_update,
    "pairwise": entry_pairwise,
}


def aot_entries():
    """name -> (fn, example_specs) for every artifact we ship."""
    entries = {}
    for kernel, builder in _BUILDERS.items():
        for metric in K.METRICS:
            for d in K.DIMS:
                name = f"{kernel}_{metric}_d{d}"
                entries[name] = builder(metric, d)
    return entries


def manifest_lines():
    """Human/Rust-readable manifest describing the artifact geometry."""
    lines = [
        f"np={K.NP}",
        f"tp={K.TP}",
        f"tc={K.TC}",
        f"dims={','.join(str(d) for d in K.DIMS)}",
        f"metrics={','.join(K.METRICS)}",
    ]
    for name in sorted(aot_entries()):
        lines.append(f"entry={name}")
    return lines
