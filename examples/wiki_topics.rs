//! Wikipedia-topics scenario (paper §5, Table 2 row 1 — substituted by the
//! wikisim generator, see DESIGN.md §1): pick k pages that are maximally
//! diverse in embedding space while "well spread" across overlapping topics
//! — a transversal matroid constraint — processing the input as a STREAM.
//!
//!     cargo run --release --example wiki_topics [n] [k] [tau]

use matroid_coreset::algo::local_search::{local_search_sum, LocalSearchParams};
use matroid_coreset::data::synth;
use matroid_coreset::matroid::{Matroid, TransversalMatroid};
use matroid_coreset::runtime::BatchEngine;
use matroid_coreset::streaming::{run_stream, StreamMode};
use matroid_coreset::util::rng::Rng;
use matroid_coreset::util::timer::time_it;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(100_000);
    let k: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(25);
    let tau: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(64);

    println!("generating wikisim n={n} (25-d GloVe-like embeddings, 100 topics)...");
    let ds = synth::wikisim(n, 7);
    let matroid = TransversalMatroid::new();
    println!(
        "matroid: transversal over {} topics (rank bound {})",
        ds.n_categories,
        matroid.rank_bound(&ds)
    );

    // stream pass: one permutation = one simulated arrival order
    let mut rng = Rng::new(99);
    let order = rng.permutation(ds.n());
    let rep = run_stream(&ds, &matroid, k, StreamMode::Tau(tau), &order);
    println!(
        "stream pass: {} pts at {:.0} pts/s | coreset {} pts / {} clusters | peak mem {} pts | {} restructures",
        rep.stats.points_processed,
        rep.throughput,
        rep.coreset.len(),
        rep.coreset.n_clusters,
        rep.stats.peak_memory_points,
        rep.stats.restructures,
    );

    // final solution on the coreset (engine built outside the timed block)
    let engine = BatchEngine::for_dataset(&ds);
    let (res, t_ls) = time_it(|| {
        let mut r2 = Rng::new(5);
        local_search_sum(
            &ds,
            &matroid,
            k,
            &rep.coreset.indices,
            &engine,
            LocalSearchParams::default(),
            None,
            &mut r2,
        )
    });
    let res = res?;
    println!(
        "local search on coreset: diversity {:.4} in {:.2}s ({} swaps)",
        res.diversity,
        t_ls.as_secs_f64(),
        res.swaps
    );
    assert!(matroid.is_independent(&ds, &res.solution));

    // report topic coverage of the solution — the point of the constraint
    let mut topics: Vec<u32> = res
        .solution
        .iter()
        .flat_map(|&i| ds.categories[i].iter().copied())
        .collect();
    topics.sort_unstable();
    topics.dedup();
    println!(
        "solution covers {} distinct topics with {} pages",
        topics.len(),
        res.solution.len()
    );
    println!(
        "end-to-end: {:.2}s stream + {:.2}s search over {} pages",
        rep.elapsed.as_secs_f64(),
        t_ls.as_secs_f64(),
        ds.n()
    );
    Ok(())
}
