//! End-to-end driver — the full-system validation run recorded in
//! EXPERIMENTS.md §E2E.
//!
//! Exercises every layer on a real (synthetic, see DESIGN.md §1) workload:
//!
//!  1. Table-2 stand-ins: wikisim (transversal) + songsim (partition);
//!  2. the PJRT engine (L1 Pallas kernels via AOT HLO) powering SeqCoreset,
//!     cross-checked against the scalar path;
//!  3. all three settings (sequential / streaming / MapReduce ell=1..8)
//!     with the AMT local-search finisher, reporting the paper's headline
//!     metric: coreset routes reach AMT-level diversity 1-2 orders of
//!     magnitude faster than local search on the full input;
//!  4. the (1-eps)-exhaustive route for a non-sum variant (tree-DMMC).
//!
//!     cargo run --release --example e2e_pipeline [n]

use matroid_coreset::algo::Budget;
use matroid_coreset::coordinator::{
    build_dataset, build_matroid, run_pipeline, DatasetSpec, Finisher, MatroidSpec, Pipeline,
    Setting,
};
use matroid_coreset::diversity::Objective;
use matroid_coreset::matroid::Matroid;
use matroid_coreset::runtime::{default_artifact_dir, EngineKind, Manifest};
use matroid_coreset::streaming::StreamMode;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(50_000);
    let n_amt = 5_000.min(n); // paper §5.1 runs AMT on 5k samples
    let tau = 64;

    let pjrt_available =
        cfg!(feature = "pjrt") && Manifest::load(default_artifact_dir()).is_ok();
    println!(
        "e2e: n={n} tau={tau} | PJRT: {}",
        if pjrt_available { "found" } else { "unavailable (scalar + batch only)" }
    );

    for (label, dspec) in [
        ("wikisim/transversal", DatasetSpec::Wikisim { n, seed: 1 }),
        ("songsim/partition", DatasetSpec::Songsim { n, seed: 1 }),
    ] {
        let ds = build_dataset(&dspec)?;
        let mspec = MatroidSpec::default_for(&dspec);
        let m = build_matroid(&mspec, &ds);
        let rank = m.rank_bound(&ds);
        let k = (rank / 4).max(2);
        println!("\n=== {label}: n={} rank={rank} k={k} ===", ds.n());

        // AMT baseline on a 5k sample (running it on the full input is the
        // very intractability the paper addresses)
        let sample = ds.subset(&(0..n_amt).collect::<Vec<_>>());
        let amt = run_pipeline(
            &sample, &m, k, Objective::Sum,
            Pipeline {
                setting: Setting::Full,
                finisher: Finisher::LocalSearch { gamma: 0.0 },
                engine: EngineKind::Scalar,
            },
            1,
        )?;
        println!(
            "AMT baseline (5k sample):    div {:>9.3}  time {:>8.2}s",
            amt.diversity,
            amt.total_time().as_secs_f64()
        );

        let engines: &[EngineKind] = if pjrt_available {
            &[
                EngineKind::Scalar,
                EngineKind::Batch,
                EngineKind::Simd,
                EngineKind::Pjrt,
            ]
        } else {
            &[EngineKind::Scalar, EngineKind::Batch, EngineKind::Simd]
        };
        for &engine in engines {
            let seq = run_pipeline(
                &ds, &m, k, Objective::Sum,
                Pipeline {
                    setting: Setting::Seq { budget: Budget::Clusters(tau) },
                    finisher: Finisher::LocalSearch { gamma: 0.0 },
                    engine,
                },
                1,
            )?;
            println!(
                "SeqCoreset[{:<6}] (full n):  div {:>9.3}  coreset {:>5}  cs {:>7.2}s + ls {:>6.2}s",
                engine.name(),
                seq.diversity,
                seq.coreset_size,
                seq.coreset_time.as_secs_f64(),
                seq.finish_time.as_secs_f64()
            );
            assert!(m.is_independent(&ds, &seq.solution));
        }

        let stream = run_pipeline(
            &ds, &m, k, Objective::Sum,
            Pipeline {
                setting: Setting::Stream { mode: StreamMode::Tau(tau) },
                finisher: Finisher::LocalSearch { gamma: 0.0 },
                engine: EngineKind::Batch,
            },
            1,
        )?;
        println!(
            "StreamCoreset (full n):      div {:>9.3}  coreset {:>5}  cs {:>7.2}s + ls {:>6.2}s  (peak mem {} pts)",
            stream.diversity,
            stream.coreset_size,
            stream.coreset_time.as_secs_f64(),
            stream.finish_time.as_secs_f64(),
            stream.extra["peak_memory"] as usize
        );

        for ell in [2usize, 4, 8] {
            let mr = run_pipeline(
                &ds, &m, k, Objective::Sum,
                Pipeline {
                    setting: Setting::MapReduce {
                        workers: ell,
                        budget: Budget::Clusters((tau / ell).max(1)),
                        second_round_tau: None,
                    },
                    finisher: Finisher::LocalSearch { gamma: 0.0 },
                    engine: EngineKind::Batch,
                },
                1,
            )?;
            println!(
                "MRCoreset ell={ell} (full n):   div {:>9.3}  coreset {:>5}  cs {:>7.2}s + ls {:>6.2}s",
                mr.diversity,
                mr.coreset_size,
                mr.coreset_time.as_secs_f64(),
                mr.finish_time.as_secs_f64()
            );
        }
    }

    // non-sum variant: the (1-eps)-approximate exhaustive route
    println!("\n=== tree-DMMC via exhaustive-on-coreset (cube n=20000, k=5) ===");
    let dspec = DatasetSpec::Cube { n: 20_000.min(n), dim: 6, seed: 2 };
    let ds = build_dataset(&dspec)?;
    let m = build_matroid(&MatroidSpec::Uniform(5), &ds);
    let out = run_pipeline(
        &ds, &m, 5, Objective::Tree,
        Pipeline {
            setting: Setting::Seq { budget: Budget::Clusters(12) },
            finisher: Finisher::Exhaustive,
            engine: EngineKind::Batch,
        },
        3,
    )?;
    println!(
        "tree diversity {:.4} from a {}-point coreset in {:.2}s (search visited {} nodes)",
        out.diversity,
        out.coreset_size,
        out.total_time().as_secs_f64(),
        out.extra["search_nodes"] as u64
    );
    println!("\ne2e OK");
    Ok(())
}
