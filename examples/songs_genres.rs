//! Songs-genres scenario (paper §5, Table 2 row 2 — substituted by the
//! songsim generator): a partition matroid with caps proportional to genre
//! frequency (rank ~ 89), processed with the MAPREDUCE coreset at several
//! degrees of parallelism — the paper's Figure 3 protocol in miniature.
//!
//!     cargo run --release --example songs_genres [n] [tau]

use matroid_coreset::algo::local_search::{local_search_sum, LocalSearchParams};
use matroid_coreset::algo::Budget;
use matroid_coreset::data::synth;
use matroid_coreset::mapreduce::{mr_coreset, MapReduceConfig};
use matroid_coreset::matroid::Matroid;
use matroid_coreset::runtime::{BatchEngine, EngineKind};
use matroid_coreset::util::rng::Rng;
use matroid_coreset::util::timer::time_it;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(100_000);
    let tau: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(64);

    println!("generating songsim n={n} (48-d count vectors, 16 genres)...");
    let ds = synth::songsim(n, 11);
    let matroid = synth::songsim_matroid(&ds, 89);
    let rank = matroid.rank_bound(&ds);
    let k = rank / 4;
    println!("matroid: {} (rank {rank}), k = {k}", matroid.describe());
    let engine = BatchEngine::for_dataset(&ds);

    println!("\n ell  makespan_r1  wall      coreset  diversity  (tau/ell clusters per worker)");
    for ell in [1usize, 2, 4, 8] {
        let cfg = MapReduceConfig {
            workers: ell,
            budget: Budget::Clusters((tau / ell).max(1)),
            second_round_tau: None,
            seed: 33,
            engine: EngineKind::Batch,
        };
        let (rep, _) = time_it(|| mr_coreset(&ds, &matroid, k, cfg));
        let rep = rep?;
        let mut rng = Rng::new(1);
        let (res, t_ls) = time_it(|| {
            local_search_sum(
                &ds,
                &matroid,
                k,
                &rep.coreset.indices,
                &engine,
                LocalSearchParams::default(),
                None,
                &mut rng,
            )
        });
        let res = res?;
        assert!(matroid.is_independent(&ds, &res.solution));
        println!(
            "  {ell:2}  {:>9.3}s  {:>7.3}s  {:>7}  {:>9.3}  (+{:.2}s local search)",
            rep.makespan_round1.as_secs_f64(),
            rep.wall_time.as_secs_f64(),
            rep.coreset.len(),
            res.diversity,
            t_ls.as_secs_f64()
        );
    }

    // genre balance of the ell=4 solution
    let cfg = MapReduceConfig {
        workers: 4,
        budget: Budget::Clusters((tau / 4).max(1)),
        second_round_tau: None,
        seed: 33,
        engine: EngineKind::Batch,
    };
    let rep = mr_coreset(&ds, &matroid, k, cfg)?;
    let mut rng = Rng::new(1);
    let res = local_search_sum(
        &ds,
        &matroid,
        k,
        &rep.coreset.indices,
        &engine,
        LocalSearchParams::default(),
        None,
        &mut rng,
    )?;
    let mut per_genre = vec![0usize; ds.n_categories as usize];
    for &i in &res.solution {
        per_genre[ds.categories[i][0] as usize] += 1;
    }
    println!("\ngenre histogram of the solution (cap per genre in parens):");
    for (g, &cnt) in per_genre.iter().enumerate() {
        if cnt > 0 {
            println!("  genre {g:2}: {cnt} (cap {})", matroid.cap(g as u32));
            assert!(cnt <= matroid.cap(g as u32));
        }
    }
    Ok(())
}
