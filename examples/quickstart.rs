//! Quickstart: the 60-second tour of the public API.
//!
//! Builds a small clustered dataset with a partition constraint, constructs
//! a coreset with SeqCoreset (Algorithm 1), extracts a sum-diverse solution
//! with AMT local search, and compares against running local search on the
//! full input.
//!
//!     cargo run --release --example quickstart

use matroid_coreset::algo::local_search::{local_search_sum, LocalSearchParams};
use matroid_coreset::algo::seq_coreset::seq_coreset;
use matroid_coreset::algo::Budget;
use matroid_coreset::data::synth;
use matroid_coreset::diversity::{diversity, Objective};
use matroid_coreset::matroid::{Matroid, PartitionMatroid};
use matroid_coreset::runtime::BatchEngine;
use matroid_coreset::util::rng::Rng;
use matroid_coreset::util::timer::time_it;

fn main() -> anyhow::Result<()> {
    // 1. a dataset: 20k points in 8-d, 6 categories, Gaussian blobs
    let ds = synth::clustered(20_000, 8, 32, 0.15, 6, 42);
    println!("dataset: {} ({} points, dim {})", ds.name, ds.n(), ds.dim);

    // 2. a matroid constraint: at most 2 representatives per category
    let matroid = PartitionMatroid::new(vec![2; 6]);
    let k = 8;
    println!("matroid: {} | k = {k}", matroid.describe());

    // 3. build a (1-eps)-coreset with SeqCoreset (Algorithm 1), on the
    //    default multi-threaded batch engine
    let engine = BatchEngine::for_dataset(&ds);
    let (coreset, t_coreset) =
        time_it(|| seq_coreset(&ds, &matroid, k, Budget::Clusters(64), &engine));
    let coreset = coreset?;
    println!(
        "coreset: {} points from {} clusters (radius {:.4}) in {:.3}s",
        coreset.len(),
        coreset.n_clusters,
        coreset.radius,
        t_coreset.as_secs_f64()
    );

    // 4. extract the final solution with AMT local search (gamma = 0)
    let mut rng = Rng::new(1);
    let (result, t_search) = time_it(|| {
        local_search_sum(
            &ds,
            &matroid,
            k,
            &coreset.indices,
            &engine,
            LocalSearchParams::default(),
            None,
            &mut rng,
        )
    });
    let result = result?;
    println!(
        "solution: {:?}\n  sum-diversity = {:.4} ({} swaps, {:.3}s)",
        result.solution,
        result.diversity,
        result.swaps,
        t_search.as_secs_f64()
    );
    assert!(matroid.is_independent(&ds, &result.solution));

    // 5. compare against local search on the FULL input (the AMT baseline)
    let all: Vec<usize> = (0..ds.n()).collect();
    let mut rng2 = Rng::new(1);
    let (full, t_full) = time_it(|| {
        local_search_sum(
            &ds,
            &matroid,
            k,
            &all,
            &engine,
            LocalSearchParams::default(),
            None,
            &mut rng2,
        )
    });
    let full = full?;
    println!(
        "baseline (AMT on full input): diversity = {:.4} in {:.3}s",
        full.diversity,
        t_full.as_secs_f64()
    );
    let total = t_coreset.as_secs_f64() + t_search.as_secs_f64();
    println!(
        "=> coreset route keeps {:.1}% of the diversity at {:.1}x speedup",
        100.0 * result.diversity / full.diversity,
        t_full.as_secs_f64() / total
    );

    // other objectives work via exhaustive search on the coreset (the
    // same engine supplies the candidate tile and the final evaluation):
    let tree = matroid_coreset::algo::exhaustive::exhaustive_best(
        &ds,
        &&matroid,
        4,
        &coreset.indices,
        Objective::Tree,
        &engine,
    )?;
    println!(
        "tree-DMMC (k=4, exhaustive on coreset): {:.4} (={:.4} recomputed)",
        tree.diversity,
        diversity(&ds, &tree.solution, Objective::Tree)
    );
    Ok(())
}
