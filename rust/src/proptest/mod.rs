//! Mini property-testing framework (the offline image has no proptest).
//!
//! Deterministic: each named property derives its base seed from the
//! property name, and every failing case reports `(name, case, seed)` so a
//! failure is reproducible by rerunning the same test binary.  Shrinking is
//! size-scheduling rather than counterexample-driven: cases start tiny and
//! grow, so the first failure is usually near-minimal already.

use crate::util::rng::Rng;

/// Generator handle passed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Current size class (grows with the case index).
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    /// A "sized" count in [1, max(1, size)] — drives near-minimal failures.
    pub fn sized(&mut self, cap: usize) -> usize {
        let hi = self.size.clamp(1, cap.max(1));
        self.usize_in(1, hi)
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| (self.rng.normal() as f32) * scale).collect()
    }

    /// Random subset of `0..n` of size `m`.
    pub fn subset(&mut self, n: usize, m: usize) -> Vec<usize> {
        self.rng.sample_indices(n, m)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

fn name_seed(name: &str) -> u64 {
    crate::util::fnv1a(name)
}

/// Run `cases` random cases of the property; panic with a reproducible
/// report on the first failure (`Err(reason)`).
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base = name_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut g = Gen {
            rng: Rng::new(seed),
            // grow the size class: first cases are tiny
            size: 2 + case * 2,
        };
        if let Err(reason) = f(&mut g) {
            panic!(
                "property {name} failed at case {case} (seed {seed:#x}, size {}): {reason}",
                g.size
            );
        }
    }
}

/// Convenience: assert-like helper producing the Err format `check` wants.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($msg:tt)+) => {
        if !$cond {
            return Err(format!($($msg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0);
        check("always-true", 50, |g| {
            counter.set(counter.get() + 1);
            let x = g.usize_in(0, 10);
            if x <= 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(counter.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property always-false failed")]
    fn failing_property_panics_with_context() {
        check("always-false", 10, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check("det", 5, |g| {
            first.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut second = Vec::new();
        check("det", 5, |g| {
            second.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn sizes_grow() {
        let mut sizes = Vec::new();
        check("size-growth", 5, |g| {
            sizes.push(g.size);
            Ok(())
        });
        assert!(sizes.windows(2).all(|w| w[0] < w[1]));
    }
}
