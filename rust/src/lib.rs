//! # matroid-coreset
//!
//! A three-layer Rust + JAX + Pallas reproduction of *"A General
//! Coreset-Based Approach to Diversity Maximization under Matroid
//! Constraints"* (Ceccarello, Pietracaprina, Pucci — CS.DC 2020).
//!
//! The crate implements the paper's full system surface:
//!
//! * **coreset constructions** for partition / transversal / general
//!   matroids ([`algo::seq_coreset`], [`algo::stream_coreset`],
//!   [`mapreduce`]),
//! * the **five DMMC objectives** of Table 1 ([`diversity`]),
//! * **final-solution extractors**: AMT local search for sum-DMMC
//!   ([`algo::local_search`]) and matroid-pruned exhaustive search for the
//!   other variants ([`algo::exhaustive`]),
//! * the **PJRT runtime** that executes the AOT-compiled Pallas distance
//!   kernels from the Rust hot path ([`runtime`]),
//! * and the experiment substrate: synthetic datasets ([`data`]),
//!   a thread-based MapReduce simulator ([`mapreduce`]), a streaming
//!   harness ([`streaming`]), an experiment coordinator ([`coordinator`]),
//!   a bench harness ([`bench`]) and a mini property-testing framework
//!   ([`proptest`]).
//!
//! See DESIGN.md for the system inventory and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod algo;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod diversity;
pub mod mapreduce;
pub mod matroid;
pub mod proptest;
pub mod runtime;
pub mod streaming;
pub mod util;
