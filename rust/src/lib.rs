//! # matroid-coreset
//!
//! A three-layer Rust + JAX + Pallas reproduction of *"A General
//! Coreset-Based Approach to Diversity Maximization under Matroid
//! Constraints"* (Ceccarello, Pietracaprina, Pucci — CS.DC 2020).
//!
//! The crate implements the paper's full system surface:
//!
//! * **coreset constructions** for partition / transversal / general
//!   matroids ([`algo::seq_coreset`], [`algo::stream_coreset`],
//!   [`mapreduce`]),
//! * the **six DMMC objectives** — Table 1 plus remote-edge/max-min —
//!   ([`diversity`]), scored through the engine-backed
//!   [`diversity::Evaluator`] (see below),
//! * **final-solution extractors**: AMT local search for sum-DMMC
//!   ([`algo::local_search`]), matroid-pruned exhaustive search for the
//!   other variants ([`algo::exhaustive`]), and a maximum-weight-matching
//!   vs farthest-point race for remote-clique/remote-edge
//!   ([`algo::matching`]),
//! * the **distance-engine runtime** ([`runtime`]): a widened
//!   [`runtime::DistanceEngine`] trait (min-folds, pairwise tiles,
//!   per-candidate sums) behind a backend registry
//!   ([`runtime::EngineKind`]) with four backends and a cross-backend
//!   conformance harness ([`runtime::conformance`]) — see below,
//! * the **composable coreset index + query service** ([`index`]): a
//!   fully dynamic merge-and-reduce coreset tree whose root is a standing
//!   coreset of everything ingested — appends *and* tombstoned deletes
//!   touch O(log segments) nodes (threshold-triggered rebuilds from
//!   survivors), retention policies bound freshness (`last:<w>` sliding
//!   windows, `ttl:<epochs>` expiry), and an epoch-invalidated LRU query
//!   cache sits on top: N `(objective, k, matroid, engine)` queries pay
//!   one coreset construction instead of N pipeline runs (`dmmc index
//!   build/append/delete/query`, `--algo index`),
//! * the **multi-tenant query server** ([`serve`], `dmmc serve`): a
//!   std-only TCP front end (line protocol, scoped worker-thread pool)
//!   hosting many named indexes loaded from snapshots — concurrent
//!   identical queries coalesce onto one cold computation, mutations are
//!   serialized per tenant behind epoch-gated invalidation, the result
//!   cache persists across restarts via a content-id-stamped sidecar,
//!   and a load-replay harness measures p50/p99/QPS/hit-rate
//!   (`bench_results/serve_load.csv`),
//! * and the experiment substrate: synthetic datasets ([`data`]),
//!   a thread-based MapReduce simulator ([`mapreduce`]), a streaming
//!   harness ([`streaming`]), an experiment coordinator ([`coordinator`]),
//!   a bench harness ([`bench`]) and a mini property-testing framework
//!   ([`proptest`]).
//!
//! ## Building and testing
//!
//! ```text
//! cargo build --release          # default features: scalar + batch engines
//! cargo test                     # full suite incl. the engine-equivalence pins
//! cargo test --features pjrt     # PJRT backend (extra setup below)
//! cargo bench --bench micro_core # perf counters (fold speedup batch vs scalar)
//! ```
//!
//! The `pjrt` feature needs two manual steps first: uncomment the `xla`
//! path dependency in `rust/Cargo.toml` (it points at an xla-rs checkout
//! with a local XLA C++ toolchain, and is not declared by default so the
//! plain build never tries to resolve it) and run `make artifacts` for
//! the AOT kernels (see `python/compile/aot.py`).  Everything else is
//! dependency-light pure Rust.
//!
//! ## Choosing an engine
//!
//! Backends register in [`runtime::EngineKind`] and are selectable in
//! every scenario from one flag: `--engine` on the CLI, `run.engine` in
//! sweep configs, `DMMC_BENCH_ENGINE` for the bench binaries.  The
//! registry threads through `run_pipeline`, the MapReduce per-shard
//! engines, and the streaming restructure tile.  Each kind declares a
//! numerics contract ([`runtime::EngineKind::contract`]) enforced for
//! all five primitives by the conformance harness
//! ([`runtime::conformance`], run per backend by
//! `tests/engine_conformance.rs`).
//!
//! * [`runtime::BatchEngine`] — the default (`--engine batch`): chunked,
//!   `std::thread::scope`-parallel CPU kernels with precomputed norms.
//!   Bit-identical to the scalar oracle on every path (`update_min`,
//!   `pairwise_block`, `sums_to_set`, `dists_to_points`), so switching
//!   engines never changes a result — only the wall clock.
//! * [`runtime::SimdEngine`] (`--engine simd`) — lane-unrolled inner
//!   loops with deterministic reductions: Euclidean paths accumulate in
//!   the oracle's own order across four independent point lanes
//!   (bit-identical), cosine paths tree-reduce their dot products
//!   (deterministic, within `runtime::simd::SIMD_COSINE_ABS_TOL` of the
//!   oracle — the tolerance-mode mirror of how PJRT is handled).
//! * [`runtime::ScalarEngine`] — the portable point-at-a-time oracle
//!   (`--engine scalar`); use it as the reference in equivalence tests
//!   (its distance-evaluation counter also powers work-count regressions).
//! * `runtime::PjrtEngine` (`--engine pjrt`, feature `pjrt`) — executes the
//!   AOT-compiled Pallas kernels through the PJRT CPU client; validated
//!   against the oracle by `tests/runtime_numerics.rs` (tolerance, not
//!   bit-identity).
//!
//! ## Evaluator API and backend dispatch
//!
//! Diversity evaluation never walks `Dataset::dist` point-at-a-time; it
//! goes through [`diversity::Evaluator`] over whichever engine the
//! pipeline selected:
//!
//! * **sum / star** — one batched `sums_to_set` pass over the set (exact
//!   f64 oracle formulas on every CPU backend, self-pairs excluded
//!   exactly so cosine fp self-noise never contaminates the objectives);
//! * **tree / cycle / bipartition** — the dense submatrix from one
//!   `pairwise_block` tile (f32, upcast to f64 for the matrix solvers;
//!   computed as a strict upper triangle + mirror with a true-zero
//!   diagonal); CPU backends must produce bit-identical tiles, making
//!   every objective value engine-independent
//!   (`tests/engine_equivalence.rs`);
//! * [`diversity::Evaluator::diversity_all`] scores all six objectives
//!   from one sums pass + one tile, and the exhaustive finisher evaluates
//!   every DFS leaf from a single candidate tile — no duplicate distance
//!   work (pinned by an evaluation-count regression).
//!
//! ## Determinism contracts and static analysis
//!
//! The engine contracts above are enforced mechanically, not just by
//! convention.  `cargo xtask lint` (the `dmmc-lint` pass in
//! `rust/xtask`) walks every file under `rust/src` and denies:
//!
//! * **L1** `HashMap`/`HashSet` in the result-producing modules
//!   ([`matroid`], [`algo`], [`index`], [`diversity`]) — hash iteration
//!   order is process-seeded, so iterated collections there must be
//!   `BTreeMap`/`BTreeSet` or sorted;
//! * **L2** float accumulation loops in the bit-exact engine kernels
//!   outside the blessed reduction helpers (`rust/lint.toml [l2]`);
//! * **L3** `as f32` narrowing inside the exact-f64
//!   `sums_to_set`/`dists_to_points` kernels and the incremental-AMT
//!   column store ([`algo::local_search`]);
//! * **L4** `Instant::now`/`SystemTime`/ambient RNG in deterministic
//!   query paths (timers live in [`util::timer`] and bench code; query
//!   RNG derives from the `(spec, epoch)` cache key).
//!
//! Exceptions live in `rust/lint.toml` with mandatory justifications, and
//! every entry must be load-bearing (a stale entry is itself a finding).
//! CI gates on `cargo xtask lint --deny`, runs the core/algo/index unit
//! tests under Miri, and runs the engine conformance suite under
//! ThreadSanitizer; `tests/determinism_contract.rs` pins the runtime side
//! (identical solutions across category insertion orders and replays).
//!
//! ## Telemetry is a side channel
//!
//! The [`obs`] module (metrics registry, span tracing, the serve
//! `METRICS` verb, `--trace` JSONL sinks) observes the system but must
//! never feed a result path: no algorithm, finisher, cache, or index
//! decision reads a metric, span, or the clock behind them — deleting
//! every `obs` call site leaves every result bit-identical.  Span
//! durations come only from [`util::timer::Stopwatch`]/`PhaseTimer`; the
//! one ambient `Instant::now` in [`obs::trace`] anchors the trace epoch
//! and carries the single obs allow entry in `rust/lint.toml`.
//!
//! See DESIGN.md for the system inventory and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod algo;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod diversity;
pub mod index;
pub mod mapreduce;
pub mod matroid;
pub mod obs;
pub mod proptest;
pub mod runtime;
pub mod serve;
pub mod streaming;
pub mod util;
