//! Structured span tracing: RAII guards into a bounded ring buffer,
//! drainable as JSONL (`--trace out.jsonl` on `run`, `index`, `serve`).
//!
//! Tracing is off by default and costs one relaxed atomic load per
//! `span()` call when disabled, so instrumented hot paths stay free for
//! every run that didn't ask for a trace.  Durations come from
//! [`Stopwatch`] — the blessed wall-clock wrapper — and the only ambient
//! time read in this module is the process *trace epoch* below, which
//! anchors span start offsets and nothing else.  Spans are a write-only
//! side channel: no result path ever reads the ring.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::obs::metrics::json_string;
use crate::util::timer::Stopwatch;

/// Default ring capacity: enough for every phase of a full pipeline run
/// plus a few thousand per-request serve spans before overwrite.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// One completed span, recorded at guard drop.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    /// Process-unique id (1-based; 0 means "no parent").
    pub id: u64,
    /// Enclosing span's id on the same thread, 0 at top level.
    pub parent: u64,
    pub name: String,
    pub tags: Vec<(String, String)>,
    /// Start offset from the trace epoch, microseconds.
    pub start_us: u64,
    /// Wall-clock duration, microseconds (from `Stopwatch`).
    pub dur_us: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

struct Ring {
    capacity: usize,
    spans: VecDeque<SpanRecord>,
    dropped: u64,
}

fn ring() -> &'static Mutex<Ring> {
    static RING: OnceLock<Mutex<Ring>> = OnceLock::new();
    RING.get_or_init(|| {
        Mutex::new(Ring {
            capacity: DEFAULT_RING_CAPACITY,
            spans: VecDeque::new(),
            dropped: 0,
        })
    })
}

/// Microseconds since the process trace epoch.  The `Instant::now` here
/// is the single ambient-time read of the obs module (pinned by the
/// `rust/lint.toml` allow entry): it anchors span *offsets* only — span
/// durations come from `Stopwatch`, and nothing downstream of a result
/// ever reads either.
fn epoch_offset_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

thread_local! {
    static PARENT_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

/// Turn tracing on with the given ring capacity, clearing any previous
/// spans and pinning the trace epoch.
pub fn enable(capacity: usize) {
    let mut r = ring().lock().unwrap_or_else(|e| e.into_inner());
    r.capacity = capacity.max(1);
    r.spans.clear();
    r.dropped = 0;
    drop(r);
    epoch_offset_us(); // pin the epoch at enable time
    ENABLED.store(true, Ordering::Relaxed);
}

pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Open a span guard.  Inert (and nearly free) while tracing is off.
pub fn span(name: &str) -> SpanGuard {
    if !ENABLED.load(Ordering::Relaxed) {
        return SpanGuard { live: None };
    }
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    let parent = PARENT_STACK.with(|s| {
        let mut s = s.borrow_mut();
        let parent = s.last().copied().unwrap_or(0);
        s.push(id);
        parent
    });
    SpanGuard {
        live: Some(LiveSpan {
            id,
            parent,
            name: name.to_string(),
            tags: Vec::new(),
            start_us: epoch_offset_us(),
            sw: Stopwatch::start(),
        }),
    }
}

struct LiveSpan {
    id: u64,
    parent: u64,
    name: String,
    tags: Vec<(String, String)>,
    start_us: u64,
    sw: Stopwatch,
}

/// RAII span: records into the ring when dropped.
pub struct SpanGuard {
    live: Option<LiveSpan>,
}

impl SpanGuard {
    /// Attach a `key=value` tag (no-op on an inert guard).
    pub fn tag(&mut self, key: &str, value: &str) {
        if let Some(live) = &mut self.live {
            live.tags.push((key.to_string(), value.to_string()));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(live) = self.live.take() else {
            return;
        };
        let dur_us = live.sw.elapsed().as_micros() as u64;
        PARENT_STACK.with(|s| {
            let mut s = s.borrow_mut();
            if s.last() == Some(&live.id) {
                s.pop();
            } else {
                // out-of-order drop (guard moved across scopes): unlink by id
                s.retain(|&id| id != live.id);
            }
        });
        let mut r = ring().lock().unwrap_or_else(|e| e.into_inner());
        if r.spans.len() >= r.capacity {
            r.spans.pop_front();
            r.dropped += 1;
        }
        r.spans.push_back(SpanRecord {
            id: live.id,
            parent: live.parent,
            name: live.name,
            tags: live.tags,
            start_us: live.start_us,
            dur_us,
        });
    }
}

/// Take every recorded span out of the ring, returning them in
/// completion order plus the count overwritten by ring overflow.
pub fn drain() -> (Vec<SpanRecord>, u64) {
    let mut r = ring().lock().unwrap_or_else(|e| e.into_inner());
    let spans = std::mem::take(&mut r.spans).into(); // VecDeque -> Vec
    let dropped = std::mem::take(&mut r.dropped);
    (spans, dropped)
}

/// Drain the ring to a JSONL file (one span object per line); returns
/// `(spans written, spans dropped by ring overflow)`.
pub fn write_jsonl(path: &str) -> std::io::Result<(usize, u64)> {
    let (spans, dropped) = drain();
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for s in &spans {
        writeln!(f, "{}", span_json(s))?;
    }
    f.flush()?;
    Ok((spans.len(), dropped))
}

/// One span as a single-line JSON object.
pub fn span_json(s: &SpanRecord) -> String {
    let tags = s
        .tags
        .iter()
        .map(|(k, v)| format!("{}:{}", json_string(k), json_string(v)))
        .collect::<Vec<_>>()
        .join(",");
    format!(
        "{{\"id\":{},\"parent\":{},\"name\":{},\"start_us\":{},\"dur_us\":{},\"tags\":{{{tags}}}}}",
        s.id,
        s.parent,
        json_string(&s.name),
        s.start_us,
        s.dur_us
    )
}
