//! Unified telemetry: metrics registry, structured spans, exposition.
//!
//! Everything in this module is a **side channel**.  The invariant the
//! dmmc-lint L4 contract protects extends here verbatim: telemetry may
//! *observe* a result path (durations from [`crate::util::timer`], event
//! counts, receipt ledgers) but must never *feed* one — no algorithm,
//! finisher, cache, or index decision reads a metric, a span, or the
//! clock behind them.  Deleting every `obs` call site must leave every
//! result bit-identical.
//!
//! Three pieces:
//!
//! * [`metrics`] — a lock-striped [`metrics::MetricsRegistry`] of named
//!   counters, gauges, and fixed-bucket latency histograms, rendered as
//!   Prometheus text (the serve `METRICS` verb, `dmmc run
//!   --metrics-out`) or JSON (`bench_results/BENCH_*.json`).
//! * [`trace`] — `span!`/[`trace::span`] RAII guards recording
//!   start/duration/parent into a bounded ring buffer, drained to JSONL
//!   by `--trace out.jsonl` on `run`, `index`, and `serve`.
//! * the [`span!`](crate::span) macro — `span!("phase")` or
//!   `span!("phase", "tenant" = name)` sugar over [`trace::span`].
//!
//! Time discipline: [`crate::util::timer::Stopwatch`] and `PhaseTimer`
//! are the only sources feeding span durations; the single ambient
//! `Instant::now` in [`trace`] anchors the trace epoch for start offsets
//! and carries the one obs allow entry in `rust/lint.toml`.

pub mod metrics;
pub mod trace;

pub use metrics::{Counter, Gauge, Histogram, MetricsRegistry, LATENCY_BUCKETS_US};
pub use trace::{span, SpanGuard, SpanRecord};

/// Open a span guard, optionally tagging it inline:
///
/// ```
/// let _sp = matroid_coreset::span!("coreset-build");
/// let tenant = "main";
/// let _sp = matroid_coreset::span!("serve.query", "tenant" = tenant);
/// ```
///
/// Tags stringify via `Display`.  Guards are inert while tracing is off.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::trace::span($name)
    };
    ($name:expr, $($key:literal = $val:expr),+ $(,)?) => {{
        let mut __span = $crate::obs::trace::span($name);
        $(__span.tag($key, &($val).to_string());)+
        __span
    }};
}
