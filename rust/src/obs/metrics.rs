//! Metrics registry: named counters, gauges, and fixed-bucket latency
//! histograms with Prometheus text exposition.
//!
//! The registry is lock-striped: a metric handle is resolved once through
//! a striped `Mutex<BTreeMap>` (stripe chosen by FNV-1a of the metric
//! name) and every subsequent increment is a plain atomic on the shared
//! handle — the hot path (a serve worker stamping a query) never contends
//! on registry structure.  Keys are `(name, sorted label pairs)`, and the
//! per-stripe `BTreeMap`s merge into one sorted view at render time, so
//! exposition order is deterministic regardless of registration order.
//!
//! Determinism note: a [`Histogram`] stores its sum as *integer
//! microseconds*, not a float, so the same multiset of samples produces
//! identical exposition text no matter the observation order — the
//! merge-determinism contract `tests/obs_telemetry.rs` pins.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::util::fnv1a;

/// Upper bucket bounds (inclusive) of the shared latency histogram, in
/// microseconds: 10us .. 10s in a 1-2.5-5 ladder, plus an implicit +Inf
/// bucket.  One fixed ladder everywhere keeps every latency histogram in
/// the process mergeable and the exposition text schema-stable.
pub const LATENCY_BUCKETS_US: [u64; 19] = [
    10,
    25,
    50,
    100,
    250,
    500,
    1_000,
    2_500,
    5_000,
    10_000,
    25_000,
    50_000,
    100_000,
    250_000,
    500_000,
    1_000_000,
    2_500_000,
    5_000_000,
    10_000_000,
];

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (stored as f64 bits).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket latency histogram over [`LATENCY_BUCKETS_US`].
///
/// The sum is accumulated in integer microseconds so accumulation order
/// can never change the rendered text (no float rounding drift), and the
/// quantile estimator reads the same buckets the exposition prints —
/// replay CSV p50/p99 and `METRICS` agree by construction.
#[derive(Debug)]
pub struct Histogram {
    /// Per-bucket counts; `buckets[LATENCY_BUCKETS_US.len()]` is +Inf.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..=LATENCY_BUCKETS_US.len())
                .map(|_| AtomicU64::new(0))
                .collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, d: Duration) {
        self.observe_us(d.as_micros().min(u64::MAX as u128) as u64);
    }

    pub fn observe_us(&self, us: u64) {
        let idx = LATENCY_BUCKETS_US
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(LATENCY_BUCKETS_US.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Per-bucket (non-cumulative) counts, +Inf last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect()
    }

    /// Bucket-interpolated quantile estimate in microseconds (the
    /// `histogram_quantile` rule: linear within the covering bucket,
    /// clamped to the last finite bound for the +Inf bucket).  Monotone
    /// in `q`, so p99 >= p50 always holds.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let prev = cum;
            cum += c;
            if (cum as f64) >= target {
                if i >= LATENCY_BUCKETS_US.len() {
                    return LATENCY_BUCKETS_US[LATENCY_BUCKETS_US.len() - 1] as f64;
                }
                let lo = if i == 0 { 0.0 } else { LATENCY_BUCKETS_US[i - 1] as f64 };
                let hi = LATENCY_BUCKETS_US[i] as f64;
                let frac = ((target - prev as f64) / c as f64).clamp(0.0, 1.0);
                return lo + frac * (hi - lo);
            }
        }
        LATENCY_BUCKETS_US[LATENCY_BUCKETS_US.len() - 1] as f64
    }
}

/// Sorted `key=value` label pairs — the metric identity alongside the name.
type Labels = Vec<(String, String)>;

#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn type_name(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

const STRIPES: usize = 8;

/// Process-wide (or per-server) registry of named metrics.
pub struct MetricsRegistry {
    stripes: Vec<Mutex<BTreeMap<(String, Labels), Metric>>>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            stripes: (0..STRIPES).map(|_| Mutex::new(BTreeMap::new())).collect(),
        }
    }

    /// A fresh shared registry — what a `ServeState` owns so concurrent
    /// tests (and co-hosted servers) never share counters.
    pub fn fresh() -> Arc<MetricsRegistry> {
        Arc::new(MetricsRegistry::new())
    }

    /// The process-global registry: pipeline phases and `dmmc run
    /// --metrics-out` publish here.
    pub fn global() -> &'static MetricsRegistry {
        static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
        GLOBAL.get_or_init(MetricsRegistry::new)
    }

    fn stripe(&self, name: &str) -> &Mutex<BTreeMap<(String, Labels), Metric>> {
        &self.stripes[(fnv1a(name) as usize) % STRIPES]
    }

    fn entry(&self, name: &str, labels: &[(&str, &str)], make: impl FnOnce() -> Metric) -> Metric {
        let mut key_labels: Labels = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        key_labels.sort();
        let mut map = self
            .stripe(name)
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        map.entry((name.to_string(), key_labels)).or_insert_with(make).clone()
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.entry(name, labels, || Metric::Counter(Arc::new(Counter::default()))) {
            Metric::Counter(c) => c,
            other => panic!("metric {name} already registered as {}", other.type_name()),
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.entry(name, labels, || Metric::Gauge(Arc::new(Gauge::default()))) {
            Metric::Gauge(g) => g,
            other => panic!("metric {name} already registered as {}", other.type_name()),
        }
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        match self.entry(name, labels, || Metric::Histogram(Arc::new(Histogram::new()))) {
            Metric::Histogram(h) => h,
            other => panic!("metric {name} already registered as {}", other.type_name()),
        }
    }

    /// Merged sorted snapshot of every registered metric.
    fn snapshot(&self) -> BTreeMap<(String, Labels), Metric> {
        let mut all = BTreeMap::new();
        for stripe in &self.stripes {
            let map = stripe.lock().unwrap_or_else(|e| e.into_inner());
            for (k, v) in map.iter() {
                all.insert(k.clone(), v.clone());
            }
        }
        all
    }

    /// Prometheus text exposition (`# TYPE` headers, `_bucket`/`_sum`/
    /// `_count` histogram series, escaped label values).  Sorted by
    /// `(name, labels)`, so two registries holding the same samples render
    /// byte-identical text.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_name: Option<String> = None;
        for ((name, labels), metric) in self.snapshot() {
            if last_name.as_deref() != Some(name.as_str()) {
                out.push_str(&format!("# TYPE {name} {}\n", metric.type_name()));
                last_name = Some(name.clone());
            }
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("{name}{} {}\n", label_set(&labels, None), c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("{name}{} {}\n", label_set(&labels, None), g.get()));
                }
                Metric::Histogram(h) => {
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (i, &bound) in LATENCY_BUCKETS_US.iter().enumerate() {
                        cum += counts[i];
                        let le = le_seconds(bound);
                        out.push_str(&format!(
                            "{name}_bucket{} {cum}\n",
                            label_set(&labels, Some(&le))
                        ));
                    }
                    cum += counts[LATENCY_BUCKETS_US.len()];
                    out.push_str(&format!(
                        "{name}_bucket{} {cum}\n",
                        label_set(&labels, Some("+Inf"))
                    ));
                    out.push_str(&format!(
                        "{name}_sum{} {}\n",
                        label_set(&labels, None),
                        sum_seconds(h.sum_us())
                    ));
                    out.push_str(&format!("{name}_count{} {cum}\n", label_set(&labels, None)));
                }
            }
        }
        out
    }

    /// JSON snapshot of the same registry — the `BENCH_*.json` payload
    /// (schema in EXPERIMENTS.md).  Sorted like the exposition.
    pub fn render_json(&self) -> String {
        let mut items = Vec::new();
        for ((name, labels), metric) in self.snapshot() {
            let lbl = labels
                .iter()
                .map(|(k, v)| format!("{}:{}", json_string(k), json_string(v)))
                .collect::<Vec<_>>()
                .join(",");
            let head = format!(
                "{{\"name\":{},\"type\":\"{}\",\"labels\":{{{lbl}}}",
                json_string(&name),
                metric.type_name()
            );
            let body = match metric {
                Metric::Counter(c) => format!(",\"value\":{}}}", c.get()),
                Metric::Gauge(g) => format!(",\"value\":{}}}", json_f64(g.get())),
                Metric::Histogram(h) => {
                    let counts = h.bucket_counts();
                    let bounds = LATENCY_BUCKETS_US
                        .iter()
                        .map(|b| b.to_string())
                        .collect::<Vec<_>>()
                        .join(",");
                    let cells = counts
                        .iter()
                        .map(|c| c.to_string())
                        .collect::<Vec<_>>()
                        .join(",");
                    format!(
                        ",\"buckets_le_us\":[{bounds}],\"bucket_counts\":[{cells}],\"sum_us\":{},\"count\":{}}}",
                        h.sum_us(),
                        h.count()
                    )
                }
            };
            items.push(format!("{head}{body}"));
        }
        format!("[{}]", items.join(","))
    }
}

/// Render a label set `{k="v",...}` (empty string when no labels), with
/// the optional `le` histogram label appended last as Prometheus does.
fn label_set(labels: &Labels, le: Option<&str>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Prometheus label-value escaping: backslash, double quote, newline.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// A bucket bound in seconds with trailing zeros trimmed (`10us` ->
/// `0.00001`, `1s` -> `1`): exact decimal text, no float formatting.
fn le_seconds(us: u64) -> String {
    let s = format!("{}.{:06}", us / 1_000_000, us % 1_000_000);
    let t = s.trim_end_matches('0').trim_end_matches('.');
    t.to_string()
}

/// The histogram sum in seconds, printed exactly from integer micros.
fn sum_seconds(us: u64) -> String {
    format!("{}.{:06}", us / 1_000_000, us % 1_000_000)
}

/// Minimal JSON string encoder (quotes + escapes).
pub(crate) fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number for a gauge: finite f64s via Display (shortest roundtrip
/// text), non-finite mapped to null.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("x_total", &[("t", "a")]);
        c.inc();
        c.add(4);
        assert_eq!(reg.counter("x_total", &[("t", "a")]).get(), 5);
        let g = reg.gauge("g", &[]);
        g.set(0.75);
        assert_eq!(reg.gauge("g", &[]).get(), 0.75);
    }

    #[test]
    fn label_order_is_identity_insensitive() {
        let reg = MetricsRegistry::new();
        reg.counter("c_total", &[("a", "1"), ("b", "2")]).inc();
        reg.counter("c_total", &[("b", "2"), ("a", "1")]).inc();
        assert_eq!(reg.counter("c_total", &[("a", "1"), ("b", "2")]).get(), 2);
    }

    #[test]
    fn histogram_bucket_assignment_is_inclusive_upper() {
        let h = Histogram::new();
        h.observe_us(10); // lands in le=10us, not le=25us
        h.observe_us(11);
        let counts = h.bucket_counts();
        assert_eq!(counts[0], 1);
        assert_eq!(counts[1], 1);
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum_us(), 21);
    }

    #[test]
    fn quantiles_are_monotone() {
        let h = Histogram::new();
        for us in [5, 40, 90, 400, 2_000, 80_000, 20_000_000] {
            h.observe_us(us);
        }
        let p50 = h.quantile_us(0.50);
        let p99 = h.quantile_us(0.99);
        assert!(p50 > 0.0);
        assert!(p99 >= p50);
        // the +Inf sample clamps to the last finite bound
        assert!(p99 <= LATENCY_BUCKETS_US[LATENCY_BUCKETS_US.len() - 1] as f64);
    }

    #[test]
    fn le_labels_are_trimmed_decimal_text() {
        assert_eq!(le_seconds(10), "0.00001");
        assert_eq!(le_seconds(250_000), "0.25");
        assert_eq!(le_seconds(1_000_000), "1");
        assert_eq!(le_seconds(10_000_000), "10");
    }
}
