//! Streaming harness (paper §4.3 semantics): single pass, small working
//! memory, with the accounting the paper reports — pass count, peak
//! working-set size and throughput.  The [`window`] submodule extends the
//! paper with sliding-window coresets built on composability.

pub mod window;

pub use window::SlidingWindowCoreset;

use std::time::Duration;

use anyhow::Result;

use crate::algo::stream_coreset::{StreamCoreset, StreamStats, DEFAULT_C};
use crate::algo::Coreset;
use crate::core::Dataset;
use crate::diversity::{diversity_with_engine, Objective};
use crate::matroid::Matroid;
use crate::runtime::engine::DistanceEngine;
use crate::runtime::EngineKind;
use crate::util::timer::Stopwatch;

/// How the streaming algorithm is parameterized.
#[derive(Clone, Copy, Debug)]
pub enum StreamMode {
    /// Faithful Algorithm 2 (`c` = 32).
    Epsilon(f64),
    /// The tau-controlled experimental variant (§5.2).
    Tau(usize),
}

/// Report of one streaming pass.
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub coreset: Coreset,
    pub stats: StreamStats,
    pub passes: usize,
    pub elapsed: Duration,
    /// Points per second.
    pub throughput: f64,
}

impl StreamReport {
    /// Score the finished coreset under `obj` through the engine-backed
    /// diversity evaluator — the scoring half of a streaming finisher
    /// (solution *selection* still runs local search / exhaustive over
    /// the coreset; see the coordinator).
    pub fn coreset_diversity(
        &self,
        ds: &Dataset,
        obj: Objective,
        engine: &dyn DistanceEngine,
    ) -> Result<f64> {
        diversity_with_engine(ds, &self.coreset.indices, obj, engine)
    }
}

/// Run one streaming pass over `order` (a permutation of `0..ds.n()`, or
/// any index sequence — the "stream") with the default scalar restructure
/// engine (the §5.2 cost model's configuration).
pub fn run_stream(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    mode: StreamMode,
    order: &[usize],
) -> StreamReport {
    run_stream_with_engine(ds, m, k, mode, order, EngineKind::Scalar)
        .expect("scalar engine construction cannot fail")
}

/// [`run_stream`] with a registry-selected backend for the restructure
/// re-assignment tiles — the streaming arm of the engine A/B axis
/// (`run_pipeline` threads `Pipeline::engine` through here).  The engine
/// build is part of the timed pass, mirroring `run_pipeline`'s coreset
/// phase accounting; it can fail only for backends with external
/// dependencies (PJRT artifacts).
///
/// Accounting caveats: `StreamStats::peak_memory_points` counts delegate
/// points only (the §5.2 working-set measure) — a non-scalar engine on a
/// *cosine* dataset additionally holds its O(n) precomputed norms, state
/// the pipeline's finisher/evaluator engine carries anyway (Euclidean
/// backends skip the precompute entirely).  Restructure tie-breaks read
/// the engine's f32 tile, so a tolerance-level backend (simd-on-cosine,
/// pjrt) may legitimately restructure slightly differently than the
/// bit-exact backends; `distance_evals` counts tile entries either way.
pub fn run_stream_with_engine(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    mode: StreamMode,
    order: &[usize],
    engine: EngineKind,
) -> Result<StreamReport> {
    let sw = Stopwatch::start();
    let mut alg = match mode {
        StreamMode::Epsilon(eps) => StreamCoreset::new(ds, m, k, eps, DEFAULT_C),
        StreamMode::Tau(tau) => StreamCoreset::with_tau(ds, m, k, tau),
    };
    if engine != EngineKind::Scalar {
        alg.set_engine_kind(engine)?;
    }
    for &x in order {
        alg.push(x);
    }
    let (coreset, stats) = alg.finish();
    let elapsed = sw.elapsed();
    let throughput = order.len() as f64 / elapsed.as_secs_f64().max(1e-12);
    Ok(StreamReport {
        coreset,
        stats,
        passes: 1,
        elapsed,
        throughput,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::UniformMatroid;

    #[test]
    fn single_pass_reported() {
        use crate::runtime::engine::ScalarEngine;

        let ds = synth::uniform_cube(500, 2, 1);
        let m = UniformMatroid::new(4);
        let order: Vec<usize> = (0..ds.n()).collect();
        let rep = run_stream(&ds, &m, 4, StreamMode::Tau(16), &order);
        assert_eq!(rep.passes, 1);
        assert_eq!(rep.stats.points_processed, 500);
        assert!(rep.throughput > 0.0);
        assert!(!rep.coreset.is_empty());
        // engine-backed scoring of the finished coreset
        let d = rep
            .coreset_diversity(&ds, Objective::Sum, &ScalarEngine::new())
            .unwrap();
        assert!(d > 0.0);
    }

    #[test]
    fn engine_kinds_thread_through_streaming() {
        let ds = synth::uniform_cube(400, 2, 3);
        let m = UniformMatroid::new(3);
        let order: Vec<usize> = (0..ds.n()).collect();
        let base = run_stream(&ds, &m, 3, StreamMode::Tau(12), &order);
        for kind in [EngineKind::Batch, EngineKind::Simd] {
            let rep =
                run_stream_with_engine(&ds, &m, 3, StreamMode::Tau(12), &order, kind).unwrap();
            // Euclidean restructure tiles are bit-identical across the
            // CPU backends, so the coreset cannot depend on the choice
            assert_eq!(
                rep.coreset.indices,
                base.coreset.indices,
                "engine {} changed the stream coreset",
                kind.name()
            );
        }
    }

    #[test]
    fn epsilon_and_tau_modes_both_work() {
        let ds = synth::uniform_cube(300, 2, 2);
        let m = UniformMatroid::new(3);
        let order: Vec<usize> = (0..ds.n()).collect();
        let a = run_stream(&ds, &m, 3, StreamMode::Epsilon(0.5), &order);
        let b = run_stream(&ds, &m, 3, StreamMode::Tau(12), &order);
        assert!(!a.coreset.is_empty());
        assert!(b.coreset.n_clusters <= 12);
    }
}
