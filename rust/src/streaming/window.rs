//! Sliding-window coresets — an extension beyond the paper (its related
//! work cites Borassi et al. [7] for sliding-window diversity; the paper
//! itself leaves windows open).  Built directly on the paper's own
//! *composability* property (Theorem 6): the window is split into blocks,
//! each block carries its own SeqCoreset, and the union of live-block
//! coresets is a coreset for the window.
//!
//! Memory: O(blocks_per_window * coreset_size) — independent of the window
//! length in points whenever the per-block coreset is.

use anyhow::Result;

use crate::algo::seq_coreset::seq_coreset;
use crate::algo::Budget;
use crate::core::Dataset;
use crate::matroid::Matroid;
use crate::runtime::BatchEngine;

/// Blocked sliding-window coreset maintainer.
pub struct SlidingWindowCoreset<'a, M: Matroid> {
    ds: &'a Dataset,
    m: &'a M,
    k: usize,
    /// Per-block coreset budget.
    tau: usize,
    /// Points per block.
    block_size: usize,
    /// Number of live blocks (window = block_size * window_blocks points).
    window_blocks: usize,
    /// Buffer of the block being filled.
    pending: Vec<usize>,
    /// Live blocks: (first_stream_position, coreset indices into ds).
    blocks: std::collections::VecDeque<(usize, Vec<usize>)>,
    seen: usize,
}

impl<'a, M: Matroid> SlidingWindowCoreset<'a, M> {
    pub fn new(
        ds: &'a Dataset,
        m: &'a M,
        k: usize,
        tau: usize,
        block_size: usize,
        window_blocks: usize,
    ) -> Self {
        assert!(block_size > 0 && window_blocks > 0);
        SlidingWindowCoreset {
            ds,
            m,
            k,
            tau,
            block_size,
            window_blocks,
            pending: Vec::with_capacity(block_size),
            blocks: Default::default(),
            seen: 0,
        }
    }

    /// Feed the next stream element (a dataset index).
    pub fn push(&mut self, x: usize) -> Result<()> {
        self.pending.push(x);
        self.seen += 1;
        if self.pending.len() == self.block_size {
            self.seal_block()?;
        }
        Ok(())
    }

    fn seal_block(&mut self) -> Result<()> {
        let block = std::mem::take(&mut self.pending);
        let start = self.seen - block.len();
        let local = self.ds.subset(&block);
        // blocks are small, so the batch engine usually stays on one
        // thread; past its fan-out threshold the block seal parallelizes
        let cs = seq_coreset(
            &local,
            self.m,
            self.k,
            Budget::Clusters(self.tau),
            &BatchEngine::for_dataset(&local),
        )?;
        let global: Vec<usize> = cs.indices.iter().map(|&i| block[i]).collect();
        self.blocks.push_back((start, global));
        while self.blocks.len() > self.window_blocks {
            self.blocks.pop_front();
        }
        Ok(())
    }

    /// Coreset for the current window: union of live block coresets plus
    /// the raw pending buffer (its block is not sealed yet).
    pub fn query(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .blocks
            .iter()
            .flat_map(|(_, cs)| cs.iter().copied())
            .chain(self.pending.iter().copied())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Stream positions covered by the current window (inclusive start).
    pub fn window_start(&self) -> usize {
        self.blocks
            .front()
            .map(|(s, _)| *s)
            .unwrap_or(self.seen - self.pending.len())
    }

    /// Stored points right now — the memory footprint.
    pub fn memory_points(&self) -> usize {
        self.blocks.iter().map(|(_, cs)| cs.len()).sum::<usize>() + self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::{maximal_independent, PartitionMatroid, UniformMatroid};

    #[test]
    fn window_slides_and_expires_old_blocks() {
        let ds = synth::uniform_cube(1000, 2, 1);
        let m = UniformMatroid::new(4);
        let mut sw = SlidingWindowCoreset::new(&ds, &m, 4, 4, 100, 3);
        for i in 0..1000 {
            sw.push(i).unwrap();
        }
        // window = last 3 sealed blocks = positions 700..1000
        assert_eq!(sw.window_start(), 700);
        let q = sw.query();
        assert!(q.iter().all(|&i| i >= 700), "expired point in window: {q:?}");
        assert!(!q.is_empty());
    }

    #[test]
    fn memory_independent_of_stream_length() {
        let ds = synth::uniform_cube(5000, 2, 2);
        let m = UniformMatroid::new(4);
        let mut sw = SlidingWindowCoreset::new(&ds, &m, 4, 4, 200, 4);
        let mut peak = 0;
        for i in 0..5000 {
            sw.push(i).unwrap();
            peak = peak.max(sw.memory_points());
        }
        // 4 blocks x (tau * k) + one pending block
        assert!(peak <= 4 * 4 * 4 + 200, "peak {peak}");
    }

    #[test]
    fn window_coreset_feasible_under_matroid() {
        let ds = synth::clustered(2000, 2, 4, 0.1, 4, 3);
        let m = PartitionMatroid::new(vec![2; 4]);
        let k = 5;
        let mut sw = SlidingWindowCoreset::new(&ds, &m, k, 8, 250, 4);
        for i in 0..2000 {
            sw.push(i).unwrap();
            if i % 500 == 499 {
                let q = sw.query();
                let sol = maximal_independent(&m, &ds, &q, k);
                assert_eq!(sol.len(), k, "window at {i} lost feasibility");
            }
        }
    }

    #[test]
    fn pending_points_are_queryable_immediately() {
        let ds = synth::uniform_cube(50, 2, 4);
        let m = UniformMatroid::new(2);
        let mut sw = SlidingWindowCoreset::new(&ds, &m, 2, 2, 100, 2);
        for i in 0..7 {
            sw.push(i).unwrap();
        }
        let q = sw.query();
        assert_eq!(q, vec![0, 1, 2, 3, 4, 5, 6]);
    }
}
