//! Sliding-window coresets — an extension beyond the paper (its related
//! work cites Borassi et al. [7] for sliding-window diversity; the paper
//! itself leaves windows open).  Built on the paper's own *composability*
//! property (Theorem 6): the window is split into blocks, each block
//! carries its own SeqCoreset, and the union of live-block coresets is a
//! coreset for the window.
//!
//! Since the coreset index became fully dynamic, this type is a thin
//! wrapper over [`CoresetIndex`] with
//! [`RetentionPolicy::LastSegments`] retention: each sealed block is one
//! appended segment, the index's no-merge windowed mode keeps leaf
//! granularity, and expiry of whole blocks is the retention sweep.  One
//! subsystem now serves append-only, windowed, and delete-capable
//! workloads; only the pending (unsealed) buffer lives here.
//!
//! Memory: O(blocks_per_window * coreset_size) — independent of the window
//! length in points whenever the per-block coreset is.

use anyhow::Result;

use crate::core::Dataset;
use crate::index::tree::{CoresetIndex, IndexConfig, RetentionPolicy};
use crate::matroid::Matroid;
use crate::runtime::EngineKind;

/// Blocked sliding-window coreset maintainer.
pub struct SlidingWindowCoreset<'a> {
    index: CoresetIndex<'a>,
    /// Points per block.
    block_size: usize,
    /// Buffer of the block being filled.
    pending: Vec<usize>,
    seen: usize,
}

impl<'a> SlidingWindowCoreset<'a> {
    /// Window maintainer on the registry's default engine (the same
    /// backend every other scenario defaults to).
    pub fn new(
        ds: &'a Dataset,
        m: &'a dyn Matroid,
        k: usize,
        tau: usize,
        block_size: usize,
        window_blocks: usize,
    ) -> Self {
        Self::with_engine(ds, m, k, tau, block_size, window_blocks, EngineKind::default())
    }

    /// Window maintainer with an explicit block-seal backend — the
    /// `--engine` / `run.engine` / `DMMC_BENCH_ENGINE` axis, which the
    /// window previously ignored by hardcoding the batch engine.
    pub fn with_engine(
        ds: &'a Dataset,
        m: &'a dyn Matroid,
        k: usize,
        tau: usize,
        block_size: usize,
        window_blocks: usize,
        engine: EngineKind,
    ) -> Self {
        assert!(block_size > 0 && window_blocks > 0);
        // IndexConfig::new already picks Budget::Clusters(tau) seq leaves
        let cfg = IndexConfig {
            engine,
            retention: RetentionPolicy::LastSegments(window_blocks),
            ..IndexConfig::new(k, tau)
        };
        SlidingWindowCoreset {
            index: CoresetIndex::new(ds, m, cfg),
            block_size,
            pending: Vec::with_capacity(block_size),
            seen: 0,
        }
    }

    /// Feed the next stream element (a dataset index).
    pub fn push(&mut self, x: usize) -> Result<()> {
        self.pending.push(x);
        self.seen += 1;
        if self.pending.len() == self.block_size {
            let block = std::mem::take(&mut self.pending);
            // blocks are small, so the seal usually stays on one thread;
            // past the engine's fan-out threshold it parallelizes
            self.index.append(&block)?;
        }
        Ok(())
    }

    /// Coreset for the current window: union of live block coresets (the
    /// index root) plus the raw pending buffer (its block is not sealed
    /// yet).
    pub fn query(&self) -> Vec<usize> {
        let mut out = self.index.root();
        out.extend_from_slice(&self.pending);
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Stream positions covered by the current window (inclusive start).
    pub fn window_start(&self) -> usize {
        let sealed = (self.seen - self.pending.len()) / self.block_size;
        let w = match self.index.config().retention {
            RetentionPolicy::LastSegments(w) => w,
            // unreachable by construction; keep the math total anyway
            _ => sealed,
        };
        sealed.saturating_sub(w) * self.block_size
    }

    /// Stored points right now — the memory footprint (live index members
    /// plus the pending buffer).
    pub fn memory_points(&self) -> usize {
        self.index.member_count() + self.pending.len()
    }

    /// The backing index (window-retained); exposed so callers can serve
    /// queries or snapshots through the standard index surface.
    pub fn index(&self) -> &CoresetIndex<'a> {
        &self.index
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::{maximal_independent, PartitionMatroid, UniformMatroid};

    #[test]
    fn window_slides_and_expires_old_blocks() {
        let ds = synth::uniform_cube(1000, 2, 1);
        let m = UniformMatroid::new(4);
        let mut sw = SlidingWindowCoreset::new(&ds, &m, 4, 4, 100, 3);
        for i in 0..1000 {
            sw.push(i).unwrap();
        }
        // window = last 3 sealed blocks = positions 700..1000
        assert_eq!(sw.window_start(), 700);
        let q = sw.query();
        assert!(q.iter().all(|&i| i >= 700), "expired point in window: {q:?}");
        assert!(!q.is_empty());
        // the backing index saw every block as a segment and expired the
        // rest exactly
        assert_eq!(sw.index().segments(), 10);
        assert_eq!(sw.index().stats().expired_segments, 7);
    }

    #[test]
    fn memory_independent_of_stream_length() {
        let ds = synth::uniform_cube(5000, 2, 2);
        let m = UniformMatroid::new(4);
        let mut sw = SlidingWindowCoreset::new(&ds, &m, 4, 4, 200, 4);
        let mut peak = 0;
        for i in 0..5000 {
            sw.push(i).unwrap();
            peak = peak.max(sw.memory_points());
        }
        // 4 blocks x (tau * k) + one pending block
        assert!(peak <= 4 * 4 * 4 + 200, "peak {peak}");
    }

    #[test]
    fn window_coreset_feasible_under_matroid() {
        let ds = synth::clustered(2000, 2, 4, 0.1, 4, 3);
        let m = PartitionMatroid::new(vec![2; 4]);
        let k = 5;
        let mut sw = SlidingWindowCoreset::new(&ds, &m, k, 8, 250, 4);
        for i in 0..2000 {
            sw.push(i).unwrap();
            if i % 500 == 499 {
                let q = sw.query();
                let sol = maximal_independent(&m, &ds, &q, k);
                assert_eq!(sol.len(), k, "window at {i} lost feasibility");
            }
        }
    }

    #[test]
    fn pending_points_are_queryable_immediately() {
        let ds = synth::uniform_cube(50, 2, 4);
        let m = UniformMatroid::new(2);
        let mut sw = SlidingWindowCoreset::new(&ds, &m, 2, 2, 100, 2);
        for i in 0..7 {
            sw.push(i).unwrap();
        }
        let q = sw.query();
        assert_eq!(q, vec![0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn engine_kinds_agree_on_euclidean_windows() {
        let ds = synth::uniform_cube(900, 2, 5);
        let m = UniformMatroid::new(4);
        let mut base = SlidingWindowCoreset::with_engine(&ds, &m, 4, 4, 150, 3, EngineKind::Scalar);
        let mut batch = SlidingWindowCoreset::with_engine(&ds, &m, 4, 4, 150, 3, EngineKind::Batch);
        for i in 0..900 {
            base.push(i).unwrap();
            batch.push(i).unwrap();
            // Euclidean block seals are bit-identical across the CPU
            // backends, so the whole window trajectory must agree
            if i % 150 == 149 {
                assert_eq!(batch.query(), base.query(), "engines diverged at {i}");
            }
        }
        assert_eq!(batch.query(), base.query());
        assert_eq!(batch.window_start(), base.window_start());
    }
}
