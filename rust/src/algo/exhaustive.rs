//! Exhaustive search over a coreset — the paper's final-solution extractor
//! for the DMMC variants with no known polynomial-time approximation
//! (star / tree / cycle / bipartition, §4.4): on a `(1 - eps)`-coreset the
//! best independent k-subset is a `(1 - eps)`-approximation to the optimum
//! over the full input.
//!
//! DFS over independent k-subsets in index order, with:
//! * matroid pruning (`can_extend` at every level),
//! * a branch-and-bound upper bound for the *sum* objective (partial sum +
//!   optimistic `dmax` completion), and
//! * a `dmax`-based leaf bound for the other objectives (their value is at
//!   most `f(k) * dmax`, so branches are cut once `best` is within that).
//!
//! All distance work is one engine-built candidate submatrix
//! ([`Evaluator::submatrix`], i.e. a single `pairwise_block` tile): every
//! leaf of every objective evaluates from that matrix with zero further
//! distance evaluations, and only the winning solution is re-scored once
//! through [`Evaluator::diversity`] so the reported value matches the
//! pipeline's primary evaluation path (exact f64 sums for sum/star).
//!
//! Cost is O(|T|^k) in the worst case — exactly the paper's bound — so
//! callers keep |T| and k small (the whole point of the coreset).

use anyhow::Result;

use crate::core::Dataset;
use crate::diversity::{diversity_from_matrix, Evaluator, Objective};
use crate::matroid::Matroid;
use crate::runtime::engine::DistanceEngine;

/// Search outcome.
#[derive(Clone, Debug)]
pub struct ExhaustiveResult {
    pub solution: Vec<usize>,
    pub diversity: f64,
    /// Number of candidate subsets fully evaluated (leaves reached).
    pub leaves: u64,
    /// Number of tree nodes visited.
    pub nodes: u64,
}

/// Find the best independent k-subset of `candidates` under `obj`,
/// evaluating through `engine`.  Returns the best *feasible* solution
/// found; if no independent k-subset exists the solution is empty.
pub fn exhaustive_best(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    candidates: &[usize],
    obj: Objective,
    engine: &dyn DistanceEngine,
) -> Result<ExhaustiveResult> {
    let t = candidates.len();
    let evaluator = Evaluator::new(engine);
    let matrix = evaluator.submatrix(ds, candidates)?;
    let dmax = matrix.iter().cloned().fold(0.0f64, f64::max);
    let mut best = ExhaustiveResult {
        solution: Vec::new(),
        diversity: -1.0,
        leaves: 0,
        nodes: 0,
    };
    let mut chosen_pos: Vec<usize> = Vec::with_capacity(k);
    let mut chosen_idx: Vec<usize> = Vec::with_capacity(k);
    let mut partial_sum = 0.0f64; // sum of pairwise distances among chosen

    struct Ctx<'c> {
        ds: &'c Dataset,
        m: &'c dyn Matroid,
        candidates: &'c [usize],
        matrix: &'c [f64],
        t: usize,
        k: usize,
        obj: Objective,
        dmax: f64,
    }

    fn dfs(
        ctx: &Ctx,
        start: usize,
        chosen_pos: &mut Vec<usize>,
        chosen_idx: &mut Vec<usize>,
        partial_sum: &mut f64,
        best: &mut ExhaustiveResult,
    ) {
        best.nodes += 1;
        let depth = chosen_pos.len();
        if depth == ctx.k {
            best.leaves += 1;
            // every objective reads the shared candidate matrix — no
            // per-leaf submatrix rebuild, no Dataset::dist re-walk
            let value = match ctx.obj {
                Objective::Sum => *partial_sum,
                _ => diversity_from_matrix(ctx.matrix, ctx.t, chosen_pos, ctx.obj),
            };
            if value > best.diversity {
                best.diversity = value;
                best.solution = chosen_idx.clone();
            }
            return;
        }
        // not enough candidates left to fill k slots
        if ctx.t - start < ctx.k - depth {
            return;
        }
        // bound: optimistic completion with dmax edges
        if best.diversity >= 0.0 {
            let remaining_pairs = ctx.obj.f_k(ctx.k)
                - match ctx.obj {
                    Objective::Sum => (depth * depth.saturating_sub(1)) as f64 / 2.0,
                    _ => 0.0,
                };
            let bound = match ctx.obj {
                Objective::Sum => *partial_sum + remaining_pairs * ctx.dmax,
                // other objectives: global bound f(k) * dmax
                _ => remaining_pairs * ctx.dmax,
            };
            if bound <= best.diversity {
                return;
            }
        }
        for pos in start..ctx.t {
            let x = ctx.candidates[pos];
            if !ctx.m.can_extend(ctx.ds, chosen_idx, x) {
                continue;
            }
            let add: f64 = chosen_pos
                .iter()
                .map(|&p| ctx.matrix[p * ctx.t + pos])
                .sum();
            chosen_pos.push(pos);
            chosen_idx.push(x);
            *partial_sum += add;
            dfs(ctx, pos + 1, chosen_pos, chosen_idx, partial_sum, best);
            *partial_sum -= add;
            chosen_idx.pop();
            chosen_pos.pop();
        }
    }

    let ctx = Ctx {
        ds,
        m,
        candidates,
        matrix: &matrix,
        t,
        k,
        obj,
        dmax,
    };
    dfs(
        &ctx,
        0,
        &mut chosen_pos,
        &mut chosen_idx,
        &mut partial_sum,
        &mut best,
    );
    if best.diversity < 0.0 {
        best.diversity = 0.0;
    } else {
        // re-score the winner through the evaluator's primary dispatch so
        // callers can compare the reported value against `diversity` /
        // `diversity_with_engine` without representation skew (the search
        // compared sum/star leaves in f32-tile space)
        best.diversity = evaluator.diversity(ds, &best.solution, obj)?;
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::diversity::{diversity, sum_diversity, ALL_OBJECTIVES};
    use crate::matroid::{Matroid, PartitionMatroid, UniformMatroid};
    use crate::runtime::engine::ScalarEngine;
    use crate::runtime::BatchEngine;

    #[test]
    fn finds_global_optimum_sum() {
        let ds = synth::uniform_cube(18, 2, 1);
        let m = UniformMatroid::new(4);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = exhaustive_best(&ds, &m, 4, &cands, Objective::Sum, &ScalarEngine::new())
            .unwrap();
        // verify against plain enumeration; the search compares sum
        // leaves in f32-tile space, so allow f32-level slack around the
        // exact argmax
        let mut best = -1.0f64;
        for a in 0..18 {
            for b in a + 1..18 {
                for c in b + 1..18 {
                    for d in c + 1..18 {
                        best = best.max(sum_diversity(&ds, &[a, b, c, d]));
                    }
                }
            }
        }
        assert!((res.diversity - best).abs() < 1e-6 * best.max(1.0));
        assert_eq!(res.solution.len(), 4);
    }

    #[test]
    fn respects_matroid() {
        let ds = synth::clustered(30, 2, 3, 0.1, 3, 2);
        let m = PartitionMatroid::new(vec![1, 1, 1]);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = exhaustive_best(&ds, &m, 3, &cands, Objective::Sum, &ScalarEngine::new())
            .unwrap();
        assert!(m.is_independent(&ds, &res.solution));
        assert_eq!(res.solution.len(), 3);
    }

    #[test]
    fn all_objectives_produce_feasible_solutions() {
        let ds = synth::uniform_cube(16, 2, 3);
        let m = UniformMatroid::new(4);
        let cands: Vec<usize> = (0..ds.n()).collect();
        for obj in ALL_OBJECTIVES {
            let res = exhaustive_best(&ds, &m, 4, &cands, obj, &ScalarEngine::new()).unwrap();
            assert_eq!(res.solution.len(), 4, "{obj:?}");
            assert!(res.diversity > 0.0, "{obj:?}");
            // the winner is re-scored through the evaluator's primary
            // path, which is exactly what `diversity` runs
            assert!((res.diversity - diversity(&ds, &res.solution, obj)).abs() < 1e-9);
        }
    }

    #[test]
    fn engine_choice_does_not_change_the_search() {
        // the candidate tile is bit-identical across CPU engines, so the
        // whole DFS trajectory — solution, value, node counts — must agree
        let ds = synth::uniform_cube(20, 3, 8);
        let m = UniformMatroid::new(4);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let batch = BatchEngine::for_dataset(&ds);
        for obj in ALL_OBJECTIVES {
            let a = exhaustive_best(&ds, &m, 4, &cands, obj, &ScalarEngine::new()).unwrap();
            let b = exhaustive_best(&ds, &m, 4, &cands, obj, &batch).unwrap();
            assert_eq!(a.solution, b.solution, "{obj:?}");
            assert!(a.diversity.to_bits() == b.diversity.to_bits(), "{obj:?}");
            assert_eq!(a.nodes, b.nodes, "{obj:?}");
            assert_eq!(a.leaves, b.leaves, "{obj:?}");
        }
    }

    #[test]
    fn pruning_does_not_lose_optimum() {
        // compare leaves with/without effective pruning by checking the
        // value equals plain enumeration for a non-sum objective
        let ds = synth::uniform_cube(14, 2, 5);
        let m = UniformMatroid::new(4);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = exhaustive_best(&ds, &m, 4, &cands, Objective::Tree, &ScalarEngine::new())
            .unwrap();
        let mut best = -1.0;
        for a in 0..14usize {
            for b in a + 1..14 {
                for c in b + 1..14 {
                    for d in c + 1..14 {
                        best = f64::max(best, diversity(&ds, &[a, b, c, d], Objective::Tree));
                    }
                }
            }
        }
        assert!((res.diversity - best).abs() < 1e-9);
    }

    #[test]
    fn infeasible_k_returns_empty() {
        let ds = synth::clustered(10, 2, 2, 0.1, 2, 7);
        let m = PartitionMatroid::new(vec![1, 1]); // rank 2 < k=3
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = exhaustive_best(&ds, &m, 3, &cands, Objective::Sum, &ScalarEngine::new())
            .unwrap();
        assert!(res.solution.is_empty());
        assert_eq!(res.diversity, 0.0);
    }

    #[test]
    fn sum_bound_prunes() {
        // sanity: pruned search visits fewer nodes than the unpruned
        // upper bound t^k (loose check: strictly less than C(t, k) nodes
        // would be ideal; assert well under the trivial product bound)
        let ds = synth::clustered(24, 2, 2, 0.05, 1, 9);
        let m = UniformMatroid::new(4);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = exhaustive_best(&ds, &m, 4, &cands, Objective::Sum, &ScalarEngine::new())
            .unwrap();
        assert!(res.nodes < 24 * 23 * 22 * 21);
        assert!(res.leaves <= res.nodes);
    }
}
