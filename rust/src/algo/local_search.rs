//! AMT local search for sum-DMMC — the (1/2 - gamma)-approximation of
//! Abbassi, Mirrokni & Thakur [1], the paper's sequential baseline and its
//! final-solution extractor on coresets (with gamma = 0, footnote 5).
//!
//! Start from an independent set of size k, then repeatedly apply a
//! feasible swap (u out, v in) that improves the sum-diversity by a factor
//! of at least `1 + gamma`; after an accepted swap the pass restarts from
//! the first candidate (the AMT scan); stop when a full pass finds no such
//! swap.
//!
//! # Incremental vs pass-restart distance work
//!
//! The scan's acceptance logic needs, per candidate `v`, the sum of
//! distances to the current solution.  Two modes maintain those sums, both
//! producing the same swap trajectory:
//!
//! * [`LocalSearchMode::ExhaustiveRestart`] — the reference semantics:
//!   every pass recomputes all candidate sums in one batched
//!   [`DistanceEngine::sums_to_set`] call (O(n k) distance evaluations per
//!   accepted swap), plus a fresh k x k member pass after the swap.
//! * [`LocalSearchMode::Incremental`] (default) — after an accepted swap
//!   (u out, v in) every candidate's sum changes by exactly
//!   `d(c, v) - d(c, u)`, so the search keeps an exact column store
//!   `cols[c][j] = d(candidates[c], sol[j])` and refreshes it with one
//!   [`DistanceEngine::dists_to_points`] column pass per swap (O(n)
//!   distance evaluations): the evicted column is read from the store, the
//!   incoming column overwrites it, and the candidate sums absorb the
//!   difference in exact f64.  Member sums take one narrow two-column pass
//!   over the k - 1 staying members; the incoming member's sum is the
//!   delta-maintained candidate sum itself.
//!
//! # The epoch / re-anchor contract
//!
//! Delta-accumulated sums drift from the from-scratch accumulation order
//! by a few ulps per swap.  Every [`REANCHOR_EPOCH`] accepted swaps the
//! incremental state is re-anchored: candidate sums are re-summed from the
//! column store — the columns hold exact engine distances with true-zero
//! self-pairs, so the row re-summation is **bit-identical** to a fresh
//! `sums_to_set` pass at zero additional distance evaluations — and the
//! member sums get one fresh k x k engine pass.  Between anchors the drift
//! is bounded by ~2 · `REANCHOR_EPOCH` · eps relative to the sums, far
//! below the `1e-12`-relative swap-acceptance slack, so the two modes make
//! identical accept/reject decisions; `rust/tests/local_search_incremental.rs`
//! pins the full (solution, swaps, oracle_calls, passes) trajectory across
//! modes, engines, and matroid families, and
//! `rust/tests/property_invariants.rs` pins the drift bound itself.
//!
//! The incremental column store costs `candidates.len() * k` f64s of
//! memory (e.g. ~4 MB for the 5k-point full-input AMT baseline at rank
//! 100) — the trade for cutting the per-swap distance work from O(n k) to
//! O(n).
//!
//! [1]: Abbassi, Mirrokni, Thakur, "Diversity maximization under matroid
//!      constraints", KDD 2013.

use anyhow::Result;

use crate::algo::greedy::greedy_matroid_gonzalez;
use crate::core::Dataset;
use crate::matroid::Matroid;
use crate::runtime::engine::DistanceEngine;
use crate::util::rng::Rng;

/// Accepted swaps between re-anchors of the incremental state (candidate
/// sums re-summed from the exact column store, member sums refreshed with
/// one k x k engine pass) — the drift bound of the epoch contract.
pub const REANCHOR_EPOCH: usize = 32;

/// How the candidate/member sums are maintained across accepted swaps.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LocalSearchMode {
    /// Column store + per-swap delta updates (O(n) distance evaluations
    /// per accepted swap), re-anchored every [`REANCHOR_EPOCH`] swaps.
    #[default]
    Incremental,
    /// The pre-incremental reference semantics: every pass re-runs the
    /// full O(n k) `sums_to_set` scan.  Kept as the trajectory-identity
    /// oracle the incremental path is pinned against.
    ExhaustiveRestart,
}

impl LocalSearchMode {
    pub fn name(self) -> &'static str {
        match self {
            LocalSearchMode::Incremental => "incremental",
            LocalSearchMode::ExhaustiveRestart => "exhaustive_restart",
        }
    }
}

/// Outcome of a local-search run.
#[derive(Clone, Debug)]
pub struct LocalSearchResult {
    /// The solution (independent, size <= k; == k unless rank < k).
    pub solution: Vec<usize>,
    /// Its sum-diversity.
    pub diversity: f64,
    /// Number of accepted swaps.
    pub swaps: usize,
    /// Number of independence-oracle calls made.
    pub oracle_calls: u64,
    /// Number of scan passes (= swaps + 1 on normal termination: every
    /// accepted swap restarts the pass, plus the final pass that proves
    /// local optimality).
    pub passes: usize,
    /// Distance evaluations requested from the engine (batched passes,
    /// net of the self-pairs the engine excludes).  Under `ScalarEngine`
    /// this equals the engine's own `dist_evals` counter delta — the
    /// regression tests cross-check the two.  The per-improving-candidate
    /// `d(v, u)` corrections go through `Dataset::dist` directly and are
    /// not included.
    pub dist_evals: u64,
}

/// Configuration for [`local_search_sum`].
#[derive(Clone, Copy, Debug)]
pub struct LocalSearchParams {
    /// Improvement factor: accept a swap only if it improves the objective
    /// by a factor > (1 + gamma). gamma = 0 -> any strict improvement.
    pub gamma: f64,
    /// Safety cap on accepted swaps (the gamma = 0 regime has no polynomial
    /// bound; the cap is far above anything observed in practice).
    pub max_swaps: usize,
    /// Sum-maintenance strategy; [`LocalSearchMode::Incremental`] unless a
    /// test pins the trajectory against the restart reference.
    pub mode: LocalSearchMode,
    /// Accepted swaps between incremental re-anchors ([`REANCHOR_EPOCH`]
    /// by default; 0 is treated as 1).  Exposed so the trajectory tests
    /// can pin that the anchor cadence cannot change a decision.
    pub reanchor_epoch: usize,
}

impl Default for LocalSearchParams {
    fn default() -> Self {
        LocalSearchParams {
            gamma: 0.0,
            max_swaps: 10_000,
            mode: LocalSearchMode::Incremental,
            reanchor_epoch: REANCHOR_EPOCH,
        }
    }
}

/// Run AMT local search over `candidates` (e.g. a coreset or the full
/// dataset; indices must be distinct).  `init`: optional warm start (must
/// be independent, need not be a subset of `candidates`).
///
/// All batched distance work goes through `engine`
/// ([`DistanceEngine::sums_to_set`] / [`DistanceEngine::dists_to_points`]);
/// acceptance decisions stay in exact f64 with the oracle formulas, so the
/// trajectory is engine-independent across `scalar` and `batch`, and
/// mode-independent per the epoch / re-anchor contract (module docs).
#[allow(clippy::too_many_arguments)]
pub fn local_search_sum(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    candidates: &[usize],
    engine: &dyn DistanceEngine,
    params: LocalSearchParams,
    init: Option<Vec<usize>>,
    rng: &mut Rng,
) -> Result<LocalSearchResult> {
    let mut oracle_calls: u64 = 0;
    let mut dist_evals: u64 = 0;
    let mut sol = match init {
        Some(s) => s,
        None => greedy_matroid_gonzalez(ds, m, k, candidates, rng),
    };
    debug_assert!(m.is_independent(ds, &sol));
    if sol.len() < 2 {
        // fewer than two members -> no pairs -> zero sum-diversity
        return Ok(LocalSearchResult {
            solution: sol,
            diversity: 0.0,
            swaps: 0,
            oracle_calls,
            passes: 0,
            dist_evals,
        });
    }
    let kk = sol.len();
    let n = candidates.len();

    // membership bitmaps over dataset ids: `in_sol` replaces the old O(k)
    // `sol.contains(&v)` scan per candidate and is refreshed per swap;
    // `is_cand` keeps the candidate/solution overlap (the self-pairs the
    // engine excludes) countable in O(1) per swap for the eval ledger
    let mut in_sol = vec![false; ds.n()];
    for &u in &sol {
        in_sol[u] = true;
    }
    let mut is_cand = vec![false; ds.n()];
    for &c in candidates {
        debug_assert!(!is_cand[c], "local_search_sum: candidates must be distinct");
        is_cand[c] = true;
    }
    let mut overlap: u64 = candidates.iter().filter(|&&c| in_sol[c]).count() as u64;

    // per-member total distance to the whole solution (self term = 0)
    let mut sums = engine.sums_to_set(ds, &sol, &sol)?;
    dist_evals += (kk * (kk - 1)) as u64;
    let mut div: f64 = sums.iter().sum::<f64>() / 2.0;
    let mut swaps = 0usize;
    let mut passes = 0usize;

    // incremental state: `cols[c * kk + j] = d(candidates[c], sol[j])`
    // (exact f64, true-zero self-pairs) + the delta-maintained candidate
    // sums; `since_anchor` counts accepted swaps since the last re-anchor
    let incremental = params.mode == LocalSearchMode::Incremental;
    let epoch = params.reanchor_epoch.max(1);
    let mut cols: Vec<f64> = Vec::new();
    let mut cand_sums: Vec<f64> = Vec::new();
    let mut since_anchor = 0usize;
    if incremental {
        cols = engine.dists_to_points(ds, candidates, &sol)?;
        dist_evals += (n * kk) as u64 - overlap;
        cand_sums = cols.chunks(kk).map(|row| row.iter().sum()).collect();
    }

    // AMT scan: accept the first improving feasible swap, then restart the
    // pass from the first candidate (the swap changed every member sum)
    'outer: loop {
        passes += 1;
        if !incremental {
            // pass-restart reference semantics: one fresh batched scan
            cand_sums = engine.sums_to_set(ds, candidates, &sol)?;
            dist_evals += (n * kk) as u64 - overlap;
        }
        let min_sums = sums.iter().copied().fold(f64::INFINITY, f64::min);
        for (ci, &v) in candidates.iter().enumerate() {
            if in_sol[v] {
                continue;
            }
            let sumv = cand_sums[ci];
            let threshold = div * (1.0 + params.gamma) + 1e-12 * div.max(1.0);
            // exact screen: even evicting the weakest member and ignoring
            // the d(v, u) correction cannot beat the threshold
            if div - min_sums + sumv <= threshold {
                continue;
            }
            for upos in 0..kk {
                let u = sol[upos];
                // div' = div - sum_d(u, sol\{u}) + sum_d(v, sol\{u})
                let new_div = div - sums[upos] + (sumv - ds.dist(v, u));
                if new_div > threshold {
                    // feasibility check only for improving candidates
                    let mut cand = sol.clone();
                    cand[upos] = v;
                    oracle_calls += 1;
                    if m.is_independent(ds, &cand) {
                        sol = cand;
                        in_sol[u] = false;
                        in_sol[v] = true;
                        if is_cand[u] {
                            overlap -= 1;
                        }
                        overlap += 1; // v is a candidate by construction
                        div = new_div;
                        swaps += 1;
                        if incremental {
                            // delta update: one incoming column; the
                            // outgoing column is read from the store
                            let col =
                                engine.dists_to_points(ds, candidates, &sol[upos..upos + 1])?;
                            dist_evals += n as u64 - 1; // v's own self-pair
                            for (c, s) in cand_sums.iter_mut().enumerate() {
                                *s += col[c] - cols[c * kk + upos];
                                cols[c * kk + upos] = col[c];
                            }
                            // member sums: one narrow two-column pass over
                            // the k - 1 staying members ...
                            let stay: Vec<usize> = sol
                                .iter()
                                .enumerate()
                                .filter_map(|(i, &w)| (i != upos).then_some(w))
                                .collect();
                            let duv = engine.dists_to_points(ds, &stay, &[u, v])?;
                            dist_evals += 2 * (kk as u64 - 1);
                            let mut slot = 0usize;
                            for (i, s) in sums.iter_mut().enumerate() {
                                if i == upos {
                                    continue;
                                }
                                *s += duv[slot * 2 + 1] - duv[slot * 2];
                                slot += 1;
                            }
                            // ... and the incoming member's sum is its own
                            // delta-maintained candidate sum
                            sums[upos] = cand_sums[ci];
                            since_anchor += 1;
                            if since_anchor >= epoch {
                                since_anchor = 0;
                                // re-anchor: the columns hold exact engine
                                // distances, so row re-summation restores
                                // the exact from-scratch candidate sums
                                // (bit-identical to a fresh sums_to_set)
                                // at zero distance evals; member sums get
                                // one fresh k x k pass
                                for (c, s) in cand_sums.iter_mut().enumerate() {
                                    *s = cols[c * kk..(c + 1) * kk].iter().sum();
                                }
                                sums = engine.sums_to_set(ds, &sol, &sol)?;
                                dist_evals += (kk * (kk - 1)) as u64;
                            }
                        } else {
                            sums = engine.sums_to_set(ds, &sol, &sol)?;
                            dist_evals += (kk * (kk - 1)) as u64;
                        }
                        if swaps >= params.max_swaps {
                            break 'outer;
                        }
                        continue 'outer;
                    }
                }
            }
        }
        // a full pass without an accepted swap: local optimum reached
        break;
    }

    if incremental {
        // one fresh k x k pass so the reported diversity matches
        // `sum_diversity_with_engine(ds, &sol, engine)` bit for bit in
        // both modes (restart's `sums` is already fresh from the last
        // accepted swap; the delta-maintained one carries epoch drift)
        sums = engine.sums_to_set(ds, &sol, &sol)?;
        dist_evals += (kk * (kk - 1)) as u64;
    }
    let diversity = sums.iter().sum::<f64>() / 2.0;
    Ok(LocalSearchResult {
        solution: sol,
        diversity,
        swaps,
        oracle_calls,
        passes,
        dist_evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::diversity::sum_diversity;
    use crate::matroid::{Matroid, PartitionMatroid, UniformMatroid};
    use crate::runtime::engine::ScalarEngine;
    use crate::runtime::BatchEngine;

    fn brute_force_best_sum(
        ds: &Dataset,
        m: &dyn Matroid,
        k: usize,
    ) -> (Vec<usize>, f64) {
        // k small, n small: enumerate all k-subsets
        let n = ds.n();
        let mut best = (Vec::new(), -1.0);
        let mut idx: Vec<usize> = (0..k).collect();
        loop {
            if m.is_independent(ds, &idx) {
                let d = sum_diversity(ds, &idx);
                if d > best.1 {
                    best = (idx.clone(), d);
                }
            }
            // next combination
            let mut i = k;
            loop {
                if i == 0 {
                    return best;
                }
                i -= 1;
                if idx[i] != i + n - k {
                    break;
                }
            }
            idx[i] += 1;
            for j in i + 1..k {
                idx[j] = idx[j - 1] + 1;
            }
        }
    }

    #[test]
    fn reaches_half_of_optimum_small_instance() {
        let ds = synth::uniform_cube(24, 2, 1);
        let m = UniformMatroid::new(4);
        let mut rng = Rng::new(1);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = local_search_sum(
            &ds, &m, 4, &cands,
            &BatchEngine::for_dataset(&ds),
            LocalSearchParams::default(), None, &mut rng,
        )
        .unwrap();
        let (_, opt) = brute_force_best_sum(&ds, &m, 4);
        assert!(res.diversity >= 0.5 * opt - 1e-9,
            "{} < half of {}", res.diversity, opt);
        assert_eq!(res.solution.len(), 4);
    }

    #[test]
    fn trajectory_engine_independent() {
        // sums_to_set / dists_to_points are bit-identical between scalar
        // and batch, and all acceptance decisions are exact f64 — so the
        // full swap trajectory (not just the endpoint) must agree across
        // engines.
        let ds = synth::uniform_cube(150, 3, 21);
        let m = UniformMatroid::new(6);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = local_search_sum(&ds, &m, 6, &cands, &ScalarEngine::new(),
            LocalSearchParams::default(), None, &mut r1).unwrap();
        let b = local_search_sum(&ds, &m, 6, &cands, &BatchEngine::for_dataset(&ds),
            LocalSearchParams::default(), None, &mut r2).unwrap();
        assert_eq!(a.solution, b.solution);
        assert_eq!(a.swaps, b.swaps);
        assert_eq!(a.oracle_calls, b.oracle_calls);
        assert_eq!(a.passes, b.passes);
        assert_eq!(a.dist_evals, b.dist_evals);
    }

    #[test]
    fn modes_agree_on_small_instance() {
        // the full cross-mode / cross-engine / cross-matroid matrix lives
        // in rust/tests/local_search_incremental.rs; this is the unit-level
        // smoke check
        let ds = synth::uniform_cube(80, 2, 14);
        let m = UniformMatroid::new(5);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let e = ScalarEngine::new();
        let inc = local_search_sum(&ds, &m, 5, &cands, &e,
            LocalSearchParams::default(), None, &mut r1).unwrap();
        let rst = local_search_sum(&ds, &m, 5, &cands, &e,
            LocalSearchParams {
                mode: LocalSearchMode::ExhaustiveRestart,
                ..Default::default()
            },
            None, &mut r2).unwrap();
        assert_eq!(inc.solution, rst.solution);
        assert_eq!(inc.swaps, rst.swaps);
        assert_eq!(inc.oracle_calls, rst.oracle_calls);
        assert_eq!(inc.passes, rst.passes);
        assert!((inc.diversity - rst.diversity).abs() <= 1e-9 * rst.diversity.max(1.0));
    }

    #[test]
    fn respects_partition_constraint() {
        let ds = synth::clustered(60, 2, 3, 0.1, 3, 2);
        let m = PartitionMatroid::new(vec![2, 2, 2]);
        let mut rng = Rng::new(2);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = local_search_sum(
            &ds, &m, 5, &cands,
            &BatchEngine::for_dataset(&ds),
            LocalSearchParams::default(), None, &mut rng,
        )
        .unwrap();
        assert!(m.is_independent(&ds, &res.solution));
        assert_eq!(res.solution.len(), 5);
    }

    #[test]
    fn gamma_trades_quality_for_speed() {
        let ds = synth::uniform_cube(120, 2, 3);
        let m = UniformMatroid::new(6);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let e = ScalarEngine::new();
        let tight = local_search_sum(&ds, &m, 6, &cands, &e,
            LocalSearchParams { gamma: 0.0, ..Default::default() }, None, &mut r1).unwrap();
        let loose = local_search_sum(&ds, &m, 6, &cands, &e,
            LocalSearchParams { gamma: 0.5, ..Default::default() }, None, &mut r2).unwrap();
        assert!(tight.diversity >= loose.diversity - 1e-9);
        assert!(loose.swaps <= tight.swaps);
    }

    #[test]
    fn warm_start_never_worse_than_init() {
        let ds = synth::uniform_cube(80, 2, 4);
        let m = UniformMatroid::new(5);
        let mut rng = Rng::new(5);
        let init: Vec<usize> = (0..5).collect();
        let init_div = sum_diversity(&ds, &init);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = local_search_sum(&ds, &m, 5, &cands, &ScalarEngine::new(),
            LocalSearchParams::default(), Some(init), &mut rng).unwrap();
        assert!(res.diversity >= init_div - 1e-9);
    }

    #[test]
    fn max_swaps_cap_enforced() {
        let ds = synth::uniform_cube(100, 2, 6);
        let m = UniformMatroid::new(5);
        let mut rng = Rng::new(6);
        let init: Vec<usize> = (0..5).collect(); // adversarially bad start
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = local_search_sum(&ds, &m, 5, &cands, &ScalarEngine::new(),
            LocalSearchParams { max_swaps: 2, ..Default::default() },
            Some(init), &mut rng).unwrap();
        // the adversarial start guarantees the cap is reached, and the
        // cap breaks mid-pass: every counted pass accepted a swap
        assert_eq!(res.swaps, 2);
        assert_eq!(res.passes, res.swaps);
    }

    #[test]
    fn incremental_div_matches_exact() {
        let ds = synth::uniform_cube(60, 3, 7);
        let m = UniformMatroid::new(4);
        let mut rng = Rng::new(7);
        let cands: Vec<usize> = (0..ds.n()).collect();
        // the delta-maintained scan must keep the incremental `div`
        // consistent with the exact recomputation at the end
        let res = local_search_sum(
            &ds, &m, 4, &cands,
            &BatchEngine::for_dataset(&ds),
            LocalSearchParams::default(), None, &mut rng,
        )
        .unwrap();
        assert!((res.diversity - sum_diversity(&ds, &res.solution)).abs() < 1e-9);
    }

    #[test]
    fn passes_counts_scan_restarts() {
        let ds = synth::uniform_cube(90, 2, 8);
        let m = UniformMatroid::new(4);
        let cands: Vec<usize> = (0..ds.n()).collect();
        for mode in [LocalSearchMode::Incremental, LocalSearchMode::ExhaustiveRestart] {
            let mut rng = Rng::new(8);
            let res = local_search_sum(&ds, &m, 4, &cands, &ScalarEngine::new(),
                LocalSearchParams { mode, ..Default::default() }, None, &mut rng).unwrap();
            // normal termination: each accepted swap restarts the pass,
            // plus the final pass that proves local optimality
            assert_eq!(res.passes, res.swaps + 1, "{mode:?}");
        }
    }
}
