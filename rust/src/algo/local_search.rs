//! AMT local search for sum-DMMC — the (1/2 - gamma)-approximation of
//! Abbassi, Mirrokni & Thakur [1], the paper's sequential baseline and its
//! final-solution extractor on coresets (with gamma = 0, footnote 5).
//!
//! Start from an independent set of size k, then repeatedly apply a
//! feasible swap (u out, v in) that improves the sum-diversity by a factor
//! of at least `1 + gamma`; after an accepted swap the pass restarts from
//! the first candidate (the AMT scan); stop when a full pass finds no such
//! swap.  The O(n k) per-pass distance work — every candidate's distance
//! sum to the current solution — goes through one batched
//! [`DistanceEngine::sums_to_set`] call per pass, so the default batch
//! backend both blocks and multi-threads it; only improving candidates pay
//! the k exact per-member distances and one independence-oracle call.

use anyhow::Result;

use crate::algo::greedy::greedy_matroid_gonzalez;
use crate::core::Dataset;
use crate::matroid::Matroid;
use crate::runtime::engine::DistanceEngine;
use crate::util::rng::Rng;

/// Outcome of a local-search run.
#[derive(Clone, Debug)]
pub struct LocalSearchResult {
    /// The solution (independent, size <= k; == k unless rank < k).
    pub solution: Vec<usize>,
    /// Its sum-diversity.
    pub diversity: f64,
    /// Number of accepted swaps.
    pub swaps: usize,
    /// Number of independence-oracle calls made.
    pub oracle_calls: u64,
}

/// Configuration for [`local_search_sum`].
#[derive(Clone, Copy, Debug)]
pub struct LocalSearchParams {
    /// Improvement factor: accept a swap only if it improves the objective
    /// by a factor > (1 + gamma). gamma = 0 -> any strict improvement.
    pub gamma: f64,
    /// Safety cap on accepted swaps (the gamma = 0 regime has no polynomial
    /// bound; the cap is far above anything observed in practice).
    pub max_swaps: usize,
}

impl Default for LocalSearchParams {
    fn default() -> Self {
        LocalSearchParams {
            gamma: 0.0,
            max_swaps: 10_000,
        }
    }
}

/// Run AMT local search over `candidates` (e.g. a coreset or the full
/// dataset).  `init`: optional warm start (must be independent).
///
/// All O(n k) per-pass distance work is batched through `engine`
/// ([`DistanceEngine::sums_to_set`]); acceptance decisions stay in exact
/// f64 with the oracle formulas, so the trajectory is engine-independent
/// across `scalar` and `batch`.
pub fn local_search_sum(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    candidates: &[usize],
    engine: &dyn DistanceEngine,
    params: LocalSearchParams,
    init: Option<Vec<usize>>,
    rng: &mut Rng,
) -> Result<LocalSearchResult> {
    let mut oracle_calls: u64 = 0;
    let mut sol = match init {
        Some(s) => s,
        None => greedy_matroid_gonzalez(ds, m, k, candidates, rng),
    };
    debug_assert!(m.is_independent(ds, &sol));
    if sol.len() < 2 {
        // fewer than two members -> no pairs -> zero sum-diversity
        return Ok(LocalSearchResult {
            solution: sol,
            diversity: 0.0,
            swaps: 0,
            oracle_calls,
        });
    }

    // per-member total distance to the whole solution (self term = 0)
    let mut sums = engine.sums_to_set(ds, &sol, &sol)?;
    let mut div: f64 = sums.iter().sum::<f64>() / 2.0;
    let mut swaps = 0;

    // AMT scan: accept the first improving feasible swap, then restart the
    // pass from the first candidate — the swap changed every member sum,
    // so each pass recomputes the candidate sums in one batched call.
    'outer: loop {
        let cand_sums = engine.sums_to_set(ds, candidates, &sol)?;
        let min_sums = sums.iter().copied().fold(f64::INFINITY, f64::min);
        for (ci, &v) in candidates.iter().enumerate() {
            if sol.contains(&v) {
                continue;
            }
            let sumv = cand_sums[ci];
            let threshold = div * (1.0 + params.gamma) + 1e-12 * div.max(1.0);
            // exact screen: even evicting the weakest member and ignoring
            // the d(v, u) correction cannot beat the threshold
            if div - min_sums + sumv <= threshold {
                continue;
            }
            for upos in 0..sol.len() {
                let u = sol[upos];
                // div' = div - sum_d(u, sol\{u}) + sum_d(v, sol\{u})
                let new_div = div - sums[upos] + (sumv - ds.dist(v, u));
                if new_div > threshold {
                    // feasibility check only for improving candidates
                    let mut cand = sol.clone();
                    cand[upos] = v;
                    oracle_calls += 1;
                    if m.is_independent(ds, &cand) {
                        sol = cand;
                        sums = engine.sums_to_set(ds, &sol, &sol)?;
                        div = new_div;
                        swaps += 1;
                        if swaps >= params.max_swaps {
                            break 'outer;
                        }
                        continue 'outer;
                    }
                }
            }
        }
        // a full pass without an accepted swap: local optimum reached
        break;
    }

    // `sums` is re-derived from a fresh engine pass after every accepted
    // swap, so summing it washes out the incremental `div` drift exactly
    // like a from-scratch recompute — and matches
    // `sum_diversity_with_engine(ds, &sol, engine)` bit for bit with zero
    // extra distance work
    let diversity = sums.iter().sum::<f64>() / 2.0;
    Ok(LocalSearchResult {
        solution: sol,
        diversity,
        swaps,
        oracle_calls,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::diversity::sum_diversity;
    use crate::matroid::{Matroid, PartitionMatroid, UniformMatroid};
    use crate::runtime::engine::ScalarEngine;
    use crate::runtime::BatchEngine;

    fn brute_force_best_sum(
        ds: &Dataset,
        m: &dyn Matroid,
        k: usize,
    ) -> (Vec<usize>, f64) {
        // k small, n small: enumerate all k-subsets
        let n = ds.n();
        let mut best = (Vec::new(), -1.0);
        let mut idx: Vec<usize> = (0..k).collect();
        loop {
            if m.is_independent(ds, &idx) {
                let d = sum_diversity(ds, &idx);
                if d > best.1 {
                    best = (idx.clone(), d);
                }
            }
            // next combination
            let mut i = k;
            loop {
                if i == 0 {
                    return best;
                }
                i -= 1;
                if idx[i] != i + n - k {
                    break;
                }
            }
            idx[i] += 1;
            for j in i + 1..k {
                idx[j] = idx[j - 1] + 1;
            }
        }
    }

    #[test]
    fn reaches_half_of_optimum_small_instance() {
        let ds = synth::uniform_cube(24, 2, 1);
        let m = UniformMatroid::new(4);
        let mut rng = Rng::new(1);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = local_search_sum(
            &ds, &m, 4, &cands,
            &BatchEngine::for_dataset(&ds),
            LocalSearchParams::default(), None, &mut rng,
        )
        .unwrap();
        let (_, opt) = brute_force_best_sum(&ds, &m, 4);
        assert!(res.diversity >= 0.5 * opt - 1e-9,
            "{} < half of {}", res.diversity, opt);
        assert_eq!(res.solution.len(), 4);
    }

    #[test]
    fn trajectory_engine_independent() {
        // sums_to_set is bit-identical between scalar and batch, and all
        // acceptance decisions are exact f64 — so the full swap trajectory
        // (not just the endpoint) must agree across engines.
        let ds = synth::uniform_cube(150, 3, 21);
        let m = UniformMatroid::new(6);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = local_search_sum(&ds, &m, 6, &cands, &ScalarEngine::new(),
            LocalSearchParams::default(), None, &mut r1).unwrap();
        let b = local_search_sum(&ds, &m, 6, &cands, &BatchEngine::for_dataset(&ds),
            LocalSearchParams::default(), None, &mut r2).unwrap();
        assert_eq!(a.solution, b.solution);
        assert_eq!(a.swaps, b.swaps);
        assert_eq!(a.oracle_calls, b.oracle_calls);
    }

    #[test]
    fn respects_partition_constraint() {
        let ds = synth::clustered(60, 2, 3, 0.1, 3, 2);
        let m = PartitionMatroid::new(vec![2, 2, 2]);
        let mut rng = Rng::new(2);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = local_search_sum(
            &ds, &m, 5, &cands,
            &BatchEngine::for_dataset(&ds),
            LocalSearchParams::default(), None, &mut rng,
        )
        .unwrap();
        assert!(m.is_independent(&ds, &res.solution));
        assert_eq!(res.solution.len(), 5);
    }

    #[test]
    fn gamma_trades_quality_for_speed() {
        let ds = synth::uniform_cube(120, 2, 3);
        let m = UniformMatroid::new(6);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let e = ScalarEngine::new();
        let tight = local_search_sum(&ds, &m, 6, &cands, &e,
            LocalSearchParams { gamma: 0.0, max_swaps: 10_000 }, None, &mut r1).unwrap();
        let loose = local_search_sum(&ds, &m, 6, &cands, &e,
            LocalSearchParams { gamma: 0.5, max_swaps: 10_000 }, None, &mut r2).unwrap();
        assert!(tight.diversity >= loose.diversity - 1e-9);
        assert!(loose.swaps <= tight.swaps);
    }

    #[test]
    fn warm_start_never_worse_than_init() {
        let ds = synth::uniform_cube(80, 2, 4);
        let m = UniformMatroid::new(5);
        let mut rng = Rng::new(5);
        let init: Vec<usize> = (0..5).collect();
        let init_div = sum_diversity(&ds, &init);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = local_search_sum(&ds, &m, 5, &cands, &ScalarEngine::new(),
            LocalSearchParams::default(), Some(init), &mut rng).unwrap();
        assert!(res.diversity >= init_div - 1e-9);
    }

    #[test]
    fn max_swaps_cap_enforced() {
        let ds = synth::uniform_cube(100, 2, 6);
        let m = UniformMatroid::new(5);
        let mut rng = Rng::new(6);
        let init: Vec<usize> = (0..5).collect(); // adversarially bad start
        let cands: Vec<usize> = (0..ds.n()).collect();
        let res = local_search_sum(&ds, &m, 5, &cands, &ScalarEngine::new(),
            LocalSearchParams { gamma: 0.0, max_swaps: 2 }, Some(init), &mut rng).unwrap();
        assert!(res.swaps <= 2);
    }

    #[test]
    fn incremental_div_matches_exact() {
        let ds = synth::uniform_cube(60, 3, 7);
        let m = UniformMatroid::new(4);
        let mut rng = Rng::new(7);
        let cands: Vec<usize> = (0..ds.n()).collect();
        // the restart-after-swap scan must keep the incremental `div`
        // consistent with the exact recomputation at the end
        let res = local_search_sum(
            &ds, &m, 4, &cands,
            &BatchEngine::for_dataset(&ds),
            LocalSearchParams::default(), None, &mut rng,
        )
        .unwrap();
        assert!((res.diversity - sum_diversity(&ds, &res.solution)).abs() < 1e-9);
    }
}
