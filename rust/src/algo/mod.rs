//! The paper's algorithms: GMM clustering, the coreset constructions
//! (sequential + streaming; the MapReduce version lives in
//! [`crate::mapreduce`]), the AMT local-search baseline/finisher, the
//! exhaustive finisher for the non-sum DMMC variants, and the
//! matching-vs-GMM race finisher for remote-clique/remote-edge.

pub mod exhaustive;
pub mod extract;
pub mod gmm;
pub mod greedy;
pub mod local_search;
pub mod matching;
pub mod seq_coreset;
pub mod stream_coreset;

use crate::util::timer::PhaseTimer;

/// Size budget for a coreset construction.
#[derive(Clone, Copy, Debug)]
pub enum Budget {
    /// Theory mode (Algorithm 1): run GMM until the radius drops below
    /// `eps * delta / (16 k)`.
    Epsilon(f64),
    /// Experiment mode (§5): fix the number of clusters `tau` directly.
    Clusters(usize),
}

/// A coreset: indices into the originating dataset plus provenance stats.
#[derive(Clone, Debug)]
pub struct Coreset {
    /// Coreset member indices (into the dataset it was built from).
    pub indices: Vec<usize>,
    /// Number of clusters the construction used (tau).
    pub n_clusters: usize,
    /// Radius of the underlying clustering.
    pub radius: f64,
    /// Phase breakdown ("cluster", "extract", ...).
    pub timer: PhaseTimer,
}

impl Coreset {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Merge composable coresets (MapReduce union, paper §4.2).
    pub fn union(parts: Vec<Coreset>) -> Coreset {
        let mut indices = Vec::new();
        let mut n_clusters = 0;
        let mut radius = 0.0f64;
        let mut timer = PhaseTimer::new();
        for p in parts {
            indices.extend(p.indices);
            n_clusters += p.n_clusters;
            radius = radius.max(p.radius);
            timer.merge(&p.timer);
        }
        indices.sort_unstable();
        indices.dedup();
        Coreset {
            indices,
            n_clusters,
            radius,
            timer,
        }
    }
}
