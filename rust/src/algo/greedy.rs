//! Matroid-constrained greedy constructions: farthest-point (Gonzalez-
//! flavoured) initial solutions for the local search, and a plain greedy
//! sum-diversity baseline used by the benches.

use crate::core::Dataset;
use crate::matroid::Matroid;
use crate::util::rng::Rng;

/// Build an independent set of size (up to) `k` by greedy farthest-point
/// selection subject to the matroid: start from a seed, then repeatedly add
/// the feasible candidate maximizing the minimum distance to the chosen
/// set.  This is the standard strong initializer for the AMT local search.
pub fn greedy_matroid_gonzalez(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    candidates: &[usize],
    rng: &mut Rng,
) -> Vec<usize> {
    if candidates.is_empty() || k == 0 {
        return Vec::new();
    }
    let mut sol: Vec<usize> = Vec::with_capacity(k);
    // seed: random feasible singleton
    let mut order: Vec<usize> = candidates.to_vec();
    rng.shuffle(&mut order);
    for &x in &order {
        if m.can_extend(ds, &sol, x) {
            sol.push(x);
            break;
        }
    }
    if sol.is_empty() {
        return sol;
    }
    // min-dist to the current solution, maintained incrementally
    let mut mind: Vec<f64> = candidates
        .iter()
        .map(|&x| ds.dist(x, sol[0]))
        .collect();
    while sol.len() < k {
        // candidates sorted by min-dist descending; pick the farthest feasible
        let mut best: Option<(usize, f64)> = None;
        for (ci, &x) in candidates.iter().enumerate() {
            if sol.contains(&x) {
                continue;
            }
            let d = mind[ci];
            if best.map(|(_, bd)| d > bd).unwrap_or(true) && m.can_extend(ds, &sol, x) {
                best = Some((ci, d));
            }
        }
        match best {
            None => break,
            Some((ci, _)) => {
                let x = candidates[ci];
                sol.push(x);
                for (cj, &y) in candidates.iter().enumerate() {
                    let d = ds.dist(y, x);
                    if d < mind[cj] {
                        mind[cj] = d;
                    }
                }
            }
        }
    }
    sol
}

/// Plain greedy for sum-diversity under a matroid: repeatedly add the
/// feasible candidate with the largest total distance to the current set.
/// A cheap baseline the benches compare against.
pub fn greedy_sum(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    candidates: &[usize],
) -> Vec<usize> {
    let mut sol: Vec<usize> = Vec::with_capacity(k);
    // total distance to current solution, per candidate
    let mut tot: Vec<f64> = vec![0.0; candidates.len()];
    while sol.len() < k {
        let mut best: Option<(usize, f64)> = None;
        for (ci, &x) in candidates.iter().enumerate() {
            if sol.contains(&x) {
                continue;
            }
            let score = if sol.is_empty() { 1.0 } else { tot[ci] };
            if best.map(|(_, bs)| score > bs).unwrap_or(true) && m.can_extend(ds, &sol, x) {
                best = Some((ci, score));
            }
        }
        match best {
            None => break,
            Some((ci, _)) => {
                let x = candidates[ci];
                sol.push(x);
                for (cj, &y) in candidates.iter().enumerate() {
                    tot[cj] += ds.dist(y, x);
                }
            }
        }
    }
    sol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::diversity::sum_diversity;
    use crate::matroid::{Matroid, PartitionMatroid, UniformMatroid};

    #[test]
    fn gonzalez_init_is_independent_and_sized() {
        let ds = synth::clustered(200, 2, 5, 0.1, 3, 1);
        let m = PartitionMatroid::new(vec![2, 2, 2]);
        let mut rng = Rng::new(1);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let sol = greedy_matroid_gonzalez(&ds, &m, 5, &cands, &mut rng);
        assert_eq!(sol.len(), 5);
        assert!(m.is_independent(&ds, &sol));
    }

    #[test]
    fn gonzalez_respects_rank_limit() {
        let ds = synth::clustered(50, 2, 4, 0.1, 2, 2);
        let m = PartitionMatroid::new(vec![1, 1]); // rank 2
        let mut rng = Rng::new(2);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let sol = greedy_matroid_gonzalez(&ds, &m, 5, &cands, &mut rng);
        assert_eq!(sol.len(), 2);
    }

    #[test]
    fn greedy_sum_beats_random_on_average() {
        let ds = synth::uniform_cube(150, 2, 3);
        let m = UniformMatroid::new(5);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let sol = greedy_sum(&ds, &m, 5, &cands);
        assert_eq!(sol.len(), 5);
        let greedy_div = sum_diversity(&ds, &sol);
        let mut rng = Rng::new(4);
        let mut rand_div = 0.0;
        for _ in 0..20 {
            let rand_sol = rng.sample_indices(ds.n(), 5);
            rand_div += sum_diversity(&ds, &rand_sol);
        }
        rand_div /= 20.0;
        assert!(greedy_div > rand_div, "{greedy_div} <= {rand_div}");
    }

    #[test]
    fn spread_seeking_picks_far_points() {
        // two far blobs, k=2: greedy gonzalez must take one from each
        let ds = synth::clustered(100, 2, 2, 0.05, 1, 5);
        let m = UniformMatroid::new(2);
        let mut rng = Rng::new(6);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let sol = greedy_matroid_gonzalez(&ds, &m, 2, &cands, &mut rng);
        let d = ds.dist(sol[0], sol[1]);
        assert!(d > ds.diameter_exact() * 0.5);
    }
}
