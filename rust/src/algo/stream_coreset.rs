//! StreamCoreset — Algorithm 2 of the paper, plus the tau-controlled
//! variant used in the experiments (§5.2).
//!
//! One pass, working memory proportional to the coreset size.  The
//! algorithm maintains a set of centers `Z`, a per-center delegate set
//! `D_z` (updated by the matroid-specific HANDLE procedure), and a running
//! diameter estimate `R`:
//!
//! * a point farther than `2 eps R / (c k)` from every center becomes a new
//!   center (c = 32, Lemma 3);
//! * otherwise HANDLE folds it into its nearest center's delegates;
//! * whenever `d(x_i, x_1) > 2R` the estimate is raised and `Z` is
//!   *restructured* to a maximal subset with pairwise distances
//!   `> eps R / (c k)`, re-HANDLE-ing the delegates of dropped centers.
//!
//! The tau-variant replaces the diameter estimate with a radius estimate
//! that doubles whenever the number of centers exceeds `tau` (a la
//! Charikar et al. [14]), which is how the paper controls coreset size
//! directly in its experiments.

use anyhow::Result;

use crate::algo::Coreset;
use crate::core::Dataset;
use crate::matroid::{maximal_independent, Matroid, MatroidKind};
use crate::runtime::engine::{DistanceEngine, ScalarEngine};
use crate::runtime::{build_engine, EngineKind};
use crate::util::timer::PhaseTimer;

/// Lemma 3 constant.
pub const DEFAULT_C: f64 = 32.0;

/// Memory/behaviour accounting for the streaming pass.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    /// Max simultaneously-stored points (centers' delegates), the paper's
    /// "working memory" measure.
    pub peak_memory_points: usize,
    /// Number of restructure events.
    pub restructures: usize,
    /// Points consumed.
    pub points_processed: usize,
    /// Total distance evaluations (the streaming cost model of §5.2).
    pub distance_evals: u64,
}

/// Stopping/threshold policy: the faithful Algorithm 2 or the tau-variant.
#[derive(Clone, Copy, Debug)]
enum Mode {
    /// Algorithm 2: `R` estimates the diameter; threshold `2 eps R / (c k)`.
    Diameter { eps: f64, c: f64 },
    /// §5.2 variant: `R` estimates the clustering radius; threshold `2 R`;
    /// doubling restructure when `|Z| > tau`.
    Radius { tau: usize },
}

/// Single-pass streaming coreset builder.  Feed points with [`Self::push`],
/// then [`Self::finish`].
pub struct StreamCoreset<'a> {
    ds: &'a Dataset,
    m: &'a dyn Matroid,
    k: usize,
    mode: Mode,
    r: f64,
    first: usize,
    centers: Vec<usize>,
    delegates: Vec<Vec<usize>>,
    seen: usize,
    stats: StreamStats,
    /// Engine for the restructure re-assignment tile (the only
    /// super-constant distance block in the point-at-a-time algorithm).
    /// Scalar by default, not batch: the tile is bounded by the center
    /// count (far below any fan-out threshold), and a per-dataset engine
    /// would add the O(n) precompute and memory the streaming model exists
    /// to avoid.  [`Self::set_engine_kind`] lets the pipeline thread its
    /// registry-selected backend through anyway (the A/B axis of
    /// `run_stream_with_engine`).  The per-point `push` scan stays
    /// point-at-a-time — that is the streaming cost model §5.2 measures —
    /// while [`Self::push_batch`] is the mini-batch arrival mode that
    /// amortizes the scan through one `update_min_block` fold per batch.
    engine: Box<dyn DistanceEngine>,
    /// Registry kind behind `engine` — [`Self::push_batch`] builds a
    /// fresh engine of this kind per batch view (engines carry
    /// per-dataset state, so the dataset-level instance cannot serve a
    /// view).
    engine_kind: EngineKind,
}

impl<'a> StreamCoreset<'a> {
    /// Faithful Algorithm 2 with constants `eps` and `c` (use
    /// [`DEFAULT_C`] for the Lemma 3 guarantee).
    pub fn new(ds: &'a Dataset, m: &'a dyn Matroid, k: usize, eps: f64, c: f64) -> Self {
        Self::with_mode(ds, m, k, Mode::Diameter { eps, c })
    }

    /// Experiments variant (§5.2): target `tau` clusters directly.
    pub fn with_tau(ds: &'a Dataset, m: &'a dyn Matroid, k: usize, tau: usize) -> Self {
        assert!(tau >= 2, "tau-variant needs tau >= 2");
        Self::with_mode(ds, m, k, Mode::Radius { tau })
    }

    fn with_mode(ds: &'a Dataset, m: &'a dyn Matroid, k: usize, mode: Mode) -> Self {
        StreamCoreset {
            ds,
            m,
            k,
            mode,
            r: 0.0,
            first: usize::MAX,
            centers: Vec::new(),
            delegates: Vec::new(),
            seen: 0,
            stats: StreamStats::default(),
            engine: Box::new(ScalarEngine::new()),
            engine_kind: EngineKind::Scalar,
        }
    }

    /// Select the registry backend for the batched passes (the restructure
    /// re-assignment tile and the [`Self::push_batch`] nearest-center
    /// fold; see the field docs for why the default is scalar).  Distance
    /// accounting is unchanged in kind — the §5.2 eval ledger counts tile
    /// entries, not backend calls.  Fails only for backends with external
    /// dependencies (PJRT artifacts).
    pub fn set_engine_kind(&mut self, kind: EngineKind) -> Result<()> {
        self.engine = build_engine(kind, self.ds)?;
        self.engine_kind = kind;
        Ok(())
    }

    #[inline]
    fn dist(&mut self, a: usize, b: usize) -> f64 {
        self.stats.distance_evals += 1;
        self.ds.dist(a, b)
    }

    /// Distance threshold below which a point joins an existing cluster.
    fn join_threshold(&self) -> f64 {
        match self.mode {
            Mode::Diameter { eps, c } => 2.0 * eps * self.r / (c * self.k as f64),
            Mode::Radius { .. } => 2.0 * self.r,
        }
    }

    /// Pairwise separation enforced between centers on restructure.
    /// Radius mode keeps centers > R apart (not 2R): merging at 2R
    /// overshoots after a doubling and collapses |Z| far below tau,
    /// wasting the coreset budget the experiments sweep.
    fn separation_threshold(&self) -> f64 {
        match self.mode {
            Mode::Diameter { eps, c } => eps * self.r / (c * self.k as f64),
            Mode::Radius { .. } => self.r,
        }
    }

    /// Process the next stream element (a dataset index).
    pub fn push(&mut self, x: usize) {
        self.seen += 1;
        self.stats.points_processed += 1;
        if self.first == usize::MAX {
            self.first = x;
            self.centers.push(x);
            self.delegates.push(vec![x]);
            self.track_memory();
            return;
        }
        if self.centers.len() == 1 && self.seen == 2 {
            let d = self.dist(self.first, x);
            self.r = match self.mode {
                Mode::Diameter { .. } => d,
                // radius estimate seeds far below the data scale, so early
                // points all become centers and the doubling restructure
                // (Charikar et al. [14]) finds the right scale itself
                Mode::Radius { .. } => (d / 1048576.0).max(f64::MIN_POSITIVE),
            };
            self.centers.push(x);
            self.delegates.push(vec![x]);
            self.track_memory();
            return;
        }

        // nearest center
        let mut zpos = 0;
        let mut zdist = f64::INFINITY;
        for pos in 0..self.centers.len() {
            let d = self.dist(x, self.centers[pos]);
            if d < zdist {
                zdist = d;
                zpos = pos;
            }
        }

        if zdist > self.join_threshold() {
            self.centers.push(x);
            self.delegates.push(vec![x]);
        } else {
            self.handle(x, zpos);
        }

        match self.mode {
            Mode::Diameter { .. } => {
                let d1 = self.dist(x, self.first);
                if d1 > 2.0 * self.r {
                    self.r = d1;
                    self.restructure();
                }
            }
            Mode::Radius { tau } => {
                while self.centers.len() > tau {
                    self.r = if self.r > 0.0 { self.r * 2.0 } else { 1e-30 };
                    self.restructure();
                }
            }
        }
        self.track_memory();
    }

    /// Mini-batch arrival mode (the amortized counterpart of [`Self::push`],
    /// closing the ROADMAP open item): process `xs` in stream order, but
    /// route the nearest-center scan through one engine
    /// [`DistanceEngine::update_min_block`] fold per batch instead of a
    /// point-at-a-time scan per arrival.  The fold runs over a zero-copy
    /// view of `[current centers ++ batch]`, so each batch pays one
    /// traversal of `|Z|` centers x (|Z| + batch) points; centers born
    /// mid-batch are folded in exactly with point-at-a-time f64 scans
    /// (they are rare), and the batch is re-anchored after every
    /// restructure so stale fold state is never consulted.  A re-anchor
    /// discards the unconsumed remainder of one fold, but both modes grow
    /// `R` geometrically (Diameter sets `r = d1 > 2r`, Radius doubles),
    /// so a stream triggers at most O(log(spread)) restructures total —
    /// the discarded work is bounded, not per-point.
    ///
    /// Semantics match [`Self::push`] except for one documented f32 edge:
    /// the engine fold keeps the earliest center among f32-equal
    /// distances, and the join/threshold decision then re-reads the
    /// winner's distance in exact f64 (one extra eval per point).  When
    /// two centers' f64 distances differ but collide in f32 — an
    /// ulp-level tie — the batch mode may therefore delegate to the
    /// earlier of the two where the sequential scan picks the true f64
    /// argmin.  The eval ledger counts the fold tile plus the exact
    /// re-reads.
    pub fn push_batch(&mut self, xs: &[usize]) {
        let mut rest = xs;
        while !rest.is_empty() {
            // stream bootstrap (first point / R seeding) stays sequential
            if self.seen < 2 {
                self.push(rest[0]);
                rest = &rest[1..];
                continue;
            }
            let consumed = self.push_batch_chunk(rest);
            rest = &rest[consumed..];
        }
    }

    /// One batched pass over a prefix of `xs`; returns how many points
    /// were consumed.  Stops early (returning the consumed count) after a
    /// restructure, because the precomputed fold refers to center
    /// positions that no longer exist — the caller re-anchors.
    fn push_batch_chunk(&mut self, xs: &[usize]) -> usize {
        let c0 = self.centers.len();
        debug_assert!(c0 >= 1);
        // zero-copy view [centers ++ batch]: rows 0..c0 are the current
        // centers (so they double as fold centers by view row), rows
        // c0.. are the batch points whose nearest-center state we want
        let mut view_ids: Vec<usize> = Vec::with_capacity(c0 + xs.len());
        view_ids.extend_from_slice(&self.centers);
        view_ids.extend_from_slice(xs);
        let view = self.ds.subset(&view_ids);
        // per-dataset engine state means the dataset-level instance can't
        // serve the view; CPU kinds build in O(view) or less (Euclidean
        // backends skip the norm precompute entirely)
        let engine = build_engine(self.engine_kind, &view)
            .expect("batch-view engine construction (kind already built for the dataset)");
        let vn = view.n();
        let mut mind = vec![f32::INFINITY; vn];
        let mut arg = vec![u32::MAX; vn];
        let centers_pairs: Vec<(usize, u32)> = (0..c0).map(|pos| (pos, pos as u32)).collect();
        engine
            .update_min_block(&view, &centers_pairs, &mut mind, &mut arg)
            .expect("nearest-center fold");
        // ledger: the fold touches every view point once per center
        self.stats.distance_evals += (c0 * vn) as u64;

        // center positions appended after the fold (mid-batch births)
        let mut fresh: Vec<usize> = Vec::new();
        for (j, &x) in xs.iter().enumerate() {
            self.seen += 1;
            self.stats.points_processed += 1;

            // nearest among the start centers from the fold, re-read in
            // exact f64 (the fold is f32), then refined by the mid-batch
            // centers the fold has not seen
            let mut zpos = arg[c0 + j] as usize;
            let mut zdist = self.dist(x, self.centers[zpos]);
            for &p in &fresh {
                let d = self.dist(x, self.centers[p]);
                if d < zdist {
                    zdist = d;
                    zpos = p;
                }
            }

            if zdist > self.join_threshold() {
                fresh.push(self.centers.len());
                self.centers.push(x);
                self.delegates.push(vec![x]);
            } else {
                self.handle(x, zpos);
            }

            let mut restructured = false;
            match self.mode {
                Mode::Diameter { .. } => {
                    let d1 = self.dist(x, self.first);
                    if d1 > 2.0 * self.r {
                        self.r = d1;
                        self.restructure();
                        restructured = true;
                    }
                }
                Mode::Radius { tau } => {
                    while self.centers.len() > tau {
                        self.r = if self.r > 0.0 { self.r * 2.0 } else { 1e-30 };
                        self.restructure();
                        restructured = true;
                    }
                }
            }
            self.track_memory();
            if restructured {
                return j + 1;
            }
        }
        xs.len()
    }

    /// Shrink `Z` to a maximal subset with pairwise distance greater than
    /// the separation threshold; re-HANDLE delegates of dropped centers
    /// into their nearest surviving center.
    fn restructure(&mut self) {
        self.stats.restructures += 1;
        let thr = self.separation_threshold();
        let old_centers = std::mem::take(&mut self.centers);
        let old_delegates = std::mem::take(&mut self.delegates);
        let mut kept: Vec<usize> = Vec::new(); // positions into old_centers
        'outer: for (pos, &z) in old_centers.iter().enumerate() {
            for &kpos in &kept {
                if self.ds.dist(z, old_centers[kpos]) <= thr {
                    self.stats.distance_evals += 1;
                    continue 'outer;
                }
                self.stats.distance_evals += 1;
            }
            kept.push(pos);
        }
        self.centers = kept.iter().map(|&p| old_centers[p]).collect();
        self.delegates = kept.iter().map(|_| Vec::new()).collect();
        // restore ALL surviving centers' delegates first: a dropped center
        // merged before a survivor would otherwise have its re-handled
        // points clobbered by the survivor's restore
        let kept_set: std::collections::BTreeMap<usize, usize> =
            kept.iter().enumerate().map(|(new, &old)| (old, new)).collect();
        let mut dropped: Vec<(usize, Vec<usize>)> = Vec::new();
        for (pos, dz) in old_delegates.into_iter().enumerate() {
            if let Some(&new_pos) = kept_set.get(&pos) {
                self.delegates[new_pos] = dz;
            } else {
                dropped.push((pos, dz));
            }
        }
        if dropped.is_empty() {
            return;
        }
        // re-assignment: each dropped center's delegates move to the kept
        // center nearest the *dropped* center — one engine tile of
        // |dropped| x |kept| distances instead of a scalar scan per drop
        // (same eval count as the scan, so the §5.2 cost model is unchanged)
        let dropped_centers: Vec<usize> =
            dropped.iter().map(|&(pos, _)| old_centers[pos]).collect();
        let width = self.centers.len();
        let tile = self
            .engine
            .pairwise_block(self.ds, &dropped_centers, &self.centers)
            .expect("pairwise tile");
        self.stats.distance_evals += (dropped_centers.len() * width) as u64;
        for (row, (_, dz)) in dropped.into_iter().enumerate() {
            let row_tile = &tile[row * width..(row + 1) * width];
            let mut best = 0;
            for npos in 1..width {
                if row_tile[npos] < row_tile[best] {
                    best = npos;
                }
            }
            // the tile is f32; only when other centers land within f32
            // rounding of the winner re-decide the tie in exact f64 so the
            // choice matches the old all-f64 scan (rare: costs 0 extra
            // evals on the common unique-winner path)
            let band = 1e-6f32 * (row_tile[best] + 1.0);
            let near: Vec<usize> = (0..width)
                .filter(|&npos| row_tile[npos] <= row_tile[best] + band)
                .collect();
            if near.len() > 1 {
                let z_old = dropped_centers[row];
                let mut exact_d = f64::INFINITY;
                for npos in near {
                    self.stats.distance_evals += 1;
                    let d = self.ds.dist(z_old, self.centers[npos]);
                    if d < exact_d {
                        exact_d = d;
                        best = npos;
                    }
                }
            }
            for x in dz {
                self.handle(x, best);
            }
        }
    }

    /// HANDLE(x, z, D_z) — Algorithm 2's delegate update, by matroid kind.
    fn handle(&mut self, x: usize, zpos: usize) {
        let k = self.k;
        // full independent delegate set -> discard
        if self.delegates[zpos].len() == k
            && self.m.is_independent(self.ds, &self.delegates[zpos])
        {
            return;
        }
        match self.m.kind() {
            MatroidKind::Partition => {
                // D_z stays independent by construction
                if self.delegates[zpos].len() < k
                    && self.m.can_extend(self.ds, &self.delegates[zpos], x)
                {
                    self.delegates[zpos].push(x);
                }
            }
            MatroidKind::Transversal => {
                let need = self.ds.categories[x].iter().any(|&a| {
                    let have = self.delegates[zpos]
                        .iter()
                        .filter(|&&y| self.ds.categories[y].contains(&a))
                        .count();
                    have < k
                });
                if need {
                    self.delegates[zpos].push(x);
                    self.shrink_if_full(zpos);
                }
            }
            MatroidKind::General => {
                self.delegates[zpos].push(x);
                self.shrink_if_full(zpos);
            }
        }
    }

    /// If `D_z` now contains an independent set of size k, keep only it.
    fn shrink_if_full(&mut self, zpos: usize) {
        let dz = &self.delegates[zpos];
        let dprime = maximal_independent(self.m, self.ds, dz, self.k);
        if dprime.len() == self.k {
            self.delegates[zpos] = dprime;
        }
    }

    fn track_memory(&mut self) {
        let used: usize = self.delegates.iter().map(|d| d.len()).sum();
        if used > self.stats.peak_memory_points {
            self.stats.peak_memory_points = used;
        }
    }

    /// Current number of centers (|Z|).
    pub fn n_centers(&self) -> usize {
        self.centers.len()
    }

    /// Current estimate R.
    pub fn r_estimate(&self) -> f64 {
        self.r
    }

    pub fn stats(&self) -> &StreamStats {
        &self.stats
    }

    pub fn centers(&self) -> &[usize] {
        &self.centers
    }

    /// End of stream: union of delegate sets.
    pub fn finish(self) -> (Coreset, StreamStats) {
        let radius_bound = self.join_threshold();
        let mut indices: Vec<usize> = self.delegates.into_iter().flatten().collect();
        indices.sort_unstable();
        indices.dedup();
        let coreset = Coreset {
            indices,
            n_clusters: self.centers.len(),
            radius: radius_bound,
            timer: PhaseTimer::new(),
        };
        (coreset, self.stats)
    }
}

/// Convenience wrapper: run the faithful Algorithm 2 over `order`.
pub fn stream_coreset(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    eps: f64,
    order: &[usize],
) -> (Coreset, StreamStats) {
    let mut alg = StreamCoreset::new(ds, m, k, eps, DEFAULT_C);
    for &x in order {
        alg.push(x);
    }
    alg.finish()
}

/// Convenience wrapper: run the tau-variant (§5.2) over `order`.
pub fn stream_coreset_tau(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    tau: usize,
    order: &[usize],
) -> (Coreset, StreamStats) {
    let mut alg = StreamCoreset::with_tau(ds, m, k, tau);
    for &x in order {
        alg.push(x);
    }
    alg.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::{PartitionMatroid, TransversalMatroid, UniformMatroid};
    use crate::util::rng::Rng;

    #[test]
    fn lemma3_invariants_hold_along_the_stream() {
        let ds = synth::uniform_cube(300, 2, 1);
        let m = UniformMatroid::new(4);
        let (k, eps, c) = (4, 0.5, DEFAULT_C);
        let mut alg = StreamCoreset::new(&ds, &m, k, eps, c);
        let mut max_d1 = 0.0f64; // d(x_i, x_1) running max ~ prefix diameter proxy
        for i in 0..ds.n() {
            alg.push(i);
            if i > 0 {
                max_d1 = max_d1.max(ds.dist(i, 0));
            }
            if i >= 1 {
                // Invariant 1 (weak form): R_i within [prefix_max_d1/2? , Delta_i]
                // exact check: Delta_i/4 <= R <= Delta_i, with Delta_i >= max_d1
                assert!(alg.r_estimate() <= 2.0 * max_d1 + 1e-12);
            }
            // Invariant 2: centers pairwise > eps*R/(ck)
            let thr = eps * alg.r_estimate() / (c * k as f64);
            let zs = alg.centers();
            for a in 0..zs.len() {
                for b in (a + 1)..zs.len() {
                    assert!(
                        ds.dist(zs[a], zs[b]) > thr - 1e-12,
                        "centers too close after point {i}"
                    );
                }
            }
        }
        // Invariant 3 at stream end: every point within 2 eps R/(ck) of a center
        let reach = 2.0 * eps * alg.r_estimate() / (c * k as f64);
        let zs: Vec<usize> = alg.centers().to_vec();
        for i in 0..ds.n() {
            let dmin = zs.iter().map(|&z| ds.dist(i, z)).fold(f64::INFINITY, f64::min);
            assert!(dmin <= reach + 1e-9, "point {i} at {dmin} > {reach}");
        }
    }

    #[test]
    fn diameter_estimate_sandwich() {
        // Invariant 1 exactly: Delta/4 <= R <= Delta at the end of the stream
        let ds = synth::uniform_cube(150, 3, 7);
        let m = UniformMatroid::new(3);
        let mut alg = StreamCoreset::new(&ds, &m, 3, 0.5, DEFAULT_C);
        for i in 0..ds.n() {
            alg.push(i);
        }
        let delta = ds.diameter_exact();
        assert!(alg.r_estimate() <= delta + 1e-9);
        assert!(alg.r_estimate() >= delta / 4.0 - 1e-9);
    }

    #[test]
    fn partition_delegates_stay_independent_and_bounded() {
        let ds = synth::clustered(400, 2, 5, 0.2, 4, 3);
        let m = PartitionMatroid::new(vec![2; 4]);
        let k = 6;
        let (cs, stats) = stream_coreset(&ds, &m, k, 0.5, &(0..ds.n()).collect::<Vec<_>>());
        assert!(stats.peak_memory_points <= cs.n_clusters.max(1) * k + ds.n() / 10,);
        // a feasible solution of size min(k, rank) must exist in the coreset
        let sol = crate::matroid::maximal_independent(&m, &ds, &cs.indices, k);
        assert!(!sol.is_empty());
    }

    #[test]
    fn tau_variant_bounds_centers() {
        let ds = synth::uniform_cube(500, 2, 5);
        let m = UniformMatroid::new(4);
        let tau = 16;
        let mut alg = StreamCoreset::with_tau(&ds, &m, 4, tau);
        for i in 0..ds.n() {
            alg.push(i);
            assert!(alg.n_centers() <= tau, "|Z| exceeded tau mid-stream");
        }
        let (cs, stats) = alg.finish();
        assert!(cs.n_clusters <= tau);
        assert!(stats.restructures > 0, "doubling never triggered on 500 pts");
        // coverage: every point within 2R of some center
        let reach = 2.0; // bound recomputed below
        let _ = reach;
    }

    #[test]
    fn tau_variant_coverage() {
        let ds = synth::uniform_cube(300, 2, 9);
        let m = UniformMatroid::new(3);
        let mut alg = StreamCoreset::with_tau(&ds, &m, 3, 12);
        for i in 0..ds.n() {
            alg.push(i);
        }
        // merged delegates hop along a chain of dropped centers, each hop
        // bounded by the 2R of its epoch: the geometric sum bounds coverage
        // by 4R against the final centers (the paper calls this variant an
        // 8-approximation for exactly this reason); assert the 8R envelope.
        let reach = 8.0 * alg.r_estimate();
        let zs: Vec<usize> = alg.centers().to_vec();
        for i in 0..ds.n() {
            let dmin = zs.iter().map(|&z| ds.dist(i, z)).fold(f64::INFINITY, f64::min);
            assert!(dmin <= reach + 1e-9);
        }
    }

    #[test]
    fn transversal_handle_keeps_category_coverage() {
        let ds = synth::wikisim(300, 5);
        let m = TransversalMatroid::new();
        let k = 4;
        let (cs, _) = stream_coreset(&ds, &m, k, 0.5, &(0..ds.n()).collect::<Vec<_>>());
        assert!(!cs.is_empty());
        // delegates per center bounded by gamma*k^2 (gamma=4 categories max)
        assert!(cs.len() <= cs.n_clusters * 4 * k * k + k);
    }

    #[test]
    fn order_insensitivity_of_feasibility() {
        // feasibility of the extracted solution must hold under any order
        let ds = synth::clustered(200, 2, 4, 0.15, 2, 11);
        let m = PartitionMatroid::new(vec![3, 3]);
        let k = 5;
        let mut rng = Rng::new(42);
        for _ in 0..3 {
            let order = rng.permutation(ds.n());
            let (cs, _) = stream_coreset(&ds, &m, k, 0.5, &order);
            let sol = crate::matroid::maximal_independent(&m, &ds, &cs.indices, k);
            assert_eq!(sol.len(), k);
        }
    }

    #[test]
    fn push_batch_matches_sequential_push() {
        // Euclidean data: the batched fold's f32 re-read edge needs an
        // ulp-level distance collision between two centers to diverge from
        // the sequential f64 scan — absent here, so the coresets and the
        // center sets must match exactly, for every batch size
        let ds = synth::uniform_cube(400, 3, 21);
        let m = UniformMatroid::new(4);
        let order: Vec<usize> = (0..ds.n()).collect();
        let mut seq_alg = StreamCoreset::with_tau(&ds, &m, 4, 16);
        for &x in &order {
            seq_alg.push(x);
        }
        let seq_centers = seq_alg.centers().to_vec();
        let (seq_cs, seq_stats) = seq_alg.finish();
        for batch in [1usize, 7, 64, 400] {
            let mut alg = StreamCoreset::with_tau(&ds, &m, 4, 16);
            alg.set_engine_kind(EngineKind::Batch).unwrap();
            for chunk in order.chunks(batch) {
                alg.push_batch(chunk);
            }
            assert_eq!(alg.centers(), &seq_centers[..], "batch={batch}: centers moved");
            let (cs, stats) = alg.finish();
            assert_eq!(cs.indices, seq_cs.indices, "batch={batch}: coreset moved");
            assert_eq!(stats.points_processed, seq_stats.points_processed);
            assert_eq!(stats.restructures, seq_stats.restructures);
        }
    }

    #[test]
    fn push_batch_invariants_on_cosine_data() {
        // cosine tiles are tolerance-level under simd/pjrt, so no bitwise
        // pin here — assert the §5.2 invariants instead: size bound along
        // the stream, coverage, feasibility of the extracted solution
        let ds = synth::wikisim(300, 9);
        let m = TransversalMatroid::new();
        let (k, tau) = (3, 12);
        let mut alg = StreamCoreset::with_tau(&ds, &m, k, tau);
        alg.set_engine_kind(EngineKind::Batch).unwrap();
        let order: Vec<usize> = (0..ds.n()).collect();
        for chunk in order.chunks(50) {
            alg.push_batch(chunk);
            assert!(alg.n_centers() <= tau, "|Z| exceeded tau mid-stream");
        }
        let reach = 8.0 * alg.r_estimate();
        let zs: Vec<usize> = alg.centers().to_vec();
        for i in 0..ds.n() {
            let dmin = zs.iter().map(|&z| ds.dist(i, z)).fold(f64::INFINITY, f64::min);
            assert!(dmin <= reach + 1e-9);
        }
        let (cs, stats) = alg.finish();
        assert_eq!(stats.points_processed, 300);
        assert!(stats.distance_evals > 0);
        let sol = crate::matroid::maximal_independent(&m, &ds, &cs.indices, k);
        assert!(!sol.is_empty());
    }

    #[test]
    fn single_pass_memory_far_below_n() {
        let ds = synth::uniform_cube(2000, 2, 13);
        let m = UniformMatroid::new(4);
        let (cs, stats) = stream_coreset_tau(&ds, &m, 4, 16, &(0..ds.n()).collect::<Vec<_>>());
        assert!(stats.peak_memory_points < ds.n() / 4,
            "peak {} not sublinear", stats.peak_memory_points);
        assert!(cs.len() <= 16 * 4 + 16);
    }
}
