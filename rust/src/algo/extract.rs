//! Per-cluster coreset EXTRACT procedures (paper §3.1 / Algorithm 1).
//!
//! Given one cluster `C` of a small-radius clustering, select the points
//! that enter the coreset, by matroid kind:
//!
//! * **partition** (§3.1.1): a largest independent subset of size <= k —
//!   coreset size O(k tau) (Theorem 1);
//! * **transversal** (§3.1.2): a largest independent subset `U`, augmented
//!   so that every category of a point of `U` has `min(k, |A inter C|)`
//!   representatives — size O(k^2 tau) (Theorem 2);
//! * **general** (§3.1.3): a size-k independent subset if one exists,
//!   otherwise the whole cluster (Theorem 3).

use std::collections::{BTreeMap, BTreeSet};

use crate::core::Dataset;
use crate::matroid::{maximal_independent, Matroid, MatroidKind};

/// EXTRACT(C, k) of Algorithm 1, dispatching on the matroid kind.
pub fn extract(ds: &Dataset, m: &dyn Matroid, cluster: &[usize], k: usize) -> Vec<usize> {
    let u = maximal_independent(m, ds, cluster, k);
    if u.len() == k || m.kind() == MatroidKind::Partition {
        return u;
    }
    match m.kind() {
        MatroidKind::Partition => unreachable!(),
        MatroidKind::Transversal => augment_transversal(ds, cluster, u, k),
        MatroidKind::General => cluster.to_vec(),
    }
}

/// Transversal augmentation: ensure `min(k, |A inter C|)` points of every
/// category `A` of a point of `U` (a point counts for all of its
/// categories, matching the paper's remark).
fn augment_transversal(
    ds: &Dataset,
    cluster: &[usize],
    u: Vec<usize>,
    k: usize,
) -> Vec<usize> {
    // categories of interest = categories of the points of U.  BTreeMaps,
    // not HashMaps: coverage counting iterates these maps, and the
    // determinism contract (dmmc-lint L1) requires an input-defined order
    // so extraction depends only on the input order of `cluster`.
    let mut target: BTreeMap<u32, usize> = BTreeMap::new();
    for &x in &u {
        for &c in &ds.categories[x] {
            target.insert(c, 0);
        }
    }
    // |A inter C| for each category of interest
    for &x in cluster {
        for &c in &ds.categories[x] {
            if let Some(t) = target.get_mut(&c) {
                *t += 1;
            }
        }
    }
    for t in target.values_mut() {
        *t = (*t).min(k);
    }
    // count current coverage from U, then greedily add cluster points that
    // help an under-covered category
    let mut have: BTreeMap<u32, usize> = target.keys().map(|&c| (c, 0)).collect();
    let mut out = u.clone();
    let in_u: BTreeSet<usize> = u.iter().copied().collect();
    for &x in &u {
        for &c in &ds.categories[x] {
            if let Some(h) = have.get_mut(&c) {
                *h += 1;
            }
        }
    }
    for &x in cluster {
        if in_u.contains(&x) {
            continue;
        }
        let helps = ds.categories[x]
            .iter()
            .any(|c| match (have.get(c), target.get(c)) {
                (Some(h), Some(t)) => h < t,
                _ => false,
            });
        if helps {
            out.push(x);
            for &c in &ds.categories[x] {
                if let Some(h) = have.get_mut(&c) {
                    *h += 1;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dataset, Metric};
    use crate::matroid::{
        GraphicMatroid, PartitionMatroid, TransversalMatroid, UniformMatroid,
    };

    fn ds(cats: Vec<Vec<u32>>, n_categories: u32) -> Dataset {
        let n = cats.len();
        Dataset::new(
            1,
            Metric::Euclidean,
            (0..n).map(|i| i as f32).collect(),
            cats,
            n_categories,
            "test",
        )
    }

    #[test]
    fn partition_extract_is_largest_independent() {
        let d = ds(vec![vec![0], vec![0], vec![0], vec![1]], 2);
        let m = PartitionMatroid::new(vec![2, 2]);
        let out = extract(&d, &m, &[0, 1, 2, 3], 4);
        // cap on category 0 limits to 2+1 = 3 points
        assert_eq!(out.len(), 3);
        assert!(m.is_independent(&d, &out));
    }

    #[test]
    fn partition_extract_caps_at_k() {
        let d = ds(vec![vec![0]; 10], 1);
        let m = PartitionMatroid::new(vec![10]);
        let out = extract(&d, &m, &(0..10).collect::<Vec<_>>(), 3);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn transversal_extract_covers_categories() {
        // U will be smaller than k; categories of U must reach
        // min(k, |A inter C|) coverage in the output.
        // points: 0:{0}, 1:{0}, 2:{0}, 3:{1}, 4:{1}
        let d = ds(vec![vec![0], vec![0], vec![0], vec![1], vec![1]], 2);
        let m = TransversalMatroid::new();
        let k = 3;
        let out = extract(&d, &m, &[0, 1, 2, 3, 4], k);
        // max independent subset has size 2 (<k) with categories {0,1};
        // coverage targets: cat0 -> min(3,3)=3, cat1 -> min(3,2)=2
        let count = |cat: u32| {
            out.iter()
                .filter(|&&x| d.categories[x].contains(&cat))
                .count()
        };
        assert!(count(0) >= 3, "{out:?}");
        assert!(count(1) >= 2, "{out:?}");
    }

    #[test]
    fn transversal_extract_full_k_short_circuit() {
        let d = ds(vec![vec![0], vec![1], vec![2], vec![3]], 4);
        let m = TransversalMatroid::new();
        let out = extract(&d, &m, &[0, 1, 2, 3], 2);
        assert_eq!(out.len(), 2);
        assert!(m.is_independent(&d, &out));
    }

    #[test]
    fn general_extract_falls_back_to_whole_cluster() {
        let d = ds(vec![vec![0]; 6], 1);
        // graphic matroid over a path graph 0-1-2: only 2 edges independent
        let m = GraphicMatroid::new(
            vec![(0, 1), (0, 1), (1, 2), (1, 2), (0, 2), (0, 2)],
            3,
        );
        // no size-4 independent subset exists (rank = 2) -> whole cluster
        let out = extract(&d, &m, &[0, 1, 2, 3, 4, 5], 4);
        assert_eq!(out.len(), 6);
    }

    #[test]
    fn general_extract_returns_k_when_possible() {
        let d = ds(vec![vec![0]; 6], 1);
        let m = UniformMatroid::new(10);
        let out = extract(&d, &m, &[0, 1, 2, 3, 4, 5], 4);
        assert_eq!(out.len(), 4);
    }
}
