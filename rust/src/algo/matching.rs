//! Matching-based remote-clique finisher: the Hassin–Rubinstein–Tamir
//! greedy maximum-weight matching 2-approximation, raced against the
//! matroid Gonzalez farthest-point greedy with deterministic best-of-both
//! selection.
//!
//! The matching arm sorts all candidate pairs by distance (descending)
//! and greedily takes matroid-feasible edges with unused endpoints until
//! `floor(k/2)` edges are placed; odd `k` (or a matroid stall) is topped
//! up by feasible farthest-point fill.  The GMM arm is
//! [`greedy_matroid_gonzalez`].  Both finishers are scored through the
//! engine-backed [`Evaluator`], and the better solution wins (ties go to
//! the matching arm), so the race never returns a result worse than
//! either standalone finisher — a pinned invariant.
//!
//! Determinism: the matching arm is fully deterministic (edges ordered by
//! `(weight desc, i, j)` with index tie-breaks, Vec + sort only — no hash
//! collections per lint contract L1); the GMM arm consumes the caller's
//! seeded [`Rng`], so the race winner is a pure function of
//! `(dataset, matroid, k, candidates, objective, seed)`.

use anyhow::Result;

use crate::core::Dataset;
use crate::diversity::{Evaluator, Objective};
use crate::matroid::Matroid;
use crate::runtime::engine::DistanceEngine;
use crate::util::rng::Rng;

use super::greedy::greedy_matroid_gonzalez;

/// Outcome of the matching-vs-GMM race (see [`matching_race`]).
#[derive(Clone, Debug)]
pub struct MatchingRace {
    /// The winning solution (best-of-both).
    pub solution: Vec<usize>,
    /// Diversity of the winning solution under the raced objective.
    pub diversity: f64,
    /// Diversity of the matching arm's solution.
    pub matching_value: f64,
    /// Diversity of the GMM arm's solution.
    pub gmm_value: f64,
    /// Which arm won: `"matching"` or `"gmm"` (ties go to matching).
    pub winner: &'static str,
    /// Number of matching edges placed before the fill step.
    pub matching_edges: usize,
}

/// Greedy maximum-weight matching under the matroid: sort candidate
/// pairs by distance descending, take each edge whose two endpoints are
/// still unused and jointly matroid-feasible, stop at `floor(k/2)`
/// edges, then top up to `k` with feasible farthest-point fill (odd `k`,
/// or a matroid that starves the matching early).  Returns the selected
/// indices and the number of whole edges placed.
pub fn greedy_matching_solution(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    candidates: &[usize],
    engine: &dyn DistanceEngine,
) -> Result<(Vec<usize>, usize)> {
    let n = candidates.len();
    if n == 0 || k == 0 {
        return Ok((Vec::new(), 0));
    }
    let tile = Evaluator::new(engine).submatrix(ds, candidates)?;
    // all pairs (a < b) as positions into `candidates`, heaviest first;
    // ties broken by (a, b) so the order is a pure function of the input
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(n * (n - 1) / 2);
    for a in 0..n {
        for b in (a + 1)..n {
            edges.push((a, b));
        }
    }
    edges.sort_by(|&(a1, b1), &(a2, b2)| {
        let w1 = tile[a1 * n + b1];
        let w2 = tile[a2 * n + b2];
        w2.partial_cmp(&w1)
            .expect("finite distances")
            .then(a1.cmp(&a2))
            .then(b1.cmp(&b2))
    });

    let mut sol: Vec<usize> = Vec::with_capacity(k);
    let mut used = vec![false; n];
    let mut placed_edges = 0usize;
    for &(a, b) in &edges {
        if sol.len() + 2 > k {
            break;
        }
        if used[a] || used[b] {
            continue;
        }
        let (x, y) = (candidates[a], candidates[b]);
        if !m.can_extend(ds, &sol, x) {
            continue;
        }
        sol.push(x);
        if m.can_extend(ds, &sol, y) {
            sol.push(y);
            used[a] = true;
            used[b] = true;
            placed_edges += 1;
        } else {
            sol.pop();
        }
    }
    // fill the remaining slots (odd k, or matroid-starved matching) with
    // deterministic feasible farthest-point additions over the same tile
    while sol.len() < k {
        let mut best: Option<(usize, f64)> = None;
        for (a, &x) in candidates.iter().enumerate() {
            if used[a] || sol.contains(&x) {
                continue;
            }
            let mind = candidates
                .iter()
                .enumerate()
                .filter(|&(_, y)| sol.contains(y))
                .map(|(b, _)| tile[a.min(b) * n + a.max(b)])
                .fold(f64::INFINITY, f64::min);
            let d = if sol.is_empty() { 1.0 } else { mind };
            if best.map(|(_, bd)| d > bd).unwrap_or(true) && m.can_extend(ds, &sol, x) {
                best = Some((a, d));
            }
        }
        match best {
            None => break,
            Some((a, _)) => {
                used[a] = true;
                sol.push(candidates[a]);
            }
        }
    }
    Ok((sol, placed_edges))
}

/// Race the greedy maximum-weight matching against the matroid Gonzalez
/// greedy and return the better solution under `obj` (best-of-both; ties
/// go to the matching arm).
pub fn matching_race(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    candidates: &[usize],
    obj: Objective,
    engine: &dyn DistanceEngine,
    rng: &mut Rng,
) -> Result<MatchingRace> {
    let (match_sol, matching_edges) = greedy_matching_solution(ds, m, k, candidates, engine)?;
    let gmm_sol = greedy_matroid_gonzalez(ds, m, k, candidates, rng);
    let ev = Evaluator::new(engine);
    let matching_value = ev.diversity(ds, &match_sol, obj)?;
    let gmm_value = ev.diversity(ds, &gmm_sol, obj)?;
    let (solution, diversity, winner) = if matching_value >= gmm_value {
        (match_sol, matching_value, "matching")
    } else {
        (gmm_sol, gmm_value, "gmm")
    };
    Ok(MatchingRace {
        solution,
        diversity,
        matching_value,
        gmm_value,
        winner,
        matching_edges,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::{Matroid, PartitionMatroid, UniformMatroid};
    use crate::runtime::engine::ScalarEngine;

    #[test]
    fn matching_solution_is_independent_and_sized() {
        let ds = synth::clustered(120, 2, 4, 0.1, 3, 1);
        let m = PartitionMatroid::new(vec![2, 2, 2]);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let e = ScalarEngine::new();
        for k in [2usize, 4, 5, 6] {
            let (sol, edges) = greedy_matching_solution(&ds, &m, k, &cands, &e).unwrap();
            assert_eq!(sol.len(), k, "k={k}");
            assert!(m.is_independent(&ds, &sol), "k={k}");
            assert!(edges <= k / 2, "k={k} edges={edges}");
        }
    }

    #[test]
    fn matching_arm_is_deterministic() {
        let ds = synth::clustered(80, 3, 4, 0.1, 2, 2);
        let m = UniformMatroid::new(6);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let e = ScalarEngine::new();
        let (a, _) = greedy_matching_solution(&ds, &m, 5, &cands, &e).unwrap();
        let (b, _) = greedy_matching_solution(&ds, &m, 5, &cands, &e).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn race_never_loses_to_either_arm() {
        let ds = synth::clustered(100, 2, 5, 0.1, 3, 3);
        let m = PartitionMatroid::new(vec![2, 2, 2]);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let e = ScalarEngine::new();
        for obj in crate::diversity::ALL_OBJECTIVES {
            let mut rng = Rng::new(7);
            let race = matching_race(&ds, &m, 6, &cands, obj, &e, &mut rng).unwrap();
            assert!(
                race.diversity >= race.matching_value && race.diversity >= race.gmm_value,
                "{obj:?}: best-of-both {} lost to an arm (matching {}, gmm {})",
                race.diversity,
                race.matching_value,
                race.gmm_value
            );
            assert!(m.is_independent(&ds, &race.solution));
        }
    }

    #[test]
    fn race_winner_deterministic_given_seed() {
        let ds = synth::clustered(90, 2, 3, 0.1, 3, 4);
        let m = UniformMatroid::new(4);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let e = ScalarEngine::new();
        let run = |seed: u64| {
            let mut rng = Rng::new(seed);
            matching_race(&ds, &m, 4, &cands, Objective::RemoteEdge, &e, &mut rng).unwrap()
        };
        let (a, b) = (run(11), run(11));
        assert_eq!(a.solution, b.solution);
        assert_eq!(a.winner, b.winner);
        assert_eq!(a.diversity.to_bits(), b.diversity.to_bits());
    }
}
