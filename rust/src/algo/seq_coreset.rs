//! SeqCoreset — Algorithm 1 of the paper.
//!
//! Run GMM until the clustering radius satisfies Equation (1)
//! (`r <= eps * delta / (16 k)`, or a fixed cluster count `tau` in the
//! experiments' budget mode), then EXTRACT a subset from every cluster
//! according to the matroid kind.  Theorem 5: the result is a
//! `(1 - eps)`-coreset built in O(n tau) time, of size O(k tau) for the
//! partition matroid and O(k^2 tau) for the transversal matroid.

use anyhow::Result;

use crate::algo::extract::extract;
use crate::algo::gmm::{gmm, GmmStop};
use crate::algo::{Budget, Coreset};
use crate::core::Dataset;
use crate::matroid::Matroid;
use crate::runtime::engine::DistanceEngine;
use crate::util::timer::PhaseTimer;

/// Build a coreset of `ds` for solutions of size `k` under matroid `m`.
pub fn seq_coreset(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    budget: Budget,
    engine: &dyn DistanceEngine,
) -> Result<Coreset> {
    let mut timer = PhaseTimer::new();
    let stop = match budget {
        Budget::Epsilon(eps) => GmmStop::RadiusFactor { eps, k },
        Budget::Clusters(tau) => GmmStop::Clusters(tau),
    };
    let clustering = {
        let mut out = None;
        timer.phase("cluster", || -> Result<()> {
            out = Some(gmm(ds, engine, 0, stop)?);
            Ok(())
        })?;
        out.unwrap()
    };

    let mut indices = Vec::new();
    timer.phase("extract", || {
        for cluster in clustering.clusters() {
            indices.extend(extract(ds, m, &cluster, k));
        }
    });
    indices.sort_unstable();
    indices.dedup();

    Ok(Coreset {
        indices,
        n_clusters: clustering.centers.len(),
        radius: clustering.radius,
        timer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::{
        maximal_independent, PartitionMatroid, TransversalMatroid, UniformMatroid,
    };
    use crate::runtime::engine::ScalarEngine;

    #[test]
    fn partition_coreset_size_bound() {
        let ds = synth::clustered(500, 3, 8, 0.1, 4, 1);
        let m = PartitionMatroid::new(vec![2; 4]);
        let k = 6;
        let tau = 16;
        let cs = seq_coreset(&ds, &m, k, Budget::Clusters(tau), &ScalarEngine::new()).unwrap();
        assert!(cs.len() <= k * tau, "{} > {}", cs.len(), k * tau);
        assert_eq!(cs.n_clusters, tau);
        assert!(cs.len() >= 1);
    }

    #[test]
    fn coreset_contains_feasible_solution() {
        let ds = synth::clustered(300, 2, 6, 0.1, 3, 2);
        let m = PartitionMatroid::new(vec![2, 2, 2]);
        let k = 5;
        let cs = seq_coreset(&ds, &m, k, Budget::Clusters(12), &ScalarEngine::new()).unwrap();
        let sol = maximal_independent(&m, &ds, &cs.indices, k);
        assert_eq!(sol.len(), k, "coreset must contain a feasible k-set");
    }

    #[test]
    fn epsilon_budget_hits_radius_bound() {
        let ds = synth::uniform_cube(400, 2, 3);
        let m = UniformMatroid::new(4);
        let (k, eps) = (4, 0.8);
        let cs = seq_coreset(&ds, &m, k, Budget::Epsilon(eps), &ScalarEngine::new()).unwrap();
        // radius <= eps*delta/(16k) <= eps*Delta/(16k)
        let diam = ds.diameter_exact();
        assert!(cs.radius <= eps * diam / (16.0 * k as f64) + 1e-9);
    }

    #[test]
    fn transversal_coreset_respects_k2tau_bound() {
        let ds = synth::wikisim(400, 4);
        let m = TransversalMatroid::new();
        let (k, tau) = (5, 8);
        let cs = seq_coreset(&ds, &m, k, Budget::Clusters(tau), &ScalarEngine::new()).unwrap();
        // O(k^2 tau) with the O(1)-categories-per-point constant = 4
        assert!(cs.len() <= 4 * k * k * tau, "{}", cs.len());
        assert!(!cs.is_empty());
    }

    #[test]
    fn indices_unique_and_in_range() {
        let ds = synth::uniform_cube(200, 2, 5);
        let m = UniformMatroid::new(3);
        let cs = seq_coreset(&ds, &m, 3, Budget::Clusters(10), &ScalarEngine::new()).unwrap();
        // BTreeSet so a duplicate-id assertion failure names the same
        // first duplicate on every run
        let mut seen = std::collections::BTreeSet::new();
        for &i in &cs.indices {
            assert!(i < ds.n());
            assert!(seen.insert(i));
        }
    }

    #[test]
    fn timer_has_both_phases() {
        let ds = synth::uniform_cube(200, 2, 6);
        let m = UniformMatroid::new(3);
        let cs = seq_coreset(&ds, &m, 3, Budget::Clusters(8), &ScalarEngine::new()).unwrap();
        assert!(cs.timer.get("cluster") > std::time::Duration::ZERO);
    }
}
