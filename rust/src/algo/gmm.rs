//! GMM (Gonzalez) greedy k-center clustering — the clustering primitive of
//! SeqCoreset (paper §4.1, [18]).
//!
//! Incremental farthest-point iteration: each round folds the newest center
//! into the running (min-dist, argmin) state via the [`DistanceEngine`]
//! (O(n) per round — the hot path that the Pallas/PJRT backend accelerates)
//! and then picks the point of maximum min-dist as the next center.  After
//! `i` rounds the implicit clustering is a 2-approximation to the optimal
//! `i`-clustering radius [18].

use anyhow::Result;

use crate::core::Dataset;
use crate::runtime::engine::DistanceEngine;

/// Result of a GMM run: centers + the implicit clustering state.
#[derive(Clone, Debug)]
pub struct Clustering {
    /// Selected centers (dataset indices), in selection order.
    pub centers: Vec<usize>,
    /// Per point: position (into `centers`) of its closest center.
    pub assign: Vec<u32>,
    /// Per point: distance to its closest center.
    pub mindist: Vec<f32>,
    /// Clustering radius = max over points of `mindist`.
    pub radius: f64,
    /// `d(z1, z2)` — the paper's diameter proxy (`Delta/2 <= delta <= Delta`).
    pub delta: f64,
}

impl Clustering {
    /// Cluster membership lists (position-indexed like `centers`).
    pub fn clusters(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.centers.len()];
        for (i, &a) in self.assign.iter().enumerate() {
            out[a as usize].push(i);
        }
        out
    }
}

/// Stopping rule for the GMM iteration.
#[derive(Clone, Copy, Debug)]
pub enum GmmStop {
    /// Stop at exactly `tau` centers (the tau-controlled mode of §5).
    Clusters(usize),
    /// Algorithm 1 rule: stop once `radius <= eps * delta / (16 k)`.
    RadiusFactor { eps: f64, k: usize },
}

/// Run GMM from `first` until `stop` is met (or every point is a center).
pub fn gmm(
    ds: &Dataset,
    engine: &dyn DistanceEngine,
    first: usize,
    stop: GmmStop,
) -> Result<Clustering> {
    let n = ds.n();
    assert!(n > 0, "gmm on empty dataset");
    let mut centers = vec![first];
    let mut mindist = vec![f32::INFINITY; n];
    let mut assign = vec![0u32; n];
    engine.update_min(ds, first, 0, &mut mindist, &mut assign)?;

    // delta = d(z1, z2) where z2 is the farthest point from z1 — the
    // paper's diameter proxy, fixed after the first fold whether or not
    // z2 is ever promoted to a center (the stop rule is checked *before*
    // every push, so e.g. GmmStop::Clusters(1) really returns 1 center).
    let delta = mindist[argmax(&mindist)] as f64;

    loop {
        let far = argmax(&mindist);
        let radius = mindist[far] as f64;
        let done = match stop {
            GmmStop::Clusters(tau) => centers.len() >= tau.max(1),
            GmmStop::RadiusFactor { eps, k } => {
                radius <= eps * delta / (16.0 * k as f64)
            }
        };
        if done || radius == 0.0 || centers.len() == n {
            return Ok(Clustering {
                radius,
                delta,
                centers,
                assign,
                mindist,
            });
        }
        let id = centers.len() as u32;
        centers.push(far);
        engine.update_min(ds, far, id, &mut mindist, &mut assign)?;
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::engine::ScalarEngine;

    #[test]
    fn exact_cover_when_tau_equals_clusters() {
        // 4 tight blobs, tau=4 -> radius must collapse to the blob spread
        let ds = synth::clustered(200, 2, 4, 0.01, 1, 1);
        let c = gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(4)).unwrap();
        assert_eq!(c.centers.len(), 4);
        assert!(c.radius < 0.2, "radius {}", c.radius);
        // blob span is ~10; picking 2 centers leaves radius large
        let c2 = gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(2)).unwrap();
        assert!(c2.radius > c.radius);
    }

    #[test]
    fn radius_is_max_mindist_and_assign_consistent() {
        let ds = synth::uniform_cube(300, 3, 2);
        let c = gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(10)).unwrap();
        let mut maxd: f64 = 0.0;
        for i in 0..ds.n() {
            let z = c.centers[c.assign[i] as usize];
            let d = ds.dist(i, z);
            assert!((d - c.mindist[i] as f64).abs() < 1e-4);
            // closest-center property
            for &other in &c.centers {
                assert!(ds.dist(i, other) >= d - 1e-4);
            }
            maxd = maxd.max(d);
        }
        assert!((maxd - c.radius).abs() < 1e-4);
    }

    #[test]
    fn delta_is_diameter_proxy() {
        let ds = synth::uniform_cube(200, 2, 3);
        let c = gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(5)).unwrap();
        let diam = ds.diameter_exact();
        assert!(c.delta <= diam + 1e-9);
        assert!(c.delta >= diam / 2.0 - 1e-9);
    }

    #[test]
    fn radius_factor_stop_reaches_bound() {
        let ds = synth::uniform_cube(400, 2, 4);
        let (eps, k) = (0.5, 4);
        let c = gmm(
            &ds,
            &ScalarEngine::new(),
            0,
            GmmStop::RadiusFactor { eps, k },
        )
        .unwrap();
        assert!(c.radius <= eps * c.delta / (16.0 * k as f64) + 1e-9);
    }

    #[test]
    fn two_approximation_quality() {
        // GMM radius after tau rounds <= 2 * optimal tau-clustering radius.
        // On a 5x5 grid with tau=25, optimal radius is 0 -> GMM must hit 0.
        let ds = synth::grid(5);
        let c = gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(25)).unwrap();
        assert_eq!(c.radius, 0.0);
        assert_eq!(c.centers.len(), 25);
    }

    #[test]
    fn tau_one_returns_exactly_one_center() {
        // regression: the second (farthest) center used to be pushed before
        // any stop check, so Clusters(1) returned 2 centers
        let ds = synth::uniform_cube(100, 2, 9);
        let c = gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(1)).unwrap();
        assert_eq!(c.centers.len(), 1);
        assert_eq!(c.centers[0], 0);
        assert!(c.assign.iter().all(|&a| a == 0));
        // delta must still report the diameter proxy, not collapse to 0
        assert!(c.delta > 0.0);
        assert!((c.radius - c.delta).abs() < 1e-12);
    }

    #[test]
    fn batch_engine_matches_scalar_trajectory() {
        // BatchEngine is bit-identical on update_min, so the greedy center
        // sequence — argmax over f32 min-dists — must match exactly.
        let ds = synth::clustered(3000, 4, 6, 0.2, 2, 17);
        let s = gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(24)).unwrap();
        let b = gmm(
            &ds,
            &crate::runtime::BatchEngine::for_dataset(&ds),
            0,
            GmmStop::Clusters(24),
        )
        .unwrap();
        assert_eq!(s.centers, b.centers);
        assert_eq!(s.assign, b.assign);
        assert_eq!(s.mindist, b.mindist);
    }

    #[test]
    fn duplicate_points_terminate() {
        let ds = crate::core::Dataset::new(
            1,
            crate::core::Metric::Euclidean,
            vec![1.0; 50],
            vec![vec![0]; 50],
            1,
            "dup",
        );
        let c = gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(10)).unwrap();
        assert_eq!(c.radius, 0.0);
        assert!(c.centers.len() <= 2);
    }

    #[test]
    fn clusters_partition_points() {
        let ds = synth::uniform_cube(100, 2, 5);
        let c = gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(7)).unwrap();
        let clusters = c.clusters();
        let total: usize = clusters.iter().map(|cl| cl.len()).sum();
        assert_eq!(total, 100);
        for (pos, cl) in clusters.iter().enumerate() {
            assert!(cl.contains(&c.centers[pos]));
        }
    }
}
