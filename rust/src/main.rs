//! `dmmc` — the CLI launcher for the matroid-coreset system.
//!
//! Subcommands:
//!
//! * `gen-data`        — generate a synthetic dataset to a `.dmmc` file
//! * `stats`           — Table-2-style dataset statistics
//! * `run`             — full pipeline: coreset setting + finisher
//! * `sweep`           — config-driven experiment grid (configs/*.toml)
//! * `artifacts-check` — load + smoke-run the AOT artifacts vs the scalar oracle
//! * `help`            — usage
//!
//! Examples:
//!
//! ```text
//! dmmc gen-data --kind wikisim --n 100000 --seed 1 --out wiki.dmmc
//! dmmc run --data wikisim:20000 --algo seq --tau 64 --k 25 --finisher local-search
//! dmmc run --data songsim:20000 --algo mr --workers 8 --tau 64 --k 22
//! dmmc run --data cube:5000x8 --algo stream --tau 32 --k 6 --objective tree --finisher exhaustive
//! ```

use anyhow::{bail, Context, Result};

use matroid_coreset::algo::Budget;
use matroid_coreset::cli::Args;
use matroid_coreset::coordinator::{
    build_dataset, build_matroid, run_pipeline, DatasetSpec, Finisher, MatroidSpec, Pipeline,
    Setting,
};
use matroid_coreset::data::{io, synth};
use matroid_coreset::diversity::Objective;
use matroid_coreset::matroid::Matroid;
use matroid_coreset::runtime::EngineKind;
use matroid_coreset::streaming::StreamMode;

const USAGE: &str = "\
dmmc — coreset-based diversity maximization under matroid constraints

USAGE: dmmc <subcommand> [options]

SUBCOMMANDS
  gen-data   --kind wikisim|songsim|cube|clustered --n N [--seed S] --out F [--stats]
  stats      --data <file|kind:n>
  run        --data <file|kind:n> --algo seq|stream|mr|full
             [--k K] [--tau T | --eps E] [--workers L] [--objective sum|star|tree|cycle|bipartition]
             [--finisher local-search|exhaustive|greedy] [--gamma G]
             [--engine batch|scalar|simd|pjrt] [--matroid transversal|partition:R|uniform:R]
             [--seed S]
  sweep      --config configs/<file>.toml [--csv out.csv]
  artifacts-check  [--data <kind:n>]
  help
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    match run(argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "gen-data" => cmd_gen_data(&args),
        "stats" => cmd_stats(&args),
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "artifacts-check" => cmd_artifacts_check(&args),
        "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other}\n{USAGE}"),
    }
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    args.expect_known(&["kind", "n", "seed", "out", "stats", "dim"])?;
    let kind = args.require("kind")?;
    let n = args.usize_or("n", 10_000)?;
    let seed = args.u64_or("seed", 1)?;
    let ds = match kind {
        "wikisim" => synth::wikisim(n, seed),
        "songsim" => synth::songsim(n, seed),
        "cube" => synth::uniform_cube(n, args.usize_or("dim", 8)?, seed),
        "clustered" => synth::clustered(n, args.usize_or("dim", 8)?, 16, 0.1, 8, seed),
        other => bail!("unknown kind {other}"),
    };
    if args.flag("stats") {
        print_stats(&ds);
    }
    let out = args.require("out")?;
    io::save(&ds, out)?;
    println!("wrote {} points to {out}", ds.n());
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    args.expect_known(&["data", "seed"])?;
    let seed = args.u64_or("seed", 1)?;
    let spec = DatasetSpec::parse(args.require("data")?, seed)?;
    let ds = build_dataset(&spec)?;
    print_stats(&ds);
    Ok(())
}

fn print_stats(ds: &matroid_coreset::core::Dataset) {
    println!("dataset         {}", ds.name);
    println!("n               {}", ds.n());
    println!("dim             {}", ds.dim);
    println!("metric          {}", ds.metric.name());
    println!("categories      {}", ds.n_categories);
    let hist = ds.category_histogram();
    let nonzero = hist.iter().filter(|&&c| c > 0).count();
    let maxc = hist.iter().copied().max().unwrap_or(0);
    println!("nonempty cats   {nonzero}");
    println!("largest cat     {maxc}");
    let avg =
        ds.categories.iter().map(|c| c.len()).sum::<usize>() as f64 / ds.n().max(1) as f64;
    println!("cats per point  {avg:.2}");
}

fn cmd_run(args: &Args) -> Result<()> {
    args.expect_known(&[
        "data", "algo", "k", "tau", "eps", "workers", "objective", "finisher", "gamma",
        "engine", "matroid", "seed", "second-round-tau",
    ])?;
    let seed = args.u64_or("seed", 1)?;
    let spec = DatasetSpec::parse(args.require("data")?, seed)?;
    let ds = build_dataset(&spec)?;
    let mspec = match args.opt("matroid") {
        Some(s) => MatroidSpec::parse(s)?,
        None => MatroidSpec::default_for(&spec),
    };
    let matroid = build_matroid(&mspec, &ds);
    let rank = matroid.rank_bound(&ds);
    let k = args.usize_or("k", (rank / 4).max(2))?;

    let budget = if let Some(eps) = args.opt("eps") {
        Budget::Epsilon(eps.parse().context("--eps")?)
    } else {
        Budget::Clusters(args.usize_or("tau", 64)?)
    };
    let setting = match args.str_or("algo", "seq") {
        "seq" => Setting::Seq { budget },
        "stream" => Setting::Stream {
            mode: match budget {
                Budget::Epsilon(e) => StreamMode::Epsilon(e),
                Budget::Clusters(t) => StreamMode::Tau(t),
            },
        },
        "mr" => Setting::MapReduce {
            workers: args.usize_or("workers", 4)?,
            budget,
            second_round_tau: match args.opt("second-round-tau") {
                Some(v) => Some(v.parse().context("--second-round-tau")?),
                None => None,
            },
        },
        "full" => Setting::Full,
        other => bail!("unknown --algo {other}"),
    };
    let objective = Objective::parse(args.str_or("objective", "sum"))
        .context("bad --objective")?;
    let finisher = match args.str_or("finisher", "local-search") {
        "local-search" | "ls" => Finisher::LocalSearch {
            gamma: args.f64_or("gamma", 0.0)?,
        },
        "exhaustive" => Finisher::Exhaustive,
        "greedy" => Finisher::Greedy,
        other => bail!("unknown --finisher {other}"),
    };
    let engine = EngineKind::parse(args.str_or("engine", EngineKind::default().name()))
        .context("bad --engine (batch|scalar|simd|pjrt)")?;

    println!(
        "run: data={} n={} matroid={} rank={} k={k} objective={} algo={:?} engine={}",
        ds.name,
        ds.n(),
        matroid.describe(),
        rank,
        objective.name(),
        setting,
        engine.name(),
    );
    let out = run_pipeline(
        &ds,
        &matroid,
        k,
        objective,
        Pipeline {
            setting,
            finisher,
            engine,
        },
        seed,
    )?;
    println!("diversity       {:.6}", out.diversity);
    println!("solution size   {}", out.solution.len());
    println!("coreset size    {}", out.coreset_size);
    println!("coreset time    {:.3}s", out.coreset_time.as_secs_f64());
    println!("finish time     {:.3}s", out.finish_time.as_secs_f64());
    println!("total time      {:.3}s", out.total_time().as_secs_f64());
    for (key, value) in &out.extra {
        println!("  {key} = {value}");
    }
    Ok(())
}

/// Config-driven experiment grid: algos x taus x seeds x k from a TOML
/// file (see configs/*.toml).
fn cmd_sweep(args: &Args) -> Result<()> {
    use matroid_coreset::bench::{time_once, Table};
    use matroid_coreset::config::Config;
    use matroid_coreset::csv_row;
    use matroid_coreset::util::csv::CsvWriter;

    args.expect_known(&["config", "csv"])?;
    let cfg = Config::load(args.require("config")?)?;
    let title = cfg.str_or("title", "sweep");

    // dataset + matroid
    let kind = cfg.str("dataset.kind")?;
    let n = cfg.usize("dataset.n")?;
    let base_seed = 1u64;
    let ds = match kind {
        "wikisim" => synth::wikisim(n, base_seed),
        "songsim" => synth::songsim(n, base_seed),
        "cube" => synth::uniform_cube(n, cfg.usize_or("dataset.dim", 8), base_seed),
        other => bail!("dataset.kind {other} unknown"),
    };
    let mspec = match kind {
        "wikisim" => MatroidSpec::Transversal,
        "songsim" => MatroidSpec::PartitionProportional { target_rank: 89 },
        _ => MatroidSpec::Uniform(cfg.usize_or("run.k", 8)),
    };
    let matroid = build_matroid(&mspec, &ds);
    let rank = matroid.rank_bound(&ds);

    let algos: Vec<String> = match cfg.get("sweep.algos") {
        Some(matroid_coreset::config::Value::List(items)) => items
            .iter()
            .map(|v| match v {
                matroid_coreset::config::Value::Str(s) => Ok(s.clone()),
                other => bail!("sweep.algos entry {other:?} not a string"),
            })
            .collect::<Result<_>>()?,
        _ => bail!("sweep.algos must be a list of strings"),
    };
    let taus = cfg.usize_list("sweep.taus")?;
    let seeds = cfg.usize_list("sweep.seeds")?;
    let k_fracs = cfg.usize_list("sweep.k_fractions")?;
    let objective =
        Objective::parse(cfg.str_or("run.objective", "sum")).context("run.objective")?;
    let finisher = match cfg.str_or("run.finisher", "local-search") {
        "local-search" => Finisher::LocalSearch {
            gamma: cfg.f64_or("run.gamma", 0.0),
        },
        "exhaustive" => Finisher::Exhaustive,
        "greedy" => Finisher::Greedy,
        other => bail!("run.finisher {other} unknown"),
    };
    let engine = EngineKind::parse(cfg.str_or("run.engine", EngineKind::default().name()))
        .context("run.engine")?;

    println!("sweep '{title}': {} n={} rank={rank}", ds.name, ds.n());
    let mut table =
        Table::new(&["algo", "tau", "k", "seed", "diversity", "coreset_s", "finish_s", "|T|"]);
    let mut csv = CsvWriter::create(
        args.str_or("csv", &format!("bench_results/sweep_{title}.csv")),
        &["algo", "tau", "k", "seed", "diversity", "coreset_s", "finish_s", "coreset_size"],
    )?;
    for algo in &algos {
        for &tau in &taus {
            for &frac in &k_fracs {
                let k = if frac == 0 {
                    cfg.usize_or("run.k", 8)
                } else {
                    (rank / frac).max(2)
                };
                for &seed in &seeds {
                    let setting = match algo.as_str() {
                        "seq" => Setting::Seq { budget: Budget::Clusters(tau) },
                        "stream" => Setting::Stream { mode: StreamMode::Tau(tau) },
                        "full" => Setting::Full,
                        mr if mr.starts_with("mr") => {
                            let workers: usize = mr[2..].parse().context("mrN algo")?;
                            Setting::MapReduce {
                                workers,
                                budget: Budget::Clusters((tau / workers).max(1)),
                                second_round_tau: None,
                            }
                        }
                        other => bail!("sweep algo {other} unknown"),
                    };
                    let (out, _) = time_once(|| {
                        run_pipeline(
                            &ds,
                            &matroid,
                            k,
                            objective,
                            Pipeline { setting, finisher, engine },
                            seed as u64,
                        )
                    });
                    let out = out?;
                    table.row(csv_row![
                        algo,
                        tau,
                        k,
                        seed,
                        format!("{:.4}", out.diversity),
                        format!("{:.3}", out.coreset_time.as_secs_f64()),
                        format!("{:.3}", out.finish_time.as_secs_f64()),
                        out.coreset_size
                    ]);
                    csv.row(&csv_row![
                        algo, tau, k, seed, out.diversity,
                        out.coreset_time.as_secs_f64(),
                        out.finish_time.as_secs_f64(),
                        out.coreset_size
                    ])?;
                }
            }
        }
    }
    csv.flush()?;
    table.print();
    Ok(())
}

/// Without the `pjrt` feature there is nothing to check against.
#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts_check(_args: &Args) -> Result<()> {
    bail!(
        "artifacts-check needs the PJRT backend; \
         rebuild with `cargo build --features pjrt` (and run `make artifacts`)"
    )
}

/// Compile every artifact and cross-check PJRT numerics vs the scalar oracle.
#[cfg(feature = "pjrt")]
fn cmd_artifacts_check(args: &Args) -> Result<()> {
    use matroid_coreset::runtime::engine::DistanceEngine;
    use matroid_coreset::runtime::{default_artifact_dir, Manifest, PjrtEngine, ScalarEngine};

    args.expect_known(&["data", "seed"])?;
    let seed = args.u64_or("seed", 1)?;
    let spec = DatasetSpec::parse(args.str_or("data", "wikisim:2000"), seed)?;
    let ds = build_dataset(&spec)?;
    let manifest = Manifest::load(default_artifact_dir())?;
    println!(
        "manifest ok: np={} tp={} tc={} entries={}",
        manifest.np,
        manifest.tp,
        manifest.tc,
        manifest.entries.len()
    );
    let engine = PjrtEngine::for_dataset(&manifest, &ds)?;
    println!("pjrt engine: platform={} padded_dim={}", engine.platform(), engine.padded_dim());

    // cross-check update_min against the scalar engine on a few centers
    let scalar = ScalarEngine::new();
    let n = ds.n();
    let mut mind_p = vec![f32::INFINITY; n];
    let mut arg_p = vec![u32::MAX; n];
    let mut mind_s = vec![f32::INFINITY; n];
    let mut arg_s = vec![u32::MAX; n];
    for (id, &c) in [0usize, n / 3, n / 2, n - 1].iter().enumerate() {
        engine.update_min(&ds, c, id as u32, &mut mind_p, &mut arg_p)?;
        scalar.update_min(&ds, c, id as u32, &mut mind_s, &mut arg_s)?;
    }
    let mut max_err = 0.0f64;
    for i in 0..n {
        max_err = max_err.max((mind_p[i] as f64 - mind_s[i] as f64).abs());
    }
    println!("update_min max |pjrt - scalar| = {max_err:.3e}");
    if max_err > 1e-3 {
        bail!("artifact numerics diverge from scalar oracle");
    }
    println!("artifacts-check OK");
    Ok(())
}
