//! `dmmc` — the CLI launcher for the matroid-coreset system.
//!
//! Subcommands:
//!
//! * `gen-data`        — generate a synthetic dataset to a `.dmmc` file
//! * `stats`           — Table-2-style dataset statistics
//! * `run`             — full pipeline: coreset setting + finisher
//! * `sweep`           — config-driven experiment grid (configs/*.toml)
//! * `artifacts-check` — load + smoke-run the AOT artifacts vs the scalar oracle
//! * `help`            — usage
//!
//! Examples:
//!
//! ```text
//! dmmc gen-data --kind wikisim --n 100000 --seed 1 --out wiki.dmmc
//! dmmc run --data wikisim:20000 --algo seq --tau 64 --k 25 --finisher local-search
//! dmmc run --data songsim:20000 --algo mr --workers 8 --tau 64 --k 22
//! dmmc run --data cube:5000x8 --algo stream --tau 32 --k 6 --objective tree --finisher exhaustive
//! dmmc run --data cube:5000x8 --algo seq --tau 32 --k 6 --objective remote-edge --finisher matching
//! ```

use anyhow::{bail, Context, Result};

use matroid_coreset::algo::Budget;
use matroid_coreset::cli::{parse_rows, Args};
use matroid_coreset::coordinator::{
    build_dataset, build_matroid, run_pipeline, DatasetSpec, Finisher, MatroidSpec, Pipeline,
    Setting,
};
use matroid_coreset::data::{io, synth};
use matroid_coreset::diversity::Objective;
use matroid_coreset::index::{
    store, CoresetIndex, IndexConfig, IndexSnapshot, LeafIngest, QueryFinisher, QueryService,
    QuerySpec, RetentionPolicy, DEFAULT_REBUILD_THRESHOLD,
};
use matroid_coreset::matroid::Matroid;
use matroid_coreset::obs;
use matroid_coreset::runtime::EngineKind;
use matroid_coreset::serve::{self, ServeState};
use matroid_coreset::streaming::StreamMode;

const USAGE: &str = "\
dmmc — coreset-based diversity maximization under matroid constraints

USAGE: dmmc <subcommand> [options]

SUBCOMMANDS
  gen-data   --kind wikisim|songsim|cube|clustered --n N [--seed S] --out F [--stats]
  stats      --data <file|kind:n>
  run        --data <file|kind:n> --algo seq|stream|mr|index|full
             [--k K] [--tau T | --eps E] [--workers L] [--segment N]
             [--objective sum|star|tree|cycle|bipartition|remote-edge]
             [--finisher local-search|exhaustive|greedy|matching] [--gamma G]
             [--engine batch|scalar|simd|pjrt] [--matroid transversal|partition:R|uniform:R]
             [--seed S] [--trace out.jsonl] [--metrics-out out.prom]
  index      build  --data <file|kind:n> --out F.dmmcx [--k K] [--tau T] [--segment N]
                    [--count C] [--ingest seq|stream] [--engine E] [--matroid M] [--seed S]
                    [--retention keep-all|last:W|ttl:E] [--rebuild-threshold F]
             append --index F.dmmcx [--count C] [--segment N]
             delete --index F.dmmcx --rows N,A..B,... (tombstones rows; A..B is half-open)
             query  --index F.dmmcx [--objective O] [--k K] [--finisher F] [--gamma G]
                    [--engine E] [--matroid M] [--repeat R]
             (every index action also accepts --trace out.jsonl)
  serve      [name=F.dmmcx ...] [--index name=F.dmmcx,name2=G.dmmcx]
             [--listen HOST:PORT] [--workers N] [--cache-cap N]
             [--replay <ops.txt|synth:N>] [--threads N] [--csv out.csv] [--seed S]
             [--trace out.jsonl]
             (tenant specs go before any flags; --replay runs the load
              harness in-process and exits instead of listening; replay also
              writes BENCH_serve.json next to the CSV)
             wire protocol, one line per request, replies `OK ...`/`ERR ...`:
               PING | TENANTS | LOAD n F | UNLOAD n | STATS n | SAVE n
               QUERY n <objective> <k> [finisher=F] [gamma=G] [engine=E] [matroid=M]
               APPEND n [count] [segment=N] | DELETE n <rows> | DEBUG n panic | QUIT | SHUTDOWN
               METRICS (multi-line: Prometheus text exposition, ends `# EOF`)
  sweep      --config configs/<file>.toml [--csv out.csv]
  artifacts-check  [--data <kind:n>]
  help
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    match run(argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "gen-data" => cmd_gen_data(&args),
        "stats" => cmd_stats(&args),
        "run" => cmd_run(&args),
        "index" => cmd_index(&args),
        "serve" => cmd_serve(&args),
        "sweep" => cmd_sweep(&args),
        "artifacts-check" => cmd_artifacts_check(&args),
        "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other}\n{USAGE}"),
    }
}

/// When `--trace F` is present, switch the span ring on for this process
/// and return the output path for the matching [`trace_finish`] drain.
fn trace_enable(args: &Args) -> Option<String> {
    let path = args.opt("trace")?.to_string();
    obs::trace::enable(obs::trace::DEFAULT_RING_CAPACITY);
    Some(path)
}

/// Drain the span ring to JSONL (no-op when `--trace` was not given).
fn trace_finish(path: &Option<String>) -> Result<()> {
    let Some(path) = path else { return Ok(()) };
    let (written, dropped) = obs::trace::write_jsonl(path)?;
    obs::trace::disable();
    if dropped > 0 {
        println!("trace: wrote {written} spans to {path} ({dropped} dropped by ring overflow)");
    } else {
        println!("trace: wrote {written} spans to {path}");
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    args.expect_known(&["kind", "n", "seed", "out", "stats", "dim"])?;
    let kind = args.require("kind")?;
    let n = args.usize_or("n", 10_000)?;
    let seed = args.u64_or("seed", 1)?;
    let ds = match kind {
        "wikisim" => synth::wikisim(n, seed),
        "songsim" => synth::songsim(n, seed),
        "cube" => synth::uniform_cube(n, args.usize_or("dim", 8)?, seed),
        "clustered" => synth::clustered(n, args.usize_or("dim", 8)?, 16, 0.1, 8, seed),
        other => bail!("unknown kind {other}"),
    };
    if args.flag("stats") {
        print_stats(&ds);
    }
    let out = args.require("out")?;
    io::save(&ds, out)?;
    println!("wrote {} points to {out}", ds.n());
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    args.expect_known(&["data", "seed"])?;
    let seed = args.u64_or("seed", 1)?;
    let spec = DatasetSpec::parse(args.require("data")?, seed)?;
    let ds = build_dataset(&spec)?;
    print_stats(&ds);
    Ok(())
}

fn print_stats(ds: &matroid_coreset::core::Dataset) {
    println!("dataset         {}", ds.name);
    println!("n               {}", ds.n());
    println!("dim             {}", ds.dim);
    println!("metric          {}", ds.metric.name());
    println!("categories      {}", ds.n_categories);
    let hist = ds.category_histogram();
    let nonzero = hist.iter().filter(|&&c| c > 0).count();
    let maxc = hist.iter().copied().max().unwrap_or(0);
    println!("nonempty cats   {nonzero}");
    println!("largest cat     {maxc}");
    let avg =
        ds.categories.iter().map(|c| c.len()).sum::<usize>() as f64 / ds.n().max(1) as f64;
    println!("cats per point  {avg:.2}");
}

fn cmd_run(args: &Args) -> Result<()> {
    args.expect_known(&[
        "data", "algo", "k", "tau", "eps", "workers", "segment", "objective", "finisher",
        "gamma", "engine", "matroid", "seed", "second-round-tau", "trace", "metrics-out",
    ])?;
    let trace = trace_enable(args);
    let seed = args.u64_or("seed", 1)?;
    let spec = DatasetSpec::parse(args.require("data")?, seed)?;
    let ds = build_dataset(&spec)?;
    let mspec = match args.opt("matroid") {
        Some(s) => MatroidSpec::parse(s)?,
        None => MatroidSpec::default_for(&spec),
    };
    let matroid = build_matroid(&mspec, &ds);
    let rank = matroid.rank_bound(&ds);
    let k = args.usize_or("k", (rank / 4).max(2))?;

    let budget = if let Some(eps) = args.opt("eps") {
        Budget::Epsilon(eps.parse().context("--eps")?)
    } else {
        Budget::Clusters(args.usize_or("tau", 64)?)
    };
    let setting = match args.str_or("algo", "seq") {
        "seq" => Setting::Seq { budget },
        "stream" => Setting::Stream {
            mode: match budget {
                Budget::Epsilon(e) => StreamMode::Epsilon(e),
                Budget::Clusters(t) => StreamMode::Tau(t),
            },
        },
        "mr" => Setting::MapReduce {
            workers: args.usize_or("workers", 4)?,
            budget,
            second_round_tau: match args.opt("second-round-tau") {
                Some(v) => Some(v.parse().context("--second-round-tau")?),
                None => None,
            },
        },
        "index" => Setting::Index {
            segment_size: args.usize_or("segment", (ds.n() / 8).max(1))?,
            budget,
        },
        "full" => Setting::Full,
        other => bail!("unknown --algo {other}"),
    };
    let objective = Objective::parse(args.str_or("objective", "sum"))
        .with_context(|| format!("bad --objective (valid: {})", Objective::names()))?;
    let finisher = match args.str_or("finisher", "local-search") {
        "local-search" | "ls" => Finisher::LocalSearch {
            gamma: args.f64_or("gamma", 0.0)?,
        },
        "exhaustive" => Finisher::Exhaustive,
        "greedy" => Finisher::Greedy,
        "matching" => Finisher::Matching,
        other => bail!("unknown --finisher {other} (local-search|exhaustive|greedy|matching)"),
    };
    let engine = EngineKind::parse(args.str_or("engine", EngineKind::default().name()))
        .context("bad --engine (batch|scalar|simd|pjrt)")?;

    println!(
        "run: data={} n={} matroid={} rank={} k={k} objective={} algo={:?} engine={}",
        ds.name,
        ds.n(),
        matroid.describe(),
        rank,
        objective.name(),
        setting,
        engine.name(),
    );
    let out = run_pipeline(
        &ds,
        &matroid,
        k,
        objective,
        Pipeline {
            setting,
            finisher,
            engine,
        },
        seed,
    )?;
    println!("diversity       {:.6}", out.diversity);
    println!("solution size   {}", out.solution.len());
    println!("coreset size    {}", out.coreset_size);
    println!("coreset time    {:.3}s", out.coreset_time.as_secs_f64());
    println!("finish time     {:.3}s", out.finish_time.as_secs_f64());
    println!("total time      {:.3}s", out.total_time().as_secs_f64());
    for (key, value) in &out.extra {
        println!("  {key} = {value}");
    }
    trace_finish(&trace)?;
    if let Some(path) = args.opt("metrics-out") {
        let text = obs::MetricsRegistry::global().render_prometheus();
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, &text).with_context(|| format!("write {path}"))?;
        println!("metrics: wrote {} lines to {path}", text.lines().count());
    }
    Ok(())
}

/// The composable coreset index service: `index build` constructs a tree
/// over a prefix of the dataset and persists it, `index append` ingests
/// further segments into the persisted tree (touching O(log segments)
/// nodes), `index delete` tombstones rows (epoch bump, threshold-driven
/// rebuilds), `index query` answers (objective, k, matroid, engine)
/// requests from the root coreset only.  The result cache lives
/// in-process, so `--repeat R` demonstrates hit behavior within one
/// invocation.
fn cmd_index(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .map(|s| s.as_str())
        .context("index needs an action: build | append | delete | query (before any flags)")?;
    // --trace is handled once here so every action gets it for free; the
    // per-action expect_known lists still name it as a known flag
    let trace = trace_enable(args);
    let res = match action {
        "build" => cmd_index_build(args),
        "append" => cmd_index_append(args),
        "delete" => cmd_index_delete(args),
        "query" => cmd_index_query(args),
        other => bail!("unknown index action {other} (build | append | delete | query)"),
    };
    trace_finish(&trace)?;
    res
}

/// The multi-tenant serving front end (see `rust/src/serve/`): load the
/// named indexes, then either run the in-process load-replay harness
/// (`--replay`) or listen for protocol connections until `SHUTDOWN`.
fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_known(&[
        "index", "listen", "workers", "cache-cap", "replay", "threads", "csv", "seed", "trace",
    ])?;
    let trace = trace_enable(args);
    let state = ServeState::new(
        args.usize_or("cache-cap", matroid_coreset::index::DEFAULT_CACHE_CAPACITY)?,
    );
    let mut specs: Vec<String> = args.positional.clone();
    if let Some(list) = args.opt("index") {
        specs.extend(list.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()));
    }
    if specs.is_empty() {
        bail!(
            "serve needs at least one index: `name=path` positionals (before any flags) \
             or --index name=path[,name=path...]"
        );
    }
    for spec in &specs {
        let (name, path) = match spec.split_once('=') {
            Some((n, p)) => (n.to_string(), p.to_string()),
            None => {
                let stem = std::path::Path::new(spec)
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .with_context(|| format!("no tenant name derivable from {spec}"))?;
                (stem.to_string(), spec.clone())
            }
        };
        let tenant = state.load(&name, std::path::Path::new(&path))?;
        let st = tenant.status();
        println!(
            "loaded tenant={} from {path} (epoch={} segments={} root={} warm={})",
            st.name, st.epoch, st.segments, st.root, st.cache_len,
        );
    }
    if let Some(source) = args.opt("replay") {
        let threads = args.usize_or("threads", serve::DEFAULT_WORKERS)?;
        let seed = args.u64_or("seed", 1)?;
        let report = serve::replay::run_replay(&state, source, threads, seed)?;
        print!("{}", serve::replay::render_report(&report));
        let csv = args.str_or("csv", "bench_results/serve_load.csv");
        serve::replay::write_replay_csv(csv, &report)?;
        println!("wrote {csv}");
        let bench = match std::path::Path::new(csv).parent() {
            Some(dir) if !dir.as_os_str().is_empty() => {
                dir.join("BENCH_serve.json").to_string_lossy().into_owned()
            }
            _ => "BENCH_serve.json".to_string(),
        };
        serve::replay::write_replay_bench_json(&bench, &report, state.metrics())?;
        println!("wrote {bench}");
        return trace_finish(&trace);
    }
    let listen = args.str_or("listen", "127.0.0.1:7466");
    let workers = args.usize_or("workers", serve::DEFAULT_WORKERS)?;
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("bind {listen}"))?;
    println!(
        "dmmc serve: listening on {} ({workers} workers, {} tenant(s))",
        listener.local_addr()?,
        state.names().len(),
    );
    serve::server::serve(&state, listener, workers)?;
    trace_finish(&trace)
}

fn cmd_index_build(args: &Args) -> Result<()> {
    args.expect_known(&[
        "data", "out", "k", "tau", "eps", "segment", "count", "ingest", "engine", "matroid",
        "seed", "retention", "rebuild-threshold", "trace",
    ])?;
    let seed = args.u64_or("seed", 1)?;
    let data = args.require("data")?.to_string();
    let spec = DatasetSpec::parse(&data, seed)?;
    let ds = build_dataset(&spec)?;
    let matroid_str = match args.opt("matroid") {
        Some(s) => {
            MatroidSpec::parse(s)?; // validate now, store the shorthand
            s.to_string()
        }
        // exhaustive on purpose: if default_for grows or changes a
        // variant, the compiler forces this snapshot recipe to follow so
        // `index build` and `run` keep defaulting to the same matroid
        None => match MatroidSpec::default_for(&spec) {
            MatroidSpec::Transversal => "transversal".to_string(),
            MatroidSpec::PartitionProportional { target_rank } => {
                format!("partition:{target_rank}")
            }
            MatroidSpec::Uniform(r) => format!("uniform:{r}"),
            MatroidSpec::PartitionCaps(_) => {
                bail!("explicit-caps matroids have no CLI shorthand; pass --matroid")
            }
        },
    };
    let matroid = build_matroid(&MatroidSpec::parse(&matroid_str)?, &ds);
    let rank = matroid.rank_bound(&ds);
    let k_max = args.usize_or("k", (rank / 4).max(2))?;
    let budget = if let Some(eps) = args.opt("eps") {
        Budget::Epsilon(eps.parse().context("--eps")?)
    } else {
        Budget::Clusters(args.usize_or("tau", 32)?)
    };
    let engine = EngineKind::parse(args.str_or("engine", EngineKind::default().name()))
        .context("bad --engine (batch|scalar|simd|pjrt)")?;
    let leaf_ingest = LeafIngest::parse(args.str_or("ingest", "seq"))
        .context("bad --ingest (seq|stream)")?;
    let count = args.usize_or("count", ds.n())?.min(ds.n());
    let segment = args.usize_or("segment", (count / 8).max(1))?.max(1);

    let retention = RetentionPolicy::parse(args.str_or("retention", "keep-all"))
        .context("bad --retention (keep-all | last:<w> | ttl:<epochs>)")?;
    let rebuild_threshold = args.f64_or("rebuild-threshold", DEFAULT_REBUILD_THRESHOLD)?;
    if !(0.0..=1.0).contains(&rebuild_threshold) {
        bail!("--rebuild-threshold must lie in [0, 1]");
    }

    let cfg = IndexConfig {
        k_max,
        leaf_budget: budget,
        reduce_budget: budget,
        engine,
        leaf_ingest,
        retention,
        rebuild_threshold,
    };
    let mut index = CoresetIndex::new(&ds, &*matroid, cfg);
    let order: Vec<usize> = (0..count).collect();
    let receipts = index.ingest(&order, segment)?;
    let out = args.require("out")?;
    let snap = IndexSnapshot::capture(&index, data, seed, matroid_str, count);
    store::save(&snap, out)?;
    println!(
        "index build: data={} n={} ingested={} segments={} k_max={k_max} engine={} retention={}",
        ds.name,
        ds.n(),
        count,
        index.segments(),
        engine.name(),
        retention.name(),
    );
    println!("root size       {}", index.root().len());
    println!("merges          {}", index.stats().merges);
    println!("dist evals      {}", index.stats().dist_evals);
    if let Some(last) = receipts.last() {
        println!("last append     touched {} nodes", last.nodes_touched);
    }
    println!("wrote {out}");
    Ok(())
}

fn cmd_index_append(args: &Args) -> Result<()> {
    args.expect_known(&["index", "count", "segment", "trace"])?;
    let path = args.require("index")?;
    let snap = store::load(path)?;
    let (ds, matroid) = store::snapshot_world(&snap)?;
    let remaining = ds.n().saturating_sub(snap.cursor);
    if remaining == 0 {
        bail!("index already covers all {} dataset rows", ds.n());
    }
    // over-asking clamps to the rows the dataset still has — and says so,
    // instead of silently ingesting fewer rows than requested
    let requested = args.usize_or("count", remaining)?;
    let count = requested.min(remaining);
    if requested > remaining {
        println!(
            "index append: requested {requested} rows, clamped to the {count} remaining \
             (dataset n = {})",
            ds.n(),
        );
    }
    let segment = args.usize_or("segment", count)?.max(1);
    let cfg = snap.config();
    let mut index = CoresetIndex::from_parts(&ds, &*matroid, cfg, snap.parts());
    let order: Vec<usize> = (snap.cursor..snap.cursor + count).collect();
    let receipts = index.ingest(&order, segment)?;
    let new_cursor = snap.cursor + count;
    let snap2 = IndexSnapshot::capture(&index, snap.data, snap.seed, snap.matroid, new_cursor);
    store::save(&snap2, path)?;
    println!(
        "index append: +{count} rows in {} segment(s) (epoch {} -> {})",
        receipts.len(),
        snap.epoch,
        index.epoch(),
    );
    for r in &receipts {
        println!(
            "  segment {:>4}: merges={} nodes_touched={} dist_evals={} root={}",
            r.segment, r.merges, r.nodes_touched, r.dist_evals, r.root_size
        );
    }
    Ok(())
}

fn cmd_index_delete(args: &Args) -> Result<()> {
    args.expect_known(&["index", "rows", "trace"])?;
    let path = args.require("index")?;
    let rows = parse_rows(args.require("rows")?)?;
    let snap = store::load(path)?;
    let (ds, matroid) = store::snapshot_world(&snap)?;
    let cfg = snap.config();
    let mut index = CoresetIndex::from_parts(&ds, &*matroid, cfg, snap.parts());
    let r = index.delete(&rows)?;
    let snap2 = IndexSnapshot::capture(&index, snap.data, snap.seed, snap.matroid, snap.cursor);
    store::save(&snap2, path)?;
    println!(
        "index delete: {} row(s) requested, {} newly dead (epoch {} -> {})",
        rows.len(),
        r.newly_dead,
        snap.epoch,
        index.epoch(),
    );
    println!(
        "  members_killed={} nodes_touched={} rebuilds={} dropped_levels={} expired={} \
         dist_evals={}",
        r.members_killed, r.nodes_touched, r.rebuilds, r.dropped_levels, r.expired, r.dist_evals
    );
    println!(
        "  root={} live_fraction={:.3} tombstones={}",
        r.root_size,
        index.live_fraction(),
        index.tombstones().len(),
    );
    Ok(())
}

fn cmd_index_query(args: &Args) -> Result<()> {
    args.expect_known(&[
        "index", "objective", "k", "finisher", "gamma", "engine", "matroid", "repeat", "trace",
    ])?;
    let path = args.require("index")?;
    let snap = store::load(path)?;
    let (ds, matroid) = store::snapshot_world(&snap)?;
    let cfg = snap.config();
    let index = CoresetIndex::from_parts(&ds, &*matroid, cfg, snap.parts());
    let mut service = QueryService::new(index);
    // warm from the persisted sidecar (ignored unless it matches this
    // exact snapshot), so repeated invocations hit across processes
    let sidecar = store::result_cache_path(path);
    let snap_id = store::snapshot_id(&snap);
    let warm = store::load_result_cache(&sidecar, snap_id);
    let n_warm = warm.len();
    service.warm_cache(warm);

    let objective = Objective::parse(args.str_or("objective", "sum"))
        .with_context(|| format!("bad --objective (valid: {})", Objective::names()))?;
    let default_finisher = if objective == Objective::Sum { "local-search" } else { "exhaustive" };
    let finisher = match args.str_or("finisher", default_finisher) {
        "local-search" | "ls" => QueryFinisher::LocalSearch {
            gamma: args.f64_or("gamma", 0.0)?,
        },
        "exhaustive" => QueryFinisher::Exhaustive,
        "greedy" => QueryFinisher::Greedy,
        "matching" => QueryFinisher::Matching,
        other => bail!("unknown --finisher {other} (local-search|exhaustive|greedy|matching)"),
    };
    let spec = QuerySpec {
        objective,
        k: args.usize_or("k", snap.k_max)?,
        matroid: match args.opt("matroid") {
            Some(s) => Some(MatroidSpec::parse(s)?),
            None => None,
        },
        engine: EngineKind::parse(args.str_or("engine", snap.engine.name()))
            .context("bad --engine")?,
        finisher,
    };
    let repeat = args.usize_or("repeat", 1)?.max(1);
    println!(
        "index query: epoch={} segments={} root={} warm={n_warm} spec={}",
        snap.epoch,
        snap.segments,
        service.index().root().len(),
        spec.cache_key(),
    );
    for i in 0..repeat {
        let out = service.query(&spec)?;
        println!(
            "  [{i}] diversity={:.6} |sol|={} coreset={} cache_hit={} dist_evals={} {:.3}ms",
            out.result.diversity,
            out.result.solution.len(),
            out.result.coreset_size,
            out.cache_hit,
            out.dist_evals.render(),
            out.elapsed.as_secs_f64() * 1e3,
        );
    }
    // persist the cache for the next invocation (queries never bump the
    // epoch, so every entry is current; the filter guards regardless)
    let entries: Vec<_> = service
        .cache_entries()
        .into_iter()
        .filter(|(_, epoch, _)| *epoch == snap.epoch)
        .collect();
    store::save_result_cache(&sidecar, snap_id, &entries)?;
    let st = service.stats();
    println!(
        "served {} queries: {} hits, {} misses, {} errors, {} evictions \
         (persisted {} cache entries)",
        st.queries,
        st.hits,
        st.misses,
        st.errors,
        st.evictions,
        entries.len(),
    );
    Ok(())
}

/// Config-driven experiment grid: algos x taus x seeds x k from a TOML
/// file (see configs/*.toml).
fn cmd_sweep(args: &Args) -> Result<()> {
    use matroid_coreset::bench::{time_once, Table};
    use matroid_coreset::config::Config;
    use matroid_coreset::csv_row;
    use matroid_coreset::util::csv::CsvWriter;

    args.expect_known(&["config", "csv"])?;
    let cfg = Config::load(args.require("config")?)?;
    let title = cfg.str_or("title", "sweep");

    // dataset + matroid
    let kind = cfg.str("dataset.kind")?;
    let n = cfg.usize("dataset.n")?;
    let base_seed = 1u64;
    let ds = match kind {
        "wikisim" => synth::wikisim(n, base_seed),
        "songsim" => synth::songsim(n, base_seed),
        "cube" => synth::uniform_cube(n, cfg.usize_or("dataset.dim", 8), base_seed),
        other => bail!("dataset.kind {other} unknown"),
    };
    let mspec = match kind {
        "wikisim" => MatroidSpec::Transversal,
        "songsim" => MatroidSpec::PartitionProportional { target_rank: 89 },
        _ => MatroidSpec::Uniform(cfg.usize_or("run.k", 8)),
    };
    let matroid = build_matroid(&mspec, &ds);
    let rank = matroid.rank_bound(&ds);

    let algos: Vec<String> = match cfg.get("sweep.algos") {
        Some(matroid_coreset::config::Value::List(items)) => items
            .iter()
            .map(|v| match v {
                matroid_coreset::config::Value::Str(s) => Ok(s.clone()),
                other => bail!("sweep.algos entry {other:?} not a string"),
            })
            .collect::<Result<_>>()?,
        _ => bail!("sweep.algos must be a list of strings"),
    };
    let taus = cfg.usize_list("sweep.taus")?;
    let seeds = cfg.usize_list("sweep.seeds")?;
    let k_fracs = cfg.usize_list("sweep.k_fractions")?;
    let objective = Objective::parse(cfg.str_or("run.objective", "sum"))
        .with_context(|| format!("run.objective (valid: {})", Objective::names()))?;
    let finisher = match cfg.str_or("run.finisher", "local-search") {
        "local-search" => Finisher::LocalSearch {
            gamma: cfg.f64_or("run.gamma", 0.0),
        },
        "exhaustive" => Finisher::Exhaustive,
        "greedy" => Finisher::Greedy,
        "matching" => Finisher::Matching,
        other => bail!("run.finisher {other} unknown (local-search|exhaustive|greedy|matching)"),
    };
    let engine = EngineKind::parse(cfg.str_or("run.engine", EngineKind::default().name()))
        .context("run.engine")?;

    println!("sweep '{title}': {} n={} rank={rank}", ds.name, ds.n());
    let mut table =
        Table::new(&["algo", "tau", "k", "seed", "diversity", "coreset_s", "finish_s", "|T|"]);
    let mut csv = CsvWriter::create(
        args.str_or("csv", &format!("bench_results/sweep_{title}.csv")),
        &["algo", "tau", "k", "seed", "diversity", "coreset_s", "finish_s", "coreset_size"],
    )?;
    for algo in &algos {
        for &tau in &taus {
            for &frac in &k_fracs {
                let k = if frac == 0 {
                    cfg.usize_or("run.k", 8)
                } else {
                    (rank / frac).max(2)
                };
                for &seed in &seeds {
                    let setting = match algo.as_str() {
                        "seq" => Setting::Seq { budget: Budget::Clusters(tau) },
                        "stream" => Setting::Stream { mode: StreamMode::Tau(tau) },
                        "index" => Setting::Index {
                            segment_size: (ds.n() / 8).max(1),
                            budget: Budget::Clusters(tau),
                        },
                        "full" => Setting::Full,
                        mr if mr.starts_with("mr") => {
                            let workers: usize = mr[2..].parse().context("mrN algo")?;
                            Setting::MapReduce {
                                workers,
                                budget: Budget::Clusters((tau / workers).max(1)),
                                second_round_tau: None,
                            }
                        }
                        other => bail!("sweep algo {other} unknown"),
                    };
                    let (out, _) = time_once(|| {
                        run_pipeline(
                            &ds,
                            &matroid,
                            k,
                            objective,
                            Pipeline { setting, finisher, engine },
                            seed as u64,
                        )
                    });
                    let out = out?;
                    table.row(csv_row![
                        algo,
                        tau,
                        k,
                        seed,
                        format!("{:.4}", out.diversity),
                        format!("{:.3}", out.coreset_time.as_secs_f64()),
                        format!("{:.3}", out.finish_time.as_secs_f64()),
                        out.coreset_size
                    ]);
                    csv.row(&csv_row![
                        algo, tau, k, seed, out.diversity,
                        out.coreset_time.as_secs_f64(),
                        out.finish_time.as_secs_f64(),
                        out.coreset_size
                    ])?;
                }
            }
        }
    }
    csv.flush()?;
    table.print();
    Ok(())
}

/// Without the `pjrt` feature there is nothing to check against.
#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts_check(_args: &Args) -> Result<()> {
    bail!(
        "artifacts-check needs the PJRT backend; \
         rebuild with `cargo build --features pjrt` (and run `make artifacts`)"
    )
}

/// Compile every artifact and cross-check PJRT numerics vs the scalar oracle.
#[cfg(feature = "pjrt")]
fn cmd_artifacts_check(args: &Args) -> Result<()> {
    use matroid_coreset::runtime::engine::DistanceEngine;
    use matroid_coreset::runtime::{default_artifact_dir, Manifest, PjrtEngine, ScalarEngine};

    args.expect_known(&["data", "seed"])?;
    let seed = args.u64_or("seed", 1)?;
    let spec = DatasetSpec::parse(args.str_or("data", "wikisim:2000"), seed)?;
    let ds = build_dataset(&spec)?;
    let manifest = Manifest::load(default_artifact_dir())?;
    println!(
        "manifest ok: np={} tp={} tc={} entries={}",
        manifest.np,
        manifest.tp,
        manifest.tc,
        manifest.entries.len()
    );
    let engine = PjrtEngine::for_dataset(&manifest, &ds)?;
    println!("pjrt engine: platform={} padded_dim={}", engine.platform(), engine.padded_dim());

    // cross-check update_min against the scalar engine on a few centers
    let scalar = ScalarEngine::new();
    let n = ds.n();
    let mut mind_p = vec![f32::INFINITY; n];
    let mut arg_p = vec![u32::MAX; n];
    let mut mind_s = vec![f32::INFINITY; n];
    let mut arg_s = vec![u32::MAX; n];
    for (id, &c) in [0usize, n / 3, n / 2, n - 1].iter().enumerate() {
        engine.update_min(&ds, c, id as u32, &mut mind_p, &mut arg_p)?;
        scalar.update_min(&ds, c, id as u32, &mut mind_s, &mut arg_s)?;
    }
    let mut max_err = 0.0f64;
    for i in 0..n {
        max_err = max_err.max((mind_p[i] as f64 - mind_s[i] as f64).abs());
    }
    println!("update_min max |pjrt - scalar| = {max_err:.3e}");
    if max_err > 1e-3 {
        bail!("artifact numerics diverge from scalar oracle");
    }
    println!("artifacts-check OK");
    Ok(())
}
