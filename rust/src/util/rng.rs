//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** generation.
//!
//! The offline image ships no `rand` crate, so the repo carries its own
//! small, well-known generator.  Determinism matters for the experiments:
//! every bench records its seed, and paper Figure 2/3 boxplots are produced
//! from seeded random permutations.

/// xoshiro256** by Blackman & Vigna (public domain reference code).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the full 256-bit state from a single u64 via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 <= f64::MIN_POSITIVE {
            u1 = f64::MIN_POSITIVE;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `m` distinct indices from 0..n (m <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        let mut p = self.permutation(n);
        p.truncate(m);
        p
    }

    /// Zipf-like draw over [0, n) with exponent `s` (s=0 -> uniform).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // inverse-CDF on the truncated zeta distribution; n is small (#categories)
        let mut total = 0.0;
        for i in 1..=n {
            total += 1.0 / (i as f64).powf(s);
        }
        let target = self.f64() * total;
        let mut acc = 0.0;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(s);
            if acc >= target {
                return i - 1;
            }
        }
        n - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(6);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(8);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[9] * 2, "{counts:?}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(9);
        let mut a = parent.fork();
        let mut b = parent.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
