//! Small summary-statistics helpers used by the bench harness and the
//! experiment reports (boxplot quantiles of Figures 2-3).

/// Summary of a sample: mean / std / min / quartiles / max.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub max: f64,
}

/// Linear-interpolation quantile on a sorted slice (type-7, numpy default).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p25: quantile_sorted(&sorted, 0.25),
            p50: quantile_sorted(&sorted, 0.50),
            p75: quantile_sorted(&sorted, 0.75),
            max: sorted[n - 1],
        }
    }

    /// One-line boxplot-style rendering.
    pub fn render(&self) -> String {
        format!(
            "n={} mean={:.4} std={:.4} min={:.4} p25={:.4} p50={:.4} p75={:.4} max={:.4}",
            self.n, self.mean, self.std, self.min, self.p25, self.p50, self.p75, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant() {
        let s = Summary::of(&[2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn summary_simple() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantiles_match_numpy_type7() {
        let sorted = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile_sorted(&sorted, 0.0), 1.0);
        assert_eq!(quantile_sorted(&sorted, 1.0), 5.0);
        assert_eq!(quantile_sorted(&sorted, 0.5), 3.0);
        assert!((quantile_sorted(&sorted, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }
}
