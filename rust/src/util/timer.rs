//! Wall-clock timing + phase breakdown accounting.
//!
//! The paper reports per-phase runtime breakdowns (coreset construction vs
//! local search, Figures 1-3); `PhaseTimer` is the single accounting object
//! threaded through all algorithms so benches and the CLI report identical
//! breakdowns.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Measure one closure.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// A started wall-clock timer for latency accounting in code that must
/// not touch ambient time directly (dmmc-lint L4): the `Instant::now`
/// call stays inside this blessed module, and callers — the serve
/// tenants' per-query `elapsed` stamp, most prominently — only ever
/// *read* the elapsed duration, never branch on it.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { t0: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.t0.elapsed()
    }
}

/// Named-phase wall-clock accumulator.
#[derive(Default, Debug, Clone)]
pub struct PhaseTimer {
    phases: BTreeMap<String, Duration>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f` accounted under `phase` (accumulates across calls).  Also
    /// opens an identically named trace span, so every `PhaseTimer` user
    /// shows up in `--trace` output for free (inert unless tracing is on).
    pub fn phase<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let _span = crate::obs::trace::span(phase);
        let (out, dt) = time_it(f);
        self.add(phase, dt);
        out
    }

    pub fn add(&mut self, phase: &str, dt: Duration) {
        *self.phases.entry(phase.to_string()).or_default() += dt;
    }

    pub fn get(&self, phase: &str) -> Duration {
        self.phases.get(phase).copied().unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.phases.values().sum()
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.phases {
            self.add(k, *v);
        }
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, Duration)> {
        self.phases.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn render(&self) -> String {
        let mut parts: Vec<String> = self
            .phases
            .iter()
            .map(|(k, v)| format!("{k}={:.3}s", v.as_secs_f64()))
            .collect();
        parts.push(format!("total={:.3}s", self.total().as_secs_f64()));
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_phases() {
        let mut t = PhaseTimer::new();
        t.phase("a", || std::thread::sleep(Duration::from_millis(2)));
        t.phase("a", || std::thread::sleep(Duration::from_millis(2)));
        t.phase("b", || ());
        assert!(t.get("a") >= Duration::from_millis(4));
        assert!(t.total() >= t.get("a"));
        assert_eq!(t.get("missing"), Duration::ZERO);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let a = sw.elapsed();
        assert!(a >= Duration::from_millis(2));
        assert!(sw.elapsed() >= a);
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimer::new();
        a.add("x", Duration::from_millis(5));
        let mut b = PhaseTimer::new();
        b.add("x", Duration::from_millis(7));
        b.add("y", Duration::from_millis(1));
        a.merge(&b);
        assert_eq!(a.get("x"), Duration::from_millis(12));
        assert_eq!(a.get("y"), Duration::from_millis(1));
    }
}
