//! Minimal CSV writer for bench results (`bench_results/*.csv`).

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create `path` (parent dirs included) and write the header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<CsvWriter> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            columns: header.len(),
        })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        assert_eq!(
            fields.len(),
            self.columns,
            "CSV row width mismatch: {} vs header {}",
            fields.len(),
            self.columns
        );
        writeln!(self.out, "{}", fields.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Convenience macro for building a CSV row out of Display-able values.
#[macro_export]
macro_rules! csv_row {
    ($($x:expr),* $(,)?) => {
        vec![$(format!("{}", $x)),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("mc_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&csv_row![1, 2.5]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let dir = std::env::temp_dir().join("mc_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        w.row(&csv_row![1]).unwrap();
    }
}
