//! Support utilities: PRNG, summary statistics, phase timing, CSV output.

pub mod csv;
pub mod rng;
pub mod stats;
pub mod timer;
