//! Support utilities: PRNG, summary statistics, phase timing, CSV output,
//! and the shared string hash behind name-derived seeds.

pub mod csv;
pub mod rng;
pub mod stats;
pub mod timer;

/// FNV-1a over a string — the stable hash behind every name-derived seed
/// (the proptest harness's per-property seeds, the query service's
/// per-spec finisher seeds).  One implementation so the two can never
/// drift apart.
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a_is_stable_and_input_sensitive() {
        // pinned: changing these constants would silently reseed every
        // name-derived RNG in the tree
        assert_eq!(super::fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(super::fnv1a("a"), super::fnv1a("b"));
        assert_eq!(super::fnv1a("spec"), super::fnv1a("spec"));
    }
}
