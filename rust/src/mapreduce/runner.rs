//! The MapReduce simulator runner.

use std::time::Duration;

use anyhow::Result;

use crate::algo::seq_coreset::seq_coreset;
use crate::algo::{Budget, Coreset};
use crate::core::Dataset;
use crate::diversity::sum_diversity_with_engine;
use crate::matroid::Matroid;
use crate::runtime::{build_engine, build_engine_with_threads, EngineKind};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Configuration of one MR coreset job.
#[derive(Clone, Copy, Debug)]
pub struct MapReduceConfig {
    /// Degree of parallelism `ell` (shards == worker threads).
    pub workers: usize,
    /// Per-worker coreset budget.  The paper's Fig. 3 setup fixes a global
    /// tau and gives each worker `tau / ell` clusters; express that here by
    /// passing `Budget::Clusters(tau / ell)`.
    pub budget: Budget,
    /// Optional round-2 re-compression: run SeqCoreset with this cluster
    /// budget on the round-1 union (paper §4.4.2).
    pub second_round_tau: Option<usize>,
    /// Seed for the arbitrary (random) partition of `S`.
    pub seed: u64,
    /// Backend for the per-shard engines (and the round-2 engine) —
    /// `run_pipeline` threads `Pipeline::engine` through here, so the
    /// MapReduce setting rides the same A/B flag as every other scenario.
    pub engine: EngineKind,
}

/// Outcome + accounting of an MR run.
#[derive(Clone, Debug)]
pub struct MrReport {
    /// The final coreset (indices into the input dataset).
    pub coreset: Coreset,
    /// MR rounds used (1, or 2 with re-compression).
    pub rounds: usize,
    /// Max shard size = the paper's local-memory bound `M_L` for round 1.
    pub local_memory_points: usize,
    /// Per-worker wall-clock times (round 1).
    pub worker_times: Vec<Duration>,
    /// Simulated cluster makespan: max over worker times.
    pub makespan_round1: Duration,
    /// Wall-clock of the whole job as actually executed (threads overlap).
    pub wall_time: Duration,
    /// Per-worker coreset sizes.
    pub shard_coreset_sizes: Vec<usize>,
    /// Per-worker coreset sum-diversities — reducer-side quality
    /// accounting, scored through each shard's engine (one batched sums
    /// pass per shard; detects skewed shards before the finisher runs).
    pub shard_coreset_diversities: Vec<f64>,
    /// Per-worker distance evaluations spent on that scoring pass
    /// (`|T_j| * (|T_j| - 1)` each) — the same engine-work ledger the
    /// local-search finisher reports via `LocalSearchResult::dist_evals`,
    /// so end-to-end pipelines can account every batched distance pass.
    pub shard_score_dist_evals: Vec<u64>,
    /// Per-worker distance evaluations spent *building* the shard coreset
    /// (the GMM folds: one `update_min` of the shard per selected center,
    /// so `n_clusters_j * |shard_j|` each).  Previously this — the bulk of
    /// the MR distance work — was silently dropped from the pipeline
    /// extras while only the scoring pass was reported.
    pub shard_coreset_dist_evals: Vec<u64>,
    /// Distance evaluations of the optional round-2 re-compression
    /// (`n_clusters_2 * |union|`; 0 without a second round).
    pub round2_dist_evals: u64,
}

/// Build a coreset of `ds` in (simulated) MapReduce.
pub fn mr_coreset<M: Matroid + Sync>(
    ds: &Dataset,
    m: &M,
    k: usize,
    cfg: MapReduceConfig,
) -> Result<MrReport> {
    assert!(cfg.workers >= 1);
    let sw = Stopwatch::start();
    let n = ds.n();
    // map phase: random even partition into `workers` shards
    let mut rng = Rng::new(cfg.seed);
    let perm = rng.permutation(n);
    let mut shards: Vec<Vec<usize>> = vec![Vec::with_capacity(n / cfg.workers + 1); cfg.workers];
    for (pos, &idx) in perm.iter().enumerate() {
        shards[pos % cfg.workers].push(idx);
    }
    let local_memory_points = shards.iter().map(|s| s.len()).max().unwrap_or(0);

    // reduce phase, one thread per shard; each worker builds its own
    // engine (the DistanceEngine contract is per-thread construction, not
    // sharing) with the machine's cores divided between the shards so the
    // engines' scoped fan-out does not oversubscribe
    let machine = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    let threads_per_shard = (machine / cfg.workers).max(1);
    type ShardOut = Result<(Vec<usize>, Coreset, f64, Duration)>;
    let results: Vec<ShardOut> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .iter()
            .map(|shard| {
                scope.spawn(move || -> ShardOut {
                    let w0 = Stopwatch::start();
                    let local = ds.subset(shard);
                    let engine = build_engine_with_threads(cfg.engine, &local, threads_per_shard)?;
                    let engine = &*engine;
                    let cs = seq_coreset(&local, m, k, cfg.budget, engine)?;
                    // reducer-side accounting: score the shard coreset
                    // through the same engine before handing it upstream
                    let shard_div = sum_diversity_with_engine(&local, &cs.indices, engine)?;
                    // map local coreset indices back to global ids
                    let global: Vec<usize> = cs.indices.iter().map(|&i| shard[i]).collect();
                    Ok((global, cs, shard_div, w0.elapsed()))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    let mut union: Vec<usize> = Vec::new();
    let mut worker_times = Vec::with_capacity(cfg.workers);
    let mut shard_coreset_sizes = Vec::with_capacity(cfg.workers);
    let mut shard_coreset_diversities = Vec::with_capacity(cfg.workers);
    let mut shard_score_dist_evals = Vec::with_capacity(cfg.workers);
    let mut shard_coreset_dist_evals = Vec::with_capacity(cfg.workers);
    let mut n_clusters = 0;
    let mut radius = 0.0f64;
    for (shard, r) in shards.iter().zip(results) {
        let (global, cs, shard_div, dt) = r?;
        shard_coreset_sizes.push(global.len());
        shard_coreset_diversities.push(shard_div);
        // the scoring pass is one sums_to_set of the shard coreset against
        // itself: |T_j| * (|T_j| - 1) distances net of self-pairs
        shard_score_dist_evals.push((global.len() * global.len().saturating_sub(1)) as u64);
        // the construction pass is the GMM folds: one shard-wide
        // update_min per selected center
        shard_coreset_dist_evals.push((cs.n_clusters * shard.len()) as u64);
        union.extend(global);
        worker_times.push(dt);
        n_clusters += cs.n_clusters;
        radius = radius.max(cs.radius);
    }
    union.sort_unstable();
    union.dedup();
    let makespan_round1 = worker_times.iter().copied().max().unwrap_or_default();

    let mut rounds = 1;
    let mut round2_dist_evals = 0u64;
    let coreset = if let Some(tau2) = cfg.second_round_tau {
        rounds = 2;
        let sub = ds.subset(&union);
        let engine = build_engine(cfg.engine, &sub)?;
        let cs2 = seq_coreset(&sub, m, k, Budget::Clusters(tau2), &*engine)?;
        round2_dist_evals = (cs2.n_clusters * sub.n()) as u64;
        let indices: Vec<usize> = cs2.indices.iter().map(|&i| union[i]).collect();
        Coreset {
            indices,
            n_clusters: cs2.n_clusters,
            radius: radius.max(cs2.radius),
            timer: cs2.timer,
        }
    } else {
        Coreset {
            indices: union,
            n_clusters,
            radius,
            timer: Default::default(),
        }
    };

    Ok(MrReport {
        coreset,
        rounds,
        local_memory_points,
        worker_times,
        makespan_round1,
        wall_time: sw.elapsed(),
        shard_coreset_sizes,
        shard_coreset_diversities,
        shard_score_dist_evals,
        shard_coreset_dist_evals,
        round2_dist_evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::{maximal_independent, PartitionMatroid, UniformMatroid};

    fn cfg(workers: usize, tau: usize) -> MapReduceConfig {
        MapReduceConfig {
            workers,
            budget: Budget::Clusters(tau),
            second_round_tau: None,
            seed: 7,
            engine: EngineKind::default(),
        }
    }

    #[test]
    fn single_worker_equals_sequential_shape() {
        let ds = synth::clustered(400, 2, 6, 0.1, 3, 1);
        let m = PartitionMatroid::new(vec![2; 3]);
        let rep = mr_coreset(&ds, &m, 5, cfg(1, 16)).unwrap();
        assert_eq!(rep.rounds, 1);
        assert_eq!(rep.local_memory_points, 400);
        assert!(rep.coreset.len() <= 5 * 16);
    }

    #[test]
    fn shards_are_even_and_memory_sublinear() {
        let ds = synth::uniform_cube(1000, 2, 2);
        let m = UniformMatroid::new(4);
        let rep = mr_coreset(&ds, &m, 4, cfg(8, 4)).unwrap();
        assert_eq!(rep.worker_times.len(), 8);
        assert!(rep.local_memory_points <= 1000usize.div_ceil(8));
        // union of 8 shard coresets
        assert!(rep.coreset.len() <= 8 * 4 * 4);
        // per-shard scoring ledger: one sums pass over each shard coreset
        assert_eq!(rep.shard_score_dist_evals.len(), 8);
        for (evals, size) in rep.shard_score_dist_evals.iter().zip(&rep.shard_coreset_sizes) {
            assert_eq!(*evals, (size * size.saturating_sub(1)) as u64);
        }
    }

    #[test]
    fn construction_evals_are_reported() {
        // regression for the silently-dropped ledger: the GMM build work
        // (the bulk of MR distance evals) must be accounted per shard,
        // and the round-2 re-compression pass must be accounted too
        let ds = synth::uniform_cube(800, 2, 6);
        let m = UniformMatroid::new(4);
        let mut c = cfg(4, 8);
        let rep1 = mr_coreset(&ds, &m, 4, c).unwrap();
        assert_eq!(rep1.shard_coreset_dist_evals.len(), 4);
        // 800 points over 4 even shards = 200 each; tau = 8 centers, and
        // each selected center costs one shard-wide update_min fold
        for &evals in &rep1.shard_coreset_dist_evals {
            assert_eq!(evals, 8 * 200);
        }
        assert_eq!(rep1.round2_dist_evals, 0);
        c.second_round_tau = Some(8);
        let rep2 = mr_coreset(&ds, &m, 4, c).unwrap();
        assert!(rep2.round2_dist_evals > 0);
    }

    #[test]
    fn coreset_contains_feasible_solution_any_parallelism() {
        let ds = synth::clustered(600, 2, 5, 0.15, 4, 3);
        let m = PartitionMatroid::new(vec![2; 4]);
        let k = 6;
        for ell in [1usize, 2, 4, 8] {
            let rep = mr_coreset(&ds, &m, k, cfg(ell, 16 / ell.min(16))).unwrap();
            let sol = maximal_independent(&m, &ds, &rep.coreset.indices, k);
            assert_eq!(sol.len(), k, "ell={ell}");
        }
    }

    #[test]
    fn second_round_compresses() {
        let ds = synth::uniform_cube(800, 2, 4);
        let m = UniformMatroid::new(4);
        let mut c = cfg(8, 8);
        let rep1 = mr_coreset(&ds, &m, 4, c).unwrap();
        c.second_round_tau = Some(8);
        let rep2 = mr_coreset(&ds, &m, 4, c).unwrap();
        assert_eq!(rep2.rounds, 2);
        assert!(rep2.coreset.len() <= rep1.coreset.len());
        assert!(rep2.coreset.len() <= 8 * 4 + 8);
    }

    #[test]
    fn engine_kind_does_not_change_the_coreset() {
        // Euclidean per-shard work is bit-identical across the CPU
        // backends, so the registry choice cannot move a single index
        let ds = synth::uniform_cube(600, 3, 9);
        let m = UniformMatroid::new(4);
        let mut base: Option<Vec<usize>> = None;
        for kind in [EngineKind::Scalar, EngineKind::Batch, EngineKind::Simd] {
            let mut c = cfg(4, 6);
            c.engine = kind;
            let rep = mr_coreset(&ds, &m, 4, c).unwrap();
            match &base {
                None => base = Some(rep.coreset.indices),
                Some(b) => assert_eq!(b, &rep.coreset.indices, "{}", kind.name()),
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = synth::uniform_cube(300, 2, 5);
        let m = UniformMatroid::new(3);
        let a = mr_coreset(&ds, &m, 3, cfg(4, 6)).unwrap();
        let b = mr_coreset(&ds, &m, 3, cfg(4, 6)).unwrap();
        assert_eq!(a.coreset.indices, b.coreset.indices);
    }

    #[test]
    fn indices_global_and_valid() {
        let ds = synth::uniform_cube(500, 3, 6);
        let m = UniformMatroid::new(3);
        let rep = mr_coreset(&ds, &m, 3, cfg(4, 8)).unwrap();
        // BTreeSet so a duplicate-id assertion failure names the same
        // first duplicate on every run
        let mut seen = std::collections::BTreeSet::new();
        for &i in &rep.coreset.indices {
            assert!(i < ds.n());
            assert!(seen.insert(i), "duplicate index {i}");
        }
    }
}
