//! MapReduce coreset construction (paper §4.2) on a thread-based simulator.
//!
//! The construction is *composable* (Theorem 6): partition `S` arbitrarily
//! into `ell` shards, build a `(1-eps)`-coreset per shard with SeqCoreset,
//! and take the union.  Round 2 gathers the union in one "reducer" where
//! the final sequential algorithm runs; an optional intermediate
//! re-compression (SeqCoreset on the round-1 union) bounds the final
//! coreset size independently of `ell` (§4.4.2).
//!
//! The simulator runs one OS thread per shard ("machine") and accounts for
//! the quantities the paper's MR model cares about: rounds, per-reducer
//! local memory (`M_L = O(n / ell)`), per-worker wall time, and the
//! simulated cluster makespan (max over workers) — see DESIGN.md §1 for
//! why this substitutes for the paper's 16-node Spark cluster.

pub mod runner;

pub use runner::{mr_coreset, MapReduceConfig, MrReport};
