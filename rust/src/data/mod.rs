//! Dataset generation and (de)serialization.

pub mod io;
pub mod synth;
