//! Synthetic dataset generators — the environment-substitution layer.
//!
//! The paper evaluates on two real datasets (Table 2) that are not
//! available in this environment (see DESIGN.md §1); these generators build
//! synthetic equivalents that exercise the same code paths:
//!
//! * [`wikisim`]  — Wikipedia stand-in: GloVe-like 25-d embeddings from a
//!   Gaussian mixture (bounded doubling dimension), 100 overlapping topics
//!   with Zipf popularity (1..=4 per page)  ->  transversal matroid.
//! * [`songsim`]  — Songs stand-in: nonnegative count-like 48-d vectors,
//!   16 disjoint Zipf-sized genres  ->  partition matroid with caps
//!   proportional to genre frequency.
//! * [`clustered`] / [`uniform_cube`] / [`grid`] — controlled-geometry
//!   inputs for unit tests and doubling-dimension experiments.

use crate::core::{Dataset, Metric};
use crate::matroid::{Matroid, PartitionMatroid};
use crate::util::rng::Rng;

/// Wikipedia-like dataset: `n` points, 25-d, cosine metric, 100 topics,
/// 1..=4 topics per point with Zipf(1.1) popularity.
pub fn wikisim(n: usize, seed: u64) -> Dataset {
    mixture_with_topics(n, 25, 100, 200, 0.15, 4, 1.1, Metric::Cosine, seed, "wikisim")
}

/// Songs-like dataset: `n` points, 48-d nonnegative count-like vectors,
/// cosine metric, 16 disjoint genres with Zipf(1.0) sizes.
pub fn songsim(n: usize, seed: u64) -> Dataset {
    let dim = 48;
    let n_genres = 16u32;
    let mut rng = Rng::new(seed ^ 0x50_4E_47);
    // genre "style" centers: sparse nonnegative profiles
    let n_styles = n_genres as usize;
    let mut styles = vec![0.0f32; n_styles * dim];
    for s in styles.iter_mut() {
        if rng.f64() < 0.4 {
            *s = (rng.f64() * 4.0) as f32;
        }
    }
    let mut coords = Vec::with_capacity(n * dim);
    let mut categories = Vec::with_capacity(n);
    for _ in 0..n {
        let g = rng.zipf(n_styles, 1.0);
        categories.push(vec![g as u32]);
        let style = &styles[g * dim..(g + 1) * dim];
        for &sv in style.iter().take(dim) {
            // counts: style profile + nonnegative noise, some sparsity
            let noise = (rng.normal().abs() * 0.8) as f32;
            let v = if rng.f64() < 0.25 { 0.0 } else { sv + noise };
            coords.push(v);
        }
    }
    // guard: all-zero rows break nothing (cosine has an EPS guard) but are
    // unrealistic; give them one unit count.
    for i in 0..n {
        let row = &mut coords[i * dim..(i + 1) * dim];
        if row.iter().all(|&v| v == 0.0) {
            row[0] = 1.0;
        }
    }
    Dataset::new(dim, Metric::Cosine, coords, categories, n_genres, format!("songsim(n={n})"))
}

/// Partition matroid for a songsim-style dataset with rank close to
/// `target_rank` (caps proportional to genre frequency, minimum 1 — the
/// paper's construction).  Binary-searches the proportionality factor.
pub fn songsim_matroid(ds: &Dataset, target_rank: usize) -> PartitionMatroid {
    let mut lo = 0.0f64;
    let mut hi = 1.0f64;
    let mut best = PartitionMatroid::proportional(ds, 1e-9);
    for _ in 0..40 {
        let mid = (lo + hi) / 2.0;
        let m = PartitionMatroid::proportional(ds, mid);
        let rank = m.rank_bound(ds);
        if rank >= target_rank {
            best = m;
            hi = mid;
        } else {
            lo = mid;
        }
    }
    best
}

/// Gaussian-mixture embedding cloud with multi-label Zipf topics.
#[allow(clippy::too_many_arguments)]
fn mixture_with_topics(
    n: usize,
    dim: usize,
    n_topics: u32,
    n_clusters: usize,
    spread: f64,
    max_topics: usize,
    zipf_s: f64,
    metric: Metric,
    seed: u64,
    tag: &str,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut centers = vec![0.0f64; n_clusters * dim];
    for c in centers.iter_mut() {
        *c = rng.normal();
    }
    let mut coords = Vec::with_capacity(n * dim);
    let mut categories = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(n_clusters);
        for j in 0..dim {
            coords.push((centers[c * dim + j] + rng.normal() * spread) as f32);
        }
        let n_cats = 1 + rng.below(max_topics);
        let mut cats: Vec<u32> = (0..n_cats)
            .map(|_| rng.zipf(n_topics as usize, zipf_s) as u32)
            .collect();
        cats.sort_unstable();
        cats.dedup();
        categories.push(cats);
    }
    Dataset::new(dim, metric, coords, categories, n_topics, format!("{tag}(n={n})"))
}

/// `n` points uniform in `[0,1]^dim` — doubling dimension ~ dim.
pub fn uniform_cube(n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let coords: Vec<f32> = (0..n * dim).map(|_| rng.f32()).collect();
    Dataset::new(
        dim,
        Metric::Euclidean,
        coords,
        vec![vec![0]; n],
        1,
        format!("cube(n={n},d={dim})"),
    )
}

/// `n` points around `n_clusters` well-separated centers in `dim`
/// dimensions, `n_labels` single categories assigned round-robin per
/// cluster (so partition constraints interact with geometry).
pub fn clustered(
    n: usize,
    dim: usize,
    n_clusters: usize,
    spread: f64,
    n_labels: u32,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut centers = vec![0.0f64; n_clusters * dim];
    for c in centers.iter_mut() {
        *c = rng.f64() * 10.0;
    }
    let mut coords = Vec::with_capacity(n * dim);
    let mut categories = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % n_clusters;
        for j in 0..dim {
            coords.push((centers[c * dim + j] + rng.normal() * spread) as f32);
        }
        categories.push(vec![(c as u32) % n_labels]);
    }
    Dataset::new(
        dim,
        Metric::Euclidean,
        coords,
        categories,
        n_labels,
        format!("clustered(n={n},c={n_clusters})"),
    )
}

/// Regular grid in `[0,1]^2` (n = side^2) — known geometry for exact
/// assertions (diameter, GMM radius) in tests.
pub fn grid(side: usize) -> Dataset {
    let mut coords = Vec::with_capacity(side * side * 2);
    for i in 0..side {
        for j in 0..side {
            coords.push(i as f32 / (side.max(2) - 1) as f32);
            coords.push(j as f32 / (side.max(2) - 1) as f32);
        }
    }
    let n = side * side;
    Dataset::new(
        2,
        Metric::Euclidean,
        coords,
        vec![vec![0]; n],
        1,
        format!("grid({side}x{side})"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matroid::{Matroid, TransversalMatroid};

    #[test]
    fn wikisim_shape_and_categories() {
        let ds = wikisim(500, 1);
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.dim, 25);
        assert_eq!(ds.metric, Metric::Cosine);
        assert_eq!(ds.n_categories, 100);
        assert!(ds.categories.iter().all(|c| (1..=4).contains(&c.len())));
        // topic popularity must be skewed (Zipf)
        let hist = ds.category_histogram();
        assert!(hist[0] > hist[99]);
    }

    #[test]
    fn wikisim_deterministic() {
        let a = wikisim(100, 7);
        let b = wikisim(100, 7);
        assert_eq!(a.flat_coords(), b.flat_coords());
        assert_eq!(a.categories, b.categories);
        let c = wikisim(100, 8);
        assert_ne!(a.flat_coords(), c.flat_coords());
    }

    #[test]
    fn songsim_nonnegative_and_partition_ready() {
        let ds = songsim(500, 2);
        assert!(ds.flat_coords().iter().all(|&v| v >= 0.0));
        assert!(ds.categories.iter().all(|c| c.len() == 1));
        assert_eq!(ds.n_categories, 16);
    }

    #[test]
    fn songsim_matroid_hits_target_rank() {
        let ds = songsim(2000, 3);
        let m = songsim_matroid(&ds, 89);
        let rank = m.rank_bound(&ds);
        assert!((89..=105).contains(&rank), "rank {rank}");
    }

    #[test]
    fn wikisim_transversal_nontrivial() {
        let ds = wikisim(300, 4);
        let m = TransversalMatroid::new();
        // a full-dataset rank bound exists and small sets are independent
        assert!(m.is_independent(&ds, &[0, 1]) || !m.is_independent(&ds, &[0, 1]));
        assert_eq!(m.rank_bound(&ds), 100);
    }

    #[test]
    fn grid_diameter_is_sqrt2() {
        let ds = grid(5);
        assert!((ds.diameter_exact() - (2.0f64).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn clustered_labels_within_range() {
        let ds = clustered(120, 4, 6, 0.05, 3, 5);
        assert!(ds.categories.iter().all(|c| c[0] < 3));
        assert_eq!(ds.n(), 120);
    }
}
