//! Dataset (de)serialization: a simple little-endian binary format plus a
//! CSV loader for external data.
//!
//! Binary layout (`.dmmc` files, magic "DMMC1"):
//!   magic[5] | metric u8 | dim u32 | n u32 | n_categories u32 |
//!   coords f32 * (n*dim) | per point: n_cats u8, cat u32 * n_cats

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::core::{Dataset, Metric};

const MAGIC: &[u8; 5] = b"DMMC1";

pub fn save(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref()).context("create dataset file")?);
    w.write_all(MAGIC)?;
    w.write_all(&[match ds.metric {
        Metric::Euclidean => 0u8,
        Metric::Cosine => 1u8,
    }])?;
    w.write_all(&(ds.dim as u32).to_le_bytes())?;
    w.write_all(&(ds.n() as u32).to_le_bytes())?;
    w.write_all(&ds.n_categories.to_le_bytes())?;
    for &v in ds.flat_coords().iter() {
        w.write_all(&v.to_le_bytes())?;
    }
    for cats in &ds.categories {
        assert!(cats.len() < 256);
        w.write_all(&[cats.len() as u8])?;
        for &c in cats {
            w.write_all(&c.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
    let path = path.as_ref();
    let mut r = BufReader::new(File::open(path).context("open dataset file")?);
    let mut magic = [0u8; 5];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a DMMC1 dataset: {}", path.display());
    }
    let mut b1 = [0u8; 1];
    r.read_exact(&mut b1)?;
    let metric = match b1[0] {
        0 => Metric::Euclidean,
        1 => Metric::Cosine,
        x => bail!("unknown metric tag {x}"),
    };
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let dim = u32::from_le_bytes(b4) as usize;
    r.read_exact(&mut b4)?;
    let n = u32::from_le_bytes(b4) as usize;
    r.read_exact(&mut b4)?;
    let n_categories = u32::from_le_bytes(b4);
    let mut coords = vec![0.0f32; n * dim];
    for v in coords.iter_mut() {
        r.read_exact(&mut b4)?;
        *v = f32::from_le_bytes(b4);
    }
    let mut categories = Vec::with_capacity(n);
    for _ in 0..n {
        r.read_exact(&mut b1)?;
        let m = b1[0] as usize;
        let mut cats = Vec::with_capacity(m);
        for _ in 0..m {
            r.read_exact(&mut b4)?;
            cats.push(u32::from_le_bytes(b4));
        }
        categories.push(cats);
    }
    Ok(Dataset::new(
        dim,
        metric,
        coords,
        categories,
        n_categories,
        path.display().to_string(),
    ))
}

/// CSV loader: each row `x1,...,xd,cat[;cat...]` — numeric features followed
/// by one semicolon-separated category-id list column.
pub fn load_csv(path: impl AsRef<Path>, metric: Metric) -> Result<Dataset> {
    let text = std::fs::read_to_string(path.as_ref()).context("read csv")?;
    let mut coords = Vec::new();
    let mut categories: Vec<Vec<u32>> = Vec::new();
    let mut dim = None;
    let mut max_cat = 0u32;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if fields.len() < 2 {
            bail!("line {}: need >=1 feature and a category column", lineno + 1);
        }
        let (feat, cat_field) = fields.split_at(fields.len() - 1);
        match dim {
            None => dim = Some(feat.len()),
            Some(d) if d == feat.len() => {}
            Some(d) => bail!("line {}: dim {} != {}", lineno + 1, feat.len(), d),
        }
        for f in feat {
            coords.push(f.trim().parse::<f32>().with_context(|| format!("line {}", lineno + 1))?);
        }
        let cats: Vec<u32> = cat_field[0]
            .split(';')
            .map(|c| c.trim().parse::<u32>())
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("line {}: bad category list", lineno + 1))?;
        if cats.is_empty() {
            bail!("line {}: empty category list", lineno + 1);
        }
        for &c in &cats {
            max_cat = max_cat.max(c);
        }
        categories.push(cats);
    }
    let dim = dim.context("empty csv")?;
    Ok(Dataset::new(
        dim,
        metric,
        coords,
        categories,
        max_cat + 1,
        path.as_ref().display().to_string(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn binary_roundtrip() {
        let ds = synth::wikisim(50, 1);
        let path = std::env::temp_dir().join("mc_io_roundtrip.dmmc");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.dim, ds.dim);
        assert_eq!(back.metric, ds.metric);
        assert_eq!(back.flat_coords(), ds.flat_coords());
        assert_eq!(back.categories, ds.categories);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("mc_io_bad.dmmc");
        std::fs::write(&path, b"WRONG....").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_roundtrip() {
        let path = std::env::temp_dir().join("mc_io_test.csv");
        std::fs::write(&path, "# comment\n1.0,2.0,0\n3.0,4.0,1;2\n").unwrap();
        let ds = load_csv(&path, Metric::Euclidean).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.dim, 2);
        assert_eq!(ds.categories[1], vec![1, 2]);
        assert_eq!(ds.n_categories, 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_rejects_ragged_rows() {
        let path = std::env::temp_dir().join("mc_io_ragged.csv");
        std::fs::write(&path, "1.0,2.0,0\n1.0,0\n").unwrap();
        assert!(load_csv(&path, Metric::Euclidean).is_err());
        std::fs::remove_file(&path).ok();
    }
}
