//! Core types: metrics and the dataset container.

pub mod dataset;
pub mod metric;

pub use dataset::Dataset;
pub use metric::Metric;
