//! The dataset container: dense points + per-point category labels.
//!
//! Layout is a flat row-major store (cache-friendly for the GMM scan,
//! zero-copy sliceable for the PJRT padding path) behind an `Arc`, so a
//! [`Dataset::subset`] is a *view*: it shares the backing coordinates and
//! carries only a row map.  Sharding (MapReduce workers, sliding-window
//! blocks, index segments) therefore no longer doubles peak coordinate
//! memory — a shard costs `O(shard)` row indices + category lists, not
//! `O(shard * dim)` floats.  Categories carry the matroid
//! side-information: one label per point for partition matroids,
//! one-or-more for transversal matroids (paper §2.1 assumes O(1)
//! categories per element).

use std::borrow::Cow;
use std::sync::Arc;

use crate::core::metric::Metric;

/// A dataset of `n` points of dimension `dim` with category labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub metric: Metric,
    /// Row-major backing store, shared between a dataset and its views.
    coords: Arc<Vec<f32>>,
    /// View row map: row `i` of this dataset is storage row `rows[i]`.
    /// `None` = identity (the dataset covers the whole store in order).
    rows: Option<Arc<Vec<usize>>>,
    /// Per-point category ids (sorted, deduplicated). Non-empty per point.
    pub categories: Vec<Vec<u32>>,
    /// Total number of distinct categories (ids are `0..n_categories`).
    pub n_categories: u32,
    /// Human-readable provenance tag (generator name / file path).
    pub name: String,
}

impl Dataset {
    pub fn new(
        dim: usize,
        metric: Metric,
        coords: Vec<f32>,
        categories: Vec<Vec<u32>>,
        n_categories: u32,
        name: impl Into<String>,
    ) -> Dataset {
        assert!(dim > 0, "Dataset dim must be >= 1 (a 0-dim point set has no geometry)");
        assert_eq!(coords.len() % dim, 0, "coords not a multiple of dim");
        let n = coords.len() / dim;
        assert_eq!(categories.len(), n, "one category list per point");
        let mut categories = categories;
        for cats in &mut categories {
            cats.sort_unstable();
            cats.dedup();
            assert!(!cats.is_empty(), "every point needs >=1 category");
            assert!(cats.iter().all(|&c| c < n_categories), "category id OOB");
        }
        Dataset {
            dim,
            metric,
            coords: Arc::new(coords),
            rows: None,
            categories,
            n_categories,
            name: name.into(),
        }
    }

    /// Number of points.  `new` rejects `dim == 0`, so the division is
    /// always meaningful and agrees with the validation in `new`.
    #[inline]
    pub fn n(&self) -> usize {
        match &self.rows {
            None => self.coords.len() / self.dim,
            Some(rows) => rows.len(),
        }
    }

    /// True when this dataset is a [`Dataset::subset`] view over a shared
    /// backing store (its coordinate rows are remapped, not contiguous).
    #[inline]
    pub fn is_view(&self) -> bool {
        self.rows.is_some()
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[f32] {
        let r = match &self.rows {
            None => i,
            Some(rows) => rows[i],
        };
        &self.coords[r * self.dim..(r + 1) * self.dim]
    }

    /// The row-major coordinate block: borrowed from the backing store for
    /// a non-view dataset, materialized for a view (serialization and the
    /// generator tests want the flat layout; hot paths use
    /// [`Dataset::point`], which never copies).
    pub fn flat_coords(&self) -> Cow<'_, [f32]> {
        match &self.rows {
            None => Cow::Borrowed(&self.coords[..]),
            Some(rows) => {
                let mut out = Vec::with_capacity(rows.len() * self.dim);
                for i in 0..rows.len() {
                    out.extend_from_slice(self.point(i));
                }
                Cow::Owned(out)
            }
        }
    }

    /// Distance between points `i` and `j` under the dataset metric.
    #[inline]
    pub fn dist(&self, i: usize, j: usize) -> f64 {
        self.metric.dist(self.point(i), self.point(j))
    }

    /// Distance between point `i` and an arbitrary vector.
    #[inline]
    pub fn dist_to(&self, i: usize, v: &[f32]) -> f64 {
        self.metric.dist(self.point(i), v)
    }

    /// Exact diameter by brute force — O(n^2), test/bench-sized inputs only.
    pub fn diameter_exact(&self) -> f64 {
        let n = self.n();
        let mut best = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                best = best.max(self.dist(i, j));
            }
        }
        best
    }

    /// Restriction of the dataset to `indices` (preserving their order),
    /// as a zero-copy *view*: the backing coordinate store is shared via
    /// `Arc` and only a row map (plus the per-point category lists) is
    /// allocated, so sharding no longer doubles peak coordinate memory.
    /// Category ids and the metric are preserved, so matroids built from
    /// category structure remain valid on the restriction.  The view keeps
    /// the parent's backing store alive; use [`Dataset::materialize`] when
    /// an owned copy with an independent lifetime is wanted.
    pub fn subset(&self, indices: &[usize]) -> Dataset {
        let rows: Vec<usize> = match &self.rows {
            None => indices.to_vec(),
            Some(rows) => indices.iter().map(|&i| rows[i]).collect(),
        };
        let categories = indices.iter().map(|&i| self.categories[i].clone()).collect();
        Dataset {
            dim: self.dim,
            metric: self.metric,
            coords: Arc::clone(&self.coords),
            rows: Some(Arc::new(rows)),
            categories,
            n_categories: self.n_categories,
            name: format!("{}[subset:{}]", self.name, indices.len()),
        }
    }

    /// Deep copy into a fresh contiguous backing store (drops the view row
    /// map and the reference to the parent's coordinates).
    pub fn materialize(&self) -> Dataset {
        Dataset {
            dim: self.dim,
            metric: self.metric,
            coords: Arc::new(self.flat_coords().into_owned()),
            rows: None,
            categories: self.categories.clone(),
            n_categories: self.n_categories,
            name: self.name.clone(),
        }
    }

    /// Apply a permutation: point `i` of the result is `perm[i]` of `self`.
    /// The experiments (paper §5) permute the dataset before every run to
    /// probe solution-quality stability.
    pub fn permute(&self, perm: &[usize]) -> Dataset {
        assert_eq!(perm.len(), self.n());
        self.subset(perm)
    }

    /// Count of points per category (used by generators and Table 2 stats).
    pub fn category_histogram(&self) -> Vec<usize> {
        let mut hist = vec![0usize; self.n_categories as usize];
        for cats in &self.categories {
            for &c in cats {
                hist[c as usize] += 1;
            }
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            2,
            Metric::Euclidean,
            vec![0.0, 0.0, 3.0, 4.0, 0.0, 1.0],
            vec![vec![0], vec![1], vec![0, 1]],
            2,
            "tiny",
        )
    }

    #[test]
    fn basic_accessors() {
        let ds = tiny();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.point(1), &[3.0, 4.0]);
        assert_eq!(ds.dist(0, 1), 5.0);
        assert!(!ds.is_view());
    }

    #[test]
    fn diameter_exact_small() {
        let ds = tiny();
        assert_eq!(ds.diameter_exact(), 5.0);
    }

    #[test]
    fn subset_preserves_geometry() {
        let ds = tiny();
        let sub = ds.subset(&[2, 0]);
        assert_eq!(sub.n(), 2);
        assert_eq!(sub.point(0), &[0.0, 1.0]);
        assert_eq!(sub.dist(0, 1), 1.0);
        assert_eq!(sub.categories[0], vec![0, 1]);
    }

    #[test]
    fn subset_is_zero_copy_view() {
        let ds = tiny();
        let sub = ds.subset(&[2, 0]);
        assert!(sub.is_view());
        // the backing store is shared, not copied
        assert!(Arc::ptr_eq(&ds.coords, &sub.coords));
        // flat_coords materializes the remapped rows
        assert_eq!(sub.flat_coords().as_ref(), &[0.0, 1.0, 0.0, 0.0]);
        // a non-view borrows the store as-is
        assert!(matches!(ds.flat_coords(), Cow::Borrowed(_)));
    }

    #[test]
    fn view_of_view_composes_row_maps() {
        let ds = tiny();
        let sub = ds.subset(&[2, 1, 0]);
        let subsub = sub.subset(&[2, 0]);
        assert!(Arc::ptr_eq(&ds.coords, &subsub.coords));
        assert_eq!(subsub.point(0), ds.point(0));
        assert_eq!(subsub.point(1), ds.point(2));
        assert_eq!(subsub.categories[1], ds.categories[2]);
    }

    #[test]
    fn materialize_detaches_from_parent_store() {
        let ds = tiny();
        let sub = ds.subset(&[1, 2]);
        let owned = sub.materialize();
        assert!(!owned.is_view());
        assert!(!Arc::ptr_eq(&ds.coords, &owned.coords));
        assert_eq!(owned.n(), 2);
        for i in 0..2 {
            assert_eq!(owned.point(i), sub.point(i));
        }
        assert_eq!(owned.categories, sub.categories);
    }

    #[test]
    fn permute_is_bijection() {
        let ds = tiny();
        let p = ds.permute(&[2, 1, 0]);
        assert_eq!(p.point(0), ds.point(2));
        assert_eq!(p.point(2), ds.point(0));
    }

    #[test]
    fn category_histogram_counts_multi() {
        let ds = tiny();
        assert_eq!(ds.category_histogram(), vec![2, 2]);
    }

    #[test]
    #[should_panic(expected = "dim must be >= 1")]
    fn zero_dim_rejected() {
        // regression: `new` used to validate categories against
        // coords.len()/max(dim,1) while n() returned 0 for dim == 0 —
        // the two disagreed; dim == 0 is now rejected outright
        Dataset::new(0, Metric::Euclidean, vec![], vec![], 1, "bad");
    }

    #[test]
    #[should_panic]
    fn category_oob_rejected() {
        Dataset::new(1, Metric::Euclidean, vec![0.0], vec![vec![5]], 2, "bad");
    }

    #[test]
    #[should_panic]
    fn empty_categories_rejected() {
        Dataset::new(1, Metric::Euclidean, vec![0.0], vec![vec![]], 2, "bad");
    }
}
