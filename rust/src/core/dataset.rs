//! The dataset container: dense points + per-point category labels.
//!
//! Layout is a flat row-major `Vec<f32>` (cache-friendly for the GMM scan,
//! zero-copy sliceable for the PJRT padding path).  Categories carry the
//! matroid side-information: one label per point for partition matroids,
//! one-or-more for transversal matroids (paper §2.1 assumes O(1) categories
//! per element).

use crate::core::metric::Metric;

/// A dataset of `n` points of dimension `dim` with category labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub metric: Metric,
    /// Row-major coordinates, length `n * dim`.
    pub coords: Vec<f32>,
    /// Per-point category ids (sorted, deduplicated). Non-empty per point.
    pub categories: Vec<Vec<u32>>,
    /// Total number of distinct categories (ids are `0..n_categories`).
    pub n_categories: u32,
    /// Human-readable provenance tag (generator name / file path).
    pub name: String,
}

impl Dataset {
    pub fn new(
        dim: usize,
        metric: Metric,
        coords: Vec<f32>,
        categories: Vec<Vec<u32>>,
        n_categories: u32,
        name: impl Into<String>,
    ) -> Dataset {
        assert!(dim > 0, "Dataset dim must be >= 1 (a 0-dim point set has no geometry)");
        assert_eq!(coords.len() % dim, 0, "coords not a multiple of dim");
        let n = coords.len() / dim;
        assert_eq!(categories.len(), n, "one category list per point");
        let mut categories = categories;
        for cats in &mut categories {
            cats.sort_unstable();
            cats.dedup();
            assert!(!cats.is_empty(), "every point needs >=1 category");
            assert!(cats.iter().all(|&c| c < n_categories), "category id OOB");
        }
        Dataset {
            dim,
            metric,
            coords,
            categories,
            n_categories,
            name: name.into(),
        }
    }

    /// Number of points.  `new` rejects `dim == 0`, so the division is
    /// always meaningful and agrees with the validation in `new`.
    #[inline]
    pub fn n(&self) -> usize {
        self.coords.len() / self.dim
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[f32] {
        &self.coords[i * self.dim..(i + 1) * self.dim]
    }

    /// Distance between points `i` and `j` under the dataset metric.
    #[inline]
    pub fn dist(&self, i: usize, j: usize) -> f64 {
        self.metric.dist(self.point(i), self.point(j))
    }

    /// Distance between point `i` and an arbitrary vector.
    #[inline]
    pub fn dist_to(&self, i: usize, v: &[f32]) -> f64 {
        self.metric.dist(self.point(i), v)
    }

    /// Exact diameter by brute force — O(n^2), test/bench-sized inputs only.
    pub fn diameter_exact(&self) -> f64 {
        let n = self.n();
        let mut best = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                best = best.max(self.dist(i, j));
            }
        }
        best
    }

    /// Restriction of the dataset to `indices` (preserving their order).
    /// Category ids and the metric are preserved, so matroids built from
    /// category structure remain valid on the restriction.
    pub fn subset(&self, indices: &[usize]) -> Dataset {
        let mut coords = Vec::with_capacity(indices.len() * self.dim);
        let mut categories = Vec::with_capacity(indices.len());
        for &i in indices {
            coords.extend_from_slice(self.point(i));
            categories.push(self.categories[i].clone());
        }
        Dataset {
            dim: self.dim,
            metric: self.metric,
            coords,
            categories,
            n_categories: self.n_categories,
            name: format!("{}[subset:{}]", self.name, indices.len()),
        }
    }

    /// Apply a permutation: point `i` of the result is `perm[i]` of `self`.
    /// The experiments (paper §5) permute the dataset before every run to
    /// probe solution-quality stability.
    pub fn permute(&self, perm: &[usize]) -> Dataset {
        assert_eq!(perm.len(), self.n());
        self.subset(perm)
    }

    /// Count of points per category (used by generators and Table 2 stats).
    pub fn category_histogram(&self) -> Vec<usize> {
        let mut hist = vec![0usize; self.n_categories as usize];
        for cats in &self.categories {
            for &c in cats {
                hist[c as usize] += 1;
            }
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            2,
            Metric::Euclidean,
            vec![0.0, 0.0, 3.0, 4.0, 0.0, 1.0],
            vec![vec![0], vec![1], vec![0, 1]],
            2,
            "tiny",
        )
    }

    #[test]
    fn basic_accessors() {
        let ds = tiny();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.point(1), &[3.0, 4.0]);
        assert_eq!(ds.dist(0, 1), 5.0);
    }

    #[test]
    fn diameter_exact_small() {
        let ds = tiny();
        assert_eq!(ds.diameter_exact(), 5.0);
    }

    #[test]
    fn subset_preserves_geometry() {
        let ds = tiny();
        let sub = ds.subset(&[2, 0]);
        assert_eq!(sub.n(), 2);
        assert_eq!(sub.point(0), &[0.0, 1.0]);
        assert_eq!(sub.dist(0, 1), 1.0);
        assert_eq!(sub.categories[0], vec![0, 1]);
    }

    #[test]
    fn permute_is_bijection() {
        let ds = tiny();
        let p = ds.permute(&[2, 1, 0]);
        assert_eq!(p.point(0), ds.point(2));
        assert_eq!(p.point(2), ds.point(0));
    }

    #[test]
    fn category_histogram_counts_multi() {
        let ds = tiny();
        assert_eq!(ds.category_histogram(), vec![2, 2]);
    }

    #[test]
    #[should_panic(expected = "dim must be >= 1")]
    fn zero_dim_rejected() {
        // regression: `new` used to validate categories against
        // coords.len()/max(dim,1) while n() returned 0 for dim == 0 —
        // the two disagreed; dim == 0 is now rejected outright
        Dataset::new(0, Metric::Euclidean, vec![], vec![], 1, "bad");
    }

    #[test]
    #[should_panic]
    fn category_oob_rejected() {
        Dataset::new(1, Metric::Euclidean, vec![0.0], vec![vec![5]], 2, "bad");
    }

    #[test]
    #[should_panic]
    fn empty_categories_rejected() {
        Dataset::new(1, Metric::Euclidean, vec![0.0], vec![vec![]], 2, "bad");
    }
}
