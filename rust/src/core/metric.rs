//! Distance metrics over dense `f32` vectors.
//!
//! Both metrics satisfy the triangle inequality — every proof in the paper
//! (Fact 1, Lemmas 1-3) depends on it.  `Cosine` is the *metric* version of
//! cosine distance used by the paper's experiments: the angular distance
//! `arccos(cos_sim)/pi` in `[0, 1]`.  The scalar formulas here mirror the
//! Pallas kernels (`python/compile/kernels/distance.py`) and the jnp oracle
//! (`ref.py`): pallas == jnp == rust is pinned by
//! `rust/tests/runtime_numerics.rs`.

/// Supported metrics.  Names match the AOT artifact naming convention.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    /// L2 distance.
    Euclidean,
    /// Angular distance `arccos(cos_sim)/pi` — the metric cosine distance.
    Cosine,
}

impl Metric {
    pub fn name(self) -> &'static str {
        match self {
            Metric::Euclidean => "euclidean",
            Metric::Cosine => "cosine",
        }
    }

    pub fn parse(s: &str) -> Option<Metric> {
        match s {
            "euclidean" | "l2" => Some(Metric::Euclidean),
            "cosine" | "angular" => Some(Metric::Cosine),
            _ => None,
        }
    }

    /// Distance between two vectors of equal dimension.
    #[inline]
    pub fn dist(self, a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Metric::Euclidean => euclidean(a, b),
            Metric::Cosine => cosine_angular(a, b),
        }
    }
}

const EPS: f64 = 1.0e-12;

/// f64 dot product of two f32 vectors, accumulated left to right.
///
/// The accumulation order is load-bearing: [`cosine_angular`] and the
/// batched engine's fast paths all build their `<a,b>` term with exactly
/// this loop, which is what keeps the batch backend bit-identical to the
/// scalar oracle.  This sequential fold is the *definition* backends are
/// measured against, not a constraint on how they compute: a vectorized
/// backend may reorder its reductions as long as it honors its declared
/// determinism contract (`EngineKind::contract`) — e.g. the SIMD engine's
/// tree-reduced dot (`runtime::simd::dot_tree4`) is tolerance-bounded on
/// the cosine paths while its Euclidean paths stay bit-identical.  The
/// conformance suite (`runtime::conformance`, driven by
/// `rust/tests/engine_conformance.rs`) pins every registered backend to
/// its contract.  Changing *this* function, by contrast, changes the
/// definition itself — results move everywhere at once.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut ab = 0.0f64;
    for i in 0..a.len() {
        ab += a[i] as f64 * b[i] as f64;
    }
    ab
}

/// Exact-difference Euclidean distance (not the expanded form): precise at
/// d ~ 0, which matters for duplicate detection and radius accounting.
#[inline]
pub fn euclidean(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        acc += d * d;
    }
    acc.sqrt()
}

/// Angular distance in [0, 1]: `arccos(clip(cos_sim)) / pi`.
///
/// One fused pass for speed; each accumulator's per-index order matches a
/// standalone [`dot`] over the same pair, so precomputing `<a,a>`/`<b,b>`
/// with `dot` and feeding [`cosine_angular_from_parts`] is bit-identical.
#[inline]
pub fn cosine_angular(a: &[f32], b: &[f32]) -> f64 {
    let (mut ab, mut aa, mut bb) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..a.len() {
        let (x, y) = (a[i] as f64, b[i] as f64);
        ab += x * y;
        aa += x * x;
        bb += y * y;
    }
    cosine_angular_from_parts(ab, aa, bb)
}

/// Angular distance from the inner products `ab = <a,b>`, `aa = <a,a>`,
/// `bb = <b,b>`.  The batched engine precomputes the squared norms once per
/// dataset and feeds them here, which keeps its output bit-identical to
/// [`cosine_angular`] (the parts are accumulated in the same order).
#[inline]
pub fn cosine_angular_from_parts(ab: f64, aa: f64, bb: f64) -> f64 {
    let denom = (aa.sqrt() * bb.sqrt()).max(EPS);
    let sim = (ab / denom).clamp(-1.0, 1.0);
    sim.acos() / std::f64::consts::PI
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(r: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| r.normal() as f32).collect()
    }

    #[test]
    fn euclidean_known_values() {
        assert_eq!(euclidean(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        assert_eq!(euclidean(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn cosine_known_values() {
        // orthogonal -> 1/2; identical -> 0; opposite -> 1.
        assert!((cosine_angular(&[1.0, 0.0], &[0.0, 1.0]) - 0.5).abs() < 1e-12);
        assert!(cosine_angular(&[1.0, 2.0], &[2.0, 4.0]).abs() < 1e-6);
        assert!((cosine_angular(&[1.0, 0.0], &[-1.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_scale_invariant() {
        let a = [0.3f32, -1.2, 0.7];
        let b = [2.0f32, 0.1, -0.5];
        let scaled: Vec<f32> = b.iter().map(|x| x * 37.0).collect();
        let d1 = cosine_angular(&a, &b);
        let d2 = cosine_angular(&a, &scaled);
        assert!((d1 - d2).abs() < 1e-9);
    }

    #[test]
    fn zero_vector_guard_finite() {
        let z = [0.0f32; 4];
        let a = [1.0f32, 2.0, 3.0, 4.0];
        assert!(cosine_angular(&z, &a).is_finite());
        assert!(cosine_angular(&z, &z).is_finite());
    }

    #[test]
    fn metric_axioms_random() {
        let mut r = Rng::new(11);
        for metric in [Metric::Euclidean, Metric::Cosine] {
            for _ in 0..200 {
                let a = rand_vec(&mut r, 8);
                let b = rand_vec(&mut r, 8);
                let c = rand_vec(&mut r, 8);
                let dab = metric.dist(&a, &b);
                let dba = metric.dist(&b, &a);
                let dac = metric.dist(&a, &c);
                let dbc = metric.dist(&b, &c);
                assert!(dab >= 0.0);
                assert!((dab - dba).abs() < 1e-9, "symmetry");
                assert!(dac <= dab + dbc + 1e-9, "triangle inequality");
                // cosine self-similarity lands at 1 - O(eps); arccos
                // amplifies that to sqrt(2 eps) ~ 1e-8 -> tolerance 1e-6
                assert!(metric.dist(&a, &a) < 1e-6, "identity");
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        for m in [Metric::Euclidean, Metric::Cosine] {
            assert_eq!(Metric::parse(m.name()), Some(m));
        }
        assert_eq!(Metric::parse("nope"), None);
    }
}
