//! Minimum balanced-bipartition weight (bipartition-DMMC objective):
//! `min over Q subset X, |Q| = floor(|X|/2)` of the cut weight between `Q`
//! and `X \ Q`.
//!
//! Exact for k <= EXACT_MAX by enumerating the C(k, floor(k/2)) balanced
//! subsets with bitmask tricks (the objective lives on solution sets of
//! size k, which the paper assumes small).  A swap-descent heuristic is a
//! guarded fallback beyond that.

use crate::core::Dataset;
use crate::diversity::distance_submatrix;

/// Largest k enumerated exactly: C(24,12) ~ 2.7M masks.
pub const EXACT_MAX: usize = 24;

/// Minimum balanced-cut weight of the complete graph over `set`.
pub fn min_bipartition_weight(ds: &Dataset, set: &[usize]) -> f64 {
    let k = set.len();
    let m = distance_submatrix(ds, set);
    min_bipartition_matrix(&m, k, &(0..k).collect::<Vec<_>>())
}

/// Matrix variant over `members` positions of a k*k matrix.
pub fn min_bipartition_matrix(m: &[f64], k: usize, members: &[usize]) -> f64 {
    let s = members.len();
    if s < 2 {
        return 0.0;
    }
    if s <= EXACT_MAX {
        exact(m, k, members)
    } else {
        swap_descent(m, k, members)
    }
}

fn cut_weight(m: &[f64], k: usize, members: &[usize], mask: u32) -> f64 {
    let s = members.len();
    let mut acc = 0.0;
    for a in 0..s {
        if mask >> a & 1 == 0 {
            continue;
        }
        for b in 0..s {
            if mask >> b & 1 == 0 {
                acc += m[members[a] * k + members[b]];
            }
        }
    }
    acc
}

fn exact(m: &[f64], k: usize, members: &[usize]) -> f64 {
    let s = members.len();
    let q = s / 2;
    // iterate over all masks with popcount q that contain member 0 when
    // s is even (halves are symmetric); for odd s the floor-half side is
    // canonical so the full enumeration is needed.
    let mut best = f64::INFINITY;
    let mut mask: u32 = (1u32 << q) - 1;
    let limit: u32 = 1u32 << s;
    while mask < limit {
        let skip = s % 2 == 0 && mask & 1 == 0; // symmetry break for even s
        if !skip {
            let w = cut_weight(m, k, members, mask);
            if w < best {
                best = w;
            }
        }
        // Gosper's hack: next mask with same popcount
        let c = mask & mask.wrapping_neg();
        let r = mask + c;
        if c == 0 || r >= limit {
            break;
        }
        mask = (((r ^ mask) >> 2) / c) | r;
    }
    best
}

/// Local swap descent from a deterministic seed split.
fn swap_descent(m: &[f64], k: usize, members: &[usize]) -> f64 {
    let s = members.len();
    let q = s / 2;
    let mut side = vec![false; s];
    for item in side.iter_mut().take(q) {
        *item = true;
    }
    let d = |a: usize, b: usize| m[members[a] * k + members[b]];
    // cross(a) = sum of distances from a to the opposite side
    let eval = |side: &[bool]| {
        let mut acc = 0.0;
        for a in 0..s {
            if side[a] {
                for b in 0..s {
                    if !side[b] {
                        acc += d(a, b);
                    }
                }
            }
        }
        acc
    };
    let mut best = eval(&side);
    let mut improved = true;
    let mut guard = 0;
    while improved && guard < 64 {
        improved = false;
        guard += 1;
        for a in 0..s {
            if !side[a] {
                continue;
            }
            for b in 0..s {
                if side[b] {
                    continue;
                }
                side[a] = false;
                side[b] = true;
                let w = eval(&side);
                if w < best - 1e-12 {
                    best = w;
                    improved = true;
                    // `a` left the side: the inner scan over `b` is stale
                    break;
                } else {
                    side[a] = true;
                    side[b] = false;
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dataset, Metric};

    fn line(points: &[f32]) -> Dataset {
        Dataset::new(
            1,
            Metric::Euclidean,
            points.to_vec(),
            vec![vec![0]; points.len()],
            1,
            "line",
        )
    }

    #[test]
    fn two_clusters_min_cut_mixes_them() {
        // {0, 1} and {10, 11}: the MINIMUM balanced cut pairs points across
        // clusters, e.g. Q = {0, 10}: d(0,1)+d(0,11)+d(10,1)+d(10,11)
        // = 1+11+9+1 = 22 (Q = {0,1} would give 40 — the max, not the min).
        let ds = line(&[0.0, 1.0, 10.0, 11.0]);
        let w = min_bipartition_weight(&ds, &[0, 1, 2, 3]);
        assert!((w - 22.0).abs() < 1e-9, "{w}");
    }

    #[test]
    fn odd_k_floor_half() {
        // 3 points 0,1,5: |Q|=1. cuts: {0}:1+5=6, {1}:1+4=5, {5}:5+4=9 -> 5
        let ds = line(&[0.0, 1.0, 5.0]);
        let w = min_bipartition_weight(&ds, &[0, 1, 2]);
        assert!((w - 5.0).abs() < 1e-12, "{w}");
    }

    #[test]
    fn exact_matches_bruteforce_k6() {
        let mut r = crate::util::rng::Rng::new(4);
        let pts: Vec<f32> = (0..6).map(|_| r.normal() as f32 * 3.0).collect();
        let ds = line(&pts);
        let set: Vec<usize> = (0..6).collect();
        let fast = min_bipartition_weight(&ds, &set);
        // plain brute force over all 3-subsets
        let m = distance_submatrix(&ds, &set);
        let mut brute = f64::INFINITY;
        for mask in 0u32..64 {
            if mask.count_ones() == 3 {
                brute = brute.min(cut_weight(&m, 6, &[0, 1, 2, 3, 4, 5], mask));
            }
        }
        assert!((fast - brute).abs() < 1e-12);
    }

    #[test]
    fn heuristic_ge_exact() {
        let mut r = crate::util::rng::Rng::new(5);
        let pts: Vec<f32> = (0..10).map(|_| r.normal() as f32).collect();
        let ds = line(&pts);
        let set: Vec<usize> = (0..10).collect();
        let m = distance_submatrix(&ds, &set);
        let members: Vec<usize> = (0..10).collect();
        let ex = exact(&m, 10, &members);
        let heur = swap_descent(&m, 10, &members);
        assert!(heur >= ex - 1e-9);
    }

    #[test]
    fn degenerate() {
        let ds = line(&[0.0, 1.0]);
        assert_eq!(min_bipartition_weight(&ds, &[0]), 0.0);
        let w = min_bipartition_weight(&ds, &[0, 1]);
        assert!((w - 1.0).abs() < 1e-12);
    }
}
