//! Diversity objectives (paper Table 1, plus remote-edge) and
//! average-farness machinery (§3).
//!
//! Every Table-1 objective is a sum of `f(k)` pairwise distances; the
//! coreset radius bound `r <= (eps/4) * rho_{S,k}` of Lemma 2 is
//! expressed through [`farness_lower_bound`] (Lemma 1).  The sixth
//! objective, remote-edge (max-min), is the single smallest pairwise
//! distance rather than a sum; it has no known matroid-constrained
//! approximation algorithm, so the coreset route solves it exhaustively
//! on the root (the libcoral exemplar's own guidance) and GMM-style
//! farthest-point greedy serves as the full-input heuristic.
//!
//! ## Engine-backed evaluation
//!
//! Evaluation runs through the [`DistanceEngine`] runtime, never through
//! point-at-a-time `Dataset::dist` walks.  Backend-dispatch rules:
//!
//! * **sum / star** are one [`DistanceEngine::sums_to_set`] call over the
//!   set.  Those sums use the exact f64 oracle formulas on every CPU
//!   backend (a pinned bit-identity contract) and exclude self-pairs
//!   exactly, so both objectives keep full f64 precision and the Table-1
//!   definitions — `sum = Σ sums / 2`, `star = min sums`.
//! * **tree / cycle / bipartition / remote-edge** consume the dense
//!   submatrix materialized by one [`DistanceEngine::pairwise_block`]
//!   tile.  Tiles are f32 (the PJRT artifact representation), upcast to
//!   f64 for the matrix solvers; CPU backends must produce bit-identical
//!   tiles (with a true-zero diagonal, computed as an upper triangle +
//!   mirror), so these objective values are also engine-independent.
//!
//! [`Evaluator`] carries the engine and exposes the per-objective methods
//! plus [`Evaluator::diversity_all`], which scores all six objectives
//! from a single sums pass + a single tile (no duplicate distance work —
//! pinned by an evaluation-count regression test).  The free functions
//! ([`diversity`], [`sum_diversity`], [`star_diversity`],
//! [`distance_submatrix`]) run the same code paths on a fresh scalar
//! engine, so `diversity(..) == diversity_with_engine(.., scalar)` holds
//! bit for bit.

use anyhow::Result;

use crate::core::Dataset;
use crate::runtime::engine::{DistanceEngine, ScalarEngine};

pub mod bipartition;
pub mod mst;
pub mod tsp;

/// The five DMMC instantiations of Table 1, plus remote-edge (max-min).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Objective {
    /// sum-DMMC: sum of all pairwise distances (a.k.a. max-sum dispersion).
    Sum,
    /// star-DMMC: min over centers c of the star weight around c.
    Star,
    /// tree-DMMC: weight of a minimum spanning tree.
    Tree,
    /// cycle-DMMC: weight of a minimum Hamiltonian cycle (TSP).
    Cycle,
    /// bipartition-DMMC: minimum weight balanced-cut.
    Bipartition,
    /// remote-edge-DMMC: minimum pairwise distance (max-min dispersion).
    RemoteEdge,
}

pub const ALL_OBJECTIVES: [Objective; 6] = [
    Objective::Sum,
    Objective::Star,
    Objective::Tree,
    Objective::Cycle,
    Objective::Bipartition,
    Objective::RemoteEdge,
];

impl Objective {
    pub fn name(self) -> &'static str {
        match self {
            Objective::Sum => "sum",
            Objective::Star => "star",
            Objective::Tree => "tree",
            Objective::Cycle => "cycle",
            Objective::Bipartition => "bipartition",
            Objective::RemoteEdge => "remote-edge",
        }
    }

    pub fn parse(s: &str) -> Option<Objective> {
        ALL_OBJECTIVES.into_iter().find(|o| o.name() == s)
    }

    /// All valid objective names joined with `|`, for parse-error messages
    /// (every surface enumerates the same list, so a new objective can
    /// never be silently missing from one of them).
    pub fn names() -> String {
        ALL_OBJECTIVES
            .iter()
            .map(|o| o.name())
            .collect::<Vec<_>>()
            .join("|")
    }

    /// `f(k)`: the number of distances contributing to the objective (§3).
    pub fn f_k(self, k: usize) -> f64 {
        match self {
            Objective::Sum => (k * k.saturating_sub(1)) as f64 / 2.0,
            Objective::Star | Objective::Tree => k.saturating_sub(1) as f64,
            Objective::Cycle => k as f64,
            Objective::Bipartition => ((k / 2) * k.div_ceil(2)) as f64,
            // the max-min objective is a single edge, not a sum
            Objective::RemoteEdge => 1.0,
        }
    }

    /// Lemma 1 lower bound on the average farness `rho_{S,k}` as a multiple
    /// of the dataset diameter: returns `c` with `rho >= c * diameter`.
    pub fn farness_coefficient(self, k: usize) -> f64 {
        assert!(k > 1, "farness defined for k > 1");
        match self {
            Objective::Sum => 1.0 / (2.0 * k as f64),
            Objective::Star => 1.0 / (4.0 * (k as f64 - 1.0)),
            Objective::Tree => 1.0 / (2.0 * (k as f64 - 1.0)),
            Objective::Cycle => 1.0 / k as f64,
            Objective::Bipartition => 1.0 / (2.0 * (k as f64 + 1.0)),
            // Remote-edge is outside the Lemma-1 sum family; the GMM
            // anchor-set argument gives the same Delta/(2(k-1)) floor as
            // tree (any k points contain a pair at most that far below
            // the diameter-spanning pair), which is what the exemplar
            // uses to size the coreset radius for max-min.
            Objective::RemoteEdge => 1.0 / (2.0 * (k as f64 - 1.0)),
        }
    }
}

/// Lemma 1: `rho_{S,k} >= farness_coefficient * Delta_S`.
pub fn farness_lower_bound(obj: Objective, k: usize, diameter: f64) -> f64 {
    obj.farness_coefficient(k) * diameter
}

/// Engine-backed evaluator for the six objectives (Table 1 + remote-edge).
///
/// Wraps a [`DistanceEngine`] and dispatches every objective to the
/// batched engine shapes (see the module docs for the dispatch rules).
/// Construct one per evaluation site — it holds no per-dataset state, the
/// engine does.
pub struct Evaluator<'e> {
    engine: &'e dyn DistanceEngine,
}

impl<'e> Evaluator<'e> {
    pub fn new(engine: &'e dyn DistanceEngine) -> Evaluator<'e> {
        Evaluator { engine }
    }

    pub fn engine(&self) -> &'e dyn DistanceEngine {
        self.engine
    }

    /// Dense distance matrix over `set` (row-major `set.len()^2`) from one
    /// [`DistanceEngine::pairwise_block`] tile, upcast to f64 for the
    /// matrix solvers.
    pub fn submatrix(&self, ds: &Dataset, set: &[usize]) -> Result<Vec<f64>> {
        let tile = self.engine.pairwise_block(ds, set, set)?;
        Ok(tile.into_iter().map(f64::from).collect())
    }

    /// Sum of all pairwise distances (exact f64 via one batched sums pass).
    pub fn sum(&self, ds: &Dataset, set: &[usize]) -> Result<f64> {
        if set.len() < 2 {
            return Ok(0.0);
        }
        let sums = self.engine.sums_to_set(ds, set, set)?;
        Ok(sums.iter().sum::<f64>() / 2.0)
    }

    /// min over c in X of sum_{u != c} d(c, u).  The engine contract
    /// excludes self-pairs from the sums exactly, so the batched
    /// per-member sums are exactly the star weights.
    pub fn star(&self, ds: &Dataset, set: &[usize]) -> Result<f64> {
        if set.len() < 2 {
            return Ok(0.0);
        }
        let sums = self.engine.sums_to_set(ds, set, set)?;
        Ok(sums.iter().copied().fold(f64::INFINITY, f64::min))
    }

    /// MST weight over `set` from an engine-built submatrix.
    pub fn tree(&self, ds: &Dataset, set: &[usize]) -> Result<f64> {
        let m = self.submatrix(ds, set)?;
        Ok(mst::mst_weight_matrix(&m, set.len(), &positions(set.len())))
    }

    /// Minimum Hamiltonian cycle weight over `set` from an engine-built
    /// submatrix.
    pub fn cycle(&self, ds: &Dataset, set: &[usize]) -> Result<f64> {
        let m = self.submatrix(ds, set)?;
        Ok(tsp::tsp_weight_matrix(&m, set.len(), &positions(set.len())))
    }

    /// Minimum balanced-cut weight over `set` from an engine-built
    /// submatrix.
    pub fn bipartition(&self, ds: &Dataset, set: &[usize]) -> Result<f64> {
        let m = self.submatrix(ds, set)?;
        Ok(bipartition::min_bipartition_matrix(
            &m,
            set.len(),
            &positions(set.len()),
        ))
    }

    /// Minimum pairwise distance over `set` (remote-edge / max-min) from
    /// an engine-built submatrix.
    pub fn remote_edge(&self, ds: &Dataset, set: &[usize]) -> Result<f64> {
        let m = self.submatrix(ds, set)?;
        Ok(remote_edge_from_matrix(
            &m,
            set.len(),
            &positions(set.len()),
        ))
    }

    /// Evaluate one objective.
    pub fn diversity(&self, ds: &Dataset, set: &[usize], obj: Objective) -> Result<f64> {
        match obj {
            Objective::Sum => self.sum(ds, set),
            Objective::Star => self.star(ds, set),
            Objective::Tree => self.tree(ds, set),
            Objective::Cycle => self.cycle(ds, set),
            Objective::Bipartition => self.bipartition(ds, set),
            Objective::RemoteEdge => self.remote_edge(ds, set),
        }
    }

    /// All six objective values (in [`ALL_OBJECTIVES`] order) from one
    /// sums pass (`k(k-1)` distance evaluations) + one symmetric tile
    /// (`k(k-1)/2` more), where scoring the objectives one by one would
    /// re-walk the pairwise distances per objective.
    pub fn diversity_all(&self, ds: &Dataset, set: &[usize]) -> Result<[f64; 6]> {
        let k = set.len();
        let (sum, star) = if k < 2 {
            (0.0, 0.0)
        } else {
            let sums = self.engine.sums_to_set(ds, set, set)?;
            (
                sums.iter().sum::<f64>() / 2.0,
                sums.iter().copied().fold(f64::INFINITY, f64::min),
            )
        };
        let m = self.submatrix(ds, set)?;
        let members = positions(k);
        Ok([
            sum,
            star,
            mst::mst_weight_matrix(&m, k, &members),
            tsp::tsp_weight_matrix(&m, k, &members),
            bipartition::min_bipartition_matrix(&m, k, &members),
            remote_edge_from_matrix(&m, k, &members),
        ])
    }
}

/// `[0, 1, .., k)` — the identity member list for whole-matrix solvers.
fn positions(k: usize) -> Vec<usize> {
    (0..k).collect()
}

/// Evaluate the diversity of `set` under `obj` through `engine` (see the
/// sub-modules for the cycle/bipartition algorithms and their size guards).
pub fn diversity_with_engine(
    ds: &Dataset,
    set: &[usize],
    obj: Objective,
    engine: &dyn DistanceEngine,
) -> Result<f64> {
    Evaluator::new(engine).diversity(ds, set, obj)
}

/// Evaluate the diversity of `set` under `obj` on a fresh scalar engine —
/// bit-identical to [`diversity_with_engine`] on any CPU backend.
pub fn diversity(ds: &Dataset, set: &[usize], obj: Objective) -> f64 {
    diversity_with_engine(ds, set, obj, &ScalarEngine::new())
        .expect("scalar engine evaluation cannot fail")
}

/// Sum of all pairwise distances, through `engine`.
pub fn sum_diversity_with_engine(
    ds: &Dataset,
    set: &[usize],
    engine: &dyn DistanceEngine,
) -> Result<f64> {
    Evaluator::new(engine).sum(ds, set)
}

/// Sum of all pairwise distances (scalar engine).
pub fn sum_diversity(ds: &Dataset, set: &[usize]) -> f64 {
    sum_diversity_with_engine(ds, set, &ScalarEngine::new())
        .expect("scalar engine evaluation cannot fail")
}

/// min over c in X of sum_{u != c} d(c, u), through `engine`.
pub fn star_diversity_with_engine(
    ds: &Dataset,
    set: &[usize],
    engine: &dyn DistanceEngine,
) -> Result<f64> {
    Evaluator::new(engine).star(ds, set)
}

/// min over c in X of sum_{u != c} d(c, u) (scalar engine).
pub fn star_diversity(ds: &Dataset, set: &[usize]) -> f64 {
    star_diversity_with_engine(ds, set, &ScalarEngine::new())
        .expect("scalar engine evaluation cannot fail")
}

/// Dense distance matrix over `set` (row-major `set.len()^2`), shared by
/// the exact solvers and the exhaustive search on coresets — the scalar
/// engine's [`Evaluator::submatrix`].
pub fn distance_submatrix(ds: &Dataset, set: &[usize]) -> Vec<f64> {
    Evaluator::new(&ScalarEngine::new())
        .submatrix(ds, set)
        .expect("scalar engine evaluation cannot fail")
}

/// Evaluate `obj` over the `members` positions of a precomputed `k * k`
/// distance matrix (e.g. one built by [`Evaluator::submatrix`] over a
/// candidate pool) — zero distance evaluations.
pub fn diversity_from_matrix(m: &[f64], k: usize, members: &[usize], obj: Objective) -> f64 {
    match obj {
        Objective::Sum => sum_from_matrix(m, k, members),
        Objective::Star => star_from_matrix(m, k, members),
        Objective::Tree => mst::mst_weight_matrix(m, k, members),
        Objective::Cycle => tsp::tsp_weight_matrix(m, k, members),
        Objective::Bipartition => bipartition::min_bipartition_matrix(m, k, members),
        Objective::RemoteEdge => remote_edge_from_matrix(m, k, members),
    }
}

/// Sum objective over matrix positions.
pub fn sum_from_matrix(m: &[f64], k: usize, members: &[usize]) -> f64 {
    let mut acc = 0.0;
    for (a, &i) in members.iter().enumerate() {
        for &j in &members[a + 1..] {
            acc += m[i * k + j];
        }
    }
    acc
}

/// Remote-edge objective over matrix positions: minimum distance among
/// the strict upper triangle (0.0 below two members, matching the other
/// degenerate-set conventions).
pub fn remote_edge_from_matrix(m: &[f64], k: usize, members: &[usize]) -> f64 {
    if members.len() < 2 {
        return 0.0;
    }
    let mut best = f64::INFINITY;
    for (a, &i) in members.iter().enumerate() {
        for &j in &members[a + 1..] {
            best = best.min(m[i * k + j]);
        }
    }
    best
}

/// Star objective over matrix positions (the zero diagonal makes each row
/// sum a star weight).
pub fn star_from_matrix(m: &[f64], k: usize, members: &[usize]) -> f64 {
    if members.len() < 2 {
        return 0.0;
    }
    let mut best = f64::INFINITY;
    for &c in members {
        let s: f64 = members.iter().map(|&u| m[c * k + u]).sum();
        best = best.min(s);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dataset, Metric};

    /// 4 points on a line: 0, 1, 3, 7.
    fn line() -> Dataset {
        Dataset::new(
            1,
            Metric::Euclidean,
            vec![0.0, 1.0, 3.0, 7.0],
            vec![vec![0]; 4],
            1,
            "line",
        )
    }

    #[test]
    fn f_k_values() {
        assert_eq!(Objective::Sum.f_k(5), 10.0);
        assert_eq!(Objective::Star.f_k(5), 4.0);
        assert_eq!(Objective::Tree.f_k(5), 4.0);
        assert_eq!(Objective::Cycle.f_k(5), 5.0);
        assert_eq!(Objective::Bipartition.f_k(5), 6.0); // 2*3
        assert_eq!(Objective::Bipartition.f_k(4), 4.0); // 2*2
        assert_eq!(Objective::RemoteEdge.f_k(5), 1.0);
    }

    #[test]
    fn remote_edge_line() {
        let ds = line();
        // closest pair among {0, 1, 3, 7} is (0, 1)
        assert!((diversity(&ds, &[0, 1, 2, 3], Objective::RemoteEdge) - 1.0).abs() < 1e-12);
        // dropping point 1 makes (1, 3) the closest remaining pair
        assert!((diversity(&ds, &[0, 2, 3], Objective::RemoteEdge) - 3.0).abs() < 1e-12);
        let m = distance_submatrix(&ds, &[0, 1, 2, 3]);
        assert!((remote_edge_from_matrix(&m, 4, &[0, 3]) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn objective_names_round_trip_and_enumerate() {
        for obj in ALL_OBJECTIVES {
            assert_eq!(Objective::parse(obj.name()), Some(obj));
        }
        assert_eq!(Objective::parse("remote-edge"), Some(Objective::RemoteEdge));
        assert_eq!(Objective::parse("maxmin"), None);
        assert_eq!(
            Objective::names(),
            "sum|star|tree|cycle|bipartition|remote-edge"
        );
    }

    #[test]
    fn sum_diversity_line() {
        let ds = line();
        // pairs: 1+3+7 + 2+6 + 4 = 23
        assert!((sum_diversity(&ds, &[0, 1, 2, 3]) - 23.0).abs() < 1e-12);
    }

    #[test]
    fn star_diversity_line() {
        let ds = line();
        // center 1 minimizes: d(1,0)+d(1,3)+d(1,7) = 1+2+6 = 9
        assert!((star_diversity(&ds, &[0, 1, 2, 3]) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn diversity_dispatch_matches_directs() {
        let ds = line();
        let set = [0usize, 1, 2, 3];
        assert_eq!(diversity(&ds, &set, Objective::Sum), sum_diversity(&ds, &set));
        assert_eq!(
            diversity(&ds, &set, Objective::Tree),
            mst::mst_weight(&ds, &set)
        );
    }

    #[test]
    fn farness_coefficients_positive_and_ordered() {
        for obj in ALL_OBJECTIVES {
            for k in 2..20 {
                let c = obj.farness_coefficient(k);
                assert!(c > 0.0 && c <= 1.0);
            }
        }
        // tree bound is twice the star bound (Lemma 1)
        assert!(
            (Objective::Tree.farness_coefficient(5)
                - 2.0 * Objective::Star.farness_coefficient(5))
            .abs()
                < 1e-12
        );
    }

    #[test]
    fn submatrix_symmetric_zero_diag() {
        let ds = line();
        let m = distance_submatrix(&ds, &[0, 2, 3]);
        assert_eq!(m[0], 0.0);
        assert_eq!(m[1], m[3]);
        assert!((m[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_small_sets() {
        let ds = line();
        assert_eq!(sum_diversity(&ds, &[0]), 0.0);
        assert_eq!(star_diversity(&ds, &[0]), 0.0);
        assert_eq!(diversity(&ds, &[], Objective::Sum), 0.0);
        for obj in ALL_OBJECTIVES {
            assert_eq!(diversity(&ds, &[], obj), 0.0, "{obj:?} on empty set");
            assert_eq!(diversity(&ds, &[2], obj), 0.0, "{obj:?} on singleton");
        }
    }

    #[test]
    fn evaluator_matches_free_functions_bitwise() {
        let ds = line();
        let e = ScalarEngine::new();
        let ev = Evaluator::new(&e);
        let set = [0usize, 1, 2, 3];
        for obj in ALL_OBJECTIVES {
            let via_ev = ev.diversity(&ds, &set, obj).unwrap();
            let via_free = diversity(&ds, &set, obj);
            assert!(
                via_ev.to_bits() == via_free.to_bits(),
                "{obj:?}: {via_ev} != {via_free}"
            );
        }
        assert_eq!(ev.submatrix(&ds, &set).unwrap(), distance_submatrix(&ds, &set));
    }

    #[test]
    fn diversity_all_consistent_with_single_objective_paths() {
        let ds = line();
        let e = ScalarEngine::new();
        let ev = Evaluator::new(&e);
        let set = [0usize, 1, 2, 3];
        let all = ev.diversity_all(&ds, &set).unwrap();
        for (i, obj) in ALL_OBJECTIVES.into_iter().enumerate() {
            let single = ev.diversity(&ds, &set, obj).unwrap();
            assert!(
                all[i].to_bits() == single.to_bits(),
                "{obj:?}: batched {} != single {}",
                all[i],
                single
            );
        }
    }

    #[test]
    fn diversity_all_deduplicates_distance_work() {
        // one sums pass (k(k-1)) + one symmetric tile (k(k-1)/2) for all
        // six objectives; the pre-evaluator code re-walked Dataset::dist
        // per objective (and per star center)
        let ds = line();
        let e = ScalarEngine::new();
        let ev = Evaluator::new(&e);
        let set = [0usize, 1, 2, 3];
        ev.diversity_all(&ds, &set).unwrap();
        assert_eq!(e.dist_evals(), 12 + 6);
        e.reset_dist_evals();
        ev.submatrix(&ds, &set).unwrap();
        assert_eq!(e.dist_evals(), 6);
    }

    #[test]
    fn matrix_sum_star_match_engine_paths() {
        let ds = line();
        let set = [0usize, 1, 2, 3];
        let m = distance_submatrix(&ds, &set);
        let members = [0usize, 1, 2, 3];
        // the line() distances are small integers: exact in f32, so the
        // matrix path reproduces the sums path exactly here
        assert!((sum_from_matrix(&m, 4, &members) - sum_diversity(&ds, &set)).abs() < 1e-12);
        assert!((star_from_matrix(&m, 4, &members) - star_diversity(&ds, &set)).abs() < 1e-12);
        // sub-selection: positions 0 and 3 (points 0 and 7)
        assert!((sum_from_matrix(&m, 4, &[0, 3]) - 7.0).abs() < 1e-12);
        assert!((star_from_matrix(&m, 4, &[0, 3]) - 7.0).abs() < 1e-12);
    }
}
