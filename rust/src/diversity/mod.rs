//! Diversity objectives (paper Table 1) and average-farness machinery (§3).
//!
//! Every objective is a sum of `f(k)` pairwise distances; the coreset radius
//! bound `r <= (eps/4) * rho_{S,k}` of Lemma 2 is expressed through
//! [`farness_lower_bound`] (Lemma 1).

use crate::core::Dataset;

pub mod bipartition;
pub mod mst;
pub mod tsp;

/// The five DMMC instantiations of Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Objective {
    /// sum-DMMC: sum of all pairwise distances (a.k.a. max-sum dispersion).
    Sum,
    /// star-DMMC: min over centers c of the star weight around c.
    Star,
    /// tree-DMMC: weight of a minimum spanning tree.
    Tree,
    /// cycle-DMMC: weight of a minimum Hamiltonian cycle (TSP).
    Cycle,
    /// bipartition-DMMC: minimum weight balanced-cut.
    Bipartition,
}

pub const ALL_OBJECTIVES: [Objective; 5] = [
    Objective::Sum,
    Objective::Star,
    Objective::Tree,
    Objective::Cycle,
    Objective::Bipartition,
];

impl Objective {
    pub fn name(self) -> &'static str {
        match self {
            Objective::Sum => "sum",
            Objective::Star => "star",
            Objective::Tree => "tree",
            Objective::Cycle => "cycle",
            Objective::Bipartition => "bipartition",
        }
    }

    pub fn parse(s: &str) -> Option<Objective> {
        ALL_OBJECTIVES.into_iter().find(|o| o.name() == s)
    }

    /// `f(k)`: the number of distances contributing to the objective (§3).
    pub fn f_k(self, k: usize) -> f64 {
        match self {
            Objective::Sum => (k * k.saturating_sub(1)) as f64 / 2.0,
            Objective::Star | Objective::Tree => k.saturating_sub(1) as f64,
            Objective::Cycle => k as f64,
            Objective::Bipartition => ((k / 2) * k.div_ceil(2)) as f64,
        }
    }

    /// Lemma 1 lower bound on the average farness `rho_{S,k}` as a multiple
    /// of the dataset diameter: returns `c` with `rho >= c * diameter`.
    pub fn farness_coefficient(self, k: usize) -> f64 {
        assert!(k > 1, "farness defined for k > 1");
        match self {
            Objective::Sum => 1.0 / (2.0 * k as f64),
            Objective::Star => 1.0 / (4.0 * (k as f64 - 1.0)),
            Objective::Tree => 1.0 / (2.0 * (k as f64 - 1.0)),
            Objective::Cycle => 1.0 / k as f64,
            Objective::Bipartition => 1.0 / (2.0 * (k as f64 + 1.0)),
        }
    }
}

/// Lemma 1: `rho_{S,k} >= farness_coefficient * Delta_S`.
pub fn farness_lower_bound(obj: Objective, k: usize, diameter: f64) -> f64 {
    obj.farness_coefficient(k) * diameter
}

/// Evaluate the diversity of `set` under `obj` (exact solvers; see the
/// sub-modules for the cycle/bipartition algorithms and their size guards).
pub fn diversity(ds: &Dataset, set: &[usize], obj: Objective) -> f64 {
    match obj {
        Objective::Sum => sum_diversity(ds, set),
        Objective::Star => star_diversity(ds, set),
        Objective::Tree => mst::mst_weight(ds, set),
        Objective::Cycle => tsp::tsp_weight(ds, set),
        Objective::Bipartition => bipartition::min_bipartition_weight(ds, set),
    }
}

/// Sum of all pairwise distances.
pub fn sum_diversity(ds: &Dataset, set: &[usize]) -> f64 {
    let mut acc = 0.0;
    for (a, &i) in set.iter().enumerate() {
        for &j in &set[a + 1..] {
            acc += ds.dist(i, j);
        }
    }
    acc
}

/// min over c in X of sum_{u != c} d(c, u).
pub fn star_diversity(ds: &Dataset, set: &[usize]) -> f64 {
    if set.len() < 2 {
        return 0.0;
    }
    let mut best = f64::INFINITY;
    for &c in set {
        let mut s = 0.0;
        for &u in set {
            if u != c {
                s += ds.dist(c, u);
            }
        }
        best = best.min(s);
    }
    best
}

/// Dense distance matrix over `set` (row-major `set.len()^2`), shared by
/// the exact solvers and the local search on coresets.
pub fn distance_submatrix(ds: &Dataset, set: &[usize]) -> Vec<f64> {
    let k = set.len();
    let mut m = vec![0.0f64; k * k];
    for a in 0..k {
        for b in (a + 1)..k {
            let d = ds.dist(set[a], set[b]);
            m[a * k + b] = d;
            m[b * k + a] = d;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dataset, Metric};

    /// 4 points on a line: 0, 1, 3, 7.
    fn line() -> Dataset {
        Dataset::new(
            1,
            Metric::Euclidean,
            vec![0.0, 1.0, 3.0, 7.0],
            vec![vec![0]; 4],
            1,
            "line",
        )
    }

    #[test]
    fn f_k_values() {
        assert_eq!(Objective::Sum.f_k(5), 10.0);
        assert_eq!(Objective::Star.f_k(5), 4.0);
        assert_eq!(Objective::Tree.f_k(5), 4.0);
        assert_eq!(Objective::Cycle.f_k(5), 5.0);
        assert_eq!(Objective::Bipartition.f_k(5), 6.0); // 2*3
        assert_eq!(Objective::Bipartition.f_k(4), 4.0); // 2*2
    }

    #[test]
    fn sum_diversity_line() {
        let ds = line();
        // pairs: 1+3+7 + 2+6 + 4 = 23
        assert!((sum_diversity(&ds, &[0, 1, 2, 3]) - 23.0).abs() < 1e-12);
    }

    #[test]
    fn star_diversity_line() {
        let ds = line();
        // center 1 minimizes: d(1,0)+d(1,3)+d(1,7) = 1+2+6 = 9
        assert!((star_diversity(&ds, &[0, 1, 2, 3]) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn diversity_dispatch_matches_directs() {
        let ds = line();
        let set = [0usize, 1, 2, 3];
        assert_eq!(diversity(&ds, &set, Objective::Sum), sum_diversity(&ds, &set));
        assert_eq!(
            diversity(&ds, &set, Objective::Tree),
            mst::mst_weight(&ds, &set)
        );
    }

    #[test]
    fn farness_coefficients_positive_and_ordered() {
        for obj in ALL_OBJECTIVES {
            for k in 2..20 {
                let c = obj.farness_coefficient(k);
                assert!(c > 0.0 && c <= 1.0);
            }
        }
        // tree bound is twice the star bound (Lemma 1)
        assert!(
            (Objective::Tree.farness_coefficient(5)
                - 2.0 * Objective::Star.farness_coefficient(5))
            .abs()
                < 1e-12
        );
    }

    #[test]
    fn submatrix_symmetric_zero_diag() {
        let ds = line();
        let m = distance_submatrix(&ds, &[0, 2, 3]);
        assert_eq!(m[0], 0.0);
        assert_eq!(m[1], m[3]);
        assert!((m[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_small_sets() {
        let ds = line();
        assert_eq!(sum_diversity(&ds, &[0]), 0.0);
        assert_eq!(star_diversity(&ds, &[0]), 0.0);
        assert_eq!(diversity(&ds, &[], Objective::Sum), 0.0);
    }
}
