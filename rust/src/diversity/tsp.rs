//! Minimum Hamiltonian cycle weight (cycle-DMMC objective).
//!
//! Exact Held-Karp dynamic programming for k <= HELD_KARP_MAX (O(2^k k^2)
//! time, O(2^k k) space), nearest-neighbour + 2-opt refinement beyond that.
//! The paper's cycle-DMMC evaluation runs on solution sets of size k, which
//! is small by assumption ("for small values of k, a range of definite
//! interest"), so the exact path is the one that matters; the heuristic is
//! a guarded fallback and is clearly labelled as such.

use crate::core::Dataset;
use crate::diversity::distance_submatrix;

/// Largest k solved exactly. 2^15 * 15 * 8 bytes ~ 4 MB of DP table.
pub const HELD_KARP_MAX: usize = 15;

/// Weight of a minimum-weight Hamiltonian cycle over `set`.
/// |set| < 2 -> 0; |set| == 2 -> 2*d (the paper's two-anti-parallel-edges
/// convention, consistent with "two edge-disjoint paths" in Lemma 1).
pub fn tsp_weight(ds: &Dataset, set: &[usize]) -> f64 {
    let k = set.len();
    let m = distance_submatrix(ds, set);
    tsp_weight_matrix(&m, k, &(0..k).collect::<Vec<_>>())
}

/// TSP weight from a precomputed k*k matrix over `members` positions.
pub fn tsp_weight_matrix(m: &[f64], k: usize, members: &[usize]) -> f64 {
    let s = members.len();
    match s {
        0 | 1 => 0.0,
        2 => 2.0 * m[members[0] * k + members[1]],
        3 => {
            let (a, b, c) = (members[0], members[1], members[2]);
            m[a * k + b] + m[b * k + c] + m[c * k + a]
        }
        _ if s <= HELD_KARP_MAX => held_karp(m, k, members),
        _ => two_opt(m, k, members),
    }
}

/// Exact Held-Karp: dp[mask][j] = cheapest path visiting `mask`, ending at j,
/// starting at member 0.
fn held_karp(m: &[f64], k: usize, members: &[usize]) -> f64 {
    let s = members.len();
    let full = 1usize << s;
    let d = |a: usize, b: usize| m[members[a] * k + members[b]];
    let mut dp = vec![f64::INFINITY; full * s];
    dp[(1 << 0) * s] = 0.0; // mask {0}, end 0
    for mask in 1..full {
        if mask & 1 == 0 {
            continue; // paths always contain member 0
        }
        for last in 0..s {
            if mask >> last & 1 == 0 {
                continue;
            }
            let cur = dp[mask * s + last];
            if !cur.is_finite() {
                continue;
            }
            for next in 1..s {
                if mask >> next & 1 == 1 {
                    continue;
                }
                let nmask = mask | (1 << next);
                let cand = cur + d(last, next);
                if cand < dp[nmask * s + next] {
                    dp[nmask * s + next] = cand;
                }
            }
        }
    }
    let mut best = f64::INFINITY;
    for last in 1..s {
        let cand = dp[(full - 1) * s + last] + d(last, 0);
        if cand < best {
            best = cand;
        }
    }
    best
}

/// Nearest-neighbour construction + 2-opt improvement (heuristic fallback
/// for k > HELD_KARP_MAX).  Deterministic: starts from member 0.
fn two_opt(m: &[f64], k: usize, members: &[usize]) -> f64 {
    let s = members.len();
    let d = |a: usize, b: usize| m[members[a] * k + members[b]];
    // nearest neighbour tour
    let mut tour: Vec<usize> = Vec::with_capacity(s);
    let mut used = vec![false; s];
    tour.push(0);
    used[0] = true;
    for _ in 1..s {
        let last = *tour.last().unwrap();
        let mut pick = usize::MAX;
        let mut pick_d = f64::INFINITY;
        for j in 0..s {
            if !used[j] && d(last, j) < pick_d {
                pick = j;
                pick_d = d(last, j);
            }
        }
        tour.push(pick);
        used[pick] = true;
    }
    // 2-opt until no improvement (bounded passes for safety)
    let mut improved = true;
    let mut guard = 0;
    while improved && guard < 64 {
        improved = false;
        guard += 1;
        for i in 0..s - 1 {
            for j in i + 2..s {
                if i == 0 && j == s - 1 {
                    continue;
                }
                let (a, b) = (tour[i], tour[i + 1]);
                let (c, e) = (tour[j], tour[(j + 1) % s]);
                let delta = d(a, c) + d(b, e) - d(a, b) - d(c, e);
                if delta < -1e-12 {
                    tour[i + 1..=j].reverse();
                    improved = true;
                }
            }
        }
    }
    (0..s).map(|i| d(tour[i], tour[(i + 1) % s])).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dataset, Metric};
    use crate::diversity::mst::mst_weight;

    fn square() -> Dataset {
        Dataset::new(
            2,
            Metric::Euclidean,
            vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0, 0.0, 1.0],
            vec![vec![0]; 4],
            1,
            "square",
        )
    }

    #[test]
    fn unit_square_cycle_is_four() {
        let ds = square();
        assert!((tsp_weight(&ds, &[0, 1, 2, 3]) - 4.0).abs() < 1e-9);
        // order of the input set must not matter
        assert!((tsp_weight(&ds, &[2, 0, 3, 1]) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn two_points_counted_twice() {
        let ds = square();
        assert!((tsp_weight(&ds, &[0, 1]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn triangle_is_perimeter() {
        let ds = square();
        // the submatrix is an f32 tile: compare against f32-narrowed edges
        let expect = ds.dist(0, 1) as f32 as f64
            + ds.dist(1, 2) as f32 as f64
            + ds.dist(2, 0) as f32 as f64;
        assert!((tsp_weight(&ds, &[0, 1, 2]) - expect).abs() < 1e-12);
    }

    #[test]
    fn mst_leq_tsp_leq_two_mst() {
        // classic bounds; also ties the heuristic to a provable window
        let mut coords = Vec::new();
        let mut r = crate::util::rng::Rng::new(3);
        for _ in 0..12 {
            coords.push(r.normal() as f32);
            coords.push(r.normal() as f32);
        }
        let ds = Dataset::new(2, Metric::Euclidean, coords, vec![vec![0]; 12], 1, "rand");
        let set: Vec<usize> = (0..12).collect();
        let mst = mst_weight(&ds, &set);
        let tsp = tsp_weight(&ds, &set);
        // 1e-6 slack: both run on the f32 tile, whose rounding can bend
        // the doubling argument's triangle inequalities by ~1e-7 relative
        assert!(tsp >= mst - 1e-6, "tsp {tsp} < mst {mst}");
        assert!(tsp <= 2.0 * mst + 1e-6, "tsp {tsp} > 2mst {mst}");
    }

    #[test]
    fn heuristic_respects_exact_on_boundary() {
        // build 16 random points (heuristic path) and compare against
        // held-karp on the first 10 (exact path) for consistency of plumbing
        let mut r = crate::util::rng::Rng::new(9);
        let coords: Vec<f32> = (0..32).map(|_| r.normal() as f32).collect();
        let ds = Dataset::new(2, Metric::Euclidean, coords, vec![vec![0]; 16], 1, "rand");
        let exact_set: Vec<usize> = (0..10).collect();
        let exact = tsp_weight(&ds, &exact_set);
        // 2-opt on the same 10 points must be >= exact
        let m = distance_submatrix(&ds, &exact_set);
        let heur = super::two_opt(&m, 10, &(0..10).collect::<Vec<_>>());
        assert!(heur >= exact - 1e-9);
        assert!(heur <= exact * 1.3 + 1e-9, "2-opt unusually bad: {heur} vs {exact}");
    }

    #[test]
    fn degenerate() {
        let ds = square();
        assert_eq!(tsp_weight(&ds, &[]), 0.0);
        assert_eq!(tsp_weight(&ds, &[2]), 0.0);
    }
}
