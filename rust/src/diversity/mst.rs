//! Exact minimum spanning tree weight (tree-DMMC objective) via Prim's
//! algorithm in O(k^2) — k is a solution size, so dense Prim beats any
//! heap-based variant here.

use crate::core::Dataset;
use crate::diversity::distance_submatrix;

/// Weight of the MST of the complete graph on `set` with pairwise-distance
/// edge weights (engine-built submatrix; see the module docs of
/// [`crate::diversity`] for the dispatch rules).  Returns 0 for |set| < 2.
pub fn mst_weight(ds: &Dataset, set: &[usize]) -> f64 {
    let k = set.len();
    let m = distance_submatrix(ds, set);
    mst_weight_matrix(&m, k, &(0..k).collect::<Vec<_>>())
}

/// MST weight from a precomputed dense matrix (row-major k*k), used by the
/// exhaustive search to avoid re-deriving distances per candidate subset.
pub fn mst_weight_matrix(m: &[f64], k: usize, members: &[usize]) -> f64 {
    let s = members.len();
    if s < 2 {
        return 0.0;
    }
    let mut in_tree = vec![false; s];
    let mut best = vec![f64::INFINITY; s];
    in_tree[0] = true;
    for j in 1..s {
        best[j] = m[members[0] * k + members[j]];
    }
    let mut total = 0.0;
    for _ in 1..s {
        let mut pick = usize::MAX;
        let mut pick_d = f64::INFINITY;
        for j in 0..s {
            if !in_tree[j] && best[j] < pick_d {
                pick = j;
                pick_d = best[j];
            }
        }
        in_tree[pick] = true;
        total += pick_d;
        for j in 0..s {
            if !in_tree[j] {
                let d = m[members[pick] * k + members[j]];
                if d < best[j] {
                    best[j] = d;
                }
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dataset, Metric};
    use crate::diversity::distance_submatrix;

    fn square() -> Dataset {
        // unit square corners
        Dataset::new(
            2,
            Metric::Euclidean,
            vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
            vec![vec![0]; 4],
            1,
            "square",
        )
    }

    #[test]
    fn unit_square_mst_is_three() {
        let ds = square();
        assert!((mst_weight(&ds, &[0, 1, 2, 3]) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn line_mst() {
        let ds = Dataset::new(
            1,
            Metric::Euclidean,
            vec![0.0, 1.0, 3.0, 7.0],
            vec![vec![0]; 4],
            1,
            "line",
        );
        // MST on a line = span = 7
        assert!((mst_weight(&ds, &[0, 1, 2, 3]) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn matrix_variant_agrees() {
        let ds = square();
        let set = [0usize, 1, 2, 3];
        let m = distance_submatrix(&ds, &set);
        let via_matrix = mst_weight_matrix(&m, 4, &[0, 1, 2, 3]);
        assert!((via_matrix - mst_weight(&ds, &set)).abs() < 1e-12);
        // and on a sub-selection; the tile is f32, so compare against the
        // f32-narrowed oracle distance
        let sub = mst_weight_matrix(&m, 4, &[0, 3]);
        assert!((sub - ds.dist(0, 3) as f32 as f64).abs() < 1e-12);
    }

    #[test]
    fn degenerate() {
        let ds = square();
        assert_eq!(mst_weight(&ds, &[0]), 0.0);
        assert_eq!(mst_weight(&ds, &[]), 0.0);
    }

    #[test]
    fn mst_leq_any_spanning_path() {
        let ds = square();
        let set = [0usize, 1, 3, 2];
        let path: f64 = (0..3).map(|i| ds.dist(set[i], set[i + 1])).sum();
        assert!(mst_weight(&ds, &set) <= path + 1e-12);
    }
}
