//! Hand-rolled CLI argument parsing (no clap in the offline image).
//!
//! Grammar: `dmmc <subcommand> [positional ...] [--key value | --key=value |
//! --flag] ...`.  Unknown-flag detection is the caller's job via
//! [`Args::expect_known`].

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: BTreeSet<String>,
}

impl Args {
    /// Parse `argv[1..]` (i.e. without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        if let Some(sub) = iter.next() {
            if sub.starts_with('-') {
                bail!("expected a subcommand, got flag {sub}");
            }
            out.subcommand = sub;
        }
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare -- not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains(name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.opt(name).with_context(|| format!("missing required --{name}"))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad usize {v}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad u64 {v}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad f64 {v}")),
        }
    }

    /// Error on any option/flag outside `known` (catches typos).
    pub fn expect_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

/// Row-list grammar shared by `index delete --rows` and the serve
/// protocol's `DELETE` request: comma-separated entries, each a single
/// row `N` or a half-open range `A..B`.
pub fn parse_rows(s: &str) -> Result<Vec<usize>> {
    let mut out: Vec<usize> = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once("..") {
            let a: usize = a.parse().with_context(|| format!("bad range start {part:?}"))?;
            let b: usize = b.parse().with_context(|| format!("bad range end {part:?}"))?;
            if a >= b {
                bail!("empty range {part:?} (ranges are half-open A..B with A < B)");
            }
            out.extend(a..b);
        } else {
            out.push(part.parse().with_context(|| format!("bad row {part:?}"))?);
        }
    }
    if out.is_empty() {
        bail!("row list names no rows (grammar: N or A..B, comma-separated)");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // NB: a non-`--` token directly after `--key` is that key's value,
        // so positionals go before flags (documented grammar).
        let a = parse(&["run", "pos1", "--n", "100", "--eps=0.5", "--verbose"]);
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.opt("n"), Some("100"));
        assert_eq!(a.opt("eps"), Some("0.5"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["run", "--n", "100", "--eps", "0.25"]);
        assert_eq!(a.usize_or("n", 1).unwrap(), 100);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!((a.f64_or("eps", 0.0).unwrap() - 0.25).abs() < 1e-12);
        assert!(a.usize_or("eps", 0).is_err());
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["run", "--verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("verbose"), None);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse(&["run", "--n", "1", "--oops"]);
        assert!(a.expect_known(&["n"]).is_err());
        assert!(a.expect_known(&["n", "oops"]).is_ok());
    }

    #[test]
    fn require_errors_when_absent() {
        let a = parse(&["run"]);
        assert!(a.require("data").is_err());
    }

    #[test]
    fn parse_rows_grammar() {
        assert_eq!(parse_rows("3").unwrap(), vec![3]);
        assert_eq!(parse_rows("1,4..7,2").unwrap(), vec![1, 4, 5, 6, 2]);
        assert_eq!(parse_rows(" 8 , 9 ").unwrap(), vec![8, 9]);
        assert!(parse_rows("").is_err(), "empty list");
        assert!(parse_rows("5..5").is_err(), "empty range");
        assert!(parse_rows("7..3").is_err(), "reversed range");
        assert!(parse_rows("x").is_err(), "non-numeric");
    }
}
