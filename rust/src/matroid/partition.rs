//! Partition matroid (paper Definition 1).
//!
//! The ground set is partitioned into `h` disjoint categories `A_1..A_h`
//! with cardinality caps `k_1..k_h`; a set is independent iff it holds at
//! most `k_i` points of each category.  The *first* category label of each
//! point is used (partition-matroid datasets are generated with exactly one
//! label per point; see `data::synth`).

use crate::core::Dataset;
use crate::matroid::{Matroid, MatroidKind};

#[derive(Clone, Debug)]
pub struct PartitionMatroid {
    /// Cap per category id; categories beyond the vec have cap 0.
    caps: Vec<usize>,
}

impl PartitionMatroid {
    pub fn new(caps: Vec<usize>) -> Self {
        PartitionMatroid { caps }
    }

    /// Caps proportional to category frequency (the paper's Songs setup:
    /// "minimal nonzero value proportional to the number of songs of the
    /// genre"): `cap_i = max(1, round(frac * count_i))`.
    pub fn proportional(ds: &Dataset, frac: f64) -> Self {
        let hist = ds.category_histogram();
        let caps = hist
            .iter()
            .map(|&c| if c == 0 { 0 } else { ((c as f64 * frac).round() as usize).max(1) })
            .collect();
        PartitionMatroid { caps }
    }

    #[inline]
    pub fn cap(&self, category: u32) -> usize {
        self.caps.get(category as usize).copied().unwrap_or(0)
    }

    pub fn caps(&self) -> &[usize] {
        &self.caps
    }

    #[inline]
    fn category_of(ds: &Dataset, x: usize) -> u32 {
        ds.categories[x][0]
    }
}

impl Matroid for PartitionMatroid {
    fn is_independent(&self, ds: &Dataset, set: &[usize]) -> bool {
        let mut counts = vec![0usize; self.caps.len()];
        for &x in set {
            let c = Self::category_of(ds, x) as usize;
            if c >= counts.len() {
                return false;
            }
            counts[c] += 1;
            if counts[c] > self.caps[c] {
                return false;
            }
        }
        true
    }

    fn can_extend(&self, ds: &Dataset, set: &[usize], x: usize) -> bool {
        let cx = Self::category_of(ds, x);
        let cap = self.cap(cx);
        if cap == 0 {
            return false;
        }
        let in_cat = set
            .iter()
            .filter(|&&y| Self::category_of(ds, y) == cx)
            .count();
        in_cat < cap
    }

    fn rank_bound(&self, ds: &Dataset) -> usize {
        // exact: sum over categories of min(cap, |A_i|)
        let hist = ds.category_histogram();
        self.caps
            .iter()
            .enumerate()
            .map(|(i, &cap)| cap.min(hist.get(i).copied().unwrap_or(0)))
            .sum()
    }

    fn kind(&self) -> MatroidKind {
        MatroidKind::Partition
    }

    fn describe(&self) -> String {
        format!(
            "partition(h={}, rank<={})",
            self.caps.len(),
            self.caps.iter().sum::<usize>()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Metric;

    fn ds(labels: &[u32], n_categories: u32) -> Dataset {
        Dataset::new(
            1,
            Metric::Euclidean,
            (0..labels.len()).map(|i| i as f32).collect(),
            labels.iter().map(|&c| vec![c]).collect(),
            n_categories,
            "test",
        )
    }

    #[test]
    fn empty_set_independent() {
        let d = ds(&[0, 1], 2);
        let m = PartitionMatroid::new(vec![1, 1]);
        assert!(m.is_independent(&d, &[]));
    }

    #[test]
    fn caps_enforced() {
        let d = ds(&[0, 0, 0, 1], 2);
        let m = PartitionMatroid::new(vec![2, 1]);
        assert!(m.is_independent(&d, &[0, 1, 3]));
        assert!(!m.is_independent(&d, &[0, 1, 2]));
        assert!(m.can_extend(&d, &[0], 1));
        assert!(!m.can_extend(&d, &[0, 1], 2));
    }

    #[test]
    fn zero_cap_category_never_independent() {
        let d = ds(&[0, 1], 2);
        let m = PartitionMatroid::new(vec![0, 1]);
        assert!(!m.is_independent(&d, &[0]));
        assert!(!m.can_extend(&d, &[], 0));
        assert!(m.can_extend(&d, &[], 1));
    }

    #[test]
    fn rank_bound_exact() {
        let d = ds(&[0, 0, 0, 1, 2], 3);
        let m = PartitionMatroid::new(vec![2, 5, 1]);
        // min(2,3) + min(5,1) + min(1,1) = 4
        assert_eq!(m.rank_bound(&d), 4);
    }

    #[test]
    fn proportional_caps() {
        let d = ds(&[0, 0, 0, 0, 0, 0, 0, 0, 1, 1], 2);
        let m = PartitionMatroid::proportional(&d, 0.25);
        assert_eq!(m.caps(), &[2, 1]); // 8*0.25=2, max(1, round(0.5))=1
    }

    #[test]
    fn hereditary_property_samples() {
        let d = ds(&[0, 0, 1, 1, 2], 3);
        let m = PartitionMatroid::new(vec![1, 2, 1]);
        let indep = [1usize, 2, 4];
        assert!(m.is_independent(&d, &indep));
        // every subset must be independent
        for mask in 0u32..8 {
            let sub: Vec<usize> =
                (0..3).filter(|&i| mask >> i & 1 == 1).map(|i| indep[i]).collect();
            assert!(m.is_independent(&d, &sub));
        }
    }
}
