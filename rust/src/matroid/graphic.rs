//! Graphic matroid: ground-set elements are edges of a graph, a set is
//! independent iff it is a forest (union-find cycle check).
//!
//! This is a *test instance* of a genuinely non-partition, non-transversal
//! matroid, used to exercise the general coreset construction (§3.1.3) and
//! the `other` branches of EXTRACT / HANDLE.  Point `i` of the dataset is
//! edge `edges[i]`; the geometric coordinates are independent of the graph
//! structure (synthetic generators assign both).

use crate::core::Dataset;
use crate::matroid::{Matroid, MatroidKind};

#[derive(Clone, Debug)]
pub struct GraphicMatroid {
    /// Edge of the underlying graph per dataset point.
    edges: Vec<(u32, u32)>,
    n_vertices: u32,
}

impl GraphicMatroid {
    pub fn new(edges: Vec<(u32, u32)>, n_vertices: u32) -> Self {
        assert!(edges
            .iter()
            .all(|&(u, v)| u < n_vertices && v < n_vertices && u != v));
        GraphicMatroid { edges, n_vertices }
    }

    pub fn edges(&self) -> &[(u32, u32)] {
        &self.edges
    }
}

/// Tiny union-find over vertices (path halving + union by size).
struct Dsu {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl Dsu {
    fn new(n: u32) -> Dsu {
        Dsu {
            parent: (0..n).collect(),
            size: vec![1; n as usize],
        }
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            self.parent[x as usize] = self.parent[self.parent[x as usize] as usize];
            x = self.parent[x as usize];
        }
        x
    }

    /// Returns false if `u` and `v` were already connected (cycle).
    fn union(&mut self, u: u32, v: u32) -> bool {
        let (ru, rv) = (self.find(u), self.find(v));
        if ru == rv {
            return false;
        }
        let (big, small) = if self.size[ru as usize] >= self.size[rv as usize] {
            (ru, rv)
        } else {
            (rv, ru)
        };
        self.parent[small as usize] = big;
        self.size[big as usize] += self.size[small as usize];
        true
    }
}

impl Matroid for GraphicMatroid {
    fn is_independent(&self, _ds: &Dataset, set: &[usize]) -> bool {
        let mut dsu = Dsu::new(self.n_vertices);
        for &i in set {
            let (u, v) = self.edges[i];
            if !dsu.union(u, v) {
                return false;
            }
        }
        true
    }

    fn rank_bound(&self, ds: &Dataset) -> usize {
        (self.n_vertices as usize).saturating_sub(1).min(ds.n())
    }

    fn kind(&self) -> MatroidKind {
        MatroidKind::General
    }

    fn describe(&self) -> String {
        format!(
            "graphic(V={}, E={})",
            self.n_vertices,
            self.edges.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Metric;

    fn ds(n: usize) -> Dataset {
        Dataset::new(
            1,
            Metric::Euclidean,
            (0..n).map(|i| i as f32).collect(),
            vec![vec![0]; n],
            1,
            "test",
        )
    }

    #[test]
    fn forest_independent_cycle_not() {
        // triangle 0-1, 1-2, 2-0 plus pendant 2-3
        let m = GraphicMatroid::new(vec![(0, 1), (1, 2), (2, 0), (2, 3)], 4);
        let d = ds(4);
        assert!(m.is_independent(&d, &[0, 1]));
        assert!(m.is_independent(&d, &[0, 1, 3]));
        assert!(!m.is_independent(&d, &[0, 1, 2])); // the triangle
    }

    #[test]
    fn augmentation_property_holds_here() {
        let m = GraphicMatroid::new(vec![(0, 1), (1, 2), (2, 0), (2, 3)], 4);
        let d = ds(4);
        // |A|=3 spanning tree, |B|=1 -> some edge of A extends B
        let a = [0usize, 1, 3];
        let b = [2usize];
        assert!(m.is_independent(&d, &a) && m.is_independent(&d, &b));
        let extendable = a
            .iter()
            .filter(|&&x| !b.contains(&x) && m.can_extend(&d, &b, x))
            .count();
        assert!(extendable > 0);
    }

    #[test]
    fn rank_is_vertices_minus_one() {
        let m = GraphicMatroid::new(vec![(0, 1), (1, 2), (2, 0)], 3);
        let d = ds(3);
        assert_eq!(m.rank_bound(&d), 2);
    }
}
