//! Transversal matroid (paper Definition 2).
//!
//! Categories `A_1..A_h` may overlap; a set `X` is independent iff the
//! bipartite graph `(X, A; {x - A_j : x in A_j})` has a matching saturating
//! `X`.  Independence is decided with Kuhn's augmenting-path algorithm: the
//! sets the algorithms test are small (|X| <= k), and each element touches
//! O(1) categories (the paper's standing assumption), so a check costs
//! O(|X|^2) in the worst case and is near-linear in practice.

use std::collections::{BTreeMap, HashSet};

use crate::core::Dataset;
use crate::matroid::{Matroid, MatroidKind};

#[derive(Clone, Debug, Default)]
pub struct TransversalMatroid;

impl TransversalMatroid {
    pub fn new() -> Self {
        TransversalMatroid
    }

    /// Maximum matching size between `set` and their categories.
    /// Returns `set.len()` iff `set` is independent.
    pub fn matching_size(ds: &Dataset, set: &[usize]) -> usize {
        // category id -> matched element position (in `set`), built lazily:
        // only categories adjacent to `set` are ever touched.  A BTreeMap,
        // not a HashMap: `matching_witness` iterates this map, and the
        // determinism contract (dmmc-lint L1) requires every iterated
        // collection in result-producing modules to have an input-defined
        // order.
        let mut matched_cat: BTreeMap<u32, usize> = BTreeMap::new();
        let mut size = 0;
        for (pos, &x) in set.iter().enumerate() {
            let mut visited: HashSet<u32> = HashSet::new();
            if Self::augment(ds, set, pos, x, &mut matched_cat, &mut visited) {
                size += 1;
            }
        }
        size
    }

    /// DFS augmenting path from element `x` (at position `pos` of `set`).
    fn augment(
        ds: &Dataset,
        set: &[usize],
        pos: usize,
        x: usize,
        matched_cat: &mut BTreeMap<u32, usize>,
        visited: &mut HashSet<u32>,
    ) -> bool {
        for &c in &ds.categories[x] {
            if !visited.insert(c) {
                continue;
            }
            match matched_cat.get(&c).copied() {
                None => {
                    matched_cat.insert(c, pos);
                    return true;
                }
                Some(other_pos) => {
                    let other_x = set[other_pos];
                    if Self::augment(ds, set, other_pos, other_x, matched_cat, visited) {
                        matched_cat.insert(c, pos);
                        return true;
                    }
                }
            }
        }
        false
    }

    /// A matching witnessing independence: element position -> category id.
    /// Only meaningful when `set` is independent.
    pub fn matching_witness(ds: &Dataset, set: &[usize]) -> Option<Vec<u32>> {
        let mut matched_cat: BTreeMap<u32, usize> = BTreeMap::new();
        for (pos, &x) in set.iter().enumerate() {
            let mut visited: HashSet<u32> = HashSet::new();
            if !Self::augment(ds, set, pos, x, &mut matched_cat, &mut visited) {
                return None;
            }
        }
        let mut witness = vec![u32::MAX; set.len()];
        for (c, pos) in matched_cat {
            witness[pos] = c;
        }
        Some(witness)
    }
}

impl Matroid for TransversalMatroid {
    fn is_independent(&self, ds: &Dataset, set: &[usize]) -> bool {
        Self::matching_size(ds, set) == set.len()
    }

    fn rank_bound(&self, ds: &Dataset) -> usize {
        // rank = max matching size of the whole ground set <= #categories
        ds.n_categories as usize
    }

    fn kind(&self) -> MatroidKind {
        MatroidKind::Transversal
    }

    fn describe(&self) -> String {
        "transversal".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Metric;
    use crate::matroid::{maximal_independent, subset_rank};

    fn ds(cats: Vec<Vec<u32>>, n_categories: u32) -> Dataset {
        let n = cats.len();
        Dataset::new(
            1,
            Metric::Euclidean,
            (0..n).map(|i| i as f32).collect(),
            cats,
            n_categories,
            "test",
        )
    }

    #[test]
    fn disjoint_categories_behave_like_partition() {
        let d = ds(vec![vec![0], vec![0], vec![1]], 2);
        let m = TransversalMatroid::new();
        assert!(m.is_independent(&d, &[0, 2]));
        assert!(!m.is_independent(&d, &[0, 1])); // both need category 0
    }

    #[test]
    fn overlapping_categories_allow_rerouting() {
        // x0:{0}, x1:{0,1}, x2:{1} -> {x0,x1} ok (x1 takes cat 1)
        let d = ds(vec![vec![0], vec![0, 1], vec![1]], 2);
        let m = TransversalMatroid::new();
        assert!(m.is_independent(&d, &[0, 1]));
        assert!(m.is_independent(&d, &[1, 2]));
        assert!(!m.is_independent(&d, &[0, 1, 2])); // 3 elements, 2 categories
    }

    #[test]
    fn augmenting_path_needed() {
        // x0:{0,1}, x1:{0}, x2:{1}: greedy might match x0->0 first;
        // independence of all three requires rerouting and must fail
        // (3 elements, 2 categories), but any pair is independent.
        let d = ds(vec![vec![0, 1], vec![0], vec![1]], 2);
        let m = TransversalMatroid::new();
        assert!(m.is_independent(&d, &[0, 1]));
        assert!(m.is_independent(&d, &[0, 2]));
        assert!(m.is_independent(&d, &[1, 2]));
        assert!(!m.is_independent(&d, &[0, 1, 2]));
    }

    #[test]
    fn witness_is_a_valid_matching() {
        let d = ds(vec![vec![0, 1], vec![0], vec![2]], 3);
        let m = TransversalMatroid::new();
        let set = [0usize, 1, 2];
        assert!(m.is_independent(&d, &set));
        let w = TransversalMatroid::matching_witness(&d, &set).unwrap();
        // distinct categories, each adjacent to its element (BTreeSet so a
        // failed assertion names the same first duplicate on every run)
        let mut seen = std::collections::BTreeSet::new();
        for (pos, &c) in w.iter().enumerate() {
            assert!(d.categories[set[pos]].contains(&c));
            assert!(seen.insert(c));
        }
    }

    #[test]
    fn greedy_maximal_is_maximum() {
        // rank of {x0..x3} with cats {0},{0},{1},{1} is 2
        let d = ds(vec![vec![0], vec![0], vec![1], vec![1]], 2);
        let m = TransversalMatroid::new();
        let items: Vec<usize> = (0..4).collect();
        assert_eq!(subset_rank(&m, &d, &items), 2);
        let got = maximal_independent(&m, &d, &items, 10);
        assert_eq!(got.len(), 2);
        assert!(m.is_independent(&d, &got));
    }

    #[test]
    fn empty_always_independent() {
        let d = ds(vec![vec![0]], 1);
        let m = TransversalMatroid::new();
        assert!(m.is_independent(&d, &[]));
    }
}
