//! Matroid abstraction (paper §2.1).
//!
//! A matroid `M = (S, I(S))` is exposed to the algorithms exclusively
//! through an independence oracle, exactly as the paper assumes
//! ("constant-time oracles ... to check whether a subset of S is an
//! independent set").  The coreset EXTRACT / HANDLE procedures additionally
//! dispatch on [`MatroidKind`]: partition and transversal matroids get the
//! small-coreset constructions of §3.1.1-3.1.2, everything else falls back
//! to the general construction of §3.1.3.

use crate::core::Dataset;

pub mod graphic;
pub mod laminar;
pub mod partition;
pub mod transversal;
pub mod uniform;

pub use graphic::GraphicMatroid;
pub use laminar::{LaminarMatroid, LaminarSet};
pub use partition::PartitionMatroid;
pub use transversal::TransversalMatroid;
pub use uniform::UniformMatroid;

/// Which coreset construction applies (paper §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatroidKind {
    Partition,
    Transversal,
    /// Any other matroid: the general construction (§3.1.3) is used.
    General,
}

impl MatroidKind {
    pub fn name(self) -> &'static str {
        match self {
            MatroidKind::Partition => "partition",
            MatroidKind::Transversal => "transversal",
            MatroidKind::General => "general",
        }
    }
}

/// Independence oracle over subsets of a dataset's point indices.
///
/// Implementations must satisfy the matroid axioms; the mini property-test
/// framework checks hereditary + augmentation on enumerable instances
/// (`rust/tests/property_invariants.rs`).
pub trait Matroid: Send + Sync {
    /// Is `set` (distinct indices into `ds`) an independent set?
    fn is_independent(&self, ds: &Dataset, set: &[usize]) -> bool;

    /// Can `x` extend the independent set `set`?  (`set` is trusted to be
    /// independent; `x` must not already be in it.)  Default: full check.
    fn can_extend(&self, ds: &Dataset, set: &[usize], x: usize) -> bool {
        let mut ext = set.to_vec();
        ext.push(x);
        self.is_independent(ds, &ext)
    }

    /// An upper bound on the rank of the matroid (exact where cheap).
    fn rank_bound(&self, ds: &Dataset) -> usize;

    /// Which coreset construction this matroid gets.
    fn kind(&self) -> MatroidKind;

    /// Display name for reports.
    fn describe(&self) -> String;
}

impl<T: Matroid + ?Sized> Matroid for &T {
    fn is_independent(&self, ds: &Dataset, set: &[usize]) -> bool {
        (**self).is_independent(ds, set)
    }
    fn can_extend(&self, ds: &Dataset, set: &[usize], x: usize) -> bool {
        (**self).can_extend(ds, set, x)
    }
    fn rank_bound(&self, ds: &Dataset) -> usize {
        (**self).rank_bound(ds)
    }
    fn kind(&self) -> MatroidKind {
        (**self).kind()
    }
    fn describe(&self) -> String {
        (**self).describe()
    }
}

impl<T: Matroid + ?Sized> Matroid for Box<T> {
    fn is_independent(&self, ds: &Dataset, set: &[usize]) -> bool {
        (**self).is_independent(ds, set)
    }
    fn can_extend(&self, ds: &Dataset, set: &[usize], x: usize) -> bool {
        (**self).can_extend(ds, set, x)
    }
    fn rank_bound(&self, ds: &Dataset) -> usize {
        (**self).rank_bound(ds)
    }
    fn kind(&self) -> MatroidKind {
        (**self).kind()
    }
    fn describe(&self) -> String {
        (**self).describe()
    }
}

/// Greedily grow a maximum-cardinality independent subset of `items`,
/// stopping early at `cap` elements.  By the matroid augmentation property
/// greedy attains maximum cardinality, so if the result has fewer than
/// `cap` elements it is a *maximum* independent subset of `items`.
pub fn maximal_independent(
    m: &dyn Matroid,
    ds: &Dataset,
    items: &[usize],
    cap: usize,
) -> Vec<usize> {
    let mut out: Vec<usize> = Vec::with_capacity(cap.min(items.len()));
    for &x in items {
        if out.len() >= cap {
            break;
        }
        if m.can_extend(ds, &out, x) {
            out.push(x);
        }
    }
    out
}

/// Exact rank of `items` under `m` (size of a maximum independent subset),
/// computed greedily.  O(|items|) oracle calls.
pub fn subset_rank(m: &dyn Matroid, ds: &Dataset, items: &[usize]) -> usize {
    maximal_independent(m, ds, items, items.len()).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dataset, Metric};

    fn ds_with_categories(cats: Vec<Vec<u32>>, n_categories: u32) -> Dataset {
        let n = cats.len();
        Dataset::new(
            1,
            Metric::Euclidean,
            (0..n).map(|i| i as f32).collect(),
            cats,
            n_categories,
            "test",
        )
    }

    #[test]
    fn maximal_independent_respects_cap() {
        let ds = ds_with_categories(vec![vec![0]; 10], 1);
        let m = UniformMatroid::new(7);
        let items: Vec<usize> = (0..10).collect();
        assert_eq!(maximal_independent(&m, &ds, &items, 3).len(), 3);
        assert_eq!(maximal_independent(&m, &ds, &items, 9).len(), 7);
    }

    #[test]
    fn subset_rank_partition() {
        // categories 0,0,0,1 with caps [2,1] -> rank 3
        let ds = ds_with_categories(vec![vec![0], vec![0], vec![0], vec![1]], 2);
        let m = PartitionMatroid::new(vec![2, 1]);
        let items: Vec<usize> = (0..4).collect();
        assert_eq!(subset_rank(&m, &ds, &items), 3);
    }
}
