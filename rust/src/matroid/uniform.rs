//! Uniform matroid U_{r,n}: a set is independent iff it has at most `r`
//! elements.  With r = k this reduces DMMC to *unconstrained* diversity
//! maximization — the baseline regime of the earlier coreset literature
//! [4, 10, 21] — and it exercises the "general matroid" coreset path
//! (§3.1.3), since we deliberately do not special-case it.

use crate::core::Dataset;
use crate::matroid::{Matroid, MatroidKind};

#[derive(Clone, Copy, Debug)]
pub struct UniformMatroid {
    rank: usize,
}

impl UniformMatroid {
    pub fn new(rank: usize) -> Self {
        UniformMatroid { rank }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }
}

impl Matroid for UniformMatroid {
    fn is_independent(&self, _ds: &Dataset, set: &[usize]) -> bool {
        set.len() <= self.rank
    }

    fn can_extend(&self, _ds: &Dataset, set: &[usize], _x: usize) -> bool {
        set.len() < self.rank
    }

    fn rank_bound(&self, ds: &Dataset) -> usize {
        self.rank.min(ds.n())
    }

    fn kind(&self) -> MatroidKind {
        MatroidKind::General
    }

    fn describe(&self) -> String {
        format!("uniform(r={})", self.rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Metric;

    fn ds(n: usize) -> Dataset {
        Dataset::new(
            1,
            Metric::Euclidean,
            (0..n).map(|i| i as f32).collect(),
            vec![vec![0]; n],
            1,
            "test",
        )
    }

    #[test]
    fn cardinality_rule() {
        let d = ds(5);
        let m = UniformMatroid::new(2);
        assert!(m.is_independent(&d, &[0]));
        assert!(m.is_independent(&d, &[0, 3]));
        assert!(!m.is_independent(&d, &[0, 1, 2]));
        assert!(m.can_extend(&d, &[0], 4));
        assert!(!m.can_extend(&d, &[0, 1], 4));
    }

    #[test]
    fn rank_bound_clamped_by_n() {
        let d = ds(3);
        assert_eq!(UniformMatroid::new(10).rank_bound(&d), 3);
        assert_eq!(UniformMatroid::new(2).rank_bound(&d), 2);
    }
}
