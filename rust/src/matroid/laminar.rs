//! Laminar matroid — an extension beyond the paper's partition/transversal
//! pair that exercises the *general* coreset construction (§3.1.3) on a
//! structured, practically-motivated constraint.
//!
//! A laminar family over the category universe is a collection of sets
//! where any two are disjoint or nested (e.g. genre -> super-genre
//! hierarchies: "at most 2 jazz subgenres AND at most 3 from the broader
//! jazz/blues family").  A point set is independent iff for every family
//! set `F` with capacity `c_F`, at most `c_F` selected points have their
//! (primary) category inside `F`.

use crate::core::Dataset;
use crate::matroid::{Matroid, MatroidKind};

/// One constraint: a set of category ids and its capacity.
#[derive(Clone, Debug)]
pub struct LaminarSet {
    pub categories: Vec<u32>,
    pub cap: usize,
}

#[derive(Clone, Debug)]
pub struct LaminarMatroid {
    sets: Vec<LaminarSet>,
}

impl LaminarMatroid {
    /// Build from constraint sets, verifying laminarity (each pair of sets
    /// is disjoint or nested).  Panics on a non-laminar family — the
    /// independence system would not be a matroid otherwise.
    pub fn new(mut sets: Vec<LaminarSet>) -> Self {
        for s in &mut sets {
            s.categories.sort_unstable();
            s.categories.dedup();
        }
        for i in 0..sets.len() {
            for j in (i + 1)..sets.len() {
                let a = &sets[i].categories;
                let b = &sets[j].categories;
                let inter = intersection_size(a, b);
                let laminar = inter == 0 || inter == a.len() || inter == b.len();
                assert!(
                    laminar,
                    "sets {i} and {j} are neither disjoint nor nested"
                );
            }
        }
        LaminarMatroid { sets }
    }

    /// Two-level convenience constructor: per-category caps (partition
    /// part) plus caps on groups of categories.
    pub fn hierarchy(per_category: Vec<usize>, groups: Vec<(Vec<u32>, usize)>) -> Self {
        let mut sets: Vec<LaminarSet> = per_category
            .into_iter()
            .enumerate()
            .map(|(c, cap)| LaminarSet {
                categories: vec![c as u32],
                cap,
            })
            .collect();
        for (categories, cap) in groups {
            sets.push(LaminarSet { categories, cap });
        }
        LaminarMatroid::new(sets)
    }

    fn category_of(ds: &Dataset, x: usize) -> u32 {
        ds.categories[x][0]
    }
}

fn intersection_size(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut n) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

impl Matroid for LaminarMatroid {
    fn is_independent(&self, ds: &Dataset, set: &[usize]) -> bool {
        for ls in &self.sets {
            let count = set
                .iter()
                .filter(|&&x| ls.categories.binary_search(&Self::category_of(ds, x)).is_ok())
                .count();
            if count > ls.cap {
                return false;
            }
        }
        true
    }

    fn rank_bound(&self, ds: &Dataset) -> usize {
        // loose: the tightest single constraint covering everything, else n
        self.sets
            .iter()
            .filter(|s| s.categories.len() == ds.n_categories as usize)
            .map(|s| s.cap)
            .min()
            .unwrap_or_else(|| {
                self.sets
                    .iter()
                    .map(|s| s.cap)
                    .sum::<usize>()
                    .min(ds.n())
            })
    }

    fn kind(&self) -> MatroidKind {
        // handled by the general construction (the point of this extension)
        MatroidKind::General
    }

    fn describe(&self) -> String {
        format!("laminar({} sets)", self.sets.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Metric;
    use crate::matroid::maximal_independent;

    fn ds(labels: &[u32], n_categories: u32) -> Dataset {
        Dataset::new(
            1,
            Metric::Euclidean,
            (0..labels.len()).map(|i| i as f32).collect(),
            labels.iter().map(|&c| vec![c]).collect(),
            n_categories,
            "test",
        )
    }

    fn two_level() -> LaminarMatroid {
        // categories 0,1 form group A (cap 2), 2,3 form group B (cap 2);
        // each category capped at 2
        LaminarMatroid::hierarchy(vec![2; 4], vec![(vec![0, 1], 2), (vec![2, 3], 2)])
    }

    #[test]
    fn nested_caps_enforced() {
        let d = ds(&[0, 0, 1, 2, 3, 3], 4);
        let m = two_level();
        assert!(m.is_independent(&d, &[0, 1])); // 2 of cat 0, group A cap 2
        assert!(!m.is_independent(&d, &[0, 1, 2])); // 3 in group A
        assert!(m.is_independent(&d, &[0, 2, 3, 4])); // hmm: A has 0,2 -> 2 ok; B has 3,4 -> 2 ok
        assert!(!m.is_independent(&d, &[3, 4, 5])); // 3 in group B
    }

    #[test]
    fn hereditary_and_augmentation_spot_checks() {
        let d = ds(&[0, 0, 1, 1, 2, 2, 3, 3], 4);
        let m = two_level();
        // hereditary
        let indep = [0usize, 2, 4, 6];
        assert!(m.is_independent(&d, &indep));
        for drop in 0..indep.len() {
            let sub: Vec<usize> = indep
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != drop)
                .map(|(_, &x)| x)
                .collect();
            assert!(m.is_independent(&d, &sub));
        }
        // augmentation on a concrete pair
        let a = [0usize, 2, 4, 6]; // size 4
        let b = [1usize, 5]; // size 2
        assert!(m.is_independent(&d, &a) && m.is_independent(&d, &b));
        let found = a.iter().any(|&x| !b.contains(&x) && m.can_extend(&d, &b, x));
        assert!(found);
    }

    #[test]
    fn greedy_reaches_rank() {
        let d = ds(&[0, 0, 0, 1, 1, 2, 2, 3, 3, 3], 4);
        let m = two_level();
        let items: Vec<usize> = (0..d.n()).collect();
        let got = maximal_independent(&m, &d, &items, 10);
        // rank = group A cap (2) + group B cap (2) = 4
        assert_eq!(got.len(), 4);
        assert!(m.is_independent(&d, &got));
    }

    #[test]
    #[should_panic(expected = "neither disjoint nor nested")]
    fn non_laminar_rejected() {
        LaminarMatroid::new(vec![
            LaminarSet { categories: vec![0, 1], cap: 1 },
            LaminarSet { categories: vec![1, 2], cap: 1 },
        ]);
    }

    #[test]
    fn partition_special_case_agrees() {
        use crate::matroid::PartitionMatroid;
        let d = ds(&[0, 0, 1, 2, 2, 2], 3);
        let caps = vec![1usize, 2, 1];
        let part = PartitionMatroid::new(caps.clone());
        let lam = LaminarMatroid::hierarchy(caps, vec![]);
        for mask in 0u32..64 {
            let set: Vec<usize> = (0..6).filter(|&i| mask >> i & 1 == 1).collect();
            assert_eq!(
                part.is_independent(&d, &set),
                lam.is_independent(&d, &set),
                "{set:?}"
            );
        }
    }
}
