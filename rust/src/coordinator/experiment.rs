//! The pipeline runner: coreset setting x finisher -> RunOutcome.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::algo::exhaustive::exhaustive_best;
use crate::algo::greedy::greedy_sum;
use crate::algo::local_search::{local_search_sum, LocalSearchParams};
use crate::algo::matching::matching_race;
use crate::algo::seq_coreset::seq_coreset;
use crate::algo::Budget;
use crate::core::Dataset;
use crate::diversity::{diversity_with_engine, Objective};
use crate::index::{CoresetIndex, IndexConfig};
use crate::mapreduce::{mr_coreset, MapReduceConfig};
use crate::matroid::Matroid;
use crate::runtime::{build_engine, EngineKind};
use crate::streaming::{run_stream_with_engine, StreamMode};
use crate::util::rng::Rng;
use crate::util::timer::time_it;

/// How the candidate set for the finisher is produced.
#[derive(Clone, Copy, Debug)]
pub enum Setting {
    /// SeqCoreset (Algorithm 1).
    Seq { budget: Budget },
    /// StreamCoreset (Algorithm 2 or the tau-variant).
    Stream { mode: StreamMode },
    /// MapReduce coreset (paper §4.2).
    MapReduce {
        workers: usize,
        budget: Budget,
        second_round_tau: Option<usize>,
    },
    /// Composable coreset index: ingest the (permuted) input in
    /// `segment_size`-point segments through the merge-and-reduce tree
    /// and hand the root coreset to the finisher — the standing-structure
    /// counterpart of the one-shot settings (`crate::index`).
    Index {
        segment_size: usize,
        budget: Budget,
    },
    /// No coreset: the finisher runs on the full input (the AMT baseline).
    Full,
}

/// Final-solution extractor run on the candidate set.
#[derive(Clone, Copy, Debug)]
pub enum Finisher {
    /// AMT local search — sum-DMMC only.
    LocalSearch { gamma: f64 },
    /// Exhaustive search (any objective; exponential in k).
    Exhaustive,
    /// Greedy heuristic (cheap baseline).
    Greedy,
    /// Greedy maximum-weight matching raced against matroid Gonzalez,
    /// best-of-both (any objective; built for remote-clique/remote-edge).
    Matching,
}

/// One experiment configuration.
#[derive(Clone, Copy, Debug)]
pub struct Pipeline {
    pub setting: Setting,
    pub finisher: Finisher,
    pub engine: EngineKind,
}

/// Everything the benches/CLI report about one run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub solution: Vec<usize>,
    pub diversity: f64,
    pub coreset_size: usize,
    pub coreset_time: Duration,
    pub finish_time: Duration,
    /// Setting-specific extras (peak memory, worker times, swap counts...).
    pub extra: BTreeMap<String, f64>,
}

impl RunOutcome {
    pub fn total_time(&self) -> Duration {
        self.coreset_time + self.finish_time
    }
}

/// Run the full coreset -> finisher protocol.
pub fn run_pipeline<M: Matroid + Sync>(
    ds: &Dataset,
    m: &M,
    k: usize,
    obj: Objective,
    pipeline: Pipeline,
    seed: u64,
) -> Result<RunOutcome> {
    if k < 2 {
        // diversity (and the farness machinery behind the coreset radius)
        // is defined over pairs; reject here so no surface can reach the
        // `farness_coefficient` assert with k < 2
        bail!("k must be >= 2 for diversity maximization (got k={k})");
    }
    let mut extra = BTreeMap::new();
    let mut rng = Rng::new(seed);
    // one engine shared by every phase that computes distances: the
    // SeqCoreset folds, the local-search sum scans, the exhaustive
    // finisher's candidate tile, and the final objective evaluation.
    // Built unconditionally — every pipeline ends in an engine-backed
    // diversity evaluation (so `--engine pjrt` now needs artifacts even
    // for stream/greedy pipelines; construction is O(n) norms otherwise)
    let engine = build_engine(pipeline.engine, ds)?;
    let engine = &*engine;

    // ---- phase 1: candidate set ----
    let coreset_span = crate::span!("coreset-build", "k" = k);
    let (candidates, coreset_time) = match pipeline.setting {
        Setting::Seq { budget } => {
            let (cs, dt) = time_it(|| seq_coreset(ds, m, k, budget, engine));
            let cs = cs?;
            extra.insert("n_clusters".into(), cs.n_clusters as f64);
            extra.insert("radius".into(), cs.radius);
            (cs.indices, dt)
        }
        Setting::Stream { mode } => {
            let order = rng.permutation(ds.n());
            let (rep, dt) =
                time_it(|| run_stream_with_engine(ds, m, k, mode, &order, pipeline.engine));
            let rep = rep?;
            extra.insert("n_clusters".into(), rep.coreset.n_clusters as f64);
            extra.insert("peak_memory".into(), rep.stats.peak_memory_points as f64);
            extra.insert("restructures".into(), rep.stats.restructures as f64);
            extra.insert("throughput".into(), rep.throughput);
            // the §5.2 construction cost model — previously dropped here
            extra.insert("stream_dist_evals".into(), rep.stats.distance_evals as f64);
            (rep.coreset.indices, dt)
        }
        Setting::MapReduce {
            workers,
            budget,
            second_round_tau,
        } => {
            let cfg = MapReduceConfig {
                workers,
                budget,
                second_round_tau,
                seed: rng.next_u64(),
                engine: pipeline.engine,
            };
            let (rep, dt) = time_it(|| mr_coreset(ds, m, k, cfg));
            let rep = rep?;
            extra.insert("rounds".into(), rep.rounds as f64);
            extra.insert("local_memory".into(), rep.local_memory_points as f64);
            extra.insert(
                "makespan_round1".into(),
                rep.makespan_round1.as_secs_f64(),
            );
            extra.insert(
                "mr_score_dist_evals".into(),
                rep.shard_score_dist_evals.iter().sum::<u64>() as f64,
            );
            // construction ledger: shard GMM folds + optional round-2 pass
            // (the bulk of MR distance work, previously dropped here)
            extra.insert(
                "mr_coreset_dist_evals".into(),
                (rep.shard_coreset_dist_evals.iter().sum::<u64>() + rep.round2_dist_evals)
                    as f64,
            );
            (rep.coreset.indices, dt)
        }
        Setting::Index {
            segment_size,
            budget,
        } => {
            let order = rng.permutation(ds.n());
            // the tau passed to new() is irrelevant: both budgets are
            // overridden with the setting's own
            let cfg = IndexConfig {
                leaf_budget: budget,
                reduce_budget: budget,
                engine: pipeline.engine,
                ..IndexConfig::new(k, 1)
            };
            let (built, dt) = time_it(|| {
                let mut idx = CoresetIndex::new(ds, m, cfg);
                idx.ingest(&order, segment_size.max(1)).map(|receipts| {
                    let max_nodes =
                        receipts.iter().map(|r| r.nodes_touched).max().unwrap_or(0);
                    (
                        idx.root(),
                        idx.segments(),
                        idx.stats().merges,
                        idx.stats().dist_evals,
                        max_nodes,
                        idx.live_fraction(),
                        idx.stats().rebuilds,
                    )
                })
            });
            let (root, segments, merges, dist_evals, max_nodes, live_fraction, rebuilds) =
                built?;
            extra.insert("index_segments".into(), segments as f64);
            extra.insert("index_merges".into(), merges as f64);
            // index-internal merge work, reported rather than dropped
            extra.insert("index_dist_evals".into(), dist_evals as f64);
            extra.insert("index_max_nodes_touched".into(), max_nodes as f64);
            // dynamic-index health: 1.0 / 0 for this append-only setting,
            // but the columns exist so sweep CSVs stay schema-stable when
            // delete phases are added
            extra.insert("index_live_fraction".into(), live_fraction);
            extra.insert("index_rebuilds".into(), rebuilds as f64);
            (root, dt)
        }
        Setting::Full => ((0..ds.n()).collect(), Duration::ZERO),
    };
    drop(coreset_span);
    extra.insert("coreset_size".into(), candidates.len() as f64);

    // ---- phase 2: finisher ----
    let finisher_span = crate::span!("finisher", "candidates" = candidates.len());
    let (solution, finish_time) = match pipeline.finisher {
        Finisher::LocalSearch { gamma } => {
            if obj != Objective::Sum {
                bail!("local search finisher only applies to sum-DMMC");
            }
            let params = LocalSearchParams {
                gamma,
                ..Default::default()
            };
            let (res, dt) = time_it(|| {
                local_search_sum(ds, m, k, &candidates, engine, params, None, &mut rng)
            });
            let res = res?;
            extra.insert("swaps".into(), res.swaps as f64);
            extra.insert("oracle_calls".into(), res.oracle_calls as f64);
            extra.insert("passes".into(), res.passes as f64);
            extra.insert("dist_evals".into(), res.dist_evals as f64);
            (res.solution, dt)
        }
        Finisher::Exhaustive => {
            let (res, dt) = time_it(|| exhaustive_best(ds, m, k, &candidates, obj, engine));
            let res = res?;
            extra.insert("search_nodes".into(), res.nodes as f64);
            extra.insert("search_leaves".into(), res.leaves as f64);
            (res.solution, dt)
        }
        Finisher::Greedy => {
            let (sol, dt) = time_it(|| greedy_sum(ds, m, k, &candidates));
            (sol, dt)
        }
        Finisher::Matching => {
            let (res, dt) =
                time_it(|| matching_race(ds, m, k, &candidates, obj, engine, &mut rng));
            let res = res?;
            extra.insert("matching_value".into(), res.matching_value);
            extra.insert("gmm_value".into(), res.gmm_value);
            extra.insert("matching_edges".into(), res.matching_edges as f64);
            extra.insert(
                "race_winner_matching".into(),
                if res.winner == "matching" { 1.0 } else { 0.0 },
            );
            (res.solution, dt)
        }
    };
    drop(finisher_span);

    // telemetry side channel: phase timings and work ledgers into the
    // process-global registry (`dmmc run --metrics-out` renders it);
    // nothing below reads any of it back
    let metrics = crate::obs::MetricsRegistry::global();
    metrics
        .histogram("dmmc_phase_seconds", &[("phase", "coreset-build")])
        .observe(coreset_time);
    metrics
        .histogram("dmmc_phase_seconds", &[("phase", "finisher")])
        .observe(finish_time);
    for (key, val) in &extra {
        if key.ends_with("dist_evals") {
            metrics
                .counter(
                    "dmmc_engine_dist_evals_total",
                    &[("engine", pipeline.engine.name()), ("ledger", key)],
                )
                .add(*val as u64);
        }
    }

    let div = diversity_with_engine(ds, &solution, obj, engine)?;
    Ok(RunOutcome {
        solution,
        diversity: div,
        coreset_size: candidates.len(),
        coreset_time,
        finish_time,
        extra,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::{Matroid, PartitionMatroid, UniformMatroid};

    fn pipe(setting: Setting, finisher: Finisher) -> Pipeline {
        Pipeline {
            setting,
            finisher,
            engine: EngineKind::Scalar,
        }
    }

    #[test]
    fn seq_plus_local_search_runs() {
        let ds = synth::clustered(300, 2, 5, 0.1, 3, 1);
        let m = PartitionMatroid::new(vec![2; 3]);
        let out = run_pipeline(
            &ds,
            &m,
            5,
            Objective::Sum,
            pipe(
                Setting::Seq {
                    budget: Budget::Clusters(16),
                },
                Finisher::LocalSearch { gamma: 0.0 },
            ),
            1,
        )
        .unwrap();
        assert_eq!(out.solution.len(), 5);
        assert!(m.is_independent(&ds, &out.solution));
        assert!(out.diversity > 0.0);
        assert!(out.coreset_size < 300);
        // the finisher's work counters surface in the extras
        assert!(out.extra["passes"] >= 1.0);
        assert_eq!(out.extra["passes"], out.extra["swaps"] + 1.0);
        assert!(out.extra["dist_evals"] > 0.0);
    }

    #[test]
    fn stream_plus_exhaustive_runs_non_sum() {
        let ds = synth::uniform_cube(200, 2, 2);
        let m = UniformMatroid::new(4);
        let out = run_pipeline(
            &ds,
            &m,
            4,
            Objective::Tree,
            pipe(
                Setting::Stream {
                    mode: StreamMode::Tau(8),
                },
                Finisher::Exhaustive,
            ),
            2,
        )
        .unwrap();
        assert_eq!(out.solution.len(), 4);
        assert!(out.diversity > 0.0);
        assert!(out.extra.contains_key("peak_memory"));
        // the §5.2 construction evals are reported, not dropped
        assert!(out.extra["stream_dist_evals"] > 0.0);
    }

    #[test]
    fn index_setting_runs_and_reports_merge_ledger() {
        let ds = synth::uniform_cube(400, 2, 5);
        let m = UniformMatroid::new(4);
        let out = run_pipeline(
            &ds,
            &m,
            4,
            Objective::Sum,
            pipe(
                Setting::Index {
                    segment_size: 50,
                    budget: Budget::Clusters(8),
                },
                Finisher::LocalSearch { gamma: 0.0 },
            ),
            7,
        )
        .unwrap();
        assert_eq!(out.solution.len(), 4);
        assert!(out.diversity > 0.0);
        assert!(out.coreset_size < 400);
        assert_eq!(out.extra["index_segments"], 8.0);
        assert!(out.extra["index_merges"] >= 1.0);
        assert!(out.extra["index_dist_evals"] > 0.0);
        // segment 8's carry chain is the worst case: 1 + trailing_ones(7)
        assert_eq!(out.extra["index_max_nodes_touched"], 4.0);
        // append-only run: everything lives, nothing was rebuilt
        assert_eq!(out.extra["index_live_fraction"], 1.0);
        assert_eq!(out.extra["index_rebuilds"], 0.0);
    }

    #[test]
    fn mapreduce_setting_runs() {
        let ds = synth::uniform_cube(400, 2, 3);
        let m = UniformMatroid::new(4);
        let out = run_pipeline(
            &ds,
            &m,
            4,
            Objective::Sum,
            pipe(
                Setting::MapReduce {
                    workers: 4,
                    budget: Budget::Clusters(4),
                    second_round_tau: None,
                },
                Finisher::LocalSearch { gamma: 0.0 },
            ),
            3,
        )
        .unwrap();
        assert_eq!(out.extra["rounds"], 1.0);
        assert_eq!(out.solution.len(), 4);
        assert!(out.extra.contains_key("mr_score_dist_evals"));
        assert!(out.extra.contains_key("dist_evals"));
        // construction ledger: 4 shards x (tau=4 folds over 100 points)
        assert_eq!(out.extra["mr_coreset_dist_evals"], (4 * 4 * 100) as f64);
    }

    #[test]
    fn full_setting_is_the_baseline() {
        let ds = synth::uniform_cube(60, 2, 4);
        let m = UniformMatroid::new(3);
        let out = run_pipeline(
            &ds,
            &m,
            3,
            Objective::Sum,
            pipe(Setting::Full, Finisher::LocalSearch { gamma: 0.0 }),
            4,
        )
        .unwrap();
        assert_eq!(out.coreset_size, 60);
        assert_eq!(out.coreset_time, Duration::ZERO);
    }

    #[test]
    fn engine_kinds_produce_identical_euclidean_pipelines() {
        // all three CPU backends are bit-identical on Euclidean datasets,
        // so the full pipeline (coreset, swaps, final objective) must not
        // move by a bit under the registry flag
        let ds = synth::uniform_cube(250, 3, 8);
        let m = UniformMatroid::new(4);
        let mut base: Option<RunOutcome> = None;
        for engine in [EngineKind::Scalar, EngineKind::Batch, EngineKind::Simd] {
            let out = run_pipeline(
                &ds,
                &m,
                4,
                Objective::Sum,
                Pipeline {
                    setting: Setting::Seq {
                        budget: Budget::Clusters(12),
                    },
                    finisher: Finisher::LocalSearch { gamma: 0.0 },
                    engine,
                },
                6,
            )
            .unwrap();
            match &base {
                None => base = Some(out),
                Some(b) => {
                    assert_eq!(b.solution, out.solution, "{}", engine.name());
                    assert_eq!(
                        b.diversity.to_bits(),
                        out.diversity.to_bits(),
                        "{}: diversity moved",
                        engine.name()
                    );
                }
            }
        }
    }

    #[test]
    fn seq_plus_matching_runs_remote_edge() {
        let ds = synth::clustered(200, 2, 5, 0.1, 3, 9);
        let m = PartitionMatroid::new(vec![2; 3]);
        let out = run_pipeline(
            &ds,
            &m,
            5,
            Objective::RemoteEdge,
            pipe(
                Setting::Seq {
                    budget: Budget::Clusters(16),
                },
                Finisher::Matching,
            ),
            1,
        )
        .unwrap();
        assert_eq!(out.solution.len(), 5);
        assert!(m.is_independent(&ds, &out.solution));
        assert!(out.diversity > 0.0);
        // the race ledger surfaces both arms and never loses to either
        assert!(out.diversity >= out.extra["matching_value"]);
        assert!(out.diversity >= out.extra["gmm_value"]);
        assert!(out.extra.contains_key("matching_edges"));
    }

    #[test]
    fn small_k_is_an_error_not_a_panic() {
        let ds = synth::uniform_cube(50, 2, 5);
        let m = UniformMatroid::new(3);
        for k in [0, 1] {
            let res = run_pipeline(
                &ds,
                &m,
                k,
                Objective::Sum,
                pipe(Setting::Full, Finisher::Greedy),
                5,
            );
            let msg = format!("{:#}", res.unwrap_err());
            assert!(msg.contains("k must be >= 2"), "k={k}: {msg}");
        }
    }

    #[test]
    fn local_search_rejects_non_sum() {
        let ds = synth::uniform_cube(50, 2, 5);
        let m = UniformMatroid::new(3);
        let res = run_pipeline(
            &ds,
            &m,
            3,
            Objective::Star,
            pipe(Setting::Full, Finisher::LocalSearch { gamma: 0.0 }),
            5,
        );
        assert!(res.is_err());
    }
}
