//! Experiment coordinator: dataset/matroid specs, the pipeline runner that
//! the CLI / examples / benches all share, and metrics plumbing.
//!
//! The paper's experimental protocol (§5) is: build a coreset in one of the
//! three settings, then extract the final solution with a sequential
//! finisher (AMT local search with gamma = 0 for sum-DMMC, exhaustive
//! search for the other variants).  [`experiment::run_pipeline`] is that
//! protocol as a function.

pub mod experiment;
pub mod spec;

pub use experiment::{run_pipeline, Finisher, Pipeline, RunOutcome, Setting};
pub use spec::{build_dataset, build_matroid, DatasetSpec, MatroidSpec};
