//! Dataset / matroid specifications — the config-file and CLI surface.

use anyhow::{bail, Result};

use crate::core::Dataset;
use crate::data::{io, synth};
use crate::matroid::{Matroid, PartitionMatroid, TransversalMatroid, UniformMatroid};

/// Which dataset to build/load.
#[derive(Clone, Debug)]
pub enum DatasetSpec {
    /// Wikipedia stand-in (transversal matroid scenario).
    Wikisim { n: usize, seed: u64 },
    /// Songs stand-in (partition matroid scenario).
    Songsim { n: usize, seed: u64 },
    /// Controlled-geometry Gaussian blobs.
    Clustered {
        n: usize,
        dim: usize,
        clusters: usize,
        spread: f64,
        labels: u32,
        seed: u64,
    },
    /// Uniform cube (unconstrained-like testing).
    Cube { n: usize, dim: usize, seed: u64 },
    /// Load a `.dmmc` binary file.
    File(String),
}

impl DatasetSpec {
    /// Parse CLI shorthand: `wikisim:5000`, `songsim:2000`, `cube:1000x8`,
    /// `clustered:1000`, or a file path.
    pub fn parse(s: &str, seed: u64) -> Result<DatasetSpec> {
        if let Some((kind, rest)) = s.split_once(':') {
            let spec = match kind {
                "wikisim" => DatasetSpec::Wikisim {
                    n: rest.parse()?,
                    seed,
                },
                "songsim" => DatasetSpec::Songsim {
                    n: rest.parse()?,
                    seed,
                },
                "cube" => {
                    let (n, dim) = match rest.split_once('x') {
                        Some((n, d)) => (n.parse()?, d.parse()?),
                        None => (rest.parse()?, 8),
                    };
                    DatasetSpec::Cube { n, dim, seed }
                }
                "clustered" => DatasetSpec::Clustered {
                    n: rest.parse()?,
                    dim: 8,
                    clusters: 16,
                    spread: 0.1,
                    labels: 8,
                    seed,
                },
                other => bail!("unknown dataset kind {other}"),
            };
            Ok(spec)
        } else {
            Ok(DatasetSpec::File(s.to_string()))
        }
    }
}

/// Build or load the dataset.
pub fn build_dataset(spec: &DatasetSpec) -> Result<Dataset> {
    Ok(match spec {
        DatasetSpec::Wikisim { n, seed } => synth::wikisim(*n, *seed),
        DatasetSpec::Songsim { n, seed } => synth::songsim(*n, *seed),
        DatasetSpec::Clustered {
            n,
            dim,
            clusters,
            spread,
            labels,
            seed,
        } => synth::clustered(*n, *dim, *clusters, *spread, *labels, *seed),
        DatasetSpec::Cube { n, dim, seed } => synth::uniform_cube(*n, *dim, *seed),
        DatasetSpec::File(path) => io::load(path)?,
    })
}

/// Which matroid constrains the solutions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MatroidSpec {
    Transversal,
    /// Partition with caps proportional to category frequency, binary-
    /// searched so the rank lands near `target_rank` (paper's Songs setup).
    PartitionProportional { target_rank: usize },
    /// Partition with explicit caps.
    PartitionCaps(Vec<usize>),
    /// Uniform (rank r) — the unconstrained-diversity regime.
    Uniform(usize),
}

impl MatroidSpec {
    /// Parse CLI shorthand: `transversal`, `partition:89`, `uniform:10`.
    pub fn parse(s: &str) -> Result<MatroidSpec> {
        if s == "transversal" {
            return Ok(MatroidSpec::Transversal);
        }
        if let Some(rest) = s.strip_prefix("partition:") {
            return Ok(MatroidSpec::PartitionProportional {
                target_rank: rest.parse()?,
            });
        }
        if let Some(rest) = s.strip_prefix("uniform:") {
            return Ok(MatroidSpec::Uniform(rest.parse()?));
        }
        bail!("unknown matroid spec {s} (transversal | partition:<rank> | uniform:<r>)")
    }

    /// Canonical cache-key fragment: a stable, collision-free rendering
    /// of every field that can change which matroid is built.  Unlike the
    /// `Debug` form this is pinned by test and safe to persist or hash;
    /// any future float-bearing variant must render its floats as
    /// `to_bits()` hex (decimal printing is lossy and format-unstable),
    /// matching `QueryFinisher::key_part`.
    pub fn key_part(&self) -> String {
        match self {
            MatroidSpec::Transversal => "transversal".to_string(),
            MatroidSpec::PartitionProportional { target_rank } => {
                format!("partition:{target_rank}")
            }
            // comma-joined so caps [1, 2] and [12] cannot collide
            MatroidSpec::PartitionCaps(caps) => {
                let caps: Vec<String> = caps.iter().map(|c| c.to_string()).collect();
                format!("caps:{}", caps.join(","))
            }
            MatroidSpec::Uniform(r) => format!("uniform:{r}"),
        }
    }

    /// The natural matroid for a dataset spec (wikisim -> transversal,
    /// songsim -> partition rank 89, like the paper's Table 2).
    pub fn default_for(spec: &DatasetSpec) -> MatroidSpec {
        match spec {
            DatasetSpec::Wikisim { .. } => MatroidSpec::Transversal,
            DatasetSpec::Songsim { .. } => MatroidSpec::PartitionProportional { target_rank: 89 },
            _ => MatroidSpec::Uniform(16),
        }
    }
}

/// Boxed matroid usable across threads (MapReduce workers).
pub type MatroidBox = Box<dyn Matroid + Send + Sync>;

/// Materialize the matroid for `ds`.
pub fn build_matroid(spec: &MatroidSpec, ds: &Dataset) -> MatroidBox {
    match spec {
        MatroidSpec::Transversal => Box::new(TransversalMatroid::new()),
        MatroidSpec::PartitionProportional { target_rank } => {
            Box::new(synth::songsim_matroid(ds, *target_rank))
        }
        MatroidSpec::PartitionCaps(caps) => Box::new(PartitionMatroid::new(caps.clone())),
        MatroidSpec::Uniform(r) => Box::new(UniformMatroid::new(*r)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matroid::MatroidKind;

    #[test]
    fn parse_dataset_shorthands() {
        assert!(matches!(
            DatasetSpec::parse("wikisim:100", 1).unwrap(),
            DatasetSpec::Wikisim { n: 100, seed: 1 }
        ));
        assert!(matches!(
            DatasetSpec::parse("cube:50x4", 1).unwrap(),
            DatasetSpec::Cube { n: 50, dim: 4, .. }
        ));
        assert!(matches!(
            DatasetSpec::parse("some/file.dmmc", 1).unwrap(),
            DatasetSpec::File(_)
        ));
        assert!(DatasetSpec::parse("bogus:1", 1).is_err());
    }

    #[test]
    fn parse_matroid_shorthands() {
        assert!(matches!(
            MatroidSpec::parse("transversal").unwrap(),
            MatroidSpec::Transversal
        ));
        assert!(matches!(
            MatroidSpec::parse("partition:89").unwrap(),
            MatroidSpec::PartitionProportional { target_rank: 89 }
        ));
        assert!(matches!(
            MatroidSpec::parse("uniform:5").unwrap(),
            MatroidSpec::Uniform(5)
        ));
        assert!(MatroidSpec::parse("nope").is_err());
    }

    #[test]
    fn key_part_is_stable_and_collision_free() {
        // pinned literals: keys are persisted in caches, so they must not
        // drift with Debug formatting or field renames
        assert_eq!(MatroidSpec::Transversal.key_part(), "transversal");
        assert_eq!(
            MatroidSpec::PartitionProportional { target_rank: 89 }.key_part(),
            "partition:89"
        );
        assert_eq!(MatroidSpec::PartitionCaps(vec![1, 2, 3]).key_part(), "caps:1,2,3");
        assert_eq!(MatroidSpec::Uniform(16).key_part(), "uniform:16");
        // the separator keeps adjacent caps unambiguous
        assert_ne!(
            MatroidSpec::PartitionCaps(vec![1, 2]).key_part(),
            MatroidSpec::PartitionCaps(vec![12]).key_part()
        );
        // parseable shorthands roundtrip through their key form
        for s in ["transversal", "partition:89", "uniform:5"] {
            assert_eq!(MatroidSpec::parse(s).unwrap().key_part(), s);
        }
    }

    #[test]
    fn build_and_kind() {
        let spec = DatasetSpec::Wikisim { n: 100, seed: 1 };
        let ds = build_dataset(&spec).unwrap();
        let m = build_matroid(&MatroidSpec::default_for(&spec), &ds);
        assert_eq!(m.kind(), MatroidKind::Transversal);
        let spec2 = DatasetSpec::Songsim { n: 200, seed: 1 };
        let ds2 = build_dataset(&spec2).unwrap();
        let m2 = build_matroid(&MatroidSpec::default_for(&spec2), &ds2);
        assert_eq!(m2.kind(), MatroidKind::Partition);
    }
}
