//! Index persistence — the state the `dmmc index` subcommands carry
//! between invocations.
//!
//! A snapshot stores the *recipe* for the backing dataset (the CLI data
//! spec + seed; synthetic generators are deterministic, files reload) and
//! the tree state itself: config, epoch, ingest cursor, and every occupied
//! level's coreset indices.  The format is line-oriented text ("DMMCIDX1"
//! magic), f64s as hex bit patterns so reloads are bit-exact.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::algo::Budget;
use crate::index::tree::{CoresetIndex, IndexConfig, IndexNode, LeafIngest};
use crate::runtime::EngineKind;

const MAGIC: &str = "DMMCIDX1";

/// Everything needed to reconstruct a [`CoresetIndex`] (plus the CLI's
/// ingest cursor) in a later process.
#[derive(Clone, Debug)]
pub struct IndexSnapshot {
    /// CLI dataset spec string (`cube:2000x8`, a file path, ...).
    pub data: String,
    /// Seed the dataset spec was parsed with.
    pub seed: u64,
    /// CLI matroid spec shorthand (`transversal`, `partition:89`,
    /// `uniform:16`).
    pub matroid: String,
    pub k_max: usize,
    pub leaf_budget: Budget,
    pub reduce_budget: Budget,
    pub engine: EngineKind,
    pub leaf_ingest: LeafIngest,
    pub epoch: u64,
    pub segments: usize,
    pub points: usize,
    /// Next dataset row the CLI's sequential ingestion will consume.
    pub cursor: usize,
    pub levels: Vec<Option<IndexNode>>,
}

impl IndexSnapshot {
    /// Capture the tree state of `index` (the caller supplies the CLI
    /// recipe fields the tree does not know about).
    pub fn capture(
        index: &CoresetIndex<'_>,
        data: String,
        seed: u64,
        matroid: String,
        cursor: usize,
    ) -> IndexSnapshot {
        let cfg = index.config();
        IndexSnapshot {
            data,
            seed,
            matroid,
            k_max: cfg.k_max,
            leaf_budget: cfg.leaf_budget,
            reduce_budget: cfg.reduce_budget,
            engine: cfg.engine,
            leaf_ingest: cfg.leaf_ingest,
            epoch: index.epoch(),
            segments: index.segments(),
            points: index.points_ingested(),
            cursor,
            levels: index.levels().to_vec(),
        }
    }

    pub fn config(&self) -> IndexConfig {
        IndexConfig {
            k_max: self.k_max,
            leaf_budget: self.leaf_budget,
            reduce_budget: self.reduce_budget,
            engine: self.engine,
            leaf_ingest: self.leaf_ingest,
        }
    }
}

fn budget_to_str(b: Budget) -> String {
    match b {
        Budget::Clusters(tau) => format!("clusters:{tau}"),
        Budget::Epsilon(eps) => format!("eps:{:x}", eps.to_bits()),
    }
}

fn budget_from_str(s: &str) -> Result<Budget> {
    if let Some(rest) = s.strip_prefix("clusters:") {
        return Ok(Budget::Clusters(rest.parse().context("budget tau")?));
    }
    if let Some(rest) = s.strip_prefix("eps:") {
        let bits = u64::from_str_radix(rest, 16).context("budget eps bits")?;
        return Ok(Budget::Epsilon(f64::from_bits(bits)));
    }
    bail!("bad budget {s} (clusters:<tau> | eps:<bits>)")
}

/// Serialize a snapshot to its text form.
pub fn to_string(snap: &IndexSnapshot) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{MAGIC}");
    let _ = writeln!(out, "data {}", snap.data);
    let _ = writeln!(out, "seed {}", snap.seed);
    let _ = writeln!(out, "matroid {}", snap.matroid);
    let _ = writeln!(out, "k_max {}", snap.k_max);
    let _ = writeln!(out, "leaf_budget {}", budget_to_str(snap.leaf_budget));
    let _ = writeln!(out, "reduce_budget {}", budget_to_str(snap.reduce_budget));
    let _ = writeln!(out, "engine {}", snap.engine.name());
    let _ = writeln!(out, "leaf_ingest {}", snap.leaf_ingest.name());
    let _ = writeln!(out, "epoch {}", snap.epoch);
    let _ = writeln!(out, "segments {}", snap.segments);
    let _ = writeln!(out, "points {}", snap.points);
    let _ = writeln!(out, "cursor {}", snap.cursor);
    let _ = writeln!(out, "levels {}", snap.levels.len());
    for (i, level) in snap.levels.iter().enumerate() {
        match level {
            None => {
                let _ = writeln!(out, "level {i} absent");
            }
            Some(node) => {
                let _ = writeln!(
                    out,
                    "level {i} node {} {} {} {:x}",
                    node.segments,
                    node.points,
                    node.n_clusters,
                    node.radius.to_bits()
                );
                let ids: Vec<String> = node.indices.iter().map(|x| x.to_string()).collect();
                let _ = writeln!(out, "indices {}", ids.join(" "));
            }
        }
    }
    out
}

/// Parse the text form back into a snapshot.
pub fn from_str(text: &str) -> Result<IndexSnapshot> {
    let mut lines = text.lines();
    let magic = lines.next().context("empty index file")?;
    if magic.trim() != MAGIC {
        bail!("not a {MAGIC} index file");
    }
    // fixed header order keeps the parser trivial and the format auditable
    let mut field = |name: &str| -> Result<String> {
        let line = lines.next().with_context(|| format!("missing field {name}"))?;
        let rest = line
            .strip_prefix(name)
            .with_context(|| format!("expected field {name}, got {line:?}"))?;
        Ok(rest.trim().to_string())
    };
    let data = field("data")?;
    let seed: u64 = field("seed")?.parse().context("seed")?;
    let matroid = field("matroid")?;
    let k_max: usize = field("k_max")?.parse().context("k_max")?;
    let leaf_budget = budget_from_str(&field("leaf_budget")?)?;
    let reduce_budget = budget_from_str(&field("reduce_budget")?)?;
    let engine_name = field("engine")?;
    let engine = EngineKind::parse(&engine_name)
        .with_context(|| format!("unknown engine {engine_name}"))?;
    let ingest_name = field("leaf_ingest")?;
    let leaf_ingest = LeafIngest::parse(&ingest_name)
        .with_context(|| format!("unknown leaf_ingest {ingest_name}"))?;
    let epoch: u64 = field("epoch")?.parse().context("epoch")?;
    let segments: usize = field("segments")?.parse().context("segments")?;
    let points: usize = field("points")?.parse().context("points")?;
    let cursor: usize = field("cursor")?.parse().context("cursor")?;
    let n_levels: usize = field("levels")?.parse().context("levels")?;

    let mut levels: Vec<Option<IndexNode>> = Vec::with_capacity(n_levels);
    for i in 0..n_levels {
        let line = lines.next().with_context(|| format!("missing level {i}"))?;
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() < 3 || toks[0] != "level" || toks[1] != i.to_string() {
            bail!("bad level line {line:?}");
        }
        match toks[2] {
            "absent" => levels.push(None),
            "node" => {
                if toks.len() != 7 {
                    bail!("bad node line {line:?}");
                }
                let node_segments: usize = toks[3].parse().context("node segments")?;
                let node_points: usize = toks[4].parse().context("node points")?;
                let n_clusters: usize = toks[5].parse().context("node clusters")?;
                let radius =
                    f64::from_bits(u64::from_str_radix(toks[6], 16).context("node radius")?);
                let idx_line = lines.next().with_context(|| format!("missing indices {i}"))?;
                let rest = idx_line
                    .strip_prefix("indices")
                    .with_context(|| format!("expected indices line, got {idx_line:?}"))?;
                let indices: Vec<usize> = rest
                    .split_whitespace()
                    .map(|t| t.parse::<usize>().context("index"))
                    .collect::<Result<_>>()?;
                levels.push(Some(IndexNode {
                    indices,
                    segments: node_segments,
                    points: node_points,
                    n_clusters,
                    radius,
                }));
            }
            other => bail!("bad level tag {other}"),
        }
    }
    Ok(IndexSnapshot {
        data,
        seed,
        matroid,
        k_max,
        leaf_budget,
        reduce_budget,
        engine,
        leaf_ingest,
        epoch,
        segments,
        points,
        cursor,
        levels,
    })
}

pub fn save(snap: &IndexSnapshot, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path.as_ref(), to_string(snap)).context("write index file")
}

pub fn load(path: impl AsRef<Path>) -> Result<IndexSnapshot> {
    let text = std::fs::read_to_string(path.as_ref()).context("read index file")?;
    from_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::UniformMatroid;
    use crate::runtime::EngineKind;

    #[test]
    fn snapshot_roundtrips_bit_exactly() {
        let ds = synth::uniform_cube(200, 2, 29);
        let m = UniformMatroid::new(4);
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(4, 8)
        };
        let mut idx = CoresetIndex::new(&ds, &m, cfg);
        let order: Vec<usize> = (0..150).collect();
        idx.ingest(&order, 50).unwrap();
        let snap = IndexSnapshot::capture(&idx, "cube:200x2".into(), 29, "uniform:4".into(), 150);
        let text = to_string(&snap);
        let back = from_str(&text).unwrap();
        assert_eq!(back.data, "cube:200x2");
        assert_eq!(back.seed, 29);
        assert_eq!(back.matroid, "uniform:4");
        assert_eq!(back.epoch, 3);
        assert_eq!(back.segments, 3);
        assert_eq!(back.points, 150);
        assert_eq!(back.cursor, 150);
        assert_eq!(back.levels.len(), snap.levels.len());
        for (a, b) in snap.levels.iter().zip(&back.levels) {
            match (a, b) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.indices, y.indices);
                    assert_eq!(x.segments, y.segments);
                    assert_eq!(x.points, y.points);
                    assert_eq!(x.n_clusters, y.n_clusters);
                    assert_eq!(x.radius.to_bits(), y.radius.to_bits());
                }
                _ => panic!("level occupancy changed over the roundtrip"),
            }
        }
        // the restored tree keeps serving: same root, appends continue
        let back_cfg = back.config();
        let mut idx2 = CoresetIndex::from_parts(
            &ds,
            &m,
            back_cfg,
            back.levels.clone(),
            back.epoch,
            back.segments,
            back.points,
        );
        assert_eq!(idx2.root(), idx.root());
        let more: Vec<usize> = (150..200).collect();
        let r = idx2.append(&more).unwrap();
        assert_eq!(r.segment, 4);
        assert_eq!(idx2.epoch(), 4);
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_str("nonsense").is_err());
        assert!(from_str("DMMCIDX1\ndata x\nseed nope\n").is_err());
        assert!(budget_from_str("bogus").is_err());
        assert!(matches!(budget_from_str("clusters:7").unwrap(), Budget::Clusters(7)));
        let eps = Budget::Epsilon(0.25);
        match budget_from_str(&budget_to_str(eps)).unwrap() {
            Budget::Epsilon(e) => assert_eq!(e.to_bits(), 0.25f64.to_bits()),
            _ => panic!("budget kind changed"),
        }
    }
}
