//! Index persistence — the state the `dmmc index` subcommands carry
//! between invocations.
//!
//! A snapshot stores the *recipe* for the backing dataset (the CLI data
//! spec + seed; synthetic generators are deterministic, files reload) and
//! the tree state itself: config, epoch, ingest cursor, lifetime stats,
//! tombstones, and every occupied level's coreset indices.  The format is
//! line-oriented text (`DMMCIDX2` magic), f64s as hex bit patterns so
//! reloads are bit-exact.
//!
//! Legacy `DMMCIDX1` files (written before the index became dynamic)
//! still load: they imply keep-all retention, no tombstones, and a
//! reconstructed stats ledger (`appends = segments`, `merges = segments -
//! occupied levels` — exact for a pure-append keep-all tree — and
//! `dist_evals = 0`, which v1 never recorded).

use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::algo::Budget;
use crate::coordinator::spec::MatroidBox;
use crate::coordinator::{build_dataset, build_matroid, DatasetSpec, MatroidSpec};
use crate::core::Dataset;
use crate::index::service::QueryResult;
use crate::index::tree::{
    CoresetIndex, IndexConfig, IndexNode, IndexParts, IndexStats, LeafIngest, RetentionPolicy,
    DEFAULT_REBUILD_THRESHOLD,
};
use crate::runtime::EngineKind;
use crate::util::fnv1a;

const MAGIC_V2: &str = "DMMCIDX2";
const MAGIC_V1: &str = "DMMCIDX1";
const CACHE_MAGIC: &str = "DMMCCACHE1";

/// Everything needed to reconstruct a [`CoresetIndex`] (plus the CLI's
/// ingest cursor) in a later process.
#[derive(Clone, Debug)]
pub struct IndexSnapshot {
    /// CLI dataset spec string (`cube:2000x8`, a file path, ...).
    pub data: String,
    /// Seed the dataset spec was parsed with.
    pub seed: u64,
    /// CLI matroid spec shorthand (`transversal`, `partition:89`,
    /// `uniform:16`).
    pub matroid: String,
    pub k_max: usize,
    pub leaf_budget: Budget,
    pub reduce_budget: Budget,
    pub engine: EngineKind,
    pub leaf_ingest: LeafIngest,
    pub retention: RetentionPolicy,
    pub rebuild_threshold: f64,
    pub epoch: u64,
    pub segments: usize,
    pub points: usize,
    /// Next dataset row the CLI's sequential ingestion will consume.
    pub cursor: usize,
    /// Lifetime ledger — survives the roundtrip (a reloaded index keeps
    /// its append/merge/dist-eval history).
    pub stats: IndexStats,
    pub tombstones: BTreeSet<usize>,
    pub levels: Vec<Option<IndexNode>>,
}

impl IndexSnapshot {
    /// Capture the tree state of `index` (the caller supplies the CLI
    /// recipe fields the tree does not know about).
    pub fn capture(
        index: &CoresetIndex<'_>,
        data: String,
        seed: u64,
        matroid: String,
        cursor: usize,
    ) -> IndexSnapshot {
        let cfg = index.config();
        let parts = index.parts();
        IndexSnapshot {
            data,
            seed,
            matroid,
            k_max: cfg.k_max,
            leaf_budget: cfg.leaf_budget,
            reduce_budget: cfg.reduce_budget,
            engine: cfg.engine,
            leaf_ingest: cfg.leaf_ingest,
            retention: cfg.retention,
            rebuild_threshold: cfg.rebuild_threshold,
            epoch: parts.epoch,
            segments: parts.segments,
            points: parts.points,
            cursor,
            stats: parts.stats,
            tombstones: parts.tombstones,
            levels: parts.levels,
        }
    }

    pub fn config(&self) -> IndexConfig {
        IndexConfig {
            k_max: self.k_max,
            leaf_budget: self.leaf_budget,
            reduce_budget: self.reduce_budget,
            engine: self.engine,
            leaf_ingest: self.leaf_ingest,
            retention: self.retention,
            rebuild_threshold: self.rebuild_threshold,
        }
    }

    /// The resumable state for [`CoresetIndex::from_parts`].
    pub fn parts(&self) -> IndexParts {
        IndexParts {
            levels: self.levels.clone(),
            epoch: self.epoch,
            segments: self.segments,
            points: self.points,
            stats: self.stats,
            tombstones: self.tombstones.clone(),
        }
    }
}

fn budget_to_str(b: Budget) -> String {
    match b {
        Budget::Clusters(tau) => format!("clusters:{tau}"),
        Budget::Epsilon(eps) => format!("eps:{:x}", eps.to_bits()),
    }
}

fn budget_from_str(s: &str) -> Result<Budget> {
    if let Some(rest) = s.strip_prefix("clusters:") {
        return Ok(Budget::Clusters(rest.parse().context("budget tau")?));
    }
    if let Some(rest) = s.strip_prefix("eps:") {
        let bits = u64::from_str_radix(rest, 16).context("budget eps bits")?;
        return Ok(Budget::Epsilon(f64::from_bits(bits)));
    }
    bail!("bad budget {s} (clusters:<tau> | eps:<bits>)")
}

/// Serialize a snapshot to its text form (always the current `DMMCIDX2`).
pub fn to_string(snap: &IndexSnapshot) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{MAGIC_V2}");
    let _ = writeln!(out, "data {}", snap.data);
    let _ = writeln!(out, "seed {}", snap.seed);
    let _ = writeln!(out, "matroid {}", snap.matroid);
    let _ = writeln!(out, "k_max {}", snap.k_max);
    let _ = writeln!(out, "leaf_budget {}", budget_to_str(snap.leaf_budget));
    let _ = writeln!(out, "reduce_budget {}", budget_to_str(snap.reduce_budget));
    let _ = writeln!(out, "engine {}", snap.engine.name());
    let _ = writeln!(out, "leaf_ingest {}", snap.leaf_ingest.name());
    let _ = writeln!(out, "retention {}", snap.retention.name());
    let _ = writeln!(out, "rebuild_threshold {:x}", snap.rebuild_threshold.to_bits());
    let _ = writeln!(out, "epoch {}", snap.epoch);
    let _ = writeln!(out, "segments {}", snap.segments);
    let _ = writeln!(out, "points {}", snap.points);
    let _ = writeln!(out, "cursor {}", snap.cursor);
    let s = snap.stats;
    let _ = writeln!(
        out,
        "stats {} {} {} {} {} {}",
        s.appends, s.merges, s.dist_evals, s.deletes, s.rebuilds, s.expired_segments
    );
    let dead: Vec<String> = snap.tombstones.iter().map(|x| x.to_string()).collect();
    let _ = writeln!(out, "tombstones {}", dead.join(" "));
    let _ = writeln!(out, "levels {}", snap.levels.len());
    for (i, level) in snap.levels.iter().enumerate() {
        match level {
            None => {
                let _ = writeln!(out, "level {i} absent");
            }
            Some(node) => {
                let _ = writeln!(
                    out,
                    "level {i} node {} {} {} {:x} {} {}",
                    node.segments,
                    node.points,
                    node.n_clusters,
                    node.radius.to_bits(),
                    node.first_segment,
                    node.born_epoch,
                );
                let ids: Vec<String> = node.indices.iter().map(|x| x.to_string()).collect();
                let _ = writeln!(out, "indices {}", ids.join(" "));
            }
        }
    }
    out
}

/// Parse the text form back into a snapshot (`DMMCIDX2`, or legacy
/// `DMMCIDX1` with the defaults described in the module docs).
pub fn from_str(text: &str) -> Result<IndexSnapshot> {
    let mut lines = text.lines();
    let magic = lines.next().context("empty index file")?;
    let v2 = match magic.trim() {
        MAGIC_V2 => true,
        MAGIC_V1 => false,
        _ => bail!("not a {MAGIC_V2} (or legacy {MAGIC_V1}) index file"),
    };
    // fixed header order keeps the parser trivial and the format auditable
    let mut field = |name: &str| -> Result<String> {
        let line = lines.next().with_context(|| format!("missing field {name}"))?;
        let rest = line
            .strip_prefix(name)
            .with_context(|| format!("expected field {name}, got {line:?}"))?;
        Ok(rest.trim().to_string())
    };
    let data = field("data")?;
    let seed: u64 = field("seed")?.parse().context("seed")?;
    let matroid = field("matroid")?;
    let k_max: usize = field("k_max")?.parse().context("k_max")?;
    let leaf_budget = budget_from_str(&field("leaf_budget")?)?;
    let reduce_budget = budget_from_str(&field("reduce_budget")?)?;
    let engine_name = field("engine")?;
    let engine = EngineKind::parse(&engine_name)
        .with_context(|| format!("unknown engine {engine_name}"))?;
    let ingest_name = field("leaf_ingest")?;
    let leaf_ingest = LeafIngest::parse(&ingest_name)
        .with_context(|| format!("unknown leaf_ingest {ingest_name}"))?;
    let (retention, rebuild_threshold) = if v2 {
        let ret_name = field("retention")?;
        let retention = RetentionPolicy::parse(&ret_name)
            .with_context(|| format!("unknown retention {ret_name}"))?;
        let bits =
            u64::from_str_radix(&field("rebuild_threshold")?, 16).context("threshold bits")?;
        (retention, f64::from_bits(bits))
    } else {
        (RetentionPolicy::KeepAll, DEFAULT_REBUILD_THRESHOLD)
    };
    let epoch: u64 = field("epoch")?.parse().context("epoch")?;
    let segments: usize = field("segments")?.parse().context("segments")?;
    let points: usize = field("points")?.parse().context("points")?;
    let cursor: usize = field("cursor")?.parse().context("cursor")?;
    let (stats, tombstones) = if v2 {
        let stat_toks: Vec<u64> = field("stats")?
            .split_whitespace()
            .map(|t| t.parse::<u64>().context("stats entry"))
            .collect::<Result<_>>()?;
        if stat_toks.len() != 6 {
            bail!("stats line needs 6 entries, got {}", stat_toks.len());
        }
        let stats = IndexStats {
            appends: stat_toks[0],
            merges: stat_toks[1],
            dist_evals: stat_toks[2],
            deletes: stat_toks[3],
            rebuilds: stat_toks[4],
            expired_segments: stat_toks[5],
        };
        let tombstones: BTreeSet<usize> = field("tombstones")?
            .split_whitespace()
            .map(|t| t.parse::<usize>().context("tombstone row"))
            .collect::<Result<_>>()?;
        (Some(stats), tombstones)
    } else {
        (None, BTreeSet::new())
    };
    let n_levels: usize = field("levels")?.parse().context("levels")?;

    let mut levels: Vec<Option<IndexNode>> = Vec::with_capacity(n_levels);
    for i in 0..n_levels {
        let line = lines.next().with_context(|| format!("missing level {i}"))?;
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() < 3 || toks[0] != "level" || toks[1] != i.to_string() {
            bail!("bad level line {line:?}");
        }
        match toks[2] {
            "absent" => levels.push(None),
            "node" => {
                let want = if v2 { 9 } else { 7 };
                if toks.len() != want {
                    bail!("bad node line {line:?}");
                }
                let node_segments: usize = toks[3].parse().context("node segments")?;
                let node_points: usize = toks[4].parse().context("node points")?;
                let n_clusters: usize = toks[5].parse().context("node clusters")?;
                let radius =
                    f64::from_bits(u64::from_str_radix(toks[6], 16).context("node radius")?);
                // v1 wrote no provenance: 0 = "unknown first segment",
                // which only windowed retention reads, and v1 trees were
                // always keep-all
                let (first_segment, born_epoch) = if v2 {
                    (
                        toks[7].parse().context("node first_segment")?,
                        toks[8].parse().context("node born_epoch")?,
                    )
                } else {
                    (0, 0)
                };
                let idx_line = lines.next().with_context(|| format!("missing indices {i}"))?;
                let rest = idx_line
                    .strip_prefix("indices")
                    .with_context(|| format!("expected indices line, got {idx_line:?}"))?;
                let indices: Vec<usize> = rest
                    .split_whitespace()
                    .map(|t| t.parse::<usize>().context("index"))
                    .collect::<Result<_>>()?;
                levels.push(Some(IndexNode {
                    indices,
                    segments: node_segments,
                    points: node_points,
                    n_clusters,
                    radius,
                    first_segment,
                    born_epoch,
                }));
            }
            other => bail!("bad level tag {other}"),
        }
    }
    let stats = stats.unwrap_or_else(|| {
        // v1 never persisted the ledger; reconstruct what a pure-append
        // keep-all tree implies and leave dist_evals (unknowable) at 0
        let occupied = levels.iter().flatten().count() as u64;
        IndexStats {
            appends: segments as u64,
            merges: (segments as u64).saturating_sub(occupied),
            ..IndexStats::default()
        }
    });
    Ok(IndexSnapshot {
        data,
        seed,
        matroid,
        k_max,
        leaf_budget,
        reduce_budget,
        engine,
        leaf_ingest,
        retention,
        rebuild_threshold,
        epoch,
        segments,
        points,
        cursor,
        stats,
        tombstones,
        levels,
    })
}

pub fn save(snap: &IndexSnapshot, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path.as_ref(), to_string(snap)).context("write index file")
}

pub fn load(path: impl AsRef<Path>) -> Result<IndexSnapshot> {
    let text = std::fs::read_to_string(path.as_ref()).context("read index file")?;
    from_str(&text)
}

/// Reconstruct `(dataset, matroid)` from a snapshot's recipe fields —
/// the one way every consumer (the `dmmc index` subcommands, the serve
/// tenants) rebuilds the world a persisted tree was built over.
pub fn snapshot_world(snap: &IndexSnapshot) -> Result<(Dataset, MatroidBox)> {
    let spec = DatasetSpec::parse(&snap.data, snap.seed)?;
    let ds = build_dataset(&spec)?;
    let mspec = MatroidSpec::parse(&snap.matroid)?;
    let matroid = build_matroid(&mspec, &ds);
    Ok((ds, matroid))
}

/// Content identity of a snapshot: the hash of its exact text form.  Any
/// state change (epoch, levels, tombstones, cursor, config) changes the
/// id, so a result-cache sidecar stamped with it can never be replayed
/// against a tree it was not computed from.
pub fn snapshot_id(snap: &IndexSnapshot) -> u64 {
    fnv1a(&to_string(snap))
}

/// Sidecar path for the persisted result cache of the index at `path`
/// (`foo.dmmcx` -> `foo.dmmcx.cache`).
pub fn result_cache_path(path: impl AsRef<Path>) -> PathBuf {
    let mut s = path.as_ref().as_os_str().to_os_string();
    s.push(".cache");
    PathBuf::from(s)
}

/// Serialize persisted result-cache entries (`DMMCCACHE1`): diversity as
/// f64 hex bits so a warm hit replays the cold result bit for bit.  Cache
/// keys contain no whitespace by construction (`QuerySpec::cache_key` is
/// `|`-separated), so the line format stays split_whitespace-parseable.
pub fn result_cache_to_string(snapshot_id: u64, entries: &[(String, u64, QueryResult)]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{CACHE_MAGIC}");
    let _ = writeln!(out, "snapshot {snapshot_id:016x}");
    let _ = writeln!(out, "entries {}", entries.len());
    for (key, epoch, result) in entries {
        debug_assert!(!key.contains(char::is_whitespace), "cache key {key:?} has whitespace");
        let _ = writeln!(
            out,
            "entry {epoch} {} {:x} {key}",
            result.coreset_size,
            result.diversity.to_bits(),
        );
        let ids: Vec<String> = result.solution.iter().map(|x| x.to_string()).collect();
        let _ = writeln!(out, "solution {}", ids.join(" "));
    }
    out
}

/// Parse a `DMMCCACHE1` sidecar back into `(snapshot_id, entries)`.
pub fn result_cache_from_str(text: &str) -> Result<(u64, Vec<(String, u64, QueryResult)>)> {
    let mut lines = text.lines();
    let magic = lines.next().context("empty cache file")?;
    if magic.trim() != CACHE_MAGIC {
        bail!("not a {CACHE_MAGIC} result-cache file");
    }
    let snap_line = lines.next().context("missing snapshot line")?;
    let id_hex = snap_line
        .strip_prefix("snapshot")
        .with_context(|| format!("expected snapshot line, got {snap_line:?}"))?
        .trim();
    let snapshot_id = u64::from_str_radix(id_hex, 16).context("snapshot id bits")?;
    let n_line = lines.next().context("missing entries line")?;
    let n: usize = n_line
        .strip_prefix("entries")
        .with_context(|| format!("expected entries line, got {n_line:?}"))?
        .trim()
        .parse()
        .context("entries count")?;
    let mut entries = Vec::with_capacity(n);
    for i in 0..n {
        let line = lines.next().with_context(|| format!("missing entry {i}"))?;
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() != 5 || toks[0] != "entry" {
            bail!("bad entry line {line:?}");
        }
        let epoch: u64 = toks[1].parse().context("entry epoch")?;
        let coreset_size: usize = toks[2].parse().context("entry coreset size")?;
        let diversity =
            f64::from_bits(u64::from_str_radix(toks[3], 16).context("entry diversity bits")?);
        let key = toks[4].to_string();
        let sol_line = lines.next().with_context(|| format!("missing solution {i}"))?;
        let rest = sol_line
            .strip_prefix("solution")
            .with_context(|| format!("expected solution line, got {sol_line:?}"))?;
        let solution: Vec<usize> = rest
            .split_whitespace()
            .map(|t| t.parse::<usize>().context("solution id"))
            .collect::<Result<_>>()?;
        entries.push((
            key,
            epoch,
            QueryResult {
                solution,
                diversity,
                coreset_size,
            },
        ));
    }
    Ok((snapshot_id, entries))
}

pub fn save_result_cache(
    path: impl AsRef<Path>,
    snapshot_id: u64,
    entries: &[(String, u64, QueryResult)],
) -> Result<()> {
    std::fs::write(path.as_ref(), result_cache_to_string(snapshot_id, entries))
        .context("write result-cache sidecar")
}

/// Load the sidecar's entries, but only if it was stamped with
/// `expected_id`.  The cache is a best-effort warm start: a missing
/// sidecar, an unparseable one, or an id mismatch (the index file changed
/// without its sidecar) all degrade to an empty cache, never an error —
/// serving correctness must not depend on a sidecar's health.
pub fn load_result_cache(
    path: impl AsRef<Path>,
    expected_id: u64,
) -> Vec<(String, u64, QueryResult)> {
    let Ok(text) = std::fs::read_to_string(path.as_ref()) else {
        return Vec::new();
    };
    match result_cache_from_str(&text) {
        Ok((id, entries)) if id == expected_id => entries,
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::UniformMatroid;
    use crate::runtime::EngineKind;

    #[test]
    fn snapshot_roundtrips_bit_exactly() {
        let ds = synth::uniform_cube(200, 2, 29);
        let m = UniformMatroid::new(4);
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(4, 8)
        };
        let mut idx = CoresetIndex::new(&ds, &m, cfg);
        let order: Vec<usize> = (0..150).collect();
        idx.ingest(&order, 50).unwrap();
        idx.delete(&[3, 1, 4]).unwrap();
        let snap = IndexSnapshot::capture(&idx, "cube:200x2".into(), 29, "uniform:4".into(), 150);
        let text = to_string(&snap);
        assert!(text.starts_with("DMMCIDX2\n"));
        let back = from_str(&text).unwrap();
        assert_eq!(back.data, "cube:200x2");
        assert_eq!(back.seed, 29);
        assert_eq!(back.matroid, "uniform:4");
        assert_eq!(back.epoch, 4, "3 appends + 1 delete");
        assert_eq!(back.segments, 3);
        assert_eq!(back.points, 150);
        assert_eq!(back.cursor, 150);
        assert_eq!(back.retention, RetentionPolicy::KeepAll);
        assert_eq!(
            back.rebuild_threshold.to_bits(),
            DEFAULT_REBUILD_THRESHOLD.to_bits()
        );
        // the lifetime ledger survives the roundtrip (this is the
        // from_parts stats-reset regression)
        assert_eq!(back.stats, *idx.stats());
        assert_eq!(back.stats.appends, 3);
        assert_eq!(back.stats.deletes, 1);
        assert_eq!(back.tombstones, *idx.tombstones());
        assert_eq!(back.levels.len(), snap.levels.len());
        for (a, b) in snap.levels.iter().zip(&back.levels) {
            match (a, b) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.indices, y.indices);
                    assert_eq!(x.segments, y.segments);
                    assert_eq!(x.points, y.points);
                    assert_eq!(x.n_clusters, y.n_clusters);
                    assert_eq!(x.radius.to_bits(), y.radius.to_bits());
                    assert_eq!(x.first_segment, y.first_segment);
                    assert_eq!(x.born_epoch, y.born_epoch);
                }
                _ => panic!("level occupancy changed over the roundtrip"),
            }
        }
        // the restored tree keeps serving: same root and stats, appends
        // and deletes continue
        let mut idx2 = CoresetIndex::from_parts(&ds, &m, back.config(), back.parts());
        assert_eq!(idx2.root(), idx.root());
        assert_eq!(idx2.stats(), idx.stats());
        let more: Vec<usize> = (150..200).collect();
        let r = idx2.append(&more).unwrap();
        assert_eq!(r.segment, 4);
        assert_eq!(idx2.epoch(), 5);
        assert_eq!(idx2.stats().appends, 4);
    }

    #[test]
    fn windowed_retention_roundtrips() {
        let ds = synth::uniform_cube(200, 2, 41);
        let m = UniformMatroid::new(3);
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            retention: RetentionPolicy::LastSegments(2),
            ..IndexConfig::new(3, 6)
        };
        let mut idx = CoresetIndex::new(&ds, &m, cfg);
        let order: Vec<usize> = (0..200).collect();
        idx.ingest(&order, 40).unwrap();
        let snap = IndexSnapshot::capture(&idx, "cube:200x2".into(), 41, "uniform:3".into(), 200);
        let back = from_str(&to_string(&snap)).unwrap();
        assert_eq!(back.retention, RetentionPolicy::LastSegments(2));
        let mut idx2 = CoresetIndex::from_parts(&ds, &m, back.config(), back.parts());
        assert_eq!(idx2.root(), idx.root());
        // the restored window keeps sliding: a fresh append still expires
        // the oldest surviving segment
        let r = idx2.append(&(0..40).collect::<Vec<_>>()).unwrap();
        assert_eq!(r.expired, 1);
    }

    #[test]
    fn legacy_v1_snapshots_still_load() {
        // a literal DMMCIDX1 file as the previous release wrote it
        let text = "DMMCIDX1\n\
                    data cube:100x2\n\
                    seed 7\n\
                    matroid uniform:3\n\
                    k_max 3\n\
                    leaf_budget clusters:6\n\
                    reduce_budget clusters:6\n\
                    engine scalar\n\
                    leaf_ingest seq\n\
                    epoch 3\n\
                    segments 3\n\
                    points 90\n\
                    cursor 90\n\
                    levels 2\n\
                    level 0 node 1 30 4 3ff0000000000000\n\
                    indices 61 64 70 77\n\
                    level 1 node 2 60 5 4000000000000000\n\
                    indices 2 11 19 40 55\n";
        let snap = from_str(text).unwrap();
        assert_eq!(snap.retention, RetentionPolicy::KeepAll);
        assert_eq!(
            snap.rebuild_threshold.to_bits(),
            DEFAULT_REBUILD_THRESHOLD.to_bits()
        );
        assert!(snap.tombstones.is_empty());
        // reconstructed ledger: appends = segments, merges = segments -
        // occupied levels (exact for pure-append keep-all), evals unknown
        assert_eq!(snap.stats.appends, 3);
        assert_eq!(snap.stats.merges, 1);
        assert_eq!(snap.stats.dist_evals, 0);
        let node = snap.levels[1].as_ref().unwrap();
        assert_eq!(node.indices, vec![2, 11, 19, 40, 55]);
        assert_eq!(node.first_segment, 0, "v1 provenance is unknown");
        assert_eq!(node.radius, 2.0);
        // and a v2 rewrite of it parses back identically
        let back = from_str(&to_string(&snap)).unwrap();
        assert_eq!(back.stats, snap.stats);
        assert_eq!(back.levels.len(), snap.levels.len());
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_str("nonsense").is_err());
        assert!(from_str("DMMCIDX2\ndata x\nseed nope\n").is_err());
        assert!(from_str("DMMCIDX1\ndata x\nseed nope\n").is_err());
        assert!(budget_from_str("bogus").is_err());
        assert!(matches!(budget_from_str("clusters:7").unwrap(), Budget::Clusters(7)));
        let eps = Budget::Epsilon(0.25);
        match budget_from_str(&budget_to_str(eps)).unwrap() {
            Budget::Epsilon(e) => assert_eq!(e.to_bits(), 0.25f64.to_bits()),
            _ => panic!("budget kind changed"),
        }
    }

    fn sample_entries() -> Vec<(String, u64, QueryResult)> {
        vec![
            (
                "sum|k=3|m=build|e=scalar|f=ls:0".to_string(),
                4,
                QueryResult {
                    solution: vec![7, 19, 42],
                    diversity: 3.75,
                    coreset_size: 24,
                },
            ),
            (
                "tree|k=2|m=uniform:2|e=batch|f=greedy".to_string(),
                4,
                QueryResult {
                    solution: vec![0, 99],
                    diversity: 0.5f64.sqrt(),
                    coreset_size: 24,
                },
            ),
        ]
    }

    #[test]
    fn result_cache_roundtrips_bit_exactly() {
        let entries = sample_entries();
        let text = result_cache_to_string(0xdead_beef_cafe_f00d, &entries);
        assert!(text.starts_with("DMMCCACHE1\n"));
        let (id, back) = result_cache_from_str(&text).unwrap();
        assert_eq!(id, 0xdead_beef_cafe_f00d);
        assert_eq!(back.len(), entries.len());
        for ((ka, ea, ra), (kb, eb, rb)) in entries.iter().zip(&back) {
            assert_eq!(ka, kb);
            assert_eq!(ea, eb);
            assert_eq!(ra.solution, rb.solution);
            assert_eq!(ra.diversity.to_bits(), rb.diversity.to_bits());
            assert_eq!(ra.coreset_size, rb.coreset_size);
        }
    }

    #[test]
    fn result_cache_rejects_garbage() {
        assert!(result_cache_from_str("nonsense").is_err());
        assert!(result_cache_from_str("DMMCCACHE1\nsnapshot zz\n").is_err());
        assert!(result_cache_from_str("DMMCCACHE1\nsnapshot 0\nentries 1\n").is_err());
        let (_, empty) = result_cache_from_str("DMMCCACHE1\nsnapshot ff\nentries 0\n").unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn sidecar_load_is_best_effort() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("dmmc_store_sidecar_{}.dmmcx", std::process::id()));
        let sidecar = result_cache_path(&path);
        assert_eq!(
            sidecar.file_name().unwrap().to_str().unwrap(),
            format!("dmmc_store_sidecar_{}.dmmcx.cache", std::process::id()),
        );
        // missing file -> empty, not an error
        let _ = std::fs::remove_file(&sidecar);
        assert!(load_result_cache(&sidecar, 1).is_empty());
        // stamped with another snapshot id -> empty (stale sidecar)
        let entries = sample_entries();
        save_result_cache(&sidecar, 1, &entries).unwrap();
        assert!(load_result_cache(&sidecar, 2).is_empty());
        // matching id -> the entries come back
        let back = load_result_cache(&sidecar, 1);
        assert_eq!(back.len(), entries.len());
        // corrupt file -> empty
        std::fs::write(&sidecar, "DMMCCACHE1\nsnapshot 1\nentries 9\n").unwrap();
        assert!(load_result_cache(&sidecar, 1).is_empty());
        let _ = std::fs::remove_file(&sidecar);
    }

    #[test]
    fn snapshot_id_tracks_state_changes() {
        let ds = synth::uniform_cube(120, 2, 53);
        let m = UniformMatroid::new(3);
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(3, 6)
        };
        let mut idx = CoresetIndex::new(&ds, &m, cfg);
        idx.append(&(0..60).collect::<Vec<_>>()).unwrap();
        let snap = IndexSnapshot::capture(&idx, "cube:120x2".into(), 53, "uniform:3".into(), 60);
        let id0 = snapshot_id(&snap);
        assert_eq!(id0, snapshot_id(&from_str(&to_string(&snap)).unwrap()), "id is content-stable");
        idx.append(&(60..120).collect::<Vec<_>>()).unwrap();
        let snap2 = IndexSnapshot::capture(&idx, "cube:120x2".into(), 53, "uniform:3".into(), 120);
        assert_ne!(id0, snapshot_id(&snap2), "an append must change the snapshot id");
    }
}
