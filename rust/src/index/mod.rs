//! The composable coreset index + query service — the serving layer that
//! amortizes coreset construction across many `(objective, k, matroid,
//! engine)` queries.
//!
//! Every `run_pipeline` call rebuilds its coreset from scratch, yet the
//! paper's central property is that one small coreset contains a
//! near-optimal feasible solution for *any* downstream extraction, and
//! that coresets **compose** (Theorem 6 — the MapReduce algorithm is
//! exactly "coreset of coresets").  This module turns that property into
//! a standing structure:
//!
//! * [`tree::CoresetIndex`] — a merge-and-reduce tree (Bentley–Saxe
//!   binary counter): leaves are per-segment coresets built with the
//!   SeqCoreset/GMM machinery or the streaming builder's mini-batch mode
//!   ([`tree::LeafIngest`]), internal nodes are merged-then-reduced
//!   coresets.  Appending a segment touches O(log segments) nodes, and
//!   the union of the occupied levels ([`tree::CoresetIndex::root`]) is
//!   at all times a valid coreset of everything ingested — the streaming
//!   and MapReduce settings become two ingestion strategies over the same
//!   tree.  The tree is fully dynamic: [`tree::CoresetIndex::delete`]
//!   tombstones rows (O(log) node touches, threshold-triggered rebuilds
//!   from survivors), and [`tree::RetentionPolicy`] bounds freshness
//!   (`LastSegments` sliding windows, `Ttl` epoch expiry) — the
//!   standalone sliding-window coreset is now a thin wrapper over this
//!   type.
//! * [`service::QueryService`] — answers [`service::QuerySpec`] requests
//!   by running the pipeline's phase-2 finisher on the **root coreset
//!   only**, behind an LRU result cache keyed on the spec and invalidated
//!   by the tree epoch: N queries pay one coreset construction instead of
//!   N pipeline runs, and a repeat query costs zero distance evaluations.
//!   The cache and its counters live in [`service::ResultCache`] — the
//!   lock-friendly seam the multi-tenant server ([`crate::serve`]) shares
//!   across worker threads — and the cold path is the free function
//!   [`service::run_cold_query`], callable against any borrowed root.
//! * [`store`] — text snapshots of the tree (plus the CLI's
//!   dataset/matroid recipe), behind `dmmc index build/append/query`,
//!   and the result-cache sidecar (`<index>.cache`, stamped with the
//!   snapshot's content id) that keeps repeat queries warm across
//!   restarts and server reloads.
//!
//! Work accounting is analytic and test-pinned: every construction pass
//! logs `(input, clusters)` so `rust/tests/index_service.rs` can assert
//! the append path is logarithmic and cache hits are free.

pub mod service;
pub mod store;
pub mod tree;

pub use service::{
    run_cold_query, ColdQuery, DistEvals, QueryFinisher, QueryOutcome, QueryResult, QueryService,
    QuerySpec, ResultCache, ServiceStats, DEFAULT_CACHE_CAPACITY,
};
pub use store::IndexSnapshot;
pub use tree::{
    AppendReceipt, CoresetIndex, DeleteReceipt, IndexConfig, IndexNode, IndexParts, IndexStats,
    LeafIngest, RetentionPolicy, DEFAULT_REBUILD_THRESHOLD,
};
