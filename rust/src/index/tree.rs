//! The merge-and-reduce composable coreset tree.
//!
//! A Bentley–Saxe-style logarithmic structure over the paper's
//! composability theorem (Theorem 6): leaves are per-segment coresets
//! built with the existing SeqCoreset/GMM machinery (or the streaming
//! builder's mini-batch mode), and each internal node is the
//! *merge-then-reduce* of its two children — the union of two coresets is
//! a coreset of the union of their segments, re-compressed with one more
//! SeqCoreset pass to keep node sizes bounded.  The tree keeps one node
//! per binary-counter level, so appending segment number `s` touches
//! exactly `1 + trailing_ones(s - 1)` nodes — O(log segments) — and the
//! union of the occupied levels (the [`CoresetIndex::root`]) is at all
//! times a valid coreset of everything ingested.
//!
//! Every reduce is accounted in an analytic distance-evaluation ledger
//! (GMM folds cost `n_clusters * input` evaluations each; the streaming
//! leaf reports its own §5.2 counter), so tests can pin that append work
//! is logarithmic rather than proportional to the ingested total.

use anyhow::{ensure, Result};

use crate::algo::seq_coreset::seq_coreset;
use crate::algo::stream_coreset::{StreamCoreset, DEFAULT_C};
use crate::algo::Budget;
use crate::core::Dataset;
use crate::matroid::Matroid;
use crate::runtime::{build_engine, EngineKind};

/// How a leaf (per-segment) coreset is built — the two ingestion
/// strategies of the paper's distributed settings, unified over one tree:
/// `Seq` is the MapReduce shard construction (Algorithm 1 per segment),
/// `Stream` drives the one-pass builder's mini-batch mode over the
/// segment (Algorithm 2 / the tau-variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeafIngest {
    Seq,
    Stream,
}

impl LeafIngest {
    pub fn name(self) -> &'static str {
        match self {
            LeafIngest::Seq => "seq",
            LeafIngest::Stream => "stream",
        }
    }

    pub fn parse(s: &str) -> Option<LeafIngest> {
        match s {
            "seq" => Some(LeafIngest::Seq),
            "stream" => Some(LeafIngest::Stream),
            _ => None,
        }
    }
}

/// Construction parameters of a [`CoresetIndex`].
#[derive(Clone, Copy, Debug)]
pub struct IndexConfig {
    /// Largest solution size the index serves; queries must use `k <=
    /// k_max` (the paper builds coresets for the maximum k of interest).
    pub k_max: usize,
    /// Coreset budget per leaf segment.
    pub leaf_budget: Budget,
    /// Coreset budget per merge-reduce (internal node).
    pub reduce_budget: Budget,
    /// Backend for every construction pass.
    pub engine: EngineKind,
    /// Leaf construction strategy.
    pub leaf_ingest: LeafIngest,
}

impl IndexConfig {
    /// Sensible defaults: tau-budgeted SeqCoreset leaves and reduces on
    /// the default engine.
    pub fn new(k_max: usize, tau: usize) -> IndexConfig {
        IndexConfig {
            k_max,
            leaf_budget: Budget::Clusters(tau),
            reduce_budget: Budget::Clusters(tau),
            engine: EngineKind::default(),
            leaf_ingest: LeafIngest::Seq,
        }
    }
}

/// One occupied tree level: a coreset summarizing `2^level` segments.
#[derive(Clone, Debug)]
pub struct IndexNode {
    /// Coreset member indices (global, sorted, deduplicated).
    pub indices: Vec<usize>,
    /// Number of leaf segments this node summarizes.
    pub segments: usize,
    /// Number of raw points this node summarizes.
    pub points: usize,
    /// Clusters of the construction that produced this node.
    pub n_clusters: usize,
    /// Coverage radius of this node w.r.t. its raw points: every
    /// summarized point is within this distance of some member.  Compounds
    /// additively up the lineage (child radius + reduce radius).
    pub radius: f64,
}

/// Cumulative ledger across the index lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct IndexStats {
    pub appends: u64,
    pub merges: u64,
    /// Analytic distance evaluations of every construction pass (GMM
    /// folds = `n_clusters * input` each; streaming leaves report their
    /// own §5.2 counter).
    pub dist_evals: u64,
}

/// Per-append accounting, the unit the sublinearity tests pin.
#[derive(Clone, Debug)]
pub struct AppendReceipt {
    /// 1-based ordinal of the appended segment.
    pub segment: usize,
    /// Merge-reduce operations this append triggered (the binary-counter
    /// carry chain: `trailing_ones(segment - 1)`).
    pub merges: usize,
    /// Tree nodes written: `1 + merges`.
    pub nodes_touched: usize,
    /// Distance evaluations of this append (leaf build + merges).
    pub dist_evals: u64,
    /// One `(input_size, n_clusters)` entry per construction pass, leaf
    /// first — the raw material for re-deriving `dist_evals` analytically.
    pub reduce_log: Vec<(usize, usize)>,
    /// Root coreset size after the append.
    pub root_size: usize,
    /// Tree epoch after the append (bumps on every append; result caches
    /// key on it).
    pub epoch: u64,
}

/// The standing coreset structure: ingest segments, read the root.
pub struct CoresetIndex<'a> {
    ds: &'a Dataset,
    m: &'a dyn Matroid,
    cfg: IndexConfig,
    /// Binary-counter levels; `levels[i]` summarizes `2^i` segments.
    levels: Vec<Option<IndexNode>>,
    epoch: u64,
    segments: usize,
    points: usize,
    stats: IndexStats,
}

impl<'a> CoresetIndex<'a> {
    pub fn new(ds: &'a Dataset, m: &'a dyn Matroid, cfg: IndexConfig) -> CoresetIndex<'a> {
        assert!(cfg.k_max >= 1, "index needs k_max >= 1");
        CoresetIndex {
            ds,
            m,
            cfg,
            levels: Vec::new(),
            epoch: 0,
            segments: 0,
            points: 0,
            stats: IndexStats::default(),
        }
    }

    /// Restore an index from persisted parts (see `crate::index::store`).
    /// The caller is responsible for `levels`/`epoch`/`segments`/`points`
    /// being a snapshot previously produced by this type.
    pub fn from_parts(
        ds: &'a Dataset,
        m: &'a dyn Matroid,
        cfg: IndexConfig,
        levels: Vec<Option<IndexNode>>,
        epoch: u64,
        segments: usize,
        points: usize,
    ) -> CoresetIndex<'a> {
        CoresetIndex {
            ds,
            m,
            cfg,
            levels,
            epoch,
            segments,
            points,
            stats: IndexStats::default(),
        }
    }

    pub fn dataset(&self) -> &'a Dataset {
        self.ds
    }

    pub fn matroid(&self) -> &'a dyn Matroid {
        self.m
    }

    pub fn config(&self) -> &IndexConfig {
        &self.cfg
    }

    pub fn levels(&self) -> &[Option<IndexNode>] {
        &self.levels
    }

    /// Bumps on every append; cached query results are valid only for the
    /// epoch they were computed at.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn segments(&self) -> usize {
        self.segments
    }

    /// Raw points ingested so far.
    pub fn points_ingested(&self) -> usize {
        self.points
    }

    pub fn stats(&self) -> &IndexStats {
        &self.stats
    }

    /// The standing coreset of everything ingested: the union of the
    /// occupied levels' coresets (a coreset of the full ingest by
    /// composability — each level covers its own segments).
    pub fn root(&self) -> Vec<usize> {
        let mut out: Vec<usize> = Vec::new();
        for node in self.levels.iter().flatten() {
            out.extend_from_slice(&node.indices);
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Ingest one segment (a batch of dataset row indices): build its
    /// leaf coreset, then carry up the binary counter, merge-reducing one
    /// node per occupied level.  Touches `1 + trailing_ones(segments)`
    /// nodes — O(log segments) — never the whole ingest.
    pub fn append(&mut self, batch: &[usize]) -> Result<AppendReceipt> {
        ensure!(!batch.is_empty(), "index append needs a non-empty batch");
        let mut dist_evals = 0u64;
        let mut reduce_log: Vec<(usize, usize)> = Vec::new();

        let (leaf, leaf_evals) = self.build_leaf(batch)?;
        dist_evals += leaf_evals;
        reduce_log.push((batch.len(), leaf.n_clusters));

        let mut node = leaf;
        let mut merges = 0usize;
        let mut lvl = 0usize;
        loop {
            if lvl == self.levels.len() {
                self.levels.push(None);
            }
            match self.levels[lvl].take() {
                None => {
                    self.levels[lvl] = Some(node);
                    break;
                }
                Some(other) => {
                    merges += 1;
                    let (merged, evals, log) = self.reduce_pair(node, other)?;
                    dist_evals += evals;
                    reduce_log.push(log);
                    node = merged;
                    lvl += 1;
                }
            }
        }

        self.segments += 1;
        self.points += batch.len();
        self.epoch += 1;
        self.stats.appends += 1;
        self.stats.merges += merges as u64;
        self.stats.dist_evals += dist_evals;
        Ok(AppendReceipt {
            segment: self.segments,
            merges,
            nodes_touched: 1 + merges,
            dist_evals,
            reduce_log,
            root_size: self.root().len(),
            epoch: self.epoch,
        })
    }

    /// Bulk ingestion: split `order` into `segment_size`-point segments
    /// and append each (the MapReduce arbitrary-partition path expressed
    /// as tree ingestion).  Returns one receipt per segment.
    pub fn ingest(&mut self, order: &[usize], segment_size: usize) -> Result<Vec<AppendReceipt>> {
        assert!(segment_size >= 1);
        let mut receipts = Vec::new();
        for chunk in order.chunks(segment_size) {
            receipts.push(self.append(chunk)?);
        }
        Ok(receipts)
    }

    /// Leaf construction over a zero-copy segment view.
    fn build_leaf(&self, batch: &[usize]) -> Result<(IndexNode, u64)> {
        let view = self.ds.subset(batch);
        match self.cfg.leaf_ingest {
            LeafIngest::Seq => {
                let engine = build_engine(self.cfg.engine, &view)?;
                let cs =
                    seq_coreset(&view, self.m, self.cfg.k_max, self.cfg.leaf_budget, &*engine)?;
                // GMM folds the segment once per selected center
                let evals = (cs.n_clusters * view.n()) as u64;
                let node = IndexNode {
                    indices: to_global(batch, &cs.indices),
                    segments: 1,
                    points: batch.len(),
                    n_clusters: cs.n_clusters,
                    radius: cs.radius,
                };
                Ok((node, evals))
            }
            LeafIngest::Stream => {
                let mut alg = match self.cfg.leaf_budget {
                    Budget::Clusters(tau) => {
                        StreamCoreset::with_tau(&view, self.m, self.cfg.k_max, tau.max(2))
                    }
                    Budget::Epsilon(eps) => {
                        StreamCoreset::new(&view, self.m, self.cfg.k_max, eps, DEFAULT_C)
                    }
                };
                if self.cfg.engine != EngineKind::Scalar {
                    alg.set_engine_kind(self.cfg.engine)?;
                }
                let order: Vec<usize> = (0..view.n()).collect();
                alg.push_batch(&order);
                let (cs, st) = alg.finish();
                let node = IndexNode {
                    indices: to_global(batch, &cs.indices),
                    segments: 1,
                    points: batch.len(),
                    n_clusters: cs.n_clusters,
                    radius: cs.radius,
                };
                Ok((node, st.distance_evals))
            }
        }
    }

    /// Merge-then-reduce: union the two coresets (composability), then
    /// re-compress the union with one SeqCoreset pass under the reduce
    /// budget so node sizes stay bounded as levels climb.  Returns the
    /// node, its dist-eval cost, and the `(input, clusters)` ledger entry.
    fn reduce_pair(&self, a: IndexNode, b: IndexNode) -> Result<Reduced> {
        let mut union = a.indices;
        union.extend(b.indices);
        union.sort_unstable();
        union.dedup();
        let view = self.ds.subset(&union);
        let engine = build_engine(self.cfg.engine, &view)?;
        let cs = seq_coreset(&view, self.m, self.cfg.k_max, self.cfg.reduce_budget, &*engine)?;
        let evals = (cs.n_clusters * view.n()) as u64;
        let node = IndexNode {
            indices: to_global(&union, &cs.indices),
            segments: a.segments + b.segments,
            points: a.points + b.points,
            n_clusters: cs.n_clusters,
            // coverage over the lineage compounds additively (triangle
            // inequality): a raw point sits within the child's radius of a
            // child-coreset point, which sits within the reduce's radius of
            // a kept member
            radius: a.radius.max(b.radius) + cs.radius,
        };
        Ok((node, evals, (union.len(), cs.n_clusters)))
    }
}

/// A reduced node, its dist-eval cost, and its `(input, clusters)` log
/// entry.
type Reduced = (IndexNode, u64, (usize, usize));

/// Map view-local coreset indices back to global dataset rows.
fn to_global(batch: &[usize], local: &[usize]) -> Vec<usize> {
    let mut out: Vec<usize> = local.iter().map(|&i| batch[i]).collect();
    out.sort_unstable();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::{maximal_independent, PartitionMatroid, UniformMatroid};

    fn cfg(k: usize, tau: usize) -> IndexConfig {
        IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(k, tau)
        }
    }

    #[test]
    fn append_carries_like_a_binary_counter() {
        let ds = synth::uniform_cube(640, 2, 3);
        let m = UniformMatroid::new(4);
        let mut idx = CoresetIndex::new(&ds, &m, cfg(4, 8));
        let order: Vec<usize> = (0..ds.n()).collect();
        for (s, chunk) in order.chunks(40).enumerate() {
            let r = idx.append(chunk).unwrap();
            // carry chain of the binary counter: segment s+1 merges once
            // per trailing one of s
            let expect_merges = (s as u32).trailing_ones() as usize;
            assert_eq!(r.merges, expect_merges, "segment {}", s + 1);
            assert_eq!(r.nodes_touched, 1 + expect_merges);
            assert_eq!(r.segment, s + 1);
            // the ledger is exactly reconstructible from the reduce log
            let analytic: u64 =
                r.reduce_log.iter().map(|&(n, c)| (n * c) as u64).sum();
            assert_eq!(r.dist_evals, analytic);
        }
        assert_eq!(idx.segments(), 16);
        assert_eq!(idx.points_ingested(), 640);
        // 16 = 2^4 segments collapse into exactly one occupied level
        assert_eq!(idx.levels().iter().flatten().count(), 1);
        assert_eq!(idx.epoch(), 16);
    }

    #[test]
    fn root_always_contains_a_feasible_solution() {
        let ds = synth::clustered(600, 2, 5, 0.15, 4, 3);
        let m = PartitionMatroid::new(vec![2; 4]);
        let k = 6;
        let mut idx = CoresetIndex::new(&ds, &m, cfg(k, 16));
        let order: Vec<usize> = (0..ds.n()).collect();
        for chunk in order.chunks(100) {
            idx.append(chunk).unwrap();
            let root = idx.root();
            let sol = maximal_independent(&m, &ds, &root, k);
            assert_eq!(sol.len(), k, "root lost feasibility at {} segments", idx.segments());
        }
    }

    #[test]
    fn root_indices_are_global_unique_and_covered() {
        let ds = synth::uniform_cube(300, 3, 7);
        let m = UniformMatroid::new(3);
        let mut idx = CoresetIndex::new(&ds, &m, cfg(3, 6));
        let order: Vec<usize> = (0..ds.n()).collect();
        idx.ingest(&order, 50).unwrap();
        let root = idx.root();
        // BTreeSet so a duplicate-id assertion failure names the same
        // first duplicate on every run
        let mut seen = std::collections::BTreeSet::new();
        for &i in &root {
            assert!(i < ds.n());
            assert!(seen.insert(i), "duplicate root index {i}");
        }
        assert!(root.len() < ds.n());
    }

    #[test]
    fn stream_leaves_work_too() {
        let ds = synth::uniform_cube(400, 2, 5);
        let m = UniformMatroid::new(4);
        let mut c = cfg(4, 8);
        c.leaf_ingest = LeafIngest::Stream;
        let mut idx = CoresetIndex::new(&ds, &m, c);
        let order: Vec<usize> = (0..ds.n()).collect();
        let receipts = idx.ingest(&order, 80).unwrap();
        assert_eq!(receipts.len(), 5);
        assert!(receipts.iter().all(|r| r.dist_evals > 0));
        let root = idx.root();
        let sol = maximal_independent(&m, &ds, &root, 4);
        assert_eq!(sol.len(), 4);
    }

    #[test]
    fn empty_batch_rejected() {
        let ds = synth::uniform_cube(50, 2, 1);
        let m = UniformMatroid::new(2);
        let mut idx = CoresetIndex::new(&ds, &m, cfg(2, 4));
        assert!(idx.append(&[]).is_err());
    }
}
