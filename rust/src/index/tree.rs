//! The merge-and-reduce composable coreset tree.
//!
//! A Bentley–Saxe-style logarithmic structure over the paper's
//! composability theorem (Theorem 6): leaves are per-segment coresets
//! built with the existing SeqCoreset/GMM machinery (or the streaming
//! builder's mini-batch mode), and each internal node is the
//! *merge-then-reduce* of its two children — the union of two coresets is
//! a coreset of the union of their segments, re-compressed with one more
//! SeqCoreset pass to keep node sizes bounded.  The tree keeps one node
//! per binary-counter level, so appending segment number `s` touches
//! exactly `1 + trailing_ones(s - 1)` nodes — O(log segments) — and the
//! union of the occupied levels (the [`CoresetIndex::root`]) is at all
//! times a valid coreset of everything ingested.
//!
//! ## Dynamic operations
//!
//! The tree is fully dynamic, not just append-only:
//!
//! * **Deletions** are tombstones: [`CoresetIndex::delete`] marks dataset
//!   rows dead, [`CoresetIndex::root`] filters them, and the epoch bumps
//!   so every cached query result is invalidated for free.  Each delete
//!   scans only the occupied levels (O(log segments) node touches).  When
//!   a node's live fraction drops below
//!   [`IndexConfig::rebuild_threshold`] (default
//!   [`DEFAULT_REBUILD_THRESHOLD`]), that node is rebuilt from its
//!   surviving members with one SeqCoreset pass — amortized-O(log) work,
//!   because a node absorbs Ω(threshold · |node|) deletions between
//!   rebuilds and the rebuild input is only the node's live members.  A
//!   node whose members all die is simply dropped.
//! * **Retention** bounds freshness: [`RetentionPolicy::LastSegments`]
//!   expires nodes whose newest segment left the sliding window, and
//!   [`RetentionPolicy::Ttl`] expires nodes older than a fixed number of
//!   epochs.  Under a windowed policy appends do *not* merge-reduce
//!   (leaves land in the first free level slot): merging would fuse old
//!   and new segments into one node whose partial expiry could silently
//!   drop in-window coverage, so windowed trees keep leaf granularity and
//!   expire whole segments exactly — this is precisely the standalone
//!   sliding-window coreset's behavior, which is why
//!   `streaming::SlidingWindowCoreset` is now a thin wrapper over this
//!   type.
//!
//! Every construction pass is accounted in an analytic
//! distance-evaluation ledger (GMM folds cost `n_clusters * input`
//! evaluations each; the streaming leaf reports its own §5.2 counter), so
//! tests can pin that append *and delete/rebuild* work is logarithmic
//! rather than proportional to the ingested total.

use std::collections::BTreeSet;

use anyhow::{ensure, Result};

use crate::algo::seq_coreset::seq_coreset;
use crate::algo::stream_coreset::{StreamCoreset, DEFAULT_C};
use crate::algo::Budget;
use crate::core::Dataset;
use crate::matroid::Matroid;
use crate::runtime::{build_engine, EngineKind};

/// Default live-fraction threshold below which a node is rebuilt from its
/// surviving members (see [`IndexConfig::rebuild_threshold`]).
pub const DEFAULT_REBUILD_THRESHOLD: f64 = 0.5;

/// How a leaf (per-segment) coreset is built — the two ingestion
/// strategies of the paper's distributed settings, unified over one tree:
/// `Seq` is the MapReduce shard construction (Algorithm 1 per segment),
/// `Stream` drives the one-pass builder's mini-batch mode over the
/// segment (Algorithm 2 / the tau-variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeafIngest {
    Seq,
    Stream,
}

impl LeafIngest {
    pub fn name(self) -> &'static str {
        match self {
            LeafIngest::Seq => "seq",
            LeafIngest::Stream => "stream",
        }
    }

    pub fn parse(s: &str) -> Option<LeafIngest> {
        match s {
            "seq" => Some(LeafIngest::Seq),
            "stream" => Some(LeafIngest::Stream),
            _ => None,
        }
    }
}

/// What the index keeps standing as segments age.
///
/// `KeepAll` is the classic append-only tree (full merge-reduce carry
/// chain).  The windowed policies trade merging for exact expiry: see the
/// module docs for why windowed trees never merge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetentionPolicy {
    /// Every segment stays forever (default).
    KeepAll,
    /// Keep only the newest `w` segments; a node expires once its newest
    /// segment falls out of the window.
    LastSegments(usize),
    /// Keep a node only for `epochs` epochs after it was built (epochs
    /// advance on every append and every effective delete).
    Ttl(u64),
}

impl RetentionPolicy {
    pub fn name(self) -> String {
        match self {
            RetentionPolicy::KeepAll => "keep-all".to_string(),
            RetentionPolicy::LastSegments(w) => format!("last:{w}"),
            RetentionPolicy::Ttl(e) => format!("ttl:{e}"),
        }
    }

    pub fn parse(s: &str) -> Option<RetentionPolicy> {
        if s == "keep-all" {
            return Some(RetentionPolicy::KeepAll);
        }
        if let Some(rest) = s.strip_prefix("last:") {
            return rest.parse().ok().map(RetentionPolicy::LastSegments);
        }
        if let Some(rest) = s.strip_prefix("ttl:") {
            return rest.parse().ok().map(RetentionPolicy::Ttl);
        }
        None
    }

    /// Windowed policies expire nodes and therefore suppress merging.
    pub fn is_windowed(self) -> bool {
        !matches!(self, RetentionPolicy::KeepAll)
    }
}

/// Construction parameters of a [`CoresetIndex`].
#[derive(Clone, Copy, Debug)]
pub struct IndexConfig {
    /// Largest solution size the index serves; queries must use `k <=
    /// k_max` (the paper builds coresets for the maximum k of interest).
    pub k_max: usize,
    /// Coreset budget per leaf segment.
    pub leaf_budget: Budget,
    /// Coreset budget per merge-reduce (internal node) and per
    /// post-delete rebuild.
    pub reduce_budget: Budget,
    /// Backend for every construction pass.
    pub engine: EngineKind,
    /// Leaf construction strategy.
    pub leaf_ingest: LeafIngest,
    /// What to keep as segments age.
    pub retention: RetentionPolicy,
    /// A node whose live member fraction drops strictly below this is
    /// rebuilt from its survivors ([`DEFAULT_REBUILD_THRESHOLD`] by
    /// default).
    pub rebuild_threshold: f64,
}

impl IndexConfig {
    /// Sensible defaults: tau-budgeted SeqCoreset leaves and reduces on
    /// the default engine, keep-all retention, 0.5 rebuild threshold.
    pub fn new(k_max: usize, tau: usize) -> IndexConfig {
        IndexConfig {
            k_max,
            leaf_budget: Budget::Clusters(tau),
            reduce_budget: Budget::Clusters(tau),
            engine: EngineKind::default(),
            leaf_ingest: LeafIngest::Seq,
            retention: RetentionPolicy::KeepAll,
            rebuild_threshold: DEFAULT_REBUILD_THRESHOLD,
        }
    }
}

/// One occupied tree level: a coreset summarizing consecutive segments.
#[derive(Clone, Debug)]
pub struct IndexNode {
    /// Coreset member indices (global, sorted, deduplicated).  May
    /// contain tombstoned rows; readers filter through the index's
    /// tombstone set.
    pub indices: Vec<usize>,
    /// Number of leaf segments this node summarizes.
    pub segments: usize,
    /// Number of raw points this node summarizes.
    pub points: usize,
    /// Clusters of the construction that produced this node.
    pub n_clusters: usize,
    /// Coverage radius of this node w.r.t. its raw points: every
    /// summarized point is within this distance of some member.  Compounds
    /// additively up the lineage (child radius + reduce radius; a rebuild
    /// adds its own pass radius the same way).
    pub radius: f64,
    /// 1-based ordinal of the oldest segment this node covers (0 = legacy
    /// snapshot, unknown; only windowed retention reads this, and legacy
    /// `DMMCIDX1` snapshots were always keep-all).
    pub first_segment: usize,
    /// Epoch at which this node was (re)built; TTL retention ages it.
    pub born_epoch: u64,
}

/// Cumulative ledger across the index lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IndexStats {
    pub appends: u64,
    pub merges: u64,
    /// Analytic distance evaluations of every construction pass (GMM
    /// folds = `n_clusters * input` each; streaming leaves report their
    /// own §5.2 counter).  Includes post-delete rebuild passes.
    pub dist_evals: u64,
    /// `delete` calls that tombstoned at least one new row.
    pub deletes: u64,
    /// Nodes rebuilt from survivors after crossing the live-fraction
    /// threshold.
    pub rebuilds: u64,
    /// Segments dropped by the retention policy (whole expired nodes).
    pub expired_segments: u64,
}

/// Per-append accounting, the unit the sublinearity tests pin.
#[derive(Clone, Debug)]
pub struct AppendReceipt {
    /// 1-based ordinal of the appended segment.
    pub segment: usize,
    /// Merge-reduce operations this append triggered (the binary-counter
    /// carry chain: `trailing_ones(segment - 1)`; always 0 under windowed
    /// retention).
    pub merges: usize,
    /// Tree nodes written: `1 + merges`.
    pub nodes_touched: usize,
    /// Distance evaluations of this append (leaf build + merges).
    pub dist_evals: u64,
    /// One `(input_size, n_clusters)` entry per construction pass, leaf
    /// first — the raw material for re-deriving `dist_evals` analytically.
    pub reduce_log: Vec<(usize, usize)>,
    /// Segments expired by the retention policy during this append.
    pub expired: usize,
    /// Root coreset size after the append.
    pub root_size: usize,
    /// Tree epoch after the append (bumps on every append; result caches
    /// key on it).
    pub epoch: u64,
}

/// Per-delete accounting — the delete-side counterpart of
/// [`AppendReceipt`], pinned by the dynamic-index tests.
#[derive(Clone, Debug)]
pub struct DeleteReceipt {
    /// Rows newly tombstoned by this call (already-dead and duplicate
    /// rows are ignored).
    pub newly_dead: usize,
    /// Coreset member slots (across all nodes) killed by this call.
    pub members_killed: usize,
    /// Occupied levels scanned — bounded by the level count, O(log
    /// segments).
    pub nodes_touched: usize,
    /// Nodes rebuilt from survivors (live fraction crossed the
    /// threshold).
    pub rebuilds: usize,
    /// Levels dropped outright because every member died.
    pub dropped_levels: usize,
    /// Segments expired by the retention policy during this delete.
    pub expired: usize,
    /// Distance evaluations of the rebuild passes.
    pub dist_evals: u64,
    /// One `(live_input, n_clusters)` entry per rebuild pass.
    pub reduce_log: Vec<(usize, usize)>,
    /// Root coreset size after the delete.
    pub root_size: usize,
    /// Tree epoch after the delete.  Bumps iff `newly_dead > 0`, so a
    /// no-op delete leaves cached query results valid.
    pub epoch: u64,
}

/// The standing coreset structure: ingest segments, read the root.
pub struct CoresetIndex<'a> {
    ds: &'a Dataset,
    m: &'a dyn Matroid,
    cfg: IndexConfig,
    /// Binary-counter levels; under keep-all retention `levels[i]`
    /// summarizes `2^i` segments, under windowed retention slots hold
    /// single-segment leaves (first free slot wins).
    levels: Vec<Option<IndexNode>>,
    epoch: u64,
    segments: usize,
    points: usize,
    stats: IndexStats,
    /// Deleted dataset rows.  `BTreeSet` per the L1 determinism contract
    /// (iterated for persistence and live-member filtering).
    tombstones: BTreeSet<usize>,
}

/// Resumable state of a [`CoresetIndex`] minus the borrowed dataset /
/// matroid / config — what `crate::index::store` persists and
/// [`CoresetIndex::from_parts`] restores.
#[derive(Clone, Debug)]
pub struct IndexParts {
    pub levels: Vec<Option<IndexNode>>,
    pub epoch: u64,
    pub segments: usize,
    pub points: usize,
    pub stats: IndexStats,
    pub tombstones: BTreeSet<usize>,
}

impl<'a> CoresetIndex<'a> {
    pub fn new(ds: &'a Dataset, m: &'a dyn Matroid, cfg: IndexConfig) -> CoresetIndex<'a> {
        assert!(cfg.k_max >= 1, "index needs k_max >= 1");
        assert!(
            cfg.rebuild_threshold >= 0.0 && cfg.rebuild_threshold <= 1.0,
            "rebuild_threshold must lie in [0, 1]"
        );
        CoresetIndex {
            ds,
            m,
            cfg,
            levels: Vec::new(),
            epoch: 0,
            segments: 0,
            points: 0,
            stats: IndexStats::default(),
            tombstones: BTreeSet::new(),
        }
    }

    /// Restore an index from persisted parts (see `crate::index::store`).
    /// The caller is responsible for `parts` being a snapshot previously
    /// produced by this type; the lifetime ledger ([`IndexStats`])
    /// survives the roundtrip.
    pub fn from_parts(
        ds: &'a Dataset,
        m: &'a dyn Matroid,
        cfg: IndexConfig,
        parts: IndexParts,
    ) -> CoresetIndex<'a> {
        CoresetIndex {
            ds,
            m,
            cfg,
            levels: parts.levels,
            epoch: parts.epoch,
            segments: parts.segments,
            points: parts.points,
            stats: parts.stats,
            tombstones: parts.tombstones,
        }
    }

    /// Capture the resumable state for persistence.
    pub fn parts(&self) -> IndexParts {
        IndexParts {
            levels: self.levels.clone(),
            epoch: self.epoch,
            segments: self.segments,
            points: self.points,
            stats: self.stats,
            tombstones: self.tombstones.clone(),
        }
    }

    pub fn dataset(&self) -> &'a Dataset {
        self.ds
    }

    pub fn matroid(&self) -> &'a dyn Matroid {
        self.m
    }

    pub fn config(&self) -> &IndexConfig {
        &self.cfg
    }

    pub fn levels(&self) -> &[Option<IndexNode>] {
        &self.levels
    }

    /// Bumps on every append and every effective delete; cached query
    /// results are valid only for the epoch they were computed at.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn segments(&self) -> usize {
        self.segments
    }

    /// Raw points ingested so far (lifetime counter; unaffected by
    /// deletes and expiry).
    pub fn points_ingested(&self) -> usize {
        self.points
    }

    pub fn stats(&self) -> &IndexStats {
        &self.stats
    }

    /// Deleted dataset rows.
    pub fn tombstones(&self) -> &BTreeSet<usize> {
        &self.tombstones
    }

    /// Live fraction across all standing coreset member slots (1.0 for an
    /// empty tree).  The rebuild threshold applies per node; this is the
    /// aggregate the pipeline reports.
    pub fn live_fraction(&self) -> f64 {
        let mut total = 0usize;
        let mut live = 0usize;
        for node in self.levels.iter().flatten() {
            total += node.indices.len();
            live += self.live_in(node);
        }
        if total == 0 {
            1.0
        } else {
            live as f64 / total as f64
        }
    }

    /// Live coreset member slots across all nodes (cross-node duplicates
    /// counted — this is the memory-accounting bound, not the root size).
    pub fn member_count(&self) -> usize {
        self.levels.iter().flatten().map(|n| self.live_in(n)).sum()
    }

    fn live_in(&self, node: &IndexNode) -> usize {
        node.indices.iter().filter(|i| !self.tombstones.contains(i)).count()
    }

    /// The standing coreset of everything ingested, tombstone-filtered:
    /// the union of the occupied levels' live members (a coreset of the
    /// live ingest by composability — each level covers its own
    /// segments).
    pub fn root(&self) -> Vec<usize> {
        let mut out: Vec<usize> = Vec::new();
        for node in self.levels.iter().flatten() {
            out.extend(node.indices.iter().copied().filter(|i| !self.tombstones.contains(i)));
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Ingest one segment (a batch of dataset row indices): build its
    /// leaf coreset, then — under keep-all retention — carry up the
    /// binary counter, merge-reducing one node per occupied level
    /// (`1 + trailing_ones(segments)` node touches, O(log segments)).
    /// Under windowed retention the leaf lands in the first free slot
    /// (one node touch) and the retention sweep expires anything that
    /// aged out.  Tombstoned rows in the batch are skipped: a deleted row
    /// stays deleted.
    pub fn append(&mut self, batch: &[usize]) -> Result<AppendReceipt> {
        ensure!(!batch.is_empty(), "index append needs a non-empty batch");
        let batch_live: Vec<usize> = if self.tombstones.is_empty() {
            batch.to_vec()
        } else {
            batch.iter().copied().filter(|i| !self.tombstones.contains(i)).collect()
        };
        ensure!(
            !batch_live.is_empty(),
            "index append batch contains only tombstoned rows"
        );
        let mut dist_evals = 0u64;
        let mut reduce_log: Vec<(usize, usize)> = Vec::new();

        let seg_ord = self.segments + 1;
        let born = self.epoch + 1;
        let (mut leaf, leaf_evals) = self.build_leaf(&batch_live)?;
        leaf.first_segment = seg_ord;
        leaf.born_epoch = born;
        dist_evals += leaf_evals;
        reduce_log.push((batch_live.len(), leaf.n_clusters));

        let mut node = leaf;
        let mut merges = 0usize;
        if self.cfg.retention.is_windowed() {
            // no merging under windowed retention (see module docs): the
            // leaf takes the first free slot so expiry stays exact
            match self.levels.iter().position(|l| l.is_none()) {
                Some(slot) => self.levels[slot] = Some(node),
                None => self.levels.push(Some(node)),
            }
        } else {
            let mut lvl = 0usize;
            loop {
                if lvl == self.levels.len() {
                    self.levels.push(None);
                }
                match self.levels[lvl].take() {
                    None => {
                        self.levels[lvl] = Some(node);
                        break;
                    }
                    Some(other) => {
                        merges += 1;
                        let (mut merged, evals, log) = self.reduce_pair(node, other)?;
                        merged.born_epoch = born;
                        dist_evals += evals;
                        reduce_log.push(log);
                        node = merged;
                        lvl += 1;
                    }
                }
            }
        }

        self.segments = seg_ord;
        self.points += batch.len();
        self.epoch = born;
        self.stats.appends += 1;
        self.stats.merges += merges as u64;
        self.stats.dist_evals += dist_evals;
        let expired = self.apply_retention();
        Ok(AppendReceipt {
            segment: seg_ord,
            merges,
            nodes_touched: 1 + merges,
            dist_evals,
            reduce_log,
            expired,
            root_size: self.root().len(),
            epoch: self.epoch,
        })
    }

    /// Bulk ingestion: split `order` into `segment_size`-point segments
    /// and append each (the MapReduce arbitrary-partition path expressed
    /// as tree ingestion).  Returns one receipt per segment.
    pub fn ingest(&mut self, order: &[usize], segment_size: usize) -> Result<Vec<AppendReceipt>> {
        assert!(segment_size >= 1);
        let mut receipts = Vec::new();
        for chunk in order.chunks(segment_size) {
            receipts.push(self.append(chunk)?);
        }
        Ok(receipts)
    }

    /// Tombstone `rows`: mark them dead across every level, bump the
    /// epoch (cache invalidation), and rebuild any node whose live
    /// fraction dropped strictly below the configured threshold from its
    /// surviving members.  Duplicate and already-dead rows are ignored; a
    /// call that tombstones nothing new is a no-op (epoch unchanged, so
    /// caches stay valid).
    ///
    /// The whole batch is marked before any threshold is evaluated, so
    /// the resulting tree state depends only on the *set* of rows, not
    /// the order they appear in `rows` — the determinism-contract replay
    /// tests pin this.
    pub fn delete(&mut self, rows: &[usize]) -> Result<DeleteReceipt> {
        for &r in rows {
            ensure!(r < self.ds.n(), "delete row {r} out of range (n = {})", self.ds.n());
        }
        let mut newly: BTreeSet<usize> = BTreeSet::new();
        for &r in rows {
            if self.tombstones.insert(r) {
                newly.insert(r);
            }
        }
        if newly.is_empty() {
            return Ok(DeleteReceipt {
                newly_dead: 0,
                members_killed: 0,
                nodes_touched: 0,
                rebuilds: 0,
                dropped_levels: 0,
                expired: 0,
                dist_evals: 0,
                reduce_log: Vec::new(),
                root_size: self.root().len(),
                epoch: self.epoch,
            });
        }
        self.epoch += 1;
        self.stats.deletes += 1;

        let mut members_killed = 0usize;
        let mut nodes_touched = 0usize;
        let mut rebuilds = 0usize;
        let mut dropped_levels = 0usize;
        let mut dist_evals = 0u64;
        let mut reduce_log: Vec<(usize, usize)> = Vec::new();

        for lvl in 0..self.levels.len() {
            let Some(node) = self.levels[lvl].take() else { continue };
            nodes_touched += 1;
            members_killed += node.indices.iter().filter(|i| newly.contains(i)).count();
            let live: Vec<usize> = node
                .indices
                .iter()
                .copied()
                .filter(|i| !self.tombstones.contains(i))
                .collect();
            if live.is_empty() {
                dropped_levels += 1;
                continue;
            }
            if (live.len() as f64) < self.cfg.rebuild_threshold * (node.indices.len() as f64) {
                let (rebuilt, evals, log) = self.rebuild_node(&node, &live)?;
                dist_evals += evals;
                reduce_log.push(log);
                rebuilds += 1;
                self.levels[lvl] = Some(rebuilt);
            } else {
                self.levels[lvl] = Some(node);
            }
        }

        self.stats.rebuilds += rebuilds as u64;
        self.stats.dist_evals += dist_evals;
        let expired = self.apply_retention();
        Ok(DeleteReceipt {
            newly_dead: newly.len(),
            members_killed,
            nodes_touched,
            rebuilds,
            dropped_levels,
            expired,
            dist_evals,
            reduce_log,
            root_size: self.root().len(),
            epoch: self.epoch,
        })
    }

    /// Expire nodes the retention policy no longer keeps; returns the
    /// number of segments dropped.  Runs after every append and every
    /// effective delete.
    fn apply_retention(&mut self) -> usize {
        let mut expired = 0usize;
        let (segments, epoch) = (self.segments, self.epoch);
        match self.cfg.retention {
            RetentionPolicy::KeepAll => {}
            RetentionPolicy::LastSegments(w) => {
                let oldest_live = segments.saturating_sub(w.max(1)) + 1;
                for slot in self.levels.iter_mut() {
                    let drop_it = slot
                        .as_ref()
                        .is_some_and(|n| n.first_segment + n.segments - 1 < oldest_live);
                    if drop_it {
                        expired += slot.take().map_or(0, |n| n.segments);
                    }
                }
            }
            RetentionPolicy::Ttl(t) => {
                let t = t.max(1);
                for slot in self.levels.iter_mut() {
                    let drop_it = slot.as_ref().is_some_and(|n| epoch >= n.born_epoch + t);
                    if drop_it {
                        expired += slot.take().map_or(0, |n| n.segments);
                    }
                }
            }
        }
        // trim trailing empty slots so windowed trees don't grow forever
        while self.levels.last().is_some_and(|l| l.is_none()) {
            self.levels.pop();
        }
        self.stats.expired_segments += expired as u64;
        expired
    }

    /// Leaf construction over a zero-copy segment view.
    fn build_leaf(&self, batch: &[usize]) -> Result<(IndexNode, u64)> {
        let view = self.ds.subset(batch);
        match self.cfg.leaf_ingest {
            LeafIngest::Seq => {
                let engine = build_engine(self.cfg.engine, &view)?;
                let cs =
                    seq_coreset(&view, self.m, self.cfg.k_max, self.cfg.leaf_budget, &*engine)?;
                // GMM folds the segment once per selected center
                let evals = (cs.n_clusters * view.n()) as u64;
                let node = IndexNode {
                    indices: to_global(batch, &cs.indices),
                    segments: 1,
                    points: batch.len(),
                    n_clusters: cs.n_clusters,
                    radius: cs.radius,
                    first_segment: 0,
                    born_epoch: 0,
                };
                Ok((node, evals))
            }
            LeafIngest::Stream => {
                let mut alg = match self.cfg.leaf_budget {
                    Budget::Clusters(tau) => {
                        StreamCoreset::with_tau(&view, self.m, self.cfg.k_max, tau.max(2))
                    }
                    Budget::Epsilon(eps) => {
                        StreamCoreset::new(&view, self.m, self.cfg.k_max, eps, DEFAULT_C)
                    }
                };
                if self.cfg.engine != EngineKind::Scalar {
                    alg.set_engine_kind(self.cfg.engine)?;
                }
                let order: Vec<usize> = (0..view.n()).collect();
                alg.push_batch(&order);
                let (cs, st) = alg.finish();
                let node = IndexNode {
                    indices: to_global(batch, &cs.indices),
                    segments: 1,
                    points: batch.len(),
                    n_clusters: cs.n_clusters,
                    radius: cs.radius,
                    first_segment: 0,
                    born_epoch: 0,
                };
                Ok((node, st.distance_evals))
            }
        }
    }

    /// Merge-then-reduce: union the two coresets (composability), then
    /// re-compress the union with one SeqCoreset pass under the reduce
    /// budget so node sizes stay bounded as levels climb.  Tombstoned
    /// members are filtered out of the union before the pass (merging is
    /// self-cleaning).  Returns the node, its dist-eval cost, and the
    /// `(input, clusters)` ledger entry.
    fn reduce_pair(&self, a: IndexNode, b: IndexNode) -> Result<Reduced> {
        let mut union = a.indices;
        union.extend(b.indices);
        union.sort_unstable();
        union.dedup();
        if !self.tombstones.is_empty() {
            union.retain(|i| !self.tombstones.contains(i));
        }
        // never empty: a node whose members all died is dropped at delete
        // time, so both inputs carry at least one live member
        let view = self.ds.subset(&union);
        let engine = build_engine(self.cfg.engine, &view)?;
        let cs = seq_coreset(&view, self.m, self.cfg.k_max, self.cfg.reduce_budget, &*engine)?;
        let evals = (cs.n_clusters * view.n()) as u64;
        let node = IndexNode {
            indices: to_global(&union, &cs.indices),
            segments: a.segments + b.segments,
            points: a.points + b.points,
            n_clusters: cs.n_clusters,
            // coverage over the lineage compounds additively (triangle
            // inequality): a raw point sits within the child's radius of a
            // child-coreset point, which sits within the reduce's radius of
            // a kept member
            radius: a.radius.max(b.radius) + cs.radius,
            first_segment: min_first_segment(a.first_segment, b.first_segment),
            born_epoch: 0,
        };
        Ok((node, evals, (union.len(), cs.n_clusters)))
    }

    /// Rebuild a node from its surviving members with one SeqCoreset pass
    /// under the reduce budget.  Coverage compounds: a raw point sits
    /// within the old node's radius of some member, and every *live*
    /// member sits within the rebuild's radius of a kept member (dead
    /// members no longer need covering — they left the live ingest).
    fn rebuild_node(&self, node: &IndexNode, live: &[usize]) -> Result<Reduced> {
        let view = self.ds.subset(live);
        let engine = build_engine(self.cfg.engine, &view)?;
        let cs = seq_coreset(&view, self.m, self.cfg.k_max, self.cfg.reduce_budget, &*engine)?;
        let evals = (cs.n_clusters * view.n()) as u64;
        let rebuilt = IndexNode {
            indices: to_global(live, &cs.indices),
            segments: node.segments,
            points: node.points,
            n_clusters: cs.n_clusters,
            radius: node.radius + cs.radius,
            first_segment: node.first_segment,
            born_epoch: self.epoch,
        };
        Ok((rebuilt, evals, (live.len(), cs.n_clusters)))
    }
}

/// A reduced node, its dist-eval cost, and its `(input, clusters)` log
/// entry.
type Reduced = (IndexNode, u64, (usize, usize));

/// Min of two first-segment ordinals where 0 means "unknown" (legacy
/// snapshot): unknown is absorbing, because a merged node's window
/// membership can't be narrower than its least-known child.
fn min_first_segment(a: usize, b: usize) -> usize {
    if a == 0 || b == 0 {
        0
    } else {
        a.min(b)
    }
}

/// Map view-local coreset indices back to global dataset rows.
fn to_global(batch: &[usize], local: &[usize]) -> Vec<usize> {
    let mut out: Vec<usize> = local.iter().map(|&i| batch[i]).collect();
    out.sort_unstable();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::matroid::{maximal_independent, PartitionMatroid, UniformMatroid};

    fn cfg(k: usize, tau: usize) -> IndexConfig {
        IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(k, tau)
        }
    }

    #[test]
    fn append_carries_like_a_binary_counter() {
        let ds = synth::uniform_cube(640, 2, 3);
        let m = UniformMatroid::new(4);
        let mut idx = CoresetIndex::new(&ds, &m, cfg(4, 8));
        let order: Vec<usize> = (0..ds.n()).collect();
        for (s, chunk) in order.chunks(40).enumerate() {
            let r = idx.append(chunk).unwrap();
            // carry chain of the binary counter: segment s+1 merges once
            // per trailing one of s
            let expect_merges = (s as u32).trailing_ones() as usize;
            assert_eq!(r.merges, expect_merges, "segment {}", s + 1);
            assert_eq!(r.nodes_touched, 1 + expect_merges);
            assert_eq!(r.segment, s + 1);
            // the ledger is exactly reconstructible from the reduce log
            let analytic: u64 =
                r.reduce_log.iter().map(|&(n, c)| (n * c) as u64).sum();
            assert_eq!(r.dist_evals, analytic);
        }
        assert_eq!(idx.segments(), 16);
        assert_eq!(idx.points_ingested(), 640);
        // 16 = 2^4 segments collapse into exactly one occupied level
        assert_eq!(idx.levels().iter().flatten().count(), 1);
        assert_eq!(idx.epoch(), 16);
    }

    #[test]
    fn root_always_contains_a_feasible_solution() {
        let ds = synth::clustered(600, 2, 5, 0.15, 4, 3);
        let m = PartitionMatroid::new(vec![2; 4]);
        let k = 6;
        let mut idx = CoresetIndex::new(&ds, &m, cfg(k, 16));
        let order: Vec<usize> = (0..ds.n()).collect();
        for chunk in order.chunks(100) {
            idx.append(chunk).unwrap();
            let root = idx.root();
            let sol = maximal_independent(&m, &ds, &root, k);
            assert_eq!(sol.len(), k, "root lost feasibility at {} segments", idx.segments());
        }
    }

    #[test]
    fn root_indices_are_global_unique_and_covered() {
        let ds = synth::uniform_cube(300, 3, 7);
        let m = UniformMatroid::new(3);
        let mut idx = CoresetIndex::new(&ds, &m, cfg(3, 6));
        let order: Vec<usize> = (0..ds.n()).collect();
        idx.ingest(&order, 50).unwrap();
        let root = idx.root();
        // BTreeSet so a duplicate-id assertion failure names the same
        // first duplicate on every run
        let mut seen = std::collections::BTreeSet::new();
        for &i in &root {
            assert!(i < ds.n());
            assert!(seen.insert(i), "duplicate root index {i}");
        }
        assert!(root.len() < ds.n());
    }

    #[test]
    fn stream_leaves_work_too() {
        let ds = synth::uniform_cube(400, 2, 5);
        let m = UniformMatroid::new(4);
        let mut c = cfg(4, 8);
        c.leaf_ingest = LeafIngest::Stream;
        let mut idx = CoresetIndex::new(&ds, &m, c);
        let order: Vec<usize> = (0..ds.n()).collect();
        let receipts = idx.ingest(&order, 80).unwrap();
        assert_eq!(receipts.len(), 5);
        assert!(receipts.iter().all(|r| r.dist_evals > 0));
        let root = idx.root();
        let sol = maximal_independent(&m, &ds, &root, 4);
        assert_eq!(sol.len(), 4);
    }

    #[test]
    fn empty_batch_rejected() {
        let ds = synth::uniform_cube(50, 2, 1);
        let m = UniformMatroid::new(2);
        let mut idx = CoresetIndex::new(&ds, &m, cfg(2, 4));
        assert!(idx.append(&[]).is_err());
    }

    #[test]
    fn delete_tombstones_filter_root_and_bump_epoch() {
        let ds = synth::uniform_cube(300, 2, 13);
        let m = UniformMatroid::new(4);
        let mut idx = CoresetIndex::new(&ds, &m, cfg(4, 10));
        let order: Vec<usize> = (0..ds.n()).collect();
        idx.ingest(&order, 60).unwrap();
        let epoch_before = idx.epoch();
        let root_before = idx.root();
        // kill a couple of actual root members plus a non-member row
        let victims = vec![root_before[0], root_before[1], root_before[0]];
        let r = idx.delete(&victims).unwrap();
        assert_eq!(r.newly_dead, 2, "duplicates collapse");
        assert!(r.members_killed >= 2);
        assert_eq!(r.epoch, epoch_before + 1);
        let root_after = idx.root();
        assert!(!root_after.contains(&root_before[0]));
        assert!(!root_after.contains(&root_before[1]));
        // analytic ledger holds for rebuild passes too
        let analytic: u64 = r.reduce_log.iter().map(|&(n, c)| (n * c) as u64).sum();
        assert_eq!(r.dist_evals, analytic);
        // deleting the same rows again is a no-op: no epoch bump
        let r2 = idx.delete(&victims).unwrap();
        assert_eq!(r2.newly_dead, 0);
        assert_eq!(r2.epoch, idx.epoch());
        assert_eq!(r2.epoch, epoch_before + 1);
        assert_eq!(idx.stats().deletes, 1);
        // out-of-range rows are rejected
        assert!(idx.delete(&[ds.n()]).is_err());
    }

    #[test]
    fn delete_below_threshold_rebuilds_the_node() {
        let ds = synth::uniform_cube(320, 2, 17);
        let m = UniformMatroid::new(4);
        let mut idx = CoresetIndex::new(&ds, &m, cfg(4, 8));
        let order: Vec<usize> = (0..ds.n()).collect();
        // 8 segments -> one occupied level
        idx.ingest(&order, 40).unwrap();
        assert_eq!(idx.levels().iter().flatten().count(), 1);
        let root = idx.root();
        // kill well over half the node's members: must trigger a rebuild
        let kill: Vec<usize> = root.iter().copied().take(root.len() * 3 / 4).collect();
        let r = idx.delete(&kill).unwrap();
        assert_eq!(r.rebuilds, 1);
        assert_eq!(r.nodes_touched, 1);
        assert!(r.dist_evals > 0);
        assert_eq!(idx.stats().rebuilds, 1);
        // rebuild kept the node alive and its members live
        let node = idx.levels().iter().flatten().next().unwrap();
        assert!(node.indices.iter().all(|i| !idx.tombstones().contains(i)));
        assert_eq!(node.born_epoch, idx.epoch());
        // live fraction recovered to 1.0 (rebuilt from survivors only)
        assert!((idx.live_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn delete_everything_drops_levels() {
        let ds = synth::uniform_cube(100, 2, 19);
        let m = UniformMatroid::new(3);
        let mut idx = CoresetIndex::new(&ds, &m, cfg(3, 6));
        let order: Vec<usize> = (0..ds.n()).collect();
        idx.ingest(&order, 50).unwrap();
        let r = idx.delete(&order).unwrap();
        assert!(r.dropped_levels >= 1);
        assert_eq!(r.rebuilds, 0, "dead nodes drop, they don't rebuild");
        assert!(idx.root().is_empty());
        assert_eq!(idx.member_count(), 0);
        // appending only tombstoned rows is rejected; fresh rows would be
        // fine but this dataset is fully dead
        assert!(idx.append(&order[..10]).is_err());
    }

    #[test]
    fn last_segments_retention_keeps_leaf_granularity_and_expires() {
        let ds = synth::uniform_cube(400, 2, 23);
        let m = UniformMatroid::new(4);
        let mut c = cfg(4, 8);
        c.retention = RetentionPolicy::LastSegments(3);
        let mut idx = CoresetIndex::new(&ds, &m, c);
        let order: Vec<usize> = (0..ds.n()).collect();
        for (s, chunk) in order.chunks(40).enumerate() {
            let r = idx.append(chunk).unwrap();
            // windowed retention never merges: exactly one node touch
            assert_eq!(r.merges, 0, "segment {}", s + 1);
            assert_eq!(r.nodes_touched, 1);
            if s + 1 > 3 {
                assert_eq!(r.expired, 1, "segment {}", s + 1);
            }
            // at most w=3 occupied slots at any time
            assert!(idx.levels().iter().flatten().count() <= 3);
        }
        assert_eq!(idx.segments(), 10);
        assert_eq!(idx.stats().expired_segments, 7);
        // sequential ingestion: everything surviving is from the last 3
        // segments, i.e. rows >= 7 * 40
        assert!(idx.root().iter().all(|&i| i >= 280), "expired rows leaked into root");
    }

    #[test]
    fn ttl_retention_expires_by_epoch_age() {
        let ds = synth::uniform_cube(300, 2, 29);
        let m = UniformMatroid::new(3);
        let mut c = cfg(3, 6);
        c.retention = RetentionPolicy::Ttl(2);
        let mut idx = CoresetIndex::new(&ds, &m, c);
        let order: Vec<usize> = (0..ds.n()).collect();
        for chunk in order.chunks(50) {
            idx.append(chunk).unwrap();
            // each append bumps the epoch once, so with ttl=2 at most two
            // nodes are within their lifetime
            assert!(idx.levels().iter().flatten().count() <= 2);
        }
        let min_born = idx.epoch() - 1;
        assert!(idx
            .levels()
            .iter()
            .flatten()
            .all(|n| n.born_epoch >= min_born || n.born_epoch + 2 > idx.epoch()));
        assert_eq!(idx.stats().expired_segments as usize, idx.segments() - 2);
    }

    #[test]
    fn retention_policy_names_roundtrip() {
        for p in [
            RetentionPolicy::KeepAll,
            RetentionPolicy::LastSegments(7),
            RetentionPolicy::Ttl(12),
        ] {
            assert_eq!(RetentionPolicy::parse(&p.name()), Some(p));
        }
        assert_eq!(RetentionPolicy::parse("bogus"), None);
        assert_eq!(RetentionPolicy::parse("last:x"), None);
        assert!(RetentionPolicy::LastSegments(1).is_windowed());
        assert!(!RetentionPolicy::KeepAll.is_windowed());
    }

    #[test]
    fn parts_roundtrip_preserves_stats_and_tombstones() {
        let ds = synth::uniform_cube(200, 2, 31);
        let m = UniformMatroid::new(4);
        let mut idx = CoresetIndex::new(&ds, &m, cfg(4, 8));
        let order: Vec<usize> = (0..ds.n()).collect();
        idx.ingest(&order, 50).unwrap();
        idx.delete(&[0, 1, 2, 3, 4]).unwrap();
        let parts = idx.parts();
        let idx2 = CoresetIndex::from_parts(&ds, &m, *idx.config(), parts);
        assert_eq!(idx2.root(), idx.root());
        assert_eq!(idx2.stats(), idx.stats());
        assert_eq!(idx2.tombstones(), idx.tombstones());
        assert_eq!(idx2.epoch(), idx.epoch());
    }
}
