//! The query service: repeated `(objective, k, matroid, engine)` requests
//! answered from the standing root coreset, with an epoch-invalidated LRU
//! result cache.
//!
//! A cold query runs the pipeline's phase-2 finisher (AMT local search for
//! sum-DMMC, exhaustive or greedy otherwise) over [`CoresetIndex::root`]
//! — never the raw ingest — and scores the winner through the
//! engine-backed evaluator, exactly like `run_pipeline`'s finisher phase.
//! Cold runs are deterministic given `(spec, epoch)` (the finisher RNG is
//! seeded from the cache key and the tree epoch), so a cache hit returns a
//! bit-identical result at **zero** distance evaluations.  Appending to
//! the index bumps the tree epoch, which invalidates every cached entry
//! without any explicit flush.
//!
//! The cache and its counters live in [`ResultCache`], a lock-friendly
//! seam shared with the `dmmc serve` tenants (which wrap one in a
//! `Mutex`); the cold path itself is the free function
//! [`run_cold_query`], callable without a `&mut QueryService` so serve
//! worker threads can run it against a borrowed root.  Accounting is
//! error-aware: a rejected query (`k < 2`, `k > k_max`, empty index,
//! local-search-on-non-sum, engine construction failure) counts in
//! [`ServiceStats::errors`], never as a miss — misses feed the hit rate
//! the load harness reports, and error paths must not skew it.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::algo::exhaustive::exhaustive_best;
use crate::algo::greedy::greedy_sum;
use crate::algo::local_search::{local_search_sum, LocalSearchParams};
use crate::algo::matching::matching_race;
use crate::coordinator::spec::{build_matroid, MatroidSpec};
use crate::diversity::{diversity_with_engine, Objective};
use crate::index::tree::{AppendReceipt, CoresetIndex, DeleteReceipt};
use crate::matroid::Matroid;
use crate::runtime::engine::DistanceEngine;
use crate::runtime::{build_engine, EngineKind, ScalarEngine};
use crate::util::fnv1a;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Final-solution extractor of a query (mirrors the pipeline finishers;
/// a separate type so the service layer does not depend on the
/// coordinator's experiment runner).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QueryFinisher {
    /// AMT local search — sum-DMMC only.
    LocalSearch { gamma: f64 },
    /// Exhaustive search (any objective; exponential in k).
    Exhaustive,
    /// Greedy heuristic (cheap baseline, any objective scored after).
    Greedy,
    /// Matching-vs-GMM race, best-of-both (any objective; built for
    /// remote-clique/remote-edge).
    Matching,
}

impl QueryFinisher {
    fn key_part(&self) -> String {
        match self {
            QueryFinisher::LocalSearch { gamma } => format!("ls:{:x}", gamma.to_bits()),
            QueryFinisher::Exhaustive => "exhaustive".into(),
            QueryFinisher::Greedy => "greedy".into(),
            QueryFinisher::Matching => "matching".into(),
        }
    }
}

/// One query: which objective/constraint/extractor to serve from the
/// standing root coreset.
#[derive(Clone, Debug)]
pub struct QuerySpec {
    pub objective: Objective,
    /// Solution size; must satisfy `k <= IndexConfig::k_max`.
    pub k: usize,
    /// Constraint override; `None` = the matroid the index was built for.
    /// A spec must describe a matroid whose independent sets are
    /// independent under the build matroid (e.g. a lower-rank uniform
    /// query on any index), or the coreset guarantee does not transfer.
    pub matroid: Option<MatroidSpec>,
    pub engine: EngineKind,
    pub finisher: QueryFinisher,
}

impl QuerySpec {
    /// Common case: sum-DMMC through local search on the build matroid.
    pub fn sum_local_search(k: usize, engine: EngineKind) -> QuerySpec {
        QuerySpec {
            objective: Objective::Sum,
            k,
            matroid: None,
            engine,
            finisher: QueryFinisher::LocalSearch { gamma: 0.0 },
        }
    }

    /// Canonical cache key: every field that can change the result,
    /// f64s by bit pattern.
    pub fn cache_key(&self) -> String {
        format!(
            "{}|k={}|m={}|e={}|f={}",
            self.objective.name(),
            self.k,
            match &self.matroid {
                None => "build".to_string(),
                Some(ms) => ms.key_part(),
            },
            self.engine.name(),
            self.finisher.key_part(),
        )
    }
}

/// The solution payload a query returns (and the cache stores).
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub solution: Vec<usize>,
    pub diversity: f64,
    /// Root coreset size the finisher ran on.
    pub coreset_size: usize,
}

/// Distance-evaluation accounting for one served query.  The three cases
/// are deliberately distinct: a cache hit is *known* to cost zero evals,
/// a scalar cold run *measured* its count, and a cold run on a backend
/// without a counter did real work that simply was not measured —
/// conflating the last case with "measured zero" (the old `Option<u64>`
/// encoding) mis-reported counterless backends as free in the serve CSV.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistEvals {
    /// Measured by the scalar oracle's per-instance counter.  Sees only
    /// work routed through the engine (the batched passes and the final
    /// scoring); point-at-a-time `Dataset::dist` walks — the greedy
    /// finisher, local search's per-improving-candidate corrections — are
    /// not included, matching `LocalSearchResult::dist_evals`.
    Measured(u64),
    /// A cold run on a backend without an eval counter: work happened,
    /// but no number exists for it.
    Uncounted,
    /// Served from the result cache (or an in-flight coalesced
    /// computation): zero evaluations by construction.
    CachedZero,
}

impl DistEvals {
    /// The measured count, when one exists.
    pub fn measured(self) -> Option<u64> {
        match self {
            DistEvals::Measured(n) => Some(n),
            _ => None,
        }
    }

    /// True only for cache/coalesced answers (zero work by construction).
    pub fn is_free(self) -> bool {
        self == DistEvals::CachedZero
    }

    /// CLI/CSV rendering: the count, `n/a`, or `cached`.
    pub fn render(self) -> String {
        match self {
            DistEvals::Measured(n) => n.to_string(),
            DistEvals::Uncounted => "n/a".to_string(),
            DistEvals::CachedZero => "cached".to_string(),
        }
    }
}

/// Result + serving metadata.
#[derive(Clone, Debug)]
pub struct QueryOutcome {
    pub result: QueryResult,
    pub cache_hit: bool,
    /// Tree epoch the result is valid for (always the epoch of the root
    /// the cold run consumed — a result is never stamped with an epoch it
    /// was not computed from).
    pub epoch: u64,
    /// Engine distance evaluations this call performed.
    pub dist_evals: DistEvals,
    pub elapsed: Duration,
}

/// Serving counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub queries: u64,
    /// Same-epoch cache hits (including hits discovered on a coalescing
    /// leader's post-registration re-check).
    pub hits: u64,
    /// Successful cold runs.  A failed query is an error, not a miss.
    pub misses: u64,
    /// Rejected queries: `k < 2`, `k > k_max`, empty index, invalid
    /// finisher/objective combination, engine construction failure.
    pub errors: u64,
    /// Requests that waited on an identical in-flight `(spec, epoch)`
    /// computation and shared its result (serve-only; always 0 in the
    /// single-threaded service).
    pub coalesced: u64,
    pub evictions: u64,
}

impl ServiceStats {
    /// Fraction of queries answered without a cold computation (cache
    /// hits plus coalesced waits over all queries, errors included).
    pub fn hit_rate(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            (self.hits + self.coalesced) as f64 / self.queries as f64
        }
    }
}

#[derive(Debug)]
struct CacheSlot {
    key: String,
    epoch: u64,
    result: QueryResult,
    last_used: u64,
}

/// Default result-cache capacity.
pub const DEFAULT_CACHE_CAPACITY: usize = 64;

/// The epoch-invalidated LRU result cache plus its serving counters,
/// extracted from [`QueryService`] as a lock-friendly seam: the
/// single-threaded service owns one directly, the `dmmc serve` tenants
/// share one behind a `Mutex` across worker threads.
///
/// The accounting protocol is split so error paths stay out of the hit
/// rate: [`ResultCache::lookup`] counts the query (and a hit, if any);
/// on a miss the caller runs the cold path and then records exactly one
/// of [`ResultCache::complete_miss`] (success) or
/// [`ResultCache::record_error`] (failure).
#[derive(Debug)]
pub struct ResultCache {
    capacity: usize,
    slots: Vec<CacheSlot>,
    tick: u64,
    stats: ServiceStats,
}

impl ResultCache {
    pub fn new(capacity: usize) -> ResultCache {
        assert!(capacity >= 1, "cache capacity must be >= 1");
        ResultCache {
            capacity,
            slots: Vec::new(),
            tick: 0,
            stats: ServiceStats::default(),
        }
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Start serving one query: counts it, and returns the same-epoch
    /// cached result if one exists (counting a hit).  `None` is *not*
    /// yet a miss — the miss is recorded only when the cold run succeeds.
    pub fn lookup(&mut self, key: &str, epoch: u64) -> Option<QueryResult> {
        self.tick += 1;
        self.stats.queries += 1;
        self.touch(key, epoch)
    }

    /// Re-check after registering as a coalescing leader: a competing
    /// leader may have published between the [`ResultCache::lookup`] miss
    /// and the registration.  Counts a (late) hit, never a new query.
    pub fn recheck(&mut self, key: &str, epoch: u64) -> Option<QueryResult> {
        self.tick += 1;
        self.touch(key, epoch)
    }

    fn touch(&mut self, key: &str, epoch: u64) -> Option<QueryResult> {
        let tick = self.tick;
        let slot = self.slots.iter_mut().find(|s| s.key == key && s.epoch == epoch)?;
        slot.last_used = tick;
        self.stats.hits += 1;
        Some(slot.result.clone())
    }

    /// A cold run succeeded after a [`ResultCache::lookup`] miss: record
    /// the miss and cache the result for `(key, epoch)`.
    pub fn complete_miss(&mut self, key: &str, epoch: u64, result: QueryResult) {
        self.stats.misses += 1;
        self.insert(key, epoch, result, true);
    }

    /// A cold run failed after a [`ResultCache::lookup`] miss.
    pub fn record_error(&mut self) {
        self.stats.errors += 1;
    }

    /// A request shared an identical in-flight computation's result.
    pub fn record_coalesced(&mut self) {
        self.stats.coalesced += 1;
    }

    /// Warm the cache without touching any counter (persisted-sidecar
    /// load; see `index::store::load_result_cache`).
    pub fn seed(&mut self, key: &str, epoch: u64, result: QueryResult) {
        self.insert(key, epoch, result, false);
    }

    /// Every cached `(key, epoch, result)`, for the persisted sidecar.
    pub fn entries(&self) -> Vec<(String, u64, QueryResult)> {
        self.slots.iter().map(|s| (s.key.clone(), s.epoch, s.result.clone())).collect()
    }

    fn insert(&mut self, key: &str, epoch: u64, result: QueryResult, count_eviction: bool) {
        let tick = self.tick;
        if let Some(slot) = self.slots.iter_mut().find(|s| s.key == key) {
            // same spec at a stale epoch: refresh in place
            slot.epoch = epoch;
            slot.result = result;
            slot.last_used = tick;
            return;
        }
        if self.slots.len() == self.capacity {
            let lru = self
                .slots
                .iter()
                .enumerate()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(i, _)| i)
                .expect("non-empty cache");
            self.slots.swap_remove(lru);
            if count_eviction {
                self.stats.evictions += 1;
            }
        }
        self.slots.push(CacheSlot {
            key: key.to_string(),
            epoch,
            result,
            last_used: tick,
        });
    }
}

/// Borrowed context for one cold query — everything [`run_cold_query`]
/// needs, with no `&mut QueryService` in sight so serve worker threads
/// can run cold paths against a root captured under a read lock.
pub struct ColdQuery<'c> {
    pub ds: &'c crate::core::Dataset,
    /// The index's build matroid (used when the spec has no override).
    pub matroid: &'c dyn Matroid,
    pub k_max: usize,
    /// The root coreset the finisher runs on, captured at `epoch`.
    pub root: &'c [usize],
    pub epoch: u64,
}

/// Run the finisher on a root coreset.  Deterministic given `(spec,
/// epoch)`: the RNG seed derives from both, so re-running a cold query at
/// the same epoch reproduces the cached result bit for bit.
///
/// `engine` is an optional pre-built backend for `spec.engine`; when
/// `None` (and the spec is non-scalar) one is built for this call.
/// `DistanceEngine` is deliberately not `Send + Sync`, so serving threads
/// cannot share built engines and pass `None` — the same
/// engine-per-worker rule the MapReduce simulator follows.
pub fn run_cold_query(
    cx: &ColdQuery<'_>,
    spec: &QuerySpec,
    key: &str,
    engine: Option<&dyn DistanceEngine>,
) -> Result<(QueryResult, DistEvals)> {
    let _span = crate::span!("query.cold", "key" = key, "epoch" = cx.epoch);
    if spec.k < 2 {
        // rejected before it can reach the farness machinery, whose
        // coefficients assert k > 1
        bail!(
            "query k = {} is below the minimum of 2 (diversity is defined over pairs)",
            spec.k,
        );
    }
    if spec.k > cx.k_max {
        bail!(
            "query k = {} exceeds the index's k_max = {} (rebuild the index for larger k)",
            spec.k,
            cx.k_max,
        );
    }
    if cx.root.is_empty() {
        bail!("query on an empty index (append at least one segment first)");
    }
    let built = spec.matroid.as_ref().map(|ms| build_matroid(ms, cx.ds));
    let m: &dyn Matroid = match &built {
        Some(b) => &**b,
        None => cx.matroid,
    };
    let mut rng = Rng::new(fnv1a(key) ^ cx.epoch);
    if spec.engine == EngineKind::Scalar {
        // the oracle backend carries a per-instance eval counter, so
        // scalar queries report measured (not analytic) distance work
        let scalar = ScalarEngine::new();
        let result = finish(cx.ds, m, spec, cx.root, &scalar, &mut rng)?;
        return Ok((result, DistEvals::Measured(scalar.dist_evals())));
    }
    match engine {
        Some(e) => Ok((finish(cx.ds, m, spec, cx.root, e, &mut rng)?, DistEvals::Uncounted)),
        None => {
            let e = build_engine(spec.engine, cx.ds)?;
            Ok((finish(cx.ds, m, spec, cx.root, &*e, &mut rng)?, DistEvals::Uncounted))
        }
    }
}

/// A [`CoresetIndex`] plus the serving layer on top of it.
pub struct QueryService<'a> {
    index: CoresetIndex<'a>,
    cache: ResultCache,
    /// Lazily-built engines per registry kind: engines carry per-dataset
    /// state (cosine sqnorms are O(n d) to precompute over the *raw*
    /// ingest), so rebuilding one per query would make serving latency
    /// scale with ingest size instead of root size.  The dataset is
    /// immutable, so a built engine stays valid across appends.  The
    /// scalar oracle is excluded: it is stateless to build, and a fresh
    /// instance per query gives a per-query eval counter.
    engines: Vec<(EngineKind, Box<dyn DistanceEngine>)>,
}

impl<'a> QueryService<'a> {
    pub fn new(index: CoresetIndex<'a>) -> QueryService<'a> {
        QueryService::with_capacity(index, DEFAULT_CACHE_CAPACITY)
    }

    pub fn with_capacity(index: CoresetIndex<'a>, capacity: usize) -> QueryService<'a> {
        QueryService {
            index,
            cache: ResultCache::new(capacity),
            engines: Vec::new(),
        }
    }

    /// Build (if needed) the cached engine for `kind` (non-scalar only).
    fn ensure_engine(&mut self, kind: EngineKind) -> Result<()> {
        if self.engines.iter().any(|(k, _)| *k == kind) {
            return Ok(());
        }
        let engine = build_engine(kind, self.index.dataset())?;
        self.engines.push((kind, engine));
        Ok(())
    }

    fn engine_ref(&self, kind: EngineKind) -> Option<&dyn DistanceEngine> {
        self.engines.iter().find(|(k, _)| *k == kind).map(|(_, e)| &**e)
    }

    pub fn index(&self) -> &CoresetIndex<'a> {
        &self.index
    }

    pub fn stats(&self) -> &ServiceStats {
        self.cache.stats()
    }

    pub fn cache(&self) -> &ResultCache {
        &self.cache
    }

    /// Warm the cache from persisted `(key, epoch, result)` entries
    /// without touching the serving counters.
    pub fn warm_cache(&mut self, entries: Vec<(String, u64, QueryResult)>) {
        for (key, epoch, result) in entries {
            self.cache.seed(&key, epoch, result);
        }
    }

    /// Every cached entry, for persisting the result-cache sidecar.
    pub fn cache_entries(&self) -> Vec<(String, u64, QueryResult)> {
        self.cache.entries()
    }

    /// Ingest a segment.  The epoch bump implicitly invalidates every
    /// cached result; stale slots are refreshed lazily on their next miss.
    pub fn append(&mut self, batch: &[usize]) -> Result<AppendReceipt> {
        self.index.append(batch)
    }

    /// Tombstone rows.  An effective delete bumps the tree epoch, so
    /// every cached result is invalidated exactly like an append; a
    /// no-op delete (nothing newly dead) leaves the cache valid.
    pub fn delete(&mut self, rows: &[usize]) -> Result<DeleteReceipt> {
        self.index.delete(rows)
    }

    /// Serve one query from the root coreset (cache-first).
    pub fn query(&mut self, spec: &QuerySpec) -> Result<QueryOutcome> {
        let sw = Stopwatch::start();
        let key = spec.cache_key();
        let mut span = crate::span!("index.query", "key" = key);
        let epoch = self.index.epoch();
        if let Some(result) = self.cache.lookup(&key, epoch) {
            span.tag("source", "cache");
            return Ok(QueryOutcome {
                result,
                cache_hit: true,
                epoch,
                dist_evals: DistEvals::CachedZero,
                elapsed: sw.elapsed(),
            });
        }
        match self.cold_outcome(spec, &key, epoch) {
            Ok((result, dist_evals)) => {
                span.tag("source", "cold");
                self.cache.complete_miss(&key, epoch, result.clone());
                Ok(QueryOutcome {
                    result,
                    cache_hit: false,
                    epoch,
                    dist_evals,
                    elapsed: sw.elapsed(),
                })
            }
            Err(e) => {
                // rejected queries are errors, not misses: they must not
                // skew the hit rate the load harness reports
                span.tag("source", "error");
                self.cache.record_error();
                Err(e)
            }
        }
    }

    fn cold_outcome(
        &mut self,
        spec: &QuerySpec,
        key: &str,
        epoch: u64,
    ) -> Result<(QueryResult, DistEvals)> {
        if spec.engine != EngineKind::Scalar {
            self.ensure_engine(spec.engine)?;
        }
        let engine = self.engine_ref(spec.engine);
        let root = self.index.root();
        let cx = ColdQuery {
            ds: self.index.dataset(),
            matroid: self.index.matroid(),
            k_max: self.index.config().k_max,
            root: &root,
            epoch,
        };
        run_cold_query(&cx, spec, key, engine)
    }
}

/// Phase-2 of `run_pipeline`, expressed over the root coreset.
fn finish(
    ds: &crate::core::Dataset,
    m: &dyn Matroid,
    spec: &QuerySpec,
    root: &[usize],
    engine: &dyn DistanceEngine,
    rng: &mut Rng,
) -> Result<QueryResult> {
    let solution = match spec.finisher {
        QueryFinisher::LocalSearch { gamma } => {
            if spec.objective != Objective::Sum {
                bail!("local search finisher only applies to sum-DMMC");
            }
            let params = LocalSearchParams {
                gamma,
                ..Default::default()
            };
            local_search_sum(ds, m, spec.k, root, engine, params, None, rng)?.solution
        }
        QueryFinisher::Exhaustive => {
            exhaustive_best(ds, m, spec.k, root, spec.objective, engine)?.solution
        }
        QueryFinisher::Greedy => greedy_sum(ds, m, spec.k, root),
        QueryFinisher::Matching => {
            matching_race(ds, m, spec.k, root, spec.objective, engine, rng)?.solution
        }
    };
    let diversity = diversity_with_engine(ds, &solution, spec.objective, engine)?;
    Ok(QueryResult {
        solution,
        diversity,
        coreset_size: root.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::index::tree::IndexConfig;
    use crate::matroid::UniformMatroid;

    fn service<'a>(
        ds: &'a crate::core::Dataset,
        m: &'a UniformMatroid,
        k: usize,
        tau: usize,
    ) -> QueryService<'a> {
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(k, tau)
        };
        QueryService::new(CoresetIndex::new(ds, m, cfg))
    }

    #[test]
    fn cold_then_hit_then_invalidate() {
        let ds = synth::uniform_cube(300, 2, 11);
        let m = UniformMatroid::new(4);
        let mut svc = service(&ds, &m, 4, 8);
        let order: Vec<usize> = (0..200).collect();
        svc.append(&order).unwrap();
        let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);

        let cold = svc.query(&spec).unwrap();
        assert!(!cold.cache_hit);
        assert!(cold.dist_evals.measured().unwrap() > 0);

        let hit = svc.query(&spec).unwrap();
        assert!(hit.cache_hit);
        assert_eq!(hit.dist_evals, DistEvals::CachedZero);
        assert_eq!(hit.result.solution, cold.result.solution);
        assert_eq!(hit.result.diversity.to_bits(), cold.result.diversity.to_bits());

        // appending bumps the epoch and invalidates the entry
        let more: Vec<usize> = (200..300).collect();
        svc.append(&more).unwrap();
        let after = svc.query(&spec).unwrap();
        assert!(!after.cache_hit);
        assert_eq!(after.epoch, 2);
        assert_eq!(svc.stats().hits, 1);
        assert_eq!(svc.stats().misses, 2);
        assert_eq!(svc.stats().errors, 0);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let ds = synth::uniform_cube(200, 2, 13);
        let m = UniformMatroid::new(6);
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(6, 8)
        };
        let mut svc = QueryService::with_capacity(CoresetIndex::new(&ds, &m, cfg), 2);
        let order: Vec<usize> = (0..200).collect();
        svc.append(&order).unwrap();
        let s2 = QuerySpec::sum_local_search(2, EngineKind::Scalar);
        let s3 = QuerySpec::sum_local_search(3, EngineKind::Scalar);
        let s4 = QuerySpec::sum_local_search(4, EngineKind::Scalar);
        svc.query(&s2).unwrap();
        svc.query(&s3).unwrap();
        svc.query(&s2).unwrap(); // refresh s2 -> s3 becomes LRU
        svc.query(&s4).unwrap(); // evicts s3
        assert_eq!(svc.stats().evictions, 1);
        assert!(svc.query(&s2).unwrap().cache_hit);
        assert!(!svc.query(&s3).unwrap().cache_hit);
    }

    #[test]
    fn k_above_k_max_is_rejected_and_empty_index_errors() {
        let ds = synth::uniform_cube(100, 2, 17);
        let m = UniformMatroid::new(8);
        let mut svc = service(&ds, &m, 4, 8);
        let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);
        assert!(svc.query(&spec).is_err(), "empty index must error");
        let order: Vec<usize> = (0..100).collect();
        svc.append(&order).unwrap();
        let big = QuerySpec::sum_local_search(5, EngineKind::Scalar);
        assert!(svc.query(&big).is_err(), "k > k_max must error");
        // k < 2 is a structured error (not the farness assert's panic)
        let tiny = QuerySpec::sum_local_search(1, EngineKind::Scalar);
        let msg = format!("{:#}", svc.query(&tiny).unwrap_err());
        assert!(msg.contains("below the minimum of 2"), "{msg}");
        assert!(svc.query(&QuerySpec::sum_local_search(0, EngineKind::Scalar)).is_err());
    }

    #[test]
    fn errors_count_separately_and_never_inflate_misses() {
        // the serving-stats regression: before the errors counter, every
        // rejected query consumed a tick and a miss, permanently skewing
        // the hit rate the load harness reports
        let ds = synth::uniform_cube(120, 2, 31);
        let m = UniformMatroid::new(8);
        let mut svc = service(&ds, &m, 4, 8);
        let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);

        // empty index: an error, not a miss
        assert!(svc.query(&spec).is_err());
        assert_eq!(svc.stats().queries, 1);
        assert_eq!(svc.stats().errors, 1);
        assert_eq!(svc.stats().misses, 0);

        let order: Vec<usize> = (0..120).collect();
        svc.append(&order).unwrap();

        // k > k_max: same
        let big = QuerySpec::sum_local_search(5, EngineKind::Scalar);
        assert!(svc.query(&big).is_err());
        // local search on a non-sum objective: same
        let bad = QuerySpec {
            objective: Objective::Star,
            ..QuerySpec::sum_local_search(4, EngineKind::Scalar)
        };
        assert!(svc.query(&bad).is_err());
        assert_eq!(svc.stats().queries, 3);
        assert_eq!(svc.stats().errors, 3);
        assert_eq!(svc.stats().misses, 0);
        assert_eq!(svc.stats().hits, 0);

        // a valid query still records the one real miss, and the hit rate
        // counts only genuine hits over all queries
        assert!(!svc.query(&spec).unwrap().cache_hit);
        assert!(svc.query(&spec).unwrap().cache_hit);
        assert_eq!(svc.stats().misses, 1);
        assert_eq!(svc.stats().hits, 1);
        assert!((svc.stats().hit_rate() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn matroid_override_and_other_finishers() {
        let ds = synth::uniform_cube(150, 2, 19);
        let m = UniformMatroid::new(6);
        let mut svc = service(&ds, &m, 6, 8);
        let order: Vec<usize> = (0..150).collect();
        svc.append(&order).unwrap();
        // lower-rank uniform override + exhaustive finisher, non-sum
        let spec = QuerySpec {
            objective: Objective::Tree,
            k: 3,
            matroid: Some(MatroidSpec::Uniform(3)),
            engine: EngineKind::Scalar,
            finisher: QueryFinisher::Exhaustive,
        };
        let out = svc.query(&spec).unwrap();
        assert_eq!(out.result.solution.len(), 3);
        assert!(out.result.diversity > 0.0);
        // greedy works and caches separately
        let gspec = QuerySpec {
            finisher: QueryFinisher::Greedy,
            ..spec.clone()
        };
        let gout = svc.query(&gspec).unwrap();
        assert!(!gout.cache_hit);
        assert!(svc.query(&gspec).unwrap().cache_hit);
        // local search on a non-sum objective is rejected
        let bad = QuerySpec {
            objective: Objective::Star,
            finisher: QueryFinisher::LocalSearch { gamma: 0.0 },
            ..spec
        };
        assert!(svc.query(&bad).is_err());
    }

    #[test]
    fn delete_invalidates_cache_but_noop_delete_does_not() {
        let ds = synth::uniform_cube(300, 2, 37);
        let m = UniformMatroid::new(4);
        let mut svc = service(&ds, &m, 4, 8);
        let order: Vec<usize> = (0..300).collect();
        svc.append(&order).unwrap();
        let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);
        let cold = svc.query(&spec).unwrap();
        assert!(!cold.cache_hit);
        // an effective delete bumps the epoch: a cache hit is impossible
        let victim = cold.result.solution[0];
        let r = svc.delete(&[victim]).unwrap();
        assert_eq!(r.newly_dead, 1);
        let after = svc.query(&spec).unwrap();
        assert!(!after.cache_hit, "cache survived a delete");
        assert_ne!(after.epoch, cold.epoch);
        assert!(!after.result.solution.contains(&victim));
        // a no-op delete (same row again) keeps the cache valid
        let r2 = svc.delete(&[victim]).unwrap();
        assert_eq!(r2.newly_dead, 0);
        assert!(svc.query(&spec).unwrap().cache_hit);
    }

    #[test]
    fn cache_key_is_canonical_for_matroid_overrides() {
        let base = QuerySpec {
            objective: Objective::Sum,
            k: 3,
            matroid: Some(MatroidSpec::Uniform(3)),
            engine: EngineKind::Scalar,
            finisher: QueryFinisher::Greedy,
        };
        // pinned literal: the key must not drift with Debug formatting
        assert_eq!(base.cache_key(), "sum|k=3|m=uniform:3|e=scalar|f=greedy");
        let caps = QuerySpec {
            matroid: Some(MatroidSpec::PartitionCaps(vec![1, 2])),
            ..base.clone()
        };
        let caps2 = QuerySpec {
            matroid: Some(MatroidSpec::PartitionCaps(vec![12])),
            ..base.clone()
        };
        assert_ne!(caps.cache_key(), caps2.cache_key(), "caps keys must not collide");
        let build = QuerySpec {
            matroid: None,
            ..base
        };
        assert_ne!(build.cache_key(), QuerySpec::sum_local_search(3, EngineKind::Scalar).cache_key());
    }

    #[test]
    fn batch_engine_queries_report_uncounted_then_cached() {
        let ds = synth::uniform_cube(250, 3, 23);
        let m = UniformMatroid::new(4);
        let mut svc = service(&ds, &m, 4, 8);
        let order: Vec<usize> = (0..250).collect();
        svc.append(&order).unwrap();
        let spec = QuerySpec::sum_local_search(4, EngineKind::Batch);
        let out = svc.query(&spec).unwrap();
        // the batch backend has no counter: its work is Uncounted, which
        // must never be conflated with a measured zero
        assert_eq!(out.dist_evals, DistEvals::Uncounted);
        assert_eq!(out.dist_evals.measured(), None);
        // the cached repeat is genuinely free
        let hit = svc.query(&spec).unwrap();
        assert_eq!(hit.dist_evals, DistEvals::CachedZero);
        assert!(hit.dist_evals.is_free());
    }

    #[test]
    fn warm_cache_seeds_entries_without_touching_counters() {
        let ds = synth::uniform_cube(200, 2, 43);
        let m = UniformMatroid::new(4);
        let mut svc = service(&ds, &m, 4, 8);
        let order: Vec<usize> = (0..200).collect();
        svc.append(&order).unwrap();
        let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);
        let cold = svc.query(&spec).unwrap();
        let entries = svc.cache_entries();
        assert_eq!(entries.len(), 1);

        // a fresh service warmed with the persisted entries serves the
        // same bits as a hit, at zero queries-so-far on the counters
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(4, 8)
        };
        let mut idx2 = CoresetIndex::new(&ds, &m, cfg);
        idx2.append(&order).unwrap();
        let mut svc2 = QueryService::new(idx2);
        svc2.warm_cache(entries);
        assert_eq!(svc2.stats().queries, 0);
        let hit = svc2.query(&spec).unwrap();
        assert!(hit.cache_hit);
        assert_eq!(hit.dist_evals, DistEvals::CachedZero);
        assert_eq!(hit.result.diversity.to_bits(), cold.result.diversity.to_bits());
        assert_eq!(hit.result.solution, cold.result.solution);
        assert_eq!(svc2.stats().hits, 1);
        assert_eq!(svc2.stats().misses, 0);
    }
}
