//! The query service: repeated `(objective, k, matroid, engine)` requests
//! answered from the standing root coreset, with an epoch-invalidated LRU
//! result cache.
//!
//! A cold query runs the pipeline's phase-2 finisher (AMT local search for
//! sum-DMMC, exhaustive or greedy otherwise) over [`CoresetIndex::root`]
//! — never the raw ingest — and scores the winner through the
//! engine-backed evaluator, exactly like `run_pipeline`'s finisher phase.
//! Cold runs are deterministic given `(spec, epoch)` (the finisher RNG is
//! seeded from the cache key and the tree epoch), so a cache hit returns a
//! bit-identical result at **zero** distance evaluations.  Appending to
//! the index bumps the tree epoch, which invalidates every cached entry
//! without any explicit flush.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::algo::exhaustive::exhaustive_best;
use crate::algo::greedy::greedy_sum;
use crate::algo::local_search::{local_search_sum, LocalSearchParams};
use crate::coordinator::spec::{build_matroid, MatroidSpec};
use crate::diversity::{diversity_with_engine, Objective};
use crate::index::tree::{AppendReceipt, CoresetIndex, DeleteReceipt};
use crate::matroid::Matroid;
use crate::runtime::engine::DistanceEngine;
use crate::runtime::{build_engine, EngineKind, ScalarEngine};
use crate::util::fnv1a;
use crate::util::rng::Rng;

/// Final-solution extractor of a query (mirrors the pipeline finishers;
/// a separate type so the service layer does not depend on the
/// coordinator's experiment runner).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QueryFinisher {
    /// AMT local search — sum-DMMC only.
    LocalSearch { gamma: f64 },
    /// Exhaustive search (any objective; exponential in k).
    Exhaustive,
    /// Greedy heuristic (cheap baseline, any objective scored after).
    Greedy,
}

impl QueryFinisher {
    fn key_part(&self) -> String {
        match self {
            QueryFinisher::LocalSearch { gamma } => format!("ls:{:x}", gamma.to_bits()),
            QueryFinisher::Exhaustive => "exhaustive".into(),
            QueryFinisher::Greedy => "greedy".into(),
        }
    }
}

/// One query: which objective/constraint/extractor to serve from the
/// standing root coreset.
#[derive(Clone, Debug)]
pub struct QuerySpec {
    pub objective: Objective,
    /// Solution size; must satisfy `k <= IndexConfig::k_max`.
    pub k: usize,
    /// Constraint override; `None` = the matroid the index was built for.
    /// A spec must describe a matroid whose independent sets are
    /// independent under the build matroid (e.g. a lower-rank uniform
    /// query on any index), or the coreset guarantee does not transfer.
    pub matroid: Option<MatroidSpec>,
    pub engine: EngineKind,
    pub finisher: QueryFinisher,
}

impl QuerySpec {
    /// Common case: sum-DMMC through local search on the build matroid.
    pub fn sum_local_search(k: usize, engine: EngineKind) -> QuerySpec {
        QuerySpec {
            objective: Objective::Sum,
            k,
            matroid: None,
            engine,
            finisher: QueryFinisher::LocalSearch { gamma: 0.0 },
        }
    }

    /// Canonical cache key: every field that can change the result,
    /// f64s by bit pattern.
    pub fn cache_key(&self) -> String {
        format!(
            "{}|k={}|m={}|e={}|f={}",
            self.objective.name(),
            self.k,
            match &self.matroid {
                None => "build".to_string(),
                Some(ms) => ms.key_part(),
            },
            self.engine.name(),
            self.finisher.key_part(),
        )
    }
}

/// The solution payload a query returns (and the cache stores).
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub solution: Vec<usize>,
    pub diversity: f64,
    /// Root coreset size the finisher ran on.
    pub coreset_size: usize,
}

/// Result + serving metadata.
#[derive(Clone, Debug)]
pub struct QueryOutcome {
    pub result: QueryResult,
    pub cache_hit: bool,
    /// Tree epoch the result is valid for.
    pub epoch: u64,
    /// Engine distance evaluations this call performed: `Some(0)` on a
    /// cache hit, the measured scalar counter when `spec.engine ==
    /// Scalar`, `None` for backends without a counter.  The counter sees
    /// only work routed through the engine (the batched passes and the
    /// final scoring); point-at-a-time `Dataset::dist` walks — the greedy
    /// finisher, local search's per-improving-candidate corrections — are
    /// not included, matching `LocalSearchResult::dist_evals`.
    pub dist_evals: Option<u64>,
    pub elapsed: Duration,
}

/// Serving counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    pub queries: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

struct CacheSlot {
    key: String,
    epoch: u64,
    result: QueryResult,
    last_used: u64,
}

/// Default result-cache capacity.
pub const DEFAULT_CACHE_CAPACITY: usize = 64;

/// A [`CoresetIndex`] plus the serving layer on top of it.
pub struct QueryService<'a> {
    index: CoresetIndex<'a>,
    capacity: usize,
    cache: Vec<CacheSlot>,
    /// Lazily-built engines per registry kind: engines carry per-dataset
    /// state (cosine sqnorms are O(n d) to precompute over the *raw*
    /// ingest), so rebuilding one per query would make serving latency
    /// scale with ingest size instead of root size.  The dataset is
    /// immutable, so a built engine stays valid across appends.  The
    /// scalar oracle is excluded: it is stateless to build, and a fresh
    /// instance per query gives a per-query eval counter.
    engines: Vec<(EngineKind, Box<dyn DistanceEngine>)>,
    tick: u64,
    stats: ServiceStats,
}

impl<'a> QueryService<'a> {
    pub fn new(index: CoresetIndex<'a>) -> QueryService<'a> {
        QueryService::with_capacity(index, DEFAULT_CACHE_CAPACITY)
    }

    pub fn with_capacity(index: CoresetIndex<'a>, capacity: usize) -> QueryService<'a> {
        assert!(capacity >= 1, "cache capacity must be >= 1");
        QueryService {
            index,
            capacity,
            cache: Vec::new(),
            engines: Vec::new(),
            tick: 0,
            stats: ServiceStats::default(),
        }
    }

    /// Get-or-build the cached engine for `kind` (non-scalar kinds only).
    fn engine_for(&mut self, kind: EngineKind) -> Result<&dyn DistanceEngine> {
        if let Some(pos) = self.engines.iter().position(|(k, _)| *k == kind) {
            return Ok(&*self.engines[pos].1);
        }
        let engine = build_engine(kind, self.index.dataset())?;
        self.engines.push((kind, engine));
        Ok(&*self.engines.last().expect("just pushed").1)
    }

    pub fn index(&self) -> &CoresetIndex<'a> {
        &self.index
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// Ingest a segment.  The epoch bump implicitly invalidates every
    /// cached result; stale slots are refreshed lazily on their next miss.
    pub fn append(&mut self, batch: &[usize]) -> Result<AppendReceipt> {
        self.index.append(batch)
    }

    /// Tombstone rows.  An effective delete bumps the tree epoch, so
    /// every cached result is invalidated exactly like an append; a
    /// no-op delete (nothing newly dead) leaves the cache valid.
    pub fn delete(&mut self, rows: &[usize]) -> Result<DeleteReceipt> {
        self.index.delete(rows)
    }

    /// Serve one query from the root coreset (cache-first).
    pub fn query(&mut self, spec: &QuerySpec) -> Result<QueryOutcome> {
        let t0 = Instant::now();
        self.tick += 1;
        self.stats.queries += 1;
        let key = spec.cache_key();
        let epoch = self.index.epoch();
        if let Some(slot) = self.cache.iter_mut().find(|s| s.key == key && s.epoch == epoch) {
            slot.last_used = self.tick;
            self.stats.hits += 1;
            return Ok(QueryOutcome {
                result: slot.result.clone(),
                cache_hit: true,
                epoch,
                dist_evals: Some(0),
                elapsed: t0.elapsed(),
            });
        }
        self.stats.misses += 1;
        let (result, dist_evals) = self.run_cold(spec, &key, epoch)?;

        let tick = self.tick;
        if let Some(slot) = self.cache.iter_mut().find(|s| s.key == key) {
            // same spec at a stale epoch: refresh in place
            slot.epoch = epoch;
            slot.result = result.clone();
            slot.last_used = tick;
        } else {
            if self.cache.len() == self.capacity {
                let lru = self
                    .cache
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(i, _)| i)
                    .expect("non-empty cache");
                self.cache.swap_remove(lru);
                self.stats.evictions += 1;
            }
            self.cache.push(CacheSlot {
                key,
                epoch,
                result: result.clone(),
                last_used: tick,
            });
        }
        Ok(QueryOutcome {
            result,
            cache_hit: false,
            epoch,
            dist_evals,
            elapsed: t0.elapsed(),
        })
    }

    /// Run the finisher on the root coreset.  Deterministic given
    /// `(spec, epoch)`: the RNG seed derives from both, so re-running a
    /// cold query at the same epoch reproduces the cached result bit for
    /// bit.
    fn run_cold(
        &mut self,
        spec: &QuerySpec,
        key: &str,
        epoch: u64,
    ) -> Result<(QueryResult, Option<u64>)> {
        let k_max = self.index.config().k_max;
        if spec.k > k_max {
            bail!(
                "query k = {} exceeds the index's k_max = {k_max} (rebuild the index for larger k)",
                spec.k,
            );
        }
        let ds = self.index.dataset();
        let root = self.index.root();
        if root.is_empty() {
            bail!("query on an empty index (append at least one segment first)");
        }
        let built = spec.matroid.as_ref().map(|ms| build_matroid(ms, ds));
        let m: &dyn Matroid = match &built {
            Some(b) => &**b,
            None => self.index.matroid(),
        };
        let mut rng = Rng::new(fnv1a(key) ^ epoch);
        if spec.engine == EngineKind::Scalar {
            // the oracle backend carries a per-instance eval counter, so
            // scalar queries report measured (not analytic) distance work
            let scalar = ScalarEngine::new();
            let result = finish(ds, m, spec, &root, &scalar, &mut rng)?;
            Ok((result, Some(scalar.dist_evals())))
        } else {
            let engine = self.engine_for(spec.engine)?;
            let result = finish(ds, m, spec, &root, engine, &mut rng)?;
            Ok((result, None))
        }
    }
}

/// Phase-2 of `run_pipeline`, expressed over the root coreset.
fn finish(
    ds: &crate::core::Dataset,
    m: &dyn Matroid,
    spec: &QuerySpec,
    root: &[usize],
    engine: &dyn DistanceEngine,
    rng: &mut Rng,
) -> Result<QueryResult> {
    let solution = match spec.finisher {
        QueryFinisher::LocalSearch { gamma } => {
            if spec.objective != Objective::Sum {
                bail!("local search finisher only applies to sum-DMMC");
            }
            let params = LocalSearchParams {
                gamma,
                ..Default::default()
            };
            local_search_sum(ds, m, spec.k, root, engine, params, None, rng)?.solution
        }
        QueryFinisher::Exhaustive => {
            exhaustive_best(ds, m, spec.k, root, spec.objective, engine)?.solution
        }
        QueryFinisher::Greedy => greedy_sum(ds, m, spec.k, root),
    };
    let diversity = diversity_with_engine(ds, &solution, spec.objective, engine)?;
    Ok(QueryResult {
        solution,
        diversity,
        coreset_size: root.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::index::tree::IndexConfig;
    use crate::matroid::UniformMatroid;

    fn service<'a>(
        ds: &'a crate::core::Dataset,
        m: &'a UniformMatroid,
        k: usize,
        tau: usize,
    ) -> QueryService<'a> {
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(k, tau)
        };
        QueryService::new(CoresetIndex::new(ds, m, cfg))
    }

    #[test]
    fn cold_then_hit_then_invalidate() {
        let ds = synth::uniform_cube(300, 2, 11);
        let m = UniformMatroid::new(4);
        let mut svc = service(&ds, &m, 4, 8);
        let order: Vec<usize> = (0..200).collect();
        svc.append(&order).unwrap();
        let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);

        let cold = svc.query(&spec).unwrap();
        assert!(!cold.cache_hit);
        assert!(cold.dist_evals.unwrap() > 0);

        let hit = svc.query(&spec).unwrap();
        assert!(hit.cache_hit);
        assert_eq!(hit.dist_evals, Some(0));
        assert_eq!(hit.result.solution, cold.result.solution);
        assert_eq!(hit.result.diversity.to_bits(), cold.result.diversity.to_bits());

        // appending bumps the epoch and invalidates the entry
        let more: Vec<usize> = (200..300).collect();
        svc.append(&more).unwrap();
        let after = svc.query(&spec).unwrap();
        assert!(!after.cache_hit);
        assert_eq!(after.epoch, 2);
        assert_eq!(svc.stats().hits, 1);
        assert_eq!(svc.stats().misses, 2);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let ds = synth::uniform_cube(200, 2, 13);
        let m = UniformMatroid::new(6);
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(6, 8)
        };
        let mut svc = QueryService::with_capacity(CoresetIndex::new(&ds, &m, cfg), 2);
        let order: Vec<usize> = (0..200).collect();
        svc.append(&order).unwrap();
        let s2 = QuerySpec::sum_local_search(2, EngineKind::Scalar);
        let s3 = QuerySpec::sum_local_search(3, EngineKind::Scalar);
        let s4 = QuerySpec::sum_local_search(4, EngineKind::Scalar);
        svc.query(&s2).unwrap();
        svc.query(&s3).unwrap();
        svc.query(&s2).unwrap(); // refresh s2 -> s3 becomes LRU
        svc.query(&s4).unwrap(); // evicts s3
        assert_eq!(svc.stats().evictions, 1);
        assert!(svc.query(&s2).unwrap().cache_hit);
        assert!(!svc.query(&s3).unwrap().cache_hit);
    }

    #[test]
    fn k_above_k_max_is_rejected_and_empty_index_errors() {
        let ds = synth::uniform_cube(100, 2, 17);
        let m = UniformMatroid::new(8);
        let mut svc = service(&ds, &m, 4, 8);
        let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);
        assert!(svc.query(&spec).is_err(), "empty index must error");
        let order: Vec<usize> = (0..100).collect();
        svc.append(&order).unwrap();
        let big = QuerySpec::sum_local_search(5, EngineKind::Scalar);
        assert!(svc.query(&big).is_err(), "k > k_max must error");
    }

    #[test]
    fn matroid_override_and_other_finishers() {
        let ds = synth::uniform_cube(150, 2, 19);
        let m = UniformMatroid::new(6);
        let mut svc = service(&ds, &m, 6, 8);
        let order: Vec<usize> = (0..150).collect();
        svc.append(&order).unwrap();
        // lower-rank uniform override + exhaustive finisher, non-sum
        let spec = QuerySpec {
            objective: Objective::Tree,
            k: 3,
            matroid: Some(MatroidSpec::Uniform(3)),
            engine: EngineKind::Scalar,
            finisher: QueryFinisher::Exhaustive,
        };
        let out = svc.query(&spec).unwrap();
        assert_eq!(out.result.solution.len(), 3);
        assert!(out.result.diversity > 0.0);
        // greedy works and caches separately
        let gspec = QuerySpec {
            finisher: QueryFinisher::Greedy,
            ..spec.clone()
        };
        let gout = svc.query(&gspec).unwrap();
        assert!(!gout.cache_hit);
        assert!(svc.query(&gspec).unwrap().cache_hit);
        // local search on a non-sum objective is rejected
        let bad = QuerySpec {
            objective: Objective::Star,
            finisher: QueryFinisher::LocalSearch { gamma: 0.0 },
            ..spec
        };
        assert!(svc.query(&bad).is_err());
    }

    #[test]
    fn delete_invalidates_cache_but_noop_delete_does_not() {
        let ds = synth::uniform_cube(300, 2, 37);
        let m = UniformMatroid::new(4);
        let mut svc = service(&ds, &m, 4, 8);
        let order: Vec<usize> = (0..300).collect();
        svc.append(&order).unwrap();
        let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);
        let cold = svc.query(&spec).unwrap();
        assert!(!cold.cache_hit);
        // an effective delete bumps the epoch: a cache hit is impossible
        let victim = cold.result.solution[0];
        let r = svc.delete(&[victim]).unwrap();
        assert_eq!(r.newly_dead, 1);
        let after = svc.query(&spec).unwrap();
        assert!(!after.cache_hit, "cache survived a delete");
        assert_ne!(after.epoch, cold.epoch);
        assert!(!after.result.solution.contains(&victim));
        // a no-op delete (same row again) keeps the cache valid
        let r2 = svc.delete(&[victim]).unwrap();
        assert_eq!(r2.newly_dead, 0);
        assert!(svc.query(&spec).unwrap().cache_hit);
    }

    #[test]
    fn cache_key_is_canonical_for_matroid_overrides() {
        let base = QuerySpec {
            objective: Objective::Sum,
            k: 3,
            matroid: Some(MatroidSpec::Uniform(3)),
            engine: EngineKind::Scalar,
            finisher: QueryFinisher::Greedy,
        };
        // pinned literal: the key must not drift with Debug formatting
        assert_eq!(base.cache_key(), "sum|k=3|m=uniform:3|e=scalar|f=greedy");
        let caps = QuerySpec {
            matroid: Some(MatroidSpec::PartitionCaps(vec![1, 2])),
            ..base.clone()
        };
        let caps2 = QuerySpec {
            matroid: Some(MatroidSpec::PartitionCaps(vec![12])),
            ..base.clone()
        };
        assert_ne!(caps.cache_key(), caps2.cache_key(), "caps keys must not collide");
        let build = QuerySpec {
            matroid: None,
            ..base
        };
        assert_ne!(build.cache_key(), QuerySpec::sum_local_search(3, EngineKind::Scalar).cache_key());
    }

    #[test]
    fn batch_engine_queries_report_no_counter() {
        let ds = synth::uniform_cube(250, 3, 23);
        let m = UniformMatroid::new(4);
        let mut svc = service(&ds, &m, 4, 8);
        let order: Vec<usize> = (0..250).collect();
        svc.append(&order).unwrap();
        let spec = QuerySpec::sum_local_search(4, EngineKind::Batch);
        let out = svc.query(&spec).unwrap();
        assert_eq!(out.dist_evals, None);
        // and the cached repeat still reports zero
        assert_eq!(svc.query(&spec).unwrap().dist_evals, Some(0));
    }
}
