//! SimdEngine — lane-unrolled CPU backend with deterministic reductions.
//!
//! Where [`BatchEngine`](crate::runtime::batch::BatchEngine) wins by cache
//! blocking and thread fan-out, this backend additionally restructures the
//! *inner* distance loops into fixed lane patterns that keep several
//! independent floating-point dependency chains in flight (the shape
//! auto-vectorizers and superscalar schedulers want), while pinning the
//! reduction order of every emitted distance so the output is a pure
//! function of the two point rows — never of call shape, tile boundaries,
//! chunk size, or worker count.
//!
//! Determinism contract (per metric), pinned for every registered backend
//! by [`crate::runtime::conformance`] and `rust/tests/engine_conformance.rs`:
//!
//! * **Euclidean — bit-identical to the scalar oracle.**  Each distance
//!   accumulates the exact-difference squares `((a_t - b_t) as f64)^2`
//!   left to right into a single accumulator — the same degenerate
//!   (left-comb) reduction tree as [`crate::core::metric::euclidean`] —
//!   so every lane reproduces the oracle bit for bit.  Instruction-level
//!   parallelism comes from processing [`DIST_LANES`] *points* at once
//!   (four independent accumulator chains), not from splitting one
//!   distance's sum.
//! * **Cosine — deterministic, tolerance-bounded.**  The `<a,b>` terms use
//!   [`dot_tree4`]: four strided partial sums reduced in the fixed tree
//!   `(s0 + s1) + (s2 + s3)`.  That reassociation makes the dot product
//!   (and hence the angular distance) differ from the oracle's sequential
//!   fold by at most [`SIMD_COSINE_ABS_TOL`] — the bound accounts for the
//!   `arccos` amplification near parallel vectors — while staying
//!   bit-reproducible across calls, engines, and thread counts.  The
//!   squared norms fed to
//!   [`cosine_angular_from_parts`](crate::core::metric::cosine_angular_from_parts)
//!   are precomputed with the same tree kernel, so parts stay
//!   self-consistent.
//!
//! Everything else follows the CPU-backend contract of
//! [`DistanceEngine`]: self-pairs pinned to exactly zero, symmetric
//! same-slice tiles computed as strict upper triangle + mirror, and
//! `dists_to_points` row sums bit-identical to `sums_to_set` (the
//! incremental-AMT re-anchor identity) under **both** metrics.  The
//! cache blocking, worker gating, and scoped fan-out shapes are the
//! scaffolding shared with the batch backend
//! (`runtime::engine::fanout_*`), so the two CPU backends differ only in
//! their inner kernels.

use anyhow::Result;

use crate::core::metric::cosine_angular_from_parts;
use crate::core::{Dataset, Metric};
use crate::runtime::engine::{
    fanout_fold_state, fanout_row_positions, fanout_rows, mirror_upper_triangle,
    same_index_slice, workers_for, DistanceEngine, POINT_BLOCK,
};

/// Independent distance lanes (point rows) processed per unrolled step of
/// the Euclidean kernels: four separate accumulator chains, each in the
/// oracle's own summation order.
pub const DIST_LANES: usize = 4;

/// Absolute tolerance of the cosine (angular) paths against the scalar
/// oracle.  The tree-reduced dot differs from the sequential fold by a
/// relative ~`dim * eps`; `arccos` amplifies a similarity error `e` near
/// `|sim| = 1` to `sqrt(2 e)`, so with `e <~ 1e-13` the angular distance
/// stays within ~`1.5e-7 / pi`.  `1e-6` leaves an order of magnitude of
/// headroom and also covers the f32 cast of `pairwise_block` entries.
pub const SIMD_COSINE_ABS_TOL: f64 = 1e-6;

/// Euclidean distance with the dimension loop unrolled four-wide but the
/// squared differences still added left to right into ONE accumulator —
/// bit-identical to [`crate::core::metric::euclidean`] for every input
/// (the unroll reorders only the subtract/multiply work, never the adds).
#[inline]
fn euclid_unrolled(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let d = a.len();
    let mut acc = 0.0f64;
    let mut t = 0;
    while t + 4 <= d {
        let d0 = (a[t] - b[t]) as f64;
        acc += d0 * d0;
        let d1 = (a[t + 1] - b[t + 1]) as f64;
        acc += d1 * d1;
        let d2 = (a[t + 2] - b[t + 2]) as f64;
        acc += d2 * d2;
        let d3 = (a[t + 3] - b[t + 3]) as f64;
        acc += d3 * d3;
        t += 4;
    }
    while t < d {
        let dt = (a[t] - b[t]) as f64;
        acc += dt * dt;
        t += 1;
    }
    acc.sqrt()
}

/// Four Euclidean distances against a shared row `q` in one dimension
/// sweep: four independent accumulator chains (the lanes), each adding its
/// squared differences in index order — every lane is bit-identical to
/// [`euclid_unrolled`] / the scalar oracle.  `(p - q)^2 == (q - p)^2`
/// bitwise (IEEE negation is exact), so lane orientation never matters.
#[inline]
fn euclid_lane4(p0: &[f32], p1: &[f32], p2: &[f32], p3: &[f32], q: &[f32]) -> [f64; 4] {
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for t in 0..q.len() {
        let c = q[t];
        let d0 = (p0[t] - c) as f64;
        a0 += d0 * d0;
        let d1 = (p1[t] - c) as f64;
        a1 += d1 * d1;
        let d2 = (p2[t] - c) as f64;
        a2 += d2 * d2;
        let d3 = (p3[t] - c) as f64;
        a3 += d3 * d3;
    }
    [a0.sqrt(), a1.sqrt(), a2.sqrt(), a3.sqrt()]
}

/// f64 dot product of two f32 rows via four strided partial sums reduced
/// in the fixed tree `(s0 + s1) + (s2 + s3)`.
///
/// Deterministic by construction — the value depends only on the two rows
/// (the remainder elements land in fixed lanes `0..d % 4`) — but NOT
/// bit-identical to the sequential [`crate::core::metric::dot`]; the
/// difference is what [`SIMD_COSINE_ABS_TOL`] bounds after `arccos`.
#[inline]
pub(crate) fn dot_tree4(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let d = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut t = 0;
    while t + 4 <= d {
        s0 += a[t] as f64 * b[t] as f64;
        s1 += a[t + 1] as f64 * b[t + 1] as f64;
        s2 += a[t + 2] as f64 * b[t + 2] as f64;
        s3 += a[t + 3] as f64 * b[t + 3] as f64;
        t += 4;
    }
    if t < d {
        s0 += a[t] as f64 * b[t] as f64;
    }
    if t + 1 < d {
        s1 += a[t + 1] as f64 * b[t + 1] as f64;
    }
    if t + 2 < d {
        s2 += a[t + 2] as f64 * b[t + 2] as f64;
    }
    (s0 + s1) + (s2 + s3)
}

/// Lane-unrolled CPU distance engine (see the module docs for the
/// determinism contract).  Construct once per dataset; like the batch and
/// PJRT engines it precomputes per-dataset state (tree-reduced squared
/// norms for cosine) and asserts it is fed the same dataset on every call.
pub struct SimdEngine {
    metric: Metric,
    n: usize,
    threads: usize,
    /// Per-point squared L2 norms computed with [`dot_tree4`] so the
    /// cosine parts are self-consistent.  Empty for Euclidean datasets.
    sqnorms: Vec<f64>,
}

impl SimdEngine {
    /// Engine for `ds` using every available core.
    pub fn for_dataset(ds: &Dataset) -> SimdEngine {
        let threads = std::thread::available_parallelism()
            .map(|t| t.get())
            .unwrap_or(1);
        Self::with_threads(ds, threads)
    }

    /// Engine for `ds` with an explicit worker cap (`1` = never spawn) —
    /// the per-shard constructor the MapReduce simulator uses.
    pub fn with_threads(ds: &Dataset, threads: usize) -> SimdEngine {
        let n = ds.n();
        let sqnorms = match ds.metric {
            Metric::Cosine => {
                let mut sq = vec![0.0f64; n];
                for (i, s) in sq.iter_mut().enumerate() {
                    let p = ds.point(i);
                    *s = dot_tree4(p, p);
                }
                sq
            }
            Metric::Euclidean => Vec::new(),
        };
        SimdEngine {
            metric: ds.metric,
            n,
            threads: threads.max(1),
            sqnorms,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    fn check(&self, ds: &Dataset) {
        assert_eq!(ds.n(), self.n, "engine prepared for a different dataset");
        assert_eq!(ds.metric, self.metric, "engine prepared for a different metric");
    }

    /// Cosine angular distance between dataset rows `i` and `j` from the
    /// tree-reduced dot and the precomputed tree norms.
    #[inline]
    fn cos_dist(&self, ds: &Dataset, i: usize, j: usize) -> f64 {
        cosine_angular_from_parts(
            dot_tree4(ds.point(i), ds.point(j)),
            self.sqnorms[i],
            self.sqnorms[j],
        )
    }

    /// Fold `centers` into the state chunk covering global points
    /// `base..base + mind.len()`; per point the fold order equals the
    /// caller's order (centers iterate inside each point block, exactly
    /// like the batch backend).
    fn fold_chunk(
        &self,
        ds: &Dataset,
        centers: &[(usize, u32)],
        base: usize,
        mind: &mut [f32],
        arg: &mut [u32],
    ) {
        let mut start = 0;
        while start < mind.len() {
            let end = (start + POINT_BLOCK).min(mind.len());
            for &(c, id) in centers {
                let cp = ds.point(c);
                match self.metric {
                    Metric::Euclidean => {
                        let mut i = start;
                        while i + DIST_LANES <= end {
                            let d = euclid_lane4(
                                ds.point(base + i),
                                ds.point(base + i + 1),
                                ds.point(base + i + 2),
                                ds.point(base + i + 3),
                                cp,
                            );
                            for (lane, &dl) in d.iter().enumerate() {
                                let df = dl as f32;
                                if df < mind[i + lane] {
                                    mind[i + lane] = df;
                                    arg[i + lane] = id;
                                }
                            }
                            i += DIST_LANES;
                        }
                        while i < end {
                            let df = euclid_unrolled(ds.point(base + i), cp) as f32;
                            if df < mind[i] {
                                mind[i] = df;
                                arg[i] = id;
                            }
                            i += 1;
                        }
                    }
                    Metric::Cosine => {
                        // one tree dot per point: the four strided partial
                        // sums already form independent chains
                        let bb = self.sqnorms[c];
                        for i in start..end {
                            let p = ds.point(base + i);
                            let d = cosine_angular_from_parts(
                                dot_tree4(p, cp),
                                self.sqnorms[base + i],
                                bb,
                            ) as f32;
                            if d < mind[i] {
                                mind[i] = d;
                                arg[i] = id;
                            }
                        }
                    }
                }
            }
            start = end;
        }
    }

    fn fold(&self, ds: &Dataset, centers: &[(usize, u32)], mind: &mut [f32], arg: &mut [u32]) {
        self.check(ds);
        assert_eq!(mind.len(), self.n, "mind length != n");
        assert_eq!(arg.len(), self.n, "arg length != n");
        if centers.is_empty() || self.n == 0 {
            return;
        }
        let workers = workers_for(self.threads, self.n.saturating_mul(centers.len()));
        fanout_fold_state(workers, mind, arg, |base, m, a| {
            self.fold_chunk(ds, centers, base, m, a)
        });
    }

    /// Sums worker: oracle semantics (self-pairs excluded, distances added
    /// in set order), Euclidean distances produced four lanes at a time.
    fn sums_chunk(&self, ds: &Dataset, cands: &[usize], set: &[usize], out: &mut [f64]) {
        let m = set.len();
        for (slot, &v) in cands.iter().enumerate() {
            let vp = ds.point(v);
            let mut s = 0.0f64;
            match self.metric {
                Metric::Euclidean => {
                    let mut j = 0;
                    while j + DIST_LANES <= m {
                        let d = euclid_lane4(
                            ds.point(set[j]),
                            ds.point(set[j + 1]),
                            ds.point(set[j + 2]),
                            ds.point(set[j + 3]),
                            vp,
                        );
                        // the adds stay in set order, matching the oracle
                        for (lane, &dl) in d.iter().enumerate() {
                            if set[j + lane] != v {
                                s += dl;
                            }
                        }
                        j += DIST_LANES;
                    }
                    while j < m {
                        if set[j] != v {
                            s += euclid_unrolled(vp, ds.point(set[j]));
                        }
                        j += 1;
                    }
                }
                Metric::Cosine => {
                    for &w in set {
                        if w != v {
                            s += self.cos_dist(ds, v, w);
                        }
                    }
                }
            }
            out[slot] = s;
        }
    }

    /// Column-block worker (`out` arrives zeroed, so self-pairs are
    /// skips): exact f64 entries, Euclidean rows produced four id-lanes at
    /// a time per target column.
    fn dists_chunk(&self, ds: &Dataset, ids: &[usize], targets: &[usize], out: &mut [f64]) {
        let width = targets.len();
        match self.metric {
            Metric::Euclidean => {
                let mut slot = 0;
                while slot + DIST_LANES <= ids.len() {
                    let quad = [ids[slot], ids[slot + 1], ids[slot + 2], ids[slot + 3]];
                    for (c, &j) in targets.iter().enumerate() {
                        let d = euclid_lane4(
                            ds.point(quad[0]),
                            ds.point(quad[1]),
                            ds.point(quad[2]),
                            ds.point(quad[3]),
                            ds.point(j),
                        );
                        for (lane, &dl) in d.iter().enumerate() {
                            if quad[lane] != j {
                                out[(slot + lane) * width + c] = dl;
                            }
                        }
                    }
                    slot += DIST_LANES;
                }
                while slot < ids.len() {
                    let i = ids[slot];
                    let ip = ds.point(i);
                    for (c, &j) in targets.iter().enumerate() {
                        if i != j {
                            out[slot * width + c] = euclid_unrolled(ip, ds.point(j));
                        }
                    }
                    slot += 1;
                }
            }
            Metric::Cosine => {
                for (slot, &i) in ids.iter().enumerate() {
                    for (c, &j) in targets.iter().enumerate() {
                        if i != j {
                            out[slot * width + c] = self.cos_dist(ds, i, j);
                        }
                    }
                }
            }
        }
    }

    /// Pairwise worker over a row chunk (`out` is the chunk's tile slice,
    /// arriving zeroed): f32 entries, Euclidean columns in lane groups.
    fn pairwise_chunk(&self, ds: &Dataset, rows: &[usize], cols: &[usize], out: &mut [f32]) {
        let width = cols.len();
        for (r, &i) in rows.iter().enumerate() {
            let ip = ds.point(i);
            match self.metric {
                Metric::Euclidean => {
                    let mut c = 0;
                    while c + DIST_LANES <= width {
                        let quad = [cols[c], cols[c + 1], cols[c + 2], cols[c + 3]];
                        let d = euclid_lane4(
                            ds.point(quad[0]),
                            ds.point(quad[1]),
                            ds.point(quad[2]),
                            ds.point(quad[3]),
                            ip,
                        );
                        for (lane, &dl) in d.iter().enumerate() {
                            if quad[lane] != i {
                                out[r * width + c + lane] = dl as f32;
                            }
                        }
                        c += DIST_LANES;
                    }
                    while c < width {
                        let j = cols[c];
                        if i != j {
                            out[r * width + c] = euclid_unrolled(ip, ds.point(j)) as f32;
                        }
                        c += 1;
                    }
                }
                Metric::Cosine => {
                    for (c, &j) in cols.iter().enumerate() {
                        if i != j {
                            out[r * width + c] = self.cos_dist(ds, i, j) as f32;
                        }
                    }
                }
            }
        }
    }

    /// Upper-triangle worker for the symmetric tile: global rows
    /// `base..base + out.len() / k`, entries `b > a` only (the caller
    /// mirrors afterwards).
    fn pairwise_upper_chunk(&self, ds: &Dataset, set: &[usize], base: usize, out: &mut [f32]) {
        let k = set.len();
        for (r, row) in out.chunks_mut(k).enumerate() {
            let a = base + r;
            let i = set[a];
            let ip = ds.point(i);
            match self.metric {
                Metric::Euclidean => {
                    let mut b = a + 1;
                    while b + DIST_LANES <= k {
                        let d = euclid_lane4(
                            ds.point(set[b]),
                            ds.point(set[b + 1]),
                            ds.point(set[b + 2]),
                            ds.point(set[b + 3]),
                            ip,
                        );
                        for (lane, &dl) in d.iter().enumerate() {
                            row[b + lane] = dl as f32;
                        }
                        b += DIST_LANES;
                    }
                    while b < k {
                        row[b] = euclid_unrolled(ip, ds.point(set[b])) as f32;
                        b += 1;
                    }
                }
                Metric::Cosine => {
                    for b in (a + 1)..k {
                        row[b] = self.cos_dist(ds, i, set[b]) as f32;
                    }
                }
            }
        }
    }
}

impl DistanceEngine for SimdEngine {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn update_min(
        &self,
        ds: &Dataset,
        center: usize,
        center_id: u32,
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        self.fold(ds, &[(center, center_id)], mind, arg);
        Ok(())
    }

    fn update_min_block(
        &self,
        ds: &Dataset,
        centers: &[(usize, u32)],
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        self.fold(ds, centers, mind, arg);
        Ok(())
    }

    fn pairwise_block(&self, ds: &Dataset, rows: &[usize], cols: &[usize]) -> Result<Vec<f32>> {
        self.check(ds);
        let width = cols.len();
        let mut out = vec![0.0f32; rows.len() * width];
        if rows.is_empty() || width == 0 {
            return Ok(out);
        }
        if same_index_slice(rows, cols) {
            let k = rows.len();
            let workers = workers_for(self.threads, k * k.saturating_sub(1) / 2);
            fanout_row_positions(workers, k, k, &mut out, |base, out_chunk| {
                self.pairwise_upper_chunk(ds, rows, base, out_chunk)
            });
            mirror_upper_triangle(&mut out, k);
            return Ok(out);
        }
        let workers = workers_for(self.threads, rows.len().saturating_mul(width));
        fanout_rows(workers, rows, width, &mut out, |row_chunk, out_chunk| {
            self.pairwise_chunk(ds, row_chunk, cols, out_chunk)
        });
        Ok(out)
    }

    fn sums_to_set(&self, ds: &Dataset, candidates: &[usize], set: &[usize]) -> Result<Vec<f64>> {
        self.check(ds);
        let mut out = vec![0.0f64; candidates.len()];
        if candidates.is_empty() || set.is_empty() {
            return Ok(out);
        }
        let workers = workers_for(self.threads, candidates.len().saturating_mul(set.len()));
        fanout_rows(workers, candidates, 1, &mut out, |cand_chunk, out_chunk| {
            self.sums_chunk(ds, cand_chunk, set, out_chunk)
        });
        Ok(out)
    }

    fn dists_to_points(&self, ds: &Dataset, ids: &[usize], targets: &[usize]) -> Result<Vec<f64>> {
        self.check(ds);
        let width = targets.len();
        let mut out = vec![0.0f64; ids.len() * width];
        if ids.is_empty() || width == 0 {
            return Ok(out);
        }
        let workers = workers_for(self.threads, ids.len().saturating_mul(width));
        fanout_rows(workers, ids, width, &mut out, |id_chunk, out_chunk| {
            self.dists_chunk(ds, id_chunk, targets, out_chunk)
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::metric::{dot, euclidean};
    use crate::data::synth;
    use crate::runtime::engine::ScalarEngine;
    use crate::util::rng::Rng;

    #[test]
    fn euclid_kernels_bit_identical_to_oracle() {
        // every dim hits a different remainder path of the unroll
        let mut rng = Rng::new(5);
        for dim in 1..=9 {
            let a: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let c: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let d: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            assert_eq!(
                euclid_unrolled(&a, &q).to_bits(),
                euclidean(&a, &q).to_bits(),
                "dim {dim}"
            );
            let lanes = euclid_lane4(&a, &b, &c, &d, &q);
            for (lane, p) in [&a, &b, &c, &d].into_iter().enumerate() {
                assert_eq!(
                    lanes[lane].to_bits(),
                    euclidean(p, &q).to_bits(),
                    "dim {dim} lane {lane}"
                );
            }
        }
    }

    #[test]
    fn dot_tree4_deterministic_and_within_tolerance() {
        let mut rng = Rng::new(7);
        for dim in 1..=10 {
            let a: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let t1 = dot_tree4(&a, &b);
            let t2 = dot_tree4(&a, &b);
            assert_eq!(t1.to_bits(), t2.to_bits(), "dim {dim}: not deterministic");
            let seq = dot(&a, &b);
            assert!(
                (t1 - seq).abs() <= 1e-10 * seq.abs().max(1.0),
                "dim {dim}: tree {t1} vs sequential {seq}"
            );
        }
    }

    #[test]
    fn euclidean_paths_bit_identical_to_scalar() {
        let ds = synth::uniform_cube(517, 7, 3); // odd n, odd dim
        let simd = SimdEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let n = ds.n();
        let (mut ms, mut as_) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        let (mut mv, mut av) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        for (id, c) in [0usize, 100, 516].into_iter().enumerate() {
            scalar.update_min(&ds, c, id as u32, &mut ms, &mut as_).unwrap();
            simd.update_min(&ds, c, id as u32, &mut mv, &mut av).unwrap();
        }
        assert_eq!(ms, mv);
        assert_eq!(as_, av);
        let ids: Vec<usize> = (0..n).collect();
        let set: Vec<usize> = vec![3, 77, 150, 299, 3];
        assert_eq!(
            scalar.sums_to_set(&ds, &ids, &set).unwrap(),
            simd.sums_to_set(&ds, &ids, &set).unwrap()
        );
        assert_eq!(
            scalar.dists_to_points(&ds, &ids, &set).unwrap(),
            simd.dists_to_points(&ds, &ids, &set).unwrap()
        );
        assert_eq!(
            scalar.pairwise_block(&ds, &ids, &set).unwrap(),
            simd.pairwise_block(&ds, &ids, &set).unwrap()
        );
    }

    #[test]
    fn cosine_paths_within_documented_tolerance() {
        let ds = synth::wikisim(301, 9); // cosine metric
        let simd = SimdEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let ids: Vec<usize> = (0..ds.n()).collect();
        let set: Vec<usize> = vec![5, 100, 200, 300, 5];
        let ss = scalar.sums_to_set(&ds, &ids, &set).unwrap();
        let sv = simd.sums_to_set(&ds, &ids, &set).unwrap();
        for (a, b) in ss.iter().zip(&sv) {
            // sums of 4-5 distances: tolerance scales with the set size
            assert!((a - b).abs() <= set.len() as f64 * SIMD_COSINE_ABS_TOL);
        }
        let ds_block = scalar.dists_to_points(&ds, &ids, &set).unwrap();
        let sv_block = simd.dists_to_points(&ds, &ids, &set).unwrap();
        for (a, b) in ds_block.iter().zip(&sv_block) {
            assert!((a - b).abs() <= SIMD_COSINE_ABS_TOL);
        }
        // self-pairs pinned to a true zero despite cosine fp self-noise
        assert_eq!(sv_block[5 * set.len()], 0.0);
        assert_eq!(sv_block[5 * set.len() + 4], 0.0);
    }

    #[test]
    fn thread_count_cannot_change_output() {
        // cosine: both the tree dot and the fan-out must be shape-blind
        let ds = synth::wikisim(20_011, 4);
        let single = SimdEngine::with_threads(&ds, 1);
        let many = SimdEngine::with_threads(&ds, 8);
        let n = ds.n();
        let centers: Vec<(usize, u32)> = vec![(0, 0), (n / 2, 1), (n - 1, 2)];
        let (mut m1, mut a1) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        let (mut m8, mut a8) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        single.update_min_block(&ds, &centers, &mut m1, &mut a1).unwrap();
        many.update_min_block(&ds, &centers, &mut m8, &mut a8).unwrap();
        assert_eq!(m1, m8);
        assert_eq!(a1, a8);
        let ids: Vec<usize> = (0..n).step_by(3).collect();
        let targets: Vec<usize> = vec![1, 2, 20_010];
        assert_eq!(
            single.dists_to_points(&ds, &ids, &targets).unwrap(),
            many.dists_to_points(&ds, &ids, &targets).unwrap()
        );
    }

    #[test]
    fn rejects_wrong_dataset() {
        let ds = synth::uniform_cube(64, 2, 1);
        let other = synth::uniform_cube(65, 2, 1);
        let simd = SimdEngine::for_dataset(&ds);
        let mut m = vec![f32::INFINITY; 65];
        let mut a = vec![u32::MAX; 65];
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            simd.update_min(&other, 0, 0, &mut m, &mut a).unwrap();
        }));
        assert!(res.is_err());
    }
}
