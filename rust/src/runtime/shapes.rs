//! Artifact tile geometry — the Rust mirror of
//! `python/compile/kernels/distance.py` — and the `manifest.txt` parser.
//!
//! The AOT artifacts have fixed shapes; the runtime pads every call to
//! them.  `Manifest::load` cross-checks that the artifacts on disk were
//! built with the geometry this binary was compiled against, failing fast
//! on drift instead of producing shape errors deep inside PJRT.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::core::Metric;

/// Points per executable call (grid = NP / TP).
pub const NP: usize = 8192;
/// Points per Pallas tile (CPU-interpret tuning; see kernels/distance.py).
pub const TP: usize = 8192;
/// Centers per call (VMEM-resident tile).
pub const TC: usize = 256;
/// Supported padded feature dims.
pub const DIMS: [usize; 2] = [32, 64];

/// Pick the smallest supported padded dim >= `dim`.
pub fn padded_dim(dim: usize) -> Option<usize> {
    DIMS.into_iter().find(|&d| d >= dim)
}

/// Artifact entry name, mirroring the python naming convention.
pub fn entry_name(kernel: &str, metric: Metric, d: usize) -> String {
    format!("{kernel}_{}_d{d}", metric.name())
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub np: usize,
    pub tp: usize,
    pub tc: usize,
    pub dims: Vec<usize>,
    pub entries: BTreeSet<String>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let mut np = None;
        let mut tp = None;
        let mut tc = None;
        let mut dims = Vec::new();
        let mut entries = BTreeSet::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("bad manifest line: {line}"))?;
            match key {
                "np" => np = Some(value.parse()?),
                "tp" => tp = Some(value.parse()?),
                "tc" => tc = Some(value.parse()?),
                "dims" => {
                    dims = value
                        .split(',')
                        .map(|v| v.parse())
                        .collect::<std::result::Result<_, _>>()?
                }
                "metrics" => {}
                "entry" => {
                    entries.insert(value.to_string());
                }
                other => bail!("unknown manifest key {other}"),
            }
        }
        let m = Manifest {
            np: np.context("manifest missing np")?,
            tp: tp.context("manifest missing tp")?,
            tc: tc.context("manifest missing tc")?,
            dims,
            entries,
            dir,
        };
        m.validate()?;
        Ok(m)
    }

    /// Check the on-disk geometry matches this binary's constants.
    pub fn validate(&self) -> Result<()> {
        if self.np != NP || self.tp != TP || self.tc != TC {
            bail!(
                "artifact geometry mismatch: manifest np/tp/tc = {}/{}/{} vs binary {}/{}/{} — rebuild with `make artifacts`",
                self.np, self.tp, self.tc, NP, TP, TC
            );
        }
        if self.dims != DIMS {
            bail!("artifact dims {:?} != binary dims {:?}", self.dims, DIMS);
        }
        Ok(())
    }

    /// Path of an entry's HLO text, verifying it is listed and on disk.
    pub fn entry_path(&self, kernel: &str, metric: Metric, d: usize) -> Result<PathBuf> {
        let name = entry_name(kernel, metric, d);
        if !self.entries.contains(&name) {
            bail!("artifact entry {name} not in manifest");
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!("artifact file missing: {}", path.display());
        }
        Ok(path)
    }
}

/// Default artifact directory: `$DMMC_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("DMMC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padded_dim_picks_smallest_fit() {
        assert_eq!(padded_dim(2), Some(32));
        assert_eq!(padded_dim(32), Some(32));
        assert_eq!(padded_dim(33), Some(64));
        assert_eq!(padded_dim(64), Some(64));
        assert_eq!(padded_dim(65), None);
    }

    #[test]
    fn entry_names_match_python_convention() {
        assert_eq!(
            entry_name("gmm_update", Metric::Cosine, 32),
            "gmm_update_cosine_d32"
        );
        assert_eq!(
            entry_name("pairwise", Metric::Euclidean, 64),
            "pairwise_euclidean_d64"
        );
    }

    #[test]
    fn manifest_parses_and_validates() {
        let dir = std::env::temp_dir().join("mc_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "np=8192\ntp=8192\ntc=256\ndims=32,64\nmetrics=euclidean,cosine\nentry=gmm_update_cosine_d32\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.np, 8192);
        assert!(m.entries.contains("gmm_update_cosine_d32"));
        // listed but file missing
        assert!(m.entry_path("gmm_update", Metric::Cosine, 32).is_err());
        // not listed at all
        assert!(m.entry_path("pairwise", Metric::Cosine, 32).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_rejects_geometry_drift() {
        let dir = std::env::temp_dir().join("mc_manifest_drift");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "np=4096\ntp=256\ntc=256\ndims=32,64\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
