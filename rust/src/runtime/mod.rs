//! Runtime layer: the [`engine::DistanceEngine`] abstraction, its
//! backends, and the backend registry.
//!
//! Backends: the scalar oracle, the chunked multi-threaded
//! [`batch::BatchEngine`] (default), the lane-unrolled
//! [`simd::SimdEngine`] (deterministic reductions: Euclidean bit-identical
//! to the oracle, cosine tolerance-bounded), and (behind the `pjrt`
//! feature) the PJRT backend that executes the AOT-compiled Pallas
//! kernels (`artifacts/*.hlo.txt`) on the request path.
//!
//! The registry is [`EngineKind`]: parsed from `--engine`/`run.engine`,
//! threaded through `run_pipeline`, the MapReduce per-shard engines, the
//! streaming restructure tile, and the bench binaries
//! (`DMMC_BENCH_ENGINE`), so every scenario can A/B backends from one
//! flag.  Each kind declares its numerics contract
//! ([`EngineKind::contract`]); the cross-backend conformance harness
//! ([`conformance`], driven by `rust/tests/engine_conformance.rs`) pins
//! every registered backend to its contract — a new backend implements
//! the trait, registers a kind + contract, and inherits the whole suite.
//!
//! Python never runs here: `make artifacts` is the only python invocation,
//! and the Rust binary is self-contained afterwards.

pub mod batch;
pub mod conformance;
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod shapes;
pub mod simd;

pub use batch::BatchEngine;
pub use conformance::{EngineContract, IdentityLevel};
pub use engine::{DistanceEngine, ScalarEngine};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;
pub use shapes::{default_artifact_dir, Manifest};
pub use simd::SimdEngine;

use anyhow::Result;

use crate::core::Dataset;

/// Engine selection for CLI/config — the backend registry.
///
/// `Batch` is the default: bit-identical to `Scalar` on every path
/// (min-folds, pairwise tiles, sums — so switching engines never changes
/// a result, including the six diversity objectives that evaluate
/// through the tiles), several times faster on multi-core.  `Simd` adds
/// lane-unrolled inner loops with deterministic reductions (Euclidean
/// bit-identical, cosine within [`simd::SIMD_COSINE_ABS_TOL`]).  `Scalar`
/// stays the oracle for equivalence/conformance tests, and `Pjrt` needs
/// both the `pjrt` cargo feature and the AOT artifacts on disk
/// (`make artifacts`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Scalar,
    Batch,
    Simd,
    Pjrt,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "scalar" => Some(EngineKind::Scalar),
            "batch" => Some(EngineKind::Batch),
            "simd" => Some(EngineKind::Simd),
            "pjrt" => Some(EngineKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Scalar => "scalar",
            EngineKind::Batch => "batch",
            EngineKind::Simd => "simd",
            EngineKind::Pjrt => "pjrt",
        }
    }

    /// The backends this binary can construct — what the conformance
    /// suite iterates.  `Pjrt` appears only when compiled in (it may
    /// still fail to build at runtime without the AOT artifacts).
    pub fn registered() -> &'static [EngineKind] {
        if cfg!(feature = "pjrt") {
            &[
                EngineKind::Scalar,
                EngineKind::Batch,
                EngineKind::Simd,
                EngineKind::Pjrt,
            ]
        } else {
            &[EngineKind::Scalar, EngineKind::Batch, EngineKind::Simd]
        }
    }

    /// The backend's documented numerics contract, the single source of
    /// truth the conformance harness enforces (see [`conformance`]).
    pub fn contract(self) -> EngineContract {
        match self {
            // the oracle and the batch backend are bit-exact on every path
            EngineKind::Scalar | EngineKind::Batch => EngineContract {
                euclidean: IdentityLevel::BitExact,
                cosine: IdentityLevel::BitExact,
                row_sum_identity: true,
            },
            // lane-unrolled kernels: Euclidean keeps the oracle's
            // summation order per lane; cosine tree-reduces its dots
            EngineKind::Simd => EngineContract {
                euclidean: IdentityLevel::BitExact,
                cosine: IdentityLevel::AbsTol(simd::SIMD_COSINE_ABS_TOL),
                row_sum_identity: true,
            },
            // f32 Pallas kernels with padding: tolerance on both metrics,
            // and its dists_to_points inherits the f32 exemption
            EngineKind::Pjrt => EngineContract {
                euclidean: IdentityLevel::AbsTol(conformance::PJRT_ABS_TOL),
                cosine: IdentityLevel::AbsTol(conformance::PJRT_ABS_TOL),
                row_sum_identity: false,
            },
        }
    }
}

impl Default for EngineKind {
    fn default() -> Self {
        EngineKind::Batch
    }
}

/// Build an engine of the requested kind for `ds` using every available
/// core (PJRT loads artifacts from the default artifact dir).
pub fn build_engine(kind: EngineKind, ds: &Dataset) -> Result<Box<dyn DistanceEngine>> {
    match kind {
        EngineKind::Scalar => Ok(Box::new(ScalarEngine::new())),
        EngineKind::Batch => Ok(Box::new(BatchEngine::for_dataset(ds))),
        EngineKind::Simd => Ok(Box::new(SimdEngine::for_dataset(ds))),
        #[cfg(feature = "pjrt")]
        EngineKind::Pjrt => {
            let manifest = Manifest::load(default_artifact_dir())?;
            Ok(Box::new(PjrtEngine::for_dataset(&manifest, ds)?))
        }
        #[cfg(not(feature = "pjrt"))]
        EngineKind::Pjrt => anyhow::bail!(
            "this binary was built without the `pjrt` feature; \
             rebuild with `cargo build --features pjrt` (and run `make artifacts`)"
        ),
    }
}

/// [`build_engine`] with an explicit worker cap — the per-shard
/// constructor of the MapReduce simulator (and the conformance suite's
/// thread-invariance axis).  `Scalar` and `Pjrt` have no intra-call
/// fan-out; the cap is a no-op for them.
pub fn build_engine_with_threads(
    kind: EngineKind,
    ds: &Dataset,
    threads: usize,
) -> Result<Box<dyn DistanceEngine>> {
    match kind {
        EngineKind::Batch => Ok(Box::new(BatchEngine::with_threads(ds, threads))),
        EngineKind::Simd => Ok(Box::new(SimdEngine::with_threads(ds, threads))),
        other => build_engine(other, ds),
    }
}
