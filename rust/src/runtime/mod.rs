//! Runtime layer: the [`engine::DistanceEngine`] abstraction and its
//! backends — the scalar oracle, the chunked multi-threaded
//! [`batch::BatchEngine`] (default), and (behind the `pjrt` feature) the
//! PJRT backend that executes the AOT-compiled Pallas kernels
//! (`artifacts/*.hlo.txt`) on the request path.
//!
//! Python never runs here: `make artifacts` is the only python invocation,
//! and the Rust binary is self-contained afterwards.

pub mod batch;
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod shapes;

pub use batch::BatchEngine;
pub use engine::{DistanceEngine, ScalarEngine};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;
pub use shapes::{default_artifact_dir, Manifest};

use anyhow::Result;

use crate::core::Dataset;

/// Engine selection for CLI/config.
///
/// `Batch` is the default: bit-identical to `Scalar` on every path
/// (min-folds, pairwise tiles, sums — so switching engines never changes
/// a result, including the five diversity objectives that evaluate
/// through the tiles), several times faster on multi-core.  `Scalar`
/// stays the oracle for equivalence tests, and `Pjrt` needs both the
/// `pjrt` cargo feature and the AOT artifacts on disk (`make artifacts`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Scalar,
    Batch,
    Pjrt,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "scalar" => Some(EngineKind::Scalar),
            "batch" => Some(EngineKind::Batch),
            "pjrt" => Some(EngineKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Scalar => "scalar",
            EngineKind::Batch => "batch",
            EngineKind::Pjrt => "pjrt",
        }
    }
}

impl Default for EngineKind {
    fn default() -> Self {
        EngineKind::Batch
    }
}

/// Build an engine of the requested kind for `ds` (PJRT loads artifacts
/// from the default artifact dir).
pub fn build_engine(kind: EngineKind, ds: &Dataset) -> Result<Box<dyn DistanceEngine>> {
    match kind {
        EngineKind::Scalar => Ok(Box::new(ScalarEngine::new())),
        EngineKind::Batch => Ok(Box::new(BatchEngine::for_dataset(ds))),
        #[cfg(feature = "pjrt")]
        EngineKind::Pjrt => {
            let manifest = Manifest::load(default_artifact_dir())?;
            Ok(Box::new(PjrtEngine::for_dataset(&manifest, ds)?))
        }
        #[cfg(not(feature = "pjrt"))]
        EngineKind::Pjrt => anyhow::bail!(
            "this binary was built without the `pjrt` feature; \
             rebuild with `cargo build --features pjrt` (and run `make artifacts`)"
        ),
    }
}
