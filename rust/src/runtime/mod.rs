//! Runtime layer: the [`engine::DistanceEngine`] abstraction, the scalar
//! backend, and the PJRT backend that executes the AOT-compiled Pallas
//! kernels (`artifacts/*.hlo.txt`) on the request path.
//!
//! Python never runs here: `make artifacts` is the only python invocation,
//! and the Rust binary is self-contained afterwards.

pub mod engine;
pub mod pjrt;
pub mod shapes;

pub use engine::{DistanceEngine, ScalarEngine};
pub use pjrt::PjrtEngine;
pub use shapes::{default_artifact_dir, Manifest};

use anyhow::Result;

use crate::core::Dataset;

/// Engine selection for CLI/config.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Scalar,
    Pjrt,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "scalar" => Some(EngineKind::Scalar),
            "pjrt" => Some(EngineKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Scalar => "scalar",
            EngineKind::Pjrt => "pjrt",
        }
    }
}

/// Build an engine of the requested kind for `ds` (PJRT loads artifacts
/// from the default artifact dir).
pub fn build_engine(kind: EngineKind, ds: &Dataset) -> Result<Box<dyn DistanceEngine>> {
    match kind {
        EngineKind::Scalar => Ok(Box::new(ScalarEngine::new())),
        EngineKind::Pjrt => {
            let manifest = Manifest::load(default_artifact_dir())?;
            Ok(Box::new(PjrtEngine::for_dataset(&manifest, ds)?))
        }
    }
}
