//! Cross-backend conformance harness for [`DistanceEngine`] implementations.
//!
//! Grown out of the per-primitive identity checks of
//! `rust/tests/engine_equivalence.rs` (which remain the deep, large-`n`
//! batch-vs-scalar pins): this module is the *reusable* half — a
//! contract-driven case matrix that any registered backend runs for free,
//! and that a future backend (GPU, exact-kernel PJRT) inherits by adding
//! an [`EngineKind`](crate::runtime::EngineKind) variant and a
//! [`EngineContract`].
//!
//! For every backend the harness exercises **all five primitives**
//! (`update_min`, `update_min_block`, `pairwise_block`, `sums_to_set`,
//! `dists_to_points`) over a dataset matrix covering both metrics,
//! odd/even `n`, `dim = 1` (lane-remainder paths), `n = 1`, zero-distance
//! (all-duplicate-point) datasets, and one size large enough to engage the
//! scoped-thread fan-out — with duplicate ids, duplicate targets, and
//! self-pairs in every index-list shape.  (`dim = 0` is absent by
//! construction: `Dataset::new` rejects it, see `core/dataset.rs`.)
//!
//! Checks per backend, driven by its declared [`EngineContract`]:
//!
//! * **oracle agreement** — bit-identity ([`IdentityLevel::BitExact`]) or
//!   an absolute bound ([`IdentityLevel::AbsTol`]) against
//!   [`ScalarEngine`], per metric;
//! * **determinism** — repeated calls on one instance are bit-identical;
//! * **thread invariance** — a 1-worker and a multi-worker instance emit
//!   bit-identical outputs (chunk boundaries must never change a bit,
//!   even on tolerance-level metrics);
//! * **self-pair pinning** — `d(x, x)` entries are exactly zero and
//!   excluded from sums, on every backend regardless of tolerance (the
//!   angular cosine metric's raw `d(x, x)` carries ~1e-8 fp noise);
//! * **fold consistency** — `update_min_block` equals sequential
//!   `update_min` folds bit for bit on the same instance;
//! * **row-sum identity** (contract-gated) — summing a `dists_to_points`
//!   row in target order reproduces the `sums_to_set` entry bitwise, the
//!   incremental-AMT re-anchor identity.  All CPU backends guarantee it;
//!   the PJRT backend's f32 kernels are exempt.

use anyhow::{ensure, Context, Result};

use crate::core::{Dataset, Metric};
use crate::runtime::engine::{DistanceEngine, ScalarEngine};
use crate::runtime::{build_engine_with_threads, EngineKind};
use crate::util::rng::Rng;

/// Absolute tolerance of the feature-gated PJRT backend against the
/// oracle (f32 kernels + padding; the bound `artifacts-check` enforces).
pub const PJRT_ABS_TOL: f64 = 1e-3;

/// How closely a backend must reproduce the scalar oracle on one metric.
#[derive(Clone, Copy, Debug)]
pub enum IdentityLevel {
    /// Every emitted value equals the oracle's bit for bit.
    BitExact,
    /// Every emitted value is within this absolute bound of the oracle's.
    /// The backend must still be deterministic — the tolerance is against
    /// the oracle, never against itself.
    AbsTol(f64),
}

/// A backend's documented determinism contract (see
/// [`EngineKind::contract`](crate::runtime::EngineKind::contract)).
#[derive(Clone, Copy, Debug)]
pub struct EngineContract {
    pub euclidean: IdentityLevel,
    pub cosine: IdentityLevel,
    /// `dists_to_points` row sums reproduce `sums_to_set` bitwise.
    pub row_sum_identity: bool,
}

impl EngineContract {
    pub fn for_metric(&self, metric: Metric) -> IdentityLevel {
        match metric {
            Metric::Euclidean => self.euclidean,
            Metric::Cosine => self.cosine,
        }
    }
}

fn dataset(metric: Metric, n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let coords: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
    let name = format!("conf-{}-n{n}-d{dim}", metric.name());
    Dataset::new(dim, metric, coords, vec![vec![0]; n], 1, name)
}

/// All points identical (nonzero coords): every pairwise distance is a
/// true zero under Euclidean and fp self-noise under cosine — the
/// zero-distance edge case of the suite.
fn duplicate_dataset(metric: Metric, n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let row: Vec<f32> = (0..dim).map(|_| 1.0 + rng.normal().abs() as f32).collect();
    let coords: Vec<f32> = row.iter().copied().cycle().take(n * dim).collect();
    let name = format!("conf-dup-{}-n{n}-d{dim}", metric.name());
    Dataset::new(dim, metric, coords, vec![vec![0]; n], 1, name)
}

/// The case-matrix datasets every backend is checked on.
pub fn conformance_datasets() -> Vec<Dataset> {
    let mut out = Vec::new();
    for metric in [Metric::Euclidean, Metric::Cosine] {
        out.push(dataset(metric, 96, 7, 11)); // even n
        out.push(dataset(metric, 101, 5, 12)); // odd n
        out.push(dataset(metric, 33, 1, 13)); // dim 1: remainder-only lanes
        out.push(dataset(metric, 1, 3, 14)); // single point
        out.push(duplicate_dataset(metric, 16, 4, 15)); // zero distances
        out.push(dataset(metric, 9_001, 6, 16)); // engages thread fan-out
    }
    out
}

fn cmp_f64(tag: &str, got: &[f64], want: &[f64], level: IdentityLevel) -> Result<()> {
    ensure!(
        got.len() == want.len(),
        "{tag}: length {} != oracle {}",
        got.len(),
        want.len()
    );
    for (idx, (g, w)) in got.iter().zip(want).enumerate() {
        match level {
            IdentityLevel::BitExact => ensure!(
                g.to_bits() == w.to_bits(),
                "{tag}[{idx}]: {g:e} != oracle {w:e} (bit-exact contract)"
            ),
            IdentityLevel::AbsTol(tol) => ensure!(
                (g - w).abs() <= tol,
                "{tag}[{idx}]: |{g:e} - {w:e}| > {tol:e}"
            ),
        }
    }
    Ok(())
}

fn cmp_f32(tag: &str, got: &[f32], want: &[f32], level: IdentityLevel) -> Result<()> {
    ensure!(
        got.len() == want.len(),
        "{tag}: length {} != oracle {}",
        got.len(),
        want.len()
    );
    for (idx, (g, w)) in got.iter().zip(want).enumerate() {
        match level {
            IdentityLevel::BitExact => ensure!(
                g.to_bits() == w.to_bits(),
                "{tag}[{idx}]: {g:e} != oracle {w:e} (bit-exact contract)"
            ),
            IdentityLevel::AbsTol(tol) => ensure!(
                (*g as f64 - *w as f64).abs() <= tol,
                "{tag}[{idx}]: |{g:e} - {w:e}| > {tol:e}"
            ),
        }
    }
    Ok(())
}

fn bitwise_f64(tag: &str, a: &[f64], b: &[f64]) -> Result<()> {
    ensure!(a.len() == b.len(), "{tag}: lengths differ");
    for (idx, (x, y)) in a.iter().zip(b).enumerate() {
        ensure!(
            x.to_bits() == y.to_bits(),
            "{tag}[{idx}]: {x:e} != {y:e} (determinism / thread invariance)"
        );
    }
    Ok(())
}

fn bitwise_f32(tag: &str, a: &[f32], b: &[f32]) -> Result<()> {
    ensure!(a.len() == b.len(), "{tag}: lengths differ");
    for (idx, (x, y)) in a.iter().zip(b).enumerate() {
        ensure!(
            x.to_bits() == y.to_bits(),
            "{tag}[{idx}]: {x:e} != {y:e} (determinism / thread invariance)"
        );
    }
    Ok(())
}

/// Index-list shapes for a dataset of `n` points: spread rows with a
/// duplicate, and a short overlapping column list with a repeat — so every
/// primitive sees duplicate ids and self-pairs.
fn case_indices(n: usize) -> (Vec<usize>, Vec<usize>) {
    let step = (n / 13).max(1);
    let mut rows: Vec<usize> = (0..n).step_by(step).collect();
    rows.push(0); // duplicate id
    let cols: Vec<usize> = vec![0, n / 2, n - 1, 0]; // repeats + overlaps rows
    (rows, cols)
}

fn fold_centers(n: usize) -> Vec<(usize, u32)> {
    // includes a duplicated point with a later id: the strict-< fold must
    // keep the earliest center on the exact tie
    vec![(0, 0), (n / 3, 1), (n - 1, 2), (0, 3)]
}

/// Run the whole case matrix for one backend kind.  `threads = 1` and
/// `threads = 4` instances are built per dataset; see the module docs for
/// the checked properties.
pub fn check_backend(kind: EngineKind) -> Result<()> {
    let contract = kind.contract();
    for ds in conformance_datasets() {
        let e1 = build_engine_with_threads(kind, &ds, 1)
            .with_context(|| format!("build {} (1 thread)", kind.name()))?;
        let e4 = build_engine_with_threads(kind, &ds, 4)
            .with_context(|| format!("build {} (4 threads)", kind.name()))?;
        check_engine_on(&ds, contract, &*e1, &*e4)
            .with_context(|| format!("backend {} on {}", kind.name(), ds.name))?;
    }
    Ok(())
}

/// The per-dataset checks, reusable for backends not in the registry:
/// `e1` and `e4` are two instances of the same backend (ideally with
/// different worker caps) built for `ds`.
pub fn check_engine_on(
    ds: &Dataset,
    contract: EngineContract,
    e1: &dyn DistanceEngine,
    e4: &dyn DistanceEngine,
) -> Result<()> {
    let oracle = ScalarEngine::new();
    let level = contract.for_metric(ds.metric);
    let n = ds.n();
    let (rows, cols) = case_indices(n);
    let centers = fold_centers(n);

    // ---- update_min / update_min_block -------------------------------
    let mut mo = vec![f32::INFINITY; n];
    let mut ao = vec![u32::MAX; n];
    let mut m1 = mo.clone();
    let mut a1 = ao.clone();
    let mut m4 = mo.clone();
    let mut a4 = ao.clone();
    for &(c, id) in &centers {
        oracle.update_min(ds, c, id, &mut mo, &mut ao)?;
        e1.update_min(ds, c, id, &mut m1, &mut a1)?;
        e4.update_min(ds, c, id, &mut m4, &mut a4)?;
        bitwise_f32("update_min mind (1 vs 4 workers)", &m1, &m4)?;
        ensure!(a1 == a4, "update_min arg: thread count changed the argmin");
        cmp_f32(&format!("update_min mind after center {id}"), &m1, &mo, level)?;
        match level {
            IdentityLevel::BitExact => ensure!(
                a1 == ao,
                "update_min arg diverged from oracle after center {id}"
            ),
            IdentityLevel::AbsTol(_) => {
                // near-ties may legitimately resolve differently; the arg
                // must still be one of the folded centers
                for (i, &a) in a1.iter().enumerate() {
                    ensure!(
                        centers.iter().any(|&(_, id2)| id2 == a),
                        "update_min arg[{i}] = {a} is not a folded center id"
                    );
                }
            }
        }
    }
    // block fold == sequential folds, bit for bit, on the same instance
    let mut mb = vec![f32::INFINITY; n];
    let mut ab = vec![u32::MAX; n];
    e1.update_min_block(ds, &centers, &mut mb, &mut ab)?;
    bitwise_f32("update_min_block vs sequential folds (mind)", &mb, &m1)?;
    ensure!(ab == a1, "update_min_block vs sequential folds (arg)");

    // ---- pairwise_block ----------------------------------------------
    let rect_o = oracle.pairwise_block(ds, &rows, &cols)?;
    let rect_1 = e1.pairwise_block(ds, &rows, &cols)?;
    let rect_4 = e4.pairwise_block(ds, &rows, &cols)?;
    bitwise_f32("pairwise_block rect (1 vs 4 workers)", &rect_1, &rect_4)?;
    bitwise_f32(
        "pairwise_block rect (repeat call)",
        &rect_1,
        &e1.pairwise_block(ds, &rows, &cols)?,
    )?;
    cmp_f32("pairwise_block rect", &rect_1, &rect_o, level)?;
    // self-pairs exactly zero regardless of tolerance level
    let width = cols.len();
    for (r, &i) in rows.iter().enumerate() {
        for (c, &j) in cols.iter().enumerate() {
            if i == j {
                ensure!(
                    rect_1[r * width + c] == 0.0,
                    "pairwise_block self-pair ({i},{j}) not a true zero"
                );
            }
        }
    }
    // symmetric same-slice tile with a true-zero diagonal
    let k = n.min(7);
    let sym: Vec<usize> = (0..k).map(|a| a * (n - 1) / k.max(1)).collect();
    let sym_o = oracle.pairwise_block(ds, &sym, &sym)?;
    let sym_1 = e1.pairwise_block(ds, &sym, &sym)?;
    let sym_4 = e4.pairwise_block(ds, &sym, &sym)?;
    bitwise_f32("pairwise_block sym (1 vs 4 workers)", &sym_1, &sym_4)?;
    cmp_f32("pairwise_block sym", &sym_1, &sym_o, level)?;
    for a in 0..k {
        ensure!(
            sym_1[a * k + a] == 0.0,
            "symmetric tile diagonal [{a}] not a true zero"
        );
    }
    // 1 x 1 self tile and empty shapes
    ensure!(
        e1.pairwise_block(ds, &[0], &[0])? == vec![0.0f32],
        "1x1 self tile must be [0.0]"
    );
    ensure!(
        e1.pairwise_block(ds, &[], &cols)?.is_empty(),
        "empty rows must yield an empty tile"
    );
    ensure!(
        e1.pairwise_block(ds, &rows, &[])?.is_empty(),
        "empty cols must yield an empty tile"
    );

    // ---- sums_to_set --------------------------------------------------
    let sums_o = oracle.sums_to_set(ds, &rows, &cols)?;
    let sums_1 = e1.sums_to_set(ds, &rows, &cols)?;
    let sums_4 = e4.sums_to_set(ds, &rows, &cols)?;
    bitwise_f64("sums_to_set (1 vs 4 workers)", &sums_1, &sums_4)?;
    // sums accumulate cols.len() distances: scale the per-distance bound
    let sums_level = match level {
        IdentityLevel::BitExact => IdentityLevel::BitExact,
        IdentityLevel::AbsTol(tol) => IdentityLevel::AbsTol(tol * cols.len() as f64),
    };
    cmp_f64("sums_to_set", &sums_1, &sums_o, sums_level)?;
    // self-pair exclusion is tolerance-free: a candidate against only
    // itself sums to exactly zero
    ensure!(
        e1.sums_to_set(ds, &[n - 1], &[n - 1])? == vec![0.0f64],
        "sums_to_set self-only set must be exactly [0.0]"
    );
    // empty id set and empty candidate list
    ensure!(
        e1.sums_to_set(ds, &rows, &[])? == vec![0.0f64; rows.len()],
        "sums_to_set over an empty set must be all-zero"
    );
    ensure!(
        e1.sums_to_set(ds, &[], &cols)?.is_empty(),
        "sums_to_set with no candidates must be empty"
    );

    // ---- dists_to_points ---------------------------------------------
    let blk_o = oracle.dists_to_points(ds, &rows, &cols)?;
    let blk_1 = e1.dists_to_points(ds, &rows, &cols)?;
    let blk_4 = e4.dists_to_points(ds, &rows, &cols)?;
    bitwise_f64("dists_to_points (1 vs 4 workers)", &blk_1, &blk_4)?;
    bitwise_f64(
        "dists_to_points (repeat call)",
        &blk_1,
        &e1.dists_to_points(ds, &rows, &cols)?,
    )?;
    cmp_f64("dists_to_points", &blk_1, &blk_o, level)?;
    for (r, &i) in rows.iter().enumerate() {
        for (c, &j) in cols.iter().enumerate() {
            if i == j {
                ensure!(
                    blk_1[r * width + c] == 0.0,
                    "dists_to_points self-pair ({i},{j}) not a true zero"
                );
            }
        }
    }
    ensure!(
        e1.dists_to_points(ds, &rows, &[])?.is_empty(),
        "dists_to_points with empty targets must be empty"
    );
    ensure!(
        e1.dists_to_points(ds, &[], &cols)?.is_empty(),
        "dists_to_points with empty ids must be empty"
    );

    // ---- row-sum identity (contract-gated) ---------------------------
    if contract.row_sum_identity {
        for (r, want) in sums_1.iter().enumerate() {
            let resum: f64 = blk_1[r * width..(r + 1) * width].iter().sum();
            ensure!(
                resum.to_bits() == want.to_bits(),
                "row-sum identity broke at row {r}: resummed {resum:e} vs sums_to_set {want:e}"
            );
        }
    }

    // duplicated id rows must reproduce the original rows exactly (the
    // last rows entry duplicates rows[0])
    let last = rows.len() - 1;
    ensure!(
        blk_1[last * width..(last + 1) * width] == blk_1[..width],
        "duplicate id row diverged from its original"
    );

    Ok(())
}
