//! BatchEngine — the default CPU backend for the distance hot path.
//!
//! Where [`ScalarEngine`](crate::runtime::engine::ScalarEngine) walks one
//! point at a time, this backend processes the dataset in cache blocks and
//! fans the blocks out over `std::thread::scope` workers spawned per call
//! (no rayon, no shared pool: the engine itself stays compatible with the
//! `!Send + !Sync` contract of the trait, and nested consumers like the
//! MapReduce simulator can cap the per-shard thread budget).
//!
//! Numerics contract, pinned by `rust/tests/engine_equivalence.rs`:
//!
//! * **every operation is bit-identical to the scalar oracle** —
//!   `update_min` / `update_min_block` / `sums_to_set` / `pairwise_block` /
//!   `dists_to_points`.
//!   Per point the center fold is a left fold in the caller's order, each
//!   distance is evaluated with the exact same f64 formulas as
//!   [`crate::core::metric`], and the cosine path feeds the squared norms
//!   precomputed at construction through [`cosine_angular_from_parts`]
//!   (same accumulation order, same value).  Chunk boundaries and worker
//!   count cannot change a single output bit — outputs are element-wise
//!   independent under all four operations.
//!
//! `pairwise_block` used to be the one tolerance-only path (expanded-form
//! Euclidean `d^2 = |a|^2 + |b|^2 - 2<a,b>` over precomputed norms).  The
//! diversity evaluators now consume its tiles for the tree/cycle/
//! bipartition objectives, whose engine-independence requires exact tile
//! identity, so the tile kernel runs the exact difference form too; the
//! backend's win on this path is the scoped multi-thread fan-out over row
//! chunks.  An expanded-form fast tile can come back behind a separate,
//! tolerance-gated method if a profile ever justifies it.
//!
//! Per the trait contract, self-pairs are pinned to exactly zero (the
//! angular cosine metric's raw `d(x, x)` carries ~1e-8 fp noise), and the
//! symmetric same-slice tile computes only the strict upper triangle and
//! mirrors it — `d` is bit-symmetric under both metrics, so the output
//! matches the rectangular walk while the distance work halves.

use anyhow::Result;

use crate::core::metric::{cosine_angular_from_parts, dot, euclidean};
use crate::core::{Dataset, Metric};
use crate::runtime::engine::{
    fanout_fold_state, fanout_row_positions, fanout_rows, mirror_upper_triangle,
    same_index_slice, workers_for, DistanceEngine, POINT_BLOCK,
};

/// Chunked, multi-threaded CPU distance engine.
///
/// Construct once per dataset ([`BatchEngine::for_dataset`]); like the
/// PJRT engine it precomputes per-dataset state (squared norms) and
/// asserts it is fed the same dataset on every call.
pub struct BatchEngine {
    metric: Metric,
    n: usize,
    threads: usize,
    /// Per-point squared L2 norms, accumulated in the same order as the
    /// scalar cosine kernel so the cosine fast path stays bit-identical.
    /// Empty for Euclidean datasets — only the cosine kernels read it.
    sqnorms: Vec<f64>,
}

impl BatchEngine {
    /// Engine for `ds` using every available core.
    pub fn for_dataset(ds: &Dataset) -> BatchEngine {
        let threads = std::thread::available_parallelism()
            .map(|t| t.get())
            .unwrap_or(1);
        Self::with_threads(ds, threads)
    }

    /// Engine for `ds` with an explicit worker cap (`1` = never spawn).
    /// Nested-parallel consumers (one engine per MapReduce shard) use this
    /// to divide the machine between shards.
    pub fn with_threads(ds: &Dataset, threads: usize) -> BatchEngine {
        let n = ds.n();
        // the squared norms feed only the cosine kernels; Euclidean paths
        // use the exact difference form, so skip the O(n d) precompute
        // (per-block/per-shard constructors would otherwise pay it on
        // every engine)
        let sqnorms = match ds.metric {
            Metric::Cosine => {
                let mut sq = vec![0.0f64; n];
                for (i, s) in sq.iter_mut().enumerate() {
                    let p = ds.point(i);
                    *s = dot(p, p);
                }
                sq
            }
            Metric::Euclidean => Vec::new(),
        };
        BatchEngine {
            metric: ds.metric,
            n,
            threads: threads.max(1),
            sqnorms,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    fn check(&self, ds: &Dataset) {
        assert_eq!(ds.n(), self.n, "engine prepared for a different dataset");
        assert_eq!(ds.metric, self.metric, "engine prepared for a different metric");
    }

    /// Fold `centers` into the state chunk covering global points
    /// `base..base + mind.len()`.  Centers iterate inside each
    /// `POINT_BLOCK` sub-block (center rows hot in L1, point rows
    /// streaming); per point the fold order equals the caller's order.
    fn fold_chunk(
        &self,
        ds: &Dataset,
        centers: &[(usize, u32)],
        base: usize,
        mind: &mut [f32],
        arg: &mut [u32],
    ) {
        let mut start = 0;
        while start < mind.len() {
            let end = (start + POINT_BLOCK).min(mind.len());
            for &(c, id) in centers {
                let cp = ds.point(c);
                match self.metric {
                    Metric::Euclidean => {
                        for i in start..end {
                            let d = euclidean(ds.point(base + i), cp) as f32;
                            if d < mind[i] {
                                mind[i] = d;
                                arg[i] = id;
                            }
                        }
                    }
                    Metric::Cosine => {
                        let bb = self.sqnorms[c];
                        for i in start..end {
                            let p = ds.point(base + i);
                            let d = cosine_angular_from_parts(
                                dot(p, cp),
                                self.sqnorms[base + i],
                                bb,
                            ) as f32;
                            if d < mind[i] {
                                mind[i] = d;
                                arg[i] = id;
                            }
                        }
                    }
                }
            }
            start = end;
        }
    }

    fn fold(&self, ds: &Dataset, centers: &[(usize, u32)], mind: &mut [f32], arg: &mut [u32]) {
        self.check(ds);
        assert_eq!(mind.len(), self.n, "mind length != n");
        assert_eq!(arg.len(), self.n, "arg length != n");
        if centers.is_empty() || self.n == 0 {
            return;
        }
        let workers = workers_for(self.threads, self.n.saturating_mul(centers.len()));
        fanout_fold_state(workers, mind, arg, |base, m, a| {
            self.fold_chunk(ds, centers, base, m, a)
        });
    }

    /// Sums worker: `out[slot] = sum_w d(cands[slot], w)` over `set`, with
    /// the exact oracle formulas and summation order.  Self-pairs are
    /// excluded, matching the trait contract (exactly zero by definition).
    fn sums_chunk(&self, ds: &Dataset, cands: &[usize], set: &[usize], out: &mut [f64]) {
        for (slot, &v) in cands.iter().enumerate() {
            let vp = ds.point(v);
            let mut s = 0.0f64;
            match self.metric {
                Metric::Euclidean => {
                    for &w in set {
                        if w != v {
                            s += euclidean(vp, ds.point(w));
                        }
                    }
                }
                Metric::Cosine => {
                    let aa = self.sqnorms[v];
                    for &w in set {
                        if w != v {
                            s += cosine_angular_from_parts(
                                dot(vp, ds.point(w)),
                                aa,
                                self.sqnorms[w],
                            );
                        }
                    }
                }
            }
            out[slot] = s;
        }
    }

    /// Column-block worker: `out[slot * targets.len() + c] =
    /// d(ids[slot], targets[c])` in exact f64, self-pairs pinned to zero —
    /// the same per-entry formulas and values as the scalar oracle, so the
    /// incremental AMT deltas built from these columns are bit-identical
    /// to `ds.dist` (`out` arrives zeroed, so self-pairs are skips).
    fn dists_chunk(&self, ds: &Dataset, ids: &[usize], targets: &[usize], out: &mut [f64]) {
        let width = targets.len();
        for (slot, &i) in ids.iter().enumerate() {
            let ip = ds.point(i);
            match self.metric {
                Metric::Euclidean => {
                    for (c, &j) in targets.iter().enumerate() {
                        if i != j {
                            out[slot * width + c] = euclidean(ip, ds.point(j));
                        }
                    }
                }
                Metric::Cosine => {
                    let aa = self.sqnorms[i];
                    for (c, &j) in targets.iter().enumerate() {
                        if i != j {
                            out[slot * width + c] = cosine_angular_from_parts(
                                dot(ip, ds.point(j)),
                                aa,
                                self.sqnorms[j],
                            );
                        }
                    }
                }
            }
        }
    }

    /// Pairwise worker over a row chunk (`out` is the chunk's tile slice).
    /// Exact oracle formulas per entry, self-pairs pinned to zero — tile
    /// identity with the scalar engine is load-bearing for the diversity
    /// evaluators.  `out` arrives zeroed, so self-pairs are skips.
    fn pairwise_chunk(&self, ds: &Dataset, rows: &[usize], cols: &[usize], out: &mut [f32]) {
        let width = cols.len();
        for (r, &i) in rows.iter().enumerate() {
            let ip = ds.point(i);
            match self.metric {
                Metric::Euclidean => {
                    for (c, &j) in cols.iter().enumerate() {
                        if i != j {
                            out[r * width + c] = euclidean(ip, ds.point(j)) as f32;
                        }
                    }
                }
                Metric::Cosine => {
                    let aa = self.sqnorms[i];
                    for (c, &j) in cols.iter().enumerate() {
                        if i != j {
                            let d = cosine_angular_from_parts(
                                dot(ip, ds.point(j)),
                                aa,
                                self.sqnorms[j],
                            );
                            out[r * width + c] = d as f32;
                        }
                    }
                }
            }
        }
    }

    /// Upper-triangle worker for the symmetric tile: for each global row
    /// `a` in this chunk, fill the entries `b > a` only (the rest stays
    /// zero; the caller mirrors the strict upper triangle afterwards).
    fn pairwise_upper_chunk(&self, ds: &Dataset, set: &[usize], base: usize, out: &mut [f32]) {
        let k = set.len();
        for (r, row) in out.chunks_mut(k).enumerate() {
            let a = base + r;
            let i = set[a];
            let ip = ds.point(i);
            match self.metric {
                Metric::Euclidean => {
                    for b in (a + 1)..k {
                        row[b] = euclidean(ip, ds.point(set[b])) as f32;
                    }
                }
                Metric::Cosine => {
                    let aa = self.sqnorms[i];
                    for b in (a + 1)..k {
                        let j = set[b];
                        row[b] =
                            cosine_angular_from_parts(dot(ip, ds.point(j)), aa, self.sqnorms[j])
                                as f32;
                    }
                }
            }
        }
    }
}

impl DistanceEngine for BatchEngine {
    fn name(&self) -> &'static str {
        "batch"
    }

    fn update_min(
        &self,
        ds: &Dataset,
        center: usize,
        center_id: u32,
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        self.fold(ds, &[(center, center_id)], mind, arg);
        Ok(())
    }

    fn update_min_block(
        &self,
        ds: &Dataset,
        centers: &[(usize, u32)],
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        self.fold(ds, centers, mind, arg);
        Ok(())
    }

    fn pairwise_block(&self, ds: &Dataset, rows: &[usize], cols: &[usize]) -> Result<Vec<f32>> {
        self.check(ds);
        let width = cols.len();
        let mut out = vec![0.0f32; rows.len() * width];
        if rows.is_empty() || width == 0 {
            return Ok(out);
        }
        if same_index_slice(rows, cols) {
            // symmetric k x k tile: fill the strict upper triangle in
            // parallel (row chunks are imbalanced — row a has k-1-a
            // entries — but the tile stays one engine call), then mirror
            let k = rows.len();
            let workers = workers_for(self.threads, k * k.saturating_sub(1) / 2);
            fanout_row_positions(workers, k, k, &mut out, |base, out_chunk| {
                self.pairwise_upper_chunk(ds, rows, base, out_chunk)
            });
            mirror_upper_triangle(&mut out, k);
            return Ok(out);
        }
        let workers = workers_for(self.threads, rows.len().saturating_mul(width));
        fanout_rows(workers, rows, width, &mut out, |row_chunk, out_chunk| {
            self.pairwise_chunk(ds, row_chunk, cols, out_chunk)
        });
        Ok(out)
    }

    fn sums_to_set(&self, ds: &Dataset, candidates: &[usize], set: &[usize]) -> Result<Vec<f64>> {
        self.check(ds);
        let mut out = vec![0.0f64; candidates.len()];
        if candidates.is_empty() || set.is_empty() {
            return Ok(out);
        }
        let workers = workers_for(self.threads, candidates.len().saturating_mul(set.len()));
        fanout_rows(workers, candidates, 1, &mut out, |cand_chunk, out_chunk| {
            self.sums_chunk(ds, cand_chunk, set, out_chunk)
        });
        Ok(out)
    }

    fn dists_to_points(&self, ds: &Dataset, ids: &[usize], targets: &[usize]) -> Result<Vec<f64>> {
        self.check(ds);
        let width = targets.len();
        let mut out = vec![0.0f64; ids.len() * width];
        if ids.is_empty() || width == 0 {
            return Ok(out);
        }
        let workers = workers_for(self.threads, ids.len().saturating_mul(width));
        fanout_rows(workers, ids, width, &mut out, |id_chunk, out_chunk| {
            self.dists_chunk(ds, id_chunk, targets, out_chunk)
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::engine::ScalarEngine;

    #[test]
    fn fold_matches_scalar_small() {
        let ds = synth::uniform_cube(513, 3, 5);
        let batch = BatchEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let mut mb = vec![f32::INFINITY; 513];
        let mut ab = vec![u32::MAX; 513];
        let mut ms = mb.clone();
        let mut as_ = ab.clone();
        for (id, c) in [0usize, 100, 512].into_iter().enumerate() {
            batch.update_min(&ds, c, id as u32, &mut mb, &mut ab).unwrap();
            scalar.update_min(&ds, c, id as u32, &mut ms, &mut as_).unwrap();
        }
        assert_eq!(mb, ms);
        assert_eq!(ab, as_);
    }

    #[test]
    fn sums_and_pairwise_agree_with_oracle() {
        let ds = synth::wikisim(300, 2); // cosine metric
        let batch = BatchEngine::for_dataset(&ds);
        let cands: Vec<usize> = (0..300).collect();
        let set: Vec<usize> = vec![3, 77, 150, 299];
        let sums = batch.sums_to_set(&ds, &cands, &set).unwrap();
        for (i, &v) in cands.iter().enumerate() {
            // oracle semantics: self-pairs excluded exactly
            let want: f64 = set
                .iter()
                .filter(|&&w| w != v)
                .map(|&w| ds.dist(v, w))
                .sum();
            assert_eq!(sums[i], want, "sums_to_set must be bit-identical");
        }
        let tile = batch.pairwise_block(&ds, &cands, &set).unwrap();
        for (r, &i) in cands.iter().enumerate() {
            for (c, &j) in set.iter().enumerate() {
                let want = if i == j { 0.0 } else { ds.dist(i, j) as f32 };
                let got = tile[r * set.len() + c];
                assert_eq!(got, want, "pairwise tile must be bit-identical");
            }
        }
    }

    #[test]
    fn dists_to_points_agrees_with_oracle() {
        // cosine exercises the precomputed-sqnorm parts path
        let ds = synth::wikisim(400, 3);
        let batch = BatchEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let ids: Vec<usize> = (0..400).collect();
        let targets: Vec<usize> = vec![7, 123, 7, 399]; // duplicate target
        let db = batch.dists_to_points(&ds, &ids, &targets).unwrap();
        let so = scalar.dists_to_points(&ds, &ids, &targets).unwrap();
        assert_eq!(db, so, "dists_to_points must be bit-identical");
        // self-pairs pinned to a true zero despite cosine fp self-noise
        assert_eq!(db[7 * 4], 0.0);
        assert_eq!(db[7 * 4 + 2], 0.0);
        assert_eq!(db[399 * 4 + 3], 0.0);
    }

    #[test]
    fn rejects_wrong_dataset() {
        let ds = synth::uniform_cube(64, 2, 1);
        let other = synth::uniform_cube(65, 2, 1);
        let batch = BatchEngine::for_dataset(&ds);
        let mut m = vec![f32::INFINITY; 65];
        let mut a = vec![u32::MAX; 65];
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            batch.update_min(&other, 0, 0, &mut m, &mut a).unwrap();
        }));
        assert!(res.is_err());
    }
}
