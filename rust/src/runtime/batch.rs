//! BatchEngine — the default CPU backend for the distance hot path.
//!
//! Where [`ScalarEngine`](crate::runtime::engine::ScalarEngine) walks one
//! point at a time, this backend processes the dataset in cache blocks and
//! fans the blocks out over `std::thread::scope` workers spawned per call
//! (no rayon, no shared pool: the engine itself stays compatible with the
//! `!Send + !Sync` contract of the trait, and nested consumers like the
//! MapReduce simulator can cap the per-shard thread budget).
//!
//! Numerics contract, pinned by `rust/tests/engine_equivalence.rs`:
//!
//! * `update_min` / `update_min_block` / `sums_to_set` are **bit-identical**
//!   to the scalar oracle.  Per point the center fold is a left fold in the
//!   caller's order, each distance is evaluated with the exact same f64
//!   formulas as [`crate::core::metric`], and the cosine path feeds the
//!   squared norms precomputed at construction through
//!   [`cosine_angular_from_parts`] (same accumulation order, same value).
//!   Chunk boundaries and worker count therefore cannot change a single
//!   output bit — points are independent under all three operations.
//! * `pairwise_block` is the throughput path: Euclidean uses the expanded
//!   form `d^2 = |a|^2 + |b|^2 - 2<a,b>` over the precomputed squared
//!   norms, which turns the inner loop into a pure dot product.  Output is
//!   f32 and agrees with the oracle to ~1e-5 relative (cancellation near
//!   d = 0), which is why threshold-sensitive consumers (stream center
//!   separation, AMT acceptance) never read it for accept/reject decisions.

use anyhow::Result;

use crate::core::metric::{cosine_angular_from_parts, dot, euclidean};
use crate::core::{Dataset, Metric};
use crate::runtime::engine::DistanceEngine;

/// Points per cache sub-block: the center tile stays register/L1-resident
/// while `POINT_BLOCK` point rows stream through.
const POINT_BLOCK: usize = 1024;

/// Point-center pairs (or row-col pairs) per worker below which fan-out
/// does not pay for the thread spawns.
const MIN_PAIRS_PER_WORKER: usize = 8192;

/// Chunked, multi-threaded CPU distance engine.
///
/// Construct once per dataset ([`BatchEngine::for_dataset`]); like the
/// PJRT engine it precomputes per-dataset state (squared norms) and
/// asserts it is fed the same dataset on every call.
pub struct BatchEngine {
    metric: Metric,
    n: usize,
    threads: usize,
    /// Per-point squared L2 norms, accumulated in the same order as the
    /// scalar cosine kernel so the cosine fast path stays bit-identical.
    sqnorms: Vec<f64>,
}

impl BatchEngine {
    /// Engine for `ds` using every available core.
    pub fn for_dataset(ds: &Dataset) -> BatchEngine {
        let threads = std::thread::available_parallelism()
            .map(|t| t.get())
            .unwrap_or(1);
        Self::with_threads(ds, threads)
    }

    /// Engine for `ds` with an explicit worker cap (`1` = never spawn).
    /// Nested-parallel consumers (one engine per MapReduce shard) use this
    /// to divide the machine between shards.
    pub fn with_threads(ds: &Dataset, threads: usize) -> BatchEngine {
        let n = ds.n();
        let mut sqnorms = vec![0.0f64; n];
        for (i, sq) in sqnorms.iter_mut().enumerate() {
            let p = ds.point(i);
            *sq = dot(p, p);
        }
        BatchEngine {
            metric: ds.metric,
            n,
            threads: threads.max(1),
            sqnorms,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    fn check(&self, ds: &Dataset) {
        assert_eq!(ds.n(), self.n, "engine prepared for a different dataset");
        assert_eq!(ds.metric, self.metric, "engine prepared for a different metric");
    }

    /// Worker count for a call touching `pairs` point-center pairs.
    fn workers_for(&self, pairs: usize) -> usize {
        (pairs / MIN_PAIRS_PER_WORKER).clamp(1, self.threads)
    }

    /// Fold `centers` into the state chunk covering global points
    /// `base..base + mind.len()`.  Centers iterate inside each
    /// `POINT_BLOCK` sub-block (center rows hot in L1, point rows
    /// streaming); per point the fold order equals the caller's order.
    fn fold_chunk(
        &self,
        ds: &Dataset,
        centers: &[(usize, u32)],
        base: usize,
        mind: &mut [f32],
        arg: &mut [u32],
    ) {
        let mut start = 0;
        while start < mind.len() {
            let end = (start + POINT_BLOCK).min(mind.len());
            for &(c, id) in centers {
                let cp = ds.point(c);
                match self.metric {
                    Metric::Euclidean => {
                        for i in start..end {
                            let d = euclidean(ds.point(base + i), cp) as f32;
                            if d < mind[i] {
                                mind[i] = d;
                                arg[i] = id;
                            }
                        }
                    }
                    Metric::Cosine => {
                        let bb = self.sqnorms[c];
                        for i in start..end {
                            let p = ds.point(base + i);
                            let d = cosine_angular_from_parts(
                                dot(p, cp),
                                self.sqnorms[base + i],
                                bb,
                            ) as f32;
                            if d < mind[i] {
                                mind[i] = d;
                                arg[i] = id;
                            }
                        }
                    }
                }
            }
            start = end;
        }
    }

    fn fold(&self, ds: &Dataset, centers: &[(usize, u32)], mind: &mut [f32], arg: &mut [u32]) {
        self.check(ds);
        assert_eq!(mind.len(), self.n, "mind length != n");
        assert_eq!(arg.len(), self.n, "arg length != n");
        if centers.is_empty() || self.n == 0 {
            return;
        }
        let workers = self.workers_for(self.n.saturating_mul(centers.len()));
        if workers <= 1 {
            self.fold_chunk(ds, centers, 0, mind, arg);
            return;
        }
        let span = self.n.div_ceil(workers);
        std::thread::scope(|scope| {
            for (idx, (m, a)) in mind.chunks_mut(span).zip(arg.chunks_mut(span)).enumerate() {
                scope.spawn(move || self.fold_chunk(ds, centers, idx * span, m, a));
            }
        });
    }

    /// Sums worker: `out[slot] = sum_w d(cands[slot], w)` over `set`, with
    /// the exact oracle formulas and summation order.
    fn sums_chunk(&self, ds: &Dataset, cands: &[usize], set: &[usize], out: &mut [f64]) {
        for (slot, &v) in cands.iter().enumerate() {
            let vp = ds.point(v);
            let mut s = 0.0f64;
            match self.metric {
                Metric::Euclidean => {
                    for &w in set {
                        s += euclidean(vp, ds.point(w));
                    }
                }
                Metric::Cosine => {
                    let aa = self.sqnorms[v];
                    for &w in set {
                        s += cosine_angular_from_parts(dot(vp, ds.point(w)), aa, self.sqnorms[w]);
                    }
                }
            }
            out[slot] = s;
        }
    }

    /// Pairwise worker over a row chunk (`out` is the chunk's tile slice).
    fn pairwise_chunk(&self, ds: &Dataset, rows: &[usize], cols: &[usize], out: &mut [f32]) {
        let width = cols.len();
        for (r, &i) in rows.iter().enumerate() {
            let ip = ds.point(i);
            let aa = self.sqnorms[i];
            for (c, &j) in cols.iter().enumerate() {
                let ab = dot(ip, ds.point(j));
                let d = match self.metric {
                    Metric::Euclidean => (aa + self.sqnorms[j] - 2.0 * ab).max(0.0).sqrt(),
                    Metric::Cosine => cosine_angular_from_parts(ab, aa, self.sqnorms[j]),
                };
                out[r * width + c] = d as f32;
            }
        }
    }
}

impl DistanceEngine for BatchEngine {
    fn name(&self) -> &'static str {
        "batch"
    }

    fn update_min(
        &self,
        ds: &Dataset,
        center: usize,
        center_id: u32,
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        self.fold(ds, &[(center, center_id)], mind, arg);
        Ok(())
    }

    fn update_min_block(
        &self,
        ds: &Dataset,
        centers: &[(usize, u32)],
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        self.fold(ds, centers, mind, arg);
        Ok(())
    }

    fn pairwise_block(&self, ds: &Dataset, rows: &[usize], cols: &[usize]) -> Result<Vec<f32>> {
        self.check(ds);
        let width = cols.len();
        let mut out = vec![0.0f32; rows.len() * width];
        if rows.is_empty() || width == 0 {
            return Ok(out);
        }
        let workers = self.workers_for(rows.len().saturating_mul(width));
        if workers <= 1 {
            self.pairwise_chunk(ds, rows, cols, &mut out);
            return Ok(out);
        }
        let span = rows.len().div_ceil(workers);
        std::thread::scope(|scope| {
            for (row_chunk, out_chunk) in rows.chunks(span).zip(out.chunks_mut(span * width)) {
                scope.spawn(move || self.pairwise_chunk(ds, row_chunk, cols, out_chunk));
            }
        });
        Ok(out)
    }

    fn sums_to_set(&self, ds: &Dataset, candidates: &[usize], set: &[usize]) -> Result<Vec<f64>> {
        self.check(ds);
        let mut out = vec![0.0f64; candidates.len()];
        if candidates.is_empty() || set.is_empty() {
            return Ok(out);
        }
        let workers = self.workers_for(candidates.len().saturating_mul(set.len()));
        if workers <= 1 {
            self.sums_chunk(ds, candidates, set, &mut out);
            return Ok(out);
        }
        let span = candidates.len().div_ceil(workers);
        std::thread::scope(|scope| {
            for (cand_chunk, out_chunk) in candidates.chunks(span).zip(out.chunks_mut(span)) {
                scope.spawn(move || self.sums_chunk(ds, cand_chunk, set, out_chunk));
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::engine::ScalarEngine;

    #[test]
    fn fold_matches_scalar_small() {
        let ds = synth::uniform_cube(513, 3, 5);
        let batch = BatchEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let mut mb = vec![f32::INFINITY; 513];
        let mut ab = vec![u32::MAX; 513];
        let mut ms = mb.clone();
        let mut as_ = ab.clone();
        for (id, c) in [0usize, 100, 512].into_iter().enumerate() {
            batch.update_min(&ds, c, id as u32, &mut mb, &mut ab).unwrap();
            scalar.update_min(&ds, c, id as u32, &mut ms, &mut as_).unwrap();
        }
        assert_eq!(mb, ms);
        assert_eq!(ab, as_);
    }

    #[test]
    fn sums_and_pairwise_agree_with_oracle() {
        let ds = synth::wikisim(300, 2); // cosine metric
        let batch = BatchEngine::for_dataset(&ds);
        let cands: Vec<usize> = (0..300).collect();
        let set: Vec<usize> = vec![3, 77, 150, 299];
        let sums = batch.sums_to_set(&ds, &cands, &set).unwrap();
        for (i, &v) in cands.iter().enumerate() {
            let want: f64 = set.iter().map(|&w| ds.dist(v, w)).sum();
            assert_eq!(sums[i], want, "sums_to_set must be bit-identical");
        }
        let tile = batch.pairwise_block(&ds, &cands, &set).unwrap();
        for (r, &i) in cands.iter().enumerate() {
            for (c, &j) in set.iter().enumerate() {
                let want = ds.dist(i, j);
                let got = tile[r * set.len() + c] as f64;
                assert!((got - want).abs() <= 1e-5 * want.max(1e-3), "{got} vs {want}");
            }
        }
    }

    #[test]
    fn rejects_wrong_dataset() {
        let ds = synth::uniform_cube(64, 2, 1);
        let other = synth::uniform_cube(65, 2, 1);
        let batch = BatchEngine::for_dataset(&ds);
        let mut m = vec![f32::INFINITY; 65];
        let mut a = vec![u32::MAX; 65];
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            batch.update_min(&other, 0, 0, &mut m, &mut a).unwrap();
        }));
        assert!(res.is_err());
    }
}
