//! The distance-engine abstraction between the algorithms (L3) and the
//! compute backends.
//!
//! GMM and the streaming assignment only need one primitive: *fold a new
//! center into a running (min-dist, argmin) state* — exactly the
//! `gmm_update` AOT artifact.  Two implementations exist:
//!
//! * [`ScalarEngine`] — portable Rust loops (also the correctness oracle
//!   for the PJRT path);
//! * [`runtime::pjrt::PjrtEngine`](crate::runtime::pjrt::PjrtEngine) — runs
//!   the AOT-compiled Pallas kernels through the PJRT CPU client.

use anyhow::Result;

use crate::core::Dataset;

/// Backend for the O(n)-per-iteration GMM/streaming distance hot path.
///
/// Deliberately NOT `Send + Sync`: the PJRT client wraps raw C pointers.
/// Parallel consumers (the MapReduce simulator) construct one engine per
/// worker thread instead of sharing one.
pub trait DistanceEngine {
    /// Human-readable backend name (reports / bench CSV).
    fn name(&self) -> &'static str;

    /// Fold center `center` (dataset index, logical id `center_id`) into the
    /// running state: for every point `i`, if `d(i, center) < mind[i]` set
    /// `mind[i]` and `arg[i] = center_id`.
    fn update_min(
        &self,
        ds: &Dataset,
        center: usize,
        center_id: u32,
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()>;
}

/// Plain-Rust scalar backend.
#[derive(Default, Debug, Clone, Copy)]
pub struct ScalarEngine;

impl ScalarEngine {
    pub fn new() -> Self {
        ScalarEngine
    }
}

impl DistanceEngine for ScalarEngine {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn update_min(
        &self,
        ds: &Dataset,
        center: usize,
        center_id: u32,
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        let c = ds.point(center);
        for i in 0..ds.n() {
            let d = ds.metric.dist(ds.point(i), c) as f32;
            if d < mind[i] {
                mind[i] = d;
                arg[i] = center_id;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn scalar_update_min_folds() {
        let ds = synth::uniform_cube(64, 3, 1);
        let mut mind = vec![f32::INFINITY; 64];
        let mut arg = vec![u32::MAX; 64];
        let e = ScalarEngine::new();
        e.update_min(&ds, 0, 0, &mut mind, &mut arg).unwrap();
        assert!(mind.iter().all(|d| d.is_finite()));
        assert!(arg.iter().all(|&a| a == 0));
        assert_eq!(mind[0], 0.0);
        let before = mind.clone();
        e.update_min(&ds, 7, 1, &mut mind, &mut arg).unwrap();
        // monotone: folding another center can only decrease min-dists
        for i in 0..64 {
            assert!(mind[i] <= before[i]);
        }
        assert_eq!(arg[7], 1);
        assert_eq!(mind[7], 0.0);
    }
}
