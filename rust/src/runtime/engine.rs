//! The distance-engine abstraction between the algorithms (L3) and the
//! compute backends.
//!
//! The hot paths of the whole system are O(n)-per-round distance folds:
//! GMM/SeqCoreset fold a new center into a running (min-dist, argmin)
//! state, the streaming restructure re-assigns delegates across center
//! tiles, and AMT local search scans per-candidate distance *sums* to the
//! current solution.  The trait exposes all three shapes:
//!
//! * [`DistanceEngine::update_min`] / [`DistanceEngine::update_min_block`]
//!   — fold one / several centers into a (min-dist, argmin) state;
//! * [`DistanceEngine::pairwise_block`] — a row-major tile of pairwise
//!   distances;
//! * [`DistanceEngine::sums_to_set`] — per-candidate distance sums against
//!   a solution set.
//!
//! Three implementations exist:
//!
//! * [`ScalarEngine`] — portable point-at-a-time Rust loops, the
//!   correctness oracle every other backend is pinned against;
//! * [`runtime::batch::BatchEngine`](crate::runtime::batch::BatchEngine) —
//!   chunked, multi-threaded CPU backend (the default);
//! * `runtime::pjrt::PjrtEngine` (feature `pjrt`) — runs the AOT-compiled
//!   Pallas kernels through the PJRT CPU client.

use anyhow::Result;

use crate::core::Dataset;

/// Backend for the O(n)-per-iteration distance hot path.
///
/// Deliberately NOT `Send + Sync`: the PJRT client wraps raw C pointers.
/// Parallel consumers (the MapReduce simulator) construct one engine per
/// worker thread instead of sharing one; backends that want intra-call
/// parallelism (the batch engine) spawn scoped workers per call.
///
/// The default method bodies are the scalar reference semantics; backends
/// override them with batched kernels but must preserve the fold order:
/// per point, centers are folded left-to-right with a strict `<`, so ties
/// keep the earliest center.
pub trait DistanceEngine {
    /// Human-readable backend name (reports / bench CSV).
    fn name(&self) -> &'static str;

    /// Fold center `center` (dataset index, logical id `center_id`) into the
    /// running state: for every point `i`, if `d(i, center) < mind[i]` set
    /// `mind[i]` and `arg[i] = center_id`.
    fn update_min(
        &self,
        ds: &Dataset,
        center: usize,
        center_id: u32,
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()>;

    /// Fold several `(center, center_id)` pairs at once, in order.
    /// Equivalent to calling [`Self::update_min`] per pair, but backends
    /// get one traversal of the points for the whole tile.
    fn update_min_block(
        &self,
        ds: &Dataset,
        centers: &[(usize, u32)],
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        for &(c, id) in centers {
            self.update_min(ds, c, id, mind, arg)?;
        }
        Ok(())
    }

    /// Row-major `rows.len() x cols.len()` tile of pairwise distances
    /// (`out[r * cols.len() + c] = d(rows[r], cols[c])`), in f32 — the
    /// throughput representation shared with the PJRT artifacts.
    fn pairwise_block(&self, ds: &Dataset, rows: &[usize], cols: &[usize]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; rows.len() * cols.len()];
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                out[r * cols.len() + c] = ds.dist(i, j) as f32;
            }
        }
        Ok(out)
    }

    /// For every candidate `v`, the sum of distances to every member of
    /// `set` (members of `set` appearing in `candidates` include their own
    /// zero self-distance).  Kept in f64 because AMT swap acceptance
    /// compares against a `1e-12`-relative improvement threshold.
    fn sums_to_set(&self, ds: &Dataset, candidates: &[usize], set: &[usize]) -> Result<Vec<f64>> {
        Ok(candidates
            .iter()
            .map(|&v| set.iter().map(|&w| ds.dist(v, w)).sum())
            .collect())
    }
}

/// Plain-Rust scalar backend — the correctness oracle.
#[derive(Default, Debug, Clone, Copy)]
pub struct ScalarEngine;

impl ScalarEngine {
    pub fn new() -> Self {
        ScalarEngine
    }
}

impl DistanceEngine for ScalarEngine {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn update_min(
        &self,
        ds: &Dataset,
        center: usize,
        center_id: u32,
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        let c = ds.point(center);
        for i in 0..ds.n() {
            let d = ds.metric.dist(ds.point(i), c) as f32;
            if d < mind[i] {
                mind[i] = d;
                arg[i] = center_id;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn scalar_update_min_folds() {
        let ds = synth::uniform_cube(64, 3, 1);
        let mut mind = vec![f32::INFINITY; 64];
        let mut arg = vec![u32::MAX; 64];
        let e = ScalarEngine::new();
        e.update_min(&ds, 0, 0, &mut mind, &mut arg).unwrap();
        assert!(mind.iter().all(|d| d.is_finite()));
        assert!(arg.iter().all(|&a| a == 0));
        assert_eq!(mind[0], 0.0);
        let before = mind.clone();
        e.update_min(&ds, 7, 1, &mut mind, &mut arg).unwrap();
        // monotone: folding another center can only decrease min-dists
        for i in 0..64 {
            assert!(mind[i] <= before[i]);
        }
        assert_eq!(arg[7], 1);
        assert_eq!(mind[7], 0.0);
    }

    #[test]
    fn default_update_min_block_matches_sequential_folds() {
        let ds = synth::uniform_cube(100, 3, 2);
        let e = ScalarEngine::new();
        let centers: Vec<(usize, u32)> = vec![(0, 0), (31, 1), (99, 2)];
        let mut mind_b = vec![f32::INFINITY; 100];
        let mut arg_b = vec![u32::MAX; 100];
        e.update_min_block(&ds, &centers, &mut mind_b, &mut arg_b).unwrap();
        let mut mind_s = vec![f32::INFINITY; 100];
        let mut arg_s = vec![u32::MAX; 100];
        for &(c, id) in &centers {
            e.update_min(&ds, c, id, &mut mind_s, &mut arg_s).unwrap();
        }
        assert_eq!(mind_b, mind_s);
        assert_eq!(arg_b, arg_s);
    }

    #[test]
    fn default_pairwise_and_sums_match_dataset_dist() {
        let ds = synth::uniform_cube(40, 2, 3);
        let e = ScalarEngine::new();
        let rows: Vec<usize> = vec![0, 5, 39];
        let cols: Vec<usize> = vec![1, 2, 3, 4];
        let tile = e.pairwise_block(&ds, &rows, &cols).unwrap();
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                assert_eq!(tile[r * cols.len() + c], ds.dist(i, j) as f32);
            }
        }
        let sums = e.sums_to_set(&ds, &rows, &cols).unwrap();
        for (r, &i) in rows.iter().enumerate() {
            let want: f64 = cols.iter().map(|&j| ds.dist(i, j)).sum();
            assert!((sums[r] - want).abs() < 1e-12);
        }
    }
}
