//! The distance-engine abstraction between the algorithms (L3) and the
//! compute backends.
//!
//! The hot paths of the whole system are O(n)-per-round distance folds:
//! GMM/SeqCoreset fold a new center into a running (min-dist, argmin)
//! state, the streaming restructure re-assigns delegates across center
//! tiles, and AMT local search scans per-candidate distance *sums* to the
//! current solution.  The trait exposes all three shapes:
//!
//! * [`DistanceEngine::update_min`] / [`DistanceEngine::update_min_block`]
//!   — fold one / several centers into a (min-dist, argmin) state;
//! * [`DistanceEngine::pairwise_block`] — a row-major tile of pairwise
//!   distances;
//! * [`DistanceEngine::sums_to_set`] — per-candidate distance sums against
//!   a solution set;
//! * [`DistanceEngine::dists_to_points`] — a narrow exact-f64 column block
//!   against a handful of targets, the delta pass of the incremental AMT
//!   local search (each accepted swap re-reads one or two columns instead
//!   of re-scanning all O(n k) candidate sums).
//!
//! The diversity evaluators (`crate::diversity::Evaluator`) are the fourth
//! consumer: they materialize objective submatrices through
//! [`DistanceEngine::pairwise_block`] and batched sum/star scans through
//! [`DistanceEngine::sums_to_set`], so every Table-1 objective inherits
//! whatever backend the pipeline selected.
//!
//! Three implementations exist:
//!
//! * [`ScalarEngine`] — portable point-at-a-time Rust loops, the
//!   correctness oracle every other backend is pinned against (it also
//!   counts distance evaluations, which regression tests use to pin the
//!   amount of distance work a code path performs);
//! * [`runtime::batch::BatchEngine`](crate::runtime::batch::BatchEngine) —
//!   chunked, multi-threaded CPU backend (the default);
//! * `runtime::pjrt::PjrtEngine` (feature `pjrt`) — runs the AOT-compiled
//!   Pallas kernels through the PJRT CPU client.

use std::cell::Cell;

use anyhow::Result;

use crate::core::Dataset;

/// Backend for the O(n)-per-iteration distance hot path.
///
/// Deliberately NOT `Send + Sync`: the PJRT client wraps raw C pointers.
/// Parallel consumers (the MapReduce simulator) construct one engine per
/// worker thread instead of sharing one; backends that want intra-call
/// parallelism (the batch engine) spawn scoped workers per call.
///
/// The default method bodies are the scalar reference semantics; backends
/// override them with batched kernels but must preserve the fold order:
/// per point, centers are folded left-to-right with a strict `<`, so ties
/// keep the earliest center.
pub trait DistanceEngine {
    /// Human-readable backend name (reports / bench CSV).
    fn name(&self) -> &'static str;

    /// Fold center `center` (dataset index, logical id `center_id`) into the
    /// running state: for every point `i`, if `d(i, center) < mind[i]` set
    /// `mind[i]` and `arg[i] = center_id`.
    fn update_min(
        &self,
        ds: &Dataset,
        center: usize,
        center_id: u32,
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()>;

    /// Fold several `(center, center_id)` pairs at once, in order.
    /// Equivalent to calling [`Self::update_min`] per pair, but backends
    /// get one traversal of the points for the whole tile.
    fn update_min_block(
        &self,
        ds: &Dataset,
        centers: &[(usize, u32)],
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        for &(c, id) in centers {
            self.update_min(ds, c, id, mind, arg)?;
        }
        Ok(())
    }

    /// Row-major `rows.len() x cols.len()` tile of pairwise distances
    /// (`out[r * cols.len() + c] = d(rows[r], cols[c])`), in f32 — the
    /// throughput representation shared with the PJRT artifacts.
    ///
    /// Contract for the CPU backends, on which the diversity evaluators'
    /// engine-independence rests (pinned by
    /// `rust/tests/engine_equivalence.rs`):
    ///
    /// * every off-diagonal entry must equal `ds.dist(i, j) as f32`
    ///   **bit for bit**;
    /// * self-pairs (`rows[r] == cols[c]`) are **exactly 0** — the metric
    ///   identity is pinned rather than trusting fp self-noise (the
    ///   angular cosine metric evaluates `d(x, x)` at ~1e-8);
    /// * when `rows` and `cols` are the *same slice* (the symmetric
    ///   `k x k` case the evaluators produce), backends may — and the CPU
    ///   backends do — compute only the strict upper triangle and mirror
    ///   it: `d` is bit-symmetric under both metrics, so the output is
    ///   unchanged while the distance work halves.
    ///
    /// The feature-gated PJRT backend remains tolerance-validated instead.
    fn pairwise_block(&self, ds: &Dataset, rows: &[usize], cols: &[usize]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; rows.len() * cols.len()];
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                if i != j {
                    out[r * cols.len() + c] = ds.dist(i, j) as f32;
                }
            }
        }
        Ok(out)
    }

    /// For every candidate `v`, the sum of distances to every member of
    /// `set`.  Self-pairs (a member of `set` appearing as the candidate)
    /// are excluded — `d(v, v)` is exactly zero by definition, never the
    /// metric's fp self-noise — which makes the member sums exactly the
    /// star weights of the diversity layer.  Kept in f64 because AMT swap
    /// acceptance compares against a `1e-12`-relative improvement
    /// threshold.
    fn sums_to_set(&self, ds: &Dataset, candidates: &[usize], set: &[usize]) -> Result<Vec<f64>> {
        Ok(candidates
            .iter()
            .map(|&v| {
                set.iter()
                    .filter(|&&w| w != v)
                    .map(|&w| ds.dist(v, w))
                    .sum()
            })
            .collect())
    }

    /// Row-major `ids.len() x targets.len()` block of **exact f64**
    /// distances (`out[r * targets.len() + c] = d(ids[r], targets[c])`) —
    /// the narrow-column companion of [`Self::sums_to_set`] that powers
    /// the incremental AMT update: after an accepted swap (`u` out, `v`
    /// in) every candidate's solution-sum changes by exactly
    /// `d(c, v) - d(c, u)`, one one- or two-column pass instead of a full
    /// O(n k) re-scan.
    ///
    /// Contract for the CPU backends (pinned by
    /// `rust/tests/engine_equivalence.rs`):
    ///
    /// * every off-diagonal entry must equal `ds.dist(i, j)` **bit for
    ///   bit** (f64, not the f32 of [`Self::pairwise_block`] — the deltas
    ///   feed f64 sums compared against a `1e-12`-relative threshold);
    /// * self-pairs (`ids[r] == targets[c]`) are **exactly 0**, matching
    ///   the self-pair exclusion of [`Self::sums_to_set`]: summing a row
    ///   of this block in target order is bit-identical to one
    ///   `sums_to_set` entry (`x + 0.0 == x` for the non-negative partial
    ///   sums).
    ///
    /// The feature-gated PJRT backend is tolerance-level instead, like its
    /// `sums_to_set`.
    fn dists_to_points(&self, ds: &Dataset, ids: &[usize], targets: &[usize]) -> Result<Vec<f64>> {
        let width = targets.len();
        let mut out = vec![0.0f64; ids.len() * width];
        for (r, &i) in ids.iter().enumerate() {
            for (c, &j) in targets.iter().enumerate() {
                if i != j {
                    out[r * width + c] = ds.dist(i, j);
                }
            }
        }
        Ok(out)
    }
}

/// True when `a` and `b` are literally the same slice — the symmetric
/// tile case [`DistanceEngine::pairwise_block`] backends fast-path.
pub(crate) fn same_index_slice(a: &[usize], b: &[usize]) -> bool {
    a.len() == b.len() && std::ptr::eq(a.as_ptr(), b.as_ptr())
}

// ---- shared CPU-backend scaffolding ----------------------------------
//
// The batch and simd backends differ only in their inner distance
// kernels; the cache blocking, worker gating, scoped fan-out shapes, and
// the symmetric-tile mirror live here so the two backends cannot drift
// apart structurally (outputs are element-wise independent under every
// fan-out below, so chunk boundaries and worker count can never change a
// bit).

/// Points per cache sub-block in the CPU backends' fold path: the center
/// tile stays register/L1-resident while a block of point rows streams.
pub(crate) const POINT_BLOCK: usize = 1024;

/// Point-pair count per worker below which thread fan-out does not pay
/// for the scoped spawns.
pub(crate) const MIN_PAIRS_PER_WORKER: usize = 8192;

/// Worker count for a call touching `pairs` point pairs under a
/// `threads` cap.
pub(crate) fn workers_for(threads: usize, pairs: usize) -> usize {
    (pairs / MIN_PAIRS_PER_WORKER).clamp(1, threads.max(1))
}

/// Fan a row-major `rows.len() x width` output over scoped workers:
/// `work(row_chunk, out_chunk)` gets the id chunk and its matching
/// output slice.  `workers <= 1` runs inline (no spawn).
pub(crate) fn fanout_rows<T, F>(workers: usize, rows: &[usize], width: usize, out: &mut [T], work: F)
where
    T: Send,
    F: Fn(&[usize], &mut [T]) + Sync,
{
    if workers <= 1 {
        work(rows, out);
        return;
    }
    let span = rows.len().div_ceil(workers);
    let work = &work;
    std::thread::scope(|scope| {
        for (row_chunk, out_chunk) in rows.chunks(span).zip(out.chunks_mut(span * width)) {
            scope.spawn(move || work(row_chunk, out_chunk));
        }
    });
}

/// Fan an `n_rows x width` output over scoped workers by row *position*:
/// `work(base_row, out_chunk)` — for kernels that need the global row
/// index rather than an id list (the symmetric upper-triangle tile).
pub(crate) fn fanout_row_positions<T, F>(
    workers: usize,
    n_rows: usize,
    width: usize,
    out: &mut [T],
    work: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if workers <= 1 {
        work(0, out);
        return;
    }
    let span = n_rows.div_ceil(workers);
    let work = &work;
    std::thread::scope(|scope| {
        for (idx, out_chunk) in out.chunks_mut(span * width).enumerate() {
            scope.spawn(move || work(idx * span, out_chunk));
        }
    });
}

/// Fan the `(mind, arg)` fold state over scoped workers:
/// `work(base_point, mind_chunk, arg_chunk)`.
pub(crate) fn fanout_fold_state<F>(workers: usize, mind: &mut [f32], arg: &mut [u32], work: F)
where
    F: Fn(usize, &mut [f32], &mut [u32]) + Sync,
{
    if workers <= 1 {
        work(0, mind, arg);
        return;
    }
    let span = mind.len().div_ceil(workers);
    let work = &work;
    std::thread::scope(|scope| {
        for (idx, (m, a)) in mind.chunks_mut(span).zip(arg.chunks_mut(span)).enumerate() {
            scope.spawn(move || work(idx * span, m, a));
        }
    });
}

/// Mirror the strict upper triangle of a row-major `k x k` tile into the
/// lower triangle (the second half of the symmetric-tile fast path).
pub(crate) fn mirror_upper_triangle(out: &mut [f32], k: usize) {
    for a in 1..k {
        for b in 0..a {
            out[a * k + b] = out[b * k + a];
        }
    }
}

/// Plain-Rust scalar backend — the correctness oracle.
///
/// Each instance carries a counter of individual distance evaluations
/// ([`ScalarEngine::dist_evals`]).  Regression tests use it to pin the
/// *amount* of distance work a code path performs — e.g. that the
/// diversity evaluator builds its submatrix once and reuses it instead of
/// re-walking `Dataset::dist` per objective or per star center.  The
/// counter lives in a `Cell`, so counting needs no `&mut`: the engine
/// stays usable behind the shared `&dyn DistanceEngine` the algorithms
/// pass around.
#[derive(Default, Debug, Clone)]
pub struct ScalarEngine {
    dist_evals: Cell<u64>,
}

impl ScalarEngine {
    pub fn new() -> Self {
        ScalarEngine::default()
    }

    /// Individual distance evaluations performed through this instance
    /// since construction or the last [`ScalarEngine::reset_dist_evals`].
    pub fn dist_evals(&self) -> u64 {
        self.dist_evals.get()
    }

    pub fn reset_dist_evals(&self) {
        self.dist_evals.set(0);
    }

    fn count(&self, evals: usize) {
        self.dist_evals.set(self.dist_evals.get() + evals as u64);
    }
}

impl DistanceEngine for ScalarEngine {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn update_min(
        &self,
        ds: &Dataset,
        center: usize,
        center_id: u32,
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        self.count(ds.n());
        let c = ds.point(center);
        for i in 0..ds.n() {
            let d = ds.metric.dist(ds.point(i), c) as f32;
            if d < mind[i] {
                mind[i] = d;
                arg[i] = center_id;
            }
        }
        Ok(())
    }

    // The two batched shapes repeat the trait's default (oracle)
    // semantics — overridden so the instance counter sees the distances
    // actually computed, and to take the symmetric-tile fast path.

    fn pairwise_block(&self, ds: &Dataset, rows: &[usize], cols: &[usize]) -> Result<Vec<f32>> {
        let width = cols.len();
        let mut out = vec![0.0f32; rows.len() * width];
        if same_index_slice(rows, cols) {
            // symmetric k x k tile: strict upper triangle + mirror — the
            // pre-engine `distance_submatrix` work of k(k-1)/2 distances
            let k = rows.len();
            self.count(k * k.saturating_sub(1) / 2);
            for a in 0..k {
                for b in (a + 1)..k {
                    let d = ds.dist(rows[a], rows[b]) as f32;
                    out[a * k + b] = d;
                    out[b * k + a] = d;
                }
            }
            return Ok(out);
        }
        let mut evals = 0usize;
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                if i != j {
                    evals += 1;
                    out[r * width + c] = ds.dist(i, j) as f32;
                }
            }
        }
        self.count(evals);
        Ok(out)
    }

    fn sums_to_set(&self, ds: &Dataset, candidates: &[usize], set: &[usize]) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(candidates.len());
        let mut evals = 0usize;
        for &v in candidates {
            let mut s = 0.0f64;
            for &w in set {
                if w != v {
                    evals += 1;
                    s += ds.dist(v, w);
                }
            }
            out.push(s);
        }
        self.count(evals);
        Ok(out)
    }

    fn dists_to_points(&self, ds: &Dataset, ids: &[usize], targets: &[usize]) -> Result<Vec<f64>> {
        let width = targets.len();
        let mut out = vec![0.0f64; ids.len() * width];
        let mut evals = 0usize;
        for (r, &i) in ids.iter().enumerate() {
            for (c, &j) in targets.iter().enumerate() {
                if i != j {
                    evals += 1;
                    out[r * width + c] = ds.dist(i, j);
                }
            }
        }
        self.count(evals);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn scalar_update_min_folds() {
        let ds = synth::uniform_cube(64, 3, 1);
        let mut mind = vec![f32::INFINITY; 64];
        let mut arg = vec![u32::MAX; 64];
        let e = ScalarEngine::new();
        e.update_min(&ds, 0, 0, &mut mind, &mut arg).unwrap();
        assert!(mind.iter().all(|d| d.is_finite()));
        assert!(arg.iter().all(|&a| a == 0));
        assert_eq!(mind[0], 0.0);
        let before = mind.clone();
        e.update_min(&ds, 7, 1, &mut mind, &mut arg).unwrap();
        // monotone: folding another center can only decrease min-dists
        for i in 0..64 {
            assert!(mind[i] <= before[i]);
        }
        assert_eq!(arg[7], 1);
        assert_eq!(mind[7], 0.0);
    }

    #[test]
    fn default_update_min_block_matches_sequential_folds() {
        let ds = synth::uniform_cube(100, 3, 2);
        let e = ScalarEngine::new();
        let centers: Vec<(usize, u32)> = vec![(0, 0), (31, 1), (99, 2)];
        let mut mind_b = vec![f32::INFINITY; 100];
        let mut arg_b = vec![u32::MAX; 100];
        e.update_min_block(&ds, &centers, &mut mind_b, &mut arg_b).unwrap();
        let mut mind_s = vec![f32::INFINITY; 100];
        let mut arg_s = vec![u32::MAX; 100];
        for &(c, id) in &centers {
            e.update_min(&ds, c, id, &mut mind_s, &mut arg_s).unwrap();
        }
        assert_eq!(mind_b, mind_s);
        assert_eq!(arg_b, arg_s);
    }

    #[test]
    fn default_pairwise_and_sums_match_dataset_dist() {
        let ds = synth::uniform_cube(40, 2, 3);
        let e = ScalarEngine::new();
        let rows: Vec<usize> = vec![0, 5, 39];
        let cols: Vec<usize> = vec![1, 2, 3, 4];
        let tile = e.pairwise_block(&ds, &rows, &cols).unwrap();
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                assert_eq!(tile[r * cols.len() + c], ds.dist(i, j) as f32);
            }
        }
        let sums = e.sums_to_set(&ds, &rows, &cols).unwrap();
        for (r, &i) in rows.iter().enumerate() {
            let want: f64 = cols.iter().map(|&j| ds.dist(i, j)).sum();
            assert!((sums[r] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn scalar_engine_counts_distance_evaluations() {
        let ds = synth::uniform_cube(50, 2, 4);
        let e = ScalarEngine::new();
        assert_eq!(e.dist_evals(), 0);
        let mut mind = vec![f32::INFINITY; 50];
        let mut arg = vec![u32::MAX; 50];
        e.update_min(&ds, 0, 0, &mut mind, &mut arg).unwrap();
        assert_eq!(e.dist_evals(), 50);
        e.pairwise_block(&ds, &[0, 1, 2], &[3, 4]).unwrap();
        assert_eq!(e.dist_evals(), 50 + 6);
        e.sums_to_set(&ds, &[0, 1], &[2, 3, 4]).unwrap();
        assert_eq!(e.dist_evals(), 50 + 6 + 6);
        e.reset_dist_evals();
        // dists_to_points counts entries minus self-pairs
        e.dists_to_points(&ds, &[0, 1], &[1, 2, 3]).unwrap();
        assert_eq!(e.dist_evals(), 5);
        e.reset_dist_evals();
        // symmetric k x k tile costs only the strict upper triangle
        let set = [0usize, 1, 2, 3];
        e.pairwise_block(&ds, &set, &set).unwrap();
        assert_eq!(e.dist_evals(), 6);
        // member self-pairs are excluded from the sums
        e.reset_dist_evals();
        e.sums_to_set(&ds, &[0, 1], &[0, 1, 2]).unwrap();
        assert_eq!(e.dist_evals(), 4);
    }

    #[test]
    fn self_pairs_are_exactly_zero() {
        // wikisim is cosine, whose raw d(x, x) carries ~1e-8 fp noise —
        // the engine contract pins self-pairs (and the symmetric-tile
        // diagonal) to a true zero
        let ds = synth::wikisim(30, 5);
        let e = ScalarEngine::new();
        let set: Vec<usize> = (0..10).collect();
        let tile = e.pairwise_block(&ds, &set, &set).unwrap();
        for i in 0..10 {
            assert_eq!(tile[i * 10 + i], 0.0);
        }
        // rectangular call with overlapping indices: same guarantee.
        // rows [3, 4] x cols [4, 5] -> [d(3,4), d(3,5), d(4,4), d(4,5)]
        let tile = e.pairwise_block(&ds, &[3, 4], &[4, 5]).unwrap();
        assert_eq!(tile[2], 0.0, "self-pair d(4,4) must be a true zero");
        assert!(tile[0] > 0.0);
        let sums = e.sums_to_set(&ds, &[4], &[3, 4, 5]).unwrap();
        let want = ds.dist(4, 3) + ds.dist(4, 5); // no self term
        assert!((sums[0] - want).abs() < 1e-12);
    }

    #[test]
    fn dists_to_points_matches_dataset_dist_with_zero_self_pairs() {
        // cosine so the raw d(x, x) would carry fp noise without the pin
        let ds = synth::wikisim(30, 5);
        let e = ScalarEngine::new();
        let ids: Vec<usize> = vec![0, 4, 7, 4]; // duplicate id allowed
        let targets: Vec<usize> = vec![4, 9];
        let block = e.dists_to_points(&ds, &ids, &targets).unwrap();
        for (r, &i) in ids.iter().enumerate() {
            for (c, &j) in targets.iter().enumerate() {
                let want = if i == j { 0.0 } else { ds.dist(i, j) };
                assert_eq!(block[r * targets.len() + c], want, "entry ({i},{j})");
            }
        }
        assert_eq!(block[2], 0.0, "self-pair d(4,4) must be a true zero"); // row 1, col 0
        assert_eq!(block[6], 0.0, "duplicate id keeps the self-pair pin"); // row 3, col 0
    }

    #[test]
    fn dists_to_points_row_sums_equal_sums_to_set_bitwise() {
        // the re-anchor contract of the incremental AMT path: summing a
        // block row in target order reproduces sums_to_set exactly (the
        // pinned 0.0 self entries are additive no-ops)
        let ds = synth::wikisim(40, 6);
        let e = ScalarEngine::new();
        let ids: Vec<usize> = (0..40).collect();
        let set: Vec<usize> = vec![3, 11, 17, 25, 39];
        let block = e.dists_to_points(&ds, &ids, &set).unwrap();
        let sums = e.sums_to_set(&ds, &ids, &set).unwrap();
        for (r, &want) in sums.iter().enumerate() {
            let resum: f64 = block[r * set.len()..(r + 1) * set.len()].iter().sum();
            assert!(
                resum.to_bits() == want.to_bits(),
                "row {r}: resum {resum} != sums_to_set {want}"
            );
        }
    }
}
