//! PJRT execution of the AOT-compiled Pallas distance kernels.
//!
//! `PjrtEngine` loads `artifacts/*.hlo.txt` (HLO *text* — see
//! `python/compile/aot.py` for why not serialized protos), compiles each
//! once on the CPU PJRT client, and serves the [`DistanceEngine`] hot-path
//! primitive plus batch helpers (`assign_all`, `pairwise_block`).
//!
//! Padding protocol (mirrors `kernels/distance.py`): the feature dim is
//! zero-padded to the artifact dim, point blocks are padded to `NP` rows
//! (garbage rows ignored on readback), and center tiles are masked via the
//! `n_centers` operand so sentinel rows never win the argmin.

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use crate::core::{Dataset, Metric};
use crate::runtime::engine::DistanceEngine;
use crate::runtime::shapes::{padded_dim, Manifest, NP, TC};

/// Distance engine backed by the AOT Pallas kernels.
pub struct PjrtEngine {
    client: PjRtClient,
    update_exec: PjRtLoadedExecutable,
    assign_exec: PjRtLoadedExecutable,
    pairwise_exec: PjRtLoadedExecutable,
    metric: Metric,
    /// Padded feature dim (one of `shapes::DIMS`).
    d: usize,
    /// Dataset row count the padded buffer was prepared for.
    n: usize,
    /// Device-resident point chunks (one `NP x d` buffer per chunk),
    /// uploaded once at construction — the §Perf fix that removes the
    /// ~1 MB host->device literal copy from every `update_min` call.
    point_buffers: Vec<xla::PjRtBuffer>,
}

impl PjrtEngine {
    /// Load + compile the artifacts that match `ds` (metric + padded dim)
    /// and pre-pad its coordinates.
    pub fn for_dataset(manifest: &Manifest, ds: &Dataset) -> Result<PjrtEngine> {
        let d = padded_dim(ds.dim)
            .with_context(|| format!("dataset dim {} exceeds artifact dims", ds.dim))?;
        let client = PjRtClient::cpu().context("create PJRT CPU client")?;
        let load = |kernel: &str| -> Result<PjRtLoadedExecutable> {
            let path = manifest.entry_path(kernel, ds.metric, d)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compile {kernel}"))
        };
        let update_exec = load("gmm_update")?;
        let assign_exec = load("gmm_assign")?;
        let pairwise_exec = load("pairwise")?;

        let n = ds.n();
        let rows = n.div_ceil(NP).max(1) * NP;
        let mut padded = vec![0.0f32; rows * d];
        for i in 0..n {
            padded[i * d..i * d + ds.dim].copy_from_slice(ds.point(i));
        }
        // upload every point chunk to the device once
        let mut point_buffers = Vec::with_capacity(rows / NP);
        for chunk_start in (0..rows).step_by(NP) {
            let chunk = &padded[chunk_start * d..(chunk_start + NP) * d];
            point_buffers.push(client.buffer_from_host_buffer(chunk, &[NP, d], None)?);
        }
        Ok(PjrtEngine {
            client,
            update_exec,
            assign_exec,
            pairwise_exec,
            metric: ds.metric,
            d,
            n,
            point_buffers,
        })
    }

    pub fn padded_dim(&self) -> usize {
        self.d
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn check_dataset(&self, ds: &Dataset) {
        assert_eq!(ds.n(), self.n, "engine prepared for a different dataset");
        assert_eq!(ds.metric, self.metric);
    }

    fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        };
        Ok(Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            dims,
            bytes,
        )?)
    }

    /// Pad an arbitrary row set into a `rows x d` f32 block.
    fn pad_rows(&self, ds: &Dataset, rows: &[usize], out_rows: usize) -> Vec<f32> {
        let mut buf = vec![0.0f32; out_rows * self.d];
        for (slot, &i) in rows.iter().enumerate() {
            buf[slot * self.d..slot * self.d + ds.dim].copy_from_slice(ds.point(i));
        }
        buf
    }

    /// One-shot assignment of every point against `centers` (<= TC per
    /// inner call; more centers are folded tile by tile).  Returns
    /// (min-dist, argmin-position) per point — the `gmm_assign` artifact.
    pub fn assign_all(&self, ds: &Dataset, centers: &[usize]) -> Result<(Vec<f32>, Vec<u32>)> {
        self.check_dataset(ds);
        let mut mind = vec![f32::INFINITY; self.n];
        let mut arg = vec![u32::MAX; self.n];
        for (tile_idx, tile) in centers.chunks(TC).enumerate() {
            let ctile = self.pad_rows(ds, tile, TC);
            let ncdev = self
                .client
                .buffer_from_host_buffer(&[tile.len() as i32], &[1, 1], None)?;
            let cdev = self
                .client
                .buffer_from_host_buffer(&ctile, &[TC, self.d], None)?;
            for (chunk_idx, chunk_start) in (0..self.n).step_by(NP).enumerate() {
                let chunk_rows = (self.n - chunk_start).min(NP);
                let result = self.assign_exec.execute_b(&[
                    &self.point_buffers[chunk_idx],
                    &cdev,
                    &ncdev,
                ])?[0][0]
                    .to_literal_sync()?;
                let (dmin_l, amin_l) = result.to_tuple2()?;
                let dmin: Vec<f32> = dmin_l.to_vec()?;
                let amin: Vec<i32> = amin_l.to_vec()?;
                for r in 0..chunk_rows {
                    let i = chunk_start + r;
                    if dmin[r] < mind[i] {
                        mind[i] = dmin[r];
                        arg[i] = (tile_idx * TC + amin[r] as usize) as u32;
                    }
                }
            }
        }
        Ok((mind, arg))
    }

    /// Distance block between `rows_a` and `rows_b` (`rows_b.len() <= TC`),
    /// row-major `rows_a.len() x rows_b.len()` — the `pairwise` artifact.
    pub fn pairwise_block(
        &self,
        ds: &Dataset,
        rows_a: &[usize],
        rows_b: &[usize],
    ) -> Result<Vec<f32>> {
        self.check_dataset(ds);
        assert!(rows_b.len() <= TC, "pairwise_block: cols > TC");
        let btile = self.pad_rows(ds, rows_b, TC);
        let blit = Self::lit_f32(&btile, &[TC, self.d])?;
        let mut out = vec![0.0f32; rows_a.len() * rows_b.len()];
        for (chunk_idx, chunk) in rows_a.chunks(NP).enumerate() {
            let atile = self.pad_rows(ds, chunk, NP);
            let alit = Self::lit_f32(&atile, &[NP, self.d])?;
            let result = self.pairwise_exec.execute::<Literal>(&[alit, blit.clone()])?[0][0]
                .to_literal_sync()?;
            let tile = result.to_tuple1()?;
            let vals: Vec<f32> = tile.to_vec()?;
            for (r, _) in chunk.iter().enumerate() {
                let dst = (chunk_idx * NP + r) * rows_b.len();
                for (c, _) in rows_b.iter().enumerate() {
                    out[dst + c] = vals[r * TC + c];
                }
            }
        }
        Ok(out)
    }
}

impl DistanceEngine for PjrtEngine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// Batched fold: one fresh [`PjrtEngine::assign_all`] pass over the
    /// new centers (it already tiles by `TC` and chunks by `NP`), merged
    /// into the running state with the tile positions mapped back to the
    /// callers' logical center ids.
    fn update_min_block(
        &self,
        ds: &Dataset,
        centers: &[(usize, u32)],
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        if centers.is_empty() {
            return Ok(());
        }
        let rows: Vec<usize> = centers.iter().map(|&(c, _)| c).collect();
        let (tmind, targ) = self.assign_all(ds, &rows)?;
        for i in 0..self.n {
            if tmind[i] < mind[i] {
                mind[i] = tmind[i];
                arg[i] = centers[targ[i] as usize].1;
            }
        }
        Ok(())
    }

    /// Tile of pairwise distances via the `pairwise` artifact, stitching
    /// column tiles of `TC` when `cols` exceeds one artifact call.
    fn pairwise_block(&self, ds: &Dataset, rows: &[usize], cols: &[usize]) -> Result<Vec<f32>> {
        let width = cols.len();
        let mut out = vec![0.0f32; rows.len() * width];
        for (tile_idx, ctile) in cols.chunks(TC).enumerate() {
            let t = PjrtEngine::pairwise_block(self, ds, rows, ctile)?;
            for r in 0..rows.len() {
                let dst = r * width + tile_idx * TC;
                out[dst..dst + ctile.len()]
                    .copy_from_slice(&t[r * ctile.len()..(r + 1) * ctile.len()]);
            }
        }
        Ok(out)
    }

    /// Per-candidate distance sums via the `pairwise` artifact: one tile
    /// per `TC` solution members, accumulated in f64 on the host.
    ///
    /// Documented exemption from the trait's f64-exactness expectation:
    /// the artifact computes f32 distances on-device, so the sums carry
    /// ~1e-7-relative noise per term.  AMT swap trajectories under this
    /// backend may therefore diverge from the scalar/batch oracle near
    /// zero-improvement ties (each accepted swap still strictly improves
    /// the f32-observed objective); `tests/runtime_numerics.rs` pins the
    /// backend at tolerance, not bit-exactness, for exactly this reason.
    fn sums_to_set(&self, ds: &Dataset, candidates: &[usize], set: &[usize]) -> Result<Vec<f64>> {
        let mut out = vec![0.0f64; candidates.len()];
        for ctile in set.chunks(TC) {
            let t = PjrtEngine::pairwise_block(self, ds, candidates, ctile)?;
            for (r, acc) in out.iter_mut().enumerate() {
                for c in 0..ctile.len() {
                    // honor the trait's self-pair exclusion on the host:
                    // the artifact's d(v,v) is fp noise (expanded-form
                    // cancellation), never exactly zero
                    if candidates[r] != ctile[c] {
                        *acc += t[r * ctile.len() + c] as f64;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Narrow column block via the `pairwise` artifact (targets always fit
    /// one `TC` tile on the AMT delta path), upcast to f64 on the host.
    ///
    /// Same documented exemption as [`DistanceEngine::sums_to_set`] above:
    /// the artifact computes f32 distances, so the columns — and the
    /// incremental AMT deltas built from them — carry ~1e-7-relative
    /// noise; self-pairs are still pinned to exactly zero host-side.
    fn dists_to_points(&self, ds: &Dataset, ids: &[usize], targets: &[usize]) -> Result<Vec<f64>> {
        let width = targets.len();
        let mut out = vec![0.0f64; ids.len() * width];
        for (tile_idx, ttile) in targets.chunks(TC).enumerate() {
            let t = PjrtEngine::pairwise_block(self, ds, ids, ttile)?;
            for (r, &i) in ids.iter().enumerate() {
                let dst = r * width + tile_idx * TC;
                for (c, &j) in ttile.iter().enumerate() {
                    if i != j {
                        out[dst + c] = t[r * ttile.len() + c] as f64;
                    }
                }
            }
        }
        Ok(out)
    }

    fn update_min(
        &self,
        ds: &Dataset,
        center: usize,
        center_id: u32,
        mind: &mut [f32],
        arg: &mut [u32],
    ) -> Result<()> {
        self.check_dataset(ds);
        let mut cbuf = vec![0.0f32; self.d];
        cbuf[..ds.dim].copy_from_slice(ds.point(center));
        let cdev = self.client.buffer_from_host_buffer(&cbuf, &[1, self.d], None)?;
        let idev = self
            .client
            .buffer_from_host_buffer(&[center_id as i32], &[1, 1], None)?;
        let mut dstate = vec![f32::INFINITY; NP];
        let mut astate = vec![0i32; NP];
        for (chunk_idx, chunk_start) in (0..self.n).step_by(NP).enumerate() {
            let chunk_rows = (self.n - chunk_start).min(NP);
            // running state for this chunk, padded to NP
            dstate[..chunk_rows].copy_from_slice(&mind[chunk_start..chunk_start + chunk_rows]);
            dstate[chunk_rows..].fill(f32::INFINITY);
            for r in 0..chunk_rows {
                astate[r] = arg[chunk_start + r] as i32;
            }
            let ddev = self.client.buffer_from_host_buffer(&dstate, &[NP], None)?;
            let adev = self.client.buffer_from_host_buffer(&astate, &[NP], None)?;
            let result = self.update_exec.execute_b(&[
                &self.point_buffers[chunk_idx],
                &cdev,
                &ddev,
                &adev,
                &idev,
            ])?[0][0]
                .to_literal_sync()?;
            let (ndmin_l, namin_l) = result.to_tuple2()?;
            let ndmin: Vec<f32> = ndmin_l.to_vec()?;
            let namin: Vec<i32> = namin_l.to_vec()?;
            for r in 0..chunk_rows {
                mind[chunk_start + r] = ndmin[r];
                arg[chunk_start + r] = namin[r] as u32;
            }
        }
        Ok(())
    }
}
