//! Hand-rolled TOML-subset configuration (the offline image has no serde).
//!
//! Supported syntax — the subset real experiment configs need:
//!
//! ```toml
//! # comment
//! [section]            # and [nested.section]
//! name = "string"
//! count = 42
//! ratio = 0.5
//! flag = true
//! taus = [8, 16, 32]
//! ```
//!
//! Keys flatten to `section.key`.  Typed getters return `anyhow` errors
//! naming the key, so config mistakes fail loudly at startup.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed scalar or list value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    fn parse_scalar(tok: &str) -> Result<Value> {
        let tok = tok.trim();
        if tok.starts_with('"') && tok.ends_with('"') && tok.len() >= 2 {
            return Ok(Value::Str(tok[1..tok.len() - 1].to_string()));
        }
        if tok == "true" {
            return Ok(Value::Bool(true));
        }
        if tok == "false" {
            return Ok(Value::Bool(false));
        }
        if let Ok(i) = tok.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = tok.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        bail!("cannot parse value: {tok}")
    }

    fn parse(tok: &str) -> Result<Value> {
        let tok = tok.trim();
        if let Some(inner) = tok.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
            let inner = inner.trim();
            if inner.is_empty() {
                return Ok(Value::List(Vec::new()));
            }
            let items = inner
                .split(',')
                .map(Value::parse_scalar)
                .collect::<Result<Vec<_>>>()?;
            return Ok(Value::List(items));
        }
        Value::parse_scalar(tok)
    }
}

/// Flat `section.key -> Value` configuration map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(sec) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                let sec = sec.trim();
                if sec.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = sec.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let parsed = Value::parse(value)
                .with_context(|| format!("line {}: key {full_key}", lineno + 1))?;
            if map.insert(full_key.clone(), parsed).is_some() {
                bail!("line {}: duplicate key {full_key}", lineno + 1);
            }
        }
        Ok(Config { map })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read config {}", path.as_ref().display()))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|k| k.as_str())
    }

    pub fn str(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(Value::Str(s)) => Ok(s),
            Some(v) => bail!("config key {key}: expected string, got {v:?}"),
            None => bail!("config key {key} missing"),
        }
    }

    pub fn i64(&self, key: &str) -> Result<i64> {
        match self.get(key) {
            Some(Value::Int(i)) => Ok(*i),
            Some(v) => bail!("config key {key}: expected int, got {v:?}"),
            None => bail!("config key {key} missing"),
        }
    }

    pub fn usize(&self, key: &str) -> Result<usize> {
        let i = self.i64(key)?;
        if i < 0 {
            bail!("config key {key}: negative");
        }
        Ok(i as usize)
    }

    pub fn f64(&self, key: &str) -> Result<f64> {
        match self.get(key) {
            Some(Value::Float(f)) => Ok(*f),
            Some(Value::Int(i)) => Ok(*i as f64),
            Some(v) => bail!("config key {key}: expected float, got {v:?}"),
            None => bail!("config key {key} missing"),
        }
    }

    pub fn bool(&self, key: &str) -> Result<bool> {
        match self.get(key) {
            Some(Value::Bool(b)) => Ok(*b),
            Some(v) => bail!("config key {key}: expected bool, got {v:?}"),
            None => bail!("config key {key} missing"),
        }
    }

    pub fn usize_list(&self, key: &str) -> Result<Vec<usize>> {
        match self.get(key) {
            Some(Value::List(items)) => items
                .iter()
                .map(|v| match v {
                    Value::Int(i) if *i >= 0 => Ok(*i as usize),
                    other => bail!("config key {key}: non-usize item {other:?}"),
                })
                .collect(),
            Some(v) => bail!("config key {key}: expected list, got {v:?}"),
            None => bail!("config key {key} missing"),
        }
    }

    // ---- with-default variants ----
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        match self.get(key) {
            Some(Value::Str(s)) => s,
            _ => default,
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.usize(key).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.f64(key).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.bool(key).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
title = "fig1"        # inline comment
[dataset]
kind = "wikisim"
n = 5000
[run]
eps = 0.5
taus = [8, 16, 32]
pjrt = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("title").unwrap(), "fig1");
        assert_eq!(c.str("dataset.kind").unwrap(), "wikisim");
        assert_eq!(c.usize("dataset.n").unwrap(), 5000);
        assert!((c.f64("run.eps").unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(c.usize_list("run.taus").unwrap(), vec![8, 16, 32]);
        assert!(c.bool("run.pjrt").unwrap());
    }

    #[test]
    fn missing_and_mistyped_keys_error() {
        let c = Config::parse(SAMPLE).unwrap();
        assert!(c.str("nope").is_err());
        assert!(c.usize("title").is_err());
        assert!(c.bool("dataset.n").is_err());
    }

    #[test]
    fn defaults() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.usize_or("nope", 7), 7);
        assert_eq!(c.str_or("title", "x"), "fig1");
        assert!(!c.bool_or("nope", false));
    }

    #[test]
    fn int_promotes_to_float() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.f64("x").unwrap(), 3.0);
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(Config::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn bad_value_rejected() {
        assert!(Config::parse("a = what").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = Config::parse("s = \"a#b\"").unwrap();
        assert_eq!(c.str("s").unwrap(), "a#b");
    }

    #[test]
    fn empty_list() {
        let c = Config::parse("xs = []").unwrap();
        assert_eq!(c.usize_list("xs").unwrap(), Vec::<usize>::new());
    }
}
