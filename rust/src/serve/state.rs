//! Multi-tenant serving state: named indexes behind per-tenant locks.
//!
//! A [`Tenant`] owns everything one served index needs — the
//! reconstructed dataset and matroid, the tree state as [`IndexParts`],
//! and a shared [`ResultCache`] — and exposes thread-safe query / append
//! / delete entry points.  The borrowed-lifetime [`CoresetIndex`] is
//! reconstructed transiently from the owned parts inside each operation
//! (the parts *are* the tree; reconstruction is a cheap clone of the
//! level vectors, no distance work).
//!
//! Concurrency protocol per tenant:
//!
//! * **mutations are serialized** behind the `inner` write lock (one
//!   append/delete at a time; the epoch bump inside `IndexParts` is what
//!   invalidates cached results, exactly as in the single-threaded
//!   service);
//! * **queries coalesce**: a query captures `(root, epoch)` under the
//!   read lock, misses the cache, then registers in the in-flight map
//!   keyed `cache_key@epoch`.  The first registrant (leader) runs the
//!   cold computation **outside every lock**; later arrivals block on the
//!   leader's [`InflightSlot`] and receive the bit-identical result at
//!   zero distance evaluations.  The leader publishes to the cache
//!   *before* deregistering, so at every instant a duplicate request
//!   finds the result in the cache, in flight, or becomes the one leader
//!   — never a second cold run for the same `(spec, epoch)`;
//! * a result is always stamped with the epoch of the root it was
//!   computed from (captured atomically under the read lock), so an
//!   append racing a query can never produce a result labeled with an
//!   epoch it does not belong to.
//!
//! Engines are built per cold run: [`DistanceEngine`] is deliberately not
//! `Send + Sync` (the PJRT backend holds raw client pointers), so worker
//! threads must not share one — the same engine-per-worker rule the
//! MapReduce simulator follows.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use crate::coordinator::spec::MatroidBox;
use crate::core::Dataset;
use crate::index::service::{
    run_cold_query, ColdQuery, DistEvals, QueryOutcome, QueryResult, QuerySpec, ResultCache,
    ServiceStats,
};
use crate::index::store;
use crate::index::tree::{CoresetIndex, DeleteReceipt, IndexConfig, IndexParts};
use crate::index::IndexSnapshot;
use crate::obs::metrics::MetricsRegistry;
use crate::runtime::EngineKind;
use crate::util::timer::Stopwatch;

/// How a query was answered — the serving-path label the load harness
/// and the protocol report.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuerySource {
    /// A cold computation ran for this request.
    Cold,
    /// Served from the result cache.
    Cache,
    /// Waited on an identical in-flight computation and shared its
    /// result.
    Coalesced,
}

impl QuerySource {
    pub fn name(self) -> &'static str {
        match self {
            QuerySource::Cold => "cold",
            QuerySource::Cache => "cache",
            QuerySource::Coalesced => "coalesced",
        }
    }
}

/// A served query plus its serving-path label.
#[derive(Clone, Debug)]
pub struct TenantAnswer {
    pub outcome: QueryOutcome,
    pub source: QuerySource,
}

/// One in-flight cold computation: the leader publishes exactly once,
/// every follower blocks until then.  Errors propagate as strings so a
/// failing leader does not strand its followers.
#[derive(Debug, Default)]
pub struct InflightSlot {
    done: Mutex<Option<Result<QueryResult, String>>>,
    cv: Condvar,
}

impl InflightSlot {
    pub fn new() -> InflightSlot {
        InflightSlot::default()
    }

    /// Publish the computation's outcome and wake every waiter.
    pub fn publish(&self, outcome: Result<QueryResult, String>) {
        let mut done = self.done.lock().unwrap();
        *done = Some(outcome);
        self.cv.notify_all();
    }

    /// Block until the leader publishes.
    pub fn wait(&self) -> Result<QueryResult, String> {
        let mut done = self.done.lock().unwrap();
        loop {
            if let Some(outcome) = done.as_ref() {
                return outcome.clone();
            }
            done = self.cv.wait(done).unwrap();
        }
    }
}

/// Mutable tree state of a tenant: the resumable parts plus the CLI-style
/// sequential ingest cursor.
#[derive(Debug)]
struct TenantInner {
    parts: IndexParts,
    cursor: usize,
}

/// Everything an append reports over the wire (including the satellite
/// clamp semantics: over-asking is clamped to the remaining rows, and the
/// clamp is visible).
#[derive(Clone, Copy, Debug)]
pub struct AppendSummary {
    /// What the request asked for (`None` = "the rest").
    pub requested: Option<usize>,
    /// Rows actually ingested after clamping to the dataset remainder.
    pub appended: usize,
    /// True iff the request over-asked and was clamped.
    pub clamped: bool,
    /// Segments the rows were split into.
    pub segments: usize,
    /// Tree epoch after the append.
    pub epoch: u64,
    /// Root coreset size after the append.
    pub root: usize,
}

/// A delete's receipt plus the post-delete epoch.
#[derive(Clone, Debug)]
pub struct DeleteSummary {
    pub receipt: DeleteReceipt,
    pub epoch: u64,
}

/// Point-in-time tenant description for `STATS` replies and the load
/// harness.
#[derive(Clone, Debug)]
pub struct TenantStatus {
    pub name: String,
    pub stats: ServiceStats,
    pub cache_len: usize,
    pub epoch: u64,
    pub segments: usize,
    pub points: usize,
    pub root: usize,
    pub tombstones: usize,
    pub cursor: usize,
    /// Live member fraction across tree nodes (1.0 when nothing is dead).
    pub live_fraction: f64,
}

/// One served index: owned world + tree state + shared result cache.
pub struct Tenant {
    name: String,
    /// Snapshot file this tenant persists to (`None` for in-memory
    /// tenants added directly from a snapshot, e.g. in tests).
    path: Option<PathBuf>,
    data: String,
    seed: u64,
    matroid_str: String,
    ds: Dataset,
    matroid: MatroidBox,
    cfg: IndexConfig,
    inner: RwLock<TenantInner>,
    cache: Mutex<ResultCache>,
    inflight: Mutex<BTreeMap<String, Arc<InflightSlot>>>,
    /// Shared with the owning [`ServeState`]: the registry the `METRICS`
    /// verb renders.  Telemetry only — nothing in the query or mutation
    /// paths reads it back.
    metrics: Arc<MetricsRegistry>,
}

/// Tenant names travel inside whitespace-separated protocol lines.
fn validate_name(name: &str) -> Result<()> {
    if name.is_empty()
        || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
    {
        bail!("bad tenant name {name:?} (ascii alphanumerics, '-', '_' only)");
    }
    Ok(())
}

impl Tenant {
    /// Reconstruct a tenant from a snapshot (the serving twin of the
    /// `dmmc index` subcommands' load path).
    pub fn from_snapshot(
        name: &str,
        snap: &IndexSnapshot,
        path: Option<PathBuf>,
        cache_capacity: usize,
        metrics: Arc<MetricsRegistry>,
    ) -> Result<Tenant> {
        validate_name(name)?;
        let (ds, matroid) = store::snapshot_world(snap)?;
        Ok(Tenant {
            name: name.to_string(),
            path,
            data: snap.data.clone(),
            seed: snap.seed,
            matroid_str: snap.matroid.clone(),
            ds,
            matroid,
            cfg: snap.config(),
            inner: RwLock::new(TenantInner {
                parts: snap.parts(),
                cursor: snap.cursor,
            }),
            cache: Mutex::new(ResultCache::new(cache_capacity)),
            inflight: Mutex::new(BTreeMap::new()),
            metrics,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn k_max(&self) -> usize {
        self.cfg.k_max
    }

    /// The engine the index was built with (default for queries that do
    /// not override it).
    pub fn engine(&self) -> EngineKind {
        self.cfg.engine
    }

    pub fn epoch(&self) -> u64 {
        self.inner.read().unwrap().parts.epoch
    }

    pub fn cursor(&self) -> usize {
        self.inner.read().unwrap().cursor
    }

    /// A clone of the current tree state (for reference re-computations
    /// in tests).
    pub fn parts(&self) -> IndexParts {
        self.inner.read().unwrap().parts.clone()
    }

    pub fn stats(&self) -> ServiceStats {
        *self.cache.lock().unwrap().stats()
    }

    /// Charge one error to this tenant's stats — the server's
    /// panic-containment path, where no query-layer accounting ran.
    pub fn record_error(&self) {
        self.cache.lock().unwrap().record_error();
        self.metrics.counter("dmmc_errors_total", &[("tenant", &self.name)]).inc();
    }

    /// Warm the result cache from persisted entries (no counters touched).
    pub fn warm(&self, entries: Vec<(String, u64, QueryResult)>) {
        let mut cache = self.cache.lock().unwrap();
        for (key, epoch, result) in entries {
            cache.seed(&key, epoch, result);
        }
    }

    /// Serve one query: cache, then coalesce, then cold.  The wrapper
    /// stamps the per-tenant obs counters and latency histogram so the
    /// `METRICS` exposition reconciles with `STATS` one-for-one: every
    /// request counts in `dmmc_queries_total` and in exactly one of
    /// hits / misses / coalesced / errors — mirroring [`ServiceStats`].
    pub fn query(&self, spec: &QuerySpec) -> Result<TenantAnswer> {
        let sw = Stopwatch::start();
        let mut span = crate::span!("serve.query", "tenant" = self.name);
        let res = self.query_inner(spec, sw);
        let tenant: &str = &self.name;
        let m = &self.metrics;
        m.counter("dmmc_queries_total", &[("tenant", tenant)]).inc();
        match &res {
            Ok(ans) => {
                let source = ans.source.name();
                span.tag("source", source);
                let bucket = match ans.source {
                    QuerySource::Cold => "dmmc_cache_misses_total",
                    QuerySource::Cache => "dmmc_cache_hits_total",
                    QuerySource::Coalesced => "dmmc_coalesced_total",
                };
                m.counter(bucket, &[("tenant", tenant)]).inc();
                if let DistEvals::Measured(n) = ans.outcome.dist_evals {
                    m.counter("dmmc_dist_evals_total", &[("tenant", tenant)]).add(n);
                }
                m.histogram("dmmc_query_latency_seconds", &[("tenant", tenant), ("source", source)])
                    .observe(ans.outcome.elapsed);
            }
            Err(_) => {
                span.tag("source", "error");
                m.counter("dmmc_errors_total", &[("tenant", tenant)]).inc();
                m.histogram("dmmc_query_latency_seconds", &[("tenant", tenant), ("source", "error")])
                    .observe(sw.elapsed());
            }
        }
        res
    }

    fn query_inner(&self, spec: &QuerySpec, sw: Stopwatch) -> Result<TenantAnswer> {
        let key = spec.cache_key();
        // capture (root, epoch) atomically: the result is stamped with
        // the epoch of exactly the root it was computed from
        let (root, epoch) = {
            let inner = self.inner.read().unwrap();
            let idx =
                CoresetIndex::from_parts(&self.ds, &*self.matroid, self.cfg, inner.parts.clone());
            (idx.root(), idx.epoch())
        };
        if let Some(result) = self.cache.lock().unwrap().lookup(&key, epoch) {
            return Ok(self.answer(result, QuerySource::Cache, true, epoch, sw));
        }
        let ikey = format!("{key}@{epoch}");
        let (slot, leader) = {
            let mut inflight = self.inflight.lock().unwrap();
            match inflight.get(&ikey) {
                Some(slot) => (Arc::clone(slot), false),
                None => {
                    let slot = Arc::new(InflightSlot::new());
                    inflight.insert(ikey.clone(), Arc::clone(&slot));
                    (slot, true)
                }
            }
        };
        if !leader {
            return match slot.wait() {
                Ok(result) => {
                    self.cache.lock().unwrap().record_coalesced();
                    Ok(self.answer(result, QuerySource::Coalesced, false, epoch, sw))
                }
                Err(msg) => {
                    self.cache.lock().unwrap().record_error();
                    bail!("coalesced query failed: {msg}");
                }
            };
        }
        // double-checked cache: a prior leader may have published between
        // our lookup miss and our registration (publish precedes
        // deregistration, so this re-check closes the window)
        if let Some(result) = self.cache.lock().unwrap().recheck(&key, epoch) {
            self.inflight.lock().unwrap().remove(&ikey);
            slot.publish(Ok(result.clone()));
            return Ok(self.answer(result, QuerySource::Cache, true, epoch, sw));
        }
        let cx = ColdQuery {
            ds: &self.ds,
            matroid: &*self.matroid,
            k_max: self.cfg.k_max,
            root: &root,
            epoch,
        };
        // the cold run happens outside every lock; the engine is built
        // per run (DistanceEngine is not Send + Sync).  A panicking
        // finisher is converted to a plain error *here*, before the
        // publish/deregister protocol below — otherwise the leader's
        // inflight slot would leak registered forever and every future
        // identical query would block on it
        let cold = catch_unwind(AssertUnwindSafe(|| run_cold_query(&cx, spec, &key, None)))
            .unwrap_or_else(|payload| {
                Err(anyhow::anyhow!(
                    "internal panic in cold query: {}",
                    crate::serve::panic_message(payload.as_ref())
                ))
            });
        match cold {
            Ok((result, dist_evals)) => {
                // publish-before-deregister: cache first, then remove the
                // slot, then wake followers — no instant exists where a
                // duplicate request finds neither
                self.cache.lock().unwrap().complete_miss(&key, epoch, result.clone());
                self.inflight.lock().unwrap().remove(&ikey);
                slot.publish(Ok(result.clone()));
                Ok(TenantAnswer {
                    outcome: QueryOutcome {
                        result,
                        cache_hit: false,
                        epoch,
                        dist_evals,
                        elapsed: sw.elapsed(),
                    },
                    source: QuerySource::Cold,
                })
            }
            Err(e) => {
                self.cache.lock().unwrap().record_error();
                self.inflight.lock().unwrap().remove(&ikey);
                slot.publish(Err(format!("{e:#}")));
                Err(e)
            }
        }
    }

    fn answer(
        &self,
        result: QueryResult,
        source: QuerySource,
        cache_hit: bool,
        epoch: u64,
        sw: Stopwatch,
    ) -> TenantAnswer {
        TenantAnswer {
            outcome: QueryOutcome {
                result,
                cache_hit,
                epoch,
                dist_evals: DistEvals::CachedZero,
                elapsed: sw.elapsed(),
            },
            source,
        }
    }

    /// Ingest the next `requested` dataset rows (clamped to the rows the
    /// dataset still has; `None` = all of them).  Serialized behind the
    /// write lock; the epoch bump invalidates cached results implicitly.
    pub fn append(&self, requested: Option<usize>, segment: Option<usize>) -> Result<AppendSummary> {
        let mut inner = self.inner.write().unwrap();
        let remaining = self.ds.n().saturating_sub(inner.cursor);
        if remaining == 0 {
            bail!("tenant {} already covers all {} dataset rows", self.name, self.ds.n());
        }
        let count = requested.unwrap_or(remaining).min(remaining);
        if count == 0 {
            bail!("append of zero rows (pass a positive count or omit it)");
        }
        let segment = segment.unwrap_or(count).max(1);
        let _span = crate::span!("serve.append", "tenant" = self.name, "rows" = count);
        let mut idx =
            CoresetIndex::from_parts(&self.ds, &*self.matroid, self.cfg, inner.parts.clone());
        let order: Vec<usize> = (inner.cursor..inner.cursor + count).collect();
        let receipts = idx.ingest(&order, segment)?;
        inner.cursor += count;
        inner.parts = idx.parts();
        // publish the receipts' ledgers (telemetry only: the receipts the
        // caller sees are untouched)
        let m = &self.metrics;
        let lbl = [("op", "append"), ("tenant", self.name.as_str())];
        m.counter("dmmc_index_nodes_touched_total", &lbl)
            .add(receipts.iter().map(|r| r.nodes_touched as u64).sum());
        m.counter("dmmc_index_dist_evals_total", &lbl)
            .add(receipts.iter().map(|r| r.dist_evals).sum());
        m.counter("dmmc_index_merges_total", &[("tenant", self.name.as_str())])
            .add(receipts.iter().map(|r| r.merges as u64).sum());
        Ok(AppendSummary {
            requested,
            appended: count,
            clamped: requested.is_some_and(|r| r > count),
            segments: receipts.len(),
            epoch: inner.parts.epoch,
            root: idx.root().len(),
        })
    }

    /// Tombstone rows (serialized; an effective delete bumps the epoch).
    pub fn delete(&self, rows: &[usize]) -> Result<DeleteSummary> {
        let mut inner = self.inner.write().unwrap();
        let _span = crate::span!("serve.delete", "tenant" = self.name, "rows" = rows.len());
        let mut idx =
            CoresetIndex::from_parts(&self.ds, &*self.matroid, self.cfg, inner.parts.clone());
        let receipt = idx.delete(rows)?;
        inner.parts = idx.parts();
        let m = &self.metrics;
        let lbl = [("op", "delete"), ("tenant", self.name.as_str())];
        m.counter("dmmc_index_nodes_touched_total", &lbl).add(receipt.nodes_touched as u64);
        m.counter("dmmc_index_dist_evals_total", &lbl).add(receipt.dist_evals);
        m.counter("dmmc_index_rebuilds_total", &[("tenant", self.name.as_str())])
            .add(receipt.rebuilds as u64);
        Ok(DeleteSummary {
            receipt,
            epoch: inner.parts.epoch,
        })
    }

    /// Capture the current tree state as a snapshot.
    pub fn snapshot(&self) -> IndexSnapshot {
        let inner = self.inner.read().unwrap();
        let idx = CoresetIndex::from_parts(&self.ds, &*self.matroid, self.cfg, inner.parts.clone());
        IndexSnapshot::capture(
            &idx,
            self.data.clone(),
            self.seed,
            self.matroid_str.clone(),
            inner.cursor,
        )
    }

    /// Persist the tenant back to its snapshot file plus the result-cache
    /// sidecar (only current-epoch entries are worth persisting; stale
    /// ones could never hit).  Returns the path and the entry count.
    pub fn save(&self) -> Result<(PathBuf, usize)> {
        let path = self
            .path
            .clone()
            .with_context(|| format!("tenant {} was not loaded from a file", self.name))?;
        let snap = self.snapshot();
        store::save(&snap, &path)?;
        let entries: Vec<(String, u64, QueryResult)> = self
            .cache
            .lock()
            .unwrap()
            .entries()
            .into_iter()
            .filter(|(_, epoch, _)| *epoch == snap.epoch)
            .collect();
        store::save_result_cache(store::result_cache_path(&path), store::snapshot_id(&snap), &entries)?;
        Ok((path, entries.len()))
    }

    pub fn status(&self) -> TenantStatus {
        let (epoch, segments, points, root, tombstones, cursor, live_fraction) = {
            let inner = self.inner.read().unwrap();
            let idx =
                CoresetIndex::from_parts(&self.ds, &*self.matroid, self.cfg, inner.parts.clone());
            (
                idx.epoch(),
                idx.segments(),
                inner.parts.points,
                idx.root().len(),
                idx.tombstones().len(),
                inner.cursor,
                idx.live_fraction(),
            )
        };
        let (stats, cache_len) = {
            let cache = self.cache.lock().unwrap();
            (*cache.stats(), cache.len())
        };
        TenantStatus {
            name: self.name.clone(),
            stats,
            cache_len,
            epoch,
            segments,
            points,
            root,
            tombstones,
            cursor,
            live_fraction,
        }
    }
}

/// The server's tenant registry.
pub struct ServeState {
    cache_capacity: usize,
    tenants: RwLock<BTreeMap<String, Arc<Tenant>>>,
    /// One registry per server (not the process-global one): co-hosted
    /// states — every test in this binary, for instance — must never
    /// share counters, or `METRICS` could not reconcile with `STATS`.
    metrics: Arc<MetricsRegistry>,
}

impl ServeState {
    pub fn new(cache_capacity: usize) -> ServeState {
        ServeState {
            cache_capacity: cache_capacity.max(1),
            tenants: RwLock::new(BTreeMap::new()),
            metrics: MetricsRegistry::fresh(),
        }
    }

    /// The registry the `METRICS` verb renders.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Load (or replace) a tenant from a snapshot file, warming its
    /// result cache from the sidecar when the sidecar matches the
    /// snapshot's content id.
    pub fn load(&self, name: &str, path: &Path) -> Result<Arc<Tenant>> {
        let snap = store::load(path)
            .with_context(|| format!("load index {} for tenant {name}", path.display()))?;
        let tenant = Tenant::from_snapshot(
            name,
            &snap,
            Some(path.to_path_buf()),
            self.cache_capacity,
            Arc::clone(&self.metrics),
        )?;
        let warm = store::load_result_cache(store::result_cache_path(path), store::snapshot_id(&snap));
        tenant.warm(warm);
        let tenant = Arc::new(tenant);
        self.tenants.write().unwrap().insert(name.to_string(), Arc::clone(&tenant));
        Ok(tenant)
    }

    /// Register an in-memory tenant directly from a snapshot (tests, and
    /// anything that does not need persistence).
    pub fn add(&self, name: &str, snap: &IndexSnapshot) -> Result<Arc<Tenant>> {
        let tenant = Arc::new(Tenant::from_snapshot(
            name,
            snap,
            None,
            self.cache_capacity,
            Arc::clone(&self.metrics),
        )?);
        self.tenants.write().unwrap().insert(name.to_string(), Arc::clone(&tenant));
        Ok(tenant)
    }

    pub fn get(&self, name: &str) -> Result<Arc<Tenant>> {
        self.tenants
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("no tenant {name} (loaded: {})", self.names().join(", ")))
    }

    pub fn unload(&self, name: &str) -> Result<()> {
        self.tenants
            .write()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .with_context(|| format!("no tenant {name} to unload"))
    }

    pub fn names(&self) -> Vec<String> {
        self.tenants.read().unwrap().keys().cloned().collect()
    }

    /// Sum of every tenant's serving counters (the load harness reports
    /// the fleet-wide hit rate).
    pub fn total_stats(&self) -> ServiceStats {
        let mut total = ServiceStats::default();
        for tenant in self.tenants.read().unwrap().values() {
            let s = tenant.stats();
            total.queries += s.queries;
            total.hits += s.hits;
            total.misses += s.misses;
            total.errors += s.errors;
            total.coalesced += s.coalesced;
            total.evictions += s.evictions;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::index::tree::IndexConfig;
    use crate::matroid::UniformMatroid;

    fn snapshot(n: usize, ingest: usize, seed: u64) -> IndexSnapshot {
        let ds = synth::uniform_cube(n, 2, seed);
        let m = UniformMatroid::new(4);
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(4, 8)
        };
        let mut idx = CoresetIndex::new(&ds, &m, cfg);
        idx.ingest(&(0..ingest).collect::<Vec<_>>(), (ingest / 2).max(1)).unwrap();
        IndexSnapshot::capture(&idx, format!("cube:{n}x2"), seed, "uniform:4".into(), ingest)
    }

    #[test]
    fn tenant_names_are_validated() {
        let snap = snapshot(100, 50, 7);
        let m = MetricsRegistry::fresh;
        assert!(Tenant::from_snapshot("ok-name_2", &snap, None, 8, m()).is_ok());
        for bad in ["", "has space", "a/b", "a=b", "q@e"] {
            assert!(
                Tenant::from_snapshot(bad, &snap, None, 8, m()).is_err(),
                "{bad:?} accepted"
            );
        }
    }

    #[test]
    fn tenant_query_append_delete_roundtrip() {
        let state = ServeState::new(8);
        let snap = snapshot(300, 200, 11);
        let tenant = state.add("main", &snap).unwrap();
        let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);

        let cold = tenant.query(&spec).unwrap();
        assert_eq!(cold.source, QuerySource::Cold);
        assert!(!cold.outcome.cache_hit);
        let hit = tenant.query(&spec).unwrap();
        assert_eq!(hit.source, QuerySource::Cache);
        assert_eq!(hit.outcome.dist_evals, DistEvals::CachedZero);
        assert_eq!(
            hit.outcome.result.diversity.to_bits(),
            cold.outcome.result.diversity.to_bits()
        );

        // over-asking clamps and says so
        let a = tenant.append(Some(500), None).unwrap();
        assert_eq!(a.appended, 100);
        assert!(a.clamped);
        assert_eq!(tenant.cursor(), 300);
        assert!(tenant.append(Some(1), None).is_err(), "dataset exhausted");

        // post-append the cache is stale (new epoch): next query is cold
        let after = tenant.query(&spec).unwrap();
        assert_eq!(after.source, QuerySource::Cold);
        assert_eq!(after.outcome.epoch, a.epoch);

        let d = tenant.delete(&[after.outcome.result.solution[0]]).unwrap();
        assert_eq!(d.receipt.newly_dead, 1);
        assert_eq!(tenant.query(&spec).unwrap().source, QuerySource::Cold);

        let st = tenant.status();
        assert_eq!(st.stats.misses, 3);
        assert_eq!(st.stats.hits, 1);
        assert_eq!(st.cursor, 300);
    }

    #[test]
    fn state_registry_get_and_unload() {
        let state = ServeState::new(4);
        let snap = snapshot(100, 60, 13);
        state.add("a", &snap).unwrap();
        state.add("b", &snap).unwrap();
        assert_eq!(state.names(), vec!["a".to_string(), "b".to_string()]);
        assert!(state.get("a").is_ok());
        assert!(state.get("missing").is_err());
        state.unload("a").unwrap();
        assert!(state.get("a").is_err());
        assert!(state.unload("a").is_err());
    }
}
