//! Load-replay harness: drive the serving state with thousands of mixed
//! query/append/delete protocol ops and report latency/throughput — the
//! measured number behind the ROADMAP's serving north star.
//!
//! Ops come from a file (one protocol line each, `#` comments allowed)
//! or are synthesized (`synth:<n>`): a seeded mix of ~90% queries over a
//! small spec pool, ~6% single-row appends, ~4% deletes.  The workload
//! is deterministic given the seed — only the timings vary run to run.
//! Ops execute through [`handle_line`], so the harness measures exactly
//! the per-request work a TCP worker performs (minus socket I/O), across
//! `threads` concurrent workers pulling from a shared cursor.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::csv_row;
use crate::index::service::ServiceStats;
use crate::obs::metrics::{json_string, MetricsRegistry};
use crate::serve::protocol::handle_line;
use crate::serve::state::ServeState;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Latency/throughput summary for one op kind (plus the `all` row).
#[derive(Clone, Debug)]
pub struct KindSummary {
    pub kind: String,
    pub count: usize,
    pub p50_us: f64,
    pub p99_us: f64,
    /// Ops of this kind completed per wall-clock second of the whole
    /// replay (concurrent kinds share the wall, so the `all` row's qps is
    /// the aggregate throughput).
    pub qps: f64,
}

/// Everything one replay run measured.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    /// First loaded tenant (the synthetic workload's target).
    pub tenant: String,
    pub threads: usize,
    pub ops: usize,
    pub wall: Duration,
    /// Ops answered `ERR` (an exhausted-dataset append, a malformed op in
    /// a replay file, ...).
    pub err_replies: usize,
    /// Fleet-wide serving counters after the run.
    pub stats: ServiceStats,
    /// Per-kind summaries, `all` first, then kinds alphabetically.
    pub kinds: Vec<KindSummary>,
}

/// Synthesize a deterministic mixed workload against the first tenant.
fn synth_ops(state: &ServeState, n: usize, seed: u64) -> Result<Vec<String>> {
    let names = state.names();
    let name = names.first().context("synthetic replay needs a loaded tenant")?;
    let tenant = state.get(name)?;
    let k_max = tenant.k_max();
    // rows that exist at replay start — the delete pool (deleting an
    // already-dead row is a valid no-op op, so overlap is fine)
    let initial_rows = tenant.cursor().max(1);
    let mut specs: Vec<String> = Vec::new();
    for k in 2..=k_max.min(6) {
        specs.push(format!("QUERY {name} sum {k}"));
        specs.push(format!("QUERY {name} sum {k} finisher=greedy"));
        specs.push(format!("QUERY {name} tree {k} finisher=greedy"));
        specs.push(format!("QUERY {name} remote-edge {k} finisher=matching"));
    }
    if specs.is_empty() {
        specs.push(format!("QUERY {name} sum {k_max}"));
    }
    let mut rng = Rng::new(seed);
    let mut ops = Vec::with_capacity(n);
    for _ in 0..n {
        let roll = rng.below(100);
        if roll < 90 {
            ops.push(specs[rng.below(specs.len())].clone());
        } else if roll < 96 {
            ops.push(format!("APPEND {name} 1"));
        } else {
            ops.push(format!("DELETE {name} {}", rng.below(initial_rows)));
        }
    }
    Ok(ops)
}

/// Run a replay: `source` is `synth:<n>` or a path to an ops file.
pub fn run_replay(
    state: &ServeState,
    source: &str,
    threads: usize,
    seed: u64,
) -> Result<ReplayReport> {
    let ops: Vec<String> = if let Some(n) = source.strip_prefix("synth:") {
        synth_ops(state, n.parse().context("synth:<n> op count")?, seed)?
    } else {
        std::fs::read_to_string(source)
            .with_context(|| format!("read replay ops file {source}"))?
            .lines()
            .map(|l| l.trim().to_string())
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .collect()
    };
    if ops.is_empty() {
        bail!("replay source {source} holds no ops");
    }
    let tenant = state.names().first().cloned().unwrap_or_default();
    let threads = threads.max(1);
    let cursor = AtomicUsize::new(0);
    let wall_sw = Stopwatch::start();
    // each worker records (kind, latency_us, ok) locally; merged after
    let mut samples: Vec<(String, f64, bool)> = thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local: Vec<(String, f64, bool)> = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::SeqCst);
                        let Some(op) = ops.get(i) else { break };
                        let kind = op
                            .split_whitespace()
                            .next()
                            .unwrap_or("?")
                            .to_ascii_lowercase();
                        let op_sw = Stopwatch::start();
                        let reply = handle_line(state, op);
                        let us = op_sw.elapsed().as_secs_f64() * 1e6;
                        local.push((kind, us, reply.starts_with("OK ")));
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = wall_sw.elapsed();
    let wall_s = wall.as_secs_f64().max(1e-9);
    let err_replies = samples.iter().filter(|(_, _, ok)| !ok).count();

    // `all` row plus one per kind; sort keys for a deterministic CSV row
    // order (sample *values* are timing, inherently run-specific).  The
    // quantiles come from the shared obs histogram — the same object the
    // `METRICS` verb renders (`dmmc_replay_latency_seconds{kind}`), so
    // the CSV and the exposition agree by construction.
    samples.sort_by(|a, b| a.0.cmp(&b.0));
    let mut kinds: Vec<KindSummary> = Vec::new();
    let summarize = |kind: &str, lats: &[f64]| -> KindSummary {
        let hist = state
            .metrics()
            .histogram("dmmc_replay_latency_seconds", &[("kind", kind)]);
        for &us in lats {
            hist.observe_us(us as u64);
        }
        KindSummary {
            kind: kind.to_string(),
            count: lats.len(),
            p50_us: hist.quantile_us(0.5),
            p99_us: hist.quantile_us(0.99),
            qps: lats.len() as f64 / wall_s,
        }
    };
    let all: Vec<f64> = samples.iter().map(|(_, us, _)| *us).collect();
    kinds.push(summarize("all", &all));
    let mut i = 0;
    while i < samples.len() {
        let kind = samples[i].0.clone();
        let mut lats: Vec<f64> = Vec::new();
        while i < samples.len() && samples[i].0 == kind {
            lats.push(samples[i].1);
            i += 1;
        }
        kinds.push(summarize(&kind, &lats));
    }

    Ok(ReplayReport {
        tenant,
        threads,
        ops: samples.len(),
        wall,
        err_replies,
        stats: state.total_stats(),
        kinds,
    })
}

/// Write the replay CSV (`bench_results/serve_load.csv` schema, see
/// EXPERIMENTS.md): one row per kind, fleet-wide counters repeated on
/// every row.
pub fn write_replay_csv(path: &str, report: &ReplayReport) -> Result<()> {
    let mut csv = CsvWriter::create(
        path,
        &[
            "tenant", "threads", "kind", "ops", "p50_us", "p99_us", "qps", "hits", "misses",
            "errors", "coalesced", "hit_rate",
        ],
    )?;
    let s = &report.stats;
    for k in &report.kinds {
        csv.row(&csv_row![
            report.tenant,
            report.threads,
            k.kind,
            k.count,
            format!("{:.1}", k.p50_us),
            format!("{:.1}", k.p99_us),
            format!("{:.1}", k.qps),
            s.hits,
            s.misses,
            s.errors,
            s.coalesced,
            format!("{:.4}", s.hit_rate())
        ])?;
    }
    csv.flush()?;
    Ok(())
}

/// Write the machine-readable bench trajectory
/// (`bench_results/BENCH_serve.json`, schema in EXPERIMENTS.md): run
/// metadata plus a full snapshot of the serve metrics registry — the
/// same counters and histograms the `METRICS` verb exposes.
pub fn write_replay_bench_json(
    path: &str,
    report: &ReplayReport,
    registry: &MetricsRegistry,
) -> Result<()> {
    let s = &report.stats;
    let meta = format!(
        "{{\"tenant\":{},\"threads\":{},\"ops\":{},\"wall_s\":{:.6},\"err_replies\":{},\
         \"queries\":{},\"hits\":{},\"misses\":{},\"errors\":{},\"coalesced\":{},\
         \"hit_rate\":{:.6}}}",
        json_string(&report.tenant),
        report.threads,
        report.ops,
        report.wall.as_secs_f64(),
        report.err_replies,
        s.queries,
        s.hits,
        s.misses,
        s.errors,
        s.coalesced,
        s.hit_rate(),
    );
    crate::bench::write_bench_json(path, "serve", &meta, registry)
}

/// Render the report for stdout.
pub fn render_report(report: &ReplayReport) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let s = &report.stats;
    let _ = writeln!(
        out,
        "replay: tenant={} threads={} ops={} wall={:.3}s err_replies={}",
        report.tenant,
        report.threads,
        report.ops,
        report.wall.as_secs_f64(),
        report.err_replies,
    );
    let _ = writeln!(
        out,
        "stats: queries={} hits={} misses={} errors={} coalesced={} evictions={} hit_rate={:.4}",
        s.queries, s.hits, s.misses, s.errors, s.coalesced, s.evictions, s.hit_rate(),
    );
    for k in &report.kinds {
        let _ = writeln!(
            out,
            "  {:<8} ops={:<6} p50={:.1}us p99={:.1}us qps={:.1}",
            k.kind, k.count, k.p50_us, k.p99_us, k.qps,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::index::tree::{CoresetIndex, IndexConfig};
    use crate::index::IndexSnapshot;
    use crate::matroid::UniformMatroid;
    use crate::runtime::EngineKind;

    fn state_with_tenant() -> ServeState {
        let ds = synth::uniform_cube(400, 2, 61);
        let m = UniformMatroid::new(4);
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            ..IndexConfig::new(4, 8)
        };
        let mut idx = CoresetIndex::new(&ds, &m, cfg);
        idx.ingest(&(0..300).collect::<Vec<_>>(), 100).unwrap();
        let snap = IndexSnapshot::capture(&idx, "cube:400x2".into(), 61, "uniform:4".into(), 300);
        let state = ServeState::new(32);
        state.add("main", &snap).unwrap();
        state
    }

    #[test]
    fn synth_workload_is_deterministic_and_mixed() {
        let state = state_with_tenant();
        let a = synth_ops(&state, 500, 9).unwrap();
        let b = synth_ops(&state, 500, 9).unwrap();
        assert_eq!(a, b, "same seed, same ops");
        let c = synth_ops(&state, 500, 10).unwrap();
        assert_ne!(a, c, "different seed, different ops");
        let queries = a.iter().filter(|o| o.starts_with("QUERY")).count();
        let appends = a.iter().filter(|o| o.starts_with("APPEND")).count();
        let deletes = a.iter().filter(|o| o.starts_with("DELETE")).count();
        assert_eq!(queries + appends + deletes, 500);
        assert!(queries > 350, "queries dominate: {queries}");
        assert!(appends > 0 && deletes > 0, "mutations present: {appends}/{deletes}");
    }

    #[test]
    fn replay_runs_and_reports() {
        let state = state_with_tenant();
        let report = run_replay(&state, "synth:200", 4, 5).unwrap();
        assert_eq!(report.ops, 200);
        assert_eq!(report.tenant, "main");
        // every op got a reply; queries repeat within the pool, so the
        // cache + coalescing must have produced warm answers
        assert!(report.stats.queries >= 150);
        assert!(report.stats.hits + report.stats.coalesced > 0, "no warm answers at all");
        let all = &report.kinds[0];
        assert_eq!(all.kind, "all");
        assert_eq!(all.count, 200);
        assert!(all.p99_us >= all.p50_us);
        assert!(report.kinds.iter().any(|k| k.kind == "query"));

        let path = std::env::temp_dir()
            .join(format!("dmmc_replay_{}.csv", std::process::id()));
        write_replay_csv(path.to_str().unwrap(), &report).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(text.starts_with(
            "tenant,threads,kind,ops,p50_us,p99_us,qps,hits,misses,errors,coalesced,hit_rate"
        ));
        assert!(text.lines().count() >= 3, "header + all + at least one kind");

        // the bench trajectory carries the same registry the METRICS verb
        // renders, as JSON
        let json_path = std::env::temp_dir()
            .join(format!("dmmc_replay_{}.json", std::process::id()));
        write_replay_bench_json(json_path.to_str().unwrap(), &report, state.metrics()).unwrap();
        let json = std::fs::read_to_string(&json_path).unwrap();
        let _ = std::fs::remove_file(&json_path);
        assert!(json.starts_with("{\"schema_version\":1,\"bench\":\"serve\""));
        assert!(json.contains("\"name\":\"dmmc_replay_latency_seconds\""));
        assert!(json.contains("\"name\":\"dmmc_queries_total\""));
    }

    #[test]
    fn file_replay_and_bad_sources_error() {
        let state = state_with_tenant();
        assert!(run_replay(&state, "synth:zero", 1, 1).is_err());
        assert!(run_replay(&state, "/nonexistent/ops.txt", 1, 1).is_err());
        let path = std::env::temp_dir()
            .join(format!("dmmc_replay_ops_{}.txt", std::process::id()));
        std::fs::write(&path, "# comment\nQUERY main sum 3\n\nQUERY main sum 3\nPING\n").unwrap();
        let report = run_replay(&state, path.to_str().unwrap(), 2, 1).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(report.ops, 3);
        assert_eq!(report.err_replies, 0);
        assert_eq!(report.stats.queries, 2);
        assert_eq!(report.stats.misses, 1, "second identical query is warm");
    }
}
