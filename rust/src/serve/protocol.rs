//! The line-oriented `dmmc serve` request protocol.
//!
//! One request per line, whitespace-separated tokens, commands
//! case-insensitive; every reply starts `OK ` or `ERR ` (errors are
//! flattened to one line).  `METRICS` is the one multi-line reply: a
//! header line, the Prometheus text exposition, then a `# EOF`
//! terminator line.  The grammar is the wire twin of the `dmmc index`
//! subcommands:
//!
//! ```text
//! PING
//! TENANTS
//! LOAD <name> <path.dmmcx>
//! UNLOAD <name>
//! QUERY <tenant> <objective> <k> [finisher=ls|exhaustive|greedy|matching]
//!       [gamma=G] [engine=E] [matroid=M]
//! APPEND <tenant> [count] [segment=N]
//! DELETE <tenant> <rows>          # N or A..B, comma-separated
//! STATS <tenant>
//! METRICS                         # Prometheus exposition, ends `# EOF`
//! SAVE <tenant>
//! DEBUG <tenant> panic            # fault injection: panics in execute
//! QUIT                            # close this connection
//! SHUTDOWN                        # stop the whole server
//! ```
//!
//! `DEBUG ... panic` exists so the worker-pool panic containment is
//! testable over the wire without a deliberately buggy finisher: the
//! server must answer `ERR internal ...` and keep every worker alive.
//!
//! Query replies carry the diversity both human-readable (`div=`) and as
//! f64 hex bits (`bits=`), so a client can assert bit-identity of
//! cache/coalesced answers straight off the wire.

use anyhow::{bail, Context, Result};

use crate::cli::parse_rows;
use crate::coordinator::MatroidSpec;
use crate::diversity::Objective;
use crate::index::service::{QueryFinisher, QuerySpec};
use crate::runtime::EngineKind;
use crate::serve::state::ServeState;

/// A parsed protocol request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Tenants,
    Load { name: String, path: String },
    Unload { name: String },
    Query {
        tenant: String,
        objective: Objective,
        k: usize,
        finisher: QueryFinisher,
        /// `None` = the tenant's build engine.
        engine: Option<EngineKind>,
        matroid: Option<MatroidSpec>,
    },
    Append {
        tenant: String,
        count: Option<usize>,
        segment: Option<usize>,
    },
    Delete { tenant: String, rows: Vec<usize> },
    Stats { tenant: String },
    /// Render the server's metrics registry as Prometheus text.
    Metrics,
    Save { tenant: String },
    /// Fault injection (`DEBUG <tenant> panic`): deliberately panics
    /// inside `execute` to exercise the worker-pool containment path.
    Debug { tenant: String, action: String },
    Quit,
    Shutdown,
}

impl Request {
    /// The tenant a request addresses, when it addresses one — used by
    /// the panic-containment path to charge the failure to the right
    /// tenant's error counter.
    pub fn tenant(&self) -> Option<&str> {
        match self {
            Request::Load { name, .. } | Request::Unload { name } => Some(name),
            Request::Query { tenant, .. }
            | Request::Append { tenant, .. }
            | Request::Delete { tenant, .. }
            | Request::Stats { tenant }
            | Request::Save { tenant }
            | Request::Debug { tenant, .. } => Some(tenant),
            Request::Ping
            | Request::Tenants
            | Request::Metrics
            | Request::Quit
            | Request::Shutdown => None,
        }
    }
}

fn kv(tok: &str) -> Option<(&str, &str)> {
    tok.split_once('=')
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let toks: Vec<&str> = line.split_whitespace().collect();
    let cmd = toks.first().context("empty request")?.to_ascii_uppercase();
    let arg = |i: usize, what: &str| -> Result<&str> {
        toks.get(i).copied().with_context(|| format!("{cmd} needs {what}"))
    };
    match cmd.as_str() {
        "PING" => Ok(Request::Ping),
        "TENANTS" => Ok(Request::Tenants),
        "METRICS" => Ok(Request::Metrics),
        "QUIT" => Ok(Request::Quit),
        "SHUTDOWN" => Ok(Request::Shutdown),
        "LOAD" => Ok(Request::Load {
            name: arg(1, "a tenant name")?.to_string(),
            path: arg(2, "an index path")?.to_string(),
        }),
        "UNLOAD" => Ok(Request::Unload {
            name: arg(1, "a tenant name")?.to_string(),
        }),
        "STATS" => Ok(Request::Stats {
            tenant: arg(1, "a tenant name")?.to_string(),
        }),
        "SAVE" => Ok(Request::Save {
            tenant: arg(1, "a tenant name")?.to_string(),
        }),
        "DEBUG" => {
            let tenant = arg(1, "a tenant name")?.to_string();
            let action = arg(2, "an action (panic)")?.to_string();
            if action != "panic" {
                bail!("unknown DEBUG action {action} (panic)");
            }
            Ok(Request::Debug { tenant, action })
        }
        "DELETE" => Ok(Request::Delete {
            tenant: arg(1, "a tenant name")?.to_string(),
            rows: parse_rows(arg(2, "a row list")?)?,
        }),
        "APPEND" => {
            let tenant = arg(1, "a tenant name")?.to_string();
            let mut count = None;
            let mut segment = None;
            for tok in &toks[2..] {
                match kv(tok) {
                    Some(("segment", v)) => {
                        segment = Some(v.parse().with_context(|| format!("bad segment {v}"))?);
                    }
                    Some((k, _)) => bail!("unknown APPEND option {k} (segment=N)"),
                    None => {
                        if count.is_some() {
                            bail!("APPEND takes one count, got a second: {tok}");
                        }
                        count = Some(tok.parse().with_context(|| format!("bad count {tok}"))?);
                    }
                }
            }
            Ok(Request::Append { tenant, count, segment })
        }
        "QUERY" => {
            let tenant = arg(1, "a tenant name")?.to_string();
            let objective = Objective::parse(arg(2, "an objective")?).with_context(|| {
                format!("bad objective {} ({})", toks[2], Objective::names())
            })?;
            let k: usize = arg(3, "k")?.parse().with_context(|| format!("bad k {}", toks[3]))?;
            if k < 2 {
                bail!("bad k {k}: diversity queries need k >= 2");
            }
            let mut finisher_name: Option<&str> = None;
            let mut gamma = 0.0f64;
            let mut engine = None;
            let mut matroid = None;
            for tok in &toks[4..] {
                let Some((key, v)) = kv(tok) else {
                    bail!("QUERY options are key=value, got {tok}");
                };
                match key {
                    "finisher" => finisher_name = Some(v),
                    "gamma" => gamma = v.parse().with_context(|| format!("bad gamma {v}"))?,
                    "engine" => {
                        engine = Some(
                            EngineKind::parse(v).with_context(|| format!("bad engine {v}"))?,
                        );
                    }
                    "matroid" => matroid = Some(MatroidSpec::parse(v)?),
                    other => bail!("unknown QUERY option {other} (finisher|gamma|engine|matroid)"),
                }
            }
            // defaults: local search for sum (the only objective it
            // applies to), the matching race for remote-edge (its
            // purpose-built heuristic), greedy otherwise — exhaustive is
            // opt-in on a server (exponential in k)
            let finisher = match finisher_name {
                None => match objective {
                    Objective::Sum => QueryFinisher::LocalSearch { gamma },
                    Objective::RemoteEdge => QueryFinisher::Matching,
                    _ => QueryFinisher::Greedy,
                },
                Some("local-search") | Some("ls") => QueryFinisher::LocalSearch { gamma },
                Some("exhaustive") => QueryFinisher::Exhaustive,
                Some("greedy") => QueryFinisher::Greedy,
                Some("matching") => QueryFinisher::Matching,
                Some(other) => {
                    bail!("unknown finisher {other} (local-search|exhaustive|greedy|matching)")
                }
            };
            Ok(Request::Query { tenant, objective, k, finisher, engine, matroid })
        }
        other => bail!("unknown command {other} (PING TENANTS LOAD UNLOAD QUERY APPEND DELETE STATS METRICS SAVE DEBUG QUIT SHUTDOWN)"),
    }
}

/// Execute one request against the tenant registry, producing the `OK`
/// payload.  `QUIT`/`SHUTDOWN` are connection-level and never reach
/// execution.
pub fn execute(state: &ServeState, req: &Request) -> Result<String> {
    match req {
        Request::Ping => Ok("pong".to_string()),
        Request::Quit | Request::Shutdown => bail!("connection-level command reached execute"),
        Request::Tenants => {
            let names = state.names();
            Ok(format!("tenants {}", names.join(" ")).trim_end().to_string())
        }
        Request::Load { name, path } => {
            let tenant = state.load(name, std::path::Path::new(path))?;
            let st = tenant.status();
            Ok(format!(
                "loaded tenant={} epoch={} segments={} root={} warm={}",
                st.name, st.epoch, st.segments, st.root, st.cache_len
            ))
        }
        Request::Unload { name } => {
            state.unload(name)?;
            Ok(format!("unloaded tenant={name}"))
        }
        Request::Query { tenant, objective, k, finisher, engine, matroid } => {
            let t = state.get(tenant)?;
            let spec = QuerySpec {
                objective: *objective,
                k: *k,
                matroid: matroid.clone(),
                engine: engine.unwrap_or_else(|| t.engine()),
                finisher: *finisher,
            };
            let ans = t.query(&spec)?;
            let out = &ans.outcome;
            let sol: Vec<String> = out.result.solution.iter().map(|x| x.to_string()).collect();
            Ok(format!(
                "query tenant={} source={} epoch={} evals={} us={} div={:.6} bits={:x} k={} coreset={} sol={}",
                tenant,
                ans.source.name(),
                out.epoch,
                out.dist_evals.render(),
                out.elapsed.as_micros(),
                out.result.diversity,
                out.result.diversity.to_bits(),
                out.result.solution.len(),
                out.result.coreset_size,
                sol.join(","),
            ))
        }
        Request::Append { tenant, count, segment } => {
            let t = state.get(tenant)?;
            let a = t.append(*count, *segment)?;
            Ok(format!(
                "append tenant={} requested={} appended={} clamped={} segments={} epoch={} root={}",
                tenant,
                a.requested.map(|r| r.to_string()).unwrap_or_else(|| "all".to_string()),
                a.appended,
                a.clamped,
                a.segments,
                a.epoch,
                a.root,
            ))
        }
        Request::Delete { tenant, rows } => {
            let t = state.get(tenant)?;
            let d = t.delete(rows)?;
            Ok(format!(
                "delete tenant={} requested={} newly_dead={} rebuilds={} root={} epoch={}",
                tenant,
                rows.len(),
                d.receipt.newly_dead,
                d.receipt.rebuilds,
                d.receipt.root_size,
                d.epoch,
            ))
        }
        Request::Stats { tenant } => {
            let st = state.get(tenant)?.status();
            let s = st.stats;
            Ok(format!(
                "stats tenant={} queries={} hits={} misses={} errors={} coalesced={} \
                 evictions={} hit_rate={:.4} cache={} epoch={} segments={} points={} root={} \
                 tombstones={} cursor={}",
                st.name,
                s.queries,
                s.hits,
                s.misses,
                s.errors,
                s.coalesced,
                s.evictions,
                s.hit_rate(),
                st.cache_len,
                st.epoch,
                st.segments,
                st.points,
                st.root,
                st.tombstones,
                st.cursor,
            ))
        }
        Request::Metrics => {
            // refresh the point-in-time gauges from each tenant's status
            // before rendering (counters and histograms are live already)
            for name in state.names() {
                let Ok(tenant) = state.get(&name) else { continue };
                let st = tenant.status();
                let m = state.metrics();
                let lbl = [("tenant", name.as_str())];
                m.gauge("dmmc_tenant_epoch", &lbl).set(st.epoch as f64);
                m.gauge("dmmc_index_live_fraction", &lbl).set(st.live_fraction);
                m.gauge("dmmc_index_root_size", &lbl).set(st.root as f64);
                m.gauge("dmmc_cache_entries", &lbl).set(st.cache_len as f64);
            }
            let text = state.metrics().render_prometheus();
            // multi-line payload: header, exposition, `# EOF` terminator —
            // clients read lines until the terminator
            Ok(format!("metrics lines={}\n{text}# EOF", text.lines().count()))
        }
        Request::Save { tenant } => {
            let t = state.get(tenant)?;
            let (path, entries) = t.save()?;
            Ok(format!("saved tenant={} path={} entries={}", tenant, path.display(), entries))
        }
        Request::Debug { tenant, action } => {
            // unknown tenant is a normal error; a known tenant panics on
            // purpose so tests can poison a worker deterministically
            state.get(tenant)?;
            match action.as_str() {
                "panic" => panic!("DEBUG {tenant} panic: injected fault"),
                other => bail!("unknown DEBUG action {other} (panic)"),
            }
        }
    }
}

/// Flatten an error chain to one protocol-safe line.
pub fn flatten_error(e: &anyhow::Error) -> String {
    format!("{e:#}").replace('\n', " ")
}

/// Parse + execute one line into a full reply line (`OK ...` / `ERR ...`).
pub fn handle_line(state: &ServeState, line: &str) -> String {
    match parse_request(line).and_then(|req| execute(state, &req)) {
        Ok(payload) => format!("OK {payload}"),
        Err(e) => format!("ERR {}", flatten_error(&e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_grammar() {
        assert_eq!(parse_request("ping").unwrap(), Request::Ping);
        assert_eq!(parse_request("  SHUTDOWN  ").unwrap(), Request::Shutdown);
        assert_eq!(
            parse_request("LOAD main /tmp/x.dmmcx").unwrap(),
            Request::Load { name: "main".into(), path: "/tmp/x.dmmcx".into() }
        );
        let q = parse_request("QUERY main sum 4 finisher=greedy engine=scalar").unwrap();
        match q {
            Request::Query { tenant, objective, k, finisher, engine, matroid } => {
                assert_eq!(tenant, "main");
                assert_eq!(objective, Objective::Sum);
                assert_eq!(k, 4);
                assert_eq!(finisher, QueryFinisher::Greedy);
                assert_eq!(engine, Some(EngineKind::Scalar));
                assert!(matroid.is_none());
            }
            other => panic!("parsed {other:?}"),
        }
        // defaults: sum -> local search, non-sum -> greedy
        match parse_request("QUERY main sum 4").unwrap() {
            Request::Query { finisher, .. } => {
                assert_eq!(finisher, QueryFinisher::LocalSearch { gamma: 0.0 });
            }
            other => panic!("parsed {other:?}"),
        }
        match parse_request("QUERY main tree 3").unwrap() {
            Request::Query { finisher, .. } => assert_eq!(finisher, QueryFinisher::Greedy),
            other => panic!("parsed {other:?}"),
        }
        // remote-edge parses on the wire and defaults to the matching race
        match parse_request("QUERY main remote-edge 4").unwrap() {
            Request::Query { objective, finisher, .. } => {
                assert_eq!(objective, Objective::RemoteEdge);
                assert_eq!(finisher, QueryFinisher::Matching);
            }
            other => panic!("parsed {other:?}"),
        }
        match parse_request("QUERY main tree 3 finisher=matching").unwrap() {
            Request::Query { finisher, .. } => assert_eq!(finisher, QueryFinisher::Matching),
            other => panic!("parsed {other:?}"),
        }
        assert_eq!(
            parse_request("DEBUG main panic").unwrap(),
            Request::Debug { tenant: "main".into(), action: "panic".into() }
        );
        assert_eq!(
            parse_request("APPEND main 500 segment=100").unwrap(),
            Request::Append { tenant: "main".into(), count: Some(500), segment: Some(100) }
        );
        assert_eq!(
            parse_request("APPEND main").unwrap(),
            Request::Append { tenant: "main".into(), count: None, segment: None }
        );
        assert_eq!(
            parse_request("DELETE main 1,4..6").unwrap(),
            Request::Delete { tenant: "main".into(), rows: vec![1, 4, 5] }
        );
        assert_eq!(parse_request("metrics").unwrap(), Request::Metrics);
        assert_eq!(Request::Metrics.tenant(), None);
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(parse_request("").is_err());
        assert!(parse_request("FROB").is_err());
        assert!(parse_request("QUERY").is_err());
        assert!(parse_request("QUERY main").is_err());
        assert!(parse_request("QUERY main sum").is_err());
        assert!(parse_request("QUERY main sum four").is_err());
        assert!(parse_request("QUERY main sum 4 bogus").is_err());
        assert!(parse_request("QUERY main sum 4 finisher=magic").is_err());
        assert!(parse_request("APPEND main 10 20").is_err());
        assert!(parse_request("DELETE main").is_err());
        assert!(parse_request("DELETE main 9..3").is_err());
        assert!(parse_request("DEBUG main frobnicate").is_err());
    }

    #[test]
    fn parse_errors_enumerate_valid_names() {
        // an unknown objective/finisher names every valid choice, so a
        // new variant missing from one surface is caught by eye (and by
        // these pins)
        let obj_err = format!("{:#}", parse_request("QUERY main maxmin 4").unwrap_err());
        assert!(
            obj_err.contains("sum|star|tree|cycle|bipartition|remote-edge"),
            "{obj_err}"
        );
        let fin_err =
            format!("{:#}", parse_request("QUERY main sum 4 finisher=magic").unwrap_err());
        assert!(
            fin_err.contains("local-search|exhaustive|greedy|matching"),
            "{fin_err}"
        );
    }

    #[test]
    fn small_k_query_is_a_structured_error() {
        // k=1 used to reach the farness assert and panic the handler;
        // now it is rejected at the protocol boundary
        let err = format!("{:#}", parse_request("QUERY main sum 1").unwrap_err());
        assert!(err.contains("k >= 2"), "{err}");
        let state = ServeState::new(4);
        let reply = handle_line(&state, "QUERY main remote-edge 1");
        assert!(reply.starts_with("ERR "), "{reply}");
        assert!(reply.contains("k >= 2"), "{reply}");
    }

    #[test]
    fn handle_line_wraps_ok_and_err() {
        let state = ServeState::new(4);
        assert_eq!(handle_line(&state, "PING"), "OK pong");
        assert_eq!(handle_line(&state, "TENANTS"), "OK tenants");
        let err = handle_line(&state, "QUERY missing sum 4");
        assert!(err.starts_with("ERR "), "{err}");
        assert!(!err.contains('\n'));
    }

    #[test]
    fn metrics_reply_is_terminated_exposition() {
        // empty server: the one multi-line reply still carries its header
        // and `# EOF` terminator, so wire clients always know where to stop
        let state = ServeState::new(4);
        assert_eq!(handle_line(&state, "METRICS"), "OK metrics lines=0\n# EOF");
        state.metrics().counter("dmmc_queries_total", &[("tenant", "t")]).add(3);
        let reply = handle_line(&state, "METRICS");
        assert!(reply.starts_with("OK metrics lines=2\n"), "{reply}");
        assert!(reply.contains("# TYPE dmmc_queries_total counter\n"), "{reply}");
        assert!(reply.contains("dmmc_queries_total{tenant=\"t\"} 3\n"), "{reply}");
        assert!(reply.ends_with("# EOF"), "{reply}");
    }
}
