//! `dmmc serve` — a long-running multi-tenant query server over
//! persisted coreset indexes.
//!
//! The paper's serving story ends with a standing summary answering
//! expensive diversity queries cheaply; this subsystem is the process
//! around that summary.  It is std-only (no async runtime): `std::net`
//! sockets, a scoped worker-thread pool, and per-tenant locks.
//!
//! * [`state`] — the tenant registry: each [`state::Tenant`] owns a
//!   reconstructed dataset/matroid world plus the tree state, serializes
//!   appends/deletes behind a write lock, and serves queries behind the
//!   shared [`crate::index::service::ResultCache`] with **in-flight
//!   coalescing**: concurrent identical `(spec, epoch)` requests ride one
//!   cold computation and all receive the bit-identical result.
//! * [`protocol`] — the line-oriented request grammar
//!   (`QUERY`/`APPEND`/`DELETE`/`LOAD`/`STATS`/`METRICS`/...), its
//!   parser, and the executor that turns requests into `OK`/`ERR`
//!   replies (single-line except `METRICS`, which renders the server's
//!   [`crate::obs::MetricsRegistry`] as Prometheus text ending `# EOF`).
//! * [`server`] — the TCP front end: accept loop + fixed worker pool,
//!   clean `SHUTDOWN` via a stop flag and a loopback self-connect.
//! * [`replay`] — the load harness behind `dmmc serve --replay`:
//!   thousands of mixed ops, p50/p99 latency, QPS, and hit-rate into
//!   `bench_results/serve_load.csv`.
//!
//! Restarts stay warm: `SAVE` persists each tenant's snapshot plus a
//! result-cache sidecar keyed on the snapshot's content id
//! ([`crate::index::store::snapshot_id`]), and loading a tenant replays
//! matching sidecar entries into its cache.

pub mod protocol;
pub mod replay;
pub mod server;
pub mod state;

/// Render a `catch_unwind` payload as a one-line message (panic payloads
/// are `&str` or `String` in practice; anything else is opaque).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

pub use protocol::{execute, handle_line, parse_request, Request};
pub use replay::{run_replay, write_replay_csv, ReplayReport};
pub use server::{serve, spawn, ServerHandle, DEFAULT_WORKERS};
pub use state::{
    AppendSummary, DeleteSummary, InflightSlot, QuerySource, ServeState, Tenant, TenantAnswer,
    TenantStatus,
};
