//! The `dmmc serve` TCP front end: std-only (`std::net` + a scoped
//! worker-thread pool), one protocol line per request.
//!
//! Concurrency shape: the accept loop hands connections to a fixed pool
//! of workers over an mpsc channel (the receiver shared behind a mutex —
//! the classic std-only work queue).  Each worker owns one connection at
//! a time and serves its requests sequentially; cross-connection
//! concurrency is what exercises the tenants' coalescing and serialized
//! mutations.  `SHUTDOWN` sets the stop flag and pokes the listener with
//! a loopback connect so the blocking `accept` wakes and the scope can
//! join.
//!
//! Panic containment: the pool is fixed-size, so an uncontained panic
//! would permanently shrink it.  Every request executes under
//! `catch_unwind` — a panicking handler answers `ERR internal ...`,
//! charges the tenant's error counter, and the worker keeps serving
//! (pinned by the poisoned-request pool-survival test).

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

use anyhow::{Context, Result};

use crate::serve::protocol::{execute, flatten_error, parse_request, Request};
use crate::serve::state::ServeState;

/// Default worker-pool size.
pub const DEFAULT_WORKERS: usize = 4;

/// Run the server until a `SHUTDOWN` request arrives.  Blocks the
/// calling thread; connections are served by `workers` scoped threads.
pub fn serve(state: &ServeState, listener: TcpListener, workers: usize) -> Result<()> {
    let local = listener.local_addr().context("server local addr")?;
    let stop = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<TcpStream>();
    let rx = Mutex::new(rx);
    thread::scope(|s| {
        for _ in 0..workers.max(1) {
            s.spawn(|| worker_loop(state, &rx, &stop, local));
        }
        for stream in listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            if tx.send(stream).is_err() {
                break;
            }
        }
        // closing the channel ends every idle worker's recv
        drop(tx);
    });
    Ok(())
}

fn worker_loop(
    state: &ServeState,
    rx: &Mutex<mpsc::Receiver<TcpStream>>,
    stop: &AtomicBool,
    local: SocketAddr,
) {
    loop {
        // take the lock only to dequeue, never while serving
        let stream = match rx.lock().unwrap().recv() {
            Ok(stream) => stream,
            Err(_) => break,
        };
        // a broken connection only ends that connection, and a panic
        // that escapes the per-request containment only ends that
        // connection too — the pool never shrinks
        let _ = catch_unwind(AssertUnwindSafe(|| handle_conn(state, stream, stop, local)));
    }
}

fn handle_conn(
    state: &ServeState,
    stream: TcpStream,
    stop: &AtomicBool,
    local: SocketAddr,
) -> Result<()> {
    let reader_half = stream.try_clone().context("clone connection")?;
    let mut reader = BufReader::new(reader_half);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break; // EOF: client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match parse_request(trimmed) {
            Ok(Request::Quit) => {
                writeln!(writer, "OK bye")?;
                writer.flush()?;
                break;
            }
            Ok(Request::Shutdown) => {
                writeln!(writer, "OK shutting down")?;
                writer.flush()?;
                stop.store(true, Ordering::SeqCst);
                // wake the blocking accept so the scope can join
                let _ = TcpStream::connect(local);
                break;
            }
            Ok(req) => {
                let reply = match catch_unwind(AssertUnwindSafe(|| execute(state, &req))) {
                    Ok(Ok(payload)) => format!("OK {payload}"),
                    Ok(Err(e)) => format!("ERR {}", flatten_error(&e)),
                    Err(payload) => {
                        // contained panic: reply like any other error and
                        // charge the addressed tenant's error counter
                        if let Some(t) = req.tenant().and_then(|n| state.get(n).ok()) {
                            t.record_error();
                        }
                        let msg = crate::serve::panic_message(payload.as_ref());
                        format!("ERR internal {}", msg.replace('\n', " "))
                    }
                };
                writeln!(writer, "{reply}")?;
                writer.flush()?;
            }
            Err(e) => {
                writeln!(writer, "ERR {}", flatten_error(&e))?;
                writer.flush()?;
            }
        }
    }
    Ok(())
}

/// A background server for tests: bound to an ephemeral loopback port.
pub struct ServerHandle {
    pub addr: SocketAddr,
    thread: thread::JoinHandle<Result<()>>,
}

/// Spawn a server on `127.0.0.1:0` (kernel-assigned port).
pub fn spawn(state: Arc<ServeState>, workers: usize) -> Result<ServerHandle> {
    let listener = TcpListener::bind("127.0.0.1:0").context("bind loopback")?;
    let addr = listener.local_addr()?;
    let thread = thread::spawn(move || serve(&state, listener, workers));
    Ok(ServerHandle { addr, thread })
}

impl ServerHandle {
    /// Send `SHUTDOWN`, wait for the ack, and join the server thread.
    pub fn shutdown(self) -> Result<()> {
        let stream = TcpStream::connect(self.addr).context("connect for shutdown")?;
        let mut writer = BufWriter::new(stream.try_clone()?);
        writer.write_all(b"SHUTDOWN\n")?;
        writer.flush()?;
        let mut ack = String::new();
        BufReader::new(stream).read_line(&mut ack)?;
        drop(writer);
        match self.thread.join() {
            Ok(result) => result,
            Err(_) => anyhow::bail!("server thread panicked"),
        }
    }
}
