//! Mini bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are plain `fn main()` binaries (`harness = false`)
//! that use this module to (a) time experiment configurations, (b) print
//! paper-style tables to stdout, and (c) append machine-readable CSV under
//! `bench_results/`.

pub mod scenarios;

use std::time::Instant;

use anyhow::{Context, Result};

use crate::obs::metrics::MetricsRegistry;
use crate::util::stats::Summary;

/// Time one closure, returning (result, seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Repeat a closure `iters` times after `warmup` runs; summarize seconds.
pub fn bench_repeat<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, fields: Vec<String>) {
        assert_eq!(fields.len(), self.headers.len(), "table row width");
        self.rows.push(fields);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(c, s)| format!("{:width$}", s, width = widths[c]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Standard bench header: prints the context line every bench target emits.
pub fn bench_header(name: &str, description: &str) {
    println!("\n=== {name} ===");
    println!("{description}");
}

/// Write a `bench_results/BENCH_*.json` trajectory (schema_version 1, see
/// EXPERIMENTS.md): run metadata (a pre-rendered JSON object) plus a full
/// snapshot of a metrics registry — the envelope every machine-readable
/// bench artifact shares, so downstream tooling parses one shape.
pub fn write_bench_json(
    path: &str,
    bench: &str,
    meta_json: &str,
    registry: &MetricsRegistry,
) -> Result<()> {
    let body = format!(
        "{{\"schema_version\":1,\"bench\":{},\"meta\":{meta_json},\"metrics\":{}}}\n",
        crate::obs::metrics::json_string(bench),
        registry.render_json(),
    );
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, body).with_context(|| format!("write bench json {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_measures() {
        let (v, secs) = time_once(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_repeat_summary() {
        let s = bench_repeat(1, 5, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert_eq!(s.n, 5);
        assert!(s.min >= 0.001);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["algo", "time"]);
        t.row(vec!["seqcoreset".into(), "1.5".into()]);
        t.row(vec!["amt".into(), "120.0".into()]);
        let r = t.render();
        assert!(r.contains("seqcoreset"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
