//! Shared scenario setup for the `cargo bench` targets: the Table-2
//! dataset stand-ins at bench scale, environment knobs, and the faithful
//! AMT baseline configuration.
//!
//! Env knobs (all optional):
//!   DMMC_BENCH_N      points per full dataset        (default 60_000)
//!   DMMC_BENCH_RUNS   repetitions for boxplot rows   (default 5)
//!   DMMC_BENCH_SEED   base seed                      (default 1)
//!   DMMC_BENCH_ENGINE backend for the fig benches    (default batch;
//!                     scalar|batch|simd|pjrt — the registry A/B flag)

use crate::algo::local_search::{
    local_search_sum, LocalSearchMode, LocalSearchParams, LocalSearchResult,
};
use crate::core::Dataset;
use crate::coordinator::spec::MatroidBox;
use crate::data::synth;
use crate::matroid::{maximal_independent, Matroid};
use crate::runtime::{build_engine, DistanceEngine, EngineKind};
use crate::util::rng::Rng;

pub fn bench_n() -> usize {
    std::env::var("DMMC_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60_000)
}

pub fn bench_runs() -> usize {
    std::env::var("DMMC_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5)
}

pub fn bench_seed() -> u64 {
    std::env::var("DMMC_BENCH_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

/// Backend the fig benches run on (`DMMC_BENCH_ENGINE`, default batch) —
/// one env flag A/Bs every scenario across the registry.
pub fn bench_engine_kind() -> EngineKind {
    std::env::var("DMMC_BENCH_ENGINE")
        .ok()
        .and_then(|v| EngineKind::parse(&v))
        .unwrap_or_default()
}

/// Registry-built engine of [`bench_engine_kind`] for `ds`.
pub fn bench_engine(ds: &Dataset) -> Box<dyn DistanceEngine> {
    build_engine(bench_engine_kind(), ds).expect("bench engine construction")
}

/// One experimental testbed: a dataset + its natural matroid (Table 2).
pub struct Testbed {
    pub name: &'static str,
    pub ds: Dataset,
    pub matroid: MatroidBox,
    pub rank: usize,
}

/// The two Table-2 stand-ins at `n` points each.
pub fn testbeds(n: usize, seed: u64) -> Vec<Testbed> {
    let wiki = synth::wikisim(n, seed);
    let wiki_m: MatroidBox = Box::new(crate::matroid::TransversalMatroid::new());
    let wiki_rank = wiki_m.rank_bound(&wiki);
    let songs = synth::songsim(n, seed);
    let songs_m: MatroidBox = Box::new(synth::songsim_matroid(&songs, 89));
    let songs_rank = songs_m.rank_bound(&songs);
    vec![
        Testbed {
            name: "wikisim",
            ds: wiki,
            matroid: wiki_m,
            rank: wiki_rank,
        },
        Testbed {
            name: "songsim",
            ds: songs,
            matroid: songs_m,
            rank: songs_rank,
        },
    ]
}

/// The paper's AMT baseline, run faithfully: local search over `candidates`
/// from a RANDOM maximal independent start (not the strong farthest-point
/// init the coreset route uses) with swap threshold gamma.  Runs the
/// default incremental sum maintenance; [`amt_baseline_with_mode`] lets the
/// benches put the exhaustive-restart reference on the same footing.
pub fn amt_baseline(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    candidates: &[usize],
    gamma: f64,
    seed: u64,
) -> LocalSearchResult {
    amt_baseline_with_mode(ds, m, k, candidates, gamma, seed, LocalSearchMode::Incremental)
}

/// [`amt_baseline`] with an explicit [`LocalSearchMode`] — both modes walk
/// the identical swap trajectory, so timing them against each other
/// isolates the incremental update's distance-work savings.
pub fn amt_baseline_with_mode(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    candidates: &[usize],
    gamma: f64,
    seed: u64,
    mode: LocalSearchMode,
) -> LocalSearchResult {
    let mut rng = Rng::new(seed);
    let mut order = candidates.to_vec();
    rng.shuffle(&mut order);
    let init = maximal_independent(&m, ds, &order, k);
    local_search_sum(
        ds,
        m,
        k,
        candidates,
        &*bench_engine(ds),
        LocalSearchParams {
            gamma,
            max_swaps: 100_000,
            mode,
            ..Default::default()
        },
        Some(init),
        &mut rng,
    )
    .expect("AMT local search")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diversity::sum_diversity;

    #[test]
    fn testbeds_match_table2_shape() {
        let beds = testbeds(2000, 1);
        assert_eq!(beds.len(), 2);
        assert_eq!(beds[0].name, "wikisim");
        assert_eq!(beds[0].rank, 100);
        assert!((80..=110).contains(&beds[1].rank), "{}", beds[1].rank);
    }

    #[test]
    fn amt_baseline_feasible() {
        let beds = testbeds(500, 2);
        for bed in &beds {
            let k = (bed.rank / 4).max(2).min(8);
            let cands: Vec<usize> = (0..bed.ds.n()).collect();
            let res = amt_baseline(&bed.ds, &bed.matroid, k, &cands, 0.0, 3);
            assert_eq!(res.solution.len(), k);
            assert!((res.diversity - sum_diversity(&bed.ds, &res.solution)).abs() < 1e-9);
        }
    }
}
