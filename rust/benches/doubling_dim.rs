//! Theorem 4 empirically: on a dataset of doubling dimension D, the
//! optimal tau-clustering radius obeys r*_tau <= 2*Delta / tau^(1/D) —
//! i.e. log(radius) falls with slope ~ -1/D in log(tau).  GMM (a
//! 2-approximation) must track that envelope, which is exactly what makes
//! the coreset sizes of §3.2 independent of n.  We fit the slope on
//! uniform cubes of dimension 1..4 and report it against -1/D.

use matroid_coreset::algo::gmm::{gmm, GmmStop};
use matroid_coreset::bench::scenarios::bench_seed;
use matroid_coreset::bench::{bench_header, Table};
use matroid_coreset::csv_row;
use matroid_coreset::data::synth;
use matroid_coreset::runtime::ScalarEngine;
use matroid_coreset::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let seed = bench_seed();
    bench_header(
        "doubling_dim",
        "Theorem 4: GMM radius vs tau on cubes of doubling dimension D (slope ~ -1/D)",
    );
    let mut csv = CsvWriter::create(
        "bench_results/doubling_dim.csv",
        &["dim", "tau", "radius"],
    )?;
    let n = 20_000;
    let taus = [4usize, 8, 16, 32, 64, 128, 256];
    let mut table = Table::new(&["D", "fitted slope", "theory (-1/D)", "radii tau=4..256"]);
    for dim in 1..=4usize {
        let ds = synth::uniform_cube(n, dim, seed);
        let mut logs: Vec<(f64, f64)> = Vec::new();
        let mut radii = Vec::new();
        for &tau in &taus {
            let c = gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(tau)).unwrap();
            logs.push(((tau as f64).ln(), c.radius.max(1e-12).ln()));
            radii.push(format!("{:.3}", c.radius));
            csv.row(&csv_row![dim, tau, c.radius])?;
        }
        // least-squares slope of log radius vs log tau
        let mx = logs.iter().map(|p| p.0).sum::<f64>() / logs.len() as f64;
        let my = logs.iter().map(|p| p.1).sum::<f64>() / logs.len() as f64;
        let slope = logs.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum::<f64>()
            / logs.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum::<f64>();
        table.row(csv_row![
            dim,
            format!("{slope:.3}"),
            format!("{:.3}", -1.0 / dim as f64),
            radii.join(" ")
        ]);
        // the fitted decay must be within a band of the theory slope
        let theory = -1.0 / dim as f64;
        assert!(
            (slope - theory).abs() < 0.45 * theory.abs() + 0.05,
            "dim {dim}: slope {slope} far from theory {theory}"
        );
    }
    table.print();
    csv.flush()?;
    println!("\nCSV -> bench_results/doubling_dim.csv");
    Ok(())
}
