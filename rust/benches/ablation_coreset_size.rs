//! Ablation: how tight are the O(k tau) / O(k^2 tau) coreset-size bounds
//! (Theorems 1-2) in practice?  The paper remarks (§3.1.2) that the k^2
//! bound is "a rather conservative worst-case estimate" — this bench
//! quantifies that across matroid types, tau and k, for both the
//! sequential and streaming constructions.

use matroid_coreset::algo::seq_coreset::seq_coreset;
use matroid_coreset::algo::stream_coreset::stream_coreset_tau;
use matroid_coreset::algo::Budget;
use matroid_coreset::bench::scenarios::bench_seed;
use matroid_coreset::bench::{bench_header, Table};
use matroid_coreset::csv_row;
use matroid_coreset::data::synth;
use matroid_coreset::matroid::{Matroid, TransversalMatroid};
use matroid_coreset::runtime::ScalarEngine;
use matroid_coreset::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let seed = bench_seed();
    bench_header(
        "ablation_coreset_size",
        "Coreset size vs the Theorem 1/2 bounds (k*tau and k^2*tau), seq + stream",
    );
    let mut csv = CsvWriter::create(
        "bench_results/ablation_size.csv",
        &["matroid", "construction", "tau", "k", "size", "bound", "fill"],
    )?;
    let n = 30_000;
    let engine = ScalarEngine::new();

    let wiki = synth::wikisim(n, seed);
    let trans = TransversalMatroid::new();
    let songs = synth::songsim(n, seed);
    let part = synth::songsim_matroid(&songs, 89);

    let mut table = Table::new(&["matroid", "construction", "tau", "k", "|T|", "bound", "fill%"]);
    for tau in [16usize, 64] {
        for k in [5usize, 25] {
            // partition: bound k*tau
            let cs = seq_coreset(&songs, &part, k, Budget::Clusters(tau), &engine)?;
            let bound = k * tau;
            table.row(csv_row![
                "partition", "seq", tau, k, cs.len(), bound,
                format!("{:.1}", 100.0 * cs.len() as f64 / bound as f64)
            ]);
            csv.row(&csv_row!["partition", "seq", tau, k, cs.len(), bound,
                cs.len() as f64 / bound as f64])?;

            let order: Vec<usize> = (0..songs.n()).collect();
            let (scs, _) = stream_coreset_tau(&songs, &part, k, tau, &order);
            table.row(csv_row![
                "partition", "stream", tau, k, scs.len(), bound,
                format!("{:.1}", 100.0 * scs.len() as f64 / bound as f64)
            ]);
            csv.row(&csv_row!["partition", "stream", tau, k, scs.len(), bound,
                scs.len() as f64 / bound as f64])?;

            // transversal: bound gamma * k^2 * tau (gamma = 4 max topics/pt)
            let cs = seq_coreset(&wiki, &trans, k, Budget::Clusters(tau), &engine)?;
            let bound = 4 * k * k * tau;
            table.row(csv_row![
                "transversal", "seq", tau, k, cs.len(), bound,
                format!("{:.1}", 100.0 * cs.len() as f64 / bound as f64)
            ]);
            csv.row(&csv_row!["transversal", "seq", tau, k, cs.len(), bound,
                cs.len() as f64 / bound as f64])?;

            let order: Vec<usize> = (0..wiki.n()).collect();
            let (scs, _) = stream_coreset_tau(&wiki, &trans, k, tau, &order);
            table.row(csv_row![
                "transversal", "stream", tau, k, scs.len(), bound,
                format!("{:.1}", 100.0 * scs.len() as f64 / bound as f64)
            ]);
            csv.row(&csv_row!["transversal", "stream", tau, k, scs.len(), bound,
                scs.len() as f64 / bound as f64])?;
        }
    }
    table.print();
    println!("\nfill% << 100 confirms the paper's remark that the worst-case bounds are loose.");
    csv.flush()?;
    println!("CSV -> bench_results/ablation_size.csv");
    Ok(())
}
