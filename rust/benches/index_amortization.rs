//! Index amortization — the serving scenario the composable coreset index
//! exists for: N `(objective, k)` queries against one dataset.
//!
//! Three columns per testbed:
//!
//! * `pipeline xN`  — the status quo: N independent `run_pipeline` calls,
//!   each rebuilding its coreset from scratch;
//! * `index+query`  — one tree build (`CoresetIndex::ingest`), then the N
//!   queries served from the root coreset (cold cache);
//! * `index cached` — the same N queries repeated, all cache hits.
//!
//! Plus an append-latency profile: per-append wall time and nodes touched
//! as the segment count grows (the O(log segments) claim, measured).
//!
//! Env knobs are the shared ones (`DMMC_BENCH_N`, `DMMC_BENCH_RUNS`,
//! `DMMC_BENCH_SEED`, `DMMC_BENCH_ENGINE`).

use matroid_coreset::algo::Budget;
use matroid_coreset::bench::scenarios::{bench_engine_kind, bench_n, bench_seed, testbeds};
use matroid_coreset::bench::{bench_header, time_once, Table};
use matroid_coreset::coordinator::{run_pipeline, Finisher, Pipeline, Setting};
use matroid_coreset::csv_row;
use matroid_coreset::diversity::Objective;
use matroid_coreset::index::{CoresetIndex, IndexConfig, QueryService, QuerySpec};
use matroid_coreset::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let n = bench_n();
    let seed = bench_seed();
    let ekind = bench_engine_kind();
    let tau = 64usize;
    bench_header(
        "index_amortization",
        &format!(
            "Query service vs repeated pipelines (n={n}, tau={tau}, engine={})",
            ekind.name()
        ),
    );
    let mut csv = CsvWriter::create(
        "bench_results/index_amortization.csv",
        &["dataset", "mode", "queries", "total_s", "per_query_s", "diversity_k4"],
    )?;
    let mut append_csv = CsvWriter::create(
        "bench_results/index_append.csv",
        &["dataset", "segment", "nodes_touched", "dist_evals", "append_s", "root_size"],
    )?;
    let mut delete_csv = CsvWriter::create(
        "bench_results/index_delete.csv",
        &[
            "dataset",
            "rows_deleted",
            "newly_dead",
            "nodes_touched",
            "rebuilds",
            "dist_evals",
            "delete_s",
            "live_fraction",
            "root_size",
            "postdelete_query_s",
        ],
    )?;

    for bed in testbeds(n, seed) {
        let k_max = (bed.rank / 4).max(4);
        // the query mix: a small k-sweep, the repeated-traffic shape the
        // index amortizes (every query shares the one root coreset)
        let ks: Vec<usize> = [2usize, 3, 4, 6, 8]
            .into_iter()
            .filter(|&k| k <= k_max)
            .collect();
        let segment = (bed.ds.n() / 16).max(1);

        // -- status quo: one full pipeline per query ---------------------
        let mut div_k4 = 0.0f64;
        let (_, pipeline_s) = time_once(|| {
            for &k in &ks {
                let out = run_pipeline(
                    &bed.ds,
                    &bed.matroid,
                    k,
                    Objective::Sum,
                    Pipeline {
                        setting: Setting::Seq {
                            budget: Budget::Clusters(tau),
                        },
                        finisher: Finisher::LocalSearch { gamma: 0.0 },
                        engine: ekind,
                    },
                    seed,
                )
                .expect("pipeline");
                if k == 4 {
                    div_k4 = out.diversity;
                }
            }
        });

        // -- index build + cold queries + cached repeats -----------------
        let cfg = IndexConfig {
            leaf_budget: Budget::Clusters(tau),
            reduce_budget: Budget::Clusters(tau),
            engine: ekind,
            ..IndexConfig::new(k_max, tau)
        };
        let order: Vec<usize> = (0..bed.ds.n()).collect();
        let mut index = CoresetIndex::new(&bed.ds, &*bed.matroid, cfg);
        let (receipts, build_s) = time_once(|| {
            order
                .chunks(segment)
                .map(|chunk| {
                    let (r, dt) = time_once(|| index.append(chunk).expect("append"));
                    (r, dt)
                })
                .collect::<Vec<_>>()
        });
        for (r, dt) in &receipts {
            append_csv.row(&csv_row![
                bed.name, r.segment, r.nodes_touched, r.dist_evals, dt, r.root_size
            ])?;
        }
        let mut service = QueryService::new(index);
        let mut idx_div_k4 = 0.0f64;
        let (_, cold_s) = time_once(|| {
            for &k in &ks {
                let out = service
                    .query(&QuerySpec::sum_local_search(k, ekind))
                    .expect("query");
                assert!(!out.cache_hit);
                if k == 4 {
                    idx_div_k4 = out.result.diversity;
                }
            }
        });
        let (_, cached_s) = time_once(|| {
            for &k in &ks {
                let out = service
                    .query(&QuerySpec::sum_local_search(k, ekind))
                    .expect("query");
                assert!(out.cache_hit);
            }
        });

        // -- delete phase: tombstone a quarter of the ingest, remeasure --
        let victims: Vec<usize> = (0..bed.ds.n() / 4).collect();
        let (dr, delete_s) = time_once(|| service.delete(&victims).expect("delete"));
        let (_, postdel_s) = time_once(|| {
            for &k in &ks {
                let out = service
                    .query(&QuerySpec::sum_local_search(k, ekind))
                    .expect("query");
                assert!(!out.cache_hit, "delete must invalidate the cache");
            }
        });
        delete_csv.row(&csv_row![
            bed.name,
            victims.len(),
            dr.newly_dead,
            dr.nodes_touched,
            dr.rebuilds,
            dr.dist_evals,
            delete_s,
            service.index().live_fraction(),
            dr.root_size,
            postdel_s
        ])?;

        let nq = ks.len();
        let mut table = Table::new(&["mode", "total_s", "per_query_s", "diversity(k=4)"]);
        table.row(csv_row![
            format!("pipeline x{nq}"),
            format!("{pipeline_s:.3}"),
            format!("{:.3}", pipeline_s / nq as f64),
            format!("{div_k4:.3}")
        ]);
        table.row(csv_row![
            "index build",
            format!("{build_s:.3}"),
            "-",
            "-"
        ]);
        table.row(csv_row![
            format!("index+query x{nq}"),
            format!("{:.3}", build_s + cold_s),
            format!("{:.3}", cold_s / nq as f64),
            format!("{idx_div_k4:.3}")
        ]);
        table.row(csv_row![
            format!("index cached x{nq}"),
            format!("{cached_s:.6}"),
            format!("{:.6}", cached_s / nq as f64),
            "bit-identical"
        ]);
        csv.row(&csv_row![bed.name, "pipeline", nq, pipeline_s, pipeline_s / nq as f64, div_k4])?;
        csv.row(&csv_row![
            bed.name,
            "index_cold",
            nq,
            build_s + cold_s,
            cold_s / nq as f64,
            idx_div_k4
        ])?;
        csv.row(&csv_row![
            bed.name,
            "index_cached",
            nq,
            cached_s,
            cached_s / nq as f64,
            idx_div_k4
        ])?;
        println!("\n[{} k_max={k_max} queries={nq}]", bed.name);
        table.print();
        println!(
            "amortization: repeated pipelines / (build + cold queries) = {:.2}x; \
             cached repeat = {:.1}us/query",
            pipeline_s / (build_s + cold_s).max(1e-12),
            cached_s / nq as f64 * 1e6,
        );
    }
    csv.flush()?;
    append_csv.flush()?;
    delete_csv.flush()?;
    println!(
        "\nCSV -> bench_results/index_amortization.csv, bench_results/index_append.csv, \
         bench_results/index_delete.csv"
    );
    Ok(())
}
