//! Figure 1 — sequential setting: AMT vs SeqCoreset, time vs diversity
//! (top row) and the SeqCoreset runtime breakdown (bottom row).
//!
//! Protocol (paper §5.1): 5,000-element random samples of each dataset,
//! k in {rank/4, rank}; SeqCoreset with tau in {8,16,32,64,128,256}
//! finished by local search with gamma = 0; AMT with a gamma sweep
//! (we report the gamma = 0 "best quality" and gamma = 0.4 "fast" rows —
//! the paper likewise reports two representative AMT runs).
//!
//! Expected shape: SeqCoreset reaches AMT-level diversity 1-2 orders of
//! magnitude faster; larger tau -> higher diversity, more time; coreset
//! construction does not dominate at 5k.

use matroid_coreset::algo::local_search::{local_search_sum, LocalSearchMode, LocalSearchParams};
use matroid_coreset::algo::seq_coreset::seq_coreset;
use matroid_coreset::algo::Budget;
use matroid_coreset::bench::scenarios::{
    amt_baseline_with_mode, bench_engine, bench_engine_kind, bench_seed, testbeds,
};
use matroid_coreset::bench::{bench_header, time_once, Table};
use matroid_coreset::csv_row;
use matroid_coreset::util::csv::CsvWriter;
use matroid_coreset::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let seed = bench_seed();
    bench_header(
        "fig1_seq_vs_amt",
        &format!(
            "Paper Fig. 1: time vs diversity, AMT vs SeqCoreset (5k samples, \
             k in {{rank/4, rank}}, engine={})",
            bench_engine_kind().name()
        ),
    );
    let mut csv = CsvWriter::create(
        "bench_results/fig1.csv",
        &[
            "dataset", "k", "algo", "param", "diversity", "coreset_s", "search_s", "total_s",
            "coreset_size", "passes", "dist_evals",
        ],
    )?;

    for bed in testbeds(5_000, seed) {
        for k in [bed.rank / 4, bed.rank] {
            let k = k.max(2);
            let mut table = Table::new(&[
                "algo", "param", "diversity", "coreset_s", "search_s", "total_s", "|T|",
                "passes", "dist_evals",
            ]);
            // --- AMT rows (full 5k input) ---
            // gamma = 0 runs in both sum-maintenance modes: identical
            // trajectory (same diversity, same passes), so the time and
            // dist_evals columns isolate the incremental update's win
            let cands: Vec<usize> = (0..bed.ds.n()).collect();
            for (gamma, mode) in [
                (0.0, LocalSearchMode::Incremental),
                (0.0, LocalSearchMode::ExhaustiveRestart),
                (0.4, LocalSearchMode::Incremental),
            ] {
                let (res, secs) = time_once(|| {
                    amt_baseline_with_mode(&bed.ds, &bed.matroid, k, &cands, gamma, seed, mode)
                });
                let label = format!("g={gamma}/{}", mode.name());
                table.row(csv_row![
                    "AMT",
                    label.clone(),
                    format!("{:.3}", res.diversity),
                    "-",
                    format!("{secs:.3}"),
                    format!("{secs:.3}"),
                    bed.ds.n(),
                    res.passes,
                    res.dist_evals
                ]);
                csv.row(&csv_row![
                    bed.name, k, "amt", label, res.diversity, 0.0, secs, secs, bed.ds.n(),
                    res.passes, res.dist_evals
                ])?;
            }
            // --- SeqCoreset rows ---
            for tau in [8usize, 16, 32, 64, 128, 256] {
                let engine = bench_engine(&bed.ds);
                let (cs, cs_secs) = time_once(|| {
                    seq_coreset(&bed.ds, &bed.matroid, k, Budget::Clusters(tau), &*engine).unwrap()
                });
                let mut rng = Rng::new(seed);
                let (res, ls_secs) = time_once(|| {
                    local_search_sum(
                        &bed.ds,
                        &bed.matroid,
                        k,
                        &cs.indices,
                        &*engine,
                        LocalSearchParams::default(),
                        None,
                        &mut rng,
                    )
                    .unwrap()
                });
                let total = cs_secs + ls_secs;
                table.row(csv_row![
                    "SeqCoreset",
                    format!("tau={tau}"),
                    format!("{:.3}", res.diversity),
                    format!("{cs_secs:.3}"),
                    format!("{ls_secs:.3}"),
                    format!("{total:.3}"),
                    cs.len(),
                    res.passes,
                    res.dist_evals
                ]);
                csv.row(&csv_row![
                    bed.name, k, "seqcoreset", tau, res.diversity, cs_secs, ls_secs, total,
                    cs.len(), res.passes, res.dist_evals
                ])?;
            }
            println!("\n[{} k={k}]", bed.name);
            table.print();
        }
    }
    csv.flush()?;
    println!("\nCSV -> bench_results/fig1.csv");
    Ok(())
}
