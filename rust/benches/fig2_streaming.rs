//! Figure 2 — streaming setting: running-time breakdown (left) and the
//! diversity distribution across random stream orders (right) as the
//! coreset size tau grows.
//!
//! Protocol (paper §5.2): full datasets, k = rank/4, tau in {8..256},
//! >= DMMC_BENCH_RUNS random permutations per configuration; approximation
//! ratios are reported w.r.t. the best solution ever found on the dataset.
//!
//! Expected shape: quality rises and concentrates with tau; time grows
//! roughly linearly with tau.

use matroid_coreset::algo::local_search::{local_search_sum, LocalSearchParams};
use matroid_coreset::bench::scenarios::{
    bench_engine, bench_engine_kind, bench_n, bench_runs, bench_seed, testbeds,
};
use matroid_coreset::bench::{bench_header, time_once, Table};
use matroid_coreset::csv_row;
use matroid_coreset::streaming::{run_stream_with_engine, StreamMode};
use matroid_coreset::util::csv::CsvWriter;
use matroid_coreset::util::rng::Rng;
use matroid_coreset::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let n = bench_n();
    let runs = bench_runs();
    let seed = bench_seed();
    let ekind = bench_engine_kind();
    bench_header(
        "fig2_streaming",
        &format!(
            "Paper Fig. 2: StreamCoreset tau sweep (n={n}, k=rank/4, {runs} permutations, \
             engine={})",
            ekind.name()
        ),
    );
    let mut csv = CsvWriter::create(
        "bench_results/fig2.csv",
        &["dataset", "tau", "run", "diversity", "stream_s", "search_s", "coreset_size", "peak_mem"],
    )?;

    for bed in testbeds(n, seed) {
        let k = (bed.rank / 4).max(2);
        // hoisted: the sqnorm precompute must not count toward search_s
        let engine = bench_engine(&bed.ds);
        let mut table = Table::new(&[
            "tau", "stream_s(p50)", "search_s(p50)", "diversity distribution", "|T|(p50)",
            "ratio(p50)",
        ]);
        let mut best_ever: f64 = 0.0;
        let mut rows: Vec<(usize, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>)> = Vec::new();
        for tau in [8usize, 16, 32, 64, 128, 256] {
            let mut rng = Rng::new(seed ^ tau as u64);
            let (mut divs, mut st, mut se, mut sz) = (vec![], vec![], vec![], vec![]);
            for run in 0..runs {
                let order = rng.permutation(bed.ds.n());
                let (rep, stream_s) = time_once(|| {
                    let mode = StreamMode::Tau(tau);
                    run_stream_with_engine(&bed.ds, &bed.matroid, k, mode, &order, ekind).unwrap()
                });
                let mut rng2 = Rng::new(seed + run as u64);
                let (res, search_s) = time_once(|| {
                    local_search_sum(
                        &bed.ds,
                        &bed.matroid,
                        k,
                        &rep.coreset.indices,
                        &*engine,
                        LocalSearchParams::default(),
                        None,
                        &mut rng2,
                    )
                    .unwrap()
                });
                best_ever = best_ever.max(res.diversity);
                divs.push(res.diversity);
                st.push(stream_s);
                se.push(search_s);
                sz.push(rep.coreset.len() as f64);
                csv.row(&csv_row![
                    bed.name, tau, run, res.diversity, stream_s, search_s,
                    rep.coreset.len(), rep.stats.peak_memory_points
                ])?;
            }
            rows.push((tau, divs, st, se, sz));
        }
        for (tau, divs, st, se, sz) in rows {
            let d = Summary::of(&divs);
            let ratios: Vec<f64> = divs.iter().map(|v| v / best_ever).collect();
            let r = Summary::of(&ratios);
            table.row(csv_row![
                tau,
                format!("{:.3}", Summary::of(&st).p50),
                format!("{:.3}", Summary::of(&se).p50),
                format!(
                    "min {:.2} p25 {:.2} p50 {:.2} p75 {:.2} max {:.2}",
                    d.min, d.p25, d.p50, d.p75, d.max
                ),
                format!("{:.0}", Summary::of(&sz).p50),
                format!("{:.4}", r.p50)
            ]);
        }
        println!("\n[{} k={k}] (ratio vs best-ever {best_ever:.3})", bed.name);
        table.print();
    }
    csv.flush()?;
    println!("\nCSV -> bench_results/fig2.csv");
    Ok(())
}
