//! Ablation: PJRT/Pallas distance engine vs the scalar Rust path inside
//! GMM — the L1<->L3 boundary of the three-layer architecture.  Measures
//! the GMM hot loop (update_min folds) across n, dim and metric, and
//! verifies both engines select the same clustering radius.

use matroid_coreset::algo::gmm::{gmm, GmmStop};
use matroid_coreset::bench::scenarios::bench_seed;
use matroid_coreset::bench::{bench_header, time_once, Table};
use matroid_coreset::core::{Dataset, Metric};
use matroid_coreset::csv_row;
use matroid_coreset::runtime::{default_artifact_dir, Manifest, PjrtEngine, ScalarEngine};
use matroid_coreset::util::csv::CsvWriter;
use matroid_coreset::util::rng::Rng;

fn dataset(metric: Metric, n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let coords: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
    Dataset::new(dim, metric, coords, vec![vec![0]; n], 1, "bench")
}

fn main() -> anyhow::Result<()> {
    let seed = bench_seed();
    bench_header(
        "ablation_distance_engine",
        "GMM hot path: scalar Rust vs PJRT(Pallas AOT) engine (tau=64 folds)",
    );
    let manifest = match Manifest::load(default_artifact_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("SKIP: {e:#} (run `make artifacts`)");
            return Ok(());
        }
    };
    let mut csv = CsvWriter::create(
        "bench_results/ablation_engine.csv",
        &["metric", "n", "dim", "engine", "gmm_s", "radius"],
    )?;
    let tau = 64;
    let mut table =
        Table::new(&["metric", "n", "dim", "scalar_s", "pjrt_s", "speedup", "radius_agree"]);
    for metric in [Metric::Euclidean, Metric::Cosine] {
        for (n, dim) in [(20_000usize, 25usize), (50_000, 25), (50_000, 48), (100_000, 25)] {
            let ds = dataset(metric, n, dim, seed);
            let scalar = ScalarEngine::new();
            let (c_s, t_s) = time_once(|| gmm(&ds, &scalar, 0, GmmStop::Clusters(tau)).unwrap());
            let pjrt = PjrtEngine::for_dataset(&manifest, &ds)?;
            let (c_p, t_p) = time_once(|| gmm(&ds, &pjrt, 0, GmmStop::Clusters(tau)).unwrap());
            let agree = (c_s.radius - c_p.radius).abs() < 2e-3 * c_s.radius.max(1e-9);
            table.row(csv_row![
                metric.name(), n, dim,
                format!("{t_s:.3}"), format!("{t_p:.3}"),
                format!("{:.2}x", t_s / t_p),
                agree
            ]);
            csv.row(&csv_row![metric.name(), n, dim, "scalar", t_s, c_s.radius])?;
            csv.row(&csv_row![metric.name(), n, dim, "pjrt", t_p, c_p.radius])?;
        }
    }
    table.print();
    csv.flush()?;
    println!("\nCSV -> bench_results/ablation_engine.csv");
    Ok(())
}
