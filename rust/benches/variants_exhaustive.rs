//! Beyond-sum DMMC variants (star / tree / cycle / bipartition): the
//! (1 - eps)-approximate exhaustive-on-coreset route of §4.4 — the first
//! feasible algorithms for these variants, which the paper proves but does
//! not benchmark (no competitor exists).  We report quality vs the
//! exhaustive optimum on a brute-forceable instance, plus the wall-time
//! scaling in k that the coreset confinement makes practical.
//!
//! Sizes are chosen so every exhaustive run is tractable: the search is
//! O(|T| choose k), exactly the paper's bound — that it explodes without a
//! coreset IS the result.

use matroid_coreset::algo::exhaustive::exhaustive_best;
use matroid_coreset::algo::seq_coreset::seq_coreset;
use matroid_coreset::algo::Budget;
use matroid_coreset::bench::scenarios::bench_seed;
use matroid_coreset::bench::{bench_header, time_once, Table};
use matroid_coreset::csv_row;
use matroid_coreset::data::synth;
use matroid_coreset::diversity::ALL_OBJECTIVES;
use matroid_coreset::matroid::PartitionMatroid;
use matroid_coreset::runtime::{BatchEngine, ScalarEngine};
use matroid_coreset::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let seed = bench_seed();
    bench_header(
        "variants_exhaustive",
        "(1-eps) exhaustive-on-coreset for star/tree/cycle/bipartition DMMC (paper §4.4)",
    );
    let mut csv = CsvWriter::create(
        "bench_results/variants.csv",
        &["objective", "k", "tau", "diversity", "ratio_vs_opt", "coreset_s", "search_s", "nodes"],
    )?;

    // brute-forceable testbed: optimum computable on the full input
    // (n = 48, k = 4 -> C(48,4) ~ 195k leaves for the baseline search)
    let ds = synth::clustered(48, 4, 8, 0.08, 4, seed);
    let m = PartitionMatroid::new(vec![2; 4]);
    let k = 4;
    let engine = ScalarEngine::new();
    // the search's candidate tile + final evaluation run on the default
    // batch backend (bit-identical to the scalar oracle, so the reported
    // numbers are engine-independent)
    let search_engine = BatchEngine::for_dataset(&ds);
    let all: Vec<usize> = (0..ds.n()).collect();

    let mut table = Table::new(&[
        "objective", "tau", "diversity", "ratio_vs_opt", "coreset_s", "search_s", "nodes",
    ]);
    for obj in ALL_OBJECTIVES {
        let (opt, opt_s) =
            time_once(|| exhaustive_best(&ds, &m, k, &all, obj, &search_engine).unwrap().diversity);
        table.row(csv_row![
            obj.name(), "- (full)", format!("{opt:.3}"), "1.0000", "-",
            format!("{opt_s:.3}"), "-"
        ]);
        for tau in [4usize, 8, 12] {
            let (cs, cs_s) =
                time_once(|| seq_coreset(&ds, &m, k, Budget::Clusters(tau), &engine).unwrap());
            let (res, se_s) = time_once(|| {
                exhaustive_best(&ds, &m, k, &cs.indices, obj, &search_engine).unwrap()
            });
            let ratio = res.diversity / opt;
            table.row(csv_row![
                obj.name(),
                tau,
                format!("{:.3}", res.diversity),
                format!("{ratio:.4}"),
                format!("{cs_s:.3}"),
                format!("{se_s:.3}"),
                res.nodes
            ]);
            csv.row(&csv_row![
                obj.name(), k, tau, res.diversity, ratio, cs_s, se_s, res.nodes
            ])?;
            assert!(ratio > 0.5, "{obj:?} tau={tau}: ratio {ratio} collapsed");
        }
    }
    println!("\n[clustered n=48, partition matroid, k={k}] ratio = coreset route / full exhaustive");
    table.print();

    // scaling in k on a larger input where full exhaustive is intractable:
    // C(20000, 5) ~ 2.7e19 directly vs O(|T|^k) on a ~40-point coreset
    let big = synth::songsim(20_000, seed);
    let pm = synth::songsim_matroid(&big, 89);
    let big_engine = BatchEngine::for_dataset(&big);
    let mut table2 =
        Table::new(&["objective", "k", "tau", "|T|", "coreset_s", "search_s", "diversity"]);
    for obj in ALL_OBJECTIVES {
        for k in [3usize, 4, 5] {
            let tau = 8;
            let (cs, cs_s) =
                time_once(|| seq_coreset(&big, &pm, k, Budget::Clusters(tau), &engine).unwrap());
            let (res, se_s) =
                time_once(|| exhaustive_best(&big, &pm, k, &cs.indices, obj, &big_engine).unwrap());
            table2.row(csv_row![
                obj.name(),
                k,
                tau,
                cs.len(),
                format!("{cs_s:.2}"),
                format!("{se_s:.2}"),
                format!("{:.3}", res.diversity)
            ]);
            csv.row(&csv_row![
                obj.name(), k, tau, res.diversity, -1.0, cs_s, se_s, res.nodes
            ])?;
        }
    }
    println!("\n[songsim n=20000, partition rank 89] exponential-in-k search confined to the coreset:");
    table2.print();
    csv.flush()?;
    println!("\nCSV -> bench_results/variants.csv");
    Ok(())
}
