//! Figure 3 — all algorithms at tau = 64 on the full datasets: runtime
//! breakdown (coreset construction vs local search) and solution quality,
//! with MRCoreset at ell in {1, 2, 4, 8, 16} (ell = 1 == SeqCoreset) and
//! StreamCoreset alongside.
//!
//! Expected shape: coreset construction dominates on full datasets; the
//! MR construction scales with ell (superlinearly for the clustering part,
//! as each worker computes tau/ell clusters on n/ell points); streaming is
//! competitive with mid-ell MR in time with slightly lower quality.

use matroid_coreset::algo::local_search::{local_search_sum, LocalSearchParams};
use matroid_coreset::algo::Budget;
use matroid_coreset::bench::scenarios::{
    bench_engine, bench_engine_kind, bench_n, bench_runs, bench_seed, testbeds,
};
use matroid_coreset::bench::{bench_header, time_once, Table};
use matroid_coreset::csv_row;
use matroid_coreset::mapreduce::{mr_coreset, MapReduceConfig};
use matroid_coreset::streaming::{run_stream_with_engine, StreamMode};
use matroid_coreset::util::csv::CsvWriter;
use matroid_coreset::util::rng::Rng;
use matroid_coreset::util::stats::Summary;

const TAU: usize = 64;

fn main() -> anyhow::Result<()> {
    let n = bench_n();
    let runs = bench_runs();
    let seed = bench_seed();
    let ekind = bench_engine_kind();
    bench_header(
        "fig3_all_settings",
        &format!(
            "Paper Fig. 3: all settings, tau={TAU}, full datasets (n={n}), k=rank/4, engine={}",
            ekind.name()
        ),
    );
    let mut csv = CsvWriter::create(
        "bench_results/fig3.csv",
        &["dataset", "algo", "run", "diversity", "coreset_s", "search_s", "coreset_size"],
    )?;

    for bed in testbeds(n, seed) {
        let k = (bed.rank / 4).max(2);
        // hoisted: the sqnorm precompute must not count toward search_s
        let engine = bench_engine(&bed.ds);
        let mut table = Table::new(&[
            "algo", "coreset_s(p50)", "search_s(p50)", "diversity p50 [min..max]", "|T|(p50)",
        ]);
        let mut emit = |name: &str,
                        samples: Vec<(f64, f64, f64, usize)>,
                        table: &mut Table,
                        csv: &mut CsvWriter|
         -> anyhow::Result<()> {
            for (run, (div, cs_s, ls_s, size)) in samples.iter().enumerate() {
                csv.row(&csv_row![bed.name, name, run, div, cs_s, ls_s, size])?;
            }
            let divs: Vec<f64> = samples.iter().map(|s| s.0).collect();
            let sizes: Vec<f64> = samples.iter().map(|s| s.3 as f64).collect();
            let d = Summary::of(&divs);
            table.row(csv_row![
                name,
                format!("{:.3}", Summary::of(&samples.iter().map(|s| s.1).collect::<Vec<_>>()).p50),
                format!("{:.3}", Summary::of(&samples.iter().map(|s| s.2).collect::<Vec<_>>()).p50),
                format!("{:.3} [{:.3}..{:.3}]", d.p50, d.min, d.max),
                format!("{:.0}", Summary::of(&sizes).p50)
            ]);
            Ok(())
        };

        // --- MRCoreset with ell = 1 (== SeqCoreset), 2, 4, 8, 16 ---
        for ell in [1usize, 2, 4, 8, 16] {
            let mut samples = Vec::new();
            for run in 0..runs {
                let cfg = MapReduceConfig {
                    workers: ell,
                    budget: Budget::Clusters((TAU / ell).max(1)),
                    second_round_tau: None,
                    seed: seed + run as u64,
                    engine: ekind,
                };
                let (rep, cs_s) = time_once(|| mr_coreset(&bed.ds, &bed.matroid, k, cfg).unwrap());
                let mut rng = Rng::new(seed + run as u64);
                let (res, ls_s) = time_once(|| {
                    local_search_sum(
                        &bed.ds,
                        &bed.matroid,
                        k,
                        &rep.coreset.indices,
                        &engine,
                        LocalSearchParams::default(),
                        None,
                        &mut rng,
                    )
                    .unwrap()
                });
                samples.push((res.diversity, cs_s, ls_s, rep.coreset.len()));
            }
            let label = if ell == 1 {
                "SeqCoreset(=MR ell=1)".to_string()
            } else {
                format!("MRCoreset ell={ell}")
            };
            emit(&label, samples, &mut table, &mut csv)?;
        }

        // --- StreamCoreset ---
        let mut samples = Vec::new();
        let mut rng = Rng::new(seed ^ 0xBEEF);
        for run in 0..runs {
            let order = rng.permutation(bed.ds.n());
            let (rep, cs_s) = time_once(|| {
                let mode = StreamMode::Tau(TAU);
                run_stream_with_engine(&bed.ds, &bed.matroid, k, mode, &order, ekind).unwrap()
            });
            let mut rng2 = Rng::new(seed + run as u64);
            let (res, ls_s) = time_once(|| {
                local_search_sum(
                    &bed.ds,
                    &bed.matroid,
                    k,
                    &rep.coreset.indices,
                    &engine,
                    LocalSearchParams::default(),
                    None,
                    &mut rng2,
                )
                .unwrap()
            });
            samples.push((res.diversity, cs_s, ls_s, rep.coreset.len()));
        }
        emit("StreamCoreset", samples, &mut table, &mut csv)?;

        println!("\n[{} k={k}]", bed.name);
        table.print();
    }
    csv.flush()?;
    println!("\nCSV -> bench_results/fig3.csv");
    Ok(())
}
