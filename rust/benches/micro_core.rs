//! Microbenchmarks of the core primitives: distance kernels, matroid
//! oracles, GMM folds, diversity evaluators, streaming push.  These are
//! the profile-guided perf counters tracked in EXPERIMENTS.md §Perf.

use matroid_coreset::algo::gmm::{gmm, GmmStop};
use matroid_coreset::algo::local_search::{local_search_sum, LocalSearchMode, LocalSearchParams};
use matroid_coreset::algo::stream_coreset::StreamCoreset;
use matroid_coreset::bench::scenarios::bench_seed;
use matroid_coreset::bench::{bench_header, bench_repeat, Table};
use matroid_coreset::core::Metric;
use matroid_coreset::csv_row;
use matroid_coreset::data::synth;
use matroid_coreset::diversity::{
    diversity, star_diversity_with_engine, Evaluator, ALL_OBJECTIVES,
};
use matroid_coreset::matroid::{Matroid, PartitionMatroid, TransversalMatroid, UniformMatroid};
use matroid_coreset::obs::MetricsRegistry;
use matroid_coreset::runtime::{BatchEngine, DistanceEngine, ScalarEngine, SimdEngine};
use matroid_coreset::util::csv::CsvWriter;
use matroid_coreset::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let seed = bench_seed();
    bench_header("micro_core", "core primitive microbenchmarks (p50 of 20 iters)");
    let mut csv = CsvWriter::create(
        "bench_results/micro.csv",
        &["bench", "p50_us", "per_item_ns"],
    )?;
    let mut table = Table::new(&["bench", "p50", "per-item"]);
    let registry = MetricsRegistry::new();
    let reg = &registry;
    let mut emit = |name: &str, p50_s: f64, items: f64, table: &mut Table| {
        table.row(csv_row![
            name,
            format!("{:.3}ms", p50_s * 1e3),
            format!("{:.0}ns", p50_s / items * 1e9)
        ]);
        csv.row(&csv_row![name, p50_s * 1e6, p50_s / items * 1e9]).unwrap();
        // the CSV rows and BENCH_micro.json come from the same numbers
        reg.gauge("dmmc_micro_p50_us", &[("bench", name)]).set(p50_s * 1e6);
        reg.gauge("dmmc_micro_per_item_ns", &[("bench", name)]).set(p50_s / items * 1e9);
    };

    // distance evaluation
    let mut rng = Rng::new(seed);
    let a: Vec<f32> = (0..25).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..25).map(|_| rng.normal() as f32).collect();
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let s = bench_repeat(3, 20, || {
            let mut acc = 0.0;
            for _ in 0..100_000 {
                acc += metric.dist(&a, &b);
            }
            acc
        });
        emit(&format!("dist/{}/d25 x100k", metric.name()), s.p50, 100_000.0, &mut table);
    }

    // GMM fold (update_min over 50k points), scalar oracle vs batch default
    let ds = synth::wikisim(50_000, seed);
    let s = bench_repeat(1, 5, || {
        gmm(&ds, &ScalarEngine::new(), 0, GmmStop::Clusters(16)).unwrap()
    });
    emit("gmm/scalar/tau=16/n=50k", s.p50, (50_000 * 16) as f64, &mut table);
    let batch = BatchEngine::for_dataset(&ds);
    let s = bench_repeat(1, 5, || gmm(&ds, &batch, 0, GmmStop::Clusters(16)).unwrap());
    emit("gmm/batch/tau=16/n=50k", s.p50, (50_000 * 16) as f64, &mut table);
    let simd = SimdEngine::for_dataset(&ds);
    let s = bench_repeat(1, 5, || gmm(&ds, &simd, 0, GmmStop::Clusters(16)).unwrap());
    emit("gmm/simd/tau=16/n=50k", s.p50, (50_000 * 16) as f64, &mut table);

    // the acceptance workload for the batched engines: single-center folds
    // over 100k points, dim 32, Euclidean — batch must be >= 4x scalar on
    // an 8-thread machine (the ISSUE 1 criterion); the simd row tracks the
    // additional lane-unrolling win at identical output bits
    let big = synth::uniform_cube(100_000, 32, seed);
    let scalar = ScalarEngine::new();
    let fold = |engine: &dyn DistanceEngine| {
        let mut mind = vec![f32::INFINITY; big.n()];
        let mut arg = vec![u32::MAX; big.n()];
        for (id, c) in [0usize, 11, 222, 3333, 44_444, 55_555, 66_666, 99_999]
            .into_iter()
            .enumerate()
        {
            engine.update_min(&big, c, id as u32, &mut mind, &mut arg).unwrap();
        }
        mind[0]
    };
    let s_scalar = bench_repeat(1, 5, || fold(&scalar));
    emit("fold/scalar/n=100k/d=32 x8", s_scalar.p50, (100_000 * 8) as f64, &mut table);
    let big_batch = BatchEngine::for_dataset(&big);
    let s_batch = bench_repeat(1, 5, || fold(&big_batch));
    emit("fold/batch/n=100k/d=32 x8", s_batch.p50, (100_000 * 8) as f64, &mut table);
    let big_simd = SimdEngine::for_dataset(&big);
    let s_simd = bench_repeat(1, 5, || fold(&big_simd));
    emit("fold/simd/n=100k/d=32 x8", s_simd.p50, (100_000 * 8) as f64, &mut table);
    println!(
        "fold speedup batch vs scalar: {:.2}x | simd vs scalar: {:.2}x | simd vs batch: {:.2}x \
         ({} threads)",
        s_scalar.p50 / s_batch.p50.max(1e-12),
        s_scalar.p50 / s_simd.p50.max(1e-12),
        s_batch.p50 / s_simd.p50.max(1e-12),
        big_simd.threads()
    );

    // matroid oracles
    let part_ds = synth::songsim(10_000, seed);
    let part = synth::songsim_matroid(&part_ds, 89);
    let set: Vec<usize> = (0..22).collect();
    let s = bench_repeat(3, 20, || {
        let mut ok = true;
        for _ in 0..10_000 {
            ok &= part.is_independent(&part_ds, &set);
        }
        ok
    });
    emit("oracle/partition/k=22 x10k", s.p50, 10_000.0, &mut table);

    let trans = TransversalMatroid::new();
    let tset: Vec<usize> = (0..25).collect();
    let s = bench_repeat(3, 20, || {
        let mut ok = true;
        for _ in 0..1_000 {
            ok &= trans.is_independent(&ds, &tset);
        }
        ok
    });
    emit("oracle/transversal/k=25 x1k", s.p50, 1_000.0, &mut table);

    // diversity evaluators at k=12
    let sset: Vec<usize> = (0..12).collect();
    for obj in ALL_OBJECTIVES {
        let s = bench_repeat(3, 20, || {
            let mut acc = 0.0;
            for _ in 0..100 {
                acc += diversity(&ds, &sset, obj);
            }
            acc
        });
        emit(&format!("diversity/{}/k=12 x100", obj.name()), s.p50, 100.0, &mut table);
    }

    // engine-backed evaluator primitives at k=512: the pairwise tile that
    // feeds tree/cycle/bipartition and the batched sums behind sum/star —
    // scalar oracle vs the multi-threaded batch backend (bit-identical
    // outputs, different wall clock)
    let eset: Vec<usize> = (0..512).collect();
    let scalar_eval = ScalarEngine::new();
    let s = bench_repeat(3, 20, || {
        Evaluator::new(&scalar_eval).submatrix(&ds, &eset).unwrap().len()
    });
    emit("evaluator/submatrix/scalar/k=512", s.p50, (512 * 511 / 2) as f64, &mut table);
    let s = bench_repeat(3, 20, || {
        Evaluator::new(&batch).submatrix(&ds, &eset).unwrap().len()
    });
    emit("evaluator/submatrix/batch/k=512", s.p50, (512 * 511 / 2) as f64, &mut table);
    let s = bench_repeat(3, 20, || {
        Evaluator::new(&simd).submatrix(&ds, &eset).unwrap().len()
    });
    emit("evaluator/submatrix/simd/k=512", s.p50, (512 * 511 / 2) as f64, &mut table);
    let s = bench_repeat(3, 20, || star_diversity_with_engine(&ds, &eset, &batch).unwrap());
    emit("evaluator/star/batch/k=512", s.p50, (512 * 511) as f64, &mut table);

    // the incremental-AMT delta pass: a two-column dists_to_points block
    // over all 50k points — scalar oracle vs batch vs simd
    let eset_all: Vec<usize> = (0..ds.n()).collect();
    let two: Vec<usize> = vec![100, 40_000];
    let s = bench_repeat(3, 20, || {
        scalar_eval.dists_to_points(&ds, &eset_all, &two).unwrap().len()
    });
    emit("dists_to_points/scalar/n=50k x2", s.p50, (2 * ds.n()) as f64, &mut table);
    let s = bench_repeat(3, 20, || batch.dists_to_points(&ds, &eset_all, &two).unwrap().len());
    emit("dists_to_points/batch/n=50k x2", s.p50, (2 * ds.n()) as f64, &mut table);
    let s = bench_repeat(3, 20, || simd.dists_to_points(&ds, &eset_all, &two).unwrap().len());
    emit("dists_to_points/simd/n=50k x2", s.p50, (2 * ds.n()) as f64, &mut table);

    // incremental vs exhaustive-restart AMT on an identical trajectory:
    // the wall-clock ratio tracks the O(n k) -> O(n) per-swap distance
    // work cut (EXPERIMENTS.md §Perf, incremental rows)
    let amt_ds = synth::uniform_cube(2_000, 16, seed);
    let amt_m = UniformMatroid::new(16);
    let amt_cands: Vec<usize> = (0..amt_ds.n()).collect();
    let amt_engine = BatchEngine::for_dataset(&amt_ds);
    let run_amt = |mode: LocalSearchMode| {
        let s = bench_repeat(1, 5, || {
            let mut rng = Rng::new(seed);
            let init: Vec<usize> = (0..16).collect(); // bad start -> long trajectory
            local_search_sum(
                &amt_ds,
                &amt_m,
                16,
                &amt_cands,
                &amt_engine,
                LocalSearchParams { mode, ..Default::default() },
                Some(init),
                &mut rng,
            )
            .unwrap()
            .swaps
        });
        s.p50
    };
    let p_inc = run_amt(LocalSearchMode::Incremental);
    emit("local_search/incremental/n=2k/k=16", p_inc, 1.0, &mut table);
    let p_rst = run_amt(LocalSearchMode::ExhaustiveRestart);
    emit("local_search/restart/n=2k/k=16", p_rst, 1.0, &mut table);
    println!(
        "local-search speedup incremental vs restart: {:.2}x",
        p_rst / p_inc.max(1e-12)
    );

    // streaming push throughput
    let u = UniformMatroid::new(8);
    let s = bench_repeat(1, 5, || {
        let mut alg = StreamCoreset::with_tau(&ds, &u, 8, 64);
        for i in 0..ds.n() {
            alg.push(i);
        }
        alg.n_centers()
    });
    emit("stream/push/n=50k/tau=64", s.p50, ds.n() as f64, &mut table);

    // partition extract path
    let pm = PartitionMatroid::new(vec![2; 8]);
    let cl = synth::clustered(20_000, 8, 16, 0.1, 8, seed);
    let s = bench_repeat(1, 5, || {
        matroid_coreset::algo::seq_coreset::seq_coreset(
            &cl,
            &pm,
            8,
            matroid_coreset::algo::Budget::Clusters(32),
            &ScalarEngine::new(),
        )
        .unwrap()
        .len()
    });
    emit("seq_coreset/n=20k/tau=32", s.p50, cl.n() as f64, &mut table);

    table.print();
    csv.flush()?;
    matroid_coreset::bench::write_bench_json(
        "bench_results/BENCH_micro.json",
        "micro",
        &format!("{{\"seed\":{seed},\"iters\":20}}"),
        &registry,
    )?;
    println!("\nCSV -> bench_results/micro.csv");
    println!("JSON -> bench_results/BENCH_micro.json");
    Ok(())
}
