//! The determinism-contract lint registry (L1-L4).
//!
//! Each lint is a token-level pass over one source file, scoped to the
//! modules whose contracts it enforces (paths are repo-relative with `/`
//! separators).  Test modules (`#[cfg(test)] mod ... { ... }`) are
//! skipped by every lint: the contracts bind result-producing code, and
//! the tests that *pin* the contracts legitimately compare floats, time
//! phases, and so on.
//!
//! * **L1 `hash-collection`** — no `HashMap`/`HashSet` in the
//!   result-producing modules (`matroid/`, `algo/`, `index/`,
//!   `diversity/`).  Hash iteration order is seeded per process, so any
//!   iteration over these collections is a nondeterminism hazard that
//!   multiplies across MapReduce shards; require `BTreeMap`/`BTreeSet`
//!   or a sorted collect, or an allowlist entry justifying why the
//!   collection's order provably cannot reach a result (membership-only
//!   sets).
//! * **L2 `float-accum`** — no float accumulation loops in the engine
//!   kernels of bit-exact-contract backends (`runtime/engine.rs`,
//!   `runtime/batch.rs`, `runtime/simd.rs` — the modules whose
//!   `EngineKind::contract()` declares bit-exactness; `pjrt.rs` is
//!   tolerance-contracted and exempt).  A compound assignment (`+=` ...)
//!   inside a loop is flagged unless the enclosing function is a blessed
//!   reduction helper (`lint.toml [l2] blessed` — `dot_tree4`, the
//!   left-to-right lane chains) or the right-hand side is a plain
//!   integer literal / SCREAMING_CASE stride constant (index and counter
//!   bookkeeping, not numerics).
//! * **L3 `narrowing-cast`** — no `as f32` narrowing in the exact-f64
//!   paths: inside `sums_to_set`/`dists_to_points` kernels (`lint.toml
//!   [l3] exact_f64_fns`) of the bit-exact engine files, and anywhere in
//!   `algo/local_search.rs` (the incremental-AMT column store is exact
//!   f64 end to end).
//! * **L4 `ambient-time-rng`** — no `Instant::now`/`SystemTime`/ambient
//!   RNG (`thread_rng`, `from_entropy`, `OsRng`, `getrandom`) in
//!   deterministic query/finisher paths: all of `rust/src/` except
//!   `util/timer.rs` and `bench/` (the designated wall-clock homes).
//!   Query-path RNG must derive from the `(spec, epoch)` cache key so a
//!   cache hit is bit-identical to its cold run.
//!
//! Findings carry the offending `symbol`; allowlist entries may pin one
//! (`symbol = "HashSet"` suppresses only `HashSet` findings — so
//! re-introducing a `HashMap` in an allowlisted file still fails).

use crate::allowlist::Policy;
use crate::lexer::{tokenize, Tok, TokKind};
use crate::report::Finding;

/// One source file, addressed repo-relative with `/` separators
/// (`rust/src/matroid/transversal.rs`).
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub content: String,
}

/// Structural context of one token, reconstructed from the token stream.
#[derive(Debug, Clone)]
pub struct Ctx {
    /// Innermost enclosing function name, if any.
    pub fn_name: Option<String>,
    /// Number of enclosing `for`/`while`/`loop` bodies.
    pub loop_depth: u32,
    /// Inside a `#[cfg(test)]` item (inline test module).
    pub in_test: bool,
}

const L1_DIRS: &[&str] = &[
    "rust/src/matroid/",
    "rust/src/algo/",
    "rust/src/index/",
    "rust/src/diversity/",
];
const L2_FILES: &[&str] = &[
    "rust/src/runtime/engine.rs",
    "rust/src/runtime/batch.rs",
    "rust/src/runtime/simd.rs",
];
const L3_WHOLE_FILES: &[&str] = &["rust/src/algo/local_search.rs"];
const L4_ROOT: &str = "rust/src/";
const L4_EXEMPT_FILES: &[&str] = &["rust/src/util/timer.rs"];
const L4_EXEMPT_DIRS: &[&str] = &["rust/src/bench/"];
const L4_RNG_IDENTS: &[&str] = &["thread_rng", "from_entropy", "OsRng", "getrandom"];

/// Does `toks[i]` start a `#[cfg(test)]` attribute?
fn is_cfg_test_attr(toks: &[Tok], i: usize) -> bool {
    let want = ["[", "cfg", "(", "test", ")", "]"];
    toks.len() > i + want.len()
        && toks[i].text == "#"
        && want.iter().zip(&toks[i + 1..]).all(|(w, t)| t.text == *w)
}

/// Reconstruct per-token structural context (single forward pass).
pub fn contexts(toks: &[Tok]) -> Vec<Ctx> {
    let mut out = Vec::with_capacity(toks.len());
    let mut depth: i64 = 0;
    let mut fn_stack: Vec<(String, i64)> = Vec::new();
    let mut loop_stack: Vec<i64> = Vec::new();
    let mut test_stack: Vec<i64> = Vec::new();
    let mut pending_fn: Option<String> = None;
    let mut awaiting_fn_name = false;
    let mut pending_loop = false;
    let mut pending_test = false;
    let mut impl_header = false;
    // `(`/`[` nesting: a `;` inside parens or brackets (array types like
    // `-> [f64; 4]`, `[0u8; N]` params) must not cancel a pending item.
    let mut nest: i64 = 0;

    for (i, t) in toks.iter().enumerate() {
        out.push(Ctx {
            fn_name: fn_stack.last().map(|(n, _)| n.clone()),
            loop_depth: loop_stack.len() as u32,
            in_test: !test_stack.is_empty(),
        });
        match t.kind {
            TokKind::Ident => {
                if awaiting_fn_name {
                    // `fn name` — anything else (`fn(usize)` pointer
                    // types) cancels below
                    pending_fn = Some(t.text.clone());
                    awaiting_fn_name = false;
                    continue;
                }
                match t.text.as_str() {
                    "fn" => awaiting_fn_name = true,
                    "impl" => impl_header = true,
                    "while" | "loop" => pending_loop = true,
                    "for" => {
                        // not a loop in `impl Trait for Type` or HRTB
                        // `for<'a>` positions
                        let hrtb = toks
                            .get(i + 1)
                            .is_some_and(|x| x.kind == TokKind::Punct && x.text == "<");
                        if !impl_header && !hrtb {
                            pending_loop = true;
                        }
                    }
                    _ => {}
                }
            }
            TokKind::Punct => {
                awaiting_fn_name = false;
                match t.text.as_str() {
                    "{" => {
                        depth += 1;
                        impl_header = false;
                        if let Some(name) = pending_fn.take() {
                            fn_stack.push((name, depth));
                        }
                        if pending_loop {
                            loop_stack.push(depth);
                            pending_loop = false;
                        }
                        if pending_test {
                            test_stack.push(depth);
                            pending_test = false;
                        }
                    }
                    "}" => {
                        while fn_stack.last().is_some_and(|&(_, d)| d == depth) {
                            fn_stack.pop();
                        }
                        while loop_stack.last() == Some(&depth) {
                            loop_stack.pop();
                        }
                        while test_stack.last() == Some(&depth) {
                            test_stack.pop();
                        }
                        depth -= 1;
                    }
                    "(" | "[" => nest += 1,
                    ")" | "]" => nest = (nest - 1).max(0),
                    ";" => {
                        // trait method declarations, `#[cfg(test)] use ..;`
                        if nest == 0 {
                            pending_fn = None;
                            pending_loop = false;
                            pending_test = false;
                            impl_header = false;
                        }
                    }
                    "#" => {
                        if is_cfg_test_attr(toks, i) {
                            pending_test = true;
                        }
                    }
                    _ => {}
                }
            }
            _ => awaiting_fn_name = false,
        }
    }
    out
}

fn in_any_dir(path: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| path.starts_with(d))
}

fn is_screaming_const(s: &str) -> bool {
    s.len() >= 2
        && s.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
        && s.chars().any(|c| c.is_ascii_uppercase())
}

fn finding(
    lint: &str,
    name: &str,
    file: &SourceFile,
    tok: &Tok,
    symbol: &str,
    message: String,
) -> Finding {
    Finding {
        lint: lint.to_string(),
        name: name.to_string(),
        path: file.path.clone(),
        line: tok.line,
        symbol: symbol.to_string(),
        message,
    }
}

/// Run every applicable lint over one file, appending raw (unsuppressed)
/// findings to `out`.
pub fn lint_file(file: &SourceFile, policy: &Policy, out: &mut Vec<Finding>) {
    let toks = tokenize(&file.content);
    let ctxs = contexts(&toks);
    let path = file.path.as_str();

    let l1 = in_any_dir(path, L1_DIRS);
    let l2 = L2_FILES.contains(&path);
    let l3_fns = L2_FILES.contains(&path);
    let l3_whole = L3_WHOLE_FILES.contains(&path);
    let l4 = path.starts_with(L4_ROOT)
        && !L4_EXEMPT_FILES.contains(&path)
        && !in_any_dir(path, L4_EXEMPT_DIRS);

    for (i, t) in toks.iter().enumerate() {
        let ctx = &ctxs[i];
        if ctx.in_test {
            continue;
        }
        // L1: hash collections in result-producing modules
        if l1 && t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
            out.push(finding(
                "L1",
                "hash-collection",
                file,
                t,
                &t.text,
                format!(
                    "{} in a result-producing module: hash iteration order is \
                     process-seeded; use BTreeMap/BTreeSet or a sorted collect, \
                     or allowlist with a justification that its order cannot \
                     reach any result",
                    t.text
                ),
            ));
        }
        // L2: float accumulation loops outside blessed reduction helpers
        if l2
            && t.kind == TokKind::CompoundAssign
            && matches!(t.text.as_str(), "+=" | "-=" | "*=" | "/=")
            && ctx.loop_depth > 0
        {
            let blessed = ctx
                .fn_name
                .as_ref()
                .is_some_and(|f| policy.l2_blessed.iter().any(|b| b == f));
            let rhs_counter = toks.get(i + 1).zip(toks.get(i + 2)).is_some_and(|(a, b)| {
                b.text == ";"
                    && (a.kind == TokKind::Int
                        || (a.kind == TokKind::Ident && is_screaming_const(&a.text)))
            });
            if !blessed && !rhs_counter {
                let f = ctx.fn_name.clone().unwrap_or_else(|| "<top-level>".into());
                out.push(finding(
                    "L2",
                    "float-accum",
                    file,
                    t,
                    &t.text,
                    format!(
                        "accumulation `{}` in a loop of fn `{f}` in a bit-exact \
                         engine module: accumulation order is part of the engine \
                         contract — use a blessed reduction helper (lint.toml \
                         [l2] blessed) or bless this fn after review",
                        t.text
                    ),
                ));
            }
        }
        // L3: `as f32` narrowing in exact-f64 paths
        let is_as_f32 = t.kind == TokKind::Ident
            && t.text == "as"
            && toks
                .get(i + 1)
                .is_some_and(|x| x.kind == TokKind::Ident && x.text == "f32");
        if is_as_f32 {
            let in_exact_fn = l3_fns
                && ctx
                    .fn_name
                    .as_ref()
                    .is_some_and(|f| policy.l3_exact_f64_fns.iter().any(|e| e == f));
            if l3_whole || in_exact_fn {
                let f = ctx.fn_name.clone().unwrap_or_else(|| "<top-level>".into());
                out.push(finding(
                    "L3",
                    "narrowing-cast",
                    file,
                    t,
                    "as f32",
                    format!(
                        "`as f32` narrowing in exact-f64 path (fn `{f}`): \
                         sums_to_set/dists_to_points columns are exact f64 by \
                         contract (swap acceptance compares at 1e-12 relative)"
                    ),
                ));
            }
        }
        // L4: ambient time / RNG in deterministic paths
        if l4 && t.kind == TokKind::Ident {
            let instant_now = t.text == "Instant"
                && toks.get(i + 1).is_some_and(|x| x.text == ":")
                && toks.get(i + 2).is_some_and(|x| x.text == ":")
                && toks
                    .get(i + 3)
                    .is_some_and(|x| x.kind == TokKind::Ident && x.text == "now");
            let symbol = if instant_now {
                Some("Instant::now")
            } else if t.text == "SystemTime" {
                Some("SystemTime")
            } else if L4_RNG_IDENTS.contains(&t.text.as_str()) {
                Some(t.text.as_str())
            } else {
                None
            };
            if let Some(sym) = symbol {
                out.push(finding(
                    "L4",
                    "ambient-time-rng",
                    file,
                    t,
                    sym,
                    format!(
                        "`{sym}` in a deterministic path: timers belong in \
                         util/timer.rs or bench code, RNG must derive from the \
                         (spec, epoch) cache key; allowlist only wall-clock \
                         reporting that never feeds a result"
                    ),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allowlist::Policy;

    fn run_on(path: &str, content: &str, policy: &Policy) -> Vec<Finding> {
        let mut out = Vec::new();
        let f = SourceFile {
            path: path.to_string(),
            content: content.to_string(),
        };
        lint_file(&f, policy, &mut out);
        out
    }

    #[test]
    fn context_tracks_fns_loops_and_tests() {
        let toks = tokenize(
            "fn outer() { for i in 0..n { x += d; } }\n\
             #[cfg(test)]\nmod tests { fn t() { let h: HashMap<u32, u32>; } }",
        );
        let ctxs = contexts(&toks);
        let at = |text: &str| {
            let i = toks.iter().position(|t| t.text == text).unwrap();
            ctxs[i].clone()
        };
        let acc = at("+=");
        assert_eq!(acc.fn_name.as_deref(), Some("outer"));
        assert_eq!(acc.loop_depth, 1);
        assert!(!acc.in_test);
        let h = at("HashMap");
        assert!(h.in_test);
        assert_eq!(h.fn_name.as_deref(), Some("t"));
    }

    #[test]
    fn semicolon_in_array_type_keeps_fn_name() {
        // `-> [f64; 4]` must not cancel the pending fn name: euclid_lane4
        // would otherwise lose its blessing (real bug caught in review).
        let src = "fn euclid_lane4(p: &[f32]) -> [f64; 4] { for t in 0..4 { a0 += d; } }";
        let toks = tokenize(src);
        let ctxs = contexts(&toks);
        let i = toks.iter().position(|t| t.text == "+=").unwrap();
        assert_eq!(ctxs[i].fn_name.as_deref(), Some("euclid_lane4"));
    }

    #[test]
    fn impl_for_is_not_a_loop() {
        let toks = tokenize("impl Engine for Batch { fn go(&self) { s += v; } }");
        let ctxs = contexts(&toks);
        let i = toks.iter().position(|t| t.text == "+=").unwrap();
        assert_eq!(ctxs[i].loop_depth, 0);
        assert_eq!(ctxs[i].fn_name.as_deref(), Some("go"));
    }

    #[test]
    fn l1_fires_only_in_scoped_modules() {
        let p = Policy::default();
        let bad = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, u32> = HashMap::new(); }";
        assert_eq!(run_on("rust/src/matroid/x.rs", bad, &p).len(), 3);
        assert_eq!(run_on("rust/src/util/x.rs", bad, &p).len(), 0);
    }

    #[test]
    fn l2_blessing_and_counter_exemptions() {
        let p = Policy {
            l2_blessed: vec!["dot_tree4".to_string()],
            ..Policy::default()
        };
        let src = "fn dot_tree4() { while t < n { s0 += a * b; } }\n\
                   fn rogue() { for x in xs { acc += d * d; i += 1; j += LANES; } }";
        let got = run_on("rust/src/runtime/simd.rs", src, &p);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].lint, "L2");
        assert!(got[0].message.contains("rogue"));
    }

    #[test]
    fn l3_scopes_by_fn_and_by_file() {
        let p = Policy {
            l3_exact_f64_fns: vec!["sums_to_set".to_string()],
            ..Policy::default()
        };
        let src = "fn sums_to_set() { let x = d as f32; }\nfn pairwise_block() { let y = d as f32; }";
        let got = run_on("rust/src/runtime/batch.rs", src, &p);
        assert_eq!(got.len(), 1);
        assert!(got[0].message.contains("sums_to_set"));
        let got = run_on("rust/src/algo/local_search.rs", "fn any() { let x = d as f32; }", &p);
        assert_eq!(got.len(), 1, "whole-file scope for the column store");
    }

    #[test]
    fn l4_time_sources_and_exemptions() {
        let p = Policy::default();
        let src = "fn f() { let t0 = Instant::now(); let s = SystemTime::now(); }";
        let got = run_on("rust/src/streaming/mod.rs", src, &p);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].symbol, "Instant::now");
        assert_eq!(run_on("rust/src/util/timer.rs", src, &p).len(), 0);
        assert_eq!(run_on("rust/src/bench/mod.rs", src, &p).len(), 0);
    }

    #[test]
    fn test_modules_are_skipped() {
        let p = Policy::default();
        let src = "#[cfg(test)]\nmod tests {\n  use std::collections::HashSet;\n  fn f() { let t = Instant::now(); }\n}";
        assert_eq!(run_on("rust/src/algo/x.rs", src, &p).len(), 0);
    }
}
