//! `cargo xtask lint [--deny] [--json] [--out <path>] [--root <path>]`
//!
//! Exit codes: 0 clean (or findings without `--deny`), 1 findings under
//! `--deny`, 2 usage/config/IO error.  CI runs
//! `cargo xtask lint --deny --out lint-report.json` and archives the
//! report.

use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> String {
    "usage: cargo xtask lint [--deny] [--json] [--out <path>] [--root <path>]\n\
     \n\
     Runs the dmmc-lint determinism-contract pass (L1-L4, see\n\
     rust/xtask/src/lints.rs) over rust/src, applying the allowlist in\n\
     rust/lint.toml.\n\
     \n\
       --deny        exit 1 if any finding survives the allowlist\n\
       --json        print the JSON report to stdout instead of human text\n\
       --out <path>  also write the JSON report to <path>\n\
       --root <path> repo root (default: the workspace this binary was\n\
                     built from)\n"
        .to_string()
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run_cli(&args) {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("dmmc-lint: error: {msg}");
            ExitCode::from(2)
        }
    }
}

fn run_cli(args: &[String]) -> Result<ExitCode, String> {
    let mut deny = false;
    let mut json = false;
    let mut out_path: Option<PathBuf> = None;
    // xtask lives at <root>/rust/xtask, so the default root is two up.
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..");

    let mut it = args.iter();
    match it.next().map(String::as_str) {
        Some("lint") => {}
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{}", usage());
            return Ok(ExitCode::SUCCESS);
        }
        Some(other) => return Err(format!("unknown subcommand `{other}`\n{}", usage())),
    }
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--deny" => deny = true,
            "--json" => json = true,
            "--out" => {
                out_path = Some(PathBuf::from(
                    it.next().ok_or("--out needs a path argument")?,
                ))
            }
            "--root" => {
                root = PathBuf::from(it.next().ok_or("--root needs a path argument")?)
            }
            other => return Err(format!("unknown flag `{other}`\n{}", usage())),
        }
    }

    let policy_path = root.join("rust").join("lint.toml");
    let policy_src = std::fs::read_to_string(&policy_path)
        .map_err(|e| format!("read {}: {e}", policy_path.display()))?;
    let policy = xtask::allowlist::parse(&policy_src, "rust/lint.toml")?;

    let files = xtask::collect_sources(&root)?;
    let report = xtask::run(&files, &policy);

    if json {
        print!("{}", report.to_json());
    } else {
        print!("{}", report.render_human());
    }
    if let Some(p) = out_path {
        std::fs::write(&p, report.to_json()).map_err(|e| format!("write {}: {e}", p.display()))?;
    }

    if deny && !report.is_clean() {
        Ok(ExitCode::FAILURE)
    } else {
        Ok(ExitCode::SUCCESS)
    }
}
