//! dmmc-lint: determinism-contract static analysis for the
//! matroid-coreset tree (`cargo xtask lint`).
//!
//! The crate is zero-dependency by design (the repo builds without
//! network access): [`lexer`] is a hand-rolled Rust token scanner —
//! comments, strings, raw strings, char-vs-lifetime, numeric literals —
//! and [`allowlist`] is a strict reader for the TOML subset that
//! `rust/lint.toml` uses.  [`lints`] holds the four contract lints
//! (L1 hash-collection, L2 float-accum, L3 narrowing-cast, L4
//! ambient-time-rng); [`report`] renders human and JSON output.
//!
//! The pass is deterministic end to end: files are walked in sorted
//! order and findings are sorted by `(path, line, lint)`, so two runs on
//! the same tree emit byte-identical reports — the lint holds itself to
//! the contract it enforces.

pub mod allowlist;
pub mod lexer;
pub mod lints;
pub mod report;

use std::fs;
use std::path::Path;

use allowlist::Policy;
use lints::SourceFile;
use report::{Finding, LintReport};

/// Collect every `*.rs` file under `<root>/rust/src`, repo-relative with
/// `/` separators, in sorted order (so the report is stable across
/// platforms and filesystem iteration orders).
pub fn collect_sources(root: &Path) -> Result<Vec<SourceFile>, String> {
    let src_root = root.join("rust").join("src");
    let mut paths = Vec::new();
    walk(root, &src_root, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let content =
            fs::read_to_string(root.join(&p)).map_err(|e| format!("read {p}: {e}"))?;
        files.push(SourceFile { path: p, content });
    }
    Ok(files)
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<(), String> {
    let entries = fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            // Repo-relative with `/` separators (`rust/src/...`).
            let rel = path
                .strip_prefix(root)
                .map_err(|_| format!("{} escapes the repo root", path.display()))?;
            let comps: Vec<String> = rel
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect();
            out.push(comps.join("/"));
        }
    }
    Ok(())
}

/// Run the full lint pass: per-file lints, allowlist suppression, and
/// the two allowlist-hygiene findings.
///
/// * **A1 `stale-allowlist`** — an `[[allow]]` entry that suppressed
///   nothing on this tree.  Entries must stay load-bearing: once the
///   underlying code is fixed the entry has to be deleted, and deleting
///   any *used* entry makes its finding resurface — so the allowlist is
///   exact in both directions.
/// * **A2 `missing-justification`** — an `[[allow]]` entry with an empty
///   `justification`.  Suppression without a recorded reason is not
///   reviewable.
pub fn run(files: &[SourceFile], policy: &Policy) -> LintReport {
    let mut raw = Vec::new();
    for f in files {
        lints::lint_file(f, policy, &mut raw);
    }

    let mut used = vec![false; policy.allow.len()];
    let mut findings = Vec::new();
    let mut suppressed = 0u32;
    for f in raw {
        match policy.allow.iter().position(|e| e.matches(&f)) {
            Some(i) => {
                used[i] = true;
                suppressed += 1;
            }
            None => findings.push(f),
        }
    }

    for (entry, used) in policy.allow.iter().zip(&used) {
        if !used {
            findings.push(Finding {
                lint: "A1".to_string(),
                name: "stale-allowlist".to_string(),
                path: policy.source_path.clone(),
                line: entry.line,
                symbol: entry.symbol.clone(),
                message: format!(
                    "[[allow]] entry ({} in {}) suppressed nothing on this tree; \
                     delete it",
                    entry.lint, entry.path
                ),
            });
        }
        if entry.justification.trim().is_empty() {
            findings.push(Finding {
                lint: "A2".to_string(),
                name: "missing-justification".to_string(),
                path: policy.source_path.clone(),
                line: entry.line,
                symbol: entry.symbol.clone(),
                message: format!(
                    "[[allow]] entry ({} in {}) has no justification",
                    entry.lint, entry.path
                ),
            });
        }
    }

    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.lint.as_str()).cmp(&(b.path.as_str(), b.line, b.lint.as_str()))
    });
    LintReport {
        findings,
        suppressed,
        files_scanned: files.len() as u32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use allowlist::AllowEntry;

    fn file(path: &str, content: &str) -> SourceFile {
        SourceFile {
            path: path.to_string(),
            content: content.to_string(),
        }
    }

    #[test]
    fn suppression_counts_and_symbol_pinning() {
        let files = vec![file(
            "rust/src/matroid/t.rs",
            "use std::collections::{HashMap, HashSet};",
        )];
        let policy = Policy {
            allow: vec![AllowEntry {
                lint: "L1".into(),
                path: "rust/src/matroid/t.rs".into(),
                symbol: "HashSet".into(),
                justification: "membership-only".into(),
                line: 10,
            }],
            source_path: "rust/lint.toml".into(),
            ..Policy::default()
        };
        let report = run(&files, &policy);
        assert_eq!(report.suppressed, 1, "HashSet suppressed");
        assert_eq!(report.findings.len(), 1, "HashMap survives the pinned entry");
        assert_eq!(report.findings[0].symbol, "HashMap");
    }

    #[test]
    fn stale_and_unjustified_entries_are_findings() {
        let policy = Policy {
            allow: vec![AllowEntry {
                lint: "L4".into(),
                path: "rust/src/nonexistent.rs".into(),
                symbol: String::new(),
                justification: String::new(),
                line: 3,
            }],
            source_path: "rust/lint.toml".into(),
            ..Policy::default()
        };
        let report = run(&[], &policy);
        let lints: Vec<&str> = report.findings.iter().map(|f| f.lint.as_str()).collect();
        assert_eq!(lints, ["A1", "A2"]);
        assert_eq!(report.findings[0].path, "rust/lint.toml");
        assert_eq!(report.findings[0].line, 3);
    }

    #[test]
    fn findings_are_sorted() {
        let files = vec![
            file("rust/src/matroid/z.rs", "use std::collections::HashMap;"),
            file("rust/src/algo/a.rs", "\nfn f() { let m = HashMap::new(); }"),
        ];
        let report = run(&files, &Policy::default());
        let keys: Vec<(&str, u32)> = report
            .findings
            .iter()
            .map(|f| (f.path.as_str(), f.line))
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }
}
