//! A small Rust token scanner — the front end of dmmc-lint.
//!
//! The offline image ships no `syn`, so the lints run on a hand-rolled
//! lexical pass instead of a real AST.  The scanner is exact about the
//! things that would otherwise cause false positives — comments (line,
//! nested block, doc), string/char/byte literals, raw strings and raw
//! identifiers, lifetimes, numeric literals with suffixes — and emits a
//! flat token stream with line numbers.  Structural context (enclosing
//! function, loop bodies, `#[cfg(test)]` regions) is reconstructed from
//! this stream by [`crate::lints::contexts`].
//!
//! Known, documented approximations (the tree is rustfmt-formatted, which
//! CI enforces, so these cannot bite in practice):
//!
//! * `*p=x` / `&x=y` without spaces would lex as a compound-assign token;
//!   rustfmt always spaces binary assignment.

/// Token classification — only as fine-grained as the lints need.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `for`, `HashMap`, ...).
    Ident,
    /// Single punctuation character.
    Punct,
    /// Compound assignment operator (`+=`, `-=`, `*=`, `/=`, `%=`, `&=`,
    /// `|=`, `^=`) — the accumulation shape lint L2 looks for.
    CompoundAssign,
    /// Integer literal (decimal, hex, octal, binary; any suffix).
    Int,
    /// Float literal (has a fraction, an exponent, or an `f32`/`f64`
    /// suffix).
    Float,
    /// String or byte-string literal (normal or raw); contents dropped.
    Str,
    /// Char or byte-char literal.
    Char,
    /// Lifetime (`'a`, `'outer` loop labels).
    Lifetime,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Scan `src` into a token stream.  Never fails: unrecognized bytes are
/// skipped (lints only ever look for specific shapes, so dropping an
/// exotic byte is safe and keeps the scanner total).
pub fn tokenize(src: &str) -> Vec<Tok> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let count_newlines = |s: &[u8]| s.iter().filter(|&&c| c == b'\n').count() as u32;

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // comments
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // raw strings / raw identifiers / byte literals: r"  r#"  br"  b"  b'  r#ident
        if c == b'r' || c == b'b' {
            // raw (byte) string prefix: r / br, then #s, then a quote
            let after_r = if c == b'r' {
                Some(i + 1)
            } else if i + 1 < n && b[i + 1] == b'r' {
                Some(i + 2)
            } else {
                None
            };
            if let Some(start) = after_r {
                let mut j = start;
                let mut hashes = 0usize;
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == b'"' {
                    // raw (byte) string: scan to `"` followed by `hashes` #s
                    let start_line = line;
                    j += 1;
                    loop {
                        if j >= n {
                            break;
                        }
                        if b[j] == b'"' {
                            let mut k = 0usize;
                            while k < hashes && j + 1 + k < n && b[j + 1 + k] == b'#' {
                                k += 1;
                            }
                            if k == hashes {
                                j += 1 + hashes;
                                break;
                            }
                        }
                        if b[j] == b'\n' {
                            line += 1;
                        }
                        j += 1;
                    }
                    toks.push(Tok { kind: TokKind::Str, text: String::new(), line: start_line });
                    i = j;
                    continue;
                }
                if c == b'r' && hashes == 1 && j < n && is_ident_start(b[j]) {
                    // raw identifier r#type
                    let s = j;
                    while j < n && is_ident_char(b[j]) {
                        j += 1;
                    }
                    toks.push(Tok {
                        kind: TokKind::Ident,
                        text: src[s..j].to_string(),
                        line,
                    });
                    i = j;
                    continue;
                }
            }
            if c == b'b' && i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'\'') {
                // byte string / byte char with escapes
                let quote = b[i + 1];
                let start_line = line;
                let mut j = i + 2;
                while j < n && b[j] != quote {
                    if b[j] == b'\\' {
                        j += 2;
                    } else {
                        if b[j] == b'\n' {
                            line += 1;
                        }
                        j += 1;
                    }
                }
                toks.push(Tok {
                    kind: if quote == b'"' { TokKind::Str } else { TokKind::Char },
                    text: String::new(),
                    line: start_line,
                });
                i = (j + 1).min(n);
                continue;
            }
            // fall through: plain identifier starting with r/b
        }
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_char(b[i]) {
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: src[start..i].to_string(),
                line,
            });
            continue;
        }
        if c == b'"' {
            let start_line = line;
            let mut j = i + 1;
            while j < n && b[j] != b'"' {
                if b[j] == b'\\' {
                    j += 2;
                } else {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line: start_line });
            i = (j + 1).min(n);
            continue;
        }
        if c == b'\'' {
            // char literal or lifetime
            if i + 1 < n && b[i + 1] == b'\\' {
                // escaped char literal
                let mut j = i + 2;
                if j < n {
                    j += 1; // the escaped character
                }
                while j < n && b[j] != b'\'' {
                    j += 1; // \u{...} forms
                }
                toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                i = (j + 1).min(n);
                continue;
            }
            // one character (possibly multi-byte) then a closing quote -> char
            let mut j = i + 1;
            if j < n {
                let ch_len = utf8_len(b[j]);
                j += ch_len;
            }
            if j < n && b[j] == b'\'' {
                toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                i = j + 1;
                continue;
            }
            // lifetime: 'ident
            let start = i + 1;
            let mut j = start;
            while j < n && is_ident_char(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Lifetime,
                text: src[start..j].to_string(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            let mut is_float = false;
            if c == b'0' && i + 1 < n && matches!(b[i + 1], b'x' | b'o' | b'b' | b'X' | b'O' | b'B')
            {
                i += 2;
                while i < n && (b[i].is_ascii_hexdigit() || b[i] == b'_') {
                    i += 1;
                }
            } else {
                while i < n && (b[i].is_ascii_digit() || b[i] == b'_') {
                    i += 1;
                }
                if i + 1 < n && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                    is_float = true;
                    i += 1;
                    while i < n && (b[i].is_ascii_digit() || b[i] == b'_') {
                        i += 1;
                    }
                } else if i < n
                    && b[i] == b'.'
                    && (i + 1 >= n || !(is_ident_start(b[i + 1]) || b[i + 1] == b'.'))
                {
                    // `1.` trailing-dot float (but not `1.max(..)` or `0..n`)
                    is_float = true;
                    i += 1;
                }
                if i < n && (b[i] == b'e' || b[i] == b'E') {
                    let mut j = i + 1;
                    if j < n && (b[j] == b'+' || b[j] == b'-') {
                        j += 1;
                    }
                    if j < n && b[j].is_ascii_digit() {
                        is_float = true;
                        i = j;
                        while i < n && (b[i].is_ascii_digit() || b[i] == b'_') {
                            i += 1;
                        }
                    }
                }
            }
            // type suffix
            if i < n && is_ident_start(b[i]) {
                let s = i;
                while i < n && is_ident_char(b[i]) {
                    i += 1;
                }
                if src[s..i].starts_with('f') {
                    is_float = true;
                }
            }
            toks.push(Tok {
                kind: if is_float { TokKind::Float } else { TokKind::Int },
                text: src[start..i].to_string(),
                line,
            });
            continue;
        }
        // compound assignment operators
        if matches!(c, b'+' | b'-' | b'*' | b'/' | b'%' | b'^' | b'&' | b'|')
            && i + 1 < n
            && b[i + 1] == b'='
        {
            toks.push(Tok {
                kind: TokKind::CompoundAssign,
                text: format!("{}=", c as char),
                line,
            });
            i += 2;
            continue;
        }
        // single punctuation (multi-byte UTF-8 outside literals: skip)
        let len = utf8_len(c);
        if len == 1 {
            toks.push(Tok {
                kind: TokKind::Punct,
                text: (c as char).to_string(),
                line,
            });
        } else {
            line += count_newlines(&b[i..(i + len).min(n)]);
        }
        i += len;
    }
    toks
}

fn utf8_len(b0: u8) -> usize {
    if b0 < 0x80 {
        1
    } else if b0 >= 0xF0 {
        4
    } else if b0 >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        tokenize(src).into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_and_strings_are_invisible() {
        let toks = tokenize("let x = \"HashMap\"; // HashMap\n/* HashMap */ let y;");
        assert!(toks.iter().all(|t| t.text != "HashMap"));
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Str).count(), 1);
    }

    #[test]
    fn raw_strings_and_raw_idents() {
        let toks = tokenize("let s = r#\"HashMap \" inner\"#; let r#type = 1;");
        assert!(toks.iter().all(|t| t.text != "HashMap"));
        assert!(toks.iter().any(|t| t.text == "type" && t.kind == TokKind::Ident));
    }

    #[test]
    fn chars_and_lifetimes() {
        let toks = tokenize("'a' 'x: loop {} fn f<'b>(v: &'b str) {} '\\n'");
        let lifetimes: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["x", "b", "b"]);
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Char).count(), 2);
    }

    #[test]
    fn numbers_classify() {
        let toks = tokenize("1 2.5 1e3 0x1F 7usize 1.0f32 3f64 1.max(2) 0..4");
        let kinds: Vec<TokKind> = toks
            .iter()
            .filter(|t| matches!(t.kind, TokKind::Int | TokKind::Float))
            .map(|t| t.kind)
            .collect();
        assert_eq!(
            kinds,
            vec![
                TokKind::Int,
                TokKind::Float,
                TokKind::Float,
                TokKind::Int,
                TokKind::Int,
                TokKind::Float,
                TokKind::Float,
                TokKind::Int, // 1 in 1.max(2)
                TokKind::Int, // 2
                TokKind::Int, // 0
                TokKind::Int, // 4
            ]
        );
    }

    #[test]
    fn compound_assign_is_one_token() {
        let toks = tokenize("s += d; t -= 1; a == b; c = d;");
        let compounds: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::CompoundAssign)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(compounds, vec!["+=", "-="]);
        assert_eq!(texts("a==b").iter().filter(|t| *t == "=").count(), 2);
    }

    #[test]
    fn line_numbers_track_multiline_constructs() {
        let src = "let a = 1;\n/* two\nlines */ let b = \"x\ny\"; let c = 2;";
        let toks = tokenize(src);
        let c_tok = toks.iter().find(|t| t.text == "c").unwrap();
        assert_eq!(c_tok.line, 4);
    }
}
