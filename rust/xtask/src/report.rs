//! Finding/report types and the two output renderers (human, JSON).
//!
//! JSON is hand-rolled (zero-dependency crate); the shape is stable and
//! versioned so CI can archive reports across runs:
//!
//! ```json
//! {
//!   "tool": "dmmc-lint",
//!   "version": 1,
//!   "files_scanned": 42,
//!   "suppressed": 4,
//!   "findings": [
//!     {"lint": "L1", "name": "hash-collection", "path": "rust/src/...",
//!      "line": 10, "symbol": "HashMap", "message": "..."}
//!   ]
//! }
//! ```

/// One lint violation (or allowlist-hygiene finding A1/A2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Lint id: "L1".."L4", or "A1"/"A2" for allowlist hygiene.
    pub lint: String,
    /// Stable kebab-case name, e.g. "hash-collection".
    pub name: String,
    /// Repo-relative path with `/` separators.
    pub path: String,
    pub line: u32,
    /// The offending symbol (e.g. "HashMap", "Instant::now") — allowlist
    /// entries can pin on this.
    pub symbol: String,
    pub message: String,
}

/// The result of a full lint run.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    /// Findings matched (and silenced) by allowlist entries.
    pub suppressed: u32,
    pub files_scanned: u32,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256 + self.findings.len() * 160);
        out.push_str("{\n");
        out.push_str("  \"tool\": \"dmmc-lint\",\n");
        out.push_str("  \"version\": 1,\n");
        out.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        out.push_str(&format!("  \"suppressed\": {},\n", self.suppressed));
        out.push_str("  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n    {");
            out.push_str(&format!("\"lint\": {}, ", json_str(&f.lint)));
            out.push_str(&format!("\"name\": {}, ", json_str(&f.name)));
            out.push_str(&format!("\"path\": {}, ", json_str(&f.path)));
            out.push_str(&format!("\"line\": {}, ", f.line));
            out.push_str(&format!("\"symbol\": {}, ", json_str(&f.symbol)));
            out.push_str(&format!("\"message\": {}", json_str(&f.message)));
            out.push('}');
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("]\n}\n");
        out
    }

    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}:{}: [{} {}] {}\n",
                f.path, f.line, f.lint, f.name, f.message
            ));
        }
        out.push_str(&format!(
            "dmmc-lint: {} finding(s), {} suppressed by rust/lint.toml, {} file(s) scanned\n",
            self.findings.len(),
            self.suppressed,
            self.files_scanned
        ));
        out
    }
}

/// Escape a string as a JSON string literal (with surrounding quotes).
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping() {
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
    }

    #[test]
    fn json_shape() {
        let report = LintReport {
            findings: vec![Finding {
                lint: "L1".into(),
                name: "hash-collection".into(),
                path: "rust/src/algo/x.rs".into(),
                line: 7,
                symbol: "HashMap".into(),
                message: "order-sensitive".into(),
            }],
            suppressed: 2,
            files_scanned: 5,
        };
        let j = report.to_json();
        assert!(j.contains("\"tool\": \"dmmc-lint\""));
        assert!(j.contains("\"version\": 1"));
        assert!(j.contains("\"files_scanned\": 5"));
        assert!(j.contains("\"suppressed\": 2"));
        assert!(j.contains("\"lint\": \"L1\""));
        assert!(j.contains("\"line\": 7"));
    }

    #[test]
    fn human_summary_line() {
        let report = LintReport {
            findings: Vec::new(),
            suppressed: 1,
            files_scanned: 3,
        };
        let h = report.render_human();
        assert!(h.contains("0 finding(s)"));
        assert!(h.contains("1 suppressed"));
    }
}
