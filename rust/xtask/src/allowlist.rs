//! `rust/lint.toml` — the checked-in dmmc-lint policy.
//!
//! Zero-dependency, so this is a strict reader for the TOML *subset* the
//! policy file uses (and nothing more — unknown sections or keys are hard
//! errors, so a typo cannot silently widen the allowlist):
//!
//! ```toml
//! [l2]
//! blessed = ["dot_tree4", "sums_chunk"]
//!
//! [l3]
//! exact_f64_fns = ["sums_to_set", "dists_to_points"]
//!
//! [[allow]]
//! lint = "L1"
//! path = "rust/src/matroid/transversal.rs"
//! symbol = "HashSet"            # optional: pin one symbol
//! justification = "membership-only; never iterated"
//! ```
//!
//! Every `[[allow]]` entry must carry a non-empty `justification`
//! (enforced as finding `A2 missing-justification`), and every entry must
//! actually suppress something on the current tree (an unused entry is
//! finding `A1 stale-allowlist`) — so the allowlist can only ever shrink
//! to exactly the justified exceptions.

use crate::report::Finding;

/// One `[[allow]]` entry.
#[derive(Debug, Clone, Default)]
pub struct AllowEntry {
    pub lint: String,
    pub path: String,
    /// Optional: only suppress findings whose `symbol` matches exactly
    /// (empty = any symbol of that lint in that file).
    pub symbol: String,
    pub justification: String,
    /// Line of the `[[allow]]` header in lint.toml (for A1/A2 findings).
    pub line: u32,
}

impl AllowEntry {
    pub fn matches(&self, f: &Finding) -> bool {
        self.lint == f.lint
            && self.path == f.path
            && (self.symbol.is_empty() || self.symbol == f.symbol)
    }
}

/// The parsed policy: allowlist + per-lint configuration.
#[derive(Debug, Clone, Default)]
pub struct Policy {
    pub allow: Vec<AllowEntry>,
    /// L2: functions allowed to accumulate floats in loops.
    pub l2_blessed: Vec<String>,
    /// L3: kernel functions whose bodies are exact-f64 paths.
    pub l3_exact_f64_fns: Vec<String>,
    /// Repo-relative path of the policy file (for A1/A2 findings).
    pub source_path: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Section {
    None,
    L2,
    L3,
    Allow,
}

/// Parse the policy file.  Errors are fatal to the lint run: a policy
/// that cannot be read strictly must not gate anything.
pub fn parse(src: &str, source_path: &str) -> Result<Policy, String> {
    let mut policy = Policy {
        source_path: source_path.to_string(),
        ..Policy::default()
    };
    let mut section = Section::None;
    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx as u32 + 1;
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line == "[[allow]]" {
            section = Section::Allow;
            policy.allow.push(AllowEntry {
                line: lineno,
                ..AllowEntry::default()
            });
            continue;
        }
        if line.starts_with("[[") {
            return Err(format!("lint.toml:{lineno}: unknown array table {line}"));
        }
        if line.starts_with('[') {
            section = match line.as_str() {
                "[l2]" => Section::L2,
                "[l3]" => Section::L3,
                _ => return Err(format!("lint.toml:{lineno}: unknown section {line}")),
            };
            continue;
        }
        let (key, value) = match line.split_once('=') {
            Some((k, v)) => (k.trim().to_string(), v.trim().to_string()),
            None => return Err(format!("lint.toml:{lineno}: expected `key = value`")),
        };
        match (section, key.as_str()) {
            (Section::L2, "blessed") => policy.l2_blessed = parse_string_array(&value, lineno)?,
            (Section::L3, "exact_f64_fns") => {
                policy.l3_exact_f64_fns = parse_string_array(&value, lineno)?
            }
            (Section::Allow, k @ ("lint" | "path" | "symbol" | "justification")) => {
                let s = parse_string(&value, lineno)?;
                let entry = policy
                    .allow
                    .last_mut()
                    .ok_or_else(|| format!("lint.toml:{lineno}: key outside [[allow]]"))?;
                match k {
                    "lint" => entry.lint = s,
                    "path" => entry.path = s,
                    "symbol" => entry.symbol = s,
                    _ => entry.justification = s,
                }
            }
            _ => {
                return Err(format!(
                    "lint.toml:{lineno}: unknown key `{key}` in this section"
                ))
            }
        }
    }
    for e in &policy.allow {
        if e.lint.is_empty() || e.path.is_empty() {
            return Err(format!(
                "lint.toml:{}: [[allow]] entry needs both `lint` and `path`",
                e.line
            ));
        }
    }
    Ok(policy)
}

/// Strip a `#` comment, respecting `#` inside double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let b = line.as_bytes();
    let mut in_str = false;
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'"' => in_str = !in_str,
            b'\\' if in_str => i += 1,
            b'#' if !in_str => return &line[..i],
            _ => {}
        }
        i += 1;
    }
    line
}

fn parse_string(value: &str, lineno: u32) -> Result<String, String> {
    let v = value.trim();
    if v.len() < 2 || !v.starts_with('"') || !v.ends_with('"') {
        return Err(format!("lint.toml:{lineno}: expected a \"string\", got `{v}`"));
    }
    let inner = &v[1..v.len() - 1];
    let mut out = String::with_capacity(inner.len());
    let mut chars = inner.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => {}
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

fn parse_string_array(value: &str, lineno: u32) -> Result<Vec<String>, String> {
    let v = value.trim();
    if !v.starts_with('[') || !v.ends_with(']') {
        return Err(format!("lint.toml:{lineno}: expected a [\"...\"] array"));
    }
    let inner = v[1..v.len() - 1].trim();
    if inner.is_empty() {
        return Ok(Vec::new());
    }
    inner
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| parse_string(s, lineno))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# policy
[l2]
blessed = ["dot_tree4", "sums_chunk"]

[l3]
exact_f64_fns = ["sums_to_set"]

[[allow]]
lint = "L1"
path = "rust/src/matroid/transversal.rs"
symbol = "HashSet"
justification = "membership-only # not a comment"
"#;

    #[test]
    fn parses_sections_and_entries() {
        let p = parse(SAMPLE, "rust/lint.toml").unwrap();
        assert_eq!(p.l2_blessed, vec!["dot_tree4", "sums_chunk"]);
        assert_eq!(p.l3_exact_f64_fns, vec!["sums_to_set"]);
        assert_eq!(p.allow.len(), 1);
        let e = &p.allow[0];
        assert_eq!(e.lint, "L1");
        assert_eq!(e.symbol, "HashSet");
        assert!(e.justification.contains("# not a comment"));
    }

    #[test]
    fn unknown_keys_and_sections_are_errors() {
        assert!(parse("[l9]\n", "t").is_err());
        assert!(parse("[l2]\nblssed = [\"x\"]\n", "t").is_err());
        assert!(parse("[[deny]]\n", "t").is_err());
        assert!(parse("[[allow]]\nlint = \"L1\"\n", "t").is_err(), "path required");
    }

    #[test]
    fn symbol_scoping_matches() {
        let e = AllowEntry {
            lint: "L1".into(),
            path: "a.rs".into(),
            symbol: "HashSet".into(),
            justification: "j".into(),
            line: 1,
        };
        let mut f = Finding {
            lint: "L1".into(),
            name: "hash-collection".into(),
            path: "a.rs".into(),
            line: 3,
            symbol: "HashSet".into(),
            message: String::new(),
        };
        assert!(e.matches(&f));
        f.symbol = "HashMap".into();
        assert!(!e.matches(&f), "symbol-pinned entry must not cover HashMap");
    }
}
