// dmmc-lint fixture: L3 narrowing-cast.  Linted as if it lived at
// rust/src/runtime/batch.rs — the cast inside `sums_to_set` (an
// exact-f64 kernel) is the finding; the same cast in `pairwise_block`
// (f32 by contract) is not.
pub fn sums_to_set(dists: &[f64], out: &mut [f32]) {
    for (slot, &d) in dists.iter().enumerate() {
        out[slot] = d as f32; // exact-f64 path: the L3 finding
    }
}

pub fn pairwise_block(dists: &[f64], out: &mut [f32]) {
    for (slot, &d) in dists.iter().enumerate() {
        out[slot] = d as f32; // f32 tile contract: allowed
    }
}
