// dmmc-lint fixture: a clean file — deterministic collections, no float
// accumulation outside blessed helpers, no ambient time/RNG.  Zero
// findings at any linted path.
use std::collections::BTreeMap;

pub fn category_counts(labels: &[u32]) -> Vec<(u32, usize)> {
    let mut counts: BTreeMap<u32, usize> = BTreeMap::new();
    for &l in labels {
        *counts.entry(l).or_insert(0) += 1;
    }
    counts.into_iter().collect()
}

#[cfg(test)]
mod tests {
    // test modules may use anything: lints skip them
    use std::collections::HashSet;

    #[test]
    fn hash_in_tests_is_fine() {
        let mut s = HashSet::new();
        assert!(s.insert(1));
        let t0 = std::time::Instant::now();
        assert!(t0.elapsed().as_secs() < 1);
    }
}
