// dmmc-lint fixture: L4 ambient-time-rng.  Linted as if it lived at
// rust/src/index/fixture.rs — `Instant::now`, `SystemTime` and
// `thread_rng` are the 3 findings; the `#[cfg(test)]` module is skipped.
// (Fixtures are lexed, never compiled, so the paths need not resolve.)
pub fn timed_query() -> u128 {
    let t0 = std::time::Instant::now();
    let _wall = std::time::SystemTime::now();
    let _seed: u64 = rand::thread_rng().gen();
    t0.elapsed().as_nanos()
}

#[cfg(test)]
mod tests {
    #[test]
    fn timing_in_tests_is_fine() {
        let t0 = std::time::Instant::now();
        assert!(t0.elapsed().as_secs() < 1);
    }
}
