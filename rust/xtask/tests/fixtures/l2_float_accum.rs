// dmmc-lint fixture: L2 float-accum.  Linted as if it lived at
// rust/src/runtime/simd.rs — `rogue_sum` accumulates in a loop outside
// the blessed list (1 finding); the counter/stride updates and the
// blessed helper do not fire.
const LANES: usize = 4;

pub fn dot_tree4(a: &[f32], b: &[f32]) -> f64 {
    let mut s0 = 0.0f64;
    let mut t = 0;
    while t < a.len() {
        s0 += a[t] as f64 * b[t] as f64; // blessed fn: allowed
        t += 1; // integer counter: allowed anywhere
    }
    s0
}

pub fn rogue_sum(xs: &[f64]) -> f64 {
    let mut acc = 0.0f64;
    let mut i = 0;
    while i < xs.len() {
        acc += xs[i] * xs[i]; // NOT blessed: the L2 finding
        i += LANES; // SCREAMING_CASE stride: allowed
    }
    acc
}
