// dmmc-lint fixture: L1 hash-collection.  Linted as if it lived at
// rust/src/algo/fixture.rs — two `HashMap` mentions (use + type) plus
// one `HashSet` = 3 findings.
use std::collections::HashMap;

pub fn category_counts(labels: &[u32]) -> Vec<(u32, usize)> {
    let mut counts: HashMap<u32, usize> = Default::default();
    for &l in labels {
        *counts.entry(l).or_insert(0) += 1;
    }
    // iteration order reaches the result: the L1 hazard
    let mut seen = std::collections::HashSet::new();
    counts.into_iter().filter(|&(l, _)| seen.insert(l)).collect()
}
