//! Fixture tests for the dmmc-lint pass itself: each bad snippet under
//! `tests/fixtures/` produces exactly its documented findings, the clean
//! snippet produces none, and the allowlist semantics (suppression,
//! symbol pinning, A1/A2 hygiene) behave as specified.
//!
//! The per-lint configuration (`[l2] blessed`, `[l3] exact_f64_fns`)
//! comes from the REAL `rust/lint.toml`, so these tests also pin that the
//! checked-in policy keeps the fixtures' expectations true.

use xtask::allowlist::{AllowEntry, Policy};
use xtask::lints::{lint_file, SourceFile};
use xtask::report::Finding;

const L1_FIXTURE: &str = include_str!("fixtures/l1_hash_iteration.rs");
const L2_FIXTURE: &str = include_str!("fixtures/l2_float_accum.rs");
const L3_FIXTURE: &str = include_str!("fixtures/l3_narrow_cast.rs");
const L4_FIXTURE: &str = include_str!("fixtures/l4_ambient_time.rs");
const CLEAN_FIXTURE: &str = include_str!("fixtures/clean.rs");

/// The real checked-in policy, with the allowlist cleared so fixture
/// findings are observed raw (stale-entry hygiene is tested separately).
fn real_policy_no_allow() -> Policy {
    let src = include_str!("../../lint.toml");
    let mut policy = xtask::allowlist::parse(src, "rust/lint.toml").expect("rust/lint.toml parses");
    policy.allow.clear();
    policy
}

fn lint_at(path: &str, content: &str, policy: &Policy) -> Vec<Finding> {
    let mut out = Vec::new();
    let file = SourceFile {
        path: path.to_string(),
        content: content.to_string(),
    };
    lint_file(&file, policy, &mut out);
    out
}

#[test]
fn l1_fixture_flags_hash_collections() {
    let got = lint_at("rust/src/algo/fixture.rs", L1_FIXTURE, &real_policy_no_allow());
    let symbols: Vec<&str> = got.iter().map(|f| f.symbol.as_str()).collect();
    assert_eq!(symbols, ["HashMap", "HashMap", "HashSet"], "{got:#?}");
    assert!(got.iter().all(|f| f.lint == "L1"));
}

#[test]
fn l2_fixture_flags_only_the_rogue_accumulator() {
    let got = lint_at("rust/src/runtime/simd.rs", L2_FIXTURE, &real_policy_no_allow());
    assert_eq!(got.len(), 1, "{got:#?}");
    assert_eq!(got[0].lint, "L2");
    assert!(got[0].message.contains("rogue_sum"));
}

#[test]
fn l3_fixture_flags_only_the_exact_f64_kernel() {
    let got = lint_at("rust/src/runtime/batch.rs", L3_FIXTURE, &real_policy_no_allow());
    assert_eq!(got.len(), 1, "{got:#?}");
    assert_eq!(got[0].lint, "L3");
    assert!(got[0].message.contains("sums_to_set"));
}

#[test]
fn l4_fixture_flags_time_and_rng_sources() {
    let got = lint_at("rust/src/index/fixture.rs", L4_FIXTURE, &real_policy_no_allow());
    let symbols: Vec<&str> = got.iter().map(|f| f.symbol.as_str()).collect();
    assert_eq!(symbols, ["Instant::now", "SystemTime", "thread_rng"], "{got:#?}");
    assert!(got.iter().all(|f| f.lint == "L4"));
}

#[test]
fn clean_fixture_is_clean_everywhere() {
    let policy = real_policy_no_allow();
    for path in [
        "rust/src/algo/fixture.rs",
        "rust/src/runtime/simd.rs",
        "rust/src/runtime/batch.rs",
        "rust/src/index/fixture.rs",
    ] {
        let got = lint_at(path, CLEAN_FIXTURE, &policy);
        assert!(got.is_empty(), "clean fixture at {path}: {got:#?}");
    }
}

#[test]
fn allowlist_suppresses_and_pins_symbols() {
    let mut policy = real_policy_no_allow();
    policy.allow.push(AllowEntry {
        lint: "L1".to_string(),
        path: "rust/src/algo/fixture.rs".to_string(),
        symbol: "HashSet".to_string(),
        justification: "fixture".to_string(),
        line: 1,
    });
    let files = [SourceFile {
        path: "rust/src/algo/fixture.rs".to_string(),
        content: L1_FIXTURE.to_string(),
    }];
    let report = xtask::run(&files, &policy);
    assert_eq!(report.suppressed, 1, "only the HashSet finding is suppressed");
    let symbols: Vec<&str> = report.findings.iter().map(|f| f.symbol.as_str()).collect();
    assert_eq!(symbols, ["HashMap", "HashMap"], "HashMap survives the pinned entry");
}

/// The telemetry subsystem gets exactly ONE ambient-clock dispensation:
/// the pinned `Instant::now` allow entry for `rust/src/obs/trace.rs` in
/// the real checked-in lint.toml (the trace-epoch anchor).  Any other
/// time or RNG source inside obs still fails the tree, and the entry is
/// load-bearing (symbol-pinned, justified) rather than a blanket waiver.
#[test]
fn obs_clock_is_allowed_only_via_the_pinned_entry() {
    let src = include_str!("../../lint.toml");
    let mut policy = xtask::allowlist::parse(src, "rust/lint.toml").expect("rust/lint.toml parses");
    policy.allow.retain(|e| e.path == "rust/src/obs/trace.rs");
    assert_eq!(policy.allow.len(), 1, "exactly one obs allow entry in rust/lint.toml");
    assert_eq!(policy.allow[0].lint, "L4");
    assert_eq!(policy.allow[0].symbol, "Instant::now", "entry is symbol-pinned");
    assert!(!policy.allow[0].justification.is_empty());

    // the L4 fixture (Instant::now + SystemTime + thread_rng), dropped
    // into the allowed file: only the pinned symbol is suppressed
    let files = [SourceFile {
        path: "rust/src/obs/trace.rs".to_string(),
        content: L4_FIXTURE.to_string(),
    }];
    let report = xtask::run(&files, &policy);
    assert_eq!(report.suppressed, 1, "only Instant::now rides the entry");
    let symbols: Vec<&str> = report.findings.iter().map(|f| f.symbol.as_str()).collect();
    assert_eq!(symbols, ["SystemTime", "thread_rng"], "{:#?}", report.findings);

    // and without the entry the clock is a raw finding — obs is L4-scoped
    let raw = lint_at("rust/src/obs/trace.rs", L4_FIXTURE, &real_policy_no_allow());
    assert!(
        raw.iter().any(|f| f.lint == "L4" && f.symbol == "Instant::now"),
        "{raw:#?}"
    );
}

#[test]
fn stale_and_unjustified_entries_are_findings() {
    let mut policy = real_policy_no_allow();
    policy.allow.push(AllowEntry {
        lint: "L1".to_string(),
        path: "rust/src/algo/nothing_here.rs".to_string(),
        symbol: String::new(),
        justification: String::new(),
        line: 42,
    });
    let report = xtask::run(&[], &policy);
    let lints: Vec<&str> = report.findings.iter().map(|f| f.lint.as_str()).collect();
    assert_eq!(lints, ["A1", "A2"]);
    assert!(report.findings.iter().all(|f| f.path == "rust/lint.toml" && f.line == 42));
}

#[test]
fn json_report_shape() {
    let files = [SourceFile {
        path: "rust/src/algo/fixture.rs".to_string(),
        content: L1_FIXTURE.to_string(),
    }];
    let report = xtask::run(&files, &real_policy_no_allow());
    let json = report.to_json();
    assert!(json.contains("\"tool\": \"dmmc-lint\""));
    assert!(json.contains("\"version\": 1"));
    assert!(json.contains("\"lint\": \"L1\""));
    assert!(json.contains("\"symbol\": \"HashMap\""));
    assert!(json.contains("\"files_scanned\": 1"));
}
