//! The self-enforcement test: `cargo xtask lint --deny` semantics over
//! the REAL tree with the REAL `rust/lint.toml`.
//!
//! Three properties, together making the allowlist exact in both
//! directions:
//!
//! 1. the current tree + current policy is clean (this is what CI's
//!    `lint` job gates on);
//! 2. removing ANY single `[[allow]]` entry makes the run fail — every
//!    entry is load-bearing right now;
//! 3. re-introducing a `HashMap` in `matroid/transversal.rs` makes the
//!    run fail — the entry there pins `symbol = "HashSet"`, so it cannot
//!    mask a regression of the map the matching actually iterates.

use std::path::PathBuf;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
}

fn real_policy() -> xtask::allowlist::Policy {
    let src = std::fs::read_to_string(repo_root().join("rust").join("lint.toml"))
        .expect("rust/lint.toml exists");
    xtask::allowlist::parse(&src, "rust/lint.toml").expect("rust/lint.toml parses")
}

#[test]
fn real_tree_is_clean_under_real_policy() {
    let files = xtask::collect_sources(&repo_root()).expect("walk rust/src");
    assert!(files.len() > 30, "walker found the tree ({} files)", files.len());
    let report = xtask::run(&files, &real_policy());
    assert!(
        report.is_clean(),
        "dmmc-lint findings on the real tree:\n{}",
        report.render_human()
    );
    assert!(report.suppressed > 0, "the allowlist should be load-bearing");
}

#[test]
fn removing_any_allow_entry_fails_the_tree() {
    let files = xtask::collect_sources(&repo_root()).expect("walk rust/src");
    let policy = real_policy();
    assert!(!policy.allow.is_empty());
    for drop in 0..policy.allow.len() {
        let mut reduced = policy.clone();
        let removed = reduced.allow.remove(drop);
        let report = xtask::run(&files, &reduced);
        assert!(
            !report.is_clean(),
            "allowlist entry {} ({} in {}) suppresses nothing — delete it",
            drop,
            removed.lint,
            removed.path
        );
    }
}

#[test]
fn reintroducing_hashmap_in_transversal_fails() {
    let files = xtask::collect_sources(&repo_root()).expect("walk rust/src");
    let mutated: Vec<xtask::lints::SourceFile> = files
        .into_iter()
        .map(|mut f| {
            if f.path == "rust/src/matroid/transversal.rs" {
                f.content = f.content.replace("BTreeMap", "HashMap");
            }
            f
        })
        .collect();
    let report = xtask::run(&mutated, &real_policy());
    assert!(
        report
            .findings
            .iter()
            .any(|f| f.lint == "L1" && f.symbol == "HashMap"),
        "the symbol-pinned HashSet entry must not mask a HashMap:\n{}",
        report.render_human()
    );
}
