//! CLI-level regressions for the `dmmc index` subcommands, run against
//! the real binary (`CARGO_BIN_EXE_dmmc`) so the argument grammar, the
//! printed contract lines, and the on-disk artifacts are all pinned at
//! the process boundary.
//!
//! * **append clamp** — `--count` over-asking is clamped to the rows the
//!   dataset still has, and the clamp is printed (the silent-shortfall
//!   bugfix); an exhausted index refuses further appends;
//! * **cross-process warm cache** — `index query` persists its result
//!   cache to the `.cache` sidecar, so a repeat invocation in a fresh
//!   process answers from cache (`dist_evals=cached`) bit-identically;
//! * **structured argument errors** — a bogus `--objective` enumerates
//!   every valid name (all six), `--k 1` is a clean error (diversity is
//!   defined over pairs, and `farness_coefficient` would divide by zero),
//!   and the remote-edge objective answers through the matching finisher.

use std::path::PathBuf;
use std::process::{Command, Output};

fn dmmc(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_dmmc"))
        .args(args)
        .output()
        .expect("spawn dmmc")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).to_string()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dmmc_cli_{}_{name}", std::process::id()))
}

#[test]
fn append_count_overask_clamps_and_says_so() {
    let idx = tmp("clamp.dmmcx");
    let idx_s = idx.to_str().unwrap();
    let built = dmmc(&[
        "index", "build", "--data", "cube:300x2", "--out", idx_s, "--k", "4", "--tau", "8",
        "--matroid", "uniform:4", "--engine", "scalar", "--count", "200", "--segment", "50",
        "--seed", "3",
    ]);
    assert!(built.status.success(), "build failed: {}", String::from_utf8_lossy(&built.stderr));

    // 100 rows remain; asking for 500 must clamp — loudly, not silently
    let appended = dmmc(&["index", "append", "--index", idx_s, "--count", "500"]);
    let out = stdout(&appended);
    assert!(appended.status.success(), "append failed: {out}");
    assert!(
        out.contains("requested 500 rows, clamped to the 100 remaining"),
        "clamp not printed:\n{out}"
    );
    assert!(out.contains("+100 rows"), "clamped count not ingested:\n{out}");

    // nothing remains: a further append is an error, not a zero-row no-op
    let exhausted = dmmc(&["index", "append", "--index", idx_s, "--count", "1"]);
    assert!(!exhausted.status.success());
    assert!(
        String::from_utf8_lossy(&exhausted.stderr).contains("already covers all"),
        "wrong exhaustion error: {}",
        String::from_utf8_lossy(&exhausted.stderr)
    );

    std::fs::remove_file(&idx).ok();
}

#[test]
fn repeat_query_hits_the_persisted_cache_across_processes() {
    let idx = tmp("warm.dmmcx");
    let idx_s = idx.to_str().unwrap();
    let built = dmmc(&[
        "index", "build", "--data", "cube:200x2", "--out", idx_s, "--k", "4", "--tau", "8",
        "--matroid", "uniform:4", "--engine", "scalar", "--seed", "5",
    ]);
    assert!(built.status.success(), "build failed: {}", String::from_utf8_lossy(&built.stderr));

    let query = ["index", "query", "--index", idx_s, "--k", "4"];
    let cold = dmmc(&query);
    let cold_out = stdout(&cold);
    assert!(cold.status.success(), "cold query failed: {cold_out}");
    assert!(cold_out.contains("warm=0"), "first run found a sidecar:\n{cold_out}");
    assert!(cold_out.contains("cache_hit=false"), "{cold_out}");
    assert!(cold_out.contains("persisted 1 cache entries"), "{cold_out}");

    let sidecar = PathBuf::from(format!("{idx_s}.cache"));
    assert!(sidecar.exists(), "query did not write the sidecar");

    // a fresh process answers the identical spec from the sidecar
    let warm = dmmc(&query);
    let warm_out = stdout(&warm);
    assert!(warm.status.success(), "warm query failed: {warm_out}");
    assert!(warm_out.contains("warm=1"), "sidecar not loaded:\n{warm_out}");
    assert!(warm_out.contains("cache_hit=true"), "{warm_out}");
    assert!(warm_out.contains("dist_evals=cached"), "{warm_out}");

    // bit-identical across processes: the printed diversity values match
    let diversity = |s: &str| {
        s.split_whitespace()
            .find_map(|tok| tok.strip_prefix("diversity="))
            .expect("no query result line")
            .to_string()
    };
    assert_eq!(diversity(&cold_out), diversity(&warm_out));

    std::fs::remove_file(&idx).ok();
    std::fs::remove_file(&sidecar).ok();
}

#[test]
fn bad_objective_and_small_k_are_structured_errors() {
    let idx = tmp("errs.dmmcx");
    let idx_s = idx.to_str().unwrap();
    let built = dmmc(&[
        "index", "build", "--data", "cube:150x2", "--out", idx_s, "--k", "4", "--tau", "8",
        "--matroid", "uniform:4", "--engine", "scalar", "--seed", "7",
    ]);
    assert!(built.status.success(), "build failed: {}", String::from_utf8_lossy(&built.stderr));

    // a bogus objective must enumerate every valid name, all six of them
    let bogus = dmmc(&[
        "index", "query", "--index", idx_s, "--k", "4", "--objective", "frobnicate",
    ]);
    assert!(!bogus.status.success());
    let err = String::from_utf8_lossy(&bogus.stderr).to_string();
    assert!(
        err.contains("sum|star|tree|cycle|bipartition|remote-edge"),
        "objective error does not enumerate the valid names:\n{err}"
    );

    // k = 1 is an error (diversity is defined over pairs), not a panic
    let small = dmmc(&["index", "query", "--index", idx_s, "--k", "1"]);
    assert!(!small.status.success());
    let err = String::from_utf8_lossy(&small.stderr).to_string();
    assert!(err.contains("below the minimum of 2"), "wrong small-k error:\n{err}");

    // an unknown finisher enumerates the valid ones, including matching
    let fin = dmmc(&["index", "query", "--index", idx_s, "--k", "4", "--finisher", "bogus"]);
    assert!(!fin.status.success());
    let err = String::from_utf8_lossy(&fin.stderr).to_string();
    assert!(
        err.contains("local-search|exhaustive|greedy|matching"),
        "finisher error does not enumerate the valid names:\n{err}"
    );

    // and the new surface works end to end: remote-edge via the matching race
    let re = dmmc(&[
        "index", "query", "--index", idx_s, "--k", "4", "--objective", "remote-edge",
        "--finisher", "matching",
    ]);
    let out = stdout(&re);
    assert!(
        re.status.success(),
        "remote-edge query failed: {out}\n{}",
        String::from_utf8_lossy(&re.stderr)
    );
    assert!(out.contains("diversity="), "{out}");
    assert!(out.contains("|sol|=4"), "{out}");

    std::fs::remove_file(&idx).ok();
    std::fs::remove_file(PathBuf::from(format!("{idx_s}.cache"))).ok();
}
