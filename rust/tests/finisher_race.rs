//! Integration: the matching-vs-GMM finisher race (`algo::matching`).
//!
//! Pins the race's three contracts across objectives and matroid types:
//!
//! * **best-of-both never loses** — the race result is at least as good
//!   as each standalone arm (matching, GMM) for every objective, under
//!   partition and transversal matroids;
//! * **determinism** — the winner is a pure function of
//!   `(dataset, matroid, k, candidates, objective, seed)`, and on
//!   Euclidean data the race is engine-independent (scalar vs batch
//!   produce bit-identical tiles, hence identical races);
//! * **quality sanity** — the race never exceeds the exhaustive optimum,
//!   and for remote-edge under a uniform matroid the GMM arm's classic
//!   farthest-point 2-approximation carries over to the race.

use matroid_coreset::algo::exhaustive::exhaustive_best;
use matroid_coreset::algo::matching::matching_race;
use matroid_coreset::data::synth;
use matroid_coreset::diversity::{Objective, ALL_OBJECTIVES};
use matroid_coreset::matroid::{Matroid, PartitionMatroid, TransversalMatroid, UniformMatroid};
use matroid_coreset::runtime::{BatchEngine, ScalarEngine};
use matroid_coreset::util::rng::Rng;

#[test]
fn race_never_loses_under_partition_matroid() {
    let ds = synth::clustered(90, 2, 5, 0.1, 3, 21);
    let m = PartitionMatroid::new(vec![2, 2, 2]);
    let cands: Vec<usize> = (0..ds.n()).collect();
    let e = ScalarEngine::new();
    for obj in ALL_OBJECTIVES {
        let mut rng = Rng::new(5);
        let race = matching_race(&ds, &m, 5, &cands, obj, &e, &mut rng).unwrap();
        assert_eq!(race.solution.len(), 5, "{obj:?}");
        assert!(m.is_independent(&ds, &race.solution), "{obj:?}");
        assert!(
            race.diversity >= race.matching_value - 1e-12
                && race.diversity >= race.gmm_value - 1e-12,
            "{obj:?}: race {} lost to an arm (matching {}, gmm {})",
            race.diversity,
            race.matching_value,
            race.gmm_value
        );
    }
}

#[test]
fn race_never_loses_under_transversal_matroid() {
    let ds = synth::wikisim(60, 5);
    let m = TransversalMatroid::new();
    let cands: Vec<usize> = (0..ds.n()).collect();
    let e = ScalarEngine::new();
    for obj in ALL_OBJECTIVES {
        let mut rng = Rng::new(9);
        let race = matching_race(&ds, &m, 4, &cands, obj, &e, &mut rng).unwrap();
        assert_eq!(race.solution.len(), 4, "{obj:?}");
        assert!(m.is_independent(&ds, &race.solution), "{obj:?}");
        assert!(
            race.diversity >= race.matching_value - 1e-12
                && race.diversity >= race.gmm_value - 1e-12,
            "{obj:?}: race {} lost to an arm (matching {}, gmm {})",
            race.diversity,
            race.matching_value,
            race.gmm_value
        );
    }
}

#[test]
fn race_is_deterministic_and_engine_independent() {
    let ds = synth::clustered(70, 3, 4, 0.1, 2, 8);
    let m = UniformMatroid::new(6);
    let cands: Vec<usize> = (0..ds.n()).collect();
    let scalar = ScalarEngine::new();
    let batch = BatchEngine::for_dataset(&ds);
    for obj in [Objective::RemoteEdge, Objective::Sum, Objective::Tree] {
        let run = |e: &dyn matroid_coreset::runtime::DistanceEngine, seed: u64| {
            let mut rng = Rng::new(seed);
            matching_race(&ds, &m, 6, &cands, obj, e, &mut rng).unwrap()
        };
        let (a, b) = (run(&scalar, 31), run(&scalar, 31));
        assert_eq!(a.solution, b.solution, "{obj:?}: same seed, different race");
        assert_eq!(a.winner, b.winner, "{obj:?}");
        assert_eq!(a.diversity.to_bits(), b.diversity.to_bits(), "{obj:?}");

        // Euclidean scalar/batch tiles are bit-identical, so the whole
        // race — both arms and the scoring — must match bitwise
        let c = run(&batch, 31);
        assert_eq!(a.solution, c.solution, "{obj:?}: engine changed the race");
        assert_eq!(a.winner, c.winner, "{obj:?}");
        assert_eq!(a.diversity.to_bits(), c.diversity.to_bits(), "{obj:?}");
    }
}

#[test]
fn race_bounded_by_exhaustive_and_two_approx_on_remote_edge() {
    // small enough to brute-force: the race can never beat the optimum,
    // and for remote-edge under a uniform matroid the farthest-point arm
    // guarantees half the optimum (Ravi–Rosenkrantz–Tayi), which the
    // best-of-both inherits
    let ds = synth::clustered(30, 2, 5, 0.05, 1, 17);
    let m = UniformMatroid::new(4);
    let cands: Vec<usize> = (0..ds.n()).collect();
    let e = ScalarEngine::new();
    let opt = exhaustive_best(&ds, &m, 4, &cands, Objective::RemoteEdge, &e)
        .unwrap()
        .diversity;
    let mut rng = Rng::new(3);
    let race = matching_race(&ds, &m, 4, &cands, Objective::RemoteEdge, &e, &mut rng).unwrap();
    assert!(
        race.diversity <= opt + 1e-9,
        "race {} beat the exhaustive optimum {opt}",
        race.diversity
    );
    assert!(
        race.diversity >= 0.5 * opt - 1e-9,
        "race {} below the 2-approximation floor of optimum {opt}",
        race.diversity
    );
}
