//! Integration: the composable coreset index + query service
//! (`matroid_coreset::index`).
//!
//! Pins the three acceptance properties of the subsystem:
//!
//! * **quality** — the root coreset of a B-batch index matches the
//!   one-shot SeqCoreset grid of `coreset_quality` on the same data,
//!   within a pinned ratio, for all six objectives (Table 1 plus
//!   remote-edge) under both partition and transversal matroids;
//! * **sublinear appends** — each append touches exactly
//!   `1 + trailing_ones(segments)` nodes (O(log segments)), and the
//!   cumulative dist-eval ledger stays far below rebuilding a one-shot
//!   coreset per batch (the cost the index amortizes away);
//! * **free cache hits** — a repeated query is answered bit-identically
//!   to its cold run at zero distance evaluations, and appends invalidate
//!   via the tree epoch.

use matroid_coreset::algo::exhaustive::exhaustive_best;
use matroid_coreset::algo::seq_coreset::seq_coreset;
use matroid_coreset::algo::Budget;
use matroid_coreset::core::{Dataset, Metric};
use matroid_coreset::data::synth;
use matroid_coreset::diversity::{Objective, ALL_OBJECTIVES};
use matroid_coreset::index::{
    CoresetIndex, DistEvals, IndexConfig, LeafIngest, QueryService, QuerySpec,
};
use matroid_coreset::matroid::{
    maximal_independent, PartitionMatroid, TransversalMatroid, UniformMatroid,
};
use matroid_coreset::prop_assert;
use matroid_coreset::proptest::{check, Gen};
use matroid_coreset::runtime::{EngineKind, ScalarEngine};

/// The quality pin: the index's merge-and-reduce root must stay within
/// this factor of the one-shot coreset's optimum (the eps = 0.5 grid of
/// `coreset_quality`; the root is empirically near-lossless at these
/// budgets, so 0.5 leaves a wide determinism margin).
const PINNED_RATIO: f64 = 0.5;

fn scalar_cfg(k_max: usize, tau: usize) -> IndexConfig {
    IndexConfig {
        engine: EngineKind::Scalar,
        leaf_ingest: LeafIngest::Seq,
        ..IndexConfig::new(k_max, tau)
    }
}

#[test]
fn root_quality_matches_one_shot_grid() {
    // the exact dataset/matroid of coreset_quality's partition grid
    let ds = synth::clustered(60, 2, 6, 0.05, 3, 1);
    let m = PartitionMatroid::new(vec![2, 2, 2]);
    let k = 4;
    let one_shot = seq_coreset(&ds, &m, k, Budget::Epsilon(0.5), &ScalarEngine::new()).unwrap();

    let mut idx = CoresetIndex::new(&ds, &m, scalar_cfg(k, 12));
    let order: Vec<usize> = (0..ds.n()).collect();
    idx.ingest(&order, 15).unwrap();
    assert_eq!(idx.segments(), 4);
    let root = idx.root();
    assert!(root.len() < ds.n());

    let scalar = ScalarEngine::new();
    for obj in ALL_OBJECTIVES {
        let os_opt = exhaustive_best(&ds, &m, k, &one_shot.indices, obj, &scalar)
            .unwrap()
            .diversity;
        let root_opt = exhaustive_best(&ds, &m, k, &root, obj, &scalar).unwrap().diversity;
        assert!(
            root_opt >= PINNED_RATIO * os_opt - 1e-9,
            "{obj:?}: index root {root_opt} < {PINNED_RATIO} * one-shot {os_opt}"
        );
    }

    // and the end-to-end (1 - eps) shape of coreset_quality for sum:
    // root optimum vs the brute-force optimum of the full input
    let all: Vec<usize> = (0..ds.n()).collect();
    let brute = exhaustive_best(&ds, &m, k, &all, Objective::Sum, &scalar).unwrap().diversity;
    let root_sum = exhaustive_best(&ds, &m, k, &root, Objective::Sum, &scalar)
        .unwrap()
        .diversity;
    assert!(
        root_sum >= PINNED_RATIO * brute - 1e-9,
        "sum: index root {root_sum} < {PINNED_RATIO} * brute {brute}"
    );
}

#[test]
fn root_quality_matches_one_shot_grid_transversal() {
    // the exact dataset/matroid of coreset_quality's transversal grid
    let ds = synth::wikisim(50, 3);
    let m = TransversalMatroid::new();
    let k = 3;
    let one_shot = seq_coreset(&ds, &m, k, Budget::Epsilon(0.5), &ScalarEngine::new()).unwrap();

    let mut idx = CoresetIndex::new(&ds, &m, scalar_cfg(k, 10));
    let order: Vec<usize> = (0..ds.n()).collect();
    idx.ingest(&order, 13).unwrap();
    assert_eq!(idx.segments(), 4);
    let root = idx.root();

    let scalar = ScalarEngine::new();
    for obj in ALL_OBJECTIVES {
        let os_opt = exhaustive_best(&ds, &m, k, &one_shot.indices, obj, &scalar)
            .unwrap()
            .diversity;
        let root_opt = exhaustive_best(&ds, &m, k, &root, obj, &scalar).unwrap().diversity;
        assert!(
            root_opt >= PINNED_RATIO * os_opt - 1e-9,
            "transversal {obj:?}: index root {root_opt} < {PINNED_RATIO} * one-shot {os_opt}"
        );
    }
}

#[test]
fn appends_are_sublinear_in_the_dist_eval_ledger() {
    let ds = synth::uniform_cube(1024, 2, 9);
    let m = UniformMatroid::new(4);
    let (k, tau, seg) = (4usize, 8usize, 32usize);
    let order: Vec<usize> = (0..ds.n()).collect();

    // the analytic leaf formula is the measured oracle counter
    let view = ds.subset(&order[..seg]);
    let probe = ScalarEngine::new();
    let cs = seq_coreset(&view, &m, k, Budget::Clusters(tau), &probe).unwrap();
    assert_eq!(
        probe.dist_evals(),
        (cs.n_clusters * view.n()) as u64,
        "leaf ledger formula out of sync with the ScalarEngine counter"
    );

    let mut idx = CoresetIndex::new(&ds, &m, scalar_cfg(k, tau));
    let mut index_evals = 0u64;
    for (s, chunk) in order.chunks(seg).enumerate() {
        let r = idx.append(chunk).unwrap();
        // binary-counter carry: O(log segments) nodes, exactly
        assert_eq!(r.nodes_touched, 1 + (s as u32).trailing_ones() as usize);
        let log2_bound = usize::BITS - (s + 1).leading_zeros();
        assert!(
            r.nodes_touched <= log2_bound as usize + 1,
            "append {} touched {} nodes > log bound {}",
            s + 1,
            r.nodes_touched,
            log2_bound + 1
        );
        // the receipt's ledger is exactly its reduce log
        let analytic: u64 = r.reduce_log.iter().map(|&(n, c)| (n * c) as u64).sum();
        assert_eq!(r.dist_evals, analytic);
        index_evals += r.dist_evals;
    }
    assert_eq!(index_evals, idx.stats().dist_evals);

    // the amortized claim: maintaining the tree costs several times less
    // than rebuilding a one-shot coreset after every batch (measured with
    // the oracle counter, not assumed)
    let mut naive_evals = 0u64;
    for prefix in 1..=(order.len() / seg) {
        let upto = ds.subset(&order[..prefix * seg]);
        let counter = ScalarEngine::new();
        seq_coreset(&upto, &m, k, Budget::Clusters(tau), &counter).unwrap();
        naive_evals += counter.dist_evals();
    }
    assert!(
        index_evals * 3 < naive_evals,
        "index appends ({index_evals} evals) not sublinear vs per-batch rebuilds ({naive_evals})"
    );
}

#[test]
fn cached_repeat_query_does_zero_distance_evals() {
    let ds = synth::clustered(500, 3, 5, 0.1, 4, 13);
    let m = PartitionMatroid::new(vec![2; 4]);
    let k = 6;
    let order: Vec<usize> = (0..ds.n()).collect();

    let mut svc = QueryService::new(CoresetIndex::new(&ds, &m, scalar_cfg(k, 16)));
    for chunk in order.chunks(125) {
        svc.append(chunk).unwrap();
    }
    let spec = QuerySpec::sum_local_search(k, EngineKind::Scalar);
    let cold = svc.query(&spec).unwrap();
    assert!(!cold.cache_hit);
    assert!(
        cold.dist_evals.measured().unwrap() > 0,
        "cold query must do distance work"
    );
    assert_eq!(cold.result.solution.len(), k);

    let hit = svc.query(&spec).unwrap();
    assert!(hit.cache_hit);
    assert_eq!(
        hit.dist_evals,
        DistEvals::CachedZero,
        "cache hit must cost zero distance evals"
    );

    // bit-identity: the hit equals the cold run, and a second service
    // with the identical ingest reproduces the same cold result (cold
    // runs are deterministic given (spec, epoch))
    assert_eq!(hit.result.solution, cold.result.solution);
    assert_eq!(hit.result.diversity.to_bits(), cold.result.diversity.to_bits());
    let mut svc2 = QueryService::new(CoresetIndex::new(&ds, &m, scalar_cfg(k, 16)));
    for chunk in order.chunks(125) {
        svc2.append(chunk).unwrap();
    }
    let cold2 = svc2.query(&spec).unwrap();
    assert!(!cold2.cache_hit);
    assert_eq!(cold2.result.solution, cold.result.solution);
    assert_eq!(cold2.result.diversity.to_bits(), cold.result.diversity.to_bits());

    // appending invalidates: the next query is cold again at a new epoch
    assert!(svc.append(&[]).is_err(), "empty batch must be rejected");
    let epoch_before = cold.epoch;
    svc.append(&order[..10]).unwrap();
    let after = svc.query(&spec).unwrap();
    assert!(!after.cache_hit);
    assert!(after.epoch > epoch_before);
}

fn random_partition_instance(g: &mut Gen, max_n: usize) -> (Dataset, PartitionMatroid) {
    let n = g.usize_in(12, max_n);
    let dim = g.usize_in(1, 4);
    let ncat = g.usize_in(2, 4) as u32;
    let coords = g.vec_f32(n * dim, 2.0);
    let categories = (0..n).map(|_| vec![g.rng.below(ncat as usize) as u32]).collect();
    let ds = Dataset::new(dim, Metric::Euclidean, coords, categories, ncat, "idx-prop");
    let caps: Vec<usize> = (0..ncat).map(|_| g.usize_in(1, 3)).collect();
    (ds, PartitionMatroid::new(caps))
}

#[test]
fn prop_merge_order_does_not_change_root_feasibility() {
    check("index-merge-order-feasibility", 30, |g| {
        let (ds, m) = random_partition_instance(g, 80);
        let k = g.usize_in(2, 5);
        let seg = g.usize_in(4, 20);
        let order: Vec<usize> = (0..ds.n()).collect();
        let segments: Vec<&[usize]> = order.chunks(seg).collect();

        // forward segment order
        let mut fwd = CoresetIndex::new(&ds, &m, scalar_cfg(k, g.usize_in(2, 8)));
        for s in &segments {
            fwd.append(s).map_err(|e| e.to_string())?;
        }
        // reversed segment order (same segments, different merge history)
        let mut rev = CoresetIndex::new(&ds, &m, *fwd.config());
        for s in segments.iter().rev() {
            rev.append(s).map_err(|e| e.to_string())?;
        }

        let a = maximal_independent(&m, &ds, &fwd.root(), k).len();
        let b = maximal_independent(&m, &ds, &rev.root(), k).len();
        prop_assert!(
            a == b,
            "merge order changed root feasibility: forward {a}, reversed {b}"
        );
        Ok(())
    });
}

#[test]
fn prop_index_feasible_whenever_one_shot_is() {
    check("index-feasibility-vs-one-shot", 30, |g| {
        let (ds, m) = random_partition_instance(g, 80);
        let k = g.usize_in(2, 5);
        let tau = g.usize_in(2, 8);
        let one_shot =
            seq_coreset(&ds, &m, k, Budget::Clusters(tau), &ScalarEngine::new())
                .map_err(|e| e.to_string())?;
        let os_len = maximal_independent(&m, &ds, &one_shot.indices, k).len();

        let mut idx = CoresetIndex::new(&ds, &m, scalar_cfg(k, tau));
        let order: Vec<usize> = (0..ds.n()).collect();
        idx.ingest(&order, g.usize_in(4, 20)).map_err(|e| e.to_string())?;
        let root_len = maximal_independent(&m, &ds, &idx.root(), k).len();
        prop_assert!(
            root_len >= os_len,
            "index from {} batches lost feasibility: root mis {root_len} < one-shot {os_len}",
            idx.segments()
        );
        Ok(())
    });
}

#[test]
fn prop_cache_hits_bit_identical_to_cold() {
    check("index-cache-bit-identity", 15, |g| {
        let (ds, m) = random_partition_instance(g, 60);
        let rank: usize = {
            // k capped by what the instance can actually seat
            let all: Vec<usize> = (0..ds.n()).collect();
            maximal_independent(&m, &ds, &all, 5).len()
        };
        if rank < 2 {
            return Ok(());
        }
        let k = g.usize_in(2, rank);
        let mut svc =
            QueryService::new(CoresetIndex::new(&ds, &m, scalar_cfg(k, g.usize_in(2, 6))));
        let order: Vec<usize> = (0..ds.n()).collect();
        let seg = g.usize_in(5, 30);
        for chunk in order.chunks(seg) {
            svc.append(chunk).map_err(|e| e.to_string())?;
        }
        let spec = QuerySpec::sum_local_search(k, EngineKind::Scalar);
        let cold = svc.query(&spec).map_err(|e| e.to_string())?;
        let hit = svc.query(&spec).map_err(|e| e.to_string())?;
        prop_assert!(hit.cache_hit, "second identical query missed the cache");
        prop_assert!(
            hit.dist_evals == DistEvals::CachedZero,
            "cache hit did distance work: {:?}",
            hit.dist_evals
        );
        prop_assert!(
            hit.result.solution == cold.result.solution
                && hit.result.diversity.to_bits() == cold.result.diversity.to_bits(),
            "cache hit not bit-identical to the cold query"
        );
        Ok(())
    });
}
