//! Trajectory identity of the incremental AMT local search.
//!
//! The incremental path (column store + per-swap `dists_to_points` deltas,
//! re-anchored every epoch) must walk the **identical** swap trajectory —
//! same (solution, swaps, oracle_calls, passes) — as the retained
//! `ExhaustiveRestart` reference semantics, across the scalar and batch
//! engines (plus simd on Euclidean datasets, where its contract is
//! bit-exact) and across matroid families (uniform, partition,
//! transversal, graphic, laminar), while cutting the per-accepted-swap
//! distance work from Theta(n k) to Theta(n).  The distance-work claims
//! are pinned with the `ScalarEngine` evaluation counter and an exact
//! analytic ledger.

use matroid_coreset::algo::local_search::{
    local_search_sum, LocalSearchMode, LocalSearchParams, LocalSearchResult, REANCHOR_EPOCH,
};
use matroid_coreset::core::{Dataset, Metric};
use matroid_coreset::data::synth;
use matroid_coreset::matroid::{
    maximal_independent, GraphicMatroid, LaminarMatroid, Matroid, PartitionMatroid,
    TransversalMatroid, UniformMatroid,
};
use matroid_coreset::runtime::engine::{DistanceEngine, ScalarEngine};
use matroid_coreset::runtime::{BatchEngine, SimdEngine};
use matroid_coreset::util::rng::Rng;

const SEED: u64 = 7;

fn run(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    engine: &dyn DistanceEngine,
    mode: LocalSearchMode,
    init: Option<Vec<usize>>,
) -> LocalSearchResult {
    let cands: Vec<usize> = (0..ds.n()).collect();
    let mut rng = Rng::new(SEED);
    local_search_sum(
        ds,
        m,
        k,
        &cands,
        engine,
        LocalSearchParams {
            mode,
            ..Default::default()
        },
        init,
        &mut rng,
    )
    .unwrap()
}

/// A deliberately weak warm start — the nearest feasible points to point
/// 0 — so every test instance walks a non-trivial swap trajectory.
fn weak_init(ds: &Dataset, m: &dyn Matroid, k: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..ds.n()).collect();
    order.sort_by(|&a, &b| ds.dist(0, a).partial_cmp(&ds.dist(0, b)).unwrap());
    maximal_independent(m, ds, &order, k)
}

/// Every (engine x mode) run must report the same trajectory; the
/// restart/incremental diversities may differ only in the last ulps.
///
/// The engine axis covers all bit-exact backends for the dataset's
/// metric: scalar and batch always, simd on Euclidean datasets.  Simd's
/// cosine paths are tolerance-level (`EngineKind::contract`), where the
/// `1e-12`-relative swap-acceptance slack no longer guarantees the exact
/// same swap sequence — like the PJRT backend, simd-on-cosine is
/// validated by the conformance suite's tolerance mode instead.
fn assert_trajectory_pinned(ds: &Dataset, m: &dyn Matroid, k: usize, label: &str) {
    let scalar = ScalarEngine::new();
    let batch = BatchEngine::for_dataset(ds);
    let simd = SimdEngine::for_dataset(ds);
    let mut engines: Vec<&dyn DistanceEngine> = vec![&scalar, &batch];
    if ds.metric == Metric::Euclidean {
        engines.push(&simd);
    }
    let init = weak_init(ds, m, k);
    let mut base: Option<LocalSearchResult> = None;
    for engine in engines {
        for mode in [
            LocalSearchMode::Incremental,
            LocalSearchMode::ExhaustiveRestart,
        ] {
            let res = run(ds, m, k, engine, mode, Some(init.clone()));
            assert!(
                m.is_independent(ds, &res.solution),
                "{label}/{}/{}: infeasible solution",
                engine.name(),
                mode.name()
            );
            match &base {
                None => {
                    // the instances are chosen so the scan actually swaps —
                    // a zero-swap trajectory would pin nothing
                    assert!(res.swaps >= 1, "{label}: trivial trajectory");
                    base = Some(res);
                }
                Some(b) => {
                    let tag = format!("{label}/{}/{}", engine.name(), mode.name());
                    assert_eq!(b.solution, res.solution, "{tag}: solution diverged");
                    assert_eq!(b.swaps, res.swaps, "{tag}: swap count diverged");
                    assert_eq!(b.oracle_calls, res.oracle_calls, "{tag}: oracle calls diverged");
                    assert_eq!(b.passes, res.passes, "{tag}: pass count diverged");
                    assert!(
                        (b.diversity - res.diversity).abs() <= 1e-9 * b.diversity.max(1.0),
                        "{tag}: diversity diverged: {} vs {}",
                        b.diversity,
                        res.diversity
                    );
                }
            }
        }
    }
}

#[test]
fn trajectory_identity_uniform_matroid() {
    let ds = synth::uniform_cube(150, 3, 21);
    let m = UniformMatroid::new(6);
    assert_trajectory_pinned(&ds, &m, 6, "uniform");
}

#[test]
fn trajectory_identity_partition_matroid() {
    let ds = synth::clustered(120, 3, 4, 0.3, 4, 11);
    let m = PartitionMatroid::new(vec![2; 4]);
    assert_trajectory_pinned(&ds, &m, 6, "partition");
}

#[test]
fn trajectory_identity_transversal_matroid() {
    // wikisim is cosine: the delta columns run through the precomputed
    // sqnorm parts path of the batch backend; the simd backend sits this
    // one out (its cosine contract is tolerance-level, not bit-exact —
    // see assert_trajectory_pinned)
    let ds = synth::wikisim(130, 5);
    let m = TransversalMatroid::new();
    assert_trajectory_pinned(&ds, &m, 5, "transversal");
}

#[test]
fn trajectory_identity_graphic_matroid() {
    // a genuinely general-construction matroid: points are random edges
    // of a 30-vertex graph, independence = forest
    let ds = synth::uniform_cube(100, 2, 9);
    let mut rng = Rng::new(33);
    let edges: Vec<(u32, u32)> = (0..ds.n())
        .map(|_| loop {
            let a = rng.below(30) as u32;
            let b = rng.below(30) as u32;
            if a != b {
                break (a, b);
            }
        })
        .collect();
    let m = GraphicMatroid::new(edges, 30);
    assert_trajectory_pinned(&ds, &m, 6, "graphic");
}

#[test]
fn trajectory_identity_laminar_matroid() {
    let ds = synth::clustered(100, 2, 5, 0.3, 5, 13);
    let m = LaminarMatroid::hierarchy(
        vec![2; 5],
        vec![(vec![0, 1], 3), (vec![2, 3, 4], 3)],
    );
    assert_trajectory_pinned(&ds, &m, 5, "laminar");
}

#[test]
fn trajectory_identity_with_non_subset_warm_start() {
    // the warm start need not be a subset of the candidate set: the
    // incremental member pass never assumes solution members have columns
    let ds = synth::uniform_cube(120, 2, 17);
    let m = UniformMatroid::new(4);
    let cands: Vec<usize> = (0..ds.n()).step_by(2).collect();
    let init = vec![1, 3, 5, 7]; // disjoint from the even-index candidates
    let scalar = ScalarEngine::new();
    let batch = BatchEngine::for_dataset(&ds);
    let simd = SimdEngine::for_dataset(&ds);
    let engines: [&dyn DistanceEngine; 3] = [&scalar, &batch, &simd];
    let mut base: Option<LocalSearchResult> = None;
    for engine in engines {
        for mode in [
            LocalSearchMode::Incremental,
            LocalSearchMode::ExhaustiveRestart,
        ] {
            let mut rng = Rng::new(SEED);
            let res = local_search_sum(
                &ds,
                &m,
                4,
                &cands,
                engine,
                LocalSearchParams {
                    mode,
                    ..Default::default()
                },
                Some(init.clone()),
                &mut rng,
            )
            .unwrap();
            match &base {
                None => {
                    assert!(res.swaps >= 1, "warm start must be improvable");
                    base = Some(res);
                }
                Some(b) => {
                    assert_eq!(b.solution, res.solution);
                    assert_eq!(b.swaps, res.swaps);
                    assert_eq!(b.oracle_calls, res.oracle_calls);
                    assert_eq!(b.passes, res.passes);
                }
            }
        }
    }
}

/// Point 0 plus its k-1 nearest neighbours: a near-zero-diversity start
/// that forces a long swap trajectory.
fn tight_cluster_init(ds: &Dataset, k: usize) -> Vec<usize> {
    let mut by_dist: Vec<usize> = (1..ds.n()).collect();
    by_dist.sort_by(|&a, &b| ds.dist(0, a).partial_cmp(&ds.dist(0, b)).unwrap());
    let mut init = vec![0];
    init.extend_from_slice(&by_dist[..k - 1]);
    init
}

#[test]
fn incremental_cuts_distance_work_3x_on_150pt_k6() {
    // the ISSUE 3 acceptance instance: 150 points, k = 6, a long
    // adversarial trajectory, distance work counted by the ScalarEngine
    let ds = synth::uniform_cube(150, 3, 21);
    let m = UniformMatroid::new(6);
    let (n, k) = (150u64, 6u64);
    let init = tight_cluster_init(&ds, 6);

    let e_inc = ScalarEngine::new();
    let inc = run(
        &ds,
        &m,
        6,
        &e_inc,
        LocalSearchMode::Incremental,
        Some(init.clone()),
    );
    let e_rst = ScalarEngine::new();
    let rst = run(
        &ds,
        &m,
        6,
        &e_rst,
        LocalSearchMode::ExhaustiveRestart,
        Some(init),
    );

    // identical trajectory first — the speedup must not buy a different
    // answer
    assert_eq!(inc.solution, rst.solution);
    assert_eq!(inc.swaps, rst.swaps);
    assert_eq!(inc.oracle_calls, rst.oracle_calls);
    assert_eq!(inc.passes, rst.passes);

    // the engine-reported ledger equals the engine's own counter
    assert_eq!(inc.dist_evals, e_inc.dist_evals());
    assert_eq!(rst.dist_evals, e_rst.dist_evals());

    // premise for the ratio: the tight-cluster start forces a real
    // trajectory (the ratio approaches k/2 only as swaps accumulate)
    assert!(
        inc.swaps >= 5,
        "adversarial start produced only {} swaps",
        inc.swaps
    );

    // the headline: >= 3x fewer distance evaluations end to end
    assert!(
        rst.dist_evals >= 3 * inc.dist_evals,
        "restart {} < 3x incremental {}",
        rst.dist_evals,
        inc.dist_evals
    );

    // per-swap shape: restart re-scans all Theta(n k) candidate sums every
    // pass ...
    assert!(rst.dist_evals >= rst.passes as u64 * (n - 1) * k);
    // ... while the incremental path pays Theta(n) per accepted swap on
    // top of the one-time column-store build
    let build = (n * k - k) + k * (k - 1);
    assert!((inc.dist_evals - build) <= inc.swaps as u64 * 2 * n);
}

#[test]
fn incremental_dist_eval_ledger_is_exact() {
    // close the loop analytically: with candidates = the whole input and
    // an init inside the candidate set, the incremental eval ledger is
    //   k(k-1)            initial member sums
    // + n k - k           column-store build (k self-pairs excluded)
    // + S (n - 1 + 2(k-1)) one incoming column + one two-column member
    //                      pass per accepted swap
    // + floor(S / epoch) k(k-1)   re-anchor member refreshes
    // + k(k-1)            final fresh member pass
    // and the anchor cadence must not change the trajectory
    let ds = synth::uniform_cube(150, 3, 21);
    let m = UniformMatroid::new(6);
    let cands: Vec<usize> = (0..ds.n()).collect();
    let (n, k) = (150u64, 6u64);
    let init = tight_cluster_init(&ds, 6);
    let member = k * (k - 1);
    let mut base: Option<(Vec<usize>, usize)> = None;
    for epoch in [2usize, REANCHOR_EPOCH] {
        let e = ScalarEngine::new();
        let mut rng = Rng::new(SEED);
        let res = local_search_sum(
            &ds,
            &m,
            6,
            &cands,
            &e,
            LocalSearchParams {
                reanchor_epoch: epoch,
                ..Default::default()
            },
            Some(init.clone()),
            &mut rng,
        )
        .unwrap();
        let s = res.swaps as u64;
        let expected = member
            + (n * k - k)
            + s * ((n - 1) + 2 * (k - 1))
            + (s / epoch as u64) * member
            + member;
        assert_eq!(res.dist_evals, expected, "epoch {epoch}: ledger mismatch");
        assert_eq!(res.dist_evals, e.dist_evals(), "epoch {epoch}: counter mismatch");
        match &base {
            None => {
                assert!(
                    res.swaps >= 2 * epoch,
                    "need multiple re-anchors to exercise the epoch contract, got {} swaps",
                    res.swaps
                );
                base = Some((res.solution, res.swaps));
            }
            Some((sol, swaps)) => {
                assert_eq!(*sol, res.solution, "anchor cadence changed the solution");
                assert_eq!(*swaps, res.swaps, "anchor cadence changed the swap count");
            }
        }
    }
}
