//! Integration: PJRT artifacts vs. the Rust scalar oracle.
//!
//! Closes the correctness triangle pallas == jnp-ref == rust-scalar from
//! the Rust side: every AOT artifact (gmm_update / gmm_assign / pairwise,
//! both metrics, both padded dims) is executed through the `xla` crate and
//! compared elementwise against `ScalarEngine` / `Dataset::dist`.
//!
//! Requires `make artifacts` (skipped with a message otherwise, so plain
//! `cargo test` works in a fresh checkout).

use matroid_coreset::algo::gmm::{gmm, GmmStop};
use matroid_coreset::core::{Dataset, Metric};
use matroid_coreset::data::synth;
use matroid_coreset::runtime::engine::{DistanceEngine, ScalarEngine};
use matroid_coreset::runtime::{default_artifact_dir, Manifest, PjrtEngine};
use matroid_coreset::util::rng::Rng;

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load(default_artifact_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime_numerics: {e:#} (run `make artifacts`)");
            None
        }
    }
}

/// A dataset with both metrics exercised and a dim that forces padding.
fn dataset(metric: Metric, n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let coords: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
    Dataset::new(dim, metric, coords, vec![vec![0]; n], 1, "rt")
}

#[test]
fn update_min_matches_scalar_both_metrics() {
    let Some(manifest) = manifest_or_skip() else { return };
    for metric in [Metric::Euclidean, Metric::Cosine] {
        // n deliberately NOT a multiple of NP; dim 25 pads to 32
        let ds = dataset(metric, 3000, 25, 1);
        let pjrt = PjrtEngine::for_dataset(&manifest, &ds).unwrap();
        let scalar = ScalarEngine::new();
        let n = ds.n();
        let mut mp = vec![f32::INFINITY; n];
        let mut ap = vec![u32::MAX; n];
        let mut ms = vec![f32::INFINITY; n];
        let mut as_ = vec![u32::MAX; n];
        for (id, c) in [0usize, 17, n - 1, n / 2].into_iter().enumerate() {
            pjrt.update_min(&ds, c, id as u32, &mut mp, &mut ap).unwrap();
            scalar.update_min(&ds, c, id as u32, &mut ms, &mut as_).unwrap();
        }
        for i in 0..n {
            // the kernel's MXU-friendly expanded form |x|^2+|c|^2-2xc has
            // O(sqrt(eps_f32)*|x|) residue at d ~ 0 (see python tests):
            // allow ~1e-2 absolute on top of the relative band
            assert!(
                (mp[i] - ms[i]).abs() < 2e-3 * ms[i].max(1.0) + 1e-2,
                "{metric:?} point {i}: pjrt {} vs scalar {}",
                mp[i],
                ms[i]
            );
        }
        // argmins agree wherever the two nearest centers are not borderline
        let mismatches = (0..n).filter(|&i| ap[i] != as_[i]).count();
        assert!(mismatches < n / 100, "{metric:?}: {mismatches} argmin mismatches");
    }
}

#[test]
fn assign_all_matches_scalar() {
    let Some(manifest) = manifest_or_skip() else { return };
    let ds = dataset(Metric::Euclidean, 2500, 40, 2); // pads to 64
    let pjrt = PjrtEngine::for_dataset(&manifest, &ds).unwrap();
    assert_eq!(pjrt.padded_dim(), 64);
    let centers: Vec<usize> = (0..300).map(|i| i * 7 % ds.n()).collect(); // > TC: 2 tiles
    let (mind, arg) = pjrt.assign_all(&ds, &centers).unwrap();
    for i in (0..ds.n()).step_by(97) {
        let mut best = f64::INFINITY;
        for &c in &centers {
            best = best.min(ds.dist(i, c));
        }
        assert!(
            (mind[i] as f64 - best).abs() < 2e-3 * best.max(1.0) + 1e-2,
            "point {i}: {} vs {}",
            mind[i],
            best
        );
        // the reported argmin must point at a center achieving ~best
        let picked = centers[arg[i] as usize];
        assert!((ds.dist(i, picked) - best).abs() < 2e-3 * best.max(1.0) + 1e-2);
    }
}

#[test]
fn pairwise_block_matches_dataset_dist() {
    let Some(manifest) = manifest_or_skip() else { return };
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 600, 25, 3);
        let pjrt = PjrtEngine::for_dataset(&manifest, &ds).unwrap();
        let rows: Vec<usize> = (0..40).collect();
        let cols: Vec<usize> = (100..160).collect();
        let block = pjrt.pairwise_block(&ds, &rows, &cols).unwrap();
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                let expect = ds.dist(i, j);
                let got = block[r * cols.len() + c] as f64;
                assert!(
                    (got - expect).abs() < 2e-3 * expect.max(1.0) + 2e-3,
                    "{metric:?} ({i},{j}): {got} vs {expect}"
                );
            }
        }
    }
}

#[test]
fn gmm_with_pjrt_engine_matches_scalar_centers() {
    let Some(manifest) = manifest_or_skip() else { return };
    let ds = synth::clustered(2000, 8, 10, 0.05, 1, 4);
    let pjrt = PjrtEngine::for_dataset(&manifest, &ds).unwrap();
    let scalar = ScalarEngine::new();
    let c_pjrt = gmm(&ds, &pjrt, 0, GmmStop::Clusters(10)).unwrap();
    let c_scalar = gmm(&ds, &scalar, 0, GmmStop::Clusters(10)).unwrap();
    // identical farthest-point trajectories modulo fp ties: radii must agree
    assert!(
        (c_pjrt.radius - c_scalar.radius).abs() < 2e-3 * c_scalar.radius.max(1e-9),
        "radius {} vs {}",
        c_pjrt.radius,
        c_scalar.radius
    );
    assert_eq!(c_pjrt.centers.len(), c_scalar.centers.len());
}

#[test]
fn engine_rejects_wrong_dataset() {
    let Some(manifest) = manifest_or_skip() else { return };
    let ds = dataset(Metric::Euclidean, 500, 8, 5);
    let other = dataset(Metric::Euclidean, 400, 8, 6);
    let pjrt = PjrtEngine::for_dataset(&manifest, &ds).unwrap();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut m = vec![f32::INFINITY; other.n()];
        let mut a = vec![u32::MAX; other.n()];
        let _ = pjrt.update_min(&other, 0, 0, &mut m, &mut a);
    }));
    assert!(result.is_err(), "mismatched dataset must be rejected");
}

#[test]
fn oversize_dim_rejected() {
    let Some(manifest) = manifest_or_skip() else { return };
    let ds = dataset(Metric::Euclidean, 10, 100, 7); // 100 > max dim 64
    assert!(PjrtEngine::for_dataset(&manifest, &ds).is_err());
}
