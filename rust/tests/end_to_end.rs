//! Integration: full pipelines (coreset setting x finisher x matroid x
//! objective) through the coordinator — the protocol of paper §5 end to end.

use matroid_coreset::algo::Budget;
use matroid_coreset::coordinator::{
    build_dataset, build_matroid, run_pipeline, DatasetSpec, Finisher, MatroidSpec, Pipeline,
    Setting,
};
use matroid_coreset::diversity::Objective;
use matroid_coreset::matroid::Matroid;
use matroid_coreset::runtime::EngineKind;
use matroid_coreset::streaming::StreamMode;

fn pipe(setting: Setting, finisher: Finisher) -> Pipeline {
    Pipeline {
        setting,
        finisher,
        engine: EngineKind::Scalar,
    }
}

#[test]
fn wikisim_transversal_all_settings_consistent_quality() {
    let spec = DatasetSpec::Wikisim { n: 1500, seed: 1 };
    let ds = build_dataset(&spec).unwrap();
    let m = build_matroid(&MatroidSpec::Transversal, &ds);
    let k = 8;
    let seq = run_pipeline(
        &ds, &m, k, Objective::Sum,
        pipe(Setting::Seq { budget: Budget::Clusters(32) }, Finisher::LocalSearch { gamma: 0.0 }),
        1,
    ).unwrap();
    let stream = run_pipeline(
        &ds, &m, k, Objective::Sum,
        pipe(Setting::Stream { mode: StreamMode::Tau(32) }, Finisher::LocalSearch { gamma: 0.0 }),
        1,
    ).unwrap();
    let mr = run_pipeline(
        &ds, &m, k, Objective::Sum,
        pipe(
            Setting::MapReduce { workers: 4, budget: Budget::Clusters(8), second_round_tau: None },
            Finisher::LocalSearch { gamma: 0.0 },
        ),
        1,
    ).unwrap();
    for (name, out) in [("seq", &seq), ("stream", &stream), ("mr", &mr)] {
        assert_eq!(out.solution.len(), k, "{name}");
        assert!(m.is_independent(&ds, &out.solution), "{name}");
        assert!(out.diversity > 0.0, "{name}");
    }
    // all three coreset routes land within a reasonable band of each other
    let best = seq.diversity.max(stream.diversity).max(mr.diversity);
    let worst = seq.diversity.min(stream.diversity).min(mr.diversity);
    assert!(worst >= 0.6 * best, "settings disagree too much: {worst} vs {best}");
}

#[test]
fn songsim_partition_rank_and_pipeline() {
    let spec = DatasetSpec::Songsim { n: 2000, seed: 2 };
    let ds = build_dataset(&spec).unwrap();
    let m = build_matroid(&MatroidSpec::default_for(&spec), &ds);
    let rank = m.rank_bound(&ds);
    assert!((80..=110).contains(&rank), "rank {rank} out of Table-2 band");
    let k = rank / 4;
    let out = run_pipeline(
        &ds, &m, k, Objective::Sum,
        pipe(Setting::Seq { budget: Budget::Clusters(16) }, Finisher::LocalSearch { gamma: 0.0 }),
        2,
    ).unwrap();
    assert_eq!(out.solution.len(), k);
    assert!(m.is_independent(&ds, &out.solution));
}

#[test]
fn coreset_pipeline_beats_greedy_matches_full_ls() {
    // coreset + LS must come close to full-input LS and beat plain greedy
    let spec = DatasetSpec::Cube { n: 400, dim: 4, seed: 3 };
    let ds = build_dataset(&spec).unwrap();
    let m = build_matroid(&MatroidSpec::Uniform(6), &ds);
    let k = 6;
    let full = run_pipeline(
        &ds, &m, k, Objective::Sum,
        pipe(Setting::Full, Finisher::LocalSearch { gamma: 0.0 }), 3,
    ).unwrap();
    let coreset = run_pipeline(
        &ds, &m, k, Objective::Sum,
        pipe(Setting::Seq { budget: Budget::Clusters(32) }, Finisher::LocalSearch { gamma: 0.0 }),
        3,
    ).unwrap();
    let greedy = run_pipeline(
        &ds, &m, k, Objective::Sum,
        pipe(Setting::Full, Finisher::Greedy), 3,
    ).unwrap();
    assert!(
        coreset.diversity >= 0.9 * full.diversity,
        "coreset LS {} far below full LS {}", coreset.diversity, full.diversity
    );
    assert!(coreset.diversity >= 0.95 * greedy.diversity);
}

#[test]
fn non_sum_objectives_via_exhaustive_on_coreset() {
    let spec = DatasetSpec::Cube { n: 300, dim: 3, seed: 4 };
    let ds = build_dataset(&spec).unwrap();
    let m = build_matroid(&MatroidSpec::Uniform(4), &ds);
    for obj in [Objective::Star, Objective::Tree, Objective::Cycle, Objective::Bipartition] {
        let out = run_pipeline(
            &ds, &m, 4, obj,
            pipe(Setting::Seq { budget: Budget::Clusters(8) }, Finisher::Exhaustive),
            5,
        ).unwrap();
        assert_eq!(out.solution.len(), 4, "{obj:?}");
        assert!(out.diversity > 0.0, "{obj:?}");
    }
}

#[test]
fn second_round_recompression_keeps_quality() {
    let spec = DatasetSpec::Cube { n: 1200, dim: 3, seed: 6 };
    let ds = build_dataset(&spec).unwrap();
    let m = build_matroid(&MatroidSpec::Uniform(5), &ds);
    let k = 5;
    let one_round = run_pipeline(
        &ds, &m, k, Objective::Sum,
        pipe(
            Setting::MapReduce { workers: 8, budget: Budget::Clusters(8), second_round_tau: None },
            Finisher::LocalSearch { gamma: 0.0 },
        ),
        7,
    ).unwrap();
    let two_round = run_pipeline(
        &ds, &m, k, Objective::Sum,
        pipe(
            Setting::MapReduce {
                workers: 8,
                budget: Budget::Clusters(8),
                second_round_tau: Some(16),
            },
            Finisher::LocalSearch { gamma: 0.0 },
        ),
        7,
    ).unwrap();
    assert!(two_round.coreset_size <= one_round.coreset_size);
    assert!(two_round.diversity >= 0.8 * one_round.diversity);
    assert_eq!(two_round.extra["rounds"], 2.0);
}

#[test]
fn dataset_permutation_stability() {
    // the paper permutes the input before every run; quality must be stable
    let spec = DatasetSpec::Wikisim { n: 800, seed: 8 };
    let ds = build_dataset(&spec).unwrap();
    let m = build_matroid(&MatroidSpec::Transversal, &ds);
    let k = 6;
    let mut divs = Vec::new();
    for seed in 0..4u64 {
        let out = run_pipeline(
            &ds, &m, k, Objective::Sum,
            pipe(
                Setting::Stream { mode: StreamMode::Tau(24) },
                Finisher::LocalSearch { gamma: 0.0 },
            ),
            seed,
        ).unwrap();
        divs.push(out.diversity);
    }
    let max = divs.iter().cloned().fold(f64::MIN, f64::max);
    let min = divs.iter().cloned().fold(f64::MAX, f64::min);
    assert!(min >= 0.7 * max, "unstable across permutations: {divs:?}");
}
